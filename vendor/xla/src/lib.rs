//! Stub of the `xla` PJRT bindings used by `kvaccel::runtime`.
//!
//! The offline build image carries no PJRT/xla_extension shared library,
//! so this crate provides the exact API surface the runtime loader calls
//! and fails at `PjRtClient::cpu()`. Every caller already handles that
//! error path: `XlaRuntime::load` returns `Err`, the experiments default
//! to `EngineMode::Rust`, and the runtime tests skip with a message.
//! Swapping in the real bindings is a one-line change in
//! `rust/Cargo.toml`; no source edits are needed.

use std::fmt;

pub struct Error(String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error("xla stub: PJRT runtime not available in this build (vendor/xla)".to_string())
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1(_values: &[u32]) -> Literal {
        Literal { _private: () }
    }

    pub fn scalar(_value: u32) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal)> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable())
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}
