//! Offline drop-in subset of the `anyhow` crate.
//!
//! The build image has no crates.io access, so this vendored shim
//! provides the API surface the workspace actually uses: `Result`,
//! `Error` (with a context chain), the `anyhow!` / `bail!` macros, and
//! the `Context` extension trait. Formatting follows anyhow's
//! conventions: `{}` prints the outermost message, `{:#}` prints the
//! full `outer: inner: root` chain, `{:?}` prints the message plus a
//! `Caused by:` list.

use std::error::Error as StdError;
use std::fmt;

/// Error with a context chain; outermost message first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut it = self.chain.iter();
        if let Some(first) = it.next() {
            write!(f, "{first}")?;
        }
        let rest: Vec<&String> = it.collect();
        if !rest.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in rest.iter().enumerate() {
                if rest.len() > 1 {
                    write!(f, "\n    {i}: {cause}")?;
                } else {
                    write!(f, "\n    {cause}")?;
                }
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a `Result` or `Option`, as in anyhow.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Result::<(), _>::Err(io_err())
            .context("outer")
            .unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: missing");
    }

    #[test]
    fn macro_formats() {
        let e = anyhow!("x = {}", 42);
        assert_eq!(format!("{e}"), "x = 42");
        let v = 7;
        let e = anyhow!("inline {v}");
        assert_eq!(format!("{e}"), "inline 7");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn bail_returns_early() {
        fn inner(fail: bool) -> Result<u32> {
            if fail {
                bail!("nope {}", 1);
            }
            Ok(3)
        }
        assert_eq!(inner(false).unwrap(), 3);
        assert_eq!(format!("{}", inner(true).unwrap_err()), "nope 1");
    }
}
