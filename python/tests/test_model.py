"""L2 graph correctness: compaction_merge + bloom_build vs oracles,
plus the AOT lowering path itself (HLO text emission)."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import aot, model
from compile.kernels import ref


def _merge(keys, tags):
    sk, stg, kp = model.compaction_merge(jnp.asarray(keys), jnp.asarray(tags))
    return np.asarray(sk), np.asarray(stg), np.asarray(kp)


class TestCompactionMerge:
    def test_matches_ref(self):
        rng = np.random.default_rng(0)
        keys = rng.integers(0, 2**32 - 1, size=(2, 256), dtype=np.uint32)
        tags = rng.integers(0, 2**32, size=(2, 256), dtype=np.uint32)
        got = _merge(keys, tags)
        want = ref.compaction_merge_ref(keys, tags)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)

    def test_newest_version_wins(self):
        # Same key appears three times; lower tag == newer. The keep mask
        # must select exactly the lowest-tag copy.
        keys = np.array([[5, 9, 5, 5, 1, 2, 3, 4]], dtype=np.uint32)
        tags = np.array([[30, 1, 10, 20, 0, 0, 0, 0]], dtype=np.uint32)
        sk, stg, kp = _merge(keys, tags)
        kept = [(k, t) for k, t, m in zip(sk[0], stg[0], kp[0]) if m]
        assert (np.uint32(5), np.uint32(10)) in kept
        assert (np.uint32(5), np.uint32(20)) not in kept
        assert (np.uint32(5), np.uint32(30)) not in kept
        # every distinct key kept exactly once
        assert sorted(k for k, _ in kept) == [1, 2, 3, 4, 5, 9]

    def test_keep_mask_counts_distinct_keys(self):
        rng = np.random.default_rng(1)
        keys = rng.integers(0, 50, size=(1, 512), dtype=np.uint32)
        tags = np.arange(512, dtype=np.uint32)[None]
        _, _, kp = _merge(keys, tags)
        assert kp.sum() == len(np.unique(keys))

    def test_sorted_output(self):
        rng = np.random.default_rng(2)
        keys = rng.integers(0, 2**32 - 1, size=(3, 128), dtype=np.uint32)
        tags = rng.integers(0, 2**32, size=(3, 128), dtype=np.uint32)
        sk, _, _ = _merge(keys, tags)
        assert (np.diff(sk.astype(np.int64), axis=1) >= 0).all()

    def test_pad_key_sorts_last(self):
        keys = np.array(
            [[model.PAD_KEY, 3, model.PAD_KEY, 1]], dtype=np.uint32
        )
        tags = np.array([[0, 0, 1, 0]], dtype=np.uint32)
        sk, _, _ = _merge(keys, tags)
        np.testing.assert_array_equal(
            sk[0], [1, 3, model.PAD_KEY, model.PAD_KEY]
        )


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    logn=st.integers(2, 9),
    key_universe=st.sampled_from([4, 1000, 2**32 - 1]),
    seed=st.integers(0, 2**31),
)
def test_merge_matches_ref_random(b, logn, key_universe, seed):
    rng = np.random.default_rng(seed)
    n = 2**logn
    keys = rng.integers(0, key_universe, size=(b, n), dtype=np.uint32)
    # distinct tags per row mimic the Rust packing (position index)
    tags = np.tile(np.arange(n, dtype=np.uint32), (b, 1))
    got = _merge(keys, tags)
    want = ref.compaction_merge_ref(keys, tags)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


class TestBloomBuild:
    @pytest.mark.parametrize("valid", [0, 1, 100, 256])
    def test_matches_ref_with_padding(self, valid):
        rng = np.random.default_rng(5)
        keys = rng.integers(0, 2**32 - 1, size=(1, 256), dtype=np.uint32)
        got = np.asarray(
            model.bloom_build(
                jnp.asarray(keys),
                jnp.uint32(valid),
                num_probes=7,
                num_bits=2048,
            )
        )
        want = ref.bloom_bitmap_ref(keys, 7, 2048, valid=valid)
        np.testing.assert_array_equal(got, want)

    def test_no_false_negatives(self):
        rng = np.random.default_rng(6)
        keys = rng.integers(0, 2**32 - 1, size=(1, 128), dtype=np.uint32)
        words = np.asarray(
            model.bloom_build(
                jnp.asarray(keys), jnp.uint32(128), num_probes=7,
                num_bits=2048,
            )
        )
        probes = ref.bloom_probes_ref(keys, 7, 2048)[0]
        for pos in probes.reshape(-1):
            assert (words[pos // 32] >> np.uint32(pos % 32)) & 1

    def test_empty_filter_is_zero(self):
        keys = jnp.zeros((1, 64), dtype=jnp.uint32)
        words = np.asarray(
            model.bloom_build(keys, jnp.uint32(0), num_probes=7,
                              num_bits=1024)
        )
        assert (words == 0).all()


class TestAotLowering:
    def test_merge_hlo_text_parses(self):
        text = aot.lower_merge(1, 64)
        assert "HloModule" in text
        assert "u64" in text  # the packed lanes made it into the module

    def test_bloom_hlo_text_parses(self):
        text = aot.lower_bloom(64, 3, 256)
        assert "HloModule" in text

    def test_merge_artifact_is_deterministic(self):
        assert aot.lower_merge(1, 32) == aot.lower_merge(1, 32)
