"""L1 kernel correctness: Pallas kernels vs pure-numpy oracles.

Hypothesis sweeps shapes (all power-of-two widths) and adversarial value
distributions; comparisons are exact (integer workloads).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.bitonic import (
    bitonic_sort,
    sort_network_stages,
    stage_count,
)
from compile.kernels.bloom import bloom_probes


def _sort(x: np.ndarray) -> np.ndarray:
    return np.asarray(bitonic_sort(jnp.asarray(x)))


# ---------------------------------------------------------------------------
# bitonic_sort
# ---------------------------------------------------------------------------

class TestBitonicBasics:
    def test_already_sorted(self):
        x = np.arange(64, dtype=np.uint64)[None]
        np.testing.assert_array_equal(_sort(x), x)

    def test_reverse_sorted(self):
        x = np.arange(64, dtype=np.uint64)[::-1].copy()[None]
        np.testing.assert_array_equal(_sort(x), np.sort(x, axis=-1))

    def test_all_equal(self):
        x = np.full((2, 128), 7, dtype=np.uint64)
        np.testing.assert_array_equal(_sort(x), x)

    def test_u64_extremes(self):
        x = np.array(
            [[0, 2**64 - 1, 1, 2**63, 2**32, 2**32 - 1, 5, 2**63 - 1]],
            dtype=np.uint64,
        )
        np.testing.assert_array_equal(_sort(x), ref.sort_ref(x))

    def test_batch_rows_independent(self):
        rng = np.random.default_rng(1)
        x = rng.integers(0, 2**64, size=(8, 256), dtype=np.uint64)
        np.testing.assert_array_equal(_sort(x), ref.sort_ref(x))

    def test_width_must_be_pow2(self):
        with pytest.raises(ValueError, match="power of two"):
            bitonic_sort(jnp.zeros((1, 100), dtype=jnp.uint64))

    def test_rank_must_be_2(self):
        with pytest.raises(ValueError, match="expected"):
            bitonic_sort(jnp.zeros((4,), dtype=jnp.uint64))

    def test_is_permutation(self):
        rng = np.random.default_rng(2)
        x = rng.integers(0, 1000, size=(4, 512), dtype=np.uint64)
        out = _sort(x)
        for row_in, row_out in zip(x, out):
            np.testing.assert_array_equal(
                np.sort(row_in), row_out
            )


class TestSortNetworkSchedule:
    @pytest.mark.parametrize("n,expected", [(2, 1), (4, 3), (8, 6),
                                            (1024, 55), (4096, 78)])
    def test_stage_count(self, n, expected):
        assert stage_count(n) == expected
        assert len(sort_network_stages(n)) == expected

    def test_stage_count_rejects_non_pow2(self):
        with pytest.raises(ValueError):
            stage_count(3)

    def test_schedule_shape(self):
        stages = sort_network_stages(16)
        # k doubles 2..16, j halves k/2..1
        assert stages[0] == (2, 1)
        assert stages[-1] == (16, 1)
        for k, j in stages:
            assert k & (k - 1) == 0 and j & (j - 1) == 0 and j < k


@settings(max_examples=30, deadline=None)
@given(
    b=st.integers(1, 4),
    logn=st.integers(1, 10),
    seed=st.integers(0, 2**31),
)
def test_bitonic_matches_ref_random(b, logn, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 2**64, size=(b, 2**logn), dtype=np.uint64)
    np.testing.assert_array_equal(_sort(x), ref.sort_ref(x))


@settings(max_examples=15, deadline=None)
@given(
    logn=st.integers(3, 9),
    dup_universe=st.integers(1, 16),
    seed=st.integers(0, 2**31),
)
def test_bitonic_heavy_duplicates(logn, dup_universe, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, dup_universe, size=(2, 2**logn), dtype=np.uint64)
    np.testing.assert_array_equal(_sort(x), ref.sort_ref(x))


# ---------------------------------------------------------------------------
# bloom_probes
# ---------------------------------------------------------------------------

class TestBloomProbes:
    def test_matches_ref(self):
        rng = np.random.default_rng(3)
        keys = rng.integers(0, 2**32, size=(2, 64), dtype=np.uint32)
        out = np.asarray(
            bloom_probes(jnp.asarray(keys), num_probes=7, num_bits=1024)
        )
        np.testing.assert_array_equal(
            out, ref.bloom_probes_ref(keys, 7, 1024)
        )

    def test_positions_in_range(self):
        rng = np.random.default_rng(4)
        keys = rng.integers(0, 2**32, size=(1, 256), dtype=np.uint32)
        out = np.asarray(
            bloom_probes(jnp.asarray(keys), num_probes=5, num_bits=333)
        )
        assert (out < 333).all()

    def test_deterministic(self):
        keys = jnp.asarray(np.arange(32, dtype=np.uint32)[None])
        a = bloom_probes(keys, num_probes=3, num_bits=64)
        b = bloom_probes(keys, num_probes=3, num_bits=64)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_rejects_rank1(self):
        with pytest.raises(ValueError):
            bloom_probes(
                jnp.zeros((8,), dtype=jnp.uint32), num_probes=3, num_bits=64
            )


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([8, 32, 128, 512]),
    probes=st.integers(1, 10),
    logm=st.integers(6, 16),
    seed=st.integers(0, 2**31),
)
def test_bloom_probes_matches_ref_random(n, probes, logm, seed):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 2**32, size=(1, n), dtype=np.uint32)
    out = np.asarray(
        bloom_probes(jnp.asarray(keys), num_probes=probes, num_bits=2**logm)
    )
    np.testing.assert_array_equal(
        out, ref.bloom_probes_ref(keys, probes, 2**logm)
    )
