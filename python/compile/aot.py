"""AOT-lower the L2 graphs to HLO *text* artifacts for the Rust runtime.

HLO text (NOT ``lowered.compile().serialize()`` and NOT a serialized
HloModuleProto) is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the HLO text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/load_hlo and its README.

Artifacts (shape-specialized; the Rust runtime picks by name):

  merge_b{B}_n{N}.hlo.txt   compaction_merge over (B, N) u32 keys+tags
  bloom_n{N}_p{P}_m{M}.hlo.txt  bloom_build over (1, N) keys, M bits, P probes
  manifest.json             machine-readable list of the above

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

# Shape menu. Merge windows: the compaction path feeds W-way windows of
# N total lanes; batch B amortizes dispatch. Bloom: one SST's key batch
# (memtable 128 MB / 4 KB values = 32768 entries max), 10 bits/key, 7
# probes (RocksDB's defaults for 10 bits/key).
MERGE_SHAPES = [(1, 1024), (1, 4096), (4, 4096), (1, 8192)]
BLOOM_SHAPES = [(4096, 7, 40960), (32768, 7, 327680)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_merge(b: int, n: int) -> str:
    fn = jax.jit(model.compaction_merge)
    return to_hlo_text(fn.lower(*model.merge_example_args(b, n)))


def lower_bloom(n: int, probes: int, bits: int) -> str:
    fn = jax.jit(
        functools.partial(
            model.bloom_build, num_probes=probes, num_bits=bits
        )
    )
    return to_hlo_text(fn.lower(*model.bloom_example_args(n)))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    # Back-compat with the scaffold Makefile's `--out path/model.hlo.txt`:
    ap.add_argument("--out", default=None, help="also write the default "
                    "merge artifact to this exact path")
    args = ap.parse_args()
    out_dir = (
        os.path.dirname(args.out) if args.out else args.out_dir
    ) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"merge": [], "bloom": []}
    for b, n in MERGE_SHAPES:
        text = lower_merge(b, n)
        name = f"merge_b{b}_n{n}.hlo.txt"
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        manifest["merge"].append({"b": b, "n": n, "file": name})
        print(f"wrote {name} ({len(text)} chars)")
    for n, p, m in BLOOM_SHAPES:
        text = lower_bloom(n, p, m)
        name = f"bloom_n{n}_p{p}_m{m}.hlo.txt"
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        manifest["bloom"].append(
            {"n": n, "probes": p, "bits": m, "file": name}
        )
        print(f"wrote {name} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if args.out:
        # Marker file the Makefile stamps freshness on.
        with open(args.out, "w") as f:
            f.write(lower_merge(1, 4096))
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
