"""L1 Pallas kernel: batched double-hash probe positions for bloom filters.

SST filter blocks are built once per flush/compaction output over the full
batch of keys in the file — a data-parallel hash workload that rides along
with the merge offload (the host only ORs the resulting bitmap words).

Double hashing (Kirsch-Mitzenmatter): probe_i = h1(key) + i * h2(key) mod m
with h1/h2 two multiplicative hashes.  Everything is elementwise u32
arithmetic — one (1, N) VMEM tile per grid step, VPU only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bloom_probes", "H1_MULT", "H2_MULT"]

# Knuth-style odd multiplicative constants (u32).
H1_MULT = 0x9E3779B1
H2_MULT = 0x85EBCA77


def _probe_tile(keys: jax.Array, num_probes: int, num_bits: int) -> jax.Array:
    """keys: (1, N) uint32 -> (num_probes, N) uint32 probe bit positions."""
    k = keys.astype(jnp.uint32)
    h1 = (k * jnp.uint32(H1_MULT)) >> jnp.uint32(17)
    h2 = ((k * jnp.uint32(H2_MULT)) >> jnp.uint32(15)) | jnp.uint32(1)
    i = jax.lax.broadcasted_iota(jnp.uint32, (num_probes, keys.shape[-1]), 0)
    return (h1 + i * h2) % jnp.uint32(num_bits)


def _kernel(num_probes, num_bits, x_ref, o_ref):
    o_ref[...] = _probe_tile(x_ref[...], num_probes, num_bits)[None]


@functools.partial(
    jax.jit, static_argnames=("num_probes", "num_bits", "interpret")
)
def bloom_probes(
    keys: jax.Array,
    *,
    num_probes: int,
    num_bits: int,
    interpret: bool = True,
) -> jax.Array:
    """Probe positions for each key.

    keys: (B, N) uint32 -> (B, num_probes, N) uint32, values < num_bits.
    """
    if keys.ndim != 2:
        raise ValueError(f"expected (B, N), got {keys.shape}")
    b, n = keys.shape
    kern = functools.partial(_kernel, num_probes, num_bits)
    return pl.pallas_call(
        kern,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, num_probes, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, num_probes, n), jnp.uint32),
        interpret=interpret,
    )(keys)
