"""Pure-jnp/numpy oracles for the Pallas kernels and the L2 graphs.

These are the CORE correctness signal: every kernel and every lowered
artifact is pytest-compared against these references (exactly, since all
the workloads are integer).
"""

from __future__ import annotations

import numpy as np

from .bloom import H1_MULT, H2_MULT

__all__ = [
    "sort_ref",
    "bloom_probes_ref",
    "bloom_bitmap_ref",
    "compaction_merge_ref",
]

_U32 = np.uint64(0xFFFFFFFF)


def sort_ref(x: np.ndarray) -> np.ndarray:
    """Row-wise ascending sort — oracle for kernels.bitonic.bitonic_sort."""
    return np.sort(np.asarray(x), axis=-1)


def bloom_probes_ref(
    keys: np.ndarray, num_probes: int, num_bits: int
) -> np.ndarray:
    """(B, N) u32 -> (B, num_probes, N) u32 — oracle for bloom_probes."""
    k = np.asarray(keys, dtype=np.uint32)
    h1 = (k * np.uint32(H1_MULT)) >> np.uint32(17)
    h2 = ((k * np.uint32(H2_MULT)) >> np.uint32(15)) | np.uint32(1)
    i = np.arange(num_probes, dtype=np.uint32)[None, :, None]
    return (h1[:, None, :] + i * h2[:, None, :]) % np.uint32(num_bits)


def bloom_bitmap_ref(
    keys: np.ndarray, num_probes: int, num_bits: int, valid: int | None = None
) -> np.ndarray:
    """Packed u32 bitmap words — oracle for model.bloom_build.

    ``valid``: only the first ``valid`` keys contribute (padding dropped).
    """
    keys = np.asarray(keys, dtype=np.uint32).reshape(-1)
    if valid is not None:
        keys = keys[:valid]
    assert num_bits % 32 == 0
    words = np.zeros(num_bits // 32, dtype=np.uint32)
    probes = bloom_probes_ref(keys[None], num_probes, num_bits)[0]
    for pos in probes.reshape(-1):
        words[pos // 32] |= np.uint32(1) << np.uint32(pos % 32)
    return words


def compaction_merge_ref(
    keys: np.ndarray, tags: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Oracle for model.compaction_merge.

    Sort each row by (key, tag) ascending; keep mask marks the first
    occurrence of each key in the sorted row (lower tag == newer version by
    the Rust packing convention, so "first" == newest).
    Returns (sorted_keys u32, sorted_tags u32, keep u32) each (B, N).
    """
    keys = np.asarray(keys, dtype=np.uint32)
    tags = np.asarray(tags, dtype=np.uint32)
    packed = (keys.astype(np.uint64) << np.uint64(32)) | tags.astype(np.uint64)
    packed = np.sort(packed, axis=-1)
    skeys = (packed >> np.uint64(32)).astype(np.uint32)
    stags = (packed & _U32).astype(np.uint32)
    keep = np.ones_like(skeys)
    keep[:, 1:] = (skeys[:, 1:] != skeys[:, :-1]).astype(np.uint32)
    return skeys, stags, keep
