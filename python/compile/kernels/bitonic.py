"""L1 Pallas kernel: batched bitonic merge-sort over packed u64 lanes.

This is the compute hot-spot of LSM compaction (the merge-sort the paper's
hardware-acceleration lineage offloads to FPGA/GPU).  Hardware adaptation
for TPU (see DESIGN.md §Hardware-Adaptation):

- One (1, N) tile of packed ``key(32) | tag(32)`` u64 lanes stays resident
  in VMEM for the entire sorting network; ``BlockSpec`` expresses the
  HBM<->VMEM schedule that CUDA implementations express with threadblocks.
- Each bitonic stage is a branch-free compare-exchange implemented with a
  reshape + ``minimum``/``maximum`` pair — pure VPU work, no MXU, no
  data-dependent control flow.
- The batch dimension B is the Pallas grid: independent merge windows map
  to grid steps exactly like independent CUDA blocks.

``interpret=True`` is mandatory on this image: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers the network to plain
HLO ops which round-trip through the HLO-text AOT path into the Rust
runtime (see python/compile/aot.py).

N must be a power of two.  Sorting ascending by the full u64 puts equal
keys in ascending-tag order; the Rust coordinator packs tags so that this
order encodes version recency (see rust/src/runtime/merge.rs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bitonic_sort", "sort_network_stages", "stage_count"]


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def stage_count(n: int) -> int:
    """Number of compare-exchange stages the network runs for width n."""
    if not _is_pow2(n):
        raise ValueError(f"bitonic width must be a power of two, got {n}")
    log = n.bit_length() - 1
    return log * (log + 1) // 2


def _compare_exchange(v: jax.Array, k: int, j: int) -> jax.Array:
    """One bitonic stage over the last axis of ``v`` (shape (..., n)).

    Pairs elements at distance ``j`` (a power of two) by reshaping the lane
    axis to (n // (2j), 2, j); the sort direction of a pair starting at
    lane i is ascending iff ``i & k == 0``, which is constant within each
    reshaped block, so the direction vector is a (n // (2j), 1, 1) iota
    predicate — fully branch-free.
    """
    *lead, n = v.shape
    blocks = n // (2 * j)
    w = v.reshape(*lead, blocks, 2, j)
    a = w[..., 0, :]
    b = w[..., 1, :]
    # Lane index of the first element of each block is block_idx * 2j;
    # its bit `k` selects the direction for the whole block.
    block_idx = jax.lax.broadcasted_iota(jnp.uint32, (blocks, 1), 0)
    ascending = (block_idx * (2 * j)) & k == 0
    lo = jnp.minimum(a, b)
    hi = jnp.maximum(a, b)
    first = jnp.where(ascending, lo, hi)
    second = jnp.where(ascending, hi, lo)
    out = jnp.stack([first, second], axis=-2)
    return out.reshape(*lead, n)


def sort_network_stages(n: int) -> list[tuple[int, int]]:
    """The (k, j) schedule of the bitonic network for width n."""
    stages = []
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            stages.append((k, j))
            j //= 2
        k *= 2
    return stages


def _sort_tile(v: jax.Array) -> jax.Array:
    n = v.shape[-1]
    for k, j in sort_network_stages(n):
        v = _compare_exchange(v, k, j)
    return v


def _kernel(x_ref, o_ref):
    o_ref[...] = _sort_tile(x_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitonic_sort(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Sort each row of ``x`` (shape (B, N) uint64) ascending.

    B is the Pallas grid; each grid step sorts one (1, N) VMEM tile.
    """
    if x.ndim != 2:
        raise ValueError(f"expected (B, N), got shape {x.shape}")
    b, n = x.shape
    if not _is_pow2(n):
        raise ValueError(f"N must be a power of two, got {n}")
    return pl.pallas_call(
        _kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), x.dtype),
        interpret=interpret,
    )(x)
