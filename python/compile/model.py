"""L2: the compaction-offload compute graphs, calling the L1 kernels.

These are the exact graphs the Rust coordinator executes at runtime via
PJRT (lowered once to HLO text by aot.py).  Two graphs:

``compaction_merge``
    One merge window of LSM compaction: B batches of N packed
    (key, recency-tag) lanes drawn from the victim + overlapping SSTs.
    The Rust side packs tags so that *lower tag == newer version*; sorting
    ascending by the packed u64 therefore groups duplicates newest-first
    and the keep-mask (first occurrence per key) implements
    newest-version-wins dedup — the full semantic of one compaction merge
    step, not just a sort.

``bloom_build``
    Build the packed bloom-filter bitmap words for one SST's key batch
    (double hashing via kernels.bloom, scatter-OR into num_bits/32 u32
    words).  Padding keys are routed out-of-range and dropped by the
    scatter, so one artifact serves any fill count <= N.

Python/JAX run ONLY at build time; the request path is pure Rust.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.bitonic import bitonic_sort
from .kernels.bloom import bloom_probes

__all__ = ["compaction_merge", "bloom_build", "PAD_KEY"]

# Keys are 4 B (paper's db_bench config). 0xFFFFFFFF is reserved as the
# padding sentinel: it sorts last and the Rust side never emits it.
PAD_KEY = 0xFFFFFFFF


def compaction_merge(keys: jax.Array, tags: jax.Array):
    """Merge window: (B, N) u32 keys + (B, N) u32 tags.

    Returns (sorted_keys, sorted_tags, keep) — all (B, N) u32.  ``keep`` is
    1 on the first (== newest, by tag packing) occurrence of each key.
    """
    packed = (keys.astype(jnp.uint64) << jnp.uint64(32)) | tags.astype(
        jnp.uint64
    )
    packed = bitonic_sort(packed)
    sorted_keys = (packed >> jnp.uint64(32)).astype(jnp.uint32)
    sorted_tags = (packed & jnp.uint64(0xFFFFFFFF)).astype(jnp.uint32)
    first = jnp.concatenate(
        [
            jnp.ones_like(sorted_keys[:, :1]),
            (sorted_keys[:, 1:] != sorted_keys[:, :-1]).astype(jnp.uint32),
        ],
        axis=1,
    )
    return sorted_keys, sorted_tags, first


@functools.partial(jax.jit, static_argnames=("num_probes", "num_bits"))
def bloom_build(keys: jax.Array, valid: jax.Array, *, num_probes: int,
                num_bits: int):
    """Bloom bitmap for one SST: keys (1, N) u32, valid () u32 live count.

    Returns (num_bits // 32,) u32 packed words.  Positions of keys at index
    >= valid are pushed out of range and dropped by the scatter.
    """
    assert num_bits % 32 == 0
    n = keys.shape[-1]
    probes = bloom_probes(keys, num_probes=num_probes, num_bits=num_bits)
    # (1, P, N) -> (P, N); mask padding lanes out-of-bounds (drop mode).
    probes = probes[0]
    lane = jax.lax.broadcasted_iota(jnp.uint32, probes.shape, 1)
    oob = jnp.uint32(num_bits)
    pos = jnp.where(lane < valid, probes, oob).reshape(-1).astype(jnp.int32)
    # Scatter into a bit array: set(1) is idempotent under probe collisions
    # and mode="drop" discards the padding lanes routed to num_bits.
    bits = jnp.zeros((num_bits,), dtype=jnp.uint32)
    bits = bits.at[pos].set(jnp.uint32(1), mode="drop")
    # Pack 32 bits -> one u32 word (little-endian bit order, matching the
    # Rust-side probe check `word >> (pos % 32) & 1`).
    weights = jnp.left_shift(
        jnp.uint32(1), jnp.arange(32, dtype=jnp.uint32)
    )
    words = (bits.reshape(num_bits // 32, 32) * weights[None, :]).sum(
        axis=1, dtype=jnp.uint32
    )
    return words


def merge_example_args(b: int, n: int):
    spec = jax.ShapeDtypeStruct((b, n), jnp.uint32)
    return (spec, spec)


def bloom_example_args(n: int):
    return (
        jax.ShapeDtypeStruct((1, n), jnp.uint32),
        jax.ShapeDtypeStruct((), jnp.uint32),
    )
