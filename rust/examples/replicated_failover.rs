//! Replicated failover walkthrough: three KVACCEL nodes behind the
//! CDC shipper, kill the primary at a fixed virtual time mid-workload,
//! promote the most caught-up replica, keep writing through the new
//! primary, then rejoin the crashed node via Merkle anti-entropy and
//! verify the post-repair divergence is zero.
//!
//!     cargo run --release --example replicated_failover

use kvaccel::engine::{EngineBuilder, KvEngine};
use kvaccel::env::SimEnv;
use kvaccel::lsm::{LsmOptions, ValueDesc};
use kvaccel::repl::{ReadPolicy, ReplConfig, ReplicatedDb};
use kvaccel::sim::MILLIS;
use kvaccel::ssd::SsdConfig;

const KEY_SPACE: u32 = 10_000;
const CRASH_AT: u64 = 200 * MILLIS; // fixed virtual crash time

fn main() -> anyhow::Result<()> {
    let cfg = ReplConfig {
        replicas: 3,
        read_policy: ReadPolicy::ReadYourWrites,
        key_space: KEY_SPACE - 1,
        seed: 7,
        ..ReplConfig::default()
    };
    // small memtables so the primary actually stalls and redirects
    let mut db = ReplicatedDb::new(cfg, |_| {
        EngineBuilder::kvaccel().opts(LsmOptions::small_for_test()).build()
    });
    let mut env = SimEnv::new(7, SsdConfig::default());

    // phase 1: write through the primary until the fixed crash time;
    // the shipper tails the WAL and replicas apply behind the link
    let mut t = 0;
    let mut k = 0u32;
    while t < CRASH_AT {
        t = db.put(&mut env, t, k % KEY_SPACE, ValueDesc::new(k, 2048)).done;
        k += 1;
    }
    println!(
        "wrote {k} pairs to node {} by {:.1} virtual ms ({} records captured)",
        db.primary_index(),
        t as f64 / 1e6,
        db.log_len()
    );

    // -- primary dies --
    let fo = db.fail_primary(&mut env, CRASH_AT);
    println!(
        "crash node {} at {:.1} ms: node {} promoted after {:.3} ms blackout, \
         {} committed records were behind",
        fo.crashed,
        fo.at as f64 / 1e6,
        fo.promoted,
        fo.blackout_ns as f64 / 1e6,
        fo.lag_records
    );

    // phase 2: the new primary keeps taking writes (gated until the
    // election window closes), diverging past the dead node's state
    let post_from = k;
    for _ in 0..1_000 {
        t = db.put(&mut env, t, k % KEY_SPACE, ValueDesc::new(k, 2048)).done;
        k += 1;
    }
    // read-your-writes still holds across the failover
    let probe = (post_from + 500) % KEY_SPACE;
    let (got, nt) = db.get(&mut env, t, probe);
    t = nt;
    assert_eq!(
        got,
        Some(ValueDesc::new(post_from + 500, 2048)),
        "post-failover write invisible"
    );
    println!("wrote 1000 more through node {}, reads stay consistent", fo.promoted);

    // phase 3: the crashed node rejoins — recover its durable image,
    // then Merkle range exchange ships only the differing leaves
    let rep = db.rejoin_crashed(&mut env, t).expect("rejoin failed");
    let shipped = rep.hash_bytes + rep.entry_bytes;
    println!(
        "anti-entropy: {}/{} leaves dirty, {} entries shipped + {} deleted, \
         {} B on the wire vs {} B full resync ({:.1}% saved)",
        rep.dirty_leaves,
        rep.total_leaves,
        rep.entries_shipped,
        rep.keys_deleted,
        shipped,
        rep.full_resync_bytes,
        100.0 * (1.0 - shipped as f64 / rep.full_resync_bytes as f64)
    );
    assert!(shipped < rep.full_resync_bytes, "repair must beat a full resync");

    // drain the pipeline and prove the repaired node mirrors the primary
    let t_end = db.finish(&mut env, rep.done.max(t))?;
    let d_old = db.node_digest(&mut env, t_end, fo.crashed);
    let d_new = db.node_digest(&mut env, t_end, fo.promoted);
    assert_eq!(d_old, d_new, "post-repair divergence must be zero");
    println!("post-repair divergence: 0 (Merkle roots match)");
    println!("replicated_failover OK");
    Ok(())
}
