//! END-TO-END VALIDATION DRIVER — proves all three layers compose on a
//! real workload:
//!
//!   L1/L2: the Pallas bitonic-merge + bloom graphs, AOT-lowered by
//!          `make artifacts`, executed here through PJRT on every
//!          compaction and every SST filter build;
//!   L3:    the full KVACCEL system vs RocksDB vs ADOC on the simulated
//!          dual-interface SSD, workload A (fillrandom), reporting the
//!          paper's headline metric (throughput + efficiency gain).
//!
//!     make artifacts && cargo run --release --example e2e_validation
//!
//! Recorded in EXPERIMENTS.md §End-to-end.

// real-time harness: wall-clock timing is the point here, so the
// clippy.toml wall-clock ban is lifted for this file
#![allow(clippy::disallowed_methods, clippy::disallowed_types)]

use kvaccel::baselines::SystemKind;
use kvaccel::engine::{EngineBuilder, EngineStats};
use kvaccel::env::SimEnv;
use kvaccel::kvaccel::RollbackScheme;
use kvaccel::lsm::LsmOptions;
use kvaccel::runtime::{default_artifacts_dir, BloomBuilder, MergeEngine, XlaRuntime};
use kvaccel::sim::NS_PER_SEC;
use kvaccel::ssd::SsdConfig;
use kvaccel::util::Args;
use kvaccel::workload::{fillrandom, BenchConfig};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let seconds = args.get_u64("seconds", 60);

    // ---- layer check: load + execute the AOT artifacts ----
    let rt = Arc::new(XlaRuntime::load(default_artifacts_dir())?);
    println!(
        "runtime loaded: merge shapes {:?}, bloom shapes {:?}",
        rt.merge_shapes(),
        rt.bloom_shapes()
    );
    let engine = MergeEngine::xla(rt.clone())?;
    // sanity: artifact and Rust reference agree on a random window
    let pairs: Vec<(u32, u32)> = (0..4096u32).map(|i| (i.wrapping_mul(2654435761) % 10_000, i)).collect();
    let via_xla = engine.merge_window(&pairs)?;
    let via_rust = kvaccel::runtime::merge::merge_window_rust(&pairs);
    assert_eq!(via_xla, via_rust, "XLA artifact diverged from reference");
    println!("merge artifact == rust reference on a 4096-lane window ✓\n");

    // ---- end-to-end comparison on the XLA engine ----
    let cfg = BenchConfig { duration: seconds * NS_PER_SEC, ..Default::default() };
    let mut rows = Vec::new();
    for kind in [
        SystemKind::RocksDb { slowdown: true },
        SystemKind::Adoc,
        SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
    ] {
        let mut sys = EngineBuilder::new(kind)
            .opts(LsmOptions::default().with_threads(4))
            .merge_engine(MergeEngine::xla(rt.clone())?)
            .bloom_builder(BloomBuilder::xla(rt.clone()))
            .build();
        let mut env = SimEnv::new(42, SsdConfig::default());
        let wall = std::time::Instant::now();
        let r = fillrandom(&mut *sys, &mut env, &cfg);
        println!(
            "{:<10} {:>9.1} write ops/s  P99 {:>9.1} us  CPU {:>5.1}%  eff {:>5.2}  halts {:>3}  [{} compactions via XLA, {:.1}s wall]",
            kind.label(),
            r.write_kops() * 1e3,
            r.write_lat.p99_us,
            r.cpu_percent,
            r.efficiency,
            r.stop_events,
            sys.db_stats().compaction_count,
            wall.elapsed().as_secs_f64(),
        );
        rows.push((kind.label(), r));
    }

    // ---- headline metric ----
    let get = |n: &str| rows.iter().find(|(l, _)| l == n).map(|(_, r)| r).unwrap();
    let (k, a, r) = (get("KVACCEL"), get("ADOC"), get("RocksDB"));
    println!();
    println!(
        "headline: KVACCEL vs ADOC    {:+.1}% throughput, {:+.1}% efficiency (paper: up to +17%, better)",
        100.0 * (k.write_kops() - a.write_kops()) / a.write_kops(),
        100.0 * (k.efficiency - a.efficiency) / a.efficiency,
    );
    println!(
        "headline: KVACCEL vs RocksDB {:+.1}% throughput (paper: up to +37%); KVACCEL halts = {} (paper: zero)",
        100.0 * (k.write_kops() - r.write_kops()) / r.write_kops(),
        k.stop_events,
    );
    assert_eq!(k.stop_events, 0, "KVACCEL must eliminate write halts");
    assert!(k.write_kops() > a.write_kops(), "KVACCEL must beat ADOC on writes");
    assert!(k.efficiency > a.efficiency, "KVACCEL must win efficiency");
    println!("e2e_validation OK — all three layers compose");
    Ok(())
}
