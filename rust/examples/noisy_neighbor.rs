//! Noisy-neighbor QoS demo: one abusive open-loop tenant floods a
//! shared store while three well-behaved closed-loop tenants try to hit
//! their latency targets.
//!
//! Runs the same mixed population twice — QoS monitoring only, then QoS
//! enforced (token-bucket admission + SLO shedding) — and prints the
//! per-tenant breakdown of each run. With QoS off, the abuser's backlog
//! stalls the engine and everyone's p99 collapses with it; with QoS on,
//! the abuser is throttled to its contracted rate, its stale backlog is
//! shed, and the victims keep their tail latency.
//!
//!     cargo run --release --example noisy_neighbor -- --seconds 10 --abuse-rate 30000
//!
//! The `experiment qos-fairness` harness runs the calibrated version of
//! this comparison across LSM/ADOC/KVACCEL and writes BENCH_PR6.json.

use kvaccel::baselines::SystemKind;
use kvaccel::engine::EngineBuilder;
use kvaccel::env::SimEnv;
use kvaccel::lsm::LsmOptions;
use kvaccel::sim::{MILLIS, NS_PER_SEC};
use kvaccel::ssd::SsdConfig;
use kvaccel::util::Args;
use kvaccel::workload::{
    run_spec, BenchConfig, ClientConfig, LoopMode, QosConfig, RunResult, TenantSpec,
    WorkloadSpec,
};

fn spec(cfg: &BenchConfig, abuse_rate: f64, qos: QosConfig) -> WorkloadSpec {
    let mut clients = vec![
        // tenant 0: open-loop abuser offering far more than it is owed
        ClientConfig::writer()
            .with_mode(LoopMode::OpenFixed { ops_per_sec: abuse_rate })
            .with_seed_tag(0xAB5E)
            .with_tenant(0),
    ];
    // tenants 1..=3: polite closed-loop writers with think time
    for v in 0..3u32 {
        clients.push(
            ClientConfig::writer()
                .with_mode(LoopMode::Closed { think: 10 * MILLIS })
                .with_seed_tag(0x51C0 + v as u64)
                .with_tenant(v + 1),
        );
    }
    let mut s = WorkloadSpec::from_bench("noisy-neighbor", cfg).with_clients(clients);
    s.qos = Some(qos);
    s
}

fn tenant_table(cfg: &BenchConfig, admit_ops_s: f64) -> QosConfig {
    let bytes_per_op = 16 + cfg.value_size as u64;
    let rate = (admit_ops_s * bytes_per_op as f64) as u64;
    let mut tenants = vec![TenantSpec::new("abuser")
        .with_rate(rate, (rate / 4).max(bytes_per_op))
        .with_slo_p99(50 * MILLIS)];
    for v in 0..3 {
        tenants.push(TenantSpec::new(format!("victim{v}")).with_slo_p99(50 * MILLIS));
    }
    let mut q = QosConfig::new(tenants);
    q.slo_min_window_ops = 4;
    q
}

fn report(tag: &str, r: &RunResult) {
    println!("== {tag} ==");
    for t in &r.tenants {
        println!(
            "  {:<8} {:>7} ops ({:>8.1}/s)  p50 {:>9.0} us  p99 {:>10.0} us  \
             {:>6} throttled  {:>6} shed",
            t.name, t.ops, t.ops_per_sec, t.lat.p50_us, t.lat.p99_us, t.throttled, t.shed,
        );
    }
    println!(
        "  engine: {} stops ({:.2}s stalled), {} slowdowns\n",
        r.stop_events, r.stopped_s, r.slowdown_events
    );
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let seconds = args.get_u64("seconds", 10);
    let abuse_rate = args.get_f64("abuse-rate", 30_000.0);
    let admit = args.get_f64("admit-rate", 200.0);
    let cfg = BenchConfig {
        duration: seconds * NS_PER_SEC,
        key_space: 200_000,
        ..Default::default()
    };
    println!(
        "noisy neighbor on a pressure-sized LSM: abuser offers {abuse_rate:.0} ops/s, \
         contracted for {admit:.0}; 3 victims at ~100 ops/s each, {seconds} virtual s\n"
    );
    let kind = SystemKind::RocksDb { slowdown: true };

    let mut sys = EngineBuilder::new(kind)
        .opts(LsmOptions::small_for_test().with_threads(2))
        .build();
    let mut env = SimEnv::new(42, SsdConfig::default());
    let off = spec(&cfg, abuse_rate, tenant_table(&cfg, admit).monitor_only());
    report("QoS off (monitor only)", &run_spec(&mut *sys, &mut env, &off));

    let mut sys = EngineBuilder::new(kind)
        .opts(LsmOptions::small_for_test().with_threads(2))
        .build();
    let mut env = SimEnv::new(42, SsdConfig::default());
    let on = spec(&cfg, abuse_rate, tenant_table(&cfg, admit));
    report("QoS on (enforced)", &run_spec(&mut *sys, &mut env, &on));

    println!("shape: the victims' p99 collapses next to the abuser with QoS off,");
    println!("and returns to its isolated level once the bucket + shedder engage.");
    Ok(())
}
