//! Open-loop overload demo: offered load above the Main-LSM's
//! sustainable throughput.
//!
//! A closed-loop driver can never show a write-stall queue — it only
//! issues as fast as the engine completes. With open-loop (fixed-rate)
//! arrivals, requests queue in each client's FIFO while the engine
//! stalls, so latency = queueing delay + service time. On the plain LSM
//! the queueing delay grows without bound; KVACCEL redirects the
//! overflow to the Dev-LSM and keeps the tail bounded.
//!
//!     cargo run --release --example open_loop -- --seconds 20 --rate 50000

use kvaccel::baselines::SystemKind;
use kvaccel::engine::EngineBuilder;
use kvaccel::env::SimEnv;
use kvaccel::kvaccel::RollbackScheme;
use kvaccel::lsm::LsmOptions;
use kvaccel::sim::NS_PER_SEC;
use kvaccel::ssd::SsdConfig;
use kvaccel::util::Args;
use kvaccel::workload::{
    preset_spec, run_spec, BenchConfig, KeyDist, LoopMode,
};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let seconds = args.get_u64("seconds", 20);
    let rate = args.get_f64("rate", 50_000.0);
    let clients = args.get_usize("clients", 4);
    let cfg = BenchConfig {
        duration: seconds * NS_PER_SEC,
        ..Default::default()
    };
    println!(
        "open-loop fillrandom: {clients} clients, {rate:.0} ops/s aggregate, {seconds} virtual s\n"
    );
    for kind in [
        SystemKind::RocksDb { slowdown: true },
        SystemKind::Adoc,
        SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
    ] {
        let spec = preset_spec(
            "A",
            &cfg,
            clients,
            LoopMode::OpenFixed { ops_per_sec: rate },
            KeyDist::Uniform,
        )?;
        let mut sys = EngineBuilder::new(kind)
            .opts(LsmOptions::default().with_threads(4))
            .build();
        let mut env = SimEnv::new(42, SsdConfig::default());
        let r = run_spec(&mut *sys, &mut env, &spec);
        println!("== {} ==", kind.label());
        println!(
            "  served {} writes in {:.1} virtual s ({:.1} Kops/s vs {:.1} offered)",
            r.writes.total,
            r.duration_s,
            r.write_kops(),
            rate / 1e3
        );
        println!(
            "  total write latency p50 {:.0} us  p99 {:.0} us  p999 {:.0} us",
            r.write_lat.p50_us, r.write_lat.p99_us, r.write_lat.p999_us
        );
        println!(
            "  queueing delay      p50 {:.0} us  p99 {:.0} us (time waiting in the FIFO)",
            r.queue_delay.p50_us, r.queue_delay.p99_us
        );
        let series = &r.queue_delay_series_us;
        let show: Vec<String> = series
            .iter()
            .step_by((series.len() / 10).max(1))
            .map(|us| format!("{us:.0}"))
            .collect();
        println!("  mean qdelay/s (us)  [{}]", show.join(", "));
        println!(
            "  stalls: {} halts ({:.2}s), {} slowdowns; redirected {}\n",
            r.stop_events, r.stopped_s, r.slowdown_events, r.redirected_writes
        );
    }
    println!("shape: the LSM rows' queueing delay climbs second over second;");
    println!("KVACCEL redirects under pressure and its tail stays bounded.");
    Ok(())
}
