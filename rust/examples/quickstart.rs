//! Quickstart: open a KVACCEL store through the unified `KvEngine` API,
//! write/read/batch/delete/scan, survive a rollback.
//!
//!     cargo run --release --example quickstart

use kvaccel::engine::{EngineBuilder, EngineStats, KvEngine, WriteBatch};
use kvaccel::env::SimEnv;
use kvaccel::kvaccel::RollbackScheme;
use kvaccel::lsm::ValueDesc;
use kvaccel::ssd::SsdConfig;

fn main() -> anyhow::Result<()> {
    // A KVACCEL store = Main-LSM on the block interface + Dev-LSM write
    // buffer on the KV interface of one simulated dual-interface SSD.
    // Engine choice is a constructor argument: swap `kvaccel_scheme` for
    // `lsm()` or `adoc()` and nothing below changes.
    let mut db = EngineBuilder::kvaccel_scheme(RollbackScheme::Eager).build();
    let mut env = SimEnv::new(7, SsdConfig::default());

    // write 50k pairs (4 B keys / 4 KB values, the paper's config)
    let mut t = 0;
    for k in 0..50_000u32 {
        t = db.put(&mut env, t, k, ValueDesc::new(k, 4096)).done;
    }
    println!("wrote 50k pairs in {:.3} virtual s", t as f64 / 1e9);
    {
        let kv = db.kvaccel().expect("kvaccel engine");
        println!(
            "redirected to Dev-LSM: {} puts ({:.1}%)",
            kv.controller.stats.writes_to_dev,
            kv.controller.redirect_fraction() * 100.0
        );
    }

    // group-commit a batch: one admission gate, one WAL submission, and
    // (under stall pressure) one redirection decision for all 1001 ops
    let mut batch = WriteBatch::with_capacity(1001);
    for k in 50_000..51_000u32 {
        batch.put(k, ValueDesc::new(k, 4096));
    }
    batch.delete(12_346);
    let br = db.write_batch(&mut env, t, &batch);
    t = br.done;
    println!("batched {} ops in one submission", br.ops);

    // point reads route by metadata (Main vs Dev)
    let (v, t2) = db.get(&mut env, t, 12_345);
    println!("get(12345) = {v:?} at t={:.3}s", t2 as f64 / 1e9);
    assert_eq!(v, Some(ValueDesc::new(12_345, 4096)));
    let (gone, t2b) = db.get(&mut env, t2, 12_346);
    assert_eq!(gone, None, "batched delete must hide the key");

    // range scan across BOTH interfaces (dual-iterator aggregation)
    let (entries, t3) = db.scan(&mut env, t2b, 100, 10);
    println!(
        "scan(100..) -> {:?}",
        entries.iter().map(|e| e.key).collect::<Vec<_>>()
    );

    // finish: rollback any buffered pairs into the Main-LSM
    let t4 = db.finish(&mut env, t3)?;
    let kv = db.kvaccel().expect("kvaccel engine");
    println!(
        "finished at {:.3}s: {} rollbacks returned {} pairs",
        t4 as f64 / 1e9,
        kv.rollback.stats.rollbacks,
        kv.rollback.stats.entries_returned
    );
    assert!(env.device.kv_is_empty(kv.namespace()));
    println!("quickstart OK");
    Ok(())
}
