//! Crash + recovery walkthrough: write through a KVACCEL store, pull the
//! plug mid-run, reopen from the durable image, and verify the paper's
//! consistency claim — no redirected write lost, no stale copy
//! resurrected, host and device reconciled by sequence number.
//!
//!     cargo run --release --example crash_recovery

use kvaccel::engine::{EngineBuilder, EngineStats, KvEngine};
use kvaccel::env::SimEnv;
use kvaccel::lsm::{LsmOptions, ValueDesc};
use kvaccel::ssd::SsdConfig;

fn main() -> anyhow::Result<()> {
    // small memtables so the run actually stalls and redirects
    let mut db: Box<dyn KvEngine> = EngineBuilder::kvaccel()
        .opts(LsmOptions::small_for_test())
        .build();
    let mut env = SimEnv::new(7, SsdConfig::default());

    // phase 1: a burst the engine makes durable (flush barrier)
    let mut t = 0;
    for k in 0..2_000u32 {
        t = db.put(&mut env, t, k, ValueDesc::new(k, 4096)).done;
    }
    t = db.flush(&mut env, t);

    // phase 2: more writes, some redirected to the device write buffer,
    // the tail still in the page cache (sync=false) when the power dies
    for k in 2_000..4_000u32 {
        t = db.put(&mut env, t, k, ValueDesc::new(k, 4096)).done;
    }
    let redirected = db.kvaccel().map_or(0, |k| k.controller.stats.writes_to_dev);
    println!("wrote 4000 pairs, {redirected} redirected to the Dev-LSM");

    // -- power loss --
    let image = db.crash(&mut env, t);
    println!(
        "crash at {:.3} virtual s: durable image holds {} WAL records, {} manifest edits",
        t as f64 / 1e9,
        image.wal_records(),
        image.manifest.edit_count()
    );

    // reopen: manifest rebuild + WAL replay + device rescan + routing
    // reconciliation, all charged in virtual time
    let (mut db2, t2) = EngineBuilder::open(&mut env, t, image).expect("recovery failed");
    let h = db2.health();
    println!(
        "recovered in {:.3} virtual ms: {} WAL records replayed, {} device keys re-routed",
        (t2 - t) as f64 / 1e6,
        h.recovered_wal_records,
        h.recovered_dev_keys
    );

    // every barrier-covered write survived; every redirected write
    // survived (the device buffer is capacitor-backed NAND)
    let mut t3 = t2;
    for k in 0..2_000u32 {
        let (got, nt) = db2.get(&mut env, t3, k);
        t3 = nt;
        assert_eq!(got, Some(ValueDesc::new(k, 4096)), "barrier key {k} lost");
    }
    // a clean close reopens with nothing to replay
    let image = db2.close(&mut env, t3)?;
    assert!(image.clean && image.wal_records() == 0);
    let (db3, t4) = EngineBuilder::open(&mut env, t3, image).expect("recovery failed");
    assert_eq!(db3.health().recovered_wal_records, 0);
    println!("clean close -> reopen replayed 0 records at {:.3}s", t4 as f64 / 1e9);
    println!("crash_recovery OK");
    Ok(())
}
