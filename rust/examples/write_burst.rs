//! Write-burst demo: the paper's core claim in one run — drive a hot
//! fillrandom burst into RocksDB (slowdown on / off) and KVACCEL and
//! print the per-second throughput shape (Fig 2 / Fig 11 in miniature).
//!
//!     cargo run --release --example write_burst -- --seconds 30

use kvaccel::baselines::SystemKind;
use kvaccel::engine::EngineBuilder;
use kvaccel::env::SimEnv;
use kvaccel::kvaccel::RollbackScheme;
use kvaccel::lsm::LsmOptions;
use kvaccel::sim::NS_PER_SEC;
use kvaccel::ssd::SsdConfig;
use kvaccel::util::Args;
use kvaccel::workload::{fillrandom, BenchConfig};

fn sparkline(series: &[u64]) -> String {
    let max = series.iter().copied().max().unwrap_or(1).max(1);
    series
        .iter()
        .map(|&v| {
            let ticks = [' ', '▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
            ticks[(v * 8 / max) as usize]
        })
        .collect()
}

fn main() {
    let args = Args::from_env();
    let seconds = args.get_u64("seconds", 30);
    let cfg = BenchConfig {
        duration: seconds * NS_PER_SEC,
        ..Default::default()
    };
    println!("fillrandom burst, {seconds} virtual seconds, 4 threads\n");
    for kind in [
        SystemKind::RocksDb { slowdown: false },
        SystemKind::RocksDb { slowdown: true },
        SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
    ] {
        let mut sys = EngineBuilder::new(kind)
            .opts(LsmOptions::default().with_threads(4))
            .build();
        let mut env = SimEnv::new(1, SsdConfig::default());
        let r = fillrandom(&mut *sys, &mut env, &cfg);
        println!(
            "{:<13} mean {:>8.1} ops/s  halts {:>3}  slowdowns {:>3}",
            kind.label(),
            r.writes.mean_ops(),
            r.stop_events,
            r.slowdown_events
        );
        println!("  |{}|", sparkline(r.writes.ops_per_sec()));
    }
    println!("\nshape: RocksDB-noSD gaps (halts), RocksDB throttled, KVACCEL flat");
}
