//! Range queries across the hybrid interface (paper §V-F + Table V):
//! load a store until writes redirect, then run Seek+Next scans whose
//! results interleave Main-LSM and Dev-LSM entries.
//!
//!     cargo run --release --example range_scan

use kvaccel::engine::{EngineBuilder, EngineStats, KvEngine};
use kvaccel::env::SimEnv;
use kvaccel::lsm::ValueDesc;
use kvaccel::ssd::SsdConfig;

fn main() -> anyhow::Result<()> {
    // write-optimized KVACCEL: rollback disabled, so redirected pairs
    // stay in the Dev-LSM and scans must aggregate both interfaces
    let mut db = EngineBuilder::kvaccel().build();
    let mut env = SimEnv::new(3, SsdConfig::default());

    // sequential-ish fill with enough pressure to trigger redirection
    let mut t = 0;
    for k in 0..300_000u32 {
        t = db.put(&mut env, t, k, ValueDesc::new(k, 4096)).done;
    }
    let redirected = db
        .kvaccel()
        .expect("kvaccel engine")
        .controller
        .stats
        .writes_to_dev;
    println!("loaded 300k pairs; {redirected} redirected to the Dev-LSM");

    // scans must see a seamless, sorted, newest-version view
    for start in [0u32, 123_456, 299_990] {
        let (entries, nt) = db.scan(&mut env, t, start, 8);
        t = nt;
        let keys: Vec<u32> = entries.iter().map(|e| e.key).collect();
        println!("scan({start:>7}) -> {keys:?}");
        assert!(keys.windows(2).all(|w| w[0] < w[1]), "unsorted scan!");
        assert_eq!(keys.first(), Some(&start));
    }

    // compare Main-only iteration cost vs the dual iterator by timing the
    // virtual clock (Table V's effect):
    let t0 = t;
    let (_, t1) = db.scan(&mut env, t0, 150_000, 1024);
    println!(
        "dual-interface Seek+1024Next cost: {:.2} ms virtual (Dev-LSM pages have no read cache)",
        (t1 - t0) as f64 / 1e6
    );

    // the cursor API underneath scan(): pin a snapshot, walk a bounded
    // range both ways, and read the per-interface read amplification
    use kvaccel::engine::{DbIterator, IterOptions};
    let snap = db.snapshot(&mut env, t1);
    let mut it = db.iter(&mut env, t1, IterOptions::range(200_000, 200_016).at(&snap));
    let mut tc = it.seek_to_first(&mut env, t1);
    let mut fwd = Vec::new();
    while it.valid() {
        fwd.push(it.key().unwrap());
        tc = it.next(&mut env, tc);
    }
    tc = it.seek_to_last(&mut env, tc);
    let mut bwd = Vec::new();
    while it.valid() {
        bwd.push(it.key().unwrap());
        tc = it.prev(&mut env, tc);
    }
    bwd.reverse();
    assert_eq!(fwd, bwd, "reverse cursor must mirror forward");
    let amp = it.amp();
    println!(
        "cursor [200000,200016): {} keys, read-amp {:.2} blocks/next (main) {:.2} pages/next (dev)",
        fwd.len(),
        amp.main_blocks_per_next(),
        amp.dev_pages_per_next()
    );
    let _ = tc;
    println!("range_scan OK");
    Ok(())
}
