//! Mixed read/write workloads (paper Fig 13): compare the lazy vs eager
//! rollback schemes on readwhilewriting, against RocksDB and ADOC.
//!
//!     cargo run --release --example mixed_workload -- --seconds 60

use kvaccel::baselines::SystemKind;
use kvaccel::engine::EngineBuilder;
use kvaccel::env::SimEnv;
use kvaccel::kvaccel::RollbackScheme;
use kvaccel::lsm::LsmOptions;
use kvaccel::sim::NS_PER_SEC;
use kvaccel::ssd::SsdConfig;
use kvaccel::util::Args;
use kvaccel::workload::{readwhilewriting, BenchConfig};

fn main() {
    let args = Args::from_env();
    let seconds = args.get_u64("seconds", 60);
    let cfg = BenchConfig {
        duration: seconds * NS_PER_SEC,
        ..Default::default()
    };
    for (wname, ratio) in [("B (9:1)", (9u64, 1u64)), ("C (8:2)", (8, 2))] {
        println!("== workload {wname}, {seconds} virtual s, 4 threads ==");
        for kind in [
            SystemKind::RocksDb { slowdown: true },
            SystemKind::Adoc,
            SystemKind::Kvaccel { scheme: RollbackScheme::Lazy },
            SystemKind::Kvaccel { scheme: RollbackScheme::Eager },
        ] {
            let mut sys = EngineBuilder::new(kind)
                .opts(LsmOptions::default().with_threads(4))
                .build();
            let mut env = SimEnv::new(11, SsdConfig::default());
            let r = readwhilewriting(&mut *sys, &mut env, &cfg, ratio.0, ratio.1);
            println!(
                "  {:<10} write {:>8.1} ops/s  read {:>8.1} ops/s  hit {:>5.1}%  read-p99 {:>8.1} us  rollbacks {:>3}",
                kind.label(),
                r.write_kops() * 1e3,
                r.read_kops() * 1e3,
                r.read_hit_rate() * 100.0,
                r.read_lat.p99_us,
                r.rollbacks
            );
        }
        println!();
    }
    println!("shape: eager rollback trades some write bandwidth for faster reads");
}
