//! ADOC baseline (Yu et al., FAST'23): "automatically harmonizing
//! dataflow" — a feedback tuner that watches the same stall signals and
//! reacts by (a) growing the background compaction thread pool and (b)
//! growing the write-buffer (batch) size while data is overflowing, then
//! restoring both when the dataflow calms. Slowdown remains enabled as
//! the last resort (paper §III-A: "ADOC ... still falls back to
//! slowdowns").
//!
//! The control loop runs at the same 0.1 s cadence as KVACCEL's Detector
//! so the two systems observe identical signals.

use anyhow::Result;

use crate::engine::{
    BatchResult, DbIterator, DurableImage, EngineStats, IterOptions, KvEngine,
    Snapshot, WriteBatch,
};
use crate::env::SimEnv;
use crate::lsm::entry::{Entry, Key, ValueDesc};
use crate::lsm::{LsmDb, LsmOptions, Manifest, PutResult, WriteCondition};
use crate::runtime::{BloomBuilder, MergeEngine};
use crate::sim::{CpuClass, Nanos, MILLIS};

#[derive(Clone, Debug)]
pub struct AdocConfig {
    /// Control period.
    pub interval: Nanos,
    /// Thread pool may grow up to base * factor.
    pub max_thread_factor: usize,
    /// Write buffer may grow up to base * factor.
    pub max_buffer_factor: u64,
    /// Calm ticks before stepping back down.
    pub cooldown_ticks: u64,
    /// Tuner CPU cost per tick (signal collection + decision).
    pub tick_cost_ns: Nanos,
}

impl Default for AdocConfig {
    fn default() -> Self {
        Self {
            interval: 100 * MILLIS,
            max_thread_factor: 2,
            max_buffer_factor: 2,
            cooldown_ticks: 10,
            tick_cost_ns: 2_000,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct AdocStats {
    pub ticks: u64,
    pub thread_increases: u64,
    pub thread_decreases: u64,
    pub buffer_increases: u64,
    pub buffer_decreases: u64,
}

#[derive(Debug)]
pub struct AdocTuner {
    cfg: AdocConfig,
    base_threads: usize,
    base_buffer: u64,
    last_tick: Nanos,
    ticked_once: bool,
    calm_ticks: u64,
    pub stats: AdocStats,
}

impl AdocTuner {
    pub fn new(cfg: AdocConfig, base_threads: usize, base_buffer: u64) -> Self {
        Self {
            cfg,
            base_threads,
            base_buffer,
            last_tick: 0,
            ticked_once: false,
            calm_ticks: 0,
            stats: AdocStats::default(),
        }
    }

    /// One control step if the period elapsed.
    pub fn maybe_tune(&mut self, env: &mut SimEnv, at: Nanos, db: &mut LsmDb) {
        if self.ticked_once && at < self.last_tick + self.cfg.interval {
            return;
        }
        self.last_tick = at;
        self.ticked_once = true;
        self.stats.ticks += 1;
        env.cpu.charge(CpuClass::Kvaccel, at, self.cfg.tick_cost_ns);

        let cond = db.write_condition();
        let overflowing = !matches!(cond, WriteCondition::Normal);
        let max_threads = self.base_threads * self.cfg.max_thread_factor;
        let max_buffer = self.base_buffer * self.cfg.max_buffer_factor;
        if overflowing {
            self.calm_ticks = 0;
            // data overflow: add a compaction thread, widen the batch
            let threads = db.compaction_threads();
            if threads < max_threads {
                db.set_compaction_threads(threads + 1);
                self.stats.thread_increases += 1;
            }
            let buf = db.opts.write_buffer_size;
            if buf < max_buffer {
                db.set_write_buffer_size((buf + buf / 4).min(max_buffer));
                self.stats.buffer_increases += 1;
            }
        } else {
            self.calm_ticks += 1;
            if self.calm_ticks >= self.cfg.cooldown_ticks {
                // restore toward the configured baseline
                let threads = db.compaction_threads();
                if threads > self.base_threads {
                    db.set_compaction_threads(threads - 1);
                    self.stats.thread_decreases += 1;
                }
                let buf = db.opts.write_buffer_size;
                if buf > self.base_buffer {
                    db.set_write_buffer_size(
                        (buf - buf / 4).max(self.base_buffer),
                    );
                    self.stats.buffer_decreases += 1;
                }
            }
        }
    }
}

/// The ADOC system as a [`KvEngine`]: the tuned Main-LSM plus its
/// feedback controller, ticked on every client operation (the paper runs
/// the tuner on the same 0.1 s cadence as KVACCEL's Detector).
pub struct AdocEngine {
    pub db: LsmDb,
    pub tuner: AdocTuner,
    /// Original configuration, retained for the durable image.
    cfg: AdocConfig,
}

impl AdocEngine {
    pub fn new(
        opts: LsmOptions,
        cfg: AdocConfig,
        engine: MergeEngine,
        bloom: BloomBuilder,
    ) -> Self {
        let base_threads = opts.compaction_threads;
        let base_buffer = opts.write_buffer_size;
        // ADOC keeps slowdown as the last resort (paper §III-A).
        let db = LsmDb::new(opts.with_slowdown(true), engine, bloom);
        Self {
            db,
            tuner: AdocTuner::new(cfg.clone(), base_threads, base_buffer),
            cfg,
        }
    }

    /// Reopen from a durable image: the tuned Main-LSM recovers (manifest
    /// + WAL replay); the feedback controller restarts from its baseline
    /// (its state is volatile by design — it re-learns from live signals).
    #[allow(clippy::too_many_arguments)]
    pub fn open(
        env: &mut SimEnv,
        at: Nanos,
        opts: LsmOptions,
        cfg: AdocConfig,
        merge: MergeEngine,
        bloom: BloomBuilder,
        manifest: Manifest,
        wal: Vec<Entry>,
        vlog: Option<crate::vlog::VlogImage>,
        clean: bool,
    ) -> (Self, Nanos) {
        let base_threads = opts.compaction_threads;
        let base_buffer = opts.write_buffer_size;
        let (db, t) = LsmDb::open(
            env,
            at,
            opts.with_slowdown(true),
            merge,
            bloom,
            manifest,
            wal,
            vlog,
            clean,
        );
        (
            Self {
                db,
                tuner: AdocTuner::new(cfg.clone(), base_threads, base_buffer),
                cfg,
            },
            t,
        )
    }
}

impl EngineStats for AdocEngine {
    fn main_db(&self) -> &LsmDb {
        &self.db
    }
}

impl KvEngine for AdocEngine {
    fn put(&mut self, env: &mut SimEnv, at: Nanos, key: Key, val: ValueDesc) -> PutResult {
        self.tuner.maybe_tune(env, at, &mut self.db);
        self.db.put(env, at, key, val)
    }

    fn delete(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> PutResult {
        self.tuner.maybe_tune(env, at, &mut self.db);
        self.db.delete(env, at, key)
    }

    fn get(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> (Option<ValueDesc>, Nanos) {
        self.tuner.maybe_tune(env, at, &mut self.db);
        self.db.get(env, at, key)
    }

    fn write_batch(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        batch: &WriteBatch,
    ) -> BatchResult {
        self.tuner.maybe_tune(env, at, &mut self.db);
        self.db.write_batch(env, at, batch)
    }

    fn snapshot(&mut self, env: &mut SimEnv, at: Nanos) -> Snapshot {
        self.tuner.maybe_tune(env, at, &mut self.db);
        self.db.snapshot(env, at)
    }

    fn iter(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        opts: IterOptions,
    ) -> Box<dyn DbIterator> {
        self.tuner.maybe_tune(env, at, &mut self.db);
        KvEngine::iter(&mut self.db, env, at, opts)
    }

    fn tick(&mut self, env: &mut SimEnv, at: Nanos) {
        self.tuner.maybe_tune(env, at, &mut self.db);
        self.db.catch_up(env, at);
        self.db.vlog_gc_tick(env, at);
        self.db.maybe_schedule(env, at);
    }

    fn cdc_tail(&self, env: &SimEnv, wm: &[crate::lsm::Seq]) -> Vec<crate::engine::CdcRecord> {
        KvEngine::cdc_tail(&self.db, env, wm)
    }

    fn repl_apply(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        rec: &crate::engine::CdcRecord,
    ) -> PutResult {
        self.tuner.maybe_tune(env, at, &mut self.db);
        self.db.apply_entry(env, at, rec.entry)
    }

    fn set_block_cache(&mut self, cache: crate::engine::SharedBlockCache) {
        self.db.set_block_cache(cache);
    }

    fn flush(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        self.db.flush_and_wait(env, at)
    }

    fn finish(&mut self, env: &mut SimEnv, at: Nanos) -> Result<Nanos> {
        Ok(self.db.flush_and_wait(env, at))
    }

    fn close(self: Box<Self>, env: &mut SimEnv, at: Nanos) -> Result<DurableImage> {
        let AdocEngine { mut db, tuner, cfg } = *self;
        // the image carries the CONFIGURED baseline, not the tuner's
        // transient escalation — controller state is volatile, and a
        // reopen must not ratchet the baseline upward
        db.opts.compaction_threads = tuner.base_threads;
        db.opts.write_buffer_size = tuner.base_buffer;
        let mut img = db.close_into_image(env, at)?;
        img.kind = crate::baselines::SystemKind::Adoc;
        img.adoc_cfg = Some(cfg);
        Ok(img)
    }

    fn crash(self: Box<Self>, env: &mut SimEnv, at: Nanos) -> DurableImage {
        let AdocEngine { mut db, tuner, cfg } = *self;
        db.opts.compaction_threads = tuner.base_threads;
        db.opts.write_buffer_size = tuner.base_buffer;
        let mut img = db.crash_into_image(env, at);
        img.kind = crate::baselines::SystemKind::Adoc;
        img.adoc_cfg = Some(cfg);
        img
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ssd::SsdConfig;

    fn rig() -> (LsmDb, SimEnv, AdocTuner) {
        let opts = LsmOptions::small_for_test();
        let base_buf = opts.write_buffer_size;
        (
            LsmDb::new(opts, MergeEngine::rust(), BloomBuilder::rust()),
            SimEnv::new(2, SsdConfig::default()),
            AdocTuner::new(AdocConfig::default(), 1, base_buf),
        )
    }

    #[test]
    fn scales_up_under_pressure() {
        let (mut db, mut env, mut tuner) = rig();
        let mut t = 0;
        for k in 0..6000u32 {
            t = db.put(&mut env, t, k, ValueDesc::new(k, 4096)).done;
            tuner.maybe_tune(&mut env, t, &mut db);
        }
        assert!(
            tuner.stats.thread_increases > 0 || tuner.stats.buffer_increases > 0,
            "pressure should have triggered tuning: {:?}",
            tuner.stats
        );
        assert!(db.compaction_threads() >= 1);
    }

    #[test]
    fn restores_when_calm() {
        let (mut db, mut env, mut tuner) = rig();
        // force scale-up state
        db.set_compaction_threads(2);
        db.set_write_buffer_size(tuner.base_buffer * 2);
        // long calm period
        let mut t = 0;
        for _ in 0..30 {
            t += 100 * MILLIS;
            tuner.maybe_tune(&mut env, t, &mut db);
        }
        assert_eq!(db.compaction_threads(), 1, "threads restored");
        assert_eq!(db.opts.write_buffer_size, tuner.base_buffer, "buffer restored");
    }

    #[test]
    fn respects_interval() {
        let (mut db, mut env, mut tuner) = rig();
        tuner.maybe_tune(&mut env, 0, &mut db);
        tuner.maybe_tune(&mut env, 1, &mut db);
        assert_eq!(tuner.stats.ticks, 1);
        tuner.maybe_tune(&mut env, 100 * MILLIS, &mut db);
        assert_eq!(tuner.stats.ticks, 2);
    }

    #[test]
    fn bounded_by_factors() {
        let (mut db, mut env, mut tuner) = rig();
        // sustained pressure, many ticks
        let mut t = 0;
        db.opts.enable_slowdown = false;
        for k in 0..8000u32 {
            t = db.put(&mut env, t, k, ValueDesc::new(k, 4096)).done;
            tuner.maybe_tune(&mut env, t, &mut db);
        }
        assert!(db.compaction_threads() <= tuner.base_threads * 2);
        assert!(db.opts.write_buffer_size <= tuner.base_buffer * 2);
    }
}
