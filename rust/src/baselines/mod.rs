//! The evaluated systems behind one trait: RocksDB (with/without
//! slowdown), ADOC, and KVACCEL (lazy/eager/write-optimized) — the rows
//! and series of every figure in the paper.

pub mod adoc;

use anyhow::Result;

use crate::env::SimEnv;
use crate::kvaccel::{KvaccelConfig, KvaccelDb, RollbackScheme};
use crate::lsm::entry::{Entry, Key, ValueDesc};
use crate::lsm::{DbStats, LsmDb, LsmOptions, PutResult, StallStats};
use crate::runtime::{BloomBuilder, MergeEngine};
use crate::sim::Nanos;

pub use adoc::{AdocConfig, AdocStats, AdocTuner};

/// Which system to instantiate (paper Table III rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    /// RocksDB with the slowdown feature on/off.
    RocksDb { slowdown: bool },
    /// ADOC dataflow tuner (slowdown stays on as last resort).
    Adoc,
    /// KVACCEL with a rollback scheme (Disabled == the write-optimized
    /// configuration of Fig 12).
    Kvaccel { scheme: RollbackScheme },
}

impl SystemKind {
    pub fn label(&self) -> String {
        match self {
            SystemKind::RocksDb { slowdown: true } => "RocksDB".into(),
            SystemKind::RocksDb { slowdown: false } => "RocksDB-noSD".into(),
            SystemKind::Adoc => "ADOC".into(),
            SystemKind::Kvaccel { scheme: RollbackScheme::Eager } => "KVACCEL-E".into(),
            SystemKind::Kvaccel { scheme: RollbackScheme::Lazy } => "KVACCEL-L".into(),
            SystemKind::Kvaccel { scheme: RollbackScheme::Disabled } => "KVACCEL".into(),
        }
    }
}

/// Uniform store interface for the workload drivers.
pub enum System {
    RocksDb(LsmDb),
    Adoc(LsmDb, AdocTuner),
    Kvaccel(KvaccelDb),
}

impl System {
    pub fn build(
        kind: SystemKind,
        opts: LsmOptions,
        engine: MergeEngine,
        bloom: BloomBuilder,
    ) -> Self {
        match kind {
            SystemKind::RocksDb { slowdown } => {
                System::RocksDb(LsmDb::new(opts.with_slowdown(slowdown), engine, bloom))
            }
            SystemKind::Adoc => {
                let base_threads = opts.compaction_threads;
                let base_buffer = opts.write_buffer_size;
                let db = LsmDb::new(opts.with_slowdown(true), engine, bloom);
                System::Adoc(
                    db,
                    AdocTuner::new(AdocConfig::default(), base_threads, base_buffer),
                )
            }
            SystemKind::Kvaccel { scheme } => System::Kvaccel(KvaccelDb::new(
                opts,
                KvaccelConfig::default().with_scheme(scheme),
                engine,
                bloom,
            )),
        }
    }

    pub fn put(&mut self, env: &mut SimEnv, at: Nanos, key: Key, val: ValueDesc) -> PutResult {
        match self {
            System::RocksDb(db) => db.put(env, at, key, val),
            System::Adoc(db, tuner) => {
                tuner.maybe_tune(env, at, db);
                db.put(env, at, key, val)
            }
            System::Kvaccel(db) => db.put(env, at, key, val),
        }
    }

    pub fn get(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> (Option<ValueDesc>, Nanos) {
        match self {
            System::RocksDb(db) => db.get(env, at, key),
            System::Adoc(db, tuner) => {
                tuner.maybe_tune(env, at, db);
                db.get(env, at, key)
            }
            System::Kvaccel(db) => db.get(env, at, key),
        }
    }

    pub fn scan(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        start: Key,
        count: usize,
    ) -> (Vec<Entry>, Nanos) {
        match self {
            System::RocksDb(db) => db.scan(env, at, start, count),
            System::Adoc(db, _) => db.scan(env, at, start, count),
            System::Kvaccel(db) => db.scan(env, at, start, count),
        }
    }

    /// Drain background work (and final rollback for KVACCEL).
    pub fn finish(&mut self, env: &mut SimEnv, at: Nanos) -> Result<Nanos> {
        match self {
            System::RocksDb(db) | System::Adoc(db, _) => Ok(db.flush_and_wait(env, at)),
            System::Kvaccel(db) => db.finish(env, at),
        }
    }

    pub fn main_db(&self) -> &LsmDb {
        match self {
            System::RocksDb(db) | System::Adoc(db, _) => db,
            System::Kvaccel(db) => &db.main,
        }
    }

    pub fn stall_stats(&self) -> &StallStats {
        &self.main_db().stall
    }

    pub fn db_stats(&self) -> &DbStats {
        &self.main_db().stats
    }

    pub fn kvaccel(&self) -> Option<&KvaccelDb> {
        match self {
            System::Kvaccel(db) => Some(db),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ssd::SsdConfig;

    fn run_small(kind: SystemKind) -> (System, SimEnv, Nanos) {
        let mut env = SimEnv::new(4, SsdConfig::default());
        let mut sys = System::build(
            kind,
            LsmOptions::small_for_test(),
            MergeEngine::rust(),
            BloomBuilder::rust(),
        );
        let mut t = 0;
        for k in 0..2000u32 {
            t = sys.put(&mut env, t, k % 500, ValueDesc::new(k, 4096)).done;
        }
        t = sys.finish(&mut env, t).unwrap();
        (sys, env, t)
    }

    #[test]
    fn all_systems_agree_on_data() {
        for kind in [
            SystemKind::RocksDb { slowdown: true },
            SystemKind::RocksDb { slowdown: false },
            SystemKind::Adoc,
            SystemKind::Kvaccel { scheme: RollbackScheme::Eager },
            SystemKind::Kvaccel { scheme: RollbackScheme::Lazy },
            SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
        ] {
            let (mut sys, mut env, mut t) = run_small(kind);
            for key in (0..500u32).step_by(41) {
                let latest = (0..2000u32).filter(|x| x % 500 == key).max().unwrap();
                let (got, nt) = sys.get(&mut env, t, key);
                t = nt;
                assert_eq!(
                    got,
                    Some(ValueDesc::new(latest, 4096)),
                    "{}: key {key}",
                    kind.label()
                );
            }
        }
    }

    #[test]
    fn labels_are_distinct() {
        let labels: Vec<String> = [
            SystemKind::RocksDb { slowdown: true },
            SystemKind::RocksDb { slowdown: false },
            SystemKind::Adoc,
            SystemKind::Kvaccel { scheme: RollbackScheme::Eager },
            SystemKind::Kvaccel { scheme: RollbackScheme::Lazy },
            SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
        ]
        .iter()
        .map(|k| k.label())
        .collect();
        let mut dedup = labels.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), labels.len());
    }
}
