//! The evaluated systems: RocksDB (with/without slowdown), ADOC, and
//! KVACCEL (lazy/eager/write-optimized) — the rows and series of every
//! figure in the paper.
//!
//! All of them sit behind the [`crate::engine::KvEngine`] trait; there
//! is no per-system dispatch here. [`SystemKind`] names a row,
//! [`crate::engine::EngineBuilder`] constructs it, and every workload or
//! experiment driver takes `&mut dyn KvEngine`.

pub mod adoc;

use crate::kvaccel::RollbackScheme;

pub use adoc::{AdocConfig, AdocEngine, AdocStats, AdocTuner};

/// Which system to instantiate (paper Table III rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    /// RocksDB with the slowdown feature on/off.
    RocksDb { slowdown: bool },
    /// ADOC dataflow tuner (slowdown stays on as last resort).
    Adoc,
    /// KVACCEL with a rollback scheme (Disabled == the write-optimized
    /// configuration of Fig 12).
    Kvaccel { scheme: RollbackScheme },
}

impl SystemKind {
    pub fn label(&self) -> String {
        match self {
            SystemKind::RocksDb { slowdown: true } => "RocksDB".into(),
            SystemKind::RocksDb { slowdown: false } => "RocksDB-noSD".into(),
            SystemKind::Adoc => "ADOC".into(),
            SystemKind::Kvaccel { scheme: RollbackScheme::Eager } => "KVACCEL-E".into(),
            SystemKind::Kvaccel { scheme: RollbackScheme::Lazy } => "KVACCEL-L".into(),
            SystemKind::Kvaccel { scheme: RollbackScheme::Disabled } => "KVACCEL".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{EngineBuilder, KvEngine};
    use crate::env::SimEnv;
    use crate::lsm::{LsmOptions, ValueDesc};
    use crate::sim::Nanos;
    use crate::ssd::SsdConfig;

    fn run_small(kind: SystemKind) -> (Box<dyn KvEngine>, SimEnv, Nanos) {
        let mut env = SimEnv::new(4, SsdConfig::default());
        let mut sys = EngineBuilder::new(kind)
            .opts(LsmOptions::small_for_test())
            .build();
        let mut t = 0;
        for k in 0..2000u32 {
            t = sys.put(&mut env, t, k % 500, ValueDesc::new(k, 4096)).done;
        }
        t = sys.finish(&mut env, t).unwrap();
        (sys, env, t)
    }

    #[test]
    fn all_systems_agree_on_data() {
        for kind in [
            SystemKind::RocksDb { slowdown: true },
            SystemKind::RocksDb { slowdown: false },
            SystemKind::Adoc,
            SystemKind::Kvaccel { scheme: RollbackScheme::Eager },
            SystemKind::Kvaccel { scheme: RollbackScheme::Lazy },
            SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
        ] {
            let (mut sys, mut env, mut t) = run_small(kind);
            for key in (0..500u32).step_by(41) {
                let latest = (0..2000u32).filter(|x| x % 500 == key).max().unwrap();
                let (got, nt) = sys.get(&mut env, t, key);
                t = nt;
                assert_eq!(
                    got,
                    Some(ValueDesc::new(latest, 4096)),
                    "{}: key {key}",
                    kind.label()
                );
            }
        }
    }

    #[test]
    fn labels_are_distinct() {
        let labels: Vec<String> = [
            SystemKind::RocksDb { slowdown: true },
            SystemKind::RocksDb { slowdown: false },
            SystemKind::Adoc,
            SystemKind::Kvaccel { scheme: RollbackScheme::Eager },
            SystemKind::Kvaccel { scheme: RollbackScheme::Lazy },
            SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
        ]
        .iter()
        .map(|k| k.label())
        .collect();
        let mut dedup = labels.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), labels.len());
    }
}
