//! WiscKey-style value log: key-value separation for the LSM engine.
//!
//! Values at or above `LsmOptions::vlog_threshold` bytes are appended to
//! a segmented log on the SSD's block interface; the LSM (WAL, memtable,
//! SSTs) keeps only a 12 B `<segment, offset, len>` pointer
//! ([`crate::lsm::entry::ValueLoc::Vlog`]), so flush and compaction
//! traffic shrinks to pointer size — the write-amplification win the
//! `kv-sep` experiment measures.
//!
//! Layout and lifecycle:
//! - The **head** segment accumulates appends through a dedicated device
//!   WAL stream (`VLOG_STREAM_OFFSET + wal_stream`), giving vlog bytes
//!   the same page-cache / fsync / crash-cut semantics as the WAL: a
//!   crash loses the unsynced tail, and the durable prefix of the head
//!   is recovered exactly (crash mid-append → old or new copy, never a
//!   torn one).
//! - Once `vlog_segment_bytes` accumulate the head **seals**: the stream
//!   is fsync'd, the extent is registered as a block-FS file (owned by
//!   the vlog's stream id, keeping it out of the Main-LSM's orphan
//!   scan), and the segment is installed in the manifest
//!   (`ManifestEdit::VlogSeal`) so reopen rebuilds the segment list.
//! - **GC** (driven by `LsmDb::tick`) picks the sealed segment with the
//!   highest dead-byte ratio, re-appends its live values to the head,
//!   re-inserts the moved pointers through the write path, and retires
//!   the segment with `ManifestEdit::VlogDrop` + a deferred
//!   `delete_file` (sync-before-delete: the drop is only installed
//!   after the relocated copies are fsync'd).
//!
//! Values are deterministic `(seed, len)` streams ([`ValueDesc`]), so a
//! pointer dereference never moves payload bytes — it is purely a cost
//! event (a vlog block read through the shared block cache). That is
//! also why snapshots pinned across a GC stay correct by construction:
//! the descriptor rides inside the pinned entry.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::env::SimEnv;
use crate::lsm::entry::{Key, Seq, ValueDesc};
use crate::sim::Nanos;
use crate::ssd::block_if::FileId;

/// Device WAL streams `VLOG_STREAM_OFFSET + wal_stream` carry value-log
/// appends; the same number is the block-FS directory owner of sealed
/// segment files. The offset keeps vlog streams clear of every shard's
/// WAL stream (shard streams are small consecutive integers) and keeps
/// sealed segments out of `LsmDb::open`'s SST orphan scan, which only
/// looks at `file_ids_for(wal_stream)`.
pub const VLOG_STREAM_OFFSET: u32 = 512;

/// Per-record framing: 4 B key + 4 B seq + 4 B length + 4 B CRC ahead of
/// the payload (WiscKey's log record header).
pub const VLOG_RECORD_HEADER: u64 = 16;

/// One value in the log. `(seed, len)` is the deterministic payload
/// descriptor; `offset` is the record's byte offset within its segment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VlogRecord {
    pub key: Key,
    pub seq: Seq,
    pub seed: u32,
    pub len: u32,
    pub offset: u32,
}

impl VlogRecord {
    /// On-log footprint: header + payload.
    pub fn record_bytes(&self) -> u64 {
        VLOG_RECORD_HEADER + self.len as u64
    }
}

/// A log segment: the append head (file = None) or a sealed, immutable,
/// manifest-installed extent (file = Some).
#[derive(Clone, Debug)]
pub struct VlogSegment {
    pub id: u32,
    /// Block-FS file backing a sealed segment (None while head).
    pub file: Option<FileId>,
    pub records: Vec<VlogRecord>,
    pub bytes: u64,
}

impl VlogSegment {
    fn new(id: u32) -> Self {
        Self { id, file: None, records: Vec::new(), bytes: 0 }
    }
}

/// Durable image of the value log at close/crash: the head's surviving
/// records (sealed segments travel through the manifest).
#[derive(Clone, Debug, Default)]
pub struct VlogImage {
    pub head_id: u32,
    pub head_records: Vec<VlogRecord>,
    pub head_bytes: u64,
    pub next_segment: u32,
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct VlogStats {
    /// Values separated into the log (user writes + GC relocations).
    pub appends: u64,
    /// Bytes appended to the log (headers + payloads).
    pub appended_bytes: u64,
    /// Pointer dereferences served (point reads + iterator positions).
    pub derefs: u64,
    /// Vlog data blocks materialized from the device (cache misses).
    pub deref_blocks_read: u64,
    pub segments_sealed: u64,
    pub segments_dropped: u64,
    pub gc_runs: u64,
    /// Segment bytes scanned by GC.
    pub gc_read_bytes: u64,
    /// Live bytes GC re-appended to the head.
    pub gc_rewritten_bytes: u64,
    /// Dead bytes reclaimed by dropped segments.
    pub gc_reclaimed_bytes: u64,
}

/// What `Vlog::append` produced: the relocated descriptor plus, when the
/// append filled the head, the freshly sealed segment the caller must
/// install in the manifest (`ManifestEdit::VlogSeal`).
pub struct AppendOutcome {
    pub desc: ValueDesc,
    pub done: Nanos,
    pub sealed: Option<Arc<VlogSegment>>,
}

#[derive(Debug)]
pub struct Vlog {
    /// Device WAL stream carrying appends; also the block-FS directory
    /// owner of sealed segment files.
    stream: u32,
    segment_bytes: u64,
    head: VlogSegment,
    sealed: BTreeMap<u32, Arc<VlogSegment>>,
    /// Dead bytes per sealed segment, discovered by memtable overwrites,
    /// compaction drops and GC relocation. Rebuilt from zero after a
    /// reopen (an LSM scan would recover it; the simulation lets GC
    /// relearn it from ongoing traffic instead).
    dead: BTreeMap<u32, u64>,
    next_segment: u32,
    /// Stream byte offset where the current head's first record lives —
    /// converts the stream's durable watermark into a head prefix length
    /// at crash time.
    stream_base: u64,
    pub stats: VlogStats,
}

impl Vlog {
    /// A fresh, empty log bound to `wal_stream`'s companion vlog stream.
    pub fn new(wal_stream: u32, segment_bytes: u64) -> Self {
        Self {
            stream: VLOG_STREAM_OFFSET + wal_stream,
            segment_bytes: segment_bytes.max(4 << 10),
            head: VlogSegment::new(0),
            sealed: BTreeMap::new(),
            dead: BTreeMap::new(),
            next_segment: 1,
            stream_base: 0,
            stats: VlogStats::default(),
        }
    }

    /// The device WAL stream / block-FS directory this log owns.
    pub fn stream(&self) -> u32 {
        self.stream
    }

    pub fn head_id(&self) -> u32 {
        self.head.id
    }

    pub fn sealed_segments(&self) -> impl Iterator<Item = &Arc<VlogSegment>> {
        self.sealed.values()
    }

    pub fn sealed_segment(&self, id: u32) -> Option<&Arc<VlogSegment>> {
        self.sealed.get(&id)
    }

    /// Total log footprint (head + sealed segments).
    pub fn total_bytes(&self) -> u64 {
        self.head.bytes + self.sealed.values().map(|s| s.bytes).sum::<u64>()
    }

    /// Known-dead bytes across sealed segments.
    pub fn dead_bytes(&self) -> u64 {
        self.dead.values().sum()
    }

    /// Append one value at `at`; the payload rides the vlog WAL stream
    /// (page-cache semantics, so group-committed batches coalesce into
    /// contiguous writebacks). Seals the head when full.
    pub fn append(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        key: Key,
        seq: Seq,
        val: ValueDesc,
    ) -> AppendOutcome {
        debug_assert!(!val.is_tombstone() && !val.in_vlog());
        let offset = self.head.bytes as u32;
        let rec = VlogRecord { key, seq, seed: val.seed, len: val.len, offset };
        let bytes = rec.record_bytes();
        let done = env.device.wal_append_on(self.stream, at, bytes);
        self.head.records.push(rec);
        self.head.bytes += bytes;
        self.stats.appends += 1;
        self.stats.appended_bytes += bytes;
        let desc = val.at_vlog(self.head.id, offset);
        let sealed = if self.head.bytes >= self.segment_bytes {
            Some(self.seal_head(env, done))
        } else {
            None
        };
        AppendOutcome { desc, done, sealed }
    }

    /// Seal the head: fsync the stream (every record durable before the
    /// manifest may reference the segment), register the extent as a
    /// block-FS file under this log's directory, start a fresh head.
    /// The caller installs the returned segment via
    /// `ManifestEdit::VlogSeal`.
    pub fn seal_head(&mut self, env: &mut SimEnv, at: Nanos) -> Arc<VlogSegment> {
        env.device.wal_sync_on(self.stream, at);
        let mut seg = std::mem::replace(
            &mut self.head,
            VlogSegment::new(self.next_segment),
        );
        self.next_segment += 1;
        self.stream_base += seg.bytes;
        seg.file = env.device.register_file_for(self.stream, seg.bytes).ok();
        let seg = Arc::new(seg);
        self.sealed.insert(seg.id, Arc::clone(&seg));
        self.stats.segments_sealed += 1;
        seg
    }

    /// Record that the value at `loc` is no longer referenced by the
    /// latest version of its key (overwritten, deleted, or dropped by
    /// compaction). Head bytes are not tracked — GC only considers
    /// sealed segments.
    pub fn mark_dead(&mut self, segment: u32, len: u32) {
        if self.sealed.contains_key(&segment) {
            *self.dead.entry(segment).or_insert(0) += VLOG_RECORD_HEADER + len as u64;
        } else if segment == self.head.id {
            // Dead-in-head bytes become sealed-segment dead bytes once
            // the head seals; stash them under the head's future id.
            *self.dead.entry(segment).or_insert(0) += VLOG_RECORD_HEADER + len as u64;
        }
    }

    /// GC victim: the sealed segment with the highest dead fraction, if
    /// it reaches `dead_ratio`.
    pub fn gc_victim(&self, dead_ratio: f64) -> Option<u32> {
        self.sealed
            .values()
            .filter(|s| s.bytes > 0)
            .map(|s| {
                let dead = self.dead.get(&s.id).copied().unwrap_or(0).min(s.bytes);
                (s.id, dead as f64 / s.bytes as f64)
            })
            .filter(|&(_, ratio)| ratio >= dead_ratio)
            .max_by(|a, b| a.1.total_cmp(&b.1).then(b.0.cmp(&a.0)))
            .map(|(id, _)| id)
    }

    /// Remove `segment` from the live set (GC retirement). The physical
    /// `delete_file` is the caller's job, *after* installing
    /// `ManifestEdit::VlogDrop` with relocated copies already fsync'd.
    pub fn retire(&mut self, segment: u32) -> Option<Arc<VlogSegment>> {
        let seg = self.sealed.remove(&segment)?;
        let dead = self.dead.remove(&segment).unwrap_or(0);
        self.stats.segments_dropped += 1;
        self.stats.gc_reclaimed_bytes += dead.min(seg.bytes);
        Some(seg)
    }

    /// Capture the durable image at a crash: records of the head whose
    /// bytes fully reached flash (stream watermark minus the head's
    /// stream base) survive; the page-cached tail is lost — exactly the
    /// WAL's sync=false semantics.
    pub fn crash_image(&self, durable_watermark: u64) -> VlogImage {
        let durable_in_head = durable_watermark.saturating_sub(self.stream_base);
        let mut records = Vec::new();
        let mut bytes = 0u64;
        for r in &self.head.records {
            if r.offset as u64 + r.record_bytes() <= durable_in_head {
                records.push(*r);
                bytes = r.offset as u64 + r.record_bytes();
            } else {
                break;
            }
        }
        VlogImage {
            head_id: self.head.id,
            head_records: records,
            head_bytes: bytes,
            next_segment: self.next_segment,
        }
    }

    /// Capture the full head (clean shutdown: everything synced).
    pub fn clean_image(&self) -> VlogImage {
        VlogImage {
            head_id: self.head.id,
            head_records: self.head.records.clone(),
            head_bytes: self.head.bytes,
            next_segment: self.next_segment,
        }
    }

    /// Rebuild a log at open: sealed segments come from the manifest,
    /// the head from the image. The stream was reset by the caller
    /// (fresh log file), so surviving head bytes are re-appended to the
    /// stream and fsync'd — the recovered prefix is durable in the new
    /// life before any new write lands behind it.
    pub fn reopen(
        env: &mut SimEnv,
        at: Nanos,
        wal_stream: u32,
        segment_bytes: u64,
        image: &VlogImage,
        sealed: Vec<Arc<VlogSegment>>,
    ) -> Self {
        let mut log = Self::new(wal_stream, segment_bytes);
        for seg in sealed {
            log.next_segment = log.next_segment.max(seg.id + 1);
            log.sealed.insert(seg.id, seg);
        }
        log.next_segment = log.next_segment.max(image.next_segment).max(image.head_id + 1);
        log.head = VlogSegment::new(image.head_id);
        log.head.records = image.head_records.clone();
        log.head.bytes = image.head_bytes;
        if image.head_bytes > 0 {
            env.device.wal_append_on(log.stream, at, image.head_bytes);
            env.device.wal_sync_on(log.stream, at);
        }
        log
    }

    /// Live block-FS files this log owns (sealed segments) — the
    /// recovery orphan scan keeps these and deletes the rest of the
    /// vlog directory.
    pub fn live_file_ids(&self) -> Vec<FileId> {
        let mut ids: Vec<FileId> =
            self.sealed.values().filter_map(|s| s.file).collect();
        ids.sort_unstable();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ssd::SsdConfig;

    fn env() -> SimEnv {
        SimEnv::new(7, SsdConfig::default())
    }

    fn v(seed: u32, len: u32) -> ValueDesc {
        ValueDesc::new(seed, len)
    }

    #[test]
    fn append_assigns_segment_offsets() {
        let mut e = env();
        let mut log = Vlog::new(0, 1 << 20);
        let a = log.append(&mut e, 0, 1, 1, v(10, 100));
        let b = log.append(&mut e, 0, 2, 2, v(11, 200));
        assert_eq!(a.desc, v(10, 100).at_vlog(0, 0));
        assert_eq!(b.desc, v(11, 200).at_vlog(0, (VLOG_RECORD_HEADER + 100) as u32));
        assert!(a.sealed.is_none() && b.sealed.is_none());
        assert_eq!(log.stats.appends, 2);
        assert_eq!(log.total_bytes(), 2 * VLOG_RECORD_HEADER + 300);
    }

    #[test]
    fn head_seals_when_full() {
        let mut e = env();
        let mut log = Vlog::new(0, 4 << 10);
        let mut sealed = Vec::new();
        for i in 0..10u32 {
            let out = log.append(&mut e, 0, i, i, v(i, 1000));
            if let Some(s) = out.sealed {
                sealed.push(s);
            }
        }
        assert!(!sealed.is_empty());
        for s in &sealed {
            assert!(s.file.is_some(), "sealed segment registered as a file");
            assert!(s.bytes >= 4 << 10);
        }
        assert_eq!(log.stats.segments_sealed as usize, sealed.len());
        // ids are unique and the head is newer than every sealed segment
        for s in &sealed {
            assert!(s.id < log.head_id());
        }
    }

    #[test]
    fn gc_victim_needs_dead_ratio() {
        let mut e = env();
        let mut log = Vlog::new(0, 4 << 10);
        for i in 0..10u32 {
            log.append(&mut e, 0, i, i, v(i, 1000));
        }
        assert_eq!(log.gc_victim(0.4), None, "nothing dead yet");
        let victim = log.sealed_segments().next().unwrap().id;
        let seg_bytes = log.sealed_segment(victim).unwrap().bytes;
        let mut marked = 0;
        for r in log.sealed_segment(victim).unwrap().records.clone() {
            log.mark_dead(victim, r.len);
            marked += r.record_bytes();
            if marked * 2 > seg_bytes {
                break;
            }
        }
        assert_eq!(log.gc_victim(0.4), Some(victim));
        assert_eq!(log.gc_victim(0.99), None);
        let seg = log.retire(victim).unwrap();
        assert_eq!(seg.id, victim);
        assert!(log.sealed_segment(victim).is_none());
    }

    #[test]
    fn crash_image_keeps_durable_prefix_only() {
        let mut e = env();
        let mut log = Vlog::new(0, 64 << 20);
        // well below the 1 MB writeback threshold: everything page-cached
        for i in 0..5u32 {
            log.append(&mut e, 0, i, i, v(i, 100));
        }
        let wm = e.device.wal_durable_watermark_on(log.stream());
        assert_eq!(wm, 0, "small appends stay in page cache");
        let img = log.crash_image(wm);
        assert!(img.head_records.is_empty());
        // after an fsync the whole head is durable
        e.device.wal_sync_on(log.stream(), 0);
        let wm = e.device.wal_durable_watermark_on(log.stream());
        let img = log.crash_image(wm);
        assert_eq!(img.head_records.len(), 5);
        assert_eq!(img.head_bytes, log.total_bytes());
    }

    #[test]
    fn reopen_restores_head_and_sealed() {
        let mut e = env();
        let mut log = Vlog::new(3, 4 << 10);
        let mut sealed = Vec::new();
        for i in 0..8u32 {
            if let Some(s) = log.append(&mut e, 0, i, i, v(i, 1000)).sealed {
                sealed.push(s);
            }
        }
        e.device.wal_sync_on(log.stream(), 0);
        let img = log.crash_image(e.device.wal_durable_watermark_on(log.stream()));
        e.device.wal_reset_stream_on(log.stream());
        let re = Vlog::reopen(&mut e, 0, 3, 4 << 10, &img, sealed.clone());
        assert_eq!(re.head_id(), log.head_id());
        assert_eq!(re.total_bytes(), log.total_bytes());
        assert_eq!(re.sealed_segments().count(), sealed.len());
        assert!(re.next_segment >= log.next_segment);
        // recovered head is durable in the new life
        assert_eq!(e.device.wal_durable_watermark_on(re.stream()), img.head_bytes);
    }
}
