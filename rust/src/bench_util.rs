//! Minimal criterion-style micro-benchmark harness (the offline image
//! has no `criterion` crate). Warmup + timed iterations, mean/p50/p99
//! over per-batch timings, throughput reporting — enough to drive the
//! `cargo bench` targets in rust/benches/.
//!
//! This is a real-time harness file: the wall-clock ban (pallas-lint
//! no-wall-clock, clippy.toml disallowed-methods/types) is lifted here
//! and only here, because measuring host CPU time is the whole point.
#![allow(clippy::disallowed_methods, clippy::disallowed_types)]

use std::time::{Duration, Instant};

pub struct Bencher {
    /// minimum measurement time per benchmark
    pub measure_time: Duration,
    pub warmup_time: Duration,
    results: Vec<BenchResult>,
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    /// optional elements-per-iteration for throughput reporting
    pub elements: Option<u64>,
}

impl BenchResult {
    pub fn elements_per_sec(&self) -> Option<f64> {
        self.elements
            .map(|e| e as f64 / (self.mean_ns / 1e9))
    }
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // honor a quick mode for CI: KVACCEL_BENCH_QUICK=1
        let quick = std::env::var("KVACCEL_BENCH_QUICK").is_ok();
        Self {
            measure_time: if quick {
                Duration::from_millis(200)
            } else {
                Duration::from_secs(2)
            },
            warmup_time: if quick {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(500)
            },
            results: Vec::new(),
        }
    }

    /// Benchmark `f`, which performs ONE iteration per call.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        self.bench_elements(name, None, move || {
            f();
        })
    }

    /// Benchmark with a per-iteration element count (throughput).
    pub fn bench_elements(
        &mut self,
        name: &str,
        elements: Option<u64>,
        mut f: impl FnMut(),
    ) -> &BenchResult {
        // warmup + calibration
        let cal_start = Instant::now();
        let mut cal_iters = 0u64;
        while cal_start.elapsed() < self.warmup_time || cal_iters < 3 {
            f();
            cal_iters += 1;
            if cal_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = cal_start.elapsed().as_secs_f64() / cal_iters as f64;
        // choose a batch so each sample is ~1ms
        let batch = ((0.001 / per_iter).ceil() as u64).clamp(1, 1_000_000);
        let mut samples: Vec<f64> = Vec::new();
        let mut iters = 0u64;
        let meas_start = Instant::now();
        while meas_start.elapsed() < self.measure_time || samples.len() < 10 {
            let s = Instant::now();
            for _ in 0..batch {
                f();
            }
            let per = s.elapsed().as_secs_f64() * 1e9 / batch as f64;
            samples.push(per);
            iters += batch;
            if samples.len() > 100_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let p50 = samples[samples.len() / 2];
        let p99 = samples[(samples.len() * 99) / 100.min(samples.len() - 1).max(1)]
            .min(*samples.last().unwrap());
        let r = BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            p50_ns: p50,
            p99_ns: p99,
            elements,
        };
        println!("{}", format_result(&r));
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    pub fn summary(&self) {
        println!("\n=== bench summary ({} benchmarks) ===", self.results.len());
        for r in &self.results {
            println!("{}", format_result(r));
        }
    }
}

pub fn format_result(r: &BenchResult) -> String {
    let tp = r
        .elements_per_sec()
        .map(|e| format!("  {:>10}/s", crate::util::fmt::si(e).trim().to_string()))
        .unwrap_or_default();
    format!(
        "bench {:<44} mean {:>12}  p50 {:>12}  p99 {:>12}{}",
        r.name,
        crate::util::fmt::nanos(r.mean_ns),
        crate::util::fmt::nanos(r.p50_ns),
        crate::util::fmt::nanos(r.p99_ns),
        tp
    )
}

/// Prevent the optimizer from eliding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_numbers() {
        std::env::set_var("KVACCEL_BENCH_QUICK", "1");
        let mut b = Bencher::new();
        let mut acc = 0u64;
        let r = b.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.mean_ns > 0.0 && r.mean_ns < 1e6);
        assert!(r.iters > 0);
    }

    #[test]
    fn throughput_reported() {
        std::env::set_var("KVACCEL_BENCH_QUICK", "1");
        let mut b = Bencher::new();
        let r = b
            .bench_elements("sum-1k", Some(1000), || {
                let s: u64 = black_box((0..1000u64).sum());
                black_box(s);
            })
            .clone();
        assert!(r.elements_per_sec().unwrap() > 1e6);
    }
}
