//! Workload generation + measurement (the db_bench stand-in).
//!
//! `client` is the event-driven multi-client scheduler (open/closed
//! loop); `db_bench` keeps the paper's Table IV workloads as thin mix
//! presets over it; `keygen` provides the deterministic key/value
//! streams (Uniform/Zipfian/Latest); `stats` the measurement plumbing.
//! Multi-tenant QoS (token buckets, SLO shedding) lives in `crate::qos`
//! and is re-exported here because specs carry it; likewise the
//! replication result types from `crate::repl`, because run results
//! carry them.

pub mod client;
pub mod db_bench;
pub mod keygen;
pub mod stats;

pub use crate::qos::{QosConfig, TenantId, TenantResult, TenantSpec};
pub use crate::repl::{ReplConfig, ReplResult, ReplicaResult, ReplicatedDb};
pub use client::{
    run_spec, run_spec_traced, ClientConfig, LoopMode, OpKind, OpMix, OpTrace, Pace,
    WorkloadSpec,
};
pub use db_bench::{
    fillrandom, fillrandom_batched, needs_preload, preload, preset_spec,
    readwhilewriting, seekrandom, ycsb_e, ycsb_point, BenchConfig,
};
pub use keygen::{KeyDist, KeyGen, ValueSizeDist, MAX_VALUE_LEN};
pub use stats::{cdf, Histogram, OpSeries, RunResult};
