//! Workload generation + measurement (the db_bench stand-in).

pub mod db_bench;
pub mod keygen;
pub mod stats;

pub use db_bench::{
    fillrandom, fillrandom_batched, preload, readwhilewriting, seekrandom, BenchConfig,
};
pub use keygen::KeyGen;
pub use stats::{cdf, Histogram, OpSeries, RunResult};
