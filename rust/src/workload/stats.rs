//! Measurement plumbing for the evaluation harness: latency histograms
//! (P50/P99/P999), per-second op series, and the paper's efficiency
//! metric (Eq. 1: avg throughput MB/s / avg CPU%).

use crate::engine::ScanAmp;
use crate::sim::{Nanos, NS_PER_SEC};

/// Log-bucketed latency histogram: 64 powers of two x 16 linear
/// sub-buckets — <7% relative error, O(1) record.
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum: u128,
    max: u64,
}

const SUB: usize = 16;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self { buckets: vec![0; 64 * SUB], count: 0, sum: 0, max: 0 }
    }

    #[inline]
    fn bucket_of(v: u64) -> usize {
        if v < SUB as u64 {
            return v as usize;
        }
        let exp = 63 - v.leading_zeros() as usize;
        let sub = ((v >> (exp - 4)) & (SUB as u64 - 1)) as usize;
        (exp - 3) * SUB + sub
    }

    fn bucket_value(idx: usize) -> u64 {
        if idx < SUB {
            return idx as u64;
        }
        let exp = idx / SUB + 3;
        let sub = idx % SUB;
        (1u64 << exp) + ((sub as u64) << (exp - 4))
    }

    pub fn record(&mut self, v: Nanos) {
        let idx = Self::bucket_of(v).min(self.buckets.len() - 1);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Quantile in [0,1] -> approximate value.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((self.count as f64) * q).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bucket_value(i).min(self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    pub fn p999(&self) -> u64 {
        self.quantile(0.999)
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }
}

/// Per-second operation counter.
#[derive(Clone, Debug, Default)]
pub struct OpSeries {
    bins: Vec<u64>,
    pub total: u64,
}

impl OpSeries {
    pub fn record(&mut self, at: Nanos) {
        let sec = (at / NS_PER_SEC) as usize;
        if self.bins.len() <= sec {
            self.bins.resize(sec + 1, 0);
        }
        self.bins[sec] += 1;
        self.total += 1;
    }

    pub fn ops_per_sec(&self) -> &[u64] {
        &self.bins
    }

    pub fn mean_ops(&self) -> f64 {
        if self.bins.is_empty() {
            0.0
        } else {
            self.total as f64 / self.bins.len() as f64
        }
    }
}

/// Everything one workload run produces — the figures read fields off
/// this struct.
#[derive(Clone, Debug, Default)]
pub struct RunResult {
    pub system: String,
    pub workload: String,
    pub threads: usize,
    pub duration_s: f64,
    pub writes: OpSeries,
    pub reads: OpSeries,
    pub write_lat: HistogramSummary,
    pub read_lat: HistogramSummary,
    /// user write throughput in MB/s
    pub write_mbps: f64,
    pub read_mbps: f64,
    pub cpu_percent: f64,
    /// Eq. 1: MB/s per CPU%
    pub efficiency: f64,
    pub stop_events: u64,
    pub slowdown_events: u64,
    pub stopped_s: f64,
    pub write_amplification: f64,
    /// per-second combined PCIe MB/s (Intel-PCM stand-in)
    pub pcie_mbps: Vec<f64>,
    /// seconds that intersect a write-stall interval
    pub stall_seconds: Vec<usize>,
    /// KVACCEL extras
    pub redirected_writes: u64,
    pub rollbacks: u64,
    /// Point reads that found a value / found nothing (reported
    /// separately from the write series — workload B/C read visibility).
    pub read_hits: u64,
    pub read_misses: u64,
    /// Open-loop only: time ops waited in their client's FIFO before
    /// service (closed-loop runs have no queue, so this stays empty).
    /// `write_lat`/`read_lat` are *total* latency = queueing + service.
    pub queue_delay: HistogramSummary,
    /// Mean queueing delay (us) per arrival-second — the signal that
    /// grows without bound when the offered rate exceeds what the
    /// engine sustains.
    pub queue_delay_series_us: Vec<f64>,
    /// Cursor scans: one entry per Scan op (Seek + Nexts); whole-scan
    /// latency in `scan_lat`. Scans also count into `reads` (the
    /// db_bench convention: the Seek plus every Next is a read op).
    pub scans: OpSeries,
    pub scan_lat: HistogramSummary,
    /// Engine-lifetime cursor read amplification (blocks/pages touched
    /// per Next, per interface).
    pub scan_amp: ScanAmp,
    /// Per-tenant breakdown when the spec carried a `QosConfig`
    /// (empty otherwise): throughput, latency, queueing, throttling
    /// and shedding, per tenant.
    pub tenants: Vec<crate::qos::TenantResult>,
    /// Replication breakdown when the engine was a [`ReplicatedDb`]
    /// (`None` otherwise): per-replica applied progress and lag, CDC
    /// shipping volume, read routing, failover and anti-entropy totals.
    ///
    /// [`ReplicatedDb`]: crate::repl::ReplicatedDb
    pub replication: Option<crate::repl::ReplResult>,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct HistogramSummary {
    pub count: u64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub p999_us: f64,
    pub max_us: f64,
}

impl From<&Histogram> for HistogramSummary {
    fn from(h: &Histogram) -> Self {
        Self {
            count: h.count(),
            mean_us: h.mean() / 1e3,
            p50_us: h.p50() as f64 / 1e3,
            p99_us: h.p99() as f64 / 1e3,
            p999_us: h.p999() as f64 / 1e3,
            max_us: h.max() as f64 / 1e3,
        }
    }
}

impl RunResult {
    pub fn write_kops(&self) -> f64 {
        self.writes.total as f64 / self.duration_s.max(1e-9) / 1e3
    }

    pub fn read_kops(&self) -> f64 {
        self.reads.total as f64 / self.duration_s.max(1e-9) / 1e3
    }

    pub fn scan_kops(&self) -> f64 {
        self.scans.total as f64 / self.duration_s.max(1e-9) / 1e3
    }

    /// Fraction of point reads that found a value (0.0 when no reads).
    pub fn read_hit_rate(&self) -> f64 {
        let n = self.read_hits + self.read_misses;
        if n == 0 {
            0.0
        } else {
            self.read_hits as f64 / n as f64
        }
    }
}

/// Empirical CDF helper (Fig 5): fraction of samples <= each threshold.
pub fn cdf(samples: &[f64], thresholds: &[f64]) -> Vec<f64> {
    if samples.is_empty() {
        return vec![0.0; thresholds.len()];
    }
    thresholds
        .iter()
        .map(|&t| samples.iter().filter(|&&s| s <= t).count() as f64 / samples.len() as f64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_roughly_right() {
        let mut h = Histogram::new();
        for v in 1..=10_000u64 {
            h.record(v);
        }
        let p50 = h.p50();
        let p99 = h.p99();
        assert!((4500..5600).contains(&p50), "p50={p50}");
        assert!((9300..10001).contains(&p99), "p99={p99}");
        assert_eq!(h.count(), 10_000);
        assert!((h.mean() - 5000.5).abs() < 1.0);
    }

    #[test]
    fn histogram_small_values_exact() {
        let mut h = Histogram::new();
        for v in [0u64, 1, 2, 3] {
            h.record(v);
        }
        assert_eq!(h.quantile(0.25), 0);
        assert_eq!(h.max(), 3);
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(10);
        b.record(1000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max(), 1000);
    }

    #[test]
    fn op_series_bins() {
        let mut s = OpSeries::default();
        s.record(0);
        s.record(NS_PER_SEC - 1);
        s.record(2 * NS_PER_SEC);
        assert_eq!(s.ops_per_sec(), &[2, 0, 1]);
        assert_eq!(s.total, 3);
    }

    #[test]
    fn cdf_fractions() {
        let samples = vec![0.0, 10.0, 50.0, 100.0];
        let got = cdf(&samples, &[0.0, 49.0, 1000.0]);
        assert_eq!(got, vec![0.25, 0.5, 1.0]);
    }
}
