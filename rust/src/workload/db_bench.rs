//! db_bench-equivalent workload presets (paper Table IV):
//!   A: fillrandom, 1 write thread, no limit, 600 s
//!   B: readwhilewriting, +1 read thread, 9:1 write/read
//!   C: readwhilewriting, 8:2
//!   D: seekrandom (Seek + 1024 Next) after a fillrandom preload
//!
//! Since the scheduler refactor these are thin mix presets over
//! `workload::client::run_spec`: each builds a [`WorkloadSpec`] and the
//! event-driven scheduler drives the clients in global virtual-time
//! order. `readwhilewriting` is a real concurrent read client (its own
//! KeyGen/RNG stream, its own timeline in the event queue) paced to the
//! db_bench write:read ratio, not ratio interleaving inside one loop.

use anyhow::{anyhow, Result};

use crate::engine::KvEngine;
use crate::env::SimEnv;
use crate::lsm::entry::Key;
use crate::sim::{Nanos, NS_PER_SEC};

use super::client::{run_spec, ClientConfig, LoopMode, OpMix, WorkloadSpec};
use super::keygen::{KeyDist, KeyGen};
use super::stats::RunResult;

#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Virtual run length (paper: 600 s).
    pub duration: Nanos,
    pub value_size: u32,
    /// Key-space bound (db_bench --num); reads draw from the same space.
    pub key_space: Key,
    pub seed: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            duration: 600 * NS_PER_SEC,
            value_size: 4096,
            key_space: 4_000_000,
            seed: 42,
        }
    }
}

impl BenchConfig {
    /// Scale run length (CI/smoke runs).
    pub fn scaled(mut self, scale: f64) -> Self {
        self.duration = ((self.duration as f64) * scale) as Nanos;
        self
    }
}

/// Workload A: fillrandom, one closed-loop writer. The generated key
/// and timing stream is bit-identical to the pre-scheduler driver
/// (value seeds additionally fold in the generator identity so
/// concurrent writers stay distinguishable).
pub fn fillrandom(sys: &mut dyn KvEngine, env: &mut SimEnv, cfg: &BenchConfig) -> RunResult {
    let spec = WorkloadSpec::from_bench("A/fillrandom", cfg)
        .with_clients(vec![ClientConfig::writer()]);
    run_spec(sys, env, &spec)
}

/// Workload A variant driven through `write_batch`: the closed-loop
/// writer group-commits `batch_size` pairs per submission. Under
/// pressure, KVACCEL redirects each batch to the Dev-LSM as one unit.
pub fn fillrandom_batched(
    sys: &mut dyn KvEngine,
    env: &mut SimEnv,
    cfg: &BenchConfig,
    batch_size: usize,
) -> RunResult {
    let batch_size = batch_size.max(1);
    let client = ClientConfig {
        mix: OpMix::batch_only(),
        batch_size,
        ..ClientConfig::default()
    };
    let spec =
        WorkloadSpec::from_bench(format!("A/fillrandom_batched x{batch_size}"), cfg)
            .with_clients(vec![client]);
    run_spec(sys, env, &spec)
}

/// Workloads B/C: readwhilewriting at a write:read ratio (e.g. (9,1)).
/// Client 0 is the closed-loop writer; client 1 is a concurrent read
/// client paced to issue `ratio_read` reads per `ratio_write` writes
/// (db_bench keeps the running mix at that ratio). Read hit-rate and
/// read latency are reported separately in the [`RunResult`].
pub fn readwhilewriting(
    sys: &mut dyn KvEngine,
    env: &mut SimEnv,
    cfg: &BenchConfig,
    ratio_write: u64,
    ratio_read: u64,
) -> RunResult {
    let spec = WorkloadSpec::from_bench(
        format!("readwhilewriting {ratio_write}:{ratio_read}"),
        cfg,
    )
    .with_clients(vec![
        ClientConfig::writer(),
        ClientConfig::reader()
            .with_seed_tag(0xDEAD_BEEF)
            .with_pace_against(0, ratio_read, ratio_write),
    ]);
    run_spec(sys, env, &spec)
}

/// Workload D: seekrandom — `seeks` range queries of (Seek + `nexts`
/// Next) each, after the caller has preloaded the store.
pub fn seekrandom(
    sys: &mut dyn KvEngine,
    env: &mut SimEnv,
    cfg: &BenchConfig,
    seeks: usize,
    nexts: usize,
    start_at: Nanos,
) -> RunResult {
    let client = ClientConfig {
        mix: OpMix::scan_only(),
        scan_len: nexts,
        max_ops: Some(seeks as u64),
        seed_tag: 0x5EEC,
        ..ClientConfig::default()
    };
    let spec = WorkloadSpec {
        start_at,
        duration: Nanos::MAX, // bounded by max_ops, not the horizon
        ..WorkloadSpec::from_bench("D/seekrandom", cfg)
    }
    .with_clients(vec![client]);
    run_spec(sys, env, &spec)
}

/// Workload E: YCSB-E scan-heavy mix — 95% range scans driven through
/// real cursors (Seek + N Nexts, per-Next latency charged), 5% inserts.
/// Scan lengths draw uniformly from `[scan_len, scan_len_max]` (YCSB's
/// default is uniform 1..100); `scan_len_max <= scan_len` fixes them.
pub fn ycsb_e(
    cfg: &BenchConfig,
    clients: usize,
    mode: LoopMode,
    dist: KeyDist,
    scan_len: usize,
    scan_len_max: usize,
) -> WorkloadSpec {
    let clients = clients.max(1);
    // like the A/B/C presets, an open-loop rate is the aggregate
    // offered load, split evenly across the clients
    let per_client = scale_rate(mode, 1.0 / clients as f64);
    let list: Vec<ClientConfig> = (0..clients)
        .map(|i| {
            ClientConfig {
                mix: OpMix { put: 5, get: 0, delete: 0, scan: 95, batch: 0 },
                mode: per_client,
                dist,
                seed_tag: i as u64,
                ..ClientConfig::default()
            }
            .with_scan_len(scan_len.max(1), scan_len_max)
        })
        .collect();
    WorkloadSpec::from_bench("E/ycsb-e scan:insert 95:5", cfg).with_clients(list)
}

/// YCSB point-read presets B/C/D: every client runs the same
/// read-dominant op mix (YCSB threads are symmetric, unlike db_bench's
/// readwhilewriting writer/reader split). Run these after a [`preload`]
/// — against a cold store every read is a miss and the block cache has
/// nothing to do.
pub fn ycsb_point(
    name: &str,
    cfg: &BenchConfig,
    clients: usize,
    mode: LoopMode,
    dist: KeyDist,
    mix: OpMix,
) -> WorkloadSpec {
    let clients = clients.max(1);
    // open-loop rate is the aggregate offered load, split evenly
    let per_client = scale_rate(mode, 1.0 / clients as f64);
    let list: Vec<ClientConfig> = (0..clients)
        .map(|i| ClientConfig {
            mix,
            mode: per_client,
            dist,
            seed_tag: i as u64,
            ..ClientConfig::default()
        })
        .collect();
    WorkloadSpec::from_bench(name, cfg).with_clients(list)
}

/// True for the read-heavy presets that only make sense against a
/// preloaded store (the runner fills `bytes` of fillrandom data first).
pub fn needs_preload(workload: &str) -> bool {
    matches!(
        workload,
        "YCSB-B" | "ycsb-b" | "YCSB-C" | "ycsb-c" | "YCSB-D" | "ycsb-d"
    )
}

/// Preload helper for workload D (the paper's "initial 20 GB
/// fillrandom"): returns the time after preload + settle.
pub fn preload(
    sys: &mut dyn KvEngine,
    env: &mut SimEnv,
    cfg: &BenchConfig,
    bytes: u64,
) -> Result<Nanos> {
    let mut gen = KeyGen::new(cfg.seed ^ 0xF111, cfg.key_space, cfg.value_size);
    let per_op = 16 + cfg.value_size as u64;
    let ops = bytes / per_op;
    let mut t = 0;
    for op in 0..ops {
        let key = gen.random_key();
        let val = gen.value_for(key, op);
        t = sys.put(env, t, key, val).done;
    }
    sys.finish(env, t)
}

/// Build the spec behind a named workload (A|B|C) with scheduler knobs
/// exposed: client count, loop mode, key distribution. This is what the
/// CLI's `--clients/--rate/--loop-mode/--dist` flags construct.
///
/// - A: `clients` concurrent writers; an open-loop `rate` is the
///   aggregate offered load, split evenly across them.
/// - B/C closed loop: `clients` writers plus one read client paced to
///   the workload's write:read op ratio against the *total* write
///   count (approximated as `clients` x client 0, which is exact for
///   the symmetric writers the preset builds).
/// - B/C open loop: the aggregate `rate` is divided by the workload's
///   op mix — writers share `rate * w/(w+r)`, the reader offers
///   `rate * r/(w+r)` — so both the total offered load and the
///   write:read mix match the named workload.
pub fn preset_spec(
    workload: &str,
    cfg: &BenchConfig,
    clients: usize,
    mode: LoopMode,
    dist: KeyDist,
) -> Result<WorkloadSpec> {
    let clients = clients.max(1);
    let (name, ratio) = match workload {
        "A" => ("A/fillrandom", None),
        "B" => ("B/readwhilewriting 9:1", Some((9u64, 1u64))),
        "C" => ("C/readwhilewriting 8:2", Some((8u64, 2u64))),
        // YCSB-E with its default uniform 1..100 scan lengths; use
        // [`ycsb_e`] directly for custom lengths
        "E" | "ycsb-e" | "YCSB-E" => {
            return Ok(ycsb_e(cfg, clients, mode, dist, 1, 100));
        }
        // YCSB point-read presets (bare B/C stay the db_bench
        // readwhilewriting splits above; the ycsb-* names select these)
        "ycsb-b" | "YCSB-B" => {
            return Ok(ycsb_point(
                "B/ycsb-b read:update 95:5",
                cfg,
                clients,
                mode,
                dist,
                OpMix::put_get(5, 95),
            ));
        }
        "ycsb-c" | "YCSB-C" => {
            return Ok(ycsb_point(
                "C/ycsb-c read-only",
                cfg,
                clients,
                mode,
                dist,
                OpMix::read_only(),
            ));
        }
        // D forces the Latest distribution — the preset IS
        // read-latest-after-insert; `--dist` has no meaning here
        "ycsb-d" | "YCSB-D" => {
            return Ok(ycsb_point(
                "D/ycsb-d read-latest 95:5",
                cfg,
                clients,
                mode,
                KeyDist::Latest,
                OpMix::put_get(5, 95),
            ));
        }
        other => return Err(anyhow!("no preset spec for workload {other:?}")),
    };
    let write_frac = match ratio {
        Some((w, r)) if !matches!(mode, LoopMode::Closed { .. }) => {
            w as f64 / (w + r) as f64
        }
        _ => 1.0,
    };
    let writer_mode = scale_rate(mode, write_frac / clients as f64);
    let mut list: Vec<ClientConfig> = (0..clients)
        .map(|i| {
            ClientConfig::writer()
                .with_mode(writer_mode)
                .with_dist(dist)
                .with_seed_tag(i as u64)
        })
        .collect();
    if let Some((w, r)) = ratio {
        let reader = ClientConfig::reader()
            .with_dist(dist)
            .with_seed_tag(0xDEAD_BEEF);
        list.push(match mode {
            // reader tracks r/w of the TOTAL write count; writers are
            // symmetric, so client 0 carries 1/clients of it
            LoopMode::Closed { .. } => {
                reader.with_pace_against(0, r * clients as u64, w)
            }
            _ => reader.with_mode(scale_rate(mode, 1.0 - write_frac)),
        });
    }
    Ok(WorkloadSpec::from_bench(name, cfg).with_clients(list))
}

/// Scale an open-loop rate by `frac` (closed mode passes through).
fn scale_rate(mode: LoopMode, frac: f64) -> LoopMode {
    match mode {
        LoopMode::OpenFixed { ops_per_sec } => {
            LoopMode::OpenFixed { ops_per_sec: ops_per_sec * frac }
        }
        LoopMode::OpenPoisson { ops_per_sec } => {
            LoopMode::OpenPoisson { ops_per_sec: ops_per_sec * frac }
        }
        closed => closed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::SystemKind;
    use crate::engine::EngineBuilder;
    use crate::lsm::LsmOptions;
    use crate::ssd::SsdConfig;

    fn tiny_cfg() -> BenchConfig {
        BenchConfig {
            duration: 2 * NS_PER_SEC,
            key_space: 50_000,
            ..Default::default()
        }
    }

    fn sys(kind: SystemKind) -> (Box<dyn KvEngine>, SimEnv) {
        (
            EngineBuilder::new(kind)
                .opts(LsmOptions::small_for_test())
                .build(),
            SimEnv::new(3, SsdConfig::default()),
        )
    }

    #[test]
    fn fillrandom_produces_series() {
        let (mut s, mut env) = sys(SystemKind::RocksDb { slowdown: true });
        let r = fillrandom(&mut *s, &mut env, &tiny_cfg());
        assert!(r.writes.total > 100, "writes: {}", r.writes.total);
        assert!(r.duration_s >= 2.0);
        assert!(r.write_lat.p99_us > 0.0);
        assert!(!r.pcie_mbps.is_empty());
    }

    #[test]
    fn readwhilewriting_respects_ratio() {
        let (mut s, mut env) = sys(SystemKind::RocksDb { slowdown: true });
        let r = readwhilewriting(&mut *s, &mut env, &tiny_cfg(), 9, 1);
        assert!(r.writes.total > 0 && r.reads.total > 0);
        let ratio = r.writes.total as f64 / r.reads.total as f64;
        assert!((6.0..14.0).contains(&ratio), "ratio {ratio}");
        // the concurrent read client reports visibility separately
        assert_eq!(r.read_hits + r.read_misses, r.reads.total);
        assert!(r.read_lat.count > 0);
    }

    #[test]
    fn seekrandom_counts_next_ops() {
        let (mut s, mut env) = sys(SystemKind::RocksDb { slowdown: true });
        let cfg = tiny_cfg();
        let t = preload(&mut *s, &mut env, &cfg, 2 << 20).unwrap();
        let r = seekrandom(&mut *s, &mut env, &cfg, 10, 16, t);
        assert!(r.reads.total >= 10, "ops {}", r.reads.total);
        assert!(r.duration_s > 0.0);
    }

    #[test]
    fn kvaccel_run_reports_redirects() {
        use crate::kvaccel::RollbackScheme;
        let (mut s, mut env) = sys(SystemKind::Kvaccel {
            scheme: RollbackScheme::Disabled,
        });
        let r = fillrandom(&mut *s, &mut env, &tiny_cfg());
        assert!(r.redirected_writes > 0, "expected redirection under pressure");
        assert_eq!(r.stop_events, 0, "KVACCEL must not hard-stop");
    }

    #[test]
    fn batched_fillrandom_runs_on_every_engine() {
        use crate::kvaccel::RollbackScheme;
        for kind in [
            SystemKind::RocksDb { slowdown: true },
            SystemKind::Adoc,
            SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
        ] {
            let (mut s, mut env) = sys(kind);
            let r = fillrandom_batched(&mut *s, &mut env, &tiny_cfg(), 16);
            assert!(
                r.writes.total > 100,
                "{}: writes {}",
                kind.label(),
                r.writes.total
            );
            assert!(r.workload.contains("batched"));
        }
    }

    #[test]
    fn preset_spec_builds_multi_client_variants() {
        let cfg = tiny_cfg();
        let a = preset_spec("A", &cfg, 4, LoopMode::Closed { think: 0 }, KeyDist::Uniform)
            .unwrap();
        assert_eq!(a.clients.len(), 4);
        let b = preset_spec(
            "B",
            &cfg,
            2,
            LoopMode::OpenFixed { ops_per_sec: 1000.0 },
            KeyDist::Zipfian { theta: 0.99 },
        )
        .unwrap();
        assert_eq!(b.clients.len(), 3, "2 writers + 1 reader");
        // the aggregate 1000 ops/s divides 9:1 across writes and reads,
        // and the write share splits across the 2 writers
        match b.clients[0].mode {
            LoopMode::OpenFixed { ops_per_sec } => {
                assert!((ops_per_sec - 450.0).abs() < 1e-9, "writer {ops_per_sec}")
            }
            other => panic!("unexpected mode {other:?}"),
        }
        match b.clients[2].mode {
            LoopMode::OpenFixed { ops_per_sec } => {
                assert!((ops_per_sec - 100.0).abs() < 1e-9, "reader {ops_per_sec}")
            }
            other => panic!("unexpected mode {other:?}"),
        }
        // closed-loop B with N writers paces the reader on the total
        let b2 = preset_spec("B", &cfg, 4, LoopMode::Closed { think: 0 }, KeyDist::Uniform)
            .unwrap();
        let pace = b2.clients[4].pace.expect("reader is paced");
        assert_eq!((pace.num, pace.den), (4, 9), "1/9 of 4x client 0's ops");
        assert!(preset_spec("D", &cfg, 1, LoopMode::Closed { think: 0 }, KeyDist::Uniform)
            .is_err());
    }

    #[test]
    fn ycsb_point_presets_build_and_run() {
        let cfg = tiny_cfg();
        let c = preset_spec(
            "ycsb-c",
            &cfg,
            2,
            LoopMode::Closed { think: 0 },
            KeyDist::Uniform,
        )
        .unwrap();
        assert_eq!(c.clients.len(), 2);
        assert_eq!(c.clients[0].mix, OpMix::read_only());
        let d = preset_spec(
            "YCSB-D",
            &cfg,
            1,
            LoopMode::Closed { think: 0 },
            KeyDist::Uniform,
        )
        .unwrap();
        assert_eq!(d.clients[0].dist, KeyDist::Latest, "D forces Latest");
        assert!(needs_preload("ycsb-b") && !needs_preload("A"));
        // end-to-end: B after a preload is read-dominant
        let (mut s, mut env) = sys(SystemKind::RocksDb { slowdown: true });
        let t = preload(&mut *s, &mut env, &cfg, 2 << 20).unwrap();
        let spec = WorkloadSpec {
            start_at: t,
            ..preset_spec(
                "ycsb-b",
                &cfg,
                1,
                LoopMode::Closed { think: 0 },
                KeyDist::Uniform,
            )
            .unwrap()
        };
        let r = run_spec(&mut *s, &mut env, &spec);
        assert!(r.reads.total > r.writes.total, "95:5 read-dominant");
    }

    #[test]
    fn multi_writer_workload_a_scales_clients() {
        let (mut s, mut env) = sys(SystemKind::RocksDb { slowdown: true });
        let cfg = tiny_cfg();
        let spec =
            preset_spec("A", &cfg, 3, LoopMode::Closed { think: 0 }, KeyDist::Uniform)
                .unwrap();
        let r = super::super::client::run_spec(&mut *s, &mut env, &spec);
        assert!(r.writes.total > 300);
    }
}
