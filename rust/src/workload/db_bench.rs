//! db_bench-equivalent workload drivers (paper Table IV):
//!   A: fillrandom, 1 write thread, no limit, 600 s
//!   B: readwhilewriting, +1 read thread, 9:1 write/read
//!   C: readwhilewriting, 8:2
//!   D: seekrandom (Seek + 1024 Next) after a fillrandom preload
//!
//! Closed-loop actors on the virtual clock: each thread issues its next
//! operation when the previous completes; throughput and stalls emerge
//! from the engine + device models.

use anyhow::Result;

use crate::engine::{EngineStats, KvEngine, WriteBatch};
use crate::env::SimEnv;
use crate::lsm::entry::Key;
use crate::sim::{Nanos, NS_PER_SEC};

use super::keygen::KeyGen;
use super::stats::{Histogram, HistogramSummary, OpSeries, RunResult};

#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Virtual run length (paper: 600 s).
    pub duration: Nanos,
    pub value_size: u32,
    /// Key-space bound (db_bench --num); reads draw from the same space.
    pub key_space: Key,
    pub seed: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            duration: 600 * NS_PER_SEC,
            value_size: 4096,
            key_space: 4_000_000,
            seed: 42,
        }
    }
}

impl BenchConfig {
    /// Scale run length (CI/smoke runs).
    pub fn scaled(mut self, scale: f64) -> Self {
        self.duration = ((self.duration as f64) * scale) as Nanos;
        self
    }
}

/// Workload A: fillrandom, one closed-loop writer.
pub fn fillrandom(sys: &mut dyn KvEngine, env: &mut SimEnv, cfg: &BenchConfig) -> RunResult {
    let mut gen = KeyGen::new(cfg.seed, cfg.key_space, cfg.value_size);
    let mut writes = OpSeries::default();
    let mut wlat = Histogram::new();
    let mut t: Nanos = 0;
    let mut op: u64 = 0;
    while t < cfg.duration {
        let key = gen.random_key();
        let val = gen.value_for(key, op);
        let r = sys.put(env, t, key, val);
        wlat.record(r.done - t);
        writes.record(r.done.min(cfg.duration - 1));
        t = r.done;
        op += 1;
    }
    assemble(sys, env, cfg, "A/fillrandom", writes, wlat, OpSeries::default(), Histogram::new(), t)
}

/// Workload A variant driven through `write_batch`: the closed-loop
/// writer group-commits `batch_size` pairs per submission. Under
/// pressure, KVACCEL redirects each batch to the Dev-LSM as one unit.
pub fn fillrandom_batched(
    sys: &mut dyn KvEngine,
    env: &mut SimEnv,
    cfg: &BenchConfig,
    batch_size: usize,
) -> RunResult {
    let batch_size = batch_size.max(1);
    let mut gen = KeyGen::new(cfg.seed, cfg.key_space, cfg.value_size);
    let mut writes = OpSeries::default();
    let mut wlat = Histogram::new();
    let mut t: Nanos = 0;
    let mut op: u64 = 0;
    let mut batch = WriteBatch::with_capacity(batch_size);
    while t < cfg.duration {
        batch.clear();
        for _ in 0..batch_size {
            let key = gen.random_key();
            batch.put(key, gen.value_for(key, op));
            op += 1;
        }
        let r = sys.write_batch(env, t, &batch);
        // per-op latency: the batch latency is shared by its ops
        let per_op = (r.done - t) / batch_size as u64;
        for _ in 0..batch_size {
            wlat.record(per_op.max(1));
            writes.record(r.done.min(cfg.duration - 1));
        }
        t = r.done;
    }
    let name = format!("A/fillrandom_batched x{batch_size}");
    assemble(sys, env, cfg, &name, writes, wlat, OpSeries::default(), Histogram::new(), t)
}

/// Workloads B/C: readwhilewriting at a write:read ratio (e.g. (9,1)).
pub fn readwhilewriting(
    sys: &mut dyn KvEngine,
    env: &mut SimEnv,
    cfg: &BenchConfig,
    ratio_write: u64,
    ratio_read: u64,
) -> RunResult {
    let mut wgen = KeyGen::new(cfg.seed, cfg.key_space, cfg.value_size);
    let mut rgen = KeyGen::new(cfg.seed ^ 0xDEAD_BEEF, cfg.key_space, cfg.value_size);
    let mut writes = OpSeries::default();
    let mut reads = OpSeries::default();
    let mut wlat = Histogram::new();
    let mut rlat = Histogram::new();
    let (mut wt, mut rt): (Nanos, Nanos) = (0, 0);
    let (mut wops, mut rops): (u64, u64) = (0, 0);
    let mut end = 0;
    loop {
        // keep the running mix at ratio_write:ratio_read, each thread
        // closed-loop on its own clock
        let want_read =
            rops * ratio_write < wops * ratio_read && rt < cfg.duration;
        if want_read {
            let key = rgen.random_key();
            let (_, done) = sys.get(env, rt, key);
            rlat.record(done - rt);
            reads.record(done.min(cfg.duration - 1));
            rt = done;
            rops += 1;
            end = end.max(rt);
        } else if wt < cfg.duration {
            let key = wgen.random_key();
            let val = wgen.value_for(key, wops);
            let r = sys.put(env, wt, key, val);
            wlat.record(r.done - wt);
            writes.record(r.done.min(cfg.duration - 1));
            wt = r.done;
            wops += 1;
            end = end.max(wt);
        } else {
            break;
        }
        if wt >= cfg.duration && rt >= cfg.duration {
            break;
        }
    }
    let name = format!("readwhilewriting {ratio_write}:{ratio_read}");
    assemble(sys, env, cfg, &name, writes, wlat, reads, rlat, end)
}

/// Workload D: seekrandom — `seeks` range queries of (Seek + `nexts`
/// Next) each, after the caller has preloaded the store.
pub fn seekrandom(
    sys: &mut dyn KvEngine,
    env: &mut SimEnv,
    cfg: &BenchConfig,
    seeks: usize,
    nexts: usize,
    start_at: Nanos,
) -> RunResult {
    let mut gen = KeyGen::new(cfg.seed ^ 0x5EEC, cfg.key_space, cfg.value_size);
    let mut reads = OpSeries::default();
    let mut rlat = Histogram::new();
    let mut t = start_at;
    let t0 = start_at;
    for _ in 0..seeks {
        let start = gen.random_key();
        let issue = t;
        let (got, done) = sys.scan(env, t, start, nexts);
        // ops counted the db_bench way: the Seek plus every Next
        for _ in 0..=got.len() {
            reads.record(done.min(issue + NS_PER_SEC));
        }
        rlat.record(done - issue);
        t = done;
    }
    let mut r = assemble(
        sys,
        env,
        cfg,
        "D/seekrandom",
        OpSeries::default(),
        Histogram::new(),
        reads,
        rlat,
        t,
    );
    r.duration_s = (t - t0) as f64 / NS_PER_SEC as f64;
    r
}

/// Preload helper for workload D (the paper's "initial 20 GB
/// fillrandom"): returns the time after preload + settle.
pub fn preload(
    sys: &mut dyn KvEngine,
    env: &mut SimEnv,
    cfg: &BenchConfig,
    bytes: u64,
) -> Result<Nanos> {
    let mut gen = KeyGen::new(cfg.seed ^ 0xF111, cfg.key_space, cfg.value_size);
    let per_op = 16 + cfg.value_size as u64;
    let ops = bytes / per_op;
    let mut t = 0;
    for op in 0..ops {
        let key = gen.random_key();
        let val = gen.value_for(key, op);
        t = sys.put(env, t, key, val).done;
    }
    sys.finish(env, t)
}

#[allow(clippy::too_many_arguments)]
fn assemble(
    sys: &dyn KvEngine,
    env: &SimEnv,
    cfg: &BenchConfig,
    workload: &str,
    writes: OpSeries,
    wlat: Histogram,
    reads: OpSeries,
    rlat: Histogram,
    end: Nanos,
) -> RunResult {
    let duration_s = (end.max(1)) as f64 / NS_PER_SEC as f64;
    let db = sys.main_db();
    let stall = sys.stall_stats();
    let cpu_percent = env.cpu.host_cpu_percent(end.max(1), 8);
    let write_mbps = writes.total as f64 * (16 + cfg.value_size as u64) as f64
        / duration_s
        / (1024.0 * 1024.0);
    let read_mbps = reads.total as f64 * (16 + cfg.value_size as u64) as f64
        / duration_s
        / (1024.0 * 1024.0);
    let efficiency = if cpu_percent > 0.0 {
        (write_mbps + read_mbps) / cpu_percent
    } else {
        0.0
    };
    let total_secs = duration_s.ceil() as usize;
    let stall_seconds: Vec<usize> = (0..total_secs)
        .filter(|&s| stall.second_in_stall(s))
        .collect();
    let (redirected, rollbacks) = sys
        .kvaccel()
        .map(|k| {
            (
                k.controller.stats.writes_to_dev,
                k.rollback.stats.rollbacks,
            )
        })
        .unwrap_or((0, 0));
    RunResult {
        system: String::new(), // caller labels
        workload: workload.to_string(),
        threads: db.compaction_threads(),
        duration_s,
        write_lat: HistogramSummary::from(&wlat),
        read_lat: HistogramSummary::from(&rlat),
        writes,
        reads,
        write_mbps,
        read_mbps,
        cpu_percent,
        efficiency,
        stop_events: stall.stop_events,
        slowdown_events: stall.slowdown_events,
        stopped_s: stall.stopped_ns_total as f64 / NS_PER_SEC as f64,
        write_amplification: db.stats.write_amplification(),
        pcie_mbps: env.device.pcie.stats.combined_mbps(),
        stall_seconds,
        redirected_writes: redirected,
        rollbacks,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::SystemKind;
    use crate::engine::EngineBuilder;
    use crate::lsm::LsmOptions;
    use crate::ssd::SsdConfig;

    fn tiny_cfg() -> BenchConfig {
        BenchConfig {
            duration: 2 * NS_PER_SEC,
            key_space: 50_000,
            ..Default::default()
        }
    }

    fn sys(kind: SystemKind) -> (Box<dyn KvEngine>, SimEnv) {
        (
            EngineBuilder::new(kind)
                .opts(LsmOptions::small_for_test())
                .build(),
            SimEnv::new(3, SsdConfig::default()),
        )
    }

    #[test]
    fn fillrandom_produces_series() {
        let (mut s, mut env) = sys(SystemKind::RocksDb { slowdown: true });
        let r = fillrandom(&mut *s, &mut env, &tiny_cfg());
        assert!(r.writes.total > 100, "writes: {}", r.writes.total);
        assert!(r.duration_s >= 2.0);
        assert!(r.write_lat.p99_us > 0.0);
        assert!(!r.pcie_mbps.is_empty());
    }

    #[test]
    fn readwhilewriting_respects_ratio() {
        let (mut s, mut env) = sys(SystemKind::RocksDb { slowdown: true });
        let r = readwhilewriting(&mut *s, &mut env, &tiny_cfg(), 9, 1);
        assert!(r.writes.total > 0 && r.reads.total > 0);
        let ratio = r.writes.total as f64 / r.reads.total as f64;
        assert!((6.0..14.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn seekrandom_counts_next_ops() {
        let (mut s, mut env) = sys(SystemKind::RocksDb { slowdown: true });
        let cfg = tiny_cfg();
        let t = preload(&mut *s, &mut env, &cfg, 2 << 20).unwrap();
        let r = seekrandom(&mut *s, &mut env, &cfg, 10, 16, t);
        assert!(r.reads.total >= 10, "ops {}", r.reads.total);
        assert!(r.duration_s > 0.0);
    }

    #[test]
    fn kvaccel_run_reports_redirects() {
        use crate::kvaccel::RollbackScheme;
        let (mut s, mut env) = sys(SystemKind::Kvaccel {
            scheme: RollbackScheme::Disabled,
        });
        let r = fillrandom(&mut *s, &mut env, &tiny_cfg());
        assert!(r.redirected_writes > 0, "expected redirection under pressure");
        assert_eq!(r.stop_events, 0, "KVACCEL must not hard-stop");
    }

    #[test]
    fn batched_fillrandom_runs_on_every_engine() {
        use crate::kvaccel::RollbackScheme;
        for kind in [
            SystemKind::RocksDb { slowdown: true },
            SystemKind::Adoc,
            SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
        ] {
            let (mut s, mut env) = sys(kind);
            let r = fillrandom_batched(&mut *s, &mut env, &tiny_cfg(), 16);
            assert!(
                r.writes.total > 100,
                "{}: writes {}",
                kind.label(),
                r.writes.total
            );
            assert!(r.workload.contains("batched"));
        }
    }
}
