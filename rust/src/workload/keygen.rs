//! Deterministic key/value generation matching the paper's db_bench
//! configuration: 4 B keys, 4 KB values (Table IV).

use crate::lsm::entry::{Key, ValueDesc, MAX_USER_KEY};
use crate::sim::SimRng;

#[derive(Clone, Debug)]
pub struct KeyGen {
    rng: SimRng,
    /// upper bound (exclusive) of the key space
    pub key_space: Key,
    pub value_size: u32,
}

impl KeyGen {
    pub fn new(seed: u64, key_space: Key, value_size: u32) -> Self {
        assert!(key_space > 0 && key_space <= MAX_USER_KEY);
        Self { rng: SimRng::new(seed), key_space, value_size }
    }

    /// fillrandom: uniform key over the whole space.
    pub fn random_key(&mut self) -> Key {
        self.rng.gen_range_u32(self.key_space)
    }

    /// Fresh value: the seed encodes (key, op#) so overwrites are
    /// distinguishable and verifiable.
    pub fn value_for(&mut self, key: Key, op: u64) -> ValueDesc {
        let seed = (key ^ (op as u32).rotate_left(16)).wrapping_mul(0x9E37_79B1);
        ValueDesc::new(seed, self.value_size)
    }

    pub fn rng(&mut self) -> &mut SimRng {
        &mut self.rng
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_within_space() {
        let mut g = KeyGen::new(1, 1000, 4096);
        for _ in 0..10_000 {
            assert!(g.random_key() < 1000);
        }
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = KeyGen::new(7, u32::MAX - 1, 4096);
        let mut b = KeyGen::new(7, u32::MAX - 1, 4096);
        for _ in 0..100 {
            assert_eq!(a.random_key(), b.random_key());
        }
    }

    #[test]
    fn values_differ_by_op() {
        let mut g = KeyGen::new(1, 100, 4096);
        let v1 = g.value_for(5, 1);
        let v2 = g.value_for(5, 2);
        assert_ne!(v1, v2);
        assert_eq!(v1.len, 4096);
    }
}
