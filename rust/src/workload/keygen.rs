//! Deterministic key/value generation matching the paper's db_bench
//! configuration: 4 B keys, 4 KB values (Table IV), plus YCSB-style key
//! distributions (Uniform / Zipfian / Latest) for the multi-client
//! scheduler.

use crate::lsm::entry::{Key, ValueDesc, MAX_USER_KEY};
use crate::sim::SimRng;

/// Hard cap on a drawn value length (4 MiB): keeps a heavy lognormal
/// tail from producing values larger than a vlog segment.
pub const MAX_VALUE_LEN: u32 = 4 << 20;

/// Per-op value size distribution. `Fixed` draws nothing from the RNG,
/// so every pre-existing fixed-size workload is bit-identical; the
/// spread shapes draw from a *dedicated* value-size stream (never the
/// key RNG), so turning a spread on does not perturb the key sequence.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ValueSizeDist {
    /// Every value exactly this many bytes (db_bench default).
    Fixed(u32),
    /// Uniform in `[lo, hi]` inclusive.
    Uniform { lo: u32, hi: u32 },
    /// Log-normal: `exp(N(mu, sigma^2))` bytes, clamped to
    /// `[1, MAX_VALUE_LEN]` — the long-tailed "mostly small, few huge"
    /// shape real KV value populations show.
    LogNormal { mu: f64, sigma: f64 },
}

impl Default for ValueSizeDist {
    fn default() -> Self {
        ValueSizeDist::Fixed(4096)
    }
}

impl ValueSizeDist {
    /// Mean value size in bytes (log-normal: `exp(mu + sigma^2/2)`,
    /// clamped like the draws). Used for rate/throughput conversions.
    pub fn mean(&self) -> f64 {
        match *self {
            ValueSizeDist::Fixed(n) => n as f64,
            ValueSizeDist::Uniform { lo, hi } => (lo as f64 + hi as f64) / 2.0,
            ValueSizeDist::LogNormal { mu, sigma } => {
                (mu + sigma * sigma / 2.0).exp().clamp(1.0, MAX_VALUE_LEN as f64)
            }
        }
    }

    /// Draw one value length. `Fixed` consumes no randomness (the RNG
    /// stream must stay untouched for bit-identity with fixed-size
    /// workloads).
    pub fn draw(&self, rng: &mut SimRng) -> u32 {
        match *self {
            ValueSizeDist::Fixed(n) => n,
            ValueSizeDist::Uniform { lo, hi } => lo + rng.gen_range_u32(hi - lo + 1),
            ValueSizeDist::LogNormal { mu, sigma } => {
                // Box–Muller: next_f64 is in [0,1), so 1-u1 is in (0,1]
                // and the log never sees zero
                let u1 = rng.next_f64();
                let u2 = rng.next_f64();
                let z = (-2.0 * (1.0 - u1).ln()).sqrt()
                    * (std::f64::consts::TAU * u2).cos();
                let len = (mu + sigma * z).exp();
                len.clamp(1.0, MAX_VALUE_LEN as f64).round() as u32
            }
        }
    }

    /// CLI shape: `N` (fixed), `L:H` (uniform), `lognormal:MU:SIGMA`.
    pub fn parse(s: &str) -> Result<Self, String> {
        let int = |v: &str| -> Result<u32, String> {
            v.parse::<u32>()
                .map_err(|_| format!("expected a byte count, got {v:?}"))
        };
        if let Some(rest) = s
            .strip_prefix("lognormal:")
            .or_else(|| s.strip_prefix("lognorm:"))
        {
            let Some((mu, sigma)) = rest.split_once(':') else {
                return Err(format!(
                    "lognormal needs MU:SIGMA (log-space), got {s:?}"
                ));
            };
            let f = |v: &str| -> Result<f64, String> {
                v.parse::<f64>()
                    .map_err(|_| format!("expected a number, got {v:?}"))
            };
            let (mu, sigma) = (f(mu)?, f(sigma)?);
            if !mu.is_finite() || !sigma.is_finite() || sigma < 0.0 {
                return Err(format!(
                    "lognormal needs finite MU and SIGMA >= 0, got {s:?}"
                ));
            }
            return Ok(ValueSizeDist::LogNormal { mu, sigma });
        }
        match s.split_once(':') {
            Some((lo, hi)) => {
                let (lo, hi) = (int(lo)?, int(hi)?);
                if lo == 0 || hi < lo || hi > MAX_VALUE_LEN {
                    return Err(format!(
                        "uniform L:H needs 1 <= L <= H <= {MAX_VALUE_LEN}, got {s:?}"
                    ));
                }
                Ok(ValueSizeDist::Uniform { lo, hi })
            }
            None => {
                let n = int(s)?;
                if n == 0 || n > MAX_VALUE_LEN {
                    return Err(format!(
                        "fixed size needs 1..={MAX_VALUE_LEN}, got {s:?}"
                    ));
                }
                Ok(ValueSizeDist::Fixed(n))
            }
        }
    }
}

/// Key popularity distribution (YCSB naming).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum KeyDist {
    /// Every key equally likely (db_bench fillrandom).
    #[default]
    Uniform,
    /// Scrambled zipfian over the whole key space: a few hot keys draw
    /// most of the traffic, hash-spread across the space. `theta` in
    /// (0, 1); YCSB default is 0.99.
    Zipfian { theta: f64 },
    /// Latest-biased: writes append fresh keys, reads prefer the most
    /// recently written ones (zipfian over recency rank).
    Latest,
}

/// Precomputed zipfian sampler (Gray et al., as used by YCSB).
#[derive(Clone, Debug)]
struct Zipfian {
    n: u64,
    theta: f64,
    alpha: f64,
    zetan: f64,
    eta: f64,
}

/// Per-thread memo for `zeta` — every client of a multi-client spec
/// shares the same (n, theta), and the 1M-term series is the only
/// expensive part of Zipfian construction.
type ZetaCache = std::cell::RefCell<Vec<((u64, u64), f64)>>;

fn zeta_cached(n: u64, theta: f64) -> f64 {
    thread_local! {
        static CACHE: ZetaCache = ZetaCache::new(Vec::new());
    }
    CACHE.with(|c| {
        let key = (n, theta.to_bits());
        if let Some(&(_, v)) = c.borrow().iter().find(|(k, _)| *k == key) {
            return v;
        }
        let v = zeta(n, theta);
        c.borrow_mut().push((key, v));
        v
    })
}

/// Generalized harmonic number sum(1/i^theta, i=1..n). Exact up to 1M
/// terms, integral-approximated beyond (workload skew, not number
/// theory — the tail error is <0.1% for the spaces we use).
fn zeta(n: u64, theta: f64) -> f64 {
    const EXACT: u64 = 1_000_000;
    let m = n.min(EXACT);
    let mut z = 0.0;
    for i in 1..=m {
        z += (i as f64).powf(-theta);
    }
    if n > m {
        if (theta - 1.0).abs() < 1e-9 {
            z += (n as f64 / m as f64).ln();
        } else {
            z += ((n as f64).powf(1.0 - theta) - (m as f64).powf(1.0 - theta))
                / (1.0 - theta);
        }
    }
    z
}

impl Zipfian {
    fn new(n: u64, theta: f64) -> Self {
        assert!(
            theta > 0.0 && theta < 1.0,
            "zipfian theta must be in (0,1), got {theta}"
        );
        let n = n.max(2);
        let zetan = zeta_cached(n, theta);
        let zeta2 = zeta(2, theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
        Self { n, theta, alpha, zetan, eta }
    }

    /// Draw a popularity rank in [0, n): rank 0 is the hottest item.
    fn draw(&self, rng: &mut SimRng) -> u64 {
        let u = rng.next_f64();
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let rank = (self.n as f64 * (self.eta * u - self.eta + 1.0).powf(self.alpha)) as u64;
        rank.min(self.n - 1)
    }
}

/// Stateless integer hash (splitmix64 finalizer): spreads zipfian ranks
/// across the key space so hot keys are not all adjacent.
fn scramble(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[derive(Clone, Debug)]
pub struct KeyGen {
    rng: SimRng,
    /// upper bound (exclusive) of the key space
    pub key_space: Key,
    /// Fixed size, or the rounded mean when a spread is configured.
    pub value_size: u32,
    dist: KeyDist,
    vdist: ValueSizeDist,
    /// Dedicated stream for value-size draws: spread distributions must
    /// not perturb the key sequence (and `Fixed` never touches it).
    vrng: SimRng,
    zipf: Option<Zipfian>,
    /// Latest: number of keys written so far (write high-water mark).
    inserted: u64,
    /// Folded from the generator seed: distinguishes values written by
    /// different clients for the same (key, op#) pair.
    value_salt: u32,
}

impl KeyGen {
    /// Uniform keys — byte-compatible with the pre-scheduler generator:
    /// the draw sequence of `random_key` is unchanged.
    pub fn new(seed: u64, key_space: Key, value_size: u32) -> Self {
        Self::with_dist(seed, key_space, value_size, KeyDist::Uniform)
    }

    pub fn with_dist(seed: u64, key_space: Key, value_size: u32, dist: KeyDist) -> Self {
        Self::with_value_dist(seed, key_space, dist, ValueSizeDist::Fixed(value_size))
    }

    pub fn with_value_dist(
        seed: u64,
        key_space: Key,
        dist: KeyDist,
        vdist: ValueSizeDist,
    ) -> Self {
        assert!(key_space > 0 && key_space <= MAX_USER_KEY);
        let zipf = match dist {
            KeyDist::Uniform => None,
            KeyDist::Zipfian { theta } => Some(Zipfian::new(key_space as u64, theta)),
            // Latest draws a recency *rank*; 0.99 is the YCSB default.
            KeyDist::Latest => Some(Zipfian::new(key_space as u64, 0.99)),
        };
        Self {
            rng: SimRng::new(seed),
            key_space,
            value_size: vdist.mean().round().max(1.0) as u32,
            dist,
            vdist,
            vrng: SimRng::new(seed ^ 0x5A1E_BEEF_1057_0DD5),
            zipf,
            inserted: 0,
            value_salt: (seed ^ (seed >> 32)) as u32,
        }
    }

    pub fn dist(&self) -> KeyDist {
        self.dist
    }

    /// Write high-water mark (Latest: number of appended keys).
    pub fn inserted(&self) -> u64 {
        self.inserted
    }

    /// Adopt a higher write high-water mark observed elsewhere (the
    /// scheduler shares the newest frontier across Latest clients, so a
    /// read-only client follows the writers' appends — YCSB's latest
    /// distribution uses one global insert counter).
    pub fn observe_inserted(&mut self, high_water: u64) {
        if high_water > self.inserted {
            self.inserted = high_water;
        }
    }

    /// Read-side key draw. Uniform/Zipfian: the stationary distribution.
    /// Latest: zipfian over recency, newest keys hottest.
    pub fn random_key(&mut self) -> Key {
        match self.dist {
            KeyDist::Uniform => self.rng.gen_range_u32(self.key_space),
            KeyDist::Zipfian { .. } => {
                let rank = self.zipf.as_ref().unwrap().draw(&mut self.rng);
                (scramble(rank) % self.key_space as u64) as Key
            }
            KeyDist::Latest => {
                if self.inserted == 0 {
                    return 0;
                }
                let window = self.inserted.min(self.key_space as u64);
                let z = self.zipf.as_ref().unwrap().draw(&mut self.rng) % window;
                // newest written key minus its recency rank, modulo wrap
                ((self.inserted - 1 - z) % self.key_space as u64) as Key
            }
        }
    }

    /// Write-side key draw. Latest appends sequentially (YCSB insert
    /// order, wrapping at the space bound); other distributions write
    /// where they read.
    pub fn write_key(&mut self) -> Key {
        match self.dist {
            KeyDist::Latest => {
                let k = (self.inserted % self.key_space as u64) as Key;
                self.inserted += 1;
                k
            }
            _ => self.random_key(),
        }
    }

    /// Fresh value: the seed encodes (generator, key, op#) so
    /// overwrites are distinguishable and verifiable, including across
    /// concurrent clients writing the same key. The length comes from
    /// the value-size distribution (`Fixed` draws no randomness).
    pub fn value_for(&mut self, key: Key, op: u64) -> ValueDesc {
        let len = self.draw_value_len();
        self.value_with_len(key, op, len)
    }

    /// Like `value_for` with the length already drawn (the QoS admission
    /// path draws up front so the bucket charges what will be written).
    pub fn value_with_len(&mut self, key: Key, op: u64, len: u32) -> ValueDesc {
        let seed = (key ^ (op as u32).rotate_left(16) ^ self.value_salt)
            .wrapping_mul(0x9E37_79B1);
        ValueDesc::new(seed, len)
    }

    /// Draw one value length from the configured distribution.
    pub fn draw_value_len(&mut self) -> u32 {
        self.vdist.draw(&mut self.vrng)
    }

    pub fn rng(&mut self) -> &mut SimRng {
        &mut self.rng
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_within_space() {
        let mut g = KeyGen::new(1, 1000, 4096);
        for _ in 0..10_000 {
            assert!(g.random_key() < 1000);
        }
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = KeyGen::new(7, u32::MAX - 1, 4096);
        let mut b = KeyGen::new(7, u32::MAX - 1, 4096);
        for _ in 0..100 {
            assert_eq!(a.random_key(), b.random_key());
        }
    }

    #[test]
    fn values_differ_by_op() {
        let mut g = KeyGen::new(1, 100, 4096);
        let v1 = g.value_for(5, 1);
        let v2 = g.value_for(5, 2);
        assert_ne!(v1, v2);
        assert_eq!(v1.len, 4096);
    }

    #[test]
    fn zipfian_is_skewed_and_bounded() {
        let space: Key = 10_000;
        let mut g = KeyGen::with_dist(3, space, 64, KeyDist::Zipfian { theta: 0.99 });
        let mut counts = std::collections::BTreeMap::new();
        let draws = 20_000;
        for _ in 0..draws {
            let k = g.random_key();
            assert!(k < space);
            *counts.entry(k).or_insert(0u32) += 1;
        }
        let hottest = counts.values().max().copied().unwrap();
        // uniform expectation is 2 per key; the zipfian head must be far
        // above that, and the space must not collapse to a handful of keys
        assert!(hottest > 1000, "no skew: hottest={hottest}");
        assert!(counts.len() > 500, "collapsed: {} distinct", counts.len());
    }

    #[test]
    fn zipfian_deterministic() {
        let mk = || KeyGen::with_dist(9, 5000, 64, KeyDist::Zipfian { theta: 0.8 });
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..1000 {
            assert_eq!(a.random_key(), b.random_key());
        }
    }

    #[test]
    fn latest_prefers_recent_writes() {
        let space: Key = 100_000;
        let mut g = KeyGen::with_dist(5, space, 64, KeyDist::Latest);
        // before any write, reads fall back to key 0
        assert_eq!(g.random_key(), 0);
        for i in 0..10_000u32 {
            assert_eq!(g.write_key(), i, "latest writes append sequentially");
        }
        let mut recent = 0;
        let reads = 5_000;
        for _ in 0..reads {
            let k = g.random_key();
            assert!(k < 10_000, "read beyond high-water mark: {k}");
            if k >= 9_000 {
                recent += 1;
            }
        }
        // zipf(0.99) over recency: the newest 10% of keys should draw a
        // clear majority of reads
        assert!(recent * 2 > reads, "latest not biased: {recent}/{reads}");
    }

    #[test]
    fn latest_write_wraps_at_space_bound() {
        let mut g = KeyGen::with_dist(5, 10, 64, KeyDist::Latest);
        for _ in 0..25 {
            let k = g.write_key();
            assert!(k < 10);
        }
        for _ in 0..100 {
            assert!(g.random_key() < 10);
        }
    }

    #[test]
    fn fixed_value_dist_is_bit_identical_to_plain_fixed() {
        // Fixed draws nothing from either RNG stream, so the full
        // (key, value) sequence matches a pre-spread-era generator
        let mut a = KeyGen::new(11, 10_000, 4096);
        let mut b = KeyGen::with_value_dist(
            11,
            10_000,
            KeyDist::Uniform,
            ValueSizeDist::Fixed(4096),
        );
        for op in 0..1000 {
            let (ka, kb) = (a.write_key(), b.write_key());
            assert_eq!(ka, kb);
            assert_eq!(a.value_for(ka, op), b.value_for(kb, op));
        }
    }

    #[test]
    fn uniform_value_dist_spans_range_deterministically() {
        let d = ValueSizeDist::Uniform { lo: 100, hi: 8192 };
        let mk = || {
            KeyGen::with_value_dist(21, 1000, KeyDist::Uniform, d)
        };
        let (mut a, mut b) = (mk(), mk());
        let mut lens = std::collections::BTreeSet::new();
        for _ in 0..2000 {
            let (la, lb) = (a.draw_value_len(), b.draw_value_len());
            assert_eq!(la, lb, "value stream must be deterministic");
            assert!((100..=8192).contains(&la));
            lens.insert(la);
        }
        assert!(lens.len() > 500, "uniform collapsed: {}", lens.len());
        assert!((d.mean() - 4146.0).abs() < 1.0);
    }

    #[test]
    fn lognormal_value_dist_long_tailed_and_clamped() {
        // mu=8, sigma=1.5: median e^8 ~ 3 kB, mean ~ 9.2 kB, rare
        // multi-hundred-kB outliers
        let d = ValueSizeDist::LogNormal { mu: 8.0, sigma: 1.5 };
        let mut g = KeyGen::with_value_dist(33, 1000, KeyDist::Uniform, d);
        let draws: Vec<u32> = (0..5000).map(|_| g.draw_value_len()).collect();
        assert!(draws.iter().all(|&l| (1..=MAX_VALUE_LEN).contains(&l)));
        let mean = draws.iter().map(|&l| l as f64).sum::<f64>() / draws.len() as f64;
        assert!((4000.0..20_000.0).contains(&mean), "mean {mean}");
        let max = *draws.iter().max().unwrap();
        assert!(max > 50_000, "no tail: max {max}");
    }

    #[test]
    fn value_dist_parse_accepts_the_cli_shapes() {
        assert_eq!(ValueSizeDist::parse("4096"), Ok(ValueSizeDist::Fixed(4096)));
        assert_eq!(
            ValueSizeDist::parse("64:1024"),
            Ok(ValueSizeDist::Uniform { lo: 64, hi: 1024 })
        );
        assert_eq!(
            ValueSizeDist::parse("lognormal:8.0:1.5"),
            Ok(ValueSizeDist::LogNormal { mu: 8.0, sigma: 1.5 })
        );
        for bad in [
            "", "0", "big", "10:5", "0:5", "lognormal:8", "lognormal:x:1",
            "lognormal:8:-1", "9999999999",
        ] {
            assert!(ValueSizeDist::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn spread_values_do_not_perturb_the_key_stream() {
        let mut fixed = KeyGen::new(5, 10_000, 4096);
        let mut spread = KeyGen::with_value_dist(
            5,
            10_000,
            KeyDist::Uniform,
            ValueSizeDist::Uniform { lo: 16, hi: 65_536 },
        );
        for op in 0..1000 {
            let (ka, kb) = (fixed.write_key(), spread.write_key());
            assert_eq!(ka, kb, "value sizing leaked into the key RNG");
            // the value *seed* matches too; only the length differs
            let (va, vb) = (fixed.value_for(ka, op), spread.value_for(kb, op));
            assert_eq!(va.seed, vb.seed);
        }
    }

    #[test]
    fn zeta_tail_approximation_close() {
        // compare the integral tail against brute force on a crossable size
        let exact: f64 = (1..=2_000_000u64).map(|i| (i as f64).powf(-0.9)).sum();
        let approx = zeta(2_000_000, 0.9);
        assert!((exact - approx).abs() / exact < 1e-3, "{exact} vs {approx}");
    }
}
