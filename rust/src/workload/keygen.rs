//! Deterministic key/value generation matching the paper's db_bench
//! configuration: 4 B keys, 4 KB values (Table IV), plus YCSB-style key
//! distributions (Uniform / Zipfian / Latest) for the multi-client
//! scheduler.

use crate::lsm::entry::{Key, ValueDesc, MAX_USER_KEY};
use crate::sim::SimRng;

/// Key popularity distribution (YCSB naming).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum KeyDist {
    /// Every key equally likely (db_bench fillrandom).
    #[default]
    Uniform,
    /// Scrambled zipfian over the whole key space: a few hot keys draw
    /// most of the traffic, hash-spread across the space. `theta` in
    /// (0, 1); YCSB default is 0.99.
    Zipfian { theta: f64 },
    /// Latest-biased: writes append fresh keys, reads prefer the most
    /// recently written ones (zipfian over recency rank).
    Latest,
}

/// Precomputed zipfian sampler (Gray et al., as used by YCSB).
#[derive(Clone, Debug)]
struct Zipfian {
    n: u64,
    theta: f64,
    alpha: f64,
    zetan: f64,
    eta: f64,
}

/// Per-thread memo for `zeta` — every client of a multi-client spec
/// shares the same (n, theta), and the 1M-term series is the only
/// expensive part of Zipfian construction.
type ZetaCache = std::cell::RefCell<Vec<((u64, u64), f64)>>;

fn zeta_cached(n: u64, theta: f64) -> f64 {
    thread_local! {
        static CACHE: ZetaCache = ZetaCache::new(Vec::new());
    }
    CACHE.with(|c| {
        let key = (n, theta.to_bits());
        if let Some(&(_, v)) = c.borrow().iter().find(|(k, _)| *k == key) {
            return v;
        }
        let v = zeta(n, theta);
        c.borrow_mut().push((key, v));
        v
    })
}

/// Generalized harmonic number sum(1/i^theta, i=1..n). Exact up to 1M
/// terms, integral-approximated beyond (workload skew, not number
/// theory — the tail error is <0.1% for the spaces we use).
fn zeta(n: u64, theta: f64) -> f64 {
    const EXACT: u64 = 1_000_000;
    let m = n.min(EXACT);
    let mut z = 0.0;
    for i in 1..=m {
        z += (i as f64).powf(-theta);
    }
    if n > m {
        if (theta - 1.0).abs() < 1e-9 {
            z += (n as f64 / m as f64).ln();
        } else {
            z += ((n as f64).powf(1.0 - theta) - (m as f64).powf(1.0 - theta))
                / (1.0 - theta);
        }
    }
    z
}

impl Zipfian {
    fn new(n: u64, theta: f64) -> Self {
        assert!(
            theta > 0.0 && theta < 1.0,
            "zipfian theta must be in (0,1), got {theta}"
        );
        let n = n.max(2);
        let zetan = zeta_cached(n, theta);
        let zeta2 = zeta(2, theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
        Self { n, theta, alpha, zetan, eta }
    }

    /// Draw a popularity rank in [0, n): rank 0 is the hottest item.
    fn draw(&self, rng: &mut SimRng) -> u64 {
        let u = rng.next_f64();
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let rank = (self.n as f64 * (self.eta * u - self.eta + 1.0).powf(self.alpha)) as u64;
        rank.min(self.n - 1)
    }
}

/// Stateless integer hash (splitmix64 finalizer): spreads zipfian ranks
/// across the key space so hot keys are not all adjacent.
fn scramble(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[derive(Clone, Debug)]
pub struct KeyGen {
    rng: SimRng,
    /// upper bound (exclusive) of the key space
    pub key_space: Key,
    pub value_size: u32,
    dist: KeyDist,
    zipf: Option<Zipfian>,
    /// Latest: number of keys written so far (write high-water mark).
    inserted: u64,
    /// Folded from the generator seed: distinguishes values written by
    /// different clients for the same (key, op#) pair.
    value_salt: u32,
}

impl KeyGen {
    /// Uniform keys — byte-compatible with the pre-scheduler generator:
    /// the draw sequence of `random_key` is unchanged.
    pub fn new(seed: u64, key_space: Key, value_size: u32) -> Self {
        Self::with_dist(seed, key_space, value_size, KeyDist::Uniform)
    }

    pub fn with_dist(seed: u64, key_space: Key, value_size: u32, dist: KeyDist) -> Self {
        assert!(key_space > 0 && key_space <= MAX_USER_KEY);
        let zipf = match dist {
            KeyDist::Uniform => None,
            KeyDist::Zipfian { theta } => Some(Zipfian::new(key_space as u64, theta)),
            // Latest draws a recency *rank*; 0.99 is the YCSB default.
            KeyDist::Latest => Some(Zipfian::new(key_space as u64, 0.99)),
        };
        Self {
            rng: SimRng::new(seed),
            key_space,
            value_size,
            dist,
            zipf,
            inserted: 0,
            value_salt: (seed ^ (seed >> 32)) as u32,
        }
    }

    pub fn dist(&self) -> KeyDist {
        self.dist
    }

    /// Write high-water mark (Latest: number of appended keys).
    pub fn inserted(&self) -> u64 {
        self.inserted
    }

    /// Adopt a higher write high-water mark observed elsewhere (the
    /// scheduler shares the newest frontier across Latest clients, so a
    /// read-only client follows the writers' appends — YCSB's latest
    /// distribution uses one global insert counter).
    pub fn observe_inserted(&mut self, high_water: u64) {
        if high_water > self.inserted {
            self.inserted = high_water;
        }
    }

    /// Read-side key draw. Uniform/Zipfian: the stationary distribution.
    /// Latest: zipfian over recency, newest keys hottest.
    pub fn random_key(&mut self) -> Key {
        match self.dist {
            KeyDist::Uniform => self.rng.gen_range_u32(self.key_space),
            KeyDist::Zipfian { .. } => {
                let rank = self.zipf.as_ref().unwrap().draw(&mut self.rng);
                (scramble(rank) % self.key_space as u64) as Key
            }
            KeyDist::Latest => {
                if self.inserted == 0 {
                    return 0;
                }
                let window = self.inserted.min(self.key_space as u64);
                let z = self.zipf.as_ref().unwrap().draw(&mut self.rng) % window;
                // newest written key minus its recency rank, modulo wrap
                ((self.inserted - 1 - z) % self.key_space as u64) as Key
            }
        }
    }

    /// Write-side key draw. Latest appends sequentially (YCSB insert
    /// order, wrapping at the space bound); other distributions write
    /// where they read.
    pub fn write_key(&mut self) -> Key {
        match self.dist {
            KeyDist::Latest => {
                let k = (self.inserted % self.key_space as u64) as Key;
                self.inserted += 1;
                k
            }
            _ => self.random_key(),
        }
    }

    /// Fresh value: the seed encodes (generator, key, op#) so
    /// overwrites are distinguishable and verifiable, including across
    /// concurrent clients writing the same key.
    pub fn value_for(&mut self, key: Key, op: u64) -> ValueDesc {
        let seed = (key ^ (op as u32).rotate_left(16) ^ self.value_salt)
            .wrapping_mul(0x9E37_79B1);
        ValueDesc::new(seed, self.value_size)
    }

    pub fn rng(&mut self) -> &mut SimRng {
        &mut self.rng
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_within_space() {
        let mut g = KeyGen::new(1, 1000, 4096);
        for _ in 0..10_000 {
            assert!(g.random_key() < 1000);
        }
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = KeyGen::new(7, u32::MAX - 1, 4096);
        let mut b = KeyGen::new(7, u32::MAX - 1, 4096);
        for _ in 0..100 {
            assert_eq!(a.random_key(), b.random_key());
        }
    }

    #[test]
    fn values_differ_by_op() {
        let mut g = KeyGen::new(1, 100, 4096);
        let v1 = g.value_for(5, 1);
        let v2 = g.value_for(5, 2);
        assert_ne!(v1, v2);
        assert_eq!(v1.len, 4096);
    }

    #[test]
    fn zipfian_is_skewed_and_bounded() {
        let space: Key = 10_000;
        let mut g = KeyGen::with_dist(3, space, 64, KeyDist::Zipfian { theta: 0.99 });
        let mut counts = std::collections::BTreeMap::new();
        let draws = 20_000;
        for _ in 0..draws {
            let k = g.random_key();
            assert!(k < space);
            *counts.entry(k).or_insert(0u32) += 1;
        }
        let hottest = counts.values().max().copied().unwrap();
        // uniform expectation is 2 per key; the zipfian head must be far
        // above that, and the space must not collapse to a handful of keys
        assert!(hottest > 1000, "no skew: hottest={hottest}");
        assert!(counts.len() > 500, "collapsed: {} distinct", counts.len());
    }

    #[test]
    fn zipfian_deterministic() {
        let mk = || KeyGen::with_dist(9, 5000, 64, KeyDist::Zipfian { theta: 0.8 });
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..1000 {
            assert_eq!(a.random_key(), b.random_key());
        }
    }

    #[test]
    fn latest_prefers_recent_writes() {
        let space: Key = 100_000;
        let mut g = KeyGen::with_dist(5, space, 64, KeyDist::Latest);
        // before any write, reads fall back to key 0
        assert_eq!(g.random_key(), 0);
        for i in 0..10_000u32 {
            assert_eq!(g.write_key(), i, "latest writes append sequentially");
        }
        let mut recent = 0;
        let reads = 5_000;
        for _ in 0..reads {
            let k = g.random_key();
            assert!(k < 10_000, "read beyond high-water mark: {k}");
            if k >= 9_000 {
                recent += 1;
            }
        }
        // zipf(0.99) over recency: the newest 10% of keys should draw a
        // clear majority of reads
        assert!(recent * 2 > reads, "latest not biased: {recent}/{reads}");
    }

    #[test]
    fn latest_write_wraps_at_space_bound() {
        let mut g = KeyGen::with_dist(5, 10, 64, KeyDist::Latest);
        for _ in 0..25 {
            let k = g.write_key();
            assert!(k < 10);
        }
        for _ in 0..100 {
            assert!(g.random_key() < 10);
        }
    }

    #[test]
    fn zeta_tail_approximation_close() {
        // compare the integral tail against brute force on a crossable size
        let exact: f64 = (1..=2_000_000u64).map(|i| (i as f64).powf(-0.9)).sum();
        let approx = zeta(2_000_000, 0.9);
        assert!((exact - approx).abs() / exact < 1e-3, "{exact} vs {approx}");
    }
}
