//! Event-driven multi-client workload model.
//!
//! A [`WorkloadSpec`] describes N concurrent clients, each a state
//! machine with its own `KeyGen`/RNG stream and a weighted op mix
//! (put/get/delete/scan/batch). A discrete-event scheduler
//! (`sim::sched::EventQueue`) drives them in global virtual-time order
//! against one shared `&mut dyn KvEngine`:
//!
//! - **Closed loop**: a client reissues when its previous op completes
//!   (plus optional think time). Latency is pure service time; the
//!   offered load adapts to what the engine sustains — write-stall
//!   *queueing* is invisible by construction.
//! - **Open loop**: requests arrive at a fixed or Poisson rate into a
//!   per-client FIFO regardless of completions. Latency = queueing
//!   delay + service time, recorded separately, so a rate above the
//!   engine's sustainable throughput shows up as unbounded queue growth
//!   (the write-stall pathology the paper's Table IV workloads probe).
//!
//! The old db_bench drivers (`workload::db_bench`) are thin mix presets
//! over this scheduler.

use crate::engine::{DbIterator, EngineStats, IterOptions, KvEngine, WriteBatch};
use crate::env::SimEnv;
use crate::lsm::entry::Key;
use crate::qos::{QosConfig, QosController, TenantId, TenantSpec};
use crate::sim::sched::{ActorId, EventKind, EventQueue};
use crate::sim::{Nanos, SimRng, NS_PER_SEC};

use super::db_bench::BenchConfig;
use super::keygen::{KeyDist, KeyGen, ValueSizeDist};
use super::stats::{Histogram, HistogramSummary, OpSeries, RunResult};

// ---------------------------------------------------------------------
// Client configuration
// ---------------------------------------------------------------------

/// One operation kind a client can issue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    Put,
    Get,
    Delete,
    Scan,
    Batch,
}

/// Weighted operation mix; weights are relative (9:1, not percentages).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OpMix {
    pub put: u32,
    pub get: u32,
    pub delete: u32,
    pub scan: u32,
    pub batch: u32,
}

impl OpMix {
    pub fn write_only() -> Self {
        Self { put: 1, get: 0, delete: 0, scan: 0, batch: 0 }
    }

    pub fn read_only() -> Self {
        Self { put: 0, get: 1, delete: 0, scan: 0, batch: 0 }
    }

    pub fn scan_only() -> Self {
        Self { put: 0, get: 0, delete: 0, scan: 1, batch: 0 }
    }

    pub fn batch_only() -> Self {
        Self { put: 0, get: 0, delete: 0, scan: 0, batch: 1 }
    }

    /// Mixed put/get at the given write:read weights.
    pub fn put_get(put: u32, get: u32) -> Self {
        Self { put, get, delete: 0, scan: 0, batch: 0 }
    }

    fn total(&self) -> u32 {
        self.put + self.get + self.delete + self.scan + self.batch
    }

    fn pick(&self, rng: &mut SimRng) -> OpKind {
        let total = self.total().max(1);
        // single-kind mixes skip the draw (keeps presets cheap)
        if self.put == total {
            return OpKind::Put;
        }
        if self.get == total {
            return OpKind::Get;
        }
        if self.delete == total {
            return OpKind::Delete;
        }
        if self.scan == total {
            return OpKind::Scan;
        }
        if self.batch == total {
            return OpKind::Batch;
        }
        let mut x = rng.gen_range_u32(total);
        for (w, k) in [
            (self.put, OpKind::Put),
            (self.get, OpKind::Get),
            (self.delete, OpKind::Delete),
            (self.scan, OpKind::Scan),
            (self.batch, OpKind::Batch),
        ] {
            if x < w {
                return k;
            }
            x -= w;
        }
        OpKind::Put
    }
}

/// How a client generates load.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LoopMode {
    /// Reissue when the previous op completes, after `think` ns.
    Closed { think: Nanos },
    /// Deterministic fixed-rate arrivals into the client's FIFO.
    OpenFixed { ops_per_sec: f64 },
    /// Poisson arrivals at the given mean rate.
    OpenPoisson { ops_per_sec: f64 },
}

/// Ratio coupling for closed-loop clients (db_bench readwhilewriting):
/// this client only issues while `own_ops * den < other_ops * num`,
/// i.e. it tracks `num/den` of the paced-against client's op count.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Pace {
    pub against: ActorId,
    pub num: u64,
    pub den: u64,
}

#[derive(Clone, Debug)]
pub struct ClientConfig {
    pub mix: OpMix,
    pub mode: LoopMode,
    pub dist: KeyDist,
    /// Next count per Scan op (the minimum when `scan_len_max` is set).
    pub scan_len: usize,
    /// When > `scan_len`, each Scan draws its Next count uniformly from
    /// `[scan_len, scan_len_max]` (YCSB-E's uniform scan lengths);
    /// 0 (the default) keeps the fixed length.
    pub scan_len_max: usize,
    /// Puts per Batch op.
    pub batch_size: usize,
    /// Stop after this many issued ops (None = run to the horizon).
    /// Open-loop clients also stop arrivals and drop any queued backlog
    /// once the cap is reached.
    pub max_ops: Option<u64>,
    /// Ratio coupling (closed-loop only; open-loop rates are absolute).
    pub pace: Option<Pace>,
    /// XOR'd into the spec seed for this client's generator stream.
    pub seed_tag: u64,
    /// Which tenant this client bills to (an index into
    /// `WorkloadSpec::qos.tenants`; ignored when the spec has no QoS).
    pub tenant: TenantId,
}

impl Default for ClientConfig {
    fn default() -> Self {
        Self {
            mix: OpMix::write_only(),
            mode: LoopMode::Closed { think: 0 },
            dist: KeyDist::Uniform,
            scan_len: 16,
            scan_len_max: 0,
            batch_size: 16,
            max_ops: None,
            pace: None,
            seed_tag: 0,
            tenant: 0,
        }
    }
}

impl ClientConfig {
    pub fn writer() -> Self {
        Self::default()
    }

    pub fn reader() -> Self {
        Self { mix: OpMix::read_only(), ..Self::default() }
    }

    pub fn with_mode(mut self, mode: LoopMode) -> Self {
        self.mode = mode;
        self
    }

    pub fn with_dist(mut self, dist: KeyDist) -> Self {
        self.dist = dist;
        self
    }

    pub fn with_seed_tag(mut self, tag: u64) -> Self {
        self.seed_tag = tag;
        self
    }

    pub fn with_tenant(mut self, tenant: TenantId) -> Self {
        self.tenant = tenant;
        self
    }

    /// Couple this client to `num/den` of another client's op count.
    pub fn with_pace_against(mut self, against: ActorId, num: u64, den: u64) -> Self {
        self.pace = Some(Pace { against, num, den });
        self
    }

    /// Fixed or uniform scan length: `max == len` (or 0) keeps it fixed.
    pub fn with_scan_len(mut self, len: usize, max: usize) -> Self {
        self.scan_len = len;
        self.scan_len_max = max;
        self
    }

    /// Draw this op's Next count (uniform in `[scan_len, scan_len_max]`
    /// when a spread is configured).
    pub fn draw_scan_len(&self, rng: &mut SimRng) -> usize {
        if self.scan_len_max > self.scan_len {
            let span = (self.scan_len_max - self.scan_len + 1) as u32;
            self.scan_len + rng.gen_range_u32(span) as usize
        } else {
            self.scan_len
        }
    }
}

/// A full multi-client workload description.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub name: String,
    pub clients: Vec<ClientConfig>,
    /// Arrival/issue horizon: no client starts new work at or after
    /// `start_at + duration` (open-loop queues still drain).
    pub duration: Nanos,
    pub start_at: Nanos,
    pub key_space: Key,
    /// Fixed size, or the rounded mean when `value_dist` is a spread
    /// (kept for report labels; the generators use `value_dist`).
    pub value_size: u32,
    /// Per-op value size distribution (`Fixed(value_size)` reproduces
    /// the pre-spread generator bit for bit).
    pub value_dist: ValueSizeDist,
    pub seed: u64,
    /// Global op budget across ALL clients: once this many ops have been
    /// issued, every client retires and open-loop backlogs are dropped.
    /// The crash-injection hook (`run --crash-at <ops>`) cuts the run
    /// here so the driver can power-loss the engine mid-workload.
    pub stop_after_ops: Option<u64>,
    /// Multi-tenant QoS: tenant table + admission/SLO/arbitration knobs.
    /// None = no QoS at all (the pre-PR6 scheduler, bit for bit).
    pub qos: Option<QosConfig>,
}

impl WorkloadSpec {
    pub fn from_bench(name: impl Into<String>, cfg: &BenchConfig) -> Self {
        Self {
            name: name.into(),
            clients: Vec::new(),
            duration: cfg.duration,
            start_at: 0,
            key_space: cfg.key_space,
            value_size: cfg.value_size,
            value_dist: ValueSizeDist::Fixed(cfg.value_size),
            seed: cfg.seed,
            stop_after_ops: None,
            qos: None,
        }
    }

    pub fn with_clients(mut self, clients: Vec<ClientConfig>) -> Self {
        self.clients = clients;
        self
    }

    /// Cut the run after `n` issued ops in total (crash injection).
    pub fn with_stop_after(mut self, n: u64) -> Self {
        self.stop_after_ops = Some(n);
        self
    }

    /// Swap in a value-size distribution; `value_size` becomes the
    /// rounded mean so throughput conversions and report labels stay
    /// meaningful.
    pub fn with_value_dist(mut self, dist: ValueSizeDist) -> Self {
        self.value_dist = dist;
        self.value_size = dist.mean().round().max(1.0) as u32;
        self
    }

    /// Attach a fully custom QoS config (tenants assigned per client).
    pub fn with_qos(mut self, qos: QosConfig) -> Self {
        self.qos = Some(qos);
        self
    }

    /// The `--tenants` CLI shape: round-robin the clients across `n`
    /// identical tenants (client `i` bills tenant `i % n`), each with a
    /// token rate of `rate_ops_s` ops/s (0 = unlimited; charged at
    /// `16 + value_size` bytes per op, a quarter second of burst) and an
    /// optional shared p99 SLO.
    pub fn with_tenants(
        mut self,
        n: usize,
        rate_ops_s: f64,
        slo_p99: Option<Nanos>,
    ) -> Self {
        let n = n.max(1);
        for (i, c) in self.clients.iter_mut().enumerate() {
            c.tenant = (i % n) as TenantId;
        }
        let bytes_per_op = 16 + self.value_dist.mean().round() as u64;
        let rate_bytes = (rate_ops_s.max(0.0) * bytes_per_op as f64) as u64;
        let burst = (rate_bytes / 4).max(bytes_per_op);
        let tenants = (0..n)
            .map(|t| {
                let mut spec = TenantSpec::new(format!("t{t}"));
                if rate_bytes > 0 {
                    spec = spec.with_rate(rate_bytes, burst);
                }
                if let Some(slo) = slo_p99 {
                    spec = spec.with_slo_p99(slo);
                }
                spec
            })
            .collect();
        self.qos = Some(QosConfig::new(tenants));
        self
    }
}

/// One issued operation, for determinism checks and debugging.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OpTrace {
    pub client: ActorId,
    pub kind: OpKind,
    pub key: Key,
    pub issue: Nanos,
    pub done: Nanos,
}

// ---------------------------------------------------------------------
// Runtime state
// ---------------------------------------------------------------------

struct Client {
    cfg: ClientConfig,
    gen: KeyGen,
    rng: SimRng,
    /// Ops issued so far (pace / max_ops accounting; a batch counts 1).
    issued: u64,
    /// Per-client op counter feeding `KeyGen::value_for`.
    op_seq: u64,
    /// When the client's previous op completes.
    free_at: Nanos,
    /// Open-loop: a Dispatch event is outstanding.
    busy: bool,
    /// Open-loop FIFO of arrival times awaiting service.
    fifo: std::collections::VecDeque<Nanos>,
    /// Closed-loop paced client waiting for its ratio budget.
    parked: bool,
    /// Op kind already drawn for an op the QoS bucket deferred: the RNG
    /// stream must not re-draw when the op is retried.
    pending_kind: Option<OpKind>,
    /// Value lengths drawn up front for the next write op (admission
    /// charges what will actually be written); consumed by `issue_one`
    /// and, like `pending_kind`, NOT re-drawn on a QoS retry.
    pending_lens: Vec<u32>,
}

impl Client {
    fn interarrival(&mut self) -> Nanos {
        let ns = match self.cfg.mode {
            LoopMode::OpenFixed { ops_per_sec } => {
                NS_PER_SEC as f64 / ops_per_sec.max(1e-9)
            }
            LoopMode::OpenPoisson { ops_per_sec } => {
                let mean = NS_PER_SEC as f64 / ops_per_sec.max(1e-9);
                -(1.0 - self.rng.next_f64()).ln() * mean
            }
            LoopMode::Closed { .. } => 0.0,
        };
        (ns as Nanos).max(1)
    }
}

struct RunStats {
    writes: OpSeries,
    wlat: Histogram,
    reads: OpSeries,
    rlat: Histogram,
    scans: OpSeries,
    scan_lat: Histogram,
    read_hits: u64,
    read_misses: u64,
    qdelay: Histogram,
    qdelay_sum: Vec<f64>,
    qdelay_cnt: Vec<u64>,
    /// Per-second series bins are capped here (pre-refactor behavior:
    /// completions land in the last in-horizon second).
    series_cap: Nanos,
}

impl RunStats {
    fn new(end_time: Nanos) -> Self {
        Self {
            writes: OpSeries::default(),
            wlat: Histogram::new(),
            reads: OpSeries::default(),
            rlat: Histogram::new(),
            scans: OpSeries::default(),
            scan_lat: Histogram::new(),
            read_hits: 0,
            read_misses: 0,
            qdelay: Histogram::new(),
            qdelay_sum: Vec::new(),
            qdelay_cnt: Vec::new(),
            series_cap: end_time.saturating_sub(1),
        }
    }

    /// Closed-loop completions clip to the last in-horizon second (the
    /// pre-refactor behavior: only the final op ever overshoots).
    /// Open-loop drain completions keep their true second, so the
    /// per-second series shows the real service shape, not a spike.
    fn series_at(&self, done: Nanos, cap: bool) -> Nanos {
        if cap {
            done.min(self.series_cap)
        } else {
            done
        }
    }

    fn write_op(&mut self, from: Nanos, done: Nanos, cap: bool) {
        self.wlat.record(done.saturating_sub(from));
        self.writes.record(self.series_at(done, cap));
    }

    fn batch_op(&mut self, from: Nanos, done: Nanos, ops: usize, cap: bool) {
        let per_op = done.saturating_sub(from) / ops.max(1) as u64;
        let at = self.series_at(done, cap);
        for _ in 0..ops {
            self.wlat.record(per_op.max(1));
            self.writes.record(at);
        }
    }

    fn read_op(&mut self, from: Nanos, done: Nanos, hit: Option<bool>, ops: usize, cap: bool) {
        self.rlat.record(done.saturating_sub(from));
        let at = self.series_at(done, cap);
        for _ in 0..ops {
            self.reads.record(at);
        }
        match hit {
            Some(true) => self.read_hits += 1,
            Some(false) => self.read_misses += 1,
            None => {}
        }
    }

    /// One whole Scan op (Seek + Nexts) — latency and per-op series,
    /// reported separately from point reads.
    fn scan_op(&mut self, from: Nanos, done: Nanos, cap: bool) {
        self.scan_lat.record(done.saturating_sub(from));
        self.scans.record(self.series_at(done, cap));
    }

    fn queue_wait(&mut self, arrived: Nanos, start: Nanos) {
        self.qdelay.record(start.saturating_sub(arrived));
        let sec = (arrived / NS_PER_SEC) as usize;
        if self.qdelay_sum.len() <= sec {
            self.qdelay_sum.resize(sec + 1, 0.0);
            self.qdelay_cnt.resize(sec + 1, 0);
        }
        self.qdelay_sum[sec] += start.saturating_sub(arrived) as f64;
        self.qdelay_cnt[sec] += 1;
    }
}

// ---------------------------------------------------------------------
// The scheduler
// ---------------------------------------------------------------------

/// Run a workload spec against an engine; see [`run_spec_traced`].
pub fn run_spec(sys: &mut dyn KvEngine, env: &mut SimEnv, spec: &WorkloadSpec) -> RunResult {
    run_spec_traced(sys, env, spec, false).0
}

/// Run a workload spec, optionally recording the full op trace (used by
/// the determinism conformance tests).
pub fn run_spec_traced(
    sys: &mut dyn KvEngine,
    env: &mut SimEnv,
    spec: &WorkloadSpec,
    record_trace: bool,
) -> (RunResult, Vec<OpTrace>) {
    assert!(!spec.clients.is_empty(), "workload spec has no clients");
    let end_time = spec.start_at.saturating_add(spec.duration);
    let mut q = EventQueue::new();
    let mut clients: Vec<Client> = spec
        .clients
        .iter()
        .enumerate()
        .map(|(i, cfg)| {
            // client 0 with no tag gets exactly the spec seed, so the
            // single-writer presets reproduce the pre-scheduler key
            // streams bit-for-bit
            let seed = spec.seed
                ^ cfg.seed_tag
                ^ (i as u64).wrapping_mul(0xA076_1D64_78BD_642F);
            Client {
                gen: KeyGen::with_value_dist(
                    seed,
                    spec.key_space,
                    cfg.dist,
                    spec.value_dist,
                ),
                rng: SimRng::new(seed ^ 0x6D17_ACED),
                cfg: cfg.clone(),
                issued: 0,
                op_seq: 0,
                free_at: spec.start_at,
                busy: false,
                fifo: std::collections::VecDeque::new(),
                parked: false,
                pending_kind: None,
                pending_lens: Vec::new(),
            }
        })
        .collect();
    for (i, c) in clients.iter().enumerate() {
        match c.cfg.mode {
            LoopMode::Closed { .. } => q.push(spec.start_at, i as ActorId, EventKind::Issue),
            _ => q.push(spec.start_at, i as ActorId, EventKind::Arrival),
        }
    }

    // QoS: one controller for the run, ticked by a reserved actor slot
    // one past the last client (ticks never enter the op trace)
    let mut qos: Option<QosController> = spec.qos.as_ref().map(|qc| {
        assert!(!qc.tenants.is_empty(), "QosConfig has no tenants");
        for c in &spec.clients {
            assert!(
                (c.tenant as usize) < qc.tenants.len(),
                "client tenant {} out of range ({} tenants)",
                c.tenant,
                qc.tenants.len()
            );
        }
        QosController::new(qc)
    });
    let tick_actor = clients.len() as ActorId;
    if let Some(ctl) = &qos {
        q.push(
            spec.start_at.saturating_add(ctl.tick_interval()),
            tick_actor,
            EventKind::QosTick,
        );
    }

    let mut stats = RunStats::new(end_time);
    let mut trace = Vec::new();
    let mut end = spec.start_at;
    let mut total_issued: u64 = 0;
    let budget_spent =
        |total: u64| spec.stop_after_ops.is_some_and(|m| total >= m);

    while let Some(ev) = q.pop() {
        let a = ev.actor as usize;
        match ev.kind {
            EventKind::Issue => {
                if ev.at >= end_time
                    || budget_spent(total_issued)
                    || clients[a].cfg.max_ops.is_some_and(|m| clients[a].issued >= m)
                {
                    continue; // client retires
                }
                if let Some(p) = clients[a].cfg.pace {
                    let other = clients[p.against as usize].issued;
                    if clients[a].issued * p.den >= other * p.num {
                        clients[a].parked = true; // ahead of ratio: wait
                        continue;
                    }
                }
                sync_latest_frontier(&mut clients, a);
                let kind = take_kind(&mut clients[a]);
                let cost = op_cost_bytes(kind, &mut clients[a], spec);
                if let Some(ctl) = qos.as_mut() {
                    let t = clients[a].cfg.tenant as usize;
                    if let Some(ready) = ctl.try_charge(t, ev.at, cost) {
                        // over budget: stash the drawn kind (the RNG
                        // stream must not re-draw) and retry at refill
                        clients[a].pending_kind = Some(kind);
                        q.push(ready, ev.actor, EventKind::Issue);
                        continue;
                    }
                    ctl.before_op(sys, env, t);
                }
                let done = issue_one(
                    sys, env, &mut clients[a], ev.actor, ev.at, ev.at, true, kind,
                    &mut stats, &mut trace, record_trace,
                );
                if let Some(ctl) = qos.as_mut() {
                    let t = clients[a].cfg.tenant as usize;
                    ctl.after_op(sys, t, cost, done.saturating_sub(ev.at));
                }
                clients[a].issued += 1;
                total_issued += 1;
                clients[a].free_at = done;
                end = end.max(done);
                let think = match clients[a].cfg.mode {
                    LoopMode::Closed { think } => think,
                    _ => 0,
                };
                q.push(done.saturating_add(think), ev.actor, EventKind::Issue);
                wake_paced(&mut clients, &mut q, ev.actor);
            }
            EventKind::Arrival => {
                if ev.at >= end_time
                    || budget_spent(total_issued)
                    || clients[a].cfg.max_ops.is_some_and(|m| clients[a].issued >= m)
                {
                    continue; // arrivals stop at the horizon
                }
                let ia = clients[a].interarrival();
                q.push(ev.at.saturating_add(ia), ev.actor, EventKind::Arrival);
                clients[a].fifo.push_back(ev.at);
                if !clients[a].busy {
                    clients[a].busy = true;
                    q.push(ev.at, ev.actor, EventKind::Dispatch);
                }
            }
            EventKind::Dispatch => {
                if budget_spent(total_issued)
                    || clients[a].cfg.max_ops.is_some_and(|m| clients[a].issued >= m)
                {
                    // op cap reached: abandon the queued backlog too
                    clients[a].fifo.clear();
                    clients[a].busy = false;
                    continue;
                }
                // SLO shedder: an over-target tenant drops its *stale*
                // backlog first — never an op the bucket already
                // admitted (stashed kind means mid-retry, not backlog)
                if clients[a].pending_kind.is_none() {
                    if let Some(ctl) = qos.as_mut() {
                        let t = clients[a].cfg.tenant as usize;
                        if let Some(slo) = ctl.shed_threshold(t) {
                            let horizon = ev.at.max(clients[a].free_at);
                            while let Some(&arr) = clients[a].fifo.front() {
                                if horizon.saturating_sub(arr) <= slo {
                                    break;
                                }
                                clients[a].fifo.pop_front();
                                ctl.note_shed(t);
                            }
                        }
                    }
                }
                let Some(arrived) = clients[a].fifo.pop_front() else {
                    clients[a].busy = false;
                    continue;
                };
                // the op was queued at `arrived`; service starts once
                // the client's previous op is done
                let start = ev.at.max(clients[a].free_at);
                let kind = take_kind(&mut clients[a]);
                let cost = op_cost_bytes(kind, &mut clients[a], spec);
                if let Some(ctl) = qos.as_mut() {
                    let t = clients[a].cfg.tenant as usize;
                    if let Some(ready) = ctl.try_charge(t, start, cost) {
                        // over budget: the head op waits in place; the
                        // hold shows up as queueing delay once served
                        clients[a].pending_kind = Some(kind);
                        clients[a].fifo.push_front(arrived);
                        q.push(ready, ev.actor, EventKind::Dispatch);
                        continue;
                    }
                }
                stats.queue_wait(arrived, start);
                if let Some(ctl) = qos.as_mut() {
                    let t = clients[a].cfg.tenant as usize;
                    ctl.record_queue_wait(t, start.saturating_sub(arrived));
                    ctl.before_op(sys, env, t);
                }
                sync_latest_frontier(&mut clients, a);
                let done = issue_one(
                    sys, env, &mut clients[a], ev.actor, start, arrived, false, kind,
                    &mut stats, &mut trace, record_trace,
                );
                if let Some(ctl) = qos.as_mut() {
                    let t = clients[a].cfg.tenant as usize;
                    ctl.after_op(sys, t, cost, done.saturating_sub(arrived));
                }
                clients[a].issued += 1;
                total_issued += 1;
                clients[a].free_at = done;
                end = end.max(done);
                if clients[a].fifo.is_empty() {
                    clients[a].busy = false;
                } else {
                    q.push(done, ev.actor, EventKind::Dispatch);
                }
                wake_paced(&mut clients, &mut q, ev.actor);
            }
            EventKind::QosTick => {
                if ev.at >= end_time {
                    continue; // controller retires with the arrivals
                }
                if let Some(ctl) = qos.as_mut() {
                    ctl.on_tick(ev.at, sys, env);
                    q.push(
                        ev.at.saturating_add(ctl.tick_interval()),
                        ev.actor,
                        EventKind::QosTick,
                    );
                }
            }
            // replication events live on the ReplicatedDb's private
            // queue (pumped around each engine call); they never reach
            // the workload scheduler
            EventKind::ReplShip | EventKind::ReplDeliver => {}
        }
    }

    (assemble(sys, env, spec, stats, qos, end), trace)
}

/// Latest-biased clients share one insert frontier (YCSB keeps a global
/// counter): before a Latest client issues, it adopts the newest write
/// high-water mark across all clients, so a read-only client follows
/// the writers' appends instead of reading key 0 forever.
fn sync_latest_frontier(clients: &mut [Client], a: usize) {
    if clients[a].cfg.dist != KeyDist::Latest {
        return;
    }
    let hw = clients.iter().map(|c| c.gen.inserted()).max().unwrap_or(0);
    clients[a].gen.observe_inserted(hw);
}

/// Re-arm closed-loop clients parked on a pace ratio against `changed`.
#[allow(clippy::needless_range_loop)] // indexes two clients at once
fn wake_paced(clients: &mut [Client], q: &mut EventQueue, changed: ActorId) {
    for j in 0..clients.len() {
        if !clients[j].parked {
            continue;
        }
        let Some(p) = clients[j].cfg.pace else { continue };
        if p.against != changed {
            continue;
        }
        let other = clients[p.against as usize].issued;
        if clients[j].issued * p.den < other * p.num {
            clients[j].parked = false;
            // resume on the client's own timeline (it was idle, not
            // busy), preserving its configured think spacing
            let think = match clients[j].cfg.mode {
                LoopMode::Closed { think } => think,
                _ => 0,
            };
            let at = clients[j].free_at.saturating_add(think);
            q.push(at, j as ActorId, EventKind::Issue);
        }
    }
}

/// The op kind for the next issue: either the kind stashed when the QoS
/// bucket deferred this op (the RNG stream must not re-draw on retry),
/// or a fresh draw from the client's mix.
fn take_kind(c: &mut Client) -> OpKind {
    match c.pending_kind.take() {
        Some(k) => k,
        None => c.cfg.mix.pick(&mut c.rng),
    }
}

/// Admission cost of one op in simulated bytes, charged against the
/// tenant's token bucket *before* the op runs. Writes charge the key
/// plus the value bytes this op will *actually* write: the lengths are
/// drawn from the value-size distribution here and stashed on the
/// client so `issue_one` writes exactly what was charged (and a QoS
/// retry re-charges the same lengths instead of re-drawing). Reads
/// have no per-op length, so they charge the distribution mean per
/// entry; deletes write a bare tombstone.
fn op_cost_bytes(kind: OpKind, c: &mut Client, spec: &WorkloadSpec) -> u64 {
    let mean_entry = 16 + spec.value_dist.mean().round() as u64;
    match kind {
        OpKind::Put => {
            if c.pending_lens.is_empty() {
                let len = c.gen.draw_value_len();
                c.pending_lens.push(len);
            }
            16 + c.pending_lens[0] as u64
        }
        OpKind::Batch => {
            let n = c.cfg.batch_size.max(1);
            while c.pending_lens.len() < n {
                let len = c.gen.draw_value_len();
                c.pending_lens.push(len);
            }
            c.pending_lens.iter().map(|&l| 16 + l as u64).sum()
        }
        OpKind::Delete => 16,
        OpKind::Get => mean_entry,
        OpKind::Scan => mean_entry * c.cfg.scan_len.max(1) as u64,
    }
}

/// The value length for the next write entry: the stash filled at
/// admission time, or a fresh draw when no QoS controller pre-drew.
fn take_len(c: &mut Client) -> u32 {
    if c.pending_lens.is_empty() {
        c.gen.draw_value_len()
    } else {
        c.pending_lens.remove(0)
    }
}

/// Issue one operation for a client at `at`; latency is measured from
/// `lat_from` (arrival time in open loop, issue time in closed loop);
/// `cap_series` clips the per-second bin to the horizon (closed loop).
#[allow(clippy::too_many_arguments)]
fn issue_one(
    sys: &mut dyn KvEngine,
    env: &mut SimEnv,
    c: &mut Client,
    id: ActorId,
    at: Nanos,
    lat_from: Nanos,
    cap_series: bool,
    kind: OpKind,
    stats: &mut RunStats,
    trace: &mut Vec<OpTrace>,
    record: bool,
) -> Nanos {
    let (key, done) = match kind {
        OpKind::Put => {
            let key = c.gen.write_key();
            let len = take_len(c);
            let val = c.gen.value_with_len(key, c.op_seq, len);
            c.op_seq += 1;
            let r = sys.put(env, at, key, val);
            stats.write_op(lat_from, r.done, cap_series);
            (key, r.done)
        }
        OpKind::Delete => {
            let key = c.gen.write_key();
            c.op_seq += 1;
            let r = sys.delete(env, at, key);
            stats.write_op(lat_from, r.done, cap_series);
            (key, r.done)
        }
        OpKind::Get => {
            let key = c.gen.random_key();
            let (got, done) = sys.get(env, at, key);
            stats.read_op(lat_from, done, Some(got.is_some()), 1, cap_series);
            (key, done)
        }
        OpKind::Scan => {
            let start = c.gen.random_key();
            let len = c.cfg.draw_scan_len(&mut c.rng);
            // a real cursor: Seek + up to `len` Nexts, each movement
            // individually charged (per-Next latency and per-block /
            // per-page read amplification land where they occur)
            let mut it = sys.iter(env, at, IterOptions::default());
            let mut done = it.seek(env, at, start);
            let mut nexts = 0usize;
            while nexts < len && it.valid() {
                nexts += 1;
                done = it.next(env, done);
            }
            // counted the db_bench way: the Seek plus every Next
            stats.read_op(lat_from, done, None, nexts + 1, cap_series);
            stats.scan_op(lat_from, done, cap_series);
            (start, done)
        }
        OpKind::Batch => {
            let n = c.cfg.batch_size.max(1);
            let mut batch = WriteBatch::with_capacity(n);
            let mut first: Option<Key> = None;
            for _ in 0..n {
                let key = c.gen.write_key();
                let len = take_len(c);
                let val = c.gen.value_with_len(key, c.op_seq, len);
                c.op_seq += 1;
                if first.is_none() {
                    first = Some(key);
                }
                batch.put(key, val);
            }
            let r = sys.write_batch(env, at, &batch);
            stats.batch_op(lat_from, r.done, n, cap_series);
            (first.unwrap_or(0), r.done)
        }
    };
    if record {
        trace.push(OpTrace { client: id, kind, key, issue: at, done });
    }
    done
}

fn assemble(
    sys: &dyn KvEngine,
    env: &SimEnv,
    spec: &WorkloadSpec,
    stats: RunStats,
    qos: Option<QosController>,
    end: Nanos,
) -> RunResult {
    let end = end.max(spec.start_at + 1);
    let duration_s = (end - spec.start_at) as f64 / NS_PER_SEC as f64;
    let db = sys.main_db();
    // trait accessors, not `db` fields: a sharded engine aggregates
    // these across its children
    let db_stats = sys.db_stats();
    let stall = sys.stall_stats();
    let cpu_percent = env.cpu.host_cpu_percent(end, 8);
    let bytes_per_op = 16.0 + spec.value_dist.mean();
    let write_mbps =
        stats.writes.total as f64 * bytes_per_op / duration_s / (1024.0 * 1024.0);
    let read_mbps =
        stats.reads.total as f64 * bytes_per_op / duration_s / (1024.0 * 1024.0);
    let efficiency = if cpu_percent > 0.0 {
        (write_mbps + read_mbps) / cpu_percent
    } else {
        0.0
    };
    let total_secs = (end as f64 / NS_PER_SEC as f64).ceil() as usize;
    let stall_seconds: Vec<usize> = (0..total_secs)
        .filter(|&s| stall.second_in_stall(s))
        .collect();
    let (redirected, rollbacks) = (sys.redirected_writes(), sys.rollbacks());
    let queue_delay_series_us: Vec<f64> = stats
        .qdelay_sum
        .iter()
        .zip(&stats.qdelay_cnt)
        .map(|(&s, &n)| if n == 0 { 0.0 } else { s / n as f64 / 1e3 })
        .collect();
    RunResult {
        system: String::new(), // caller labels
        workload: spec.name.clone(),
        threads: db.compaction_threads(),
        duration_s,
        write_lat: HistogramSummary::from(&stats.wlat),
        read_lat: HistogramSummary::from(&stats.rlat),
        writes: stats.writes,
        reads: stats.reads,
        write_mbps,
        read_mbps,
        cpu_percent,
        efficiency,
        stop_events: stall.stop_events,
        slowdown_events: stall.slowdown_events,
        stopped_s: stall.stopped_ns_total as f64 / NS_PER_SEC as f64,
        write_amplification: db_stats.write_amplification(),
        pcie_mbps: env.device.pcie.stats.combined_mbps(),
        stall_seconds,
        redirected_writes: redirected,
        rollbacks,
        read_hits: stats.read_hits,
        read_misses: stats.read_misses,
        queue_delay: HistogramSummary::from(&stats.qdelay),
        queue_delay_series_us,
        scans: stats.scans,
        scan_lat: HistogramSummary::from(&stats.scan_lat),
        scan_amp: sys.scan_amp(),
        tenants: qos.map(|q| q.into_results(duration_s)).unwrap_or_default(),
        replication: sys.replicated().map(|r| r.results()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::SystemKind;
    use crate::engine::EngineBuilder;
    use crate::lsm::LsmOptions;
    use crate::ssd::SsdConfig;

    fn spec(clients: Vec<ClientConfig>, secs: u64) -> WorkloadSpec {
        WorkloadSpec {
            name: "test".into(),
            clients,
            duration: secs * NS_PER_SEC,
            start_at: 0,
            key_space: 50_000,
            value_size: 4096,
            value_dist: ValueSizeDist::Fixed(4096),
            seed: 42,
            stop_after_ops: None,
            qos: None,
        }
    }

    fn build() -> (Box<dyn KvEngine>, SimEnv) {
        (
            EngineBuilder::new(SystemKind::RocksDb { slowdown: true })
                .opts(LsmOptions::small_for_test())
                .build(),
            SimEnv::new(3, SsdConfig::default()),
        )
    }

    #[test]
    fn mix_pick_honors_weights() {
        let mix = OpMix::put_get(9, 1);
        let mut rng = SimRng::new(1);
        let mut gets = 0;
        for _ in 0..10_000 {
            if mix.pick(&mut rng) == OpKind::Get {
                gets += 1;
            }
        }
        assert!((700..1300).contains(&gets), "gets {gets}");
    }

    #[test]
    fn closed_loop_single_writer_runs() {
        let (mut s, mut env) = build();
        let r = run_spec(&mut *s, &mut env, &spec(vec![ClientConfig::writer()], 1));
        assert!(r.writes.total > 100);
        assert_eq!(r.queue_delay.count, 0, "closed loop has no queue");
    }

    #[test]
    fn open_loop_fixed_rate_tracks_rate() {
        let (mut s, mut env) = build();
        // a deliberately low rate the engine trivially sustains
        let c = ClientConfig::writer()
            .with_mode(LoopMode::OpenFixed { ops_per_sec: 500.0 });
        let r = run_spec(&mut *s, &mut env, &spec(vec![c], 2));
        // ~1000 arrivals in 2 s, all served with negligible queueing
        assert!((900..1100).contains(&(r.writes.total as i64)), "{}", r.writes.total);
        assert!(r.queue_delay.count > 0);
        // under-load, the typical op sees (almost) no queue; transient
        // stall windows may still inflate the tail, so check the median
        assert!(
            r.queue_delay.p50_us < 1000.0,
            "under-load queueing should be tiny: p50 {}",
            r.queue_delay.p50_us
        );
    }

    #[test]
    fn open_loop_poisson_rate_roughly_tracks() {
        let (mut s, mut env) = build();
        let c = ClientConfig::writer()
            .with_mode(LoopMode::OpenPoisson { ops_per_sec: 500.0 });
        let r = run_spec(&mut *s, &mut env, &spec(vec![c], 2));
        assert!((700..1300).contains(&(r.writes.total as i64)), "{}", r.writes.total);
    }

    #[test]
    fn multi_client_interleaves_and_totals_add_up() {
        let (mut s, mut env) = build();
        let clients = vec![
            ClientConfig::writer(),
            ClientConfig::writer().with_seed_tag(7),
            ClientConfig::reader()
                .with_mode(LoopMode::OpenFixed { ops_per_sec: 200.0 })
                .with_seed_tag(9),
        ];
        let (r, trace) =
            run_spec_traced(&mut *s, &mut env, &spec(clients, 1), true);
        assert!(r.writes.total > 200);
        assert!(r.reads.total > 100);
        assert_eq!(r.read_hits + r.read_misses, r.reads.total);
        let ids: std::collections::BTreeSet<ActorId> =
            trace.iter().map(|t| t.client).collect();
        assert_eq!(ids.len(), 3, "all clients issued ops");
        assert_eq!(trace.len() as u64, r.writes.total + r.reads.total);
    }

    #[test]
    fn paced_reader_tracks_ratio() {
        let (mut s, mut env) = build();
        let clients = vec![
            ClientConfig::writer(),
            ClientConfig::reader().with_seed_tag(0xDEAD_BEEF).with_pace_against(0, 1, 9),
        ];
        let r = run_spec(&mut *s, &mut env, &spec(clients, 2));
        assert!(r.reads.total > 0);
        // small_for_test can saturate the reader on cold reads, so this
        // only checks the coupling holds roughly; the strict 1% bound is
        // asserted on paper-default options in tests/scheduler.rs
        let ratio = r.writes.total as f64 / r.reads.total as f64;
        assert!((7.0..12.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn latest_read_only_client_follows_writer_frontier() {
        let (mut s, mut env) = build();
        let clients = vec![
            ClientConfig::writer().with_dist(KeyDist::Latest),
            ClientConfig::reader().with_dist(KeyDist::Latest).with_seed_tag(3),
        ];
        let (r, trace) = run_spec_traced(&mut *s, &mut env, &spec(clients, 1), true);
        assert!(r.reads.total > 100);
        // the reader never writes; without frontier sharing it would
        // read key 0 forever
        let distinct: std::collections::BTreeSet<Key> = trace
            .iter()
            .filter(|t| t.kind == OpKind::Get)
            .map(|t| t.key)
            .collect();
        assert!(distinct.len() > 10, "latest reads stuck at the origin");
        assert!(
            r.read_hit_rate() > 0.5,
            "latest reads should find the writer's appends: {:.2}",
            r.read_hit_rate()
        );
    }

    #[test]
    fn stop_after_ops_cuts_the_run_globally() {
        let (mut s, mut env) = build();
        let clients = vec![
            ClientConfig::writer(),
            ClientConfig::writer().with_seed_tag(5),
            ClientConfig::writer()
                .with_mode(LoopMode::OpenFixed { ops_per_sec: 5_000.0 })
                .with_seed_tag(9),
        ];
        let r = run_spec(
            &mut *s,
            &mut env,
            &spec(clients, 5).with_stop_after(250),
        );
        assert_eq!(r.writes.total, 250, "global budget must cut exactly");
    }

    #[test]
    fn think_time_throttles_a_closed_client() {
        let (mut s, mut env) = build();
        let fast = run_spec(&mut *s, &mut env, &spec(vec![ClientConfig::writer()], 1));
        let (mut s2, mut env2) = build();
        let slow_cfg = ClientConfig::writer()
            .with_mode(LoopMode::Closed { think: 10 * crate::sim::MILLIS });
        let slow = run_spec(&mut *s2, &mut env2, &spec(vec![slow_cfg], 1));
        assert!(slow.writes.total < fast.writes.total / 2);
        // ~100 ops/s with 10 ms think time
        assert!((50..150).contains(&(slow.writes.total as i64)), "{}", slow.writes.total);
    }

    #[test]
    fn tenant_breakdown_accounts_every_op() {
        let (mut s, mut env) = build();
        let clients = vec![
            ClientConfig::writer(),
            ClientConfig::writer().with_seed_tag(7),
        ];
        // two tenants, no rate limit, no SLO: pure accounting
        let sp = spec(clients, 1).with_tenants(2, 0.0, None);
        let r = run_spec(&mut *s, &mut env, &sp);
        assert_eq!(r.tenants.len(), 2);
        let per_tenant: u64 = r.tenants.iter().map(|t| t.ops).sum();
        assert_eq!(per_tenant, r.writes.total, "tenant ops must sum to run ops");
        for t in &r.tenants {
            assert!(t.ops > 0, "{} issued nothing", t.name);
            assert_eq!(t.throttled, 0, "unlimited tenant throttled");
            assert_eq!(t.shed, 0, "unlimited tenant shed");
        }
    }

    #[test]
    fn tenant_bucket_throttles_closed_loop_rate() {
        let (mut s, mut env) = build();
        // one writer metered to ~200 ops/s; a closed loop would
        // otherwise push thousands
        let sp = spec(vec![ClientConfig::writer()], 2).with_tenants(1, 200.0, None);
        let r = run_spec(&mut *s, &mut env, &sp);
        assert!(
            (300..550).contains(&(r.writes.total as i64)),
            "metered writer did {} ops in 2 s (want ~400 + burst)",
            r.writes.total
        );
        assert!(r.tenants[0].throttled > 0, "bucket never engaged");
    }

    #[test]
    fn value_size_spread_run_completes() {
        let (mut s, mut env) = build();
        let sp = spec(vec![ClientConfig::writer()], 1)
            .with_value_dist(ValueSizeDist::LogNormal { mu: 8.0, sigma: 1.0 });
        let r = run_spec(&mut *s, &mut env, &sp);
        assert!(r.writes.total > 100, "{}", r.writes.total);
    }

    #[test]
    fn qos_charges_tombstones_at_their_actual_size() {
        let (mut s, mut env) = build();
        let mut c = ClientConfig::writer();
        c.mix = OpMix { put: 0, get: 0, delete: 1, scan: 0, batch: 0 };
        // the bucket is sized for 200 put-equivalents/s (16+4096 B per
        // op); a 16 B tombstone stream fits ~257x that, so the closed
        // loop must never park on the bucket
        let sp = spec(vec![c], 1).with_tenants(1, 200.0, None);
        let r = run_spec(&mut *s, &mut env, &sp);
        assert_eq!(r.tenants[0].throttled, 0, "tombstones over-charged");
        assert!(r.writes.total > 100, "{}", r.writes.total);
    }

    #[test]
    fn monitor_only_matches_unmetered_run() {
        let clients = || {
            vec![
                ClientConfig::writer(),
                ClientConfig::reader()
                    .with_mode(LoopMode::OpenFixed { ops_per_sec: 300.0 })
                    .with_seed_tag(9),
            ]
        };
        let (mut s1, mut env1) = build();
        let (base, t1) =
            run_spec_traced(&mut *s1, &mut env1, &spec(clients(), 1), true);
        let (mut s2, mut env2) = build();
        let mut sp =
            spec(clients(), 1).with_tenants(2, 500.0, Some(crate::sim::MILLIS));
        sp.qos = sp.qos.map(|q| q.monitor_only());
        let (mon, t2) = run_spec_traced(&mut *s2, &mut env2, &sp, true);
        assert_eq!(t1, t2, "monitor-only QoS must not perturb the trace");
        assert_eq!(base.writes.total, mon.writes.total);
        assert_eq!(mon.tenants.len(), 2, "monitoring still reports tenants");
    }
}
