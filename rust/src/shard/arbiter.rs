//! Device write-buffer capacity arbiter for KVACCEL shards.
//!
//! All KVACCEL shards redirect into the *same* physical KV region (the
//! paper's Fig 8 disaggregation point) — one shard's redirected burst
//! eats the capacity every other shard would need for its own stall.
//! The arbiter partitions the redirection budget (the controller's
//! `max_kv_occupancy`, 0.9 of the region by default) into per-shard
//! grants, and rebalances them when one shard's stall detector fires
//! while others are idle, so redirection capacity follows the hot shard.
//!
//! Enforcement is the existing controller backpressure: shard `i`'s
//! controller refuses redirection once the region occupancy reaches
//! `grant[i]`, so the grant vector is pushed into each shard's
//! `ControllerConfig` whenever it changes. With one shard the single
//! grant equals the default cap and the arbiter is inert — the unsharded
//! behavior, bit for bit.
//!
//! Rebalancing is **revoke-before-grant** two-phase: a transfer first
//! deducts the donor's grant (refusals start immediately), and only
//! credits the receiver once the revocation has propagated (one detector
//! interval later). The region can therefore never be over-granted, and
//! a crash inside the window leaves a durable pending-transfer record
//! that recovery rolls *forward* — the recovered grant table always sums
//! back to the full budget.

use crate::sim::{Nanos, MILLIS};

/// One in-flight revoke-before-grant capacity move.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PendingTransfer {
    pub from: usize,
    pub to: usize,
    /// Occupancy fraction being moved.
    pub amount: f64,
    /// When the revocation has propagated and the credit applies.
    pub effective_at: Nanos,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct ArbiterStats {
    /// Completed grant transfers.
    pub rebalances: u64,
    /// Transfers rolled forward by crash recovery.
    pub recovered_transfers: u64,
    /// Arbitration passes that looked at the shard signals.
    pub ticks: u64,
}

#[derive(Clone, Debug)]
pub struct ArbiterConfig {
    /// Total redirection budget split across shards (the unsharded
    /// controller default: 0.9 of the KV region).
    pub total_occupancy: f64,
    /// No shard's grant falls below this floor (a cold shard can always
    /// absorb the first moments of a burst while the arbiter reacts).
    pub min_grant: f64,
    /// Fraction of the total budget moved per transfer.
    pub step: f64,
    /// Arbitration cadence (the detector's 0.1 s).
    pub interval: Nanos,
}

impl Default for ArbiterConfig {
    fn default() -> Self {
        Self {
            total_occupancy: 0.9,
            min_grant: 0.05,
            step: 0.1,
            interval: 100 * MILLIS,
        }
    }
}

/// What the arbiter sees of one KVACCEL shard each pass.
#[derive(Clone, Copy, Debug)]
pub struct ShardSignal {
    /// Detector verdict (stall imminent on this shard's Main-LSM).
    pub stall_imminent: bool,
    /// This shard's namespace share of the KV region (0..1).
    pub occupancy: f64,
}

#[derive(Clone, Debug)]
pub struct DeviceArbiter {
    cfg: ArbiterConfig,
    /// Per-KVACCEL-shard occupancy caps; always sums to
    /// `total_occupancy` minus any revoked-but-not-yet-granted amount.
    grants: Vec<f64>,
    pending: Option<PendingTransfer>,
    last_tick: Nanos,
    ticked_once: bool,
    pub stats: ArbiterStats,
}

impl DeviceArbiter {
    /// Equal initial partition of the budget across `n` KVACCEL shards.
    pub fn new(n: usize, cfg: ArbiterConfig) -> Self {
        let n = n.max(1);
        let grants = vec![cfg.total_occupancy / n as f64; n];
        Self {
            cfg,
            grants,
            pending: None,
            last_tick: 0,
            ticked_once: false,
            stats: ArbiterStats::default(),
        }
    }

    /// Rebuild from a recovered shard manifest. A pending transfer that
    /// was mid-flight at the crash is rolled forward (the revocation was
    /// already durable; granting completes it), so the table comes back
    /// consistent: every grant within `[min_grant, total]` and the sum
    /// restored to the full budget.
    pub fn recover(
        grants: Vec<f64>,
        pending: Option<PendingTransfer>,
        cfg: ArbiterConfig,
    ) -> Self {
        let n = grants.len().max(1);
        let mut a = Self {
            cfg,
            grants,
            pending: None,
            last_tick: 0,
            ticked_once: false,
            stats: ArbiterStats::default(),
        };
        if let Some(p) = pending {
            if p.to < a.grants.len() {
                a.grants[p.to] += p.amount;
                a.stats.recovered_transfers += 1;
            }
        }
        // defensive normalization: a torn manifest must never leave the
        // region over- or under-granted
        let sum: f64 = a.grants.iter().sum();
        if sum > 0.0 && (sum - a.cfg.total_occupancy).abs() > 1e-9 {
            let scale = a.cfg.total_occupancy / sum;
            for g in &mut a.grants {
                *g *= scale;
            }
        } else if sum == 0.0 {
            a.grants = vec![a.cfg.total_occupancy / n as f64; n];
        }
        // scaling can push a small grant under the floor; lift those back
        // up and take the deficit from the others' headroom, so the table
        // keeps both invariants (sum == budget, every grant >= floor)
        let floor = a
            .cfg
            .min_grant
            .min(a.cfg.total_occupancy / a.grants.len() as f64);
        let mut deficit = 0.0;
        for g in &mut a.grants {
            if *g < floor {
                deficit += floor - *g;
                *g = floor;
            }
        }
        if deficit > 0.0 {
            let headroom: f64 =
                a.grants.iter().map(|g| (g - floor).max(0.0)).sum();
            if headroom > 0.0 {
                for g in &mut a.grants {
                    let h = (*g - floor).max(0.0);
                    *g -= deficit * h / headroom;
                }
            }
        }
        a
    }

    pub fn config(&self) -> &ArbiterConfig {
        &self.cfg
    }

    pub fn grants(&self) -> &[f64] {
        &self.grants
    }

    pub fn pending(&self) -> Option<PendingTransfer> {
        self.pending
    }

    /// Grant capacity still unassigned because a transfer is mid-flight.
    pub fn in_flight_amount(&self) -> f64 {
        self.pending.map_or(0.0, |p| p.amount)
    }

    /// Would a pass at `at` do any work — a matured transfer to settle,
    /// or the cadence elapsed? Lets the caller skip collecting per-shard
    /// signals on the overwhelming majority of operations.
    pub fn due(&self, at: Nanos) -> bool {
        self.pending.is_some_and(|p| at >= p.effective_at)
            || !self.ticked_once
            || at >= self.last_tick + self.cfg.interval
    }

    /// Begin a revoke-before-grant transfer: deduct the donor now, credit
    /// the receiver at `effective_at`. Public as the crash-injection hook
    /// for the conformance tests (a crash between revoke and grant must
    /// recover to a consistent table).
    pub fn begin_transfer(&mut self, at: Nanos, from: usize, to: usize, amount: f64) -> bool {
        if self.pending.is_some() || from == to || amount <= 0.0 {
            return false;
        }
        let floor = self.cfg.min_grant;
        let amount = amount.min((self.grants[from] - floor).max(0.0));
        if amount <= 0.0 {
            return false;
        }
        self.grants[from] -= amount;
        self.pending = Some(PendingTransfer {
            from,
            to,
            amount,
            effective_at: at + self.cfg.interval,
        });
        true
    }

    /// Apply a matured pending transfer. Returns true if the grant table
    /// changed.
    fn settle(&mut self, at: Nanos) -> bool {
        let Some(p) = self.pending else { return false };
        if at < p.effective_at {
            return false;
        }
        self.grants[p.to] += p.amount;
        self.pending = None;
        self.stats.rebalances += 1;
        true
    }

    /// One arbitration pass at `at` over the per-shard signals (indexed
    /// like the grant table). Returns true when the grant table changed
    /// and the new caps must be pushed to the shard controllers.
    pub fn maybe_rebalance(&mut self, at: Nanos, signals: &[ShardSignal]) -> bool {
        let mut changed = self.settle(at);
        if self.grants.len() < 2 || signals.len() != self.grants.len() {
            return changed;
        }
        if self.ticked_once && at < self.last_tick + self.cfg.interval {
            return changed;
        }
        self.last_tick = at;
        self.ticked_once = true;
        self.stats.ticks += 1;
        if self.pending.is_some() {
            return changed; // one transfer in flight at a time
        }
        // hottest claimant: stalling and near its cap (redirection is
        // about to be refused)
        let claimant = signals
            .iter()
            .enumerate()
            .filter(|(i, s)| s.stall_imminent && s.occupancy >= 0.5 * self.grants[*i])
            .max_by(|a, b| a.1.occupancy.total_cmp(&b.1.occupancy))
            .map(|(i, _)| i);
        let Some(to) = claimant else { return changed };
        // calmest donor: not stalling, with the most unused grant beyond
        // the floor and its own residency
        let donor = signals
            .iter()
            .enumerate()
            .filter(|(i, s)| {
                *i != to
                    && !s.stall_imminent
                    && self.grants[*i] - self.cfg.min_grant > 1e-9
            })
            .max_by(|a, b| {
                let ha = self.grants[a.0] - a.1.occupancy;
                let hb = self.grants[b.0] - b.1.occupancy;
                ha.total_cmp(&hb)
            })
            .map(|(i, _)| i);
        let Some(from) = donor else { return changed };
        let step = self.cfg.step * self.cfg.total_occupancy;
        // never revoke below what the donor already occupies (its
        // resident data keeps its claim until a rollback drains it)
        let headroom = (self.grants[from]
            - self.cfg.min_grant.max(signals[from].occupancy))
        .max(0.0);
        let amount = step.min(headroom);
        if amount > 1e-9 && self.begin_transfer(at, from, to, amount) {
            changed = true; // donor cap dropped immediately
        }
        changed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(stall: bool, occ: f64) -> ShardSignal {
        ShardSignal { stall_imminent: stall, occupancy: occ }
    }

    #[test]
    fn equal_initial_partition() {
        let a = DeviceArbiter::new(4, ArbiterConfig::default());
        for &g in a.grants() {
            assert!((g - 0.225).abs() < 1e-12);
        }
        let one = DeviceArbiter::new(1, ArbiterConfig::default());
        assert!((one.grants()[0] - 0.9).abs() < 1e-12, "N=1 keeps the default cap");
    }

    #[test]
    fn grant_follows_the_hot_shard() {
        let mut a = DeviceArbiter::new(2, ArbiterConfig::default());
        let hot = sig(true, 0.40);
        let cold = sig(false, 0.0);
        // revoke at t0, credit one interval later
        assert!(a.maybe_rebalance(0, &[hot, cold]));
        assert!(a.grants()[1] < 0.45, "donor revoked immediately");
        assert!(a.grants()[0] < 0.46, "credit not yet applied");
        assert!(a.pending().is_some());
        let t1 = a.cfg.interval;
        assert!(a.maybe_rebalance(t1, &[hot, cold]));
        assert!(a.grants()[0] > 0.45, "hot shard gained capacity");
        let sum: f64 = a.grants().iter().sum();
        assert!((sum - 0.9).abs() < 1e-9, "budget conserved: {sum}");
        assert_eq!(a.stats.rebalances, 1);
    }

    #[test]
    fn donor_never_falls_below_floor_or_residency() {
        let cfg = ArbiterConfig::default();
        let mut a = DeviceArbiter::new(2, cfg.clone());
        // donor already holds 0.42 of the region: nothing to give beyond
        // its own residency
        let hot = sig(true, 0.4);
        let full_cold = sig(false, 0.449);
        for t in 0..20u64 {
            a.maybe_rebalance(t * cfg.interval, &[hot, full_cold]);
        }
        assert!(
            a.grants()[1] >= 0.449 - 1e-9,
            "donor revoked below its resident data: {}",
            a.grants()[1]
        );
    }

    #[test]
    fn no_rebalance_without_a_calm_donor() {
        let mut a = DeviceArbiter::new(2, ArbiterConfig::default());
        let both_hot = [sig(true, 0.3), sig(true, 0.3)];
        assert!(!a.maybe_rebalance(0, &both_hot));
        assert_eq!(a.stats.rebalances, 0);
    }

    #[test]
    fn crash_mid_transfer_recovers_consistently() {
        let mut a = DeviceArbiter::new(2, ArbiterConfig::default());
        assert!(a.begin_transfer(0, 1, 0, 0.09));
        // crash here: grants sum to 0.81, pending carries the 0.09
        let grants = a.grants().to_vec();
        let pending = a.pending();
        let sum_torn: f64 = grants.iter().sum();
        assert!((sum_torn - 0.81).abs() < 1e-9);
        let r = DeviceArbiter::recover(grants, pending, ArbiterConfig::default());
        let sum: f64 = r.grants().iter().sum();
        assert!((sum - 0.9).abs() < 1e-9, "recovered sum {sum}");
        assert!(r.pending().is_none());
        assert_eq!(r.stats.recovered_transfers, 1);
        assert!((r.grants()[0] - 0.54).abs() < 1e-9, "transfer rolled forward");
    }

    #[test]
    fn recover_normalizes_a_torn_table() {
        // a manifest written mid-rebalance by a buggy layer: over-granted
        let r = DeviceArbiter::recover(vec![0.6, 0.6], None, ArbiterConfig::default());
        let sum: f64 = r.grants().iter().sum();
        assert!((sum - 0.9).abs() < 1e-9);
    }

    #[test]
    fn recover_normalization_respects_the_floor() {
        // scaling 0.05 + 0.91 down to the 0.9 budget would push the
        // small grant under the 0.05 floor; recovery must lift it back
        // and take the deficit from the big grant
        let r = DeviceArbiter::recover(vec![0.05, 0.91], None, ArbiterConfig::default());
        let sum: f64 = r.grants().iter().sum();
        assert!((sum - 0.9).abs() < 1e-9, "sum {sum}");
        for &g in r.grants() {
            assert!(g >= 0.05 - 1e-9, "grant {g} below floor");
        }
    }
}
