//! Sharded engine layer: a range- or hash-partitioned store of N child
//! engines (any [`SystemKind`], including KVACCEL) behind the one
//! [`KvEngine`] interface, sharing a single dual-interface SSD.
//!
//! This is the production topology the survey literature assumes —
//! many column-family/instance-level LSMs serving a high client count —
//! and the regime where the paper's device write buffer becomes a
//! *shared, contended* resource: every KVACCEL shard redirects into the
//! same KV region, so capacity is partitioned by the
//! [`arbiter::DeviceArbiter`] and follows whichever shard is stalling.
//!
//! - [`router::Router`] resolves every key to exactly one shard
//!   (boundary table for range, seeded hash for hash policy).
//! - Cross-shard [`WriteBatch`]es split into per-shard sub-batches, each
//!   applied through its shard's single admission gate.
//! - Cross-shard snapshots pin every shard at one virtual instant (the
//!   coherent sequence horizon) and cross-shard cursors k-way-merge the
//!   per-shard iterators, lazily touching shards so an idle shard whose
//!   cursor never yields charges no read amplification.
//! - The durable lifecycle runs per shard (one WAL stream + manifest per
//!   shard) under a top-level shard manifest (ranges → child images,
//!   plus the arbiter grant table), so close/crash/recover round-trips
//!   and a crash mid-rebalance recovers to a consistent grant table.

pub mod arbiter;
pub mod router;

use std::sync::Arc;

use anyhow::Result;

use crate::baselines::SystemKind;
use crate::engine::{
    BatchResult, DbIterator, DurableImage, EngineBuilder, EngineHealth,
    EngineStats, IterOptions, KvEngine, ScanAmp, ScanCounters, Snapshot,
    WriteBatch,
};
use crate::env::SimEnv;
use crate::lsm::entry::{Entry, Key, ValueDesc, MAX_USER_KEY};
use crate::lsm::{
    DbStats, LsmDb, LsmOptions, Manifest, PutResult, StallStats, WriteCondition,
};
use crate::runtime::{BloomBuilder, MergeEngine};
use crate::sim::{Nanos, NS_PER_SEC};

pub use arbiter::{
    ArbiterConfig, ArbiterStats, DeviceArbiter, PendingTransfer, ShardSignal,
};
pub use router::{Router, ShardPolicy, ShardSpec};

// ---------------------------------------------------------------------
// Durable shard image
// ---------------------------------------------------------------------

/// The sharded store's durable state: the top-level shard manifest
/// (partitioning + arbiter grant table) plus one full child image per
/// shard. Carried inside [`DurableImage::shard`].
pub struct ShardImage {
    pub policy: ShardPolicy,
    /// Range boundary table (first key per shard; zeros for hash).
    pub boundaries: Vec<Key>,
    pub hash_seed: u64,
    pub child_kind: SystemKind,
    /// Per-shard images in shard order (each with its own manifest and
    /// WAL stream — the per-shard directories).
    pub children: Vec<DurableImage>,
    /// Arbiter grant table as last durably recorded.
    pub grants: Vec<f64>,
    /// A revoke-before-grant transfer that was mid-flight at the cut;
    /// recovery rolls it forward.
    pub pending: Option<PendingTransfer>,
}

/// Estimated on-flash size of the top-level shard manifest record.
fn shard_manifest_bytes(n: usize) -> u64 {
    64 + 16 * n as u64
}

// ---------------------------------------------------------------------
// Per-shard reporting
// ---------------------------------------------------------------------

/// One row of the per-shard breakdown (`run` report, experiments).
#[derive(Clone, Debug)]
pub struct ShardReport {
    pub shard: usize,
    /// Owned key range (range policy) or hash slot label.
    pub label: String,
    pub puts: u64,
    pub gets: u64,
    pub redirected: u64,
    pub rollbacks: u64,
    pub stop_events: u64,
    pub stopped_s: f64,
    pub slowdown_events: u64,
    pub dev_resident_keys: usize,
    /// Arbiter occupancy grant (None for non-KVACCEL shards).
    pub grant: Option<f64>,
    /// This shard's namespace share of the KV region.
    pub dev_occupancy: f64,
}

// ---------------------------------------------------------------------
// The sharded engine
// ---------------------------------------------------------------------

pub struct ShardedDb {
    shards: Vec<Box<dyn KvEngine>>,
    router: Router,
    arbiter: DeviceArbiter,
    kind: SystemKind,
    /// Sharded-level cursor counters: logical seeks/nexts counted once
    /// per cross-shard movement, blocks/pages folded from the child
    /// cursors that actually moved — idle shards contribute nothing.
    counters: Arc<ScanCounters>,
    /// Aggregates over the children, refreshed after every operation so
    /// `EngineStats` getters can hand out references.
    agg_db: DbStats,
    agg_stall: StallStats,
    booted: bool,
}

impl ShardedDb {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        spec: ShardSpec,
        kind: SystemKind,
        opts: LsmOptions,
        merge: MergeEngine,
        bloom: BloomBuilder,
        kvaccel_cfg: crate::kvaccel::KvaccelConfig,
        adoc_cfg: crate::baselines::AdocConfig,
    ) -> Self {
        let router = Router::from_spec(&spec);
        let n = router.shard_count();
        // the arbiter partitions the CONFIGURED redirection budget (the
        // controller's occupancy cap), not a hardcoded one, so a custom
        // cap survives sharding — and N=1 hands the exact configured cap
        // back to its only shard
        let arbiter_cfg = ArbiterConfig {
            total_occupancy: kvaccel_cfg.controller.max_kv_occupancy,
            ..ArbiterConfig::default()
        };
        // one engine-wide block cache: every shard shares the same
        // instance, so the configured budget bounds the whole store and
        // a hot shard can use capacity a cold shard leaves idle
        let block_cache =
            crate::engine::new_block_cache(opts.block_cache_blocks);
        let shards: Vec<Box<dyn KvEngine>> = (0..n)
            .map(|i| {
                let mut kcfg = kvaccel_cfg.clone();
                // every KVACCEL shard gets its own Dev-LSM namespace on
                // the one shared device
                kcfg.namespace = i as u32;
                EngineBuilder::new(kind)
                    .opts(opts.clone().with_wal_stream(i as u32))
                    .merge_engine(merge.clone())
                    .bloom_builder(bloom.clone())
                    .kvaccel_config(kcfg)
                    .adoc_config(adoc_cfg.clone())
                    .block_cache(block_cache.clone())
                    .build()
            })
            .collect();
        let mut db = Self {
            shards,
            router,
            arbiter: DeviceArbiter::new(n, arbiter_cfg),
            kind,
            counters: Arc::new(ScanCounters::default()),
            agg_db: DbStats::default(),
            agg_stall: StallStats::default(),
            booted: false,
        };
        db.refresh_stats();
        db
    }

    fn is_kvaccel(&self) -> bool {
        matches!(self.kind, SystemKind::Kvaccel { .. })
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    pub fn arbiter(&self) -> &DeviceArbiter {
        &self.arbiter
    }

    /// Mutable arbiter access — the conformance tests' fault-injection
    /// hook (begin a transfer, crash before it settles).
    pub fn arbiter_mut(&mut self) -> &mut DeviceArbiter {
        &mut self.arbiter
    }

    pub fn shards(&self) -> &[Box<dyn KvEngine>] {
        &self.shards
    }

    /// Per-shard stall/redirect breakdown for reports.
    pub fn shard_reports(&self, env: &SimEnv) -> Vec<ShardReport> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, sh)| {
                let stats = sh.db_stats();
                let stall = sh.stall_stats();
                let kv = sh.kvaccel();
                ShardReport {
                    shard: i,
                    label: self.router.shard_label(i),
                    puts: stats.puts,
                    gets: stats.gets,
                    redirected: sh.redirected_writes(),
                    rollbacks: sh.rollbacks(),
                    stop_events: stall.stop_events,
                    stopped_s: stall.stopped_ns_total as f64 / NS_PER_SEC as f64,
                    slowdown_events: stall.slowdown_events,
                    dev_resident_keys: kv.map_or(0, |k| k.metadata.len()),
                    grant: kv.map(|_| self.arbiter.grants()[i]),
                    dev_occupancy: kv
                        .map_or(0.0, |k| env.device.kv_ns_occupancy(k.namespace())),
                }
            })
            .collect()
    }

    /// First-use provisioning: per-shard WAL streams and (for KVACCEL)
    /// Dev-LSM namespaces on the shared device, plus the initial grant
    /// push. Idempotent.
    fn ensure_boot(&mut self, env: &mut SimEnv) {
        if self.booted {
            return;
        }
        env.device.wal_ensure_streams(self.shards.len());
        if self.is_kvaccel() {
            env.device.kv_ensure_namespaces(self.shards.len());
        }
        self.push_grants();
        self.booted = true;
    }

    /// Install the arbiter's current grants as each KVACCEL shard's
    /// controller occupancy cap. With N >= 2, each shard also switches
    /// to its *own* namespace occupancy as the backpressure signal: the
    /// grants sum to the region budget, so every shard honoring its own
    /// grant bounds the region, and one shard's fill never chokes a
    /// sibling's redirection. (N=1 keeps the region-wide signal and the
    /// full 0.9 cap — bit-identical to the unsharded engine.)
    fn push_grants(&mut self) {
        if !self.is_kvaccel() {
            return;
        }
        let scoped = self.shards.len() > 1;
        let grants = self.arbiter.grants().to_vec();
        for (sh, g) in self.shards.iter_mut().zip(grants) {
            if let Some(k) = sh.kvaccel_mut() {
                k.controller.cfg.max_kv_occupancy = g;
                k.scoped_occupancy = scoped;
            }
        }
    }

    /// One arbitration pass: read each shard's detector verdict and
    /// namespace occupancy, rebalance grants if a hot shard needs the
    /// capacity an idle shard holds, and durably record a changed table
    /// (the commit point crash recovery rolls forward from).
    fn arbitrate(&mut self, env: &mut SimEnv, at: Nanos) {
        if !self.is_kvaccel() || self.shards.len() < 2 {
            return;
        }
        // signals are only worth collecting when the arbiter would act
        // (cadence elapsed or a transfer matured) — not on every op
        if !self.arbiter.due(at) {
            return;
        }
        let signals: Vec<ShardSignal> = self
            .shards
            .iter()
            .map(|sh| {
                let k = sh.kvaccel().expect("kvaccel shard");
                ShardSignal {
                    stall_imminent: k.detector.stall_imminent(),
                    occupancy: env.device.kv_ns_occupancy(k.namespace()),
                }
            })
            .collect();
        if self.arbiter.maybe_rebalance(at, &signals) {
            env.device.meta_sync_write(at, shard_manifest_bytes(self.shards.len()));
            self.push_grants();
        }
    }

    /// Pre-operation maintenance: tick every shard the op does not touch
    /// (their flushes/compactions apply on virtual time instead of
    /// freezing) and run arbitration. With one shard this is a no-op, so
    /// N=1 stays bit-identical to the unsharded engine.
    fn pre_op(&mut self, env: &mut SimEnv, at: Nanos, target: Option<usize>) {
        self.ensure_boot(env);
        if self.shards.len() < 2 {
            return;
        }
        for (i, sh) in self.shards.iter_mut().enumerate() {
            if Some(i) != target {
                sh.tick(env, at);
            }
        }
        self.arbitrate(env, at);
    }

    fn refresh_stats(&mut self) {
        let mut db = DbStats::default();
        let mut stall = StallStats::default();
        for sh in &self.shards {
            let d = sh.db_stats();
            db.puts += d.puts;
            db.deletes += d.deletes;
            db.batches += d.batches;
            db.gets += d.gets;
            db.get_hits += d.get_hits;
            db.block_reads += d.block_reads;
            db.bloom_negative_probes += d.bloom_negative_probes;
            db.bloom_false_positives += d.bloom_false_positives;
            db.flush_count += d.flush_count;
            db.compaction_count += d.compaction_count;
            db.bytes_flushed += d.bytes_flushed;
            db.bytes_compacted_read += d.bytes_compacted_read;
            db.bytes_compacted_written += d.bytes_compacted_written;
            db.user_bytes_written += d.user_bytes_written;
            db.stall_anomalies += d.stall_anomalies;
            let st = sh.stall_stats();
            stall.slowdown_events += st.slowdown_events;
            stall.stop_events += st.stop_events;
            stall.stopped_ns_total += st.stopped_ns_total;
            stall.delayed_ns_total += st.delayed_ns_total;
        }
        // interval lists only change when a stop completes (one interval
        // per stop event); keep the previous merged list otherwise, so
        // the per-op refresh stays O(shards) instead of re-sorting the
        // whole stall history on every operation
        if stall.stop_events == self.agg_stall.stop_events {
            stall.stall_intervals = std::mem::take(&mut self.agg_stall.stall_intervals);
        } else {
            for sh in &self.shards {
                stall
                    .stall_intervals
                    .extend(sh.stall_stats().stall_intervals.iter().copied());
            }
            stall.stall_intervals.sort_unstable();
        }
        self.agg_db = db;
        self.agg_stall = stall;
    }

    // -----------------------------------------------------------------
    // Durable lifecycle
    // -----------------------------------------------------------------

    /// The top-level shard manifest contents (children filled by the
    /// caller after closing/crashing each shard).
    fn shard_image(&self) -> ShardImage {
        ShardImage {
            policy: self.router.policy(),
            boundaries: self.router.boundaries().to_vec(),
            hash_seed: self.router.hash_seed(),
            child_kind: self.kind,
            children: Vec::new(),
            grants: self.arbiter.grants().to_vec(),
            pending: self.arbiter.pending(),
        }
    }

    /// Reopen from a recovered shard manifest: children recover
    /// sequentially (manifest replay + WAL replay + device reconcile,
    /// each against its own WAL stream and namespace), the router comes
    /// back from the boundary table, and the arbiter grant table rolls
    /// any mid-flight transfer forward to a consistent state.
    pub fn open(env: &mut SimEnv, at: Nanos, image: ShardImage) -> Result<(Self, Nanos)> {
        let n = image.children.len().max(1);
        env.device.wal_ensure_streams(n);
        if matches!(image.child_kind, SystemKind::Kvaccel { .. }) {
            env.device.kv_ensure_namespaces(n);
        }
        // the recovered children carry the ORIGINAL configured controller
        // cap (not their last granted slice); that is the budget the
        // recovered grant table must sum back to
        let total_occupancy = image
            .children
            .first()
            .and_then(|c| c.kvaccel_cfg.as_ref())
            .map(|c| c.controller.max_kv_occupancy)
            .unwrap_or_else(|| ArbiterConfig::default().total_occupancy);
        // read the top-level shard manifest back
        let mut t = env.device.read_block(at, shard_manifest_bytes(n));
        let mut shards: Vec<Box<dyn KvEngine>> = Vec::with_capacity(n);
        let mut block_cache: Option<crate::engine::SharedBlockCache> = None;
        for child in image.children {
            let (mut sh, tc) = EngineBuilder::open(env, t, child)?;
            t = tc;
            // recovered children each built their own cold cache; swap in
            // one store-wide instance (the cache is volatile state, so a
            // cold shared cache is exactly what a restart produces)
            let cache = block_cache
                .get_or_insert_with(|| {
                    crate::engine::new_block_cache(
                        sh.main_db().opts.block_cache_blocks,
                    )
                })
                .clone();
            sh.set_block_cache(cache);
            shards.push(sh);
        }
        let router =
            Router::from_parts(image.policy, image.boundaries, image.hash_seed);
        let arbiter = DeviceArbiter::recover(
            image.grants,
            image.pending,
            ArbiterConfig { total_occupancy, ..ArbiterConfig::default() },
        );
        let mut db = Self {
            shards,
            router,
            arbiter,
            kind: image.child_kind,
            counters: Arc::new(ScanCounters::default()),
            agg_db: DbStats::default(),
            agg_stall: StallStats::default(),
            booted: false,
        };
        db.ensure_boot(env);
        db.refresh_stats();
        env.clock.advance_to(t);
        Ok((db, t))
    }
}

// ---------------------------------------------------------------------
// EngineStats: cross-shard aggregation
// ---------------------------------------------------------------------

impl EngineStats for ShardedDb {
    /// Shard 0's Main-LSM (uniform configuration across shards); the
    /// aggregated accessors below are the real reporting surface.
    fn main_db(&self) -> &LsmDb {
        self.shards[0].main_db()
    }

    fn sharded(&self) -> Option<&ShardedDb> {
        Some(self)
    }

    fn stall_stats(&self) -> &StallStats {
        &self.agg_stall
    }

    fn db_stats(&self) -> &DbStats {
        &self.agg_db
    }

    fn scan_amp(&self) -> ScanAmp {
        self.counters.snapshot()
    }

    fn redirected_writes(&self) -> u64 {
        self.shards.iter().map(|s| s.redirected_writes()).sum()
    }

    fn rollbacks(&self) -> u64 {
        self.shards.iter().map(|s| s.rollbacks()).sum()
    }

    fn health(&self) -> EngineHealth {
        let mut agg: Option<EngineHealth> = None;
        for sh in &self.shards {
            let h = sh.health();
            agg = Some(match agg {
                None => h,
                Some(mut a) => {
                    a.write_condition = worst_condition(a.write_condition, h.write_condition);
                    a.l0_files += h.l0_files;
                    a.imm_memtables += h.imm_memtables;
                    a.memtable_bytes += h.memtable_bytes;
                    a.pending_compaction_bytes += h.pending_compaction_bytes;
                    a.wal_live_bytes += h.wal_live_bytes;
                    a.dev_resident_keys += h.dev_resident_keys;
                    a.stall_imminent |= h.stall_imminent;
                    // every sharded snapshot pins all shards, so the
                    // logical count is the per-shard maximum
                    a.live_snapshots = a.live_snapshots.max(h.live_snapshots);
                    a.min_pinned_seq = match (a.min_pinned_seq, h.min_pinned_seq) {
                        (Some(x), Some(y)) => Some(x.min(y)),
                        (x, y) => x.or(y),
                    };
                    a.recoveries = a.recoveries.max(h.recoveries);
                    a.recovered_wal_records += h.recovered_wal_records;
                    a.recovered_dev_keys += h.recovered_dev_keys;
                    a
                }
            });
        }
        agg.expect("sharded store has at least one shard")
    }
}

fn worst_condition(a: WriteCondition, b: WriteCondition) -> WriteCondition {
    let rank = |c: &WriteCondition| match c {
        WriteCondition::Normal => 0,
        WriteCondition::Delayed(_) => 1,
        WriteCondition::Stopped(_) => 2,
    };
    if rank(&b) > rank(&a) {
        b
    } else {
        a
    }
}

// ---------------------------------------------------------------------
// KvEngine
// ---------------------------------------------------------------------

impl KvEngine for ShardedDb {
    fn put(&mut self, env: &mut SimEnv, at: Nanos, key: Key, val: ValueDesc) -> PutResult {
        let s = self.router.shard_of(key);
        self.pre_op(env, at, Some(s));
        let r = self.shards[s].put(env, at, key, val);
        self.refresh_stats();
        r
    }

    fn delete(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> PutResult {
        let s = self.router.shard_of(key);
        self.pre_op(env, at, Some(s));
        let r = self.shards[s].delete(env, at, key);
        self.refresh_stats();
        r
    }

    fn get(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> (Option<ValueDesc>, Nanos) {
        let s = self.router.shard_of(key);
        self.pre_op(env, at, Some(s));
        let r = self.shards[s].get(env, at, key);
        self.refresh_stats();
        r
    }

    /// Split the batch into per-shard sub-batches (stable order within
    /// each shard) and apply each through its shard's single admission
    /// gate at the same issue instant — shards are independent stores,
    /// so the sub-batches proceed as parallel group commits and the
    /// caller completes at the slowest shard.
    fn write_batch(&mut self, env: &mut SimEnv, at: Nanos, batch: &WriteBatch) -> BatchResult {
        if batch.is_empty() {
            return BatchResult { done: at, ..Default::default() };
        }
        let n = self.shards.len();
        let mut subs: Vec<WriteBatch> = vec![WriteBatch::new(); n];
        for op in batch.ops() {
            let s = self.router.shard_of(op.key());
            match *op {
                crate::engine::BatchOp::Put { key, val } => {
                    subs[s].put(key, val);
                }
                crate::engine::BatchOp::Delete { key } => {
                    subs[s].delete(key);
                }
            }
        }
        self.ensure_boot(env);
        if n > 1 {
            for (i, sub) in subs.iter().enumerate() {
                if sub.is_empty() {
                    self.shards[i].tick(env, at);
                }
            }
            self.arbitrate(env, at);
        }
        let mut done = at;
        let mut stalled_ns = 0;
        let mut delayed_ns = 0;
        for (i, sub) in subs.iter().enumerate() {
            if sub.is_empty() {
                continue;
            }
            let r = self.shards[i].write_batch(env, at, sub);
            done = done.max(r.done);
            // sub-batches run as parallel group commits: the caller's
            // stall is the slowest shard's, not the sum (keeps the
            // single-shard invariant stalled_ns <= done - at)
            stalled_ns = stalled_ns.max(r.stalled_ns);
            delayed_ns = delayed_ns.max(r.delayed_ns);
        }
        env.clock.advance_to(done);
        self.refresh_stats();
        BatchResult { done, stalled_ns, delayed_ns, ops: batch.len() }
    }

    /// Pin every shard at the same virtual instant — the coherent
    /// sequence horizon: no operation can interleave between the
    /// per-shard pins, so the composite view is exactly the store's
    /// state at `at`.
    fn snapshot(&mut self, env: &mut SimEnv, at: Nanos) -> Snapshot {
        self.ensure_boot(env);
        let snaps: Vec<Snapshot> = self
            .shards
            .iter_mut()
            .map(|sh| sh.snapshot(env, at))
            .collect();
        Snapshot::pin_sharded(at, snaps)
    }

    fn iter(&mut self, env: &mut SimEnv, at: Nanos, opts: IterOptions) -> Box<dyn DbIterator> {
        self.ensure_boot(env);
        let snap = match &opts.snapshot {
            Some(s) => {
                // a foreign snapshot (child engine, unsharded store, or a
                // previous life) cannot provide the coherent horizon this
                // cursor promises — fail loudly instead of silently
                // re-pinning current state
                assert_eq!(
                    s.inner().shards.len(),
                    self.shards.len(),
                    "iterating a sharded store requires a snapshot pinned \
                     by the same sharded store"
                );
                s.clone()
            }
            None => self.snapshot(env, at),
        };
        let child_snaps = snap.inner().shards.clone();
        let router = self.router.clone();
        let is_range = router.policy() == ShardPolicy::Range;
        let children: Vec<Box<dyn DbIterator>> = self
            .shards
            .iter_mut()
            .zip(child_snaps)
            .enumerate()
            .map(|(i, (sh, cs))| {
                // a range shard wholly outside [lower, upper) can never
                // yield: stand in a trivially-empty cursor instead of
                // building a real one (the frontier walk skips it anyway)
                if is_range
                    && (router.shard_beyond_upper(i, opts.upper_bound)
                        || router.shard_below_lower(i, opts.lower_bound))
                {
                    return Box::new(EmptyCursor) as Box<dyn DbIterator>;
                }
                // children are plain ascending-vocabulary cursors; the
                // sharded cursor mirrors movement ops itself
                let child_opts = IterOptions {
                    lower_bound: opts.lower_bound,
                    upper_bound: opts.upper_bound,
                    reverse: false,
                    snapshot: Some(cs),
                };
                sh.iter(env, at, child_opts)
            })
            .collect();
        Box::new(ShardIter::new(
            children,
            router,
            &opts,
            self.counters.clone(),
        ))
    }

    fn flush(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        self.ensure_boot(env);
        let mut t = at;
        for sh in &mut self.shards {
            t = t.max(sh.flush(env, at));
        }
        self.refresh_stats();
        t
    }

    fn finish(&mut self, env: &mut SimEnv, at: Nanos) -> Result<Nanos> {
        self.ensure_boot(env);
        let mut t = at;
        for sh in &mut self.shards {
            t = sh.finish(env, t)?;
        }
        self.refresh_stats();
        Ok(t)
    }

    fn tick(&mut self, env: &mut SimEnv, at: Nanos) {
        self.ensure_boot(env);
        for sh in &mut self.shards {
            sh.tick(env, at);
        }
        self.arbitrate(env, at);
    }

    fn set_block_cache(&mut self, cache: crate::engine::SharedBlockCache) {
        for sh in &mut self.shards {
            sh.set_block_cache(cache.clone());
        }
    }

    /// One CDC stream per shard: the children have independent sequence
    /// domains (per-shard WAL streams), so their tails cannot be merged
    /// into one ordered log — the shipper keeps one watermark per stream
    /// and the replica's identically-seeded router re-derives the target
    /// shard from each record's key.
    fn cdc_streams(&self) -> usize {
        self.shards.len()
    }

    fn cdc_tail(
        &self,
        env: &SimEnv,
        wm: &[crate::lsm::Seq],
    ) -> Vec<crate::engine::CdcRecord> {
        let mut out = Vec::new();
        for (i, sh) in self.shards.iter().enumerate() {
            let w = [wm.get(i).copied().unwrap_or(0)];
            out.extend(
                sh.cdc_tail(env, &w)
                    .into_iter()
                    .map(|r| crate::engine::CdcRecord { stream: i, ..r }),
            );
        }
        out
    }

    fn repl_apply(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        rec: &crate::engine::CdcRecord,
    ) -> PutResult {
        // route by key, not by stream: the router is rebuilt from the
        // same spec on every replica, so this lands on the shard whose
        // sequence domain the record's seq belongs to
        let s = self.router.shard_of(rec.entry.key);
        self.pre_op(env, at, Some(s));
        let r = self.shards[s].repl_apply(env, at, rec);
        self.refresh_stats();
        r
    }

    /// Clean shutdown: every shard closes (final rollback, sealed +
    /// fsync'd WAL, CleanShutdown edit), then the top-level shard
    /// manifest is written durably.
    fn close(self: Box<Self>, env: &mut SimEnv, at: Nanos) -> Result<DurableImage> {
        let mut image = self.shard_image();
        let ShardedDb { shards, kind, .. } = *self;
        let mut t = at;
        for sh in shards {
            let img = sh.close(env, t)?;
            t = t.max(img.taken_at);
            image.children.push(img);
        }
        let t = env
            .device
            .meta_sync_write(t, shard_manifest_bytes(image.children.len()));
        env.clock.advance_to(t);
        let opts = image.children[0].opts.clone();
        Ok(DurableImage {
            kind,
            opts,
            merge: MergeEngine::rust(),
            bloom: BloomBuilder::rust(),
            manifest: Manifest::new(),
            wal: Vec::new(),
            vlog: None,
            kvaccel_cfg: None,
            adoc_cfg: None,
            shard: Some(Box::new(image)),
            clean: true,
            taken_at: t,
        })
    }

    /// One physical power loss for the whole store: each shard captures
    /// its own durable cut (per-shard WAL stream watermark, per-shard
    /// manifest; device-side state survives in place), and the shard
    /// manifest carries the grant table exactly as last recorded —
    /// including a torn mid-rebalance transfer, which recovery rolls
    /// forward.
    fn crash(self: Box<Self>, env: &mut SimEnv, at: Nanos) -> DurableImage {
        let mut image = self.shard_image();
        let ShardedDb { shards, kind, .. } = *self;
        let losses_before = env.device.power_losses;
        for sh in shards {
            image.children.push(sh.crash(env, at));
        }
        // the shards all died in the same power loss, not one each
        env.device.power_losses = losses_before + 1;
        let opts = image.children[0].opts.clone();
        DurableImage {
            kind,
            opts,
            merge: MergeEngine::rust(),
            bloom: BloomBuilder::rust(),
            manifest: Manifest::new(),
            wal: Vec::new(),
            vlog: None,
            kvaccel_cfg: None,
            adoc_cfg: None,
            shard: Some(Box::new(image)),
            clean: false,
            taken_at: at,
        }
    }
}

// ---------------------------------------------------------------------
// Cross-shard cursor
// ---------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Dir {
    Fwd,
    Bwd,
}

/// Stand-in cursor for a range shard wholly outside the iterator's key
/// bounds: always invalid, never charges anything.
struct EmptyCursor;

impl DbIterator for EmptyCursor {
    fn seek(&mut self, _env: &mut SimEnv, at: Nanos, _key: Key) -> Nanos {
        at
    }
    fn seek_to_first(&mut self, _env: &mut SimEnv, at: Nanos) -> Nanos {
        at
    }
    fn seek_to_last(&mut self, _env: &mut SimEnv, at: Nanos) -> Nanos {
        at
    }
    fn seek_for_prev(&mut self, _env: &mut SimEnv, at: Nanos, _key: Key) -> Nanos {
        at
    }
    fn next(&mut self, _env: &mut SimEnv, at: Nanos) -> Nanos {
        at
    }
    fn prev(&mut self, _env: &mut SimEnv, at: Nanos) -> Nanos {
        at
    }
    fn valid(&self) -> bool {
        false
    }
    fn entry(&self) -> Option<Entry> {
        None
    }
    fn amp(&self) -> ScanAmp {
        ScanAmp::default()
    }
}

/// The cross-shard [`DbIterator`]: a k-way merge over per-shard cursors.
///
/// Range policy walks shards in key order, touching each shard's cursor
/// only when the scan frontier reaches its range — an idle shard whose
/// cursor never yields charges zero read amplification (the PR5 bugfix:
/// no double-charged `ScanAmp` from idle shards). Hash policy is
/// scatter-gather: every shard may own in-range keys, so every cursor
/// positions and the merge emits the global key order (a key lives on
/// exactly one shard, so heads never tie).
pub struct ShardIter {
    children: Vec<Box<dyn DbIterator>>,
    router: Router,
    lower: Option<Key>,
    upper: Option<Key>,
    reverse: bool,
    dir: Dir,
    cur: Option<(usize, Entry)>,
    /// Last folded per-child amp, so each movement folds only the delta.
    folded: Vec<ScanAmp>,
    counters: Arc<ScanCounters>,
    local: ScanAmp,
}

impl ShardIter {
    fn new(
        children: Vec<Box<dyn DbIterator>>,
        router: Router,
        opts: &IterOptions,
        counters: Arc<ScanCounters>,
    ) -> Self {
        let n = children.len();
        Self {
            children,
            router,
            lower: opts.lower_bound,
            upper: opts.upper_bound,
            reverse: opts.reverse,
            dir: Dir::Fwd,
            cur: None,
            folded: vec![ScanAmp::default(); n],
            counters,
            local: ScanAmp::default(),
        }
    }

    fn is_range(&self) -> bool {
        self.router.policy() == ShardPolicy::Range
    }

    /// Fold child `i`'s block/page deltas into the sharded counters.
    fn fold(&mut self, i: usize) {
        let a = self.children[i].amp();
        let blocks = a.main_blocks - self.folded[i].main_blocks;
        let pages = a.dev_pages - self.folded[i].dev_pages;
        let vlog = a.vlog_blocks - self.folded[i].vlog_blocks;
        if blocks > 0 {
            self.local.main_blocks += blocks;
            self.counters
                .main_blocks
                .fetch_add(blocks, std::sync::atomic::Ordering::Relaxed);
        }
        if pages > 0 {
            self.local.dev_pages += pages;
            self.counters
                .dev_pages
                .fetch_add(pages, std::sync::atomic::Ordering::Relaxed);
        }
        if vlog > 0 {
            self.local.vlog_blocks += vlog;
            self.counters
                .vlog_blocks
                .fetch_add(vlog, std::sync::atomic::Ordering::Relaxed);
        }
        self.folded[i] = a;
    }

    fn count_seek(&mut self) {
        self.local.seeks += 1;
        self.counters
            .seeks
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    fn count_next(&mut self) {
        self.local.nexts += 1;
        self.counters
            .nexts
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    /// Winner among positioned children: smallest key (ascending).
    fn settle_min(&mut self) {
        let mut best: Option<(usize, Entry)> = None;
        for (i, c) in self.children.iter().enumerate() {
            if let Some(e) = c.entry() {
                if best.map_or(true, |(_, b)| e.key < b.key) {
                    best = Some((i, e));
                }
            }
        }
        self.cur = best;
    }

    /// Winner among positioned children: largest key (descending).
    fn settle_max(&mut self) {
        let mut best: Option<(usize, Entry)> = None;
        for (i, c) in self.children.iter().enumerate() {
            if let Some(e) = c.entry() {
                if best.map_or(true, |(_, b)| e.key > b.key) {
                    best = Some((i, e));
                }
            }
        }
        self.cur = best;
    }

    /// Shard `i` cannot yield under the cursor's upper bound (range
    /// policy; one shared predicate on the router).
    fn shard_beyond_upper(&self, i: usize) -> bool {
        self.router.shard_beyond_upper(i, self.upper)
    }

    /// Shard `i`'s range lies entirely below the cursor's lower bound.
    fn shard_below_lower(&self, i: usize) -> bool {
        self.router.shard_below_lower(i, self.lower)
    }

    fn seek_ascending(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> Nanos {
        self.count_seek();
        // clamp into bounds first (like the single-shard cursor), so the
        // range policy resolves the owner of the first key that can
        // actually be emitted
        let key = match self.lower {
            Some(lo) => key.max(lo),
            None => key,
        };
        let mut t = at;
        if self.is_range() {
            let mut idx = self.router.shard_of(key);
            loop {
                if self.shard_beyond_upper(idx) {
                    self.cur = None;
                    break;
                }
                t = self.children[idx].seek(env, t, key);
                self.fold(idx);
                if let Some(e) = self.children[idx].entry() {
                    self.cur = Some((idx, e));
                    break;
                }
                if idx + 1 >= self.children.len() {
                    self.cur = None;
                    break;
                }
                idx += 1;
            }
        } else {
            for i in 0..self.children.len() {
                t = self.children[i].seek(env, t, key);
                self.fold(i);
            }
            self.settle_min();
        }
        self.dir = Dir::Fwd;
        t
    }

    fn seek_descending(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> Nanos {
        self.count_seek();
        let mut key = key;
        if let Some(up) = self.upper {
            if up == 0 {
                self.cur = None;
                self.dir = Dir::Bwd;
                return at;
            }
            key = key.min(up - 1);
        }
        if let Some(lo) = self.lower {
            if key < lo {
                self.cur = None;
                self.dir = Dir::Bwd;
                return at;
            }
        }
        let mut t = at;
        if self.is_range() {
            let mut idx = self.router.shard_of(key);
            loop {
                if self.shard_below_lower(idx) {
                    self.cur = None;
                    break;
                }
                t = self.children[idx].seek_for_prev(env, t, key);
                self.fold(idx);
                if let Some(e) = self.children[idx].entry() {
                    self.cur = Some((idx, e));
                    break;
                }
                if idx == 0 {
                    self.cur = None;
                    break;
                }
                idx -= 1;
            }
        } else {
            for i in 0..self.children.len() {
                t = self.children[i].seek_for_prev(env, t, key);
                self.fold(i);
            }
            self.settle_max();
        }
        self.dir = Dir::Bwd;
        t
    }

    fn step_ascending(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        let Some((idx, e)) = self.cur else { return at };
        self.count_next();
        let mut t = at;
        if self.is_range() {
            // the child handles its own direction switch; crossing a
            // shard boundary re-seeks the successor lazily
            t = self.children[idx].next(env, t);
            self.fold(idx);
            if let Some(ne) = self.children[idx].entry() {
                self.cur = Some((idx, ne));
            } else {
                self.cur = None;
                let mut i = idx + 1;
                while i < self.children.len() && e.key < MAX_USER_KEY {
                    if self.shard_beyond_upper(i) {
                        break;
                    }
                    t = self.children[i].seek(env, t, e.key + 1);
                    self.fold(i);
                    if let Some(ne) = self.children[i].entry() {
                        self.cur = Some((i, ne));
                        break;
                    }
                    i += 1;
                }
            }
        } else if self.dir == Dir::Bwd {
            // direction switch: re-position every shard past the cursor
            if e.key >= MAX_USER_KEY {
                self.cur = None;
                return t;
            }
            for i in 0..self.children.len() {
                t = self.children[i].seek(env, t, e.key + 1);
                self.fold(i);
            }
            self.settle_min();
        } else {
            t = self.children[idx].next(env, t);
            self.fold(idx);
            self.settle_min();
        }
        self.dir = Dir::Fwd;
        t
    }

    fn step_descending(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        let Some((idx, e)) = self.cur else { return at };
        self.count_next();
        let mut t = at;
        if self.is_range() {
            t = self.children[idx].prev(env, t);
            self.fold(idx);
            if let Some(ne) = self.children[idx].entry() {
                self.cur = Some((idx, ne));
            } else {
                self.cur = None;
                let mut i = idx;
                while i > 0 && e.key > 0 {
                    i -= 1;
                    if self.shard_below_lower(i) {
                        break;
                    }
                    t = self.children[i].seek_for_prev(env, t, e.key - 1);
                    self.fold(i);
                    if let Some(ne) = self.children[i].entry() {
                        self.cur = Some((i, ne));
                        break;
                    }
                }
            }
        } else if self.dir == Dir::Fwd {
            if e.key == 0 {
                self.cur = None;
                return t;
            }
            for i in 0..self.children.len() {
                t = self.children[i].seek_for_prev(env, t, e.key - 1);
                self.fold(i);
            }
            self.settle_max();
        } else {
            t = self.children[idx].prev(env, t);
            self.fold(idx);
            self.settle_max();
        }
        self.dir = Dir::Bwd;
        t
    }

    fn first_in_bounds(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        let lo = self.lower.unwrap_or(0);
        self.seek_ascending(env, at, lo)
    }

    fn last_in_bounds(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        let hi = match self.upper {
            Some(0) => {
                self.cur = None;
                return at;
            }
            Some(up) => up - 1,
            None => MAX_USER_KEY,
        };
        self.seek_descending(env, at, hi)
    }
}

// The reverse flag mirrors every movement op, exactly like the
// single-shard `EngineIterator`.
impl DbIterator for ShardIter {
    fn seek(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> Nanos {
        if self.reverse {
            self.seek_descending(env, at, key)
        } else {
            self.seek_ascending(env, at, key)
        }
    }

    fn seek_to_first(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        if self.reverse {
            self.last_in_bounds(env, at)
        } else {
            self.first_in_bounds(env, at)
        }
    }

    fn seek_to_last(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        if self.reverse {
            self.first_in_bounds(env, at)
        } else {
            self.last_in_bounds(env, at)
        }
    }

    fn seek_for_prev(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> Nanos {
        if self.reverse {
            self.seek_ascending(env, at, key)
        } else {
            self.seek_descending(env, at, key)
        }
    }

    fn next(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        if self.reverse {
            self.step_descending(env, at)
        } else {
            self.step_ascending(env, at)
        }
    }

    fn prev(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        if self.reverse {
            self.step_ascending(env, at)
        } else {
            self.step_descending(env, at)
        }
    }

    fn valid(&self) -> bool {
        self.cur.is_some()
    }

    fn entry(&self) -> Option<Entry> {
        self.cur.map(|(_, e)| e)
    }

    fn amp(&self) -> ScanAmp {
        self.local
    }
}
