//! Key → shard routing for the sharded engine layer.
//!
//! Two policies, mirroring production column-family/instance sharding:
//!
//! - **Range**: a boundary table splits the keyspace into contiguous
//!   shards (`boundaries[i]` is shard `i`'s first key). Locality is
//!   preserved: a bounded scan touches only the shards whose ranges
//!   intersect it, and the cross-shard cursor walks shards in key order.
//! - **Hash**: a seeded multiplicative hash spreads keys uniformly, so
//!   hot key ranges cannot concentrate on one shard — at the price of
//!   scatter-gather scans (every shard may hold in-range keys).
//!
//! The router is part of the durable shard manifest: the boundary table
//! (or hash seed) is written at close/crash and restored at open, so a
//! reopened store routes every key to the shard that owns its data.

use crate::lsm::entry::{Key, MAX_USER_KEY};

/// How the keyspace is partitioned across shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardPolicy {
    Range,
    Hash,
}

impl ShardPolicy {
    pub fn label(&self) -> &'static str {
        match self {
            ShardPolicy::Range => "range",
            ShardPolicy::Hash => "hash",
        }
    }
}

/// Construction parameters for a sharded store.
#[derive(Clone, Debug)]
pub struct ShardSpec {
    pub shards: usize,
    pub policy: ShardPolicy,
    /// Range policy: the populated key prefix the boundary table splits
    /// evenly (keys at or beyond it route to the last shard).
    pub key_space: Key,
    /// Hash policy: seed folded into the shard hash.
    pub hash_seed: u64,
}

impl ShardSpec {
    pub fn new(shards: usize, policy: ShardPolicy) -> Self {
        Self {
            shards: shards.max(1),
            policy,
            key_space: MAX_USER_KEY,
            hash_seed: 0x5A5A_0FF1_CE00_D00D,
        }
    }
}

/// The routing table: resolves every key to exactly one shard.
#[derive(Clone, Debug)]
pub struct Router {
    policy: ShardPolicy,
    /// Range policy: `boundaries[i]` = first key owned by shard `i`
    /// (`boundaries[0] == 0`); shard `i` owns `[b[i], b[i+1])` and the
    /// last shard owns the open tail.
    boundaries: Vec<Key>,
    hash_seed: u64,
}

impl Router {
    pub fn from_spec(spec: &ShardSpec) -> Self {
        match spec.policy {
            ShardPolicy::Range => {
                let n = spec.shards as u64;
                // split the populated prefix evenly; ceil so the union
                // covers [0, key_space) exactly with the last shard
                // absorbing the remainder and the open tail
                let span = (spec.key_space.max(1) as u64).div_ceil(n).max(1);
                let boundaries = (0..spec.shards)
                    .map(|i| ((i as u64 * span).min(MAX_USER_KEY as u64)) as Key)
                    .collect();
                Self {
                    policy: ShardPolicy::Range,
                    boundaries,
                    hash_seed: spec.hash_seed,
                }
            }
            ShardPolicy::Hash => Self {
                policy: ShardPolicy::Hash,
                boundaries: vec![0; spec.shards],
                hash_seed: spec.hash_seed,
            },
        }
    }

    /// Rebuild from a recovered shard manifest.
    pub fn from_parts(policy: ShardPolicy, boundaries: Vec<Key>, hash_seed: u64) -> Self {
        assert!(!boundaries.is_empty(), "shard manifest has no shards");
        Self { policy, boundaries, hash_seed }
    }

    pub fn policy(&self) -> ShardPolicy {
        self.policy
    }

    pub fn shard_count(&self) -> usize {
        self.boundaries.len()
    }

    /// Range policy's boundary table (first key per shard; all zeros for
    /// hash policy, where the table only records the shard count).
    pub fn boundaries(&self) -> &[Key] {
        &self.boundaries
    }

    pub fn hash_seed(&self) -> u64 {
        self.hash_seed
    }

    /// The owning shard for `key`.
    pub fn shard_of(&self, key: Key) -> usize {
        match self.policy {
            ShardPolicy::Range => {
                // binary search the boundary table: last boundary <= key
                match self.boundaries.binary_search(&key) {
                    Ok(i) => i,
                    Err(i) => i - 1, // b[0] == 0 <= key, so i >= 1
                }
            }
            ShardPolicy::Hash => {
                // splitmix64-style finalizer over key ^ seed
                let mut x = key as u64 ^ self.hash_seed;
                x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
                x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
                x ^= x >> 31;
                (x % self.boundaries.len() as u64) as usize
            }
        }
    }

    /// Range of `[lower, upper_first)` for shard `i` (None upper on the
    /// last shard). Only meaningful for the range policy.
    pub fn range_of(&self, i: usize) -> (Key, Option<Key>) {
        let lo = self.boundaries[i];
        let hi = self.boundaries.get(i + 1).copied();
        (lo, hi)
    }

    /// Range policy: does shard `i`'s range start at or beyond the
    /// exclusive upper bound (so it can never yield)?
    pub fn shard_beyond_upper(&self, i: usize, upper: Option<Key>) -> bool {
        upper.is_some_and(|up| self.boundaries[i] >= up)
    }

    /// Range policy: is shard `i`'s range entirely below the inclusive
    /// lower bound? (Its exclusive end is shard `i+1`'s start.)
    pub fn shard_below_lower(&self, i: usize, lower: Option<Key>) -> bool {
        match (lower, self.boundaries.get(i + 1)) {
            (Some(lo), Some(&next)) => next <= lo,
            _ => false,
        }
    }

    /// Human label for shard `i` in reports.
    pub fn shard_label(&self, i: usize) -> String {
        match self.policy {
            ShardPolicy::Range => match self.range_of(i) {
                (lo, Some(hi)) => format!("[{lo}, {hi})"),
                (lo, None) => format!("[{lo}, ..)"),
            },
            ShardPolicy::Hash => format!("hash {i}/{}", self.shard_count()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_router_partitions_the_prefix() {
        let mut spec = ShardSpec::new(4, ShardPolicy::Range);
        spec.key_space = 1000;
        let r = Router::from_spec(&spec);
        assert_eq!(r.boundaries(), &[0, 250, 500, 750]);
        assert_eq!(r.shard_of(0), 0);
        assert_eq!(r.shard_of(249), 0);
        assert_eq!(r.shard_of(250), 1);
        assert_eq!(r.shard_of(999), 3);
        // the open tail routes to the last shard
        assert_eq!(r.shard_of(1_000_000), 3);
    }

    #[test]
    fn hash_router_covers_all_shards_deterministically() {
        let r = Router::from_spec(&ShardSpec::new(4, ShardPolicy::Hash));
        let mut counts = [0usize; 4];
        for k in 0..4000u32 {
            counts[r.shard_of(k)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!((700..1300).contains(&c), "shard {i} got {c} of 4000");
        }
        // deterministic: same key, same shard
        let r2 = Router::from_spec(&ShardSpec::new(4, ShardPolicy::Hash));
        for k in (0..4000u32).step_by(37) {
            assert_eq!(r.shard_of(k), r2.shard_of(k));
        }
    }

    #[test]
    fn single_shard_routes_everything_to_zero() {
        for policy in [ShardPolicy::Range, ShardPolicy::Hash] {
            let r = Router::from_spec(&ShardSpec::new(1, policy));
            for k in [0u32, 1, 12345, MAX_USER_KEY] {
                assert_eq!(r.shard_of(k), 0);
            }
        }
    }

    #[test]
    fn roundtrips_through_manifest_parts() {
        let mut spec = ShardSpec::new(3, ShardPolicy::Range);
        spec.key_space = 999;
        let r = Router::from_spec(&spec);
        let r2 = Router::from_parts(
            r.policy(),
            r.boundaries().to_vec(),
            r.hash_seed(),
        );
        for k in (0..2000u32).step_by(13) {
            assert_eq!(r.shard_of(k), r2.shard_of(k));
        }
    }
}
