//! `pallas-lint` — the repo-invariant static-analysis pass.
//!
//! Walks `rust/src/**` and enforces the determinism, recovery-safety,
//! and durability-ordering rules in [`kvaccel::lint`]. Exits nonzero
//! when any finding is neither suppressed by an inline
//! `// lint:allow(<rule>): <reason>` nor parked in the checked-in
//! baseline (`rust/lint_baseline.txt`).
//!
//! Run with `cargo run --bin pallas_lint` (any working directory; the
//! source root is resolved from the crate manifest).

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use kvaccel::lint::{lint_file, Baseline, Finding};

fn main() -> ExitCode {
    let manifest_dir = Path::new(env!("CARGO_MANIFEST_DIR"));
    let src_root = manifest_dir.join("src");
    let baseline_path = manifest_dir.join("lint_baseline.txt");

    let baseline = match fs::read_to_string(&baseline_path) {
        Ok(text) => Baseline::parse(&text),
        Err(_) => Baseline::default(),
    };

    let mut files = Vec::new();
    if let Err(e) = collect_rs_files(&src_root, &mut files) {
        eprintln!("pallas-lint: cannot walk {}: {e}", src_root.display());
        return ExitCode::from(2);
    }
    // deterministic report order regardless of directory enumeration
    files.sort();

    let mut live: Vec<Finding> = Vec::new();
    let mut baselined = 0usize;
    let mut suppressed = 0usize;
    let mut scanned = 0usize;
    for path in &files {
        let src = match fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("pallas-lint: cannot read {}: {e}", path.display());
                return ExitCode::from(2);
            }
        };
        let rel = rel_path(&src_root, path);
        let report = lint_file(&rel, &src);
        suppressed += report.suppressed;
        scanned += 1;
        for f in report.findings {
            if baseline.covers(&f) {
                baselined += 1;
            } else {
                live.push(f);
            }
        }
    }

    for f in &live {
        println!("src/{}:{}: [{}] {}", f.path, f.line, f.rule, f.msg);
    }
    println!(
        "pallas-lint: {} files, {} findings ({} allowed inline, {} baselined)",
        scanned,
        live.len(),
        suppressed,
        baselined,
    );
    if live.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

/// Recursively collect `.rs` files under `dir`.
fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Forward-slash path relative to the source root.
fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}
