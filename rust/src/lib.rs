//! KVACCEL — reproduction of "A Host-SSD Collaborative Write Accelerator
//! for LSM-Tree-Based Key-Value Stores" (CS.AR 2024).
//!
//! Three-layer architecture:
//! - **L3 (this crate)**: the paper's contribution — Detector / Controller /
//!   Metadata Manager / Rollback Manager on top of a from-scratch
//!   RocksDB-like LSM engine and a dual-interface SSD simulator; plus the
//!   RocksDB-slowdown and ADOC baselines and the full evaluation harness.
//! - **L2/L1 (python/compile, build time only)**: the compaction-merge and
//!   bloom-build compute graphs (JAX + Pallas), AOT-lowered to HLO text.
//! - **runtime**: PJRT loader executing those artifacts from the Rust
//!   compaction hot path.
//!
//! All systems are driven through one store interface: the
//! [`engine::KvEngine`] trait (put/get/delete/write_batch/snapshot/
//! iter/scan/flush/finish — reads are cursor-first, with refcounted
//! pinned snapshots; see `engine::iter`), constructed by
//! [`engine::EngineBuilder`], living a durable open → run →
//! (close | crash) → reopen lifecycle ([`engine::DurableImage`],
//! `EngineBuilder::open`: manifest replay + WAL recovery + host-device
//! reconciliation), and loaded by the
//! event-driven multi-client scheduler ([`workload::client`] over
//! [`sim::sched`]): N concurrent clients, open- or closed-loop, driven
//! in global virtual-time order.
//!
//! See DESIGN.md for the module inventory and the per-experiment index.

pub mod env;
pub mod runtime;

pub mod sim;

pub mod ssd;

pub mod lsm;

pub mod vlog;

pub mod kvaccel;

pub mod baselines;

pub mod engine;

pub mod shard;

pub mod qos;

pub mod repl;

pub mod workload;

pub mod experiments;

pub mod bench_util;

pub mod lint;

pub mod util;
