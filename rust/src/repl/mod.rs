//! Replication subsystem: WAL-shipped CDC, replica reads, failover and
//! Merkle anti-entropy across simulated nodes.
//!
//! [`ReplicatedDb`] wraps N engine replicas (any [`KvEngine`] kind,
//! including a sharded store) behind the one engine interface:
//!
//! - **CDC shipping** — a change-data-capture shipper tails the
//!   primary's seq-ordered commit stream ([`KvEngine::cdc_tail`],
//!   synchronous with every primary op at zero virtual cost) and applies
//!   the records on each replica over a simulated network link with
//!   configurable one-way latency and bandwidth. Link traffic is modeled
//!   as `ReplShip`/`ReplDeliver` events on a private
//!   [`sim::sched::EventQueue`](crate::sim::sched::EventQueue), pumped
//!   around every operation, so a run is bit-deterministic.
//! - **Replica reads** — gets can route to replicas at snapshot
//!   consistency (each replica *is* the applied prefix of the log):
//!   [`ReadPolicy::Eventual`] round-robins and counts stale serves,
//!   [`ReadPolicy::ReadYourWrites`] only serves from a replica that has
//!   applied everything this session wrote (or observed), falling back
//!   to the primary.
//! - **Failover** — [`ReplicatedDb::fail_primary`] crashes the primary
//!   mid-workload, drains batches already on the wire (shipper-buffered
//!   batches die with the node), promotes the most-caught-up replica,
//!   truncates the log to its applied prefix (the asynchronous data-loss
//!   window) and re-points the shipper at the promoted node's WAL.
//! - **Anti-entropy** — [`ReplicatedDb::rejoin_crashed`] recovers the
//!   crashed node through the regular durable-image path
//!   ([`EngineBuilder::open`]), then repairs its divergence against the
//!   current primary by exchanging Merkle subtree hashes over key ranges
//!   and shipping only the differing ranges — strictly fewer bytes than
//!   a full resync when divergence is partial.
//!
//! Each replica runs on its own [`SimEnv`] (its own simulated SSD,
//! deterministically seeded); node 0 — the initial primary — uses the
//! caller's environment, so a replication-disabled run is untouched.
//! All clocks share one global virtual-time axis.

pub mod merkle;

use std::collections::{BTreeMap, VecDeque};

use anyhow::Result;

use crate::engine::{
    BatchResult, CdcRecord, DbIterator, DurableImage, EngineBuilder,
    EngineHealth, EngineStats, IterOptions, KvEngine, ScanAmp,
    SharedBlockCache, Snapshot, WriteBatch,
};
use crate::env::SimEnv;
use crate::lsm::entry::{Entry, Key, Seq, ValueDesc, MAX_USER_KEY};
use crate::lsm::{DbStats, LsmDb, PutResult, StallStats};
use crate::sim::sched::{ActorId, Event, EventKind, EventQueue};
use crate::sim::{Nanos, MILLIS};
use crate::ssd::SsdConfig;

pub use merkle::{MerkleTree, HASH_WIRE_BYTES};

// ---------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------

/// Where reads go.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadPolicy {
    /// Every read is served by the primary (strong, the default).
    Primary,
    /// Reads round-robin over replicas, but only a replica that has
    /// applied everything this session wrote (or previously observed)
    /// may serve; otherwise fall back to the primary. No read ever
    /// observes a state older than one it already saw.
    ReadYourWrites,
    /// Reads round-robin over replicas unconditionally; a replica behind
    /// the primary's committed log serves a stale (but internally
    /// snapshot-consistent) view, counted in `stale_reads`.
    Eventual,
}

impl ReadPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "primary" => Some(Self::Primary),
            "ryw" | "read-your-writes" => Some(Self::ReadYourWrites),
            "eventual" => Some(Self::Eventual),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::Primary => "primary",
            Self::ReadYourWrites => "ryw",
            Self::Eventual => "eventual",
        }
    }
}

/// Replication topology and link model.
#[derive(Clone, Debug)]
pub struct ReplConfig {
    /// Total nodes including the primary (>= 2).
    pub replicas: usize,
    pub read_policy: ReadPolicy,
    /// One-way link propagation delay.
    pub link_latency: Nanos,
    /// Per-link bandwidth in MiB/s (store-and-forward, serialized per
    /// replica link).
    pub link_mbps: f64,
    /// Minimum leaderless window after a primary crash (failover
    /// blackout is `max(election_timeout, last in-flight arrival)`).
    pub election_timeout: Nanos,
    /// Merkle anti-entropy: leaf ranges over the key space and tree
    /// fanout.
    pub merkle_leaves: usize,
    pub merkle_fanout: usize,
    /// Key-space hint splitting the Merkle leaf ranges evenly over the
    /// populated prefix (keys beyond it clamp into the last leaf).
    pub key_space: Key,
    /// Seeds the replicas' deterministic environments.
    pub seed: u64,
}

impl Default for ReplConfig {
    fn default() -> Self {
        Self {
            replicas: 3,
            read_policy: ReadPolicy::Primary,
            link_latency: 50_000,
            link_mbps: 1024.0,
            election_timeout: 10 * MILLIS,
            merkle_leaves: 64,
            merkle_fanout: 8,
            key_space: MAX_USER_KEY,
            seed: 42,
        }
    }
}

// ---------------------------------------------------------------------
// Reports
// ---------------------------------------------------------------------

/// Per-replica row of the replication breakdown.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ReplicaResult {
    pub node: usize,
    /// "primary" | "replica" | "down".
    pub role: String,
    /// CDC records applied (the primary reports the full log).
    pub applied_records: u64,
    /// Highest primary sequence number applied.
    pub applied_seq: Seq,
    /// Worst replication lag observed, in records behind the log.
    pub max_lag: u64,
    pub mean_lag: f64,
}

/// Replication section of a run report (`RunResult::replication`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ReplResult {
    pub replicas: Vec<ReplicaResult>,
    pub primary: usize,
    pub read_policy: String,
    /// Records captured from the primary's commit stream.
    pub captured_records: u64,
    pub shipped_records: u64,
    pub shipped_bytes: u64,
    /// Replica-served reads that observed a state behind the log.
    pub stale_reads: u64,
    pub replica_reads: u64,
    pub primary_reads: u64,
    pub failovers: u64,
    /// Total leaderless time across failovers.
    pub blackout_ns: Nanos,
    /// Committed records no surviving node held at failover.
    pub lost_records: u64,
    /// Merkle repair traffic (hashes + differing ranges).
    pub anti_entropy_bytes: u64,
    /// What a full resync would have shipped instead.
    pub full_resync_bytes: u64,
}

/// What a primary crash + promotion did.
#[derive(Clone, Copy, Debug)]
pub struct FailoverReport {
    pub crashed: usize,
    pub promoted: usize,
    pub at: Nanos,
    /// Leaderless window: election timeout or the last in-flight batch
    /// arrival, whichever is later.
    pub blackout_ns: Nanos,
    /// Records the promoted replica was behind at the crash — committed
    /// on the dead primary, lost with it.
    pub lag_records: u64,
}

/// What one Merkle anti-entropy pass shipped.
#[derive(Clone, Copy, Debug, Default)]
pub struct RepairReport {
    pub total_leaves: usize,
    pub dirty_leaves: usize,
    /// Subtree hashes exchanged (both directions).
    pub hash_bytes: u64,
    /// Differing-range entries (and delete keys) shipped.
    pub entry_bytes: u64,
    pub entries_shipped: u64,
    pub keys_deleted: u64,
    /// Every live primary entry — the full-resync alternative.
    pub full_resync_bytes: u64,
    /// Virtual time the repair completed.
    pub done: Nanos,
}

// ---------------------------------------------------------------------
// Nodes
// ---------------------------------------------------------------------

struct Node {
    /// `None` while crashed (awaiting rejoin).
    engine: Option<Box<dyn KvEngine>>,
    /// `None` for node 0, which lives on the caller's environment.
    env: Option<SimEnv>,
    /// Log prefix applied on this node.
    applied: usize,
    /// Log prefix already scheduled for shipping to this node.
    sent: usize,
    /// When this node's serialized link is free.
    link_free: Nanos,
    /// When this node finished its last apply (replica clock frontier).
    apply_free: Nanos,
    /// Batches awaiting their `ReplShip` event: `(from, upto)` log ranges.
    pending_ship: VecDeque<(usize, usize)>,
    /// Batches on the wire awaiting `ReplDeliver`.
    pending_deliver: VecDeque<(usize, usize)>,
    applied_seq: Seq,
    max_lag: u64,
    lag_sum: u128,
    lag_samples: u64,
}

/// Split a node into its engine and the environment it runs on (its own,
/// or the caller's for node 0).
fn node_parts<'a>(
    node: &'a mut Node,
    ext: &'a mut SimEnv,
) -> (&'a mut dyn KvEngine, &'a mut SimEnv) {
    let engine = node.engine.as_deref_mut().expect("node is down");
    let env = match &mut node.env {
        Some(e) => e,
        None => ext,
    };
    (engine, env)
}

// ---------------------------------------------------------------------
// The replicated store
// ---------------------------------------------------------------------

pub struct ReplicatedDb {
    nodes: Vec<Node>,
    primary: usize,
    /// The CDC log: every record captured from any primary, in capture
    /// order. Replica progress is an index into this log.
    log: Vec<CdcRecord>,
    /// Per-stream capture watermark (highest seq captured per stream).
    capture_wm: Vec<Seq>,
    /// Private event queue for link traffic (`ReplShip`/`ReplDeliver`,
    /// actor = destination node), pumped around every operation.
    q: EventQueue,
    cfg: ReplConfig,
    /// Round-robin cursor for replica read routing.
    rr_next: usize,
    /// Session watermark for read-your-writes: the log index every
    /// serving replica must have applied.
    ryw_floor: usize,
    /// Ops issued before this instant stall to it (failover blackout).
    blackout_until: Nanos,
    /// Crashed node's durable image, held for rejoin.
    old_image: Option<(usize, DurableImage)>,
    shipped_records: u64,
    shipped_bytes: u64,
    stale_reads: u64,
    replica_reads: u64,
    primary_reads: u64,
    failovers: u64,
    blackout_ns: Nanos,
    lost_records: u64,
    anti_entropy_bytes: u64,
    full_resync_bytes: u64,
}

impl ReplicatedDb {
    /// Build an N-node replicated store; `make(i)` constructs node `i`'s
    /// engine (all nodes must be the same kind and configuration — the
    /// replicas re-derive routing from it). Node 0 is the initial
    /// primary and runs on the caller's `SimEnv`; every other node gets
    /// its own deterministically-seeded environment.
    pub fn new(
        cfg: ReplConfig,
        mut make: impl FnMut(usize) -> Box<dyn KvEngine>,
    ) -> Self {
        assert!(cfg.replicas >= 2, "replication needs at least 2 nodes");
        let nodes: Vec<Node> = (0..cfg.replicas)
            .map(|i| Node {
                engine: Some(make(i)),
                env: (i > 0).then(|| {
                    SimEnv::new(
                        cfg.seed
                            ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                        SsdConfig::default(),
                    )
                }),
                applied: 0,
                sent: 0,
                link_free: 0,
                apply_free: 0,
                pending_ship: VecDeque::new(),
                pending_deliver: VecDeque::new(),
                applied_seq: 0,
                max_lag: 0,
                lag_sum: 0,
                lag_samples: 0,
            })
            .collect();
        let streams = nodes[0].engine.as_ref().unwrap().cdc_streams();
        Self {
            nodes,
            primary: 0,
            log: Vec::new(),
            capture_wm: vec![0; streams],
            q: EventQueue::new(),
            cfg,
            rr_next: 0,
            ryw_floor: 0,
            blackout_until: 0,
            old_image: None,
            shipped_records: 0,
            shipped_bytes: 0,
            stale_reads: 0,
            replica_reads: 0,
            primary_reads: 0,
            failovers: 0,
            blackout_ns: 0,
            lost_records: 0,
            anti_entropy_bytes: 0,
            full_resync_bytes: 0,
        }
    }

    pub fn primary_index(&self) -> usize {
        self.primary
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_live(&self, node: usize) -> bool {
        self.nodes[node].engine.is_some()
    }

    /// Log records applied on `node` (the primary trivially holds all).
    pub fn applied_records(&self, node: usize) -> usize {
        if node == self.primary {
            self.log.len()
        } else {
            self.nodes[node].applied
        }
    }

    /// Records captured from primaries so far.
    pub fn log_len(&self) -> usize {
        self.log.len()
    }

    fn primary_engine(&self) -> &dyn KvEngine {
        self.nodes[self.primary]
            .engine
            .as_deref()
            .expect("primary is down")
    }

    fn transit_ns(&self, bytes: u64) -> Nanos {
        (bytes as f64 * 1e9 / (self.cfg.link_mbps.max(1e-6) * 1024.0 * 1024.0))
            as Nanos
    }

    fn gate(&self, at: Nanos) -> Nanos {
        at.max(self.blackout_until)
    }

    // -----------------------------------------------------------------
    // CDC capture and link events
    // -----------------------------------------------------------------

    /// Capture everything the primary committed past the watermark
    /// (synchronous, zero virtual cost) and schedule a ship batch to
    /// every live replica.
    fn capture(&mut self, ext: &SimEnv, at: Nanos) {
        let p = self.primary;
        let recs = {
            let node = &self.nodes[p];
            let Some(engine) = node.engine.as_deref() else { return };
            let env: &SimEnv = node.env.as_ref().unwrap_or(ext);
            engine.cdc_tail(env, &self.capture_wm)
        };
        if !recs.is_empty() {
            for r in &recs {
                self.capture_wm[r.stream] =
                    self.capture_wm[r.stream].max(r.entry.seq);
            }
            self.log.extend(recs);
            for i in 0..self.nodes.len() {
                if i == p || self.nodes[i].engine.is_none() {
                    continue;
                }
                if self.nodes[i].sent < self.log.len() {
                    self.nodes[i]
                        .pending_ship
                        .push_back((self.nodes[i].sent, self.log.len()));
                    self.nodes[i].sent = self.log.len();
                    self.q.push(at, i as ActorId, EventKind::ReplShip);
                }
            }
        }
        self.sample_lag();
    }

    fn sample_lag(&mut self) {
        let len = self.log.len();
        let p = self.primary;
        for (i, node) in self.nodes.iter_mut().enumerate() {
            if i == p || node.engine.is_none() {
                continue;
            }
            let lag = (len - node.applied.min(len)) as u64;
            node.max_lag = node.max_lag.max(lag);
            node.lag_sum += lag as u128;
            node.lag_samples += 1;
        }
    }

    /// Run every link event due at or before `now`.
    fn pump(&mut self, ext: &mut SimEnv, now: Nanos) {
        while self.q.peek_time().is_some_and(|t| t <= now) {
            let ev = self.q.pop().unwrap();
            self.handle(ext, ev);
        }
    }

    /// Run the queue dry (end-of-run settling); returns the time the
    /// last apply finished.
    fn drain(&mut self, ext: &mut SimEnv) -> Nanos {
        while let Some(ev) = self.q.pop() {
            self.handle(ext, ev);
        }
        self.nodes.iter().map(|n| n.apply_free).max().unwrap_or(0)
    }

    fn handle(&mut self, ext: &mut SimEnv, ev: Event) {
        match ev.kind {
            EventKind::ReplShip => self.ship(ev.at, ev.actor as usize),
            EventKind::ReplDeliver => {
                self.deliver(ext, ev.at, ev.actor as usize);
            }
            _ => unreachable!("foreign event on the replication queue"),
        }
    }

    /// A batch leaves the shipper: serialize it onto the replica's link
    /// (store-and-forward — the link is busy until delivery).
    fn ship(&mut self, at: Nanos, i: usize) {
        let Some((from, upto)) = self.nodes[i].pending_ship.pop_front() else {
            return;
        };
        let bytes: u64 =
            self.log[from..upto].iter().map(|r| r.wire_bytes()).sum();
        let start = at.max(self.nodes[i].link_free);
        let arrive = start + self.cfg.link_latency + self.transit_ns(bytes);
        self.nodes[i].link_free = arrive;
        self.nodes[i].pending_deliver.push_back((from, upto));
        self.shipped_records += (upto - from) as u64;
        self.shipped_bytes += bytes;
        self.q.push(arrive, i as ActorId, EventKind::ReplDeliver);
    }

    /// A batch finished crossing the link: apply it on the replica's own
    /// environment, preserving primary sequence numbers.
    fn deliver(&mut self, ext: &mut SimEnv, at: Nanos, i: usize) -> Nanos {
        let Some((from, upto)) = self.nodes[i].pending_deliver.pop_front()
        else {
            return at;
        };
        let recs: Vec<CdcRecord> = self.log[from..upto].to_vec();
        let mut t = at.max(self.nodes[i].apply_free);
        {
            let (engine, env) = node_parts(&mut self.nodes[i], ext);
            for rec in &recs {
                t = engine.repl_apply(env, t, rec).done;
            }
        }
        let node = &mut self.nodes[i];
        node.applied = node.applied.max(upto);
        node.apply_free = node.apply_free.max(t);
        for rec in &recs {
            node.applied_seq = node.applied_seq.max(rec.entry.seq);
        }
        t
    }

    // -----------------------------------------------------------------
    // Read routing
    // -----------------------------------------------------------------

    /// Pick the node to serve a read: `None` = the primary.
    fn route_read(&mut self) -> Option<usize> {
        if self.cfg.read_policy == ReadPolicy::Primary {
            return None;
        }
        let cands: Vec<usize> = (0..self.nodes.len())
            .filter(|&i| i != self.primary && self.nodes[i].engine.is_some())
            .collect();
        if cands.is_empty() {
            return None;
        }
        let pick = cands[self.rr_next % cands.len()];
        self.rr_next += 1;
        if self.cfg.read_policy == ReadPolicy::ReadYourWrites
            && self.nodes[pick].applied < self.ryw_floor
        {
            // another caught-up replica may serve; otherwise the primary
            return cands
                .into_iter()
                .find(|&c| self.nodes[c].applied >= self.ryw_floor);
        }
        Some(pick)
    }

    // -----------------------------------------------------------------
    // Failover
    // -----------------------------------------------------------------

    /// Crash the current primary at `at` and promote the most-caught-up
    /// live replica. Batches already on the wire still arrive (and count
    /// toward the blackout); batches buffered in the dead shipper are
    /// lost. The log truncates to the promoted node's applied prefix —
    /// committed records past it are the asynchronous-replication loss
    /// window — and the shipper re-points at the promoted node's WAL.
    /// The crashed node's durable image is kept for `rejoin_crashed`.
    pub fn fail_primary(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
    ) -> FailoverReport {
        let at = self.gate(at);
        self.pump(env, at);
        let old = self.primary;
        assert!(
            self.old_image.is_none(),
            "previous crashed node has not rejoined"
        );
        // drain the wire: deliveries land at their scheduled arrival,
        // un-popped ship batches die with the primary
        let mut last_arrival = at;
        while let Some(ev) = self.q.pop() {
            let i = ev.actor as usize;
            match ev.kind {
                EventKind::ReplShip => {
                    self.nodes[i].pending_ship.pop_front();
                }
                EventKind::ReplDeliver => {
                    let done = self.deliver(env, ev.at.max(at), i);
                    last_arrival = last_arrival.max(done);
                }
                _ => unreachable!("foreign event on the replication queue"),
            }
        }
        for node in &mut self.nodes {
            node.pending_ship.clear();
            node.sent = node.applied;
        }
        let promoted = (0..self.nodes.len())
            .filter(|&i| i != old && self.nodes[i].engine.is_some())
            .max_by_key(|&i| (self.nodes[i].applied, std::cmp::Reverse(i)))
            .expect("failover requires at least one live replica");
        // power-loss the old primary on its own environment; the image
        // (and its device state) waits for rejoin
        let engine = self.nodes[old].engine.take().expect("primary engine");
        let image = {
            let node = &mut self.nodes[old];
            let nenv = match &mut node.env {
                Some(e) => e,
                None => env,
            };
            engine.crash(nenv, at)
        };
        self.old_image = Some((old, image));
        let lag_records =
            (self.log.len() - self.nodes[promoted].applied) as u64;
        self.log.truncate(self.nodes[promoted].applied);
        // re-point the shipper: watermarks restart from the promoted
        // node's history (its WAL holds the applied records with their
        // original seqs, so tailing resumes seamlessly)
        let mut wm = vec![0; self.capture_wm.len()];
        for r in &self.log {
            wm[r.stream] = wm[r.stream].max(r.entry.seq);
        }
        self.capture_wm = wm;
        self.primary = promoted;
        let blackout_until =
            (at + self.cfg.election_timeout).max(last_arrival);
        self.blackout_until = self.blackout_until.max(blackout_until);
        // survivors behind the promoted node catch up from its history
        for i in 0..self.nodes.len() {
            if i == promoted || self.nodes[i].engine.is_none() {
                continue;
            }
            let node = &mut self.nodes[i];
            node.applied = node.applied.min(self.log.len());
            node.sent = node.applied;
            if node.sent < self.log.len() {
                node.pending_ship.push_back((node.sent, self.log.len()));
                node.sent = self.log.len();
                self.q.push(blackout_until, i as ActorId, EventKind::ReplShip);
            }
        }
        self.failovers += 1;
        self.blackout_ns += blackout_until - at;
        self.lost_records += lag_records;
        FailoverReport {
            crashed: old,
            promoted,
            at,
            blackout_ns: blackout_until - at,
            lag_records,
        }
    }

    // -----------------------------------------------------------------
    // Anti-entropy rejoin
    // -----------------------------------------------------------------

    /// Bring the crashed ex-primary back: recover it from its durable
    /// image through the regular open path, then repair its divergence
    /// against the current primary with a Merkle range exchange. After
    /// repair the node mirrors the primary and resumes tailing the CDC
    /// stream as an ordinary replica.
    pub fn rejoin_crashed(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
    ) -> Result<RepairReport> {
        let at = self.gate(at);
        self.pump(env, at);
        let (idx, image) = self
            .old_image
            .take()
            .ok_or_else(|| anyhow::anyhow!("no crashed node to rejoin"))?;
        let (engine, t_rec) = {
            let node = &mut self.nodes[idx];
            let nenv = match &mut node.env {
                Some(e) => e,
                None => &mut *env,
            };
            EngineBuilder::open(nenv, at, image)?
        };
        self.nodes[idx].engine = Some(engine);
        let report = self.anti_entropy(env, t_rec, idx);
        let len = self.log.len();
        let top_seq = self.capture_wm.iter().copied().max().unwrap_or(0);
        let node = &mut self.nodes[idx];
        node.applied = len;
        node.sent = len;
        node.apply_free = node.apply_free.max(report.done);
        node.link_free = node.link_free.max(report.done);
        node.applied_seq = node.applied_seq.max(top_seq);
        self.anti_entropy_bytes += report.hash_bytes + report.entry_bytes;
        self.full_resync_bytes += report.full_resync_bytes;
        Ok(report)
    }

    /// Merkle exchange + range repair of node `idx` against the primary.
    fn anti_entropy(
        &mut self,
        ext: &mut SimEnv,
        at: Nanos,
        idx: usize,
    ) -> RepairReport {
        let leaves = self.cfg.merkle_leaves;
        let fanout = self.cfg.merkle_fanout;
        let ks = self.cfg.key_space;
        let latency = self.cfg.link_latency;
        let p = self.primary;
        let (ptree, _) = {
            let (engine, env) = node_parts(&mut self.nodes[p], ext);
            MerkleTree::build(engine, env, at, leaves, fanout, ks)
        };
        let (rtree, t0) = {
            let (engine, env) = node_parts(&mut self.nodes[idx], ext);
            MerkleTree::build(engine, env, at, leaves, fanout, ks)
        };
        let (dirty, hash_bytes) = ptree.diff(&rtree);
        let mut t = t0;
        let mut entry_bytes = 0u64;
        let mut entries_shipped = 0u64;
        let mut keys_deleted = 0u64;
        for &leaf in &dirty {
            let want = &ptree.leaf_entries[leaf];
            let have = &rtree.leaf_entries[leaf];
            let want_keys: BTreeMap<Key, ValueDesc> =
                want.iter().map(|e| (e.key, e.val)).collect();
            let have_keys: BTreeMap<Key, ValueDesc> =
                have.iter().map(|e| (e.key, e.val)).collect();
            // only the difference crosses the wire: changed/missing
            // entries, plus a key list for deletions
            let to_ship: Vec<Entry> = want
                .iter()
                .filter(|e| have_keys.get(&e.key) != Some(&e.val))
                .copied()
                .collect();
            let to_delete: Vec<Key> = have
                .iter()
                .filter(|e| !want_keys.contains_key(&e.key))
                .map(|e| e.key)
                .collect();
            let bytes = to_ship.iter().map(|e| e.encoded_len()).sum::<u64>()
                + 8 * to_delete.len() as u64;
            entry_bytes += bytes;
            let link_free = self.nodes[idx].link_free;
            t = t.max(link_free) + latency + self.transit_ns(bytes);
            let (engine, env) = node_parts(&mut self.nodes[idx], ext);
            for &k in &to_delete {
                t = engine.delete(env, t, k).done;
                keys_deleted += 1;
            }
            for e in &to_ship {
                t = engine.put(env, t, e.key, e.val).done;
                entries_shipped += 1;
            }
        }
        self.nodes[idx].link_free = self.nodes[idx].link_free.max(t);
        RepairReport {
            total_leaves: leaves,
            dirty_leaves: dirty.len(),
            hash_bytes,
            entry_bytes,
            entries_shipped,
            keys_deleted,
            full_resync_bytes: ptree.full_bytes(),
            done: t,
        }
    }

    /// Merkle root of one node's live data (divergence checks in tests
    /// and examples; charges a real scan on the node's environment).
    pub fn node_digest(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        idx: usize,
    ) -> u64 {
        let leaves = self.cfg.merkle_leaves;
        let fanout = self.cfg.merkle_fanout;
        let ks = self.cfg.key_space;
        let (engine, nenv) = node_parts(&mut self.nodes[idx], env);
        MerkleTree::build(engine, nenv, at, leaves, fanout, ks).0.root()
    }

    /// Point-lookup on one specific node (tests: compare a replica's
    /// view against the primary's without going through read routing).
    pub fn node_get(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        idx: usize,
        key: Key,
    ) -> (Option<ValueDesc>, Nanos) {
        let (engine, nenv) = node_parts(&mut self.nodes[idx], env);
        engine.get(nenv, at, key)
    }

    // -----------------------------------------------------------------
    // Reporting
    // -----------------------------------------------------------------

    pub fn results(&self) -> ReplResult {
        let replicas = self
            .nodes
            .iter()
            .enumerate()
            .map(|(i, n)| {
                let role = if n.engine.is_none() {
                    "down"
                } else if i == self.primary {
                    "primary"
                } else {
                    "replica"
                };
                ReplicaResult {
                    node: i,
                    role: role.into(),
                    applied_records: self.applied_records(i) as u64,
                    applied_seq: if i == self.primary {
                        self.capture_wm.iter().copied().max().unwrap_or(0)
                    } else {
                        n.applied_seq
                    },
                    max_lag: n.max_lag,
                    mean_lag: if n.lag_samples == 0 {
                        0.0
                    } else {
                        n.lag_sum as f64 / n.lag_samples as f64
                    },
                }
            })
            .collect();
        ReplResult {
            replicas,
            primary: self.primary,
            read_policy: self.cfg.read_policy.label().into(),
            captured_records: self.log.len() as u64,
            shipped_records: self.shipped_records,
            shipped_bytes: self.shipped_bytes,
            stale_reads: self.stale_reads,
            replica_reads: self.replica_reads,
            primary_reads: self.primary_reads,
            failovers: self.failovers,
            blackout_ns: self.blackout_ns,
            lost_records: self.lost_records,
            anti_entropy_bytes: self.anti_entropy_bytes,
            full_resync_bytes: self.full_resync_bytes,
        }
    }
}

// ---------------------------------------------------------------------
// EngineStats: delegate to the current primary
// ---------------------------------------------------------------------

impl EngineStats for ReplicatedDb {
    fn main_db(&self) -> &LsmDb {
        self.primary_engine().main_db()
    }

    fn kvaccel(&self) -> Option<&crate::kvaccel::KvaccelDb> {
        self.primary_engine().kvaccel()
    }

    fn sharded(&self) -> Option<&crate::shard::ShardedDb> {
        self.primary_engine().sharded()
    }

    fn replicated(&self) -> Option<&ReplicatedDb> {
        Some(self)
    }

    fn stall_stats(&self) -> &StallStats {
        self.primary_engine().stall_stats()
    }

    fn db_stats(&self) -> &DbStats {
        self.primary_engine().db_stats()
    }

    fn redirected_writes(&self) -> u64 {
        self.primary_engine().redirected_writes()
    }

    fn rollbacks(&self) -> u64 {
        self.primary_engine().rollbacks()
    }

    fn scan_amp(&self) -> ScanAmp {
        self.primary_engine().scan_amp()
    }

    fn cache_stats(&self) -> crate::engine::CacheStats {
        self.primary_engine().cache_stats()
    }

    fn health(&self) -> EngineHealth {
        self.primary_engine().health()
    }
}

// ---------------------------------------------------------------------
// KvEngine: primary writes, policy-routed reads
// ---------------------------------------------------------------------

impl KvEngine for ReplicatedDb {
    fn put(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        key: Key,
        val: ValueDesc,
    ) -> PutResult {
        let at = self.gate(at);
        self.pump(env, at);
        let p = self.primary;
        let r = {
            let (engine, penv) = node_parts(&mut self.nodes[p], env);
            engine.put(penv, at, key, val)
        };
        env.clock.advance_to(r.done);
        self.capture(env, r.done);
        self.ryw_floor = self.ryw_floor.max(self.log.len());
        r
    }

    fn delete(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> PutResult {
        let at = self.gate(at);
        self.pump(env, at);
        let p = self.primary;
        let r = {
            let (engine, penv) = node_parts(&mut self.nodes[p], env);
            engine.delete(penv, at, key)
        };
        env.clock.advance_to(r.done);
        self.capture(env, r.done);
        self.ryw_floor = self.ryw_floor.max(self.log.len());
        r
    }

    fn get(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        key: Key,
    ) -> (Option<ValueDesc>, Nanos) {
        let at = self.gate(at);
        self.pump(env, at);
        match self.route_read() {
            None => {
                self.primary_reads += 1;
                if self.cfg.read_policy == ReadPolicy::ReadYourWrites {
                    self.ryw_floor = self.ryw_floor.max(self.log.len());
                }
                let p = self.primary;
                let (engine, penv) = node_parts(&mut self.nodes[p], env);
                let (v, done) = engine.get(penv, at, key);
                env.clock.advance_to(done);
                (v, done)
            }
            Some(i) => {
                self.replica_reads += 1;
                if self.nodes[i].applied < self.log.len() {
                    self.stale_reads += 1;
                }
                if self.cfg.read_policy == ReadPolicy::ReadYourWrites {
                    // monotonic session: never serve below what we saw
                    self.ryw_floor = self.ryw_floor.max(self.nodes[i].applied);
                }
                let lat = self.cfg.link_latency;
                let t0 = (at + lat).max(self.nodes[i].apply_free);
                let (v, done_r) = {
                    let (engine, renv) = node_parts(&mut self.nodes[i], env);
                    engine.get(renv, t0, key)
                };
                self.nodes[i].apply_free =
                    self.nodes[i].apply_free.max(done_r);
                let done = done_r + lat;
                env.clock.advance_to(done);
                (v, done)
            }
        }
    }

    fn write_batch(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        batch: &WriteBatch,
    ) -> BatchResult {
        let at = self.gate(at);
        self.pump(env, at);
        let p = self.primary;
        let r = {
            let (engine, penv) = node_parts(&mut self.nodes[p], env);
            engine.write_batch(penv, at, batch)
        };
        env.clock.advance_to(r.done);
        self.capture(env, r.done);
        self.ryw_floor = self.ryw_floor.max(self.log.len());
        r
    }

    fn snapshot(&mut self, env: &mut SimEnv, at: Nanos) -> Snapshot {
        let at = self.gate(at);
        self.pump(env, at);
        let p = self.primary;
        let (engine, penv) = node_parts(&mut self.nodes[p], env);
        engine.snapshot(penv, at)
    }

    fn iter(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        opts: IterOptions,
    ) -> Box<dyn DbIterator> {
        let at = self.gate(at);
        self.pump(env, at);
        let p = self.primary;
        let (engine, penv) = node_parts(&mut self.nodes[p], env);
        engine.iter(penv, at, opts)
    }

    fn tick(&mut self, env: &mut SimEnv, at: Nanos) {
        let at = self.gate(at);
        self.pump(env, at);
        let p = self.primary;
        {
            let (engine, penv) = node_parts(&mut self.nodes[p], env);
            engine.tick(penv, at);
        }
        self.capture(env, at);
    }

    fn kvaccel_mut(&mut self) -> Option<&mut crate::kvaccel::KvaccelDb> {
        self.nodes[self.primary]
            .engine
            .as_deref_mut()
            .and_then(|e| e.kvaccel_mut())
    }

    fn set_block_cache(&mut self, cache: SharedBlockCache) {
        // each replica is an independent node with its own device —
        // only the primary (the engine the caller sees) takes the cache
        if let Some(e) = self.nodes[self.primary].engine.as_deref_mut() {
            e.set_block_cache(cache);
        }
    }

    fn flush(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        let at = self.gate(at);
        self.pump(env, at);
        let p = self.primary;
        let t = {
            let (engine, penv) = node_parts(&mut self.nodes[p], env);
            engine.flush(penv, at)
        };
        env.clock.advance_to(t);
        self.capture(env, t);
        t
    }

    fn finish(&mut self, env: &mut SimEnv, at: Nanos) -> Result<Nanos> {
        let at = self.gate(at);
        self.pump(env, at);
        self.capture(env, at);
        let settled = self.drain(env).max(at);
        let mut t = settled;
        for node in &mut self.nodes {
            if node.engine.is_none() {
                continue;
            }
            let at_i = node.apply_free.max(at);
            let (engine, nenv) = node_parts(node, env);
            t = t.max(engine.finish(nenv, at_i)?);
        }
        env.clock.advance_to(t);
        Ok(t)
    }

    fn close(
        mut self: Box<Self>,
        env: &mut SimEnv,
        at: Nanos,
    ) -> Result<DurableImage> {
        let at = self.gate(at);
        self.pump(env, at);
        self.capture(env, at);
        let _ = self.drain(env);
        let p = self.primary;
        let engine = self.nodes[p].engine.take().expect("primary engine");
        let nenv = match &mut self.nodes[p].env {
            Some(e) => e,
            None => env,
        };
        engine.close(nenv, at)
    }

    fn crash(mut self: Box<Self>, env: &mut SimEnv, at: Nanos) -> DurableImage {
        let p = self.primary;
        let engine = self.nodes[p].engine.take().expect("primary engine");
        let nenv = match &mut self.nodes[p].env {
            Some(e) => e,
            None => env,
        };
        engine.crash(nenv, at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::SystemKind;
    use crate::lsm::LsmOptions;

    fn make_repl(n: usize, policy: ReadPolicy) -> (ReplicatedDb, SimEnv) {
        let cfg = ReplConfig {
            replicas: n,
            read_policy: policy,
            key_space: 10_000,
            ..ReplConfig::default()
        };
        let db = ReplicatedDb::new(cfg, |_| {
            EngineBuilder::new(SystemKind::RocksDb { slowdown: true })
                .opts(LsmOptions::small_for_test())
                .build()
        });
        (db, SimEnv::new(7, SsdConfig::default()))
    }

    #[test]
    fn replicas_converge_after_drain() {
        let (mut db, mut env) = make_repl(3, ReadPolicy::Primary);
        let mut t = 0;
        for k in 0..500u32 {
            t = db.put(&mut env, t, k % 200, ValueDesc::new(k, 512)).done;
        }
        let end = db.finish(&mut env, t).unwrap();
        assert_eq!(db.log_len(), 500);
        for i in 1..3 {
            assert_eq!(db.applied_records(i), 500, "replica {i} lagging");
        }
        let d0 = db.node_digest(&mut env, end, 0);
        let d1 = db.node_digest(&mut env, end, 1);
        let d2 = db.node_digest(&mut env, end, 2);
        assert_eq!(d0, d1);
        assert_eq!(d0, d2);
    }

    #[test]
    fn read_your_writes_sees_own_puts() {
        let (mut db, mut env) = make_repl(2, ReadPolicy::ReadYourWrites);
        let mut t = 0;
        for k in 0..100u32 {
            t = db.put(&mut env, t, k, ValueDesc::new(k, 256)).done;
            // immediately read back: the replica cannot have applied the
            // write yet (the link has latency), so RYW must fall back
            let (got, done) = db.get(&mut env, t, k);
            assert_eq!(got, Some(ValueDesc::new(k, 256)), "lost own write {k}");
            t = done;
        }
        let r = db.results();
        assert_eq!(r.stale_reads, 0, "RYW never serves stale");
    }

    #[test]
    fn eventual_reads_route_to_replicas() {
        let (mut db, mut env) = make_repl(3, ReadPolicy::Eventual);
        let mut t = 0;
        for k in 0..200u32 {
            t = db.put(&mut env, t, k, ValueDesc::new(k, 256)).done;
        }
        for k in 0..50u32 {
            let (_, done) = db.get(&mut env, t, k);
            t = done;
        }
        let r = db.results();
        assert_eq!(r.replica_reads, 50, "eventual routes every read");
        assert_eq!(r.primary_reads, 0);
    }

    #[test]
    fn failover_promotes_and_recovers_writes() {
        let (mut db, mut env) = make_repl(3, ReadPolicy::Primary);
        let mut t = 0;
        for k in 0..300u32 {
            t = db.put(&mut env, t, k, ValueDesc::new(k, 512)).done;
        }
        let fo = db.fail_primary(&mut env, t);
        assert_eq!(fo.crashed, 0);
        assert!(fo.promoted == 1 || fo.promoted == 2);
        assert!(!db.is_live(0));
        // the store keeps serving through the promoted node
        t = t.max(fo.at + fo.blackout_ns);
        for k in 300..400u32 {
            t = db.put(&mut env, t, k, ValueDesc::new(k, 512)).done;
        }
        let (got, done) = db.get(&mut env, t, 350);
        assert_eq!(got, Some(ValueDesc::new(350, 512)));
        t = done;
        // rejoin the crashed node and verify zero divergence
        let rep = db.rejoin_crashed(&mut env, t).expect("rejoin failed");
        assert!(db.is_live(0));
        assert!(
            rep.hash_bytes + rep.entry_bytes < rep.full_resync_bytes,
            "anti-entropy ({} B) must beat a full resync ({} B)",
            rep.hash_bytes + rep.entry_bytes,
            rep.full_resync_bytes
        );
        let end = db.finish(&mut env, rep.done).unwrap();
        let dp = db.node_digest(&mut env, end, db.primary_index());
        let d0 = db.node_digest(&mut env, end, 0);
        assert_eq!(dp, d0, "rejoined node still diverged after repair");
    }
}
