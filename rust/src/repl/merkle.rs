//! Merkle-tree anti-entropy: fixed-fanout hash trees over key ranges.
//!
//! A diverged replica (e.g. a crashed ex-primary rejoining after
//! failover) is repaired by exchanging subtree hashes with the current
//! primary and shipping only the key ranges whose leaf hashes differ —
//! the paper-adjacent alternative to a full resync. Leaf hashes fold
//! the *values* `(key, seed, len)` of the live entries in the range,
//! never their sequence numbers: two nodes that hold the same data
//! through different write histories (a rollback merge-back re-sequences
//! entries; a replica allocates local seqs during repair) still agree.

use crate::engine::{IterOptions, KvEngine};
use crate::env::SimEnv;
use crate::lsm::entry::{Entry, Key};
use crate::sim::Nanos;

/// Wire size of one exchanged subtree hash (a 256-bit digest in a real
/// system; the simulation folds to 64 bits but charges the full width).
pub const HASH_WIRE_BYTES: u64 = 32;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over the 8 bytes of `word`, little-endian.
fn fnv1a_u64(mut h: u64, word: u64) -> u64 {
    for b in word.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Which leaf owns `key`: the key space hint is split into `leaves`
/// equal ranges (keys past the hint clamp into the last leaf, so a
/// too-small hint degrades to coarser ranges, never to a wrong answer).
pub fn leaf_of(key: Key, leaves: usize, key_space: Key) -> usize {
    let idx = (key as u128 * leaves as u128) / (key_space as u128 + 1);
    (idx as usize).min(leaves - 1)
}

/// A fixed-fanout Merkle tree over one node's live entries, built from
/// a single ascending snapshot scan. Retains the per-leaf entry lists
/// so a diff can ship exactly the differing ranges.
pub struct MerkleTree {
    fanout: usize,
    /// `levels[0]` = leaf hashes, last level = `[root]`.
    levels: Vec<Vec<u64>>,
    /// Live entries per leaf, ascending key order (scan order).
    pub leaf_entries: Vec<Vec<Entry>>,
}

impl MerkleTree {
    /// Scan the engine's live entries at `at` and build the tree.
    /// Returns the tree and the virtual time the scan completed (the
    /// scan charges real cursor costs on `env`).
    pub fn build(
        engine: &mut dyn KvEngine,
        env: &mut SimEnv,
        at: Nanos,
        leaves: usize,
        fanout: usize,
        key_space: Key,
    ) -> (Self, Nanos) {
        let leaves = leaves.max(1);
        let fanout = fanout.max(2);
        let mut leaf_entries: Vec<Vec<Entry>> = vec![Vec::new(); leaves];
        let mut it = engine.iter(env, at, IterOptions::default());
        let mut t = it.seek_to_first(env, at);
        while let Some(e) = it.entry() {
            leaf_entries[leaf_of(e.key, leaves, key_space)].push(e);
            t = it.next(env, t);
        }
        drop(it);
        env.clock.advance_to(t);

        let leaf_hashes: Vec<u64> =
            leaf_entries.iter().map(|es| hash_leaf(es)).collect();
        let mut levels = vec![leaf_hashes];
        // fold upward until a single root remains; `levels` is seeded
        // with the leaf level, and the rejoin path must not panic, so
        // the fold is written without `unwrap`
        loop {
            let parents: Vec<u64> = match levels.last() {
                Some(below) if below.len() > 1 => below
                    .chunks(fanout)
                    .map(|c| {
                        let mut h = FNV_OFFSET;
                        h = fnv1a_u64(h, c.len() as u64);
                        for &child in c {
                            h = fnv1a_u64(h, child);
                        }
                        h
                    })
                    .collect(),
                _ => break,
            };
            levels.push(parents);
        }
        (Self { fanout, levels, leaf_entries }, t)
    }

    /// The root digest. `levels` is never empty (`build` seeds it with
    /// the leaf level); the degenerate case folds to the empty digest
    /// rather than panicking on the rejoin path.
    pub fn root(&self) -> u64 {
        self.levels
            .last()
            .and_then(|l| l.first())
            .copied()
            .unwrap_or(FNV_OFFSET)
    }

    /// Total on-wire size of every live entry — what a full resync from
    /// this node would ship.
    pub fn full_bytes(&self) -> u64 {
        self.leaf_entries
            .iter()
            .flat_map(|es| es.iter())
            .map(|e| e.encoded_len())
            .sum()
    }

    /// Exchange hashes top-down against `other` (same shape required):
    /// compare roots, descend only into differing subtrees, shipping
    /// each visited node's child hashes in both directions. Returns the
    /// differing leaf indices and the hash bytes exchanged.
    pub fn diff(&self, other: &MerkleTree) -> (Vec<usize>, u64) {
        assert_eq!(
            self.levels.len(),
            other.levels.len(),
            "anti-entropy requires identically-shaped trees"
        );
        // both sides send their root
        let mut hash_bytes = 2 * HASH_WIRE_BYTES;
        if self.root() == other.root() {
            return (Vec::new(), hash_bytes);
        }
        // frontier of differing node indices, walking from the root's
        // children down to the leaf level
        let mut frontier = vec![0usize];
        for lvl in (0..self.levels.len() - 1).rev() {
            let mut next = Vec::new();
            for &node in &frontier {
                let lo = node * self.fanout;
                let hi = ((node + 1) * self.fanout).min(self.levels[lvl].len());
                // each side ships this node's children to the other
                hash_bytes += 2 * HASH_WIRE_BYTES * (hi - lo) as u64;
                for child in lo..hi {
                    if self.levels[lvl][child] != other.levels[lvl][child] {
                        next.push(child);
                    }
                }
            }
            frontier = next;
            if frontier.is_empty() {
                break;
            }
        }
        (frontier, hash_bytes)
    }
}

/// Leaf digest: FNV-1a over `(key, value seed, value len)` of each live
/// entry in ascending key order. Sequence numbers are deliberately
/// excluded (see module docs).
fn hash_leaf(entries: &[Entry]) -> u64 {
    let mut h = FNV_OFFSET;
    h = fnv1a_u64(h, entries.len() as u64);
    for e in entries {
        h = fnv1a_u64(h, e.key as u64);
        h = fnv1a_u64(h, e.val.seed as u64);
        h = fnv1a_u64(h, e.val.len as u64);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsm::entry::ValueDesc;

    fn entry(key: Key, seed: u32) -> Entry {
        Entry::new(key, 1, ValueDesc::new(seed, 64))
    }

    #[test]
    fn leaf_of_partitions_the_hinted_space() {
        assert_eq!(leaf_of(0, 8, 799), 0);
        assert_eq!(leaf_of(799, 8, 799), 7);
        // past-the-hint keys clamp into the last leaf
        assert_eq!(leaf_of(5000, 8, 799), 7);
        let mut last = 0;
        for k in 0..800u32 {
            let l = leaf_of(k, 8, 799);
            assert!(l >= last, "leaf map must be monotone");
            last = l;
        }
    }

    #[test]
    fn leaf_hash_ignores_seq() {
        let a = vec![Entry::new(3, 10, ValueDesc::new(7, 64))];
        let b = vec![Entry::new(3, 99, ValueDesc::new(7, 64))];
        assert_eq!(hash_leaf(&a), hash_leaf(&b));
        let c = vec![Entry::new(3, 10, ValueDesc::new(8, 64))];
        assert_ne!(hash_leaf(&a), hash_leaf(&c));
    }

    #[test]
    fn identical_trees_diff_to_nothing() {
        let es: Vec<Entry> = (0..100).map(|k| entry(k, k)).collect();
        let build = |es: &[Entry]| {
            let mut leaf_entries = vec![Vec::new(); 16];
            for e in es {
                leaf_entries[leaf_of(e.key, 16, 99)].push(*e);
            }
            let leaf_hashes: Vec<u64> =
                leaf_entries.iter().map(|l| hash_leaf(l)).collect();
            let mut levels = vec![leaf_hashes];
            while levels.last().unwrap().len() > 1 {
                let below = levels.last().unwrap();
                let parents: Vec<u64> = below
                    .chunks(4)
                    .map(|c| {
                        let mut h = FNV_OFFSET;
                        h = fnv1a_u64(h, c.len() as u64);
                        for &x in c {
                            h = fnv1a_u64(h, x);
                        }
                        h
                    })
                    .collect();
                levels.push(parents);
            }
            MerkleTree { fanout: 4, levels, leaf_entries }
        };
        let t1 = build(&es);
        let t2 = build(&es);
        let (dirty, bytes) = t1.diff(&t2);
        assert!(dirty.is_empty());
        assert_eq!(bytes, 2 * HASH_WIRE_BYTES, "only the roots crossed");

        // one changed value localizes to exactly one leaf
        let mut es2 = es.clone();
        es2[50] = entry(50, 999);
        let t3 = build(&es2);
        let (dirty, bytes) = t1.diff(&t3);
        assert_eq!(dirty, vec![leaf_of(50, 16, 99)]);
        assert!(bytes > 2 * HASH_WIRE_BYTES);
    }
}
