//! Experiment registry: one entry per figure/table of the paper's
//! evaluation (see DESIGN.md §7 for the index). Each experiment prints
//! the rows/series the paper reports and writes CSV into `results/`.
//!
//! Absolute numbers come from the simulator, not the authors' OpenSSD
//! testbed; the *shapes* (who wins, by what factor, where crossovers
//! fall) are the reproduction target — see EXPERIMENTS.md.

pub mod figs;
pub mod kv_sep;
pub mod qos_fairness;
pub mod read_amp;
pub mod recovery;
pub mod repl_lag;
pub mod shard_scale;
pub mod tables;

use std::io::Write;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::baselines::SystemKind;
use crate::engine::{EngineBuilder, KvEngine};
use crate::env::SimEnv;
use crate::kvaccel::RollbackScheme;
use crate::lsm::LsmOptions;
use crate::runtime::{default_artifacts_dir, BloomBuilder, MergeEngine, XlaRuntime};
use crate::ssd::SsdConfig;
use crate::workload::{BenchConfig, RunResult};

/// Which merge/bloom engine the systems run on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineMode {
    /// AOT XLA artifacts via PJRT (the paper-analog offload; default for
    /// the end-to-end example).
    Xla,
    /// Pure-Rust fallback (fast sweeps; bit-identical results).
    Rust,
}

pub struct ExpContext {
    /// 1.0 = the paper's full 600 s runs.
    pub scale: f64,
    pub seed: u64,
    pub out_dir: PathBuf,
    pub engine: EngineMode,
    runtime: Option<Arc<XlaRuntime>>,
    pub quiet: bool,
}

impl ExpContext {
    pub fn new(scale: f64, seed: u64, engine: EngineMode) -> Result<Self> {
        let runtime = match engine {
            EngineMode::Rust => None,
            EngineMode::Xla => Some(Arc::new(
                XlaRuntime::load(default_artifacts_dir())
                    .context("loading AOT artifacts (run `make artifacts`)")?,
            )),
        };
        Ok(Self {
            scale,
            seed,
            out_dir: PathBuf::from("results"),
            engine,
            runtime,
            quiet: false,
        })
    }

    pub fn merge_engine(&self) -> MergeEngine {
        match &self.runtime {
            Some(rt) => MergeEngine::xla(rt.clone()).expect("runtime has merge artifacts"),
            None => MergeEngine::rust(),
        }
    }

    pub fn bloom_builder(&self) -> BloomBuilder {
        match &self.runtime {
            Some(rt) => BloomBuilder::xla(rt.clone()),
            None => BloomBuilder::rust(),
        }
    }

    pub fn bench_config(&self) -> BenchConfig {
        BenchConfig { seed: self.seed, ..Default::default() }.scaled(self.scale)
    }

    /// Build one evaluated system behind the unified engine interface.
    pub fn build_system(
        &self,
        kind: SystemKind,
        threads: usize,
    ) -> (Box<dyn KvEngine>, SimEnv) {
        let opts = LsmOptions::default().with_threads(threads);
        (
            EngineBuilder::new(kind)
                .opts(opts)
                .merge_engine(self.merge_engine())
                .bloom_builder(self.bloom_builder())
                .build(),
            SimEnv::new(self.seed, SsdConfig::default()),
        )
    }

    /// Run workload A (fillrandom) on a fresh system.
    pub fn run_fillrandom(&self, kind: SystemKind, threads: usize) -> RunResult {
        let (mut sys, mut env) = self.build_system(kind, threads);
        let cfg = self.bench_config();
        let mut r = crate::workload::fillrandom(&mut *sys, &mut env, &cfg);
        r.system = kind.label();
        r
    }

    /// Run workload B/C (readwhilewriting) on a fresh system.
    pub fn run_rww(
        &self,
        kind: SystemKind,
        threads: usize,
        ratio: (u64, u64),
    ) -> RunResult {
        let (mut sys, mut env) = self.build_system(kind, threads);
        let cfg = self.bench_config();
        let mut r =
            crate::workload::readwhilewriting(&mut *sys, &mut env, &cfg, ratio.0, ratio.1);
        r.system = kind.label();
        r
    }

    /// Run an arbitrary multi-client [`WorkloadSpec`] on a fresh system.
    pub fn run_workload(
        &self,
        kind: SystemKind,
        threads: usize,
        spec: &crate::workload::WorkloadSpec,
    ) -> RunResult {
        let (mut sys, mut env) = self.build_system(kind, threads);
        let mut r = crate::workload::run_spec(&mut *sys, &mut env, spec);
        r.system = kind.label();
        r
    }

    pub fn log(&self, msg: impl AsRef<str>) {
        if !self.quiet {
            println!("{}", msg.as_ref());
        }
    }

    /// Write a CSV into out_dir.
    pub fn write_csv(&self, name: &str, header: &str, rows: &[String]) -> Result<PathBuf> {
        std::fs::create_dir_all(&self.out_dir)?;
        let path = self.out_dir.join(name);
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{header}")?;
        for r in rows {
            writeln!(f, "{r}")?;
        }
        Ok(path)
    }
}

/// Standard system set for the headline comparisons.
pub fn headline_systems() -> Vec<SystemKind> {
    vec![
        SystemKind::RocksDb { slowdown: true },
        SystemKind::Adoc,
        SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
    ]
}

/// Run one experiment by id. Returns a human summary.
pub fn run(ctx: &ExpContext, id: &str) -> Result<String> {
    match id {
        "fig2" => figs::fig2(ctx),
        "fig3" => figs::fig3(ctx),
        "fig4" => figs::fig4(ctx),
        "fig5" => figs::fig5(ctx),
        "fig11" => figs::fig11(ctx),
        "fig12" => figs::fig12(ctx),
        "fig13" => figs::fig13(ctx),
        "fig14" => figs::fig14(ctx),
        "kv-sep" => kv_sep::kv_sep(ctx),
        "qdelay" => figs::qdelay(ctx),
        "qos-fairness" => qos_fairness::qos_fairness(ctx),
        "read-amp" => read_amp::read_amp(ctx),
        "recovery" => recovery::recovery(ctx),
        "repl-lag" => repl_lag::repl_lag(ctx),
        "shard-scale" => shard_scale::shard_scale(ctx),
        "table5" => tables::table5(ctx),
        "table6" => tables::table6(ctx),
        "all" => {
            let mut out = String::new();
            for id in ALL_EXPERIMENTS {
                out.push_str(&run(ctx, id)?);
                out.push('\n');
            }
            Ok(out)
        }
        other => Err(anyhow!(
            "unknown experiment {other:?}; known: {ALL_EXPERIMENTS:?} or 'all'"
        )),
    }
}

pub const ALL_EXPERIMENTS: [&str; 17] = [
    "fig2", "fig3", "fig4", "fig5", "fig11", "fig12", "fig13", "fig14",
    "kv-sep", "qdelay", "qos-fairness", "read-amp", "recovery",
    "repl-lag", "shard-scale", "table5", "table6",
];
