//! PR10 key-value separation experiment: the WiscKey-style value log
//! measured end to end on an overwrite-heavy fill.
//!
//! Sweep: value size {512 B, 4 KiB, 64 KiB} x vlog {off, on} x the
//! headline systems, each running workload A (closed-loop fillrandom)
//! over a deliberately small key space so overwrites pile up dead
//! bytes and the background GC has real work. Separation uses a 1 KiB
//! threshold — the 512 B point stays inline on purpose, showing that
//! small values never pay the indirection.
//!
//! Reported per config: write throughput, p99 put latency, flushed and
//! compaction-written bytes, total write amplification, and the value
//! log's own counters (appends, GC runs, reclaimed bytes, residual
//! dead-space ratio). Emits `results/kv_sep.csv` and the
//! machine-readable `results/BENCH_PR10.json` built in CI; the
//! headline shape is that for large values the vlog-on runs compact
//! far fewer bytes (pointers move, payloads don't) while GC keeps the
//! log's dead-space ratio bounded below 1.
use anyhow::Result;

use crate::engine::{EngineBuilder, EngineStats};
use crate::env::SimEnv;
use crate::lsm::LsmOptions;
use crate::ssd::SsdConfig;
use crate::workload::{self, BenchConfig, KeyDist, LoopMode};

use super::{headline_systems, ExpContext};

struct Row {
    system: String,
    value_size: u32,
    vlog: &'static str,
    write_kops: f64,
    put_p99_us: f64,
    bytes_flushed: u64,
    bytes_compacted_written: u64,
    write_amp: f64,
    vlog_appends: u64,
    gc_runs: u64,
    gc_reclaimed_bytes: u64,
    vlog_total_bytes: u64,
    vlog_dead_ratio: f64,
}

const CLIENTS: usize = 4;
/// Values at or past this size separate into the log; 512 B stays
/// inline, demonstrating the threshold.
const THRESHOLD: u32 = 1024;
/// Small segments so smoke-scale runs still seal several and GC fires.
const SEGMENT_BYTES: u64 = 1 << 20;

pub fn kv_sep(ctx: &ExpContext) -> Result<String> {
    let mut out = String::from(
        "== Key-value separation: value log + GC on overwrite-heavy fill ==\n",
    );
    let value_sizes: [u32; 3] = [512, 4096, 65536];
    // a small key space: uniform overwrites shadow earlier versions,
    // feeding both compaction (inline) and vlog dead-space (separated)
    let key_space = ((40_000.0 * ctx.scale) as u32).clamp(2_000, 40_000);
    let stop_ops = ((800_000.0 * ctx.scale) as u64).clamp(20_000, 800_000);

    let mut rows: Vec<Row> = Vec::new();
    for kind in headline_systems() {
        for value_size in value_sizes {
            for vlog_on in [false, true] {
                let mut opts = LsmOptions::default().with_threads(2);
                if vlog_on {
                    opts = opts
                        .with_vlog_threshold(THRESHOLD)
                        .with_vlog_segment_bytes(SEGMENT_BYTES);
                }
                let mut sys = EngineBuilder::new(kind)
                    .opts(opts)
                    .merge_engine(ctx.merge_engine())
                    .bloom_builder(ctx.bloom_builder())
                    .build();
                let mut env = SimEnv::new(ctx.seed, SsdConfig::default());
                let cfg = BenchConfig {
                    seed: ctx.seed,
                    key_space,
                    value_size,
                    ..Default::default()
                }
                .scaled(ctx.scale);
                let mut spec = workload::preset_spec(
                    "A",
                    &cfg,
                    CLIENTS,
                    LoopMode::Closed { think: 0 },
                    KeyDist::Uniform,
                )?;
                spec.stop_after_ops = Some(stop_ops);
                let r = workload::run_spec(&mut *sys, &mut env, &spec);
                let d = sys.db_stats().clone();
                let v = sys.main_db().vlog_stats();
                let vtotal = sys.main_db().vlog_total_bytes();
                let vdead = sys.main_db().vlog_dead_bytes();
                let row = Row {
                    system: kind.label(),
                    value_size,
                    vlog: if vlog_on { "on" } else { "off" },
                    write_kops: r.write_kops(),
                    put_p99_us: r.write_lat.p99_us,
                    bytes_flushed: d.bytes_flushed,
                    bytes_compacted_written: d.bytes_compacted_written,
                    write_amp: d.write_amplification(),
                    vlog_appends: v.appends,
                    gc_runs: v.gc_runs,
                    gc_reclaimed_bytes: v.gc_reclaimed_bytes,
                    vlog_total_bytes: vtotal,
                    vlog_dead_ratio: if vtotal == 0 {
                        0.0
                    } else {
                        vdead as f64 / vtotal as f64
                    },
                };
                out.push_str(&format!(
                    "  {:<10} val {:>6} vlog {:<3} {:>8.1} Kwrites/s  \
                     p99 {:>9.1} us  compacted {:>7} MiB  WA {:>5.2}  \
                     gc {:>3} runs / {:>6} MiB reclaimed  dead {:>4.2}\n",
                    row.system,
                    row.value_size,
                    row.vlog,
                    row.write_kops,
                    row.put_p99_us,
                    row.bytes_compacted_written >> 20,
                    row.write_amp,
                    row.gc_runs,
                    row.gc_reclaimed_bytes >> 20,
                    row.vlog_dead_ratio,
                ));
                rows.push(row);
            }
        }
    }

    // headline shape: separating large values shrinks compaction traffic
    for kind in headline_systems() {
        for value_size in [4096u32, 65536] {
            let find = |vlog: &str| {
                rows.iter().find(|r| {
                    r.system == kind.label()
                        && r.value_size == value_size
                        && r.vlog == vlog
                })
            };
            if let (Some(off), Some(on)) = (find("off"), find("on")) {
                out.push_str(&format!(
                    "  compaction-bytes ratio {:<10} val {:>6} {:.2}x \
                     ({} MiB -> {} MiB), WA {:.2} -> {:.2}\n",
                    kind.label(),
                    value_size,
                    off.bytes_compacted_written as f64
                        / (on.bytes_compacted_written.max(1)) as f64,
                    off.bytes_compacted_written >> 20,
                    on.bytes_compacted_written >> 20,
                    off.write_amp,
                    on.write_amp,
                ));
            }
        }
    }

    let csv: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{},{},{},{:.3},{:.2},{},{},{:.4},{},{},{},{},{:.4}",
                r.system,
                r.value_size,
                r.vlog,
                r.write_kops,
                r.put_p99_us,
                r.bytes_flushed,
                r.bytes_compacted_written,
                r.write_amp,
                r.vlog_appends,
                r.gc_runs,
                r.gc_reclaimed_bytes,
                r.vlog_total_bytes,
                r.vlog_dead_ratio,
            )
        })
        .collect();
    ctx.write_csv(
        "kv_sep.csv",
        "system,value_size,vlog,write_kops,put_p99_us,bytes_flushed,bytes_compacted_written,write_amp,vlog_appends,gc_runs,gc_reclaimed_bytes,vlog_total_bytes,vlog_dead_ratio",
        &csv,
    )?;

    let json_rows: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                concat!(
                    "    {{\"system\": \"{}\", \"value_size\": {}, ",
                    "\"vlog\": \"{}\", \"write_kops\": {:.3}, ",
                    "\"put_p99_us\": {:.2}, \"bytes_flushed\": {}, ",
                    "\"bytes_compacted_written\": {}, \"write_amp\": {:.4}, ",
                    "\"vlog_appends\": {}, \"gc_runs\": {}, ",
                    "\"gc_reclaimed_bytes\": {}, \"vlog_total_bytes\": {}, ",
                    "\"vlog_dead_ratio\": {:.4}}}"
                ),
                r.system,
                r.value_size,
                r.vlog,
                r.write_kops,
                r.put_p99_us,
                r.bytes_flushed,
                r.bytes_compacted_written,
                r.write_amp,
                r.vlog_appends,
                r.gc_runs,
                r.gc_reclaimed_bytes,
                r.vlog_total_bytes,
                r.vlog_dead_ratio,
            )
        })
        .collect();
    let json = format!(
        concat!(
            "{{\n  \"schema\": \"kvaccel-kvsep-v1\",\n",
            "  \"config\": {{\"workload\": \"A/fillrandom overwrite-heavy\", ",
            "\"loop_mode\": \"closed\", \"clients\": {}, ",
            "\"value_sizes\": [512, 4096, 65536], ",
            "\"vlog_threshold\": {}, \"vlog_segment_bytes\": {}, ",
            "\"key_space\": {}, \"stop_after_ops\": {}, ",
            "\"scale\": {}, \"seed\": {}}},\n",
            "  \"rows\": [\n{}\n  ]\n}}\n"
        ),
        CLIENTS,
        THRESHOLD,
        SEGMENT_BYTES,
        key_space,
        stop_ops,
        ctx.scale,
        ctx.seed,
        json_rows.join(",\n"),
    );
    std::fs::create_dir_all(&ctx.out_dir)?;
    std::fs::write(ctx.out_dir.join("BENCH_PR10.json"), json)?;

    out.push_str(
        "  shape check: at 4 KiB+ the separated runs compact a fraction of \
         the baseline's bytes (the LSM moves 12 B pointers, not payloads) \
         and GC holds the log's dead-space ratio under the 0.4 trigger; \
         the 512 B points are bit-identical to vlog-off (below threshold)\n",
    );
    ctx.log(&out);
    Ok(out)
}
