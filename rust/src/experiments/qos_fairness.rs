//! PR6 noisy-neighbor fairness experiment: one abusive open-loop tenant
//! against three well-behaved closed-loop tenants on a shared store,
//! with QoS off (accounting only) vs QoS on (token-bucket admission +
//! SLO shedding + per-tenant device grants).
//!
//! Per system (LSM / ADOC / KVACCEL), three runs on pressure-sized
//! stores:
//!
//! 1. **solo** — victims only, no QoS: the isolation baseline their p99
//!    is judged against.
//! 2. **off** — abuser + victims, monitor-only QoS: the abuser floods
//!    the engine at 3x its sustainable rate, stalls the shared LSM, and
//!    the victims' p99 collapses (the noisy-neighbor pathology).
//! 3. **on** — same load, enforced QoS: the abuser's bucket admits a
//!    small fraction of its offered rate and the SLO shedder drops its
//!    stale backlog, so the victims stay near their solo baseline while
//!    the abuser still makes progress (throttled, not deadlocked).
//!
//! Emits `results/qos_fairness.csv` and the machine-readable
//! `results/BENCH_PR6.json` built in CI. `tests/qos_conformance.rs`
//! asserts the fairness contract on the plain-LSM row.

use anyhow::Result;

use crate::baselines::SystemKind;
use crate::engine::EngineBuilder;
use crate::env::SimEnv;
use crate::lsm::LsmOptions;
use crate::qos::{QosConfig, TenantSpec};
use crate::sim::{MILLIS, NS_PER_SEC};
use crate::ssd::SsdConfig;
use crate::workload::{
    self, BenchConfig, ClientConfig, LoopMode, RunResult, TenantResult,
};

use super::{headline_systems, ExpContext};

/// Victim population: closed-loop writers with human-ish think time, the
/// tenants whose latency the QoS layer is defending.
const VICTIMS: usize = 3;
const VICTIM_THINK: u64 = 10 * MILLIS;
/// The abuser offers this multiple of the probed sustainable rate.
const ABUSE_FACTOR: f64 = 3.0;
/// Enforced abuser admission: this fraction of the sustainable rate,
/// clamped to a workable ops/s band at any scale.
const ABUSER_ADMIT_FRACTION: f64 = 0.05;
/// Victim/abuser p99 SLO when QoS is on.
const SLO_P99: u64 = 50 * MILLIS;

/// One system's fairness measurements across the three runs.
#[derive(Clone, Debug)]
pub struct FairnessOutcome {
    pub system: String,
    /// Probed closed-loop sustainable rate (ops/s) on the plain LSM.
    pub sustainable_ops_s: f64,
    /// Admission rate granted to the abuser when QoS is on (ops/s).
    pub admitted_ops_s: f64,
    /// Victims-only baseline p99 (us).
    pub solo_p99_us: f64,
    /// Worst victim p99 with the abuser present, QoS off / on (us).
    pub off_victim_p99_us: f64,
    pub on_victim_p99_us: f64,
    /// Abuser throughput, QoS off / on (Kops/s).
    pub off_abuser_kops: f64,
    pub on_abuser_kops: f64,
    /// Abuser ops served with QoS on (must stay > 0: throttled, not
    /// deadlocked).
    pub on_abuser_ops: u64,
    pub on_abuser_throttled: u64,
    pub on_abuser_shed: u64,
    /// Whole-run write-stall stop time, QoS off / on (s).
    pub off_stopped_s: f64,
    pub on_stopped_s: f64,
    /// KVACCEL redirected writes with QoS on (0 on the baselines).
    pub on_redirected: u64,
}

fn pressure_cfg(seed: u64, secs: u64) -> BenchConfig {
    BenchConfig {
        seed,
        duration: secs * NS_PER_SEC,
        key_space: 200_000,
        ..Default::default()
    }
}

fn build(kind: SystemKind) -> Box<dyn crate::engine::KvEngine> {
    // pressure-sized stores (as in shard-scale/recovery) so the abuser
    // actually stalls the engine at CI scale
    EngineBuilder::new(kind)
        .opts(LsmOptions::small_for_test().with_threads(2))
        .build()
}

fn victim_clients() -> Vec<ClientConfig> {
    (0..VICTIMS)
        .map(|v| {
            ClientConfig::writer()
                .with_mode(LoopMode::Closed { think: VICTIM_THINK })
                .with_seed_tag(0x51C0 + v as u64)
                .with_tenant(v as u32 + 1)
        })
        .collect()
}

/// The worst victim p99 across the per-tenant rows (tenant 0 is the
/// abuser; every other row is a victim).
fn worst_victim_p99(tenants: &[TenantResult]) -> f64 {
    tenants
        .iter()
        .skip(1)
        .map(|t| t.lat.p99_us)
        .fold(0.0, f64::max)
}

fn run_arm(
    kind: SystemKind,
    seed: u64,
    cfg: &BenchConfig,
    clients: Vec<ClientConfig>,
    qos: Option<QosConfig>,
) -> RunResult {
    let mut sys = build(kind);
    let mut env = SimEnv::new(seed, SsdConfig::default());
    let mut spec =
        workload::WorkloadSpec::from_bench("qos-fairness", cfg).with_clients(clients);
    spec.qos = qos;
    let mut r = workload::run_spec(&mut *sys, &mut env, &spec);
    r.system = kind.label();
    r
}

/// The full solo/off/on comparison for one system. Standalone (no
/// [`ExpContext`]) so `tests/qos_conformance.rs` can assert on it.
pub fn run_fairness(kind: SystemKind, seed: u64, secs: u64) -> Result<FairnessOutcome> {
    let cfg = pressure_cfg(seed, secs);

    // calibrate on the plain LSM: the abuse rate must exceed what the
    // engine sustains, whatever the scale/options (same probe pattern as
    // the qdelay experiment)
    let probe_cfg = BenchConfig { duration: 2 * NS_PER_SEC, ..cfg.clone() };
    let probe = {
        let mut sys = build(SystemKind::RocksDb { slowdown: true });
        let mut env = SimEnv::new(seed, SsdConfig::default());
        workload::fillrandom(&mut *sys, &mut env, &probe_cfg)
    };
    let sustainable = (probe.writes.total as f64 / probe.duration_s).max(100.0);
    let abuse_rate = sustainable * ABUSE_FACTOR;
    let admitted_ops_s = (sustainable * ABUSER_ADMIT_FRACTION).clamp(25.0, 400.0);

    let abuser = ClientConfig::writer()
        .with_mode(LoopMode::OpenFixed { ops_per_sec: abuse_rate })
        .with_seed_tag(0xAB5E)
        .with_tenant(0);
    let mixed_clients = || {
        let mut cs = vec![abuser.clone()];
        cs.extend(victim_clients());
        cs
    };
    let tenant_table = |enforced: bool| {
        let bytes_per_op = 16 + cfg.value_size as u64;
        let rate_bytes = (admitted_ops_s * bytes_per_op as f64) as u64;
        let mut tenants = vec![TenantSpec::new("abuser")
            .with_rate(rate_bytes, (rate_bytes / 4).max(bytes_per_op))
            .with_slo_p99(SLO_P99)];
        for v in 0..VICTIMS {
            tenants.push(TenantSpec::new(format!("victim{v}")).with_slo_p99(SLO_P99));
        }
        let mut q = QosConfig::new(tenants);
        // the enforced abuser admits only tens of ops per 100 ms tick;
        // the default 16-op window floor would keep its (seconds-deep)
        // SLO violation invisible at CI scale
        q.slo_min_window_ops = 4;
        if enforced {
            q
        } else {
            q.monitor_only()
        }
    };

    // 1. solo: victims alone, no QoS — the isolation baseline
    let solo = run_arm(kind, seed, &cfg, victim_clients(), None);
    // 2. off: abuser + victims, accounting only
    let off = run_arm(kind, seed, &cfg, mixed_clients(), Some(tenant_table(false)));
    // 3. on: same load, enforced
    let on = run_arm(kind, seed, &cfg, mixed_clients(), Some(tenant_table(true)));

    let dur = cfg.duration as f64 / NS_PER_SEC as f64;
    Ok(FairnessOutcome {
        system: kind.label(),
        sustainable_ops_s: sustainable,
        admitted_ops_s,
        solo_p99_us: solo.write_lat.p99_us,
        off_victim_p99_us: worst_victim_p99(&off.tenants),
        on_victim_p99_us: worst_victim_p99(&on.tenants),
        off_abuser_kops: off.tenants[0].ops as f64 / dur / 1e3,
        on_abuser_kops: on.tenants[0].ops as f64 / dur / 1e3,
        on_abuser_ops: on.tenants[0].ops,
        on_abuser_throttled: on.tenants[0].throttled,
        on_abuser_shed: on.tenants[0].shed,
        off_stopped_s: off.stopped_s,
        on_stopped_s: on.stopped_s,
        on_redirected: on.redirected_writes,
    })
}

pub fn qos_fairness(ctx: &ExpContext) -> Result<String> {
    let mut out = String::from(
        "== QoS fairness: 1 abusive open-loop tenant vs 3 closed-loop victims ==\n",
    );
    let secs = ((600.0 * ctx.scale) as u64).clamp(4, 30);
    let mut rows: Vec<FairnessOutcome> = Vec::new();
    for kind in headline_systems() {
        let f = run_fairness(kind, ctx.seed, secs)?;
        out.push_str(&format!(
            "  {:<10} victim p99 solo {:>9.0} us | qos-off {:>10.0} us | qos-on {:>9.0} us   \
             abuser {:>6.2} -> {:>5.2} Kops/s ({} throttled, {} shed)\n",
            f.system,
            f.solo_p99_us,
            f.off_victim_p99_us,
            f.on_victim_p99_us,
            f.off_abuser_kops,
            f.on_abuser_kops,
            f.on_abuser_throttled,
            f.on_abuser_shed,
        ));
        rows.push(f);
    }

    let csv: Vec<String> = rows
        .iter()
        .map(|f| {
            format!(
                "{},{:.1},{:.1},{:.2},{:.2},{:.2},{:.4},{:.4},{},{},{},{:.4},{:.4},{}",
                f.system,
                f.sustainable_ops_s,
                f.admitted_ops_s,
                f.solo_p99_us,
                f.off_victim_p99_us,
                f.on_victim_p99_us,
                f.off_abuser_kops,
                f.on_abuser_kops,
                f.on_abuser_ops,
                f.on_abuser_throttled,
                f.on_abuser_shed,
                f.off_stopped_s,
                f.on_stopped_s,
                f.on_redirected,
            )
        })
        .collect();
    ctx.write_csv(
        "qos_fairness.csv",
        "system,sustainable_ops_s,admitted_ops_s,solo_p99_us,off_victim_p99_us,on_victim_p99_us,off_abuser_kops,on_abuser_kops,on_abuser_ops,on_abuser_throttled,on_abuser_shed,off_stopped_s,on_stopped_s,on_redirected",
        &csv,
    )?;

    let json_rows: Vec<String> = rows
        .iter()
        .map(|f| {
            format!(
                concat!(
                    "    {{\"system\": \"{}\", \"sustainable_ops_s\": {:.1}, ",
                    "\"admitted_ops_s\": {:.1}, \"solo_p99_us\": {:.2}, ",
                    "\"off_victim_p99_us\": {:.2}, \"on_victim_p99_us\": {:.2}, ",
                    "\"off_abuser_kops\": {:.4}, \"on_abuser_kops\": {:.4}, ",
                    "\"on_abuser_ops\": {}, \"on_abuser_throttled\": {}, ",
                    "\"on_abuser_shed\": {}, \"off_stopped_s\": {:.4}, ",
                    "\"on_stopped_s\": {:.4}, \"on_redirected\": {}}}"
                ),
                f.system,
                f.sustainable_ops_s,
                f.admitted_ops_s,
                f.solo_p99_us,
                f.off_victim_p99_us,
                f.on_victim_p99_us,
                f.off_abuser_kops,
                f.on_abuser_kops,
                f.on_abuser_ops,
                f.on_abuser_throttled,
                f.on_abuser_shed,
                f.off_stopped_s,
                f.on_stopped_s,
                f.on_redirected,
            )
        })
        .collect();
    let json = format!(
        concat!(
            "{{\n  \"schema\": \"kvaccel-qosfairness-v1\",\n",
            "  \"config\": {{\"victims\": {}, \"victim_think_ms\": {}, ",
            "\"abuse_factor\": {}, \"admit_fraction\": {}, \"slo_p99_ms\": {}, ",
            "\"duration_s\": {}, \"scale\": {}, \"seed\": {}}},\n",
            "  \"rows\": [\n{}\n  ]\n}}\n"
        ),
        VICTIMS,
        VICTIM_THINK / MILLIS,
        ABUSE_FACTOR,
        ABUSER_ADMIT_FRACTION,
        SLO_P99 / MILLIS,
        secs,
        ctx.scale,
        ctx.seed,
        json_rows.join(",\n"),
    );
    std::fs::create_dir_all(&ctx.out_dir)?;
    std::fs::write(ctx.out_dir.join("BENCH_PR6.json"), json)?;

    out.push_str(
        "  shape check: with QoS off the abuser's backlog stalls the shared \
         engine and the victims' p99 collapses; with QoS on the bucket + \
         shedder hold the victims near their solo baseline while the abuser \
         keeps making (throttled) progress\n",
    );
    ctx.log(&out);
    Ok(out)
}
