//! Figure reproductions (Figs 2, 3, 4, 5, 11, 12, 13, 14).

use anyhow::Result;

use crate::baselines::SystemKind;
use crate::kvaccel::RollbackScheme;
use crate::util::fmt;
use crate::workload::{cdf, preset_spec, KeyDist, LoopMode, RunResult};

use super::ExpContext;

fn series_csv(r: &RunResult) -> Vec<String> {
    r.writes
        .ops_per_sec()
        .iter()
        .enumerate()
        .map(|(s, &ops)| format!("{s},{ops}"))
        .collect()
}

/// Fig 2: per-second throughput time-series for RocksDB and ADOC with the
/// slowdown feature disabled / enabled (4 panels).
pub fn fig2(ctx: &ExpContext) -> Result<String> {
    let mut out = String::from("== Fig 2: throughput time-series vs slowdown ==\n");
    let panels = [
        ("a_rocksdb_noslow", SystemKind::RocksDb { slowdown: false }),
        ("b_rocksdb_slow", SystemKind::RocksDb { slowdown: true }),
        ("c_adoc_noslow_proxy", SystemKind::RocksDb { slowdown: false }),
        ("d_adoc_slow", SystemKind::Adoc),
    ];
    for (name, kind) in panels {
        // panel (c): ADOC depends on slowdown for its optimizations (the
        // paper also notes this); the no-slowdown ADOC panel is RocksDB
        // tuned up — we run ADOC with slowdown for (d) and RocksDB-noSD
        // as the (c) proxy, matching the paper's observation.
        let r = ctx.run_fillrandom(kind, 4);
        let series = r.writes.ops_per_sec();
        let zeros = series.iter().filter(|&&x| x == 0).count();
        let peak = series.iter().max().copied().unwrap_or(0);
        ctx.write_csv(&format!("fig2_{name}.csv"), "sec,write_ops", &series_csv(&r))?;
        out.push_str(&format!(
            "  {name:<22} {} | mean {:>7.1} ops/s  peak {:>7}  zero-throughput seconds {:>3}  halts {}\n",
            r.system,
            r.writes.mean_ops(),
            peak,
            zeros,
            r.stop_events,
        ));
    }
    out.push_str("  shape check: slowdown-on panels should show no zero-seconds; slowdown-off panels show halts\n");
    ctx.log(&out);
    Ok(out)
}

/// Fig 3: average throughput + P99 latency, slowdown off vs on — plus the
/// §III-A slowdown instance counts (paper: RocksDB 258, ADOC 433).
pub fn fig3(ctx: &ExpContext) -> Result<String> {
    let mut out = String::from("== Fig 3: throughput / P99 vs slowdown usage ==\n");
    let rows = [
        ("RocksDB-noSD", SystemKind::RocksDb { slowdown: false }),
        ("RocksDB", SystemKind::RocksDb { slowdown: true }),
        ("ADOC-noSD", SystemKind::RocksDb { slowdown: false }), // proxy, see fig2
        ("ADOC", SystemKind::Adoc),
    ];
    let mut csv = Vec::new();
    let mut measured: Vec<(String, RunResult)> = Vec::new();
    for (label, kind) in rows {
        let r = ctx.run_fillrandom(kind, 4);
        csv.push(format!(
            "{label},{:.1},{:.1},{},{}",
            r.write_kops() * 1e3,
            r.write_lat.p99_us,
            r.slowdown_events,
            r.stop_events
        ));
        out.push_str(&format!(
            "  {label:<14} {:>8.1} ops/s  P99 {:>9}  slowdown instances {:>5}  halts {:>3}\n",
            r.write_kops() * 1e3,
            fmt::nanos(r.write_lat.p99_us * 1e3),
            r.slowdown_events,
            r.stop_events
        ));
        measured.push((label.to_string(), r));
    }
    ctx.write_csv(
        "fig3.csv",
        "system,write_ops_s,p99_us,slowdown_instances,halts",
        &csv,
    )?;
    // paper deltas: slowdown costs RocksDB 34% / ADOC 47% throughput
    let t_no = measured[0].1.write_kops();
    let t_sd = measured[1].1.write_kops();
    if t_no > 0.0 {
        out.push_str(&format!(
            "  RocksDB slowdown throughput delta: {:+.0}% (paper: -34%)\n",
            100.0 * (t_sd - t_no) / t_no
        ));
    }
    ctx.log(&out);
    Ok(out)
}

/// Fig 4: PCIe bandwidth time-series during write stalls, RocksDB(1) and
/// RocksDB(4), slowdown off, 100–200 s window.
pub fn fig4(ctx: &ExpContext) -> Result<String> {
    let mut out = String::from("== Fig 4: PCIe bandwidth during stalls (no slowdown) ==\n");
    for threads in [1usize, 4] {
        let r = ctx.run_fillrandom(SystemKind::RocksDb { slowdown: false }, threads);
        // paper plots the 100-200 s slice of 600 s = the middle third
        let len = r.pcie_mbps.len().max(3);
        let (lo, hi) = (len / 3, 2 * len / 3);
        let rows: Vec<String> = r
            .pcie_mbps
            .iter()
            .enumerate()
            .map(|(s, &m)| {
                format!("{s},{m:.2},{}", r.stall_seconds.contains(&s) as u8)
            })
            .collect();
        ctx.write_csv(
            &format!("fig4_rocksdb{threads}.csv"),
            "sec,pcie_mbps,in_stall",
            &rows,
        )?;
        let window: Vec<f64> = r
            .pcie_mbps
            .iter()
            .skip(lo)
            .take(hi - lo)
            .copied()
            .collect();
        let stall_in_window = r
            .stall_seconds
            .iter()
            .filter(|&&s| s >= lo && s < hi)
            .count();
        let peak = window.iter().cloned().fold(0.0f64, f64::max);
        let idle = window.iter().filter(|&&m| m < 1.0).count();
        out.push_str(&format!(
            "  RocksDB({threads}) window {lo}-{hi}s: peak {:.0} MB/s, idle seconds {idle}, stall seconds {stall_in_window}\n",
            peak
        ));
    }
    out.push_str("  shape check: visible idle gaps inside stall windows (merge phase leaves the link dark)\n");
    ctx.log(&out);
    Ok(out)
}

/// Fig 5: CDF of PCIe bandwidth *during write-stall seconds* for
/// RocksDB(1) and RocksDB(4). Paper: with 1 thread, 30% of stall time has
/// zero usage and 49% uses >90% of bandwidth.
pub fn fig5(ctx: &ExpContext) -> Result<String> {
    let mut out = String::from("== Fig 5: CDF of PCIe bandwidth during write stalls ==\n");
    for threads in [1usize, 4] {
        let r = ctx.run_fillrandom(SystemKind::RocksDb { slowdown: false }, threads);
        let samples: Vec<f64> = r
            .stall_seconds
            .iter()
            .filter_map(|&s| r.pcie_mbps.get(s).copied())
            .collect();
        // normalize to the observed stall-period peak (the paper uses the
        // device's 630 MB/s ceiling; our PCIe carries reads faster than
        // the NAND program path, so the observed peak is the comparable
        // "available bandwidth" reference)
        let dev_peak = samples.iter().cloned().fold(1.0f64, f64::max);
        let thresholds: Vec<f64> = (0..=100).map(|i| dev_peak * i as f64 / 100.0).collect();
        let curve = cdf(&samples, &thresholds);
        let rows: Vec<String> = thresholds
            .iter()
            .zip(&curve)
            .map(|(t, c)| format!("{t:.1},{c:.4}"))
            .collect();
        ctx.write_csv(&format!("fig5_rocksdb{threads}.csv"), "mbps,cdf", &rows)?;
        let zero_frac = samples.iter().filter(|&&s| s < 1.0).count() as f64
            / samples.len().max(1) as f64;
        let high_frac = samples.iter().filter(|&&s| s > 0.9 * dev_peak).count() as f64
            / samples.len().max(1) as f64;
        out.push_str(&format!(
            "  RocksDB({threads}): {} stall-second samples; zero-usage {:.0}% (paper {}%), >90%-usage {:.0}% (paper {}%)\n",
            samples.len(),
            zero_frac * 100.0,
            if threads == 1 { 30 } else { 21 },
            high_frac * 100.0,
            if threads == 1 { 49 } else { 55 },
        ));
    }
    ctx.log(&out);
    Ok(out)
}

/// Fig 11: per-second write throughput for RocksDB, ADOC, KVACCEL on
/// workload A — KVACCEL should hold ~full rate where the others slow to
/// the ~2 Kops/s floor.
pub fn fig11(ctx: &ExpContext) -> Result<String> {
    let mut out = String::from("== Fig 11: per-second throughput, workload A ==\n");
    let mut floor = Vec::new();
    for kind in super::headline_systems() {
        let r = ctx.run_fillrandom(kind, 4);
        ctx.write_csv(
            &format!("fig11_{}.csv", r.system.to_lowercase()),
            "sec,write_ops",
            &series_csv(&r),
        )?;
        let series = r.writes.ops_per_sec();
        // low-throughput floor: 5th percentile of non-warmup seconds
        let mut sorted: Vec<u64> = series.iter().skip(2).copied().collect();
        sorted.sort_unstable();
        let p5 = sorted.get(sorted.len() / 20).copied().unwrap_or(0);
        out.push_str(&format!(
            "  {:<10} mean {:>8.1} ops/s  5th-pct floor {:>7} ops/s  halts {}\n",
            r.system,
            r.writes.mean_ops(),
            p5,
            r.stop_events
        ));
        floor.push((r.system.clone(), p5));
    }
    out.push_str(
        "  shape check: KVACCEL floor should sit far above the baselines' slowdown floor\n",
    );
    ctx.log(&out);
    Ok(out)
}

/// Fig 12: throughput (a), P99 (b), efficiency (c) for all systems ×
/// {1,2,4} compaction threads, workload A (KVACCEL write-optimized:
/// rollback disabled during the run).
pub fn fig12(ctx: &ExpContext) -> Result<String> {
    let mut out = String::from("== Fig 12: throughput / P99 / efficiency, workload A ==\n");
    let mut csv = Vec::new();
    let mut grid: Vec<(String, usize, RunResult)> = Vec::new();
    for kind in super::headline_systems() {
        for threads in [1usize, 2, 4] {
            let r = ctx.run_fillrandom(kind, threads);
            out.push_str(&format!(
                "  {:<10}({threads}) {:>8.1} ops/s  P99 {:>10}  CPU {:>5.1}%  eff {:>6.2} MB/s/%\n",
                r.system,
                r.write_kops() * 1e3,
                fmt::nanos(r.write_lat.p99_us * 1e3),
                r.cpu_percent,
                r.efficiency
            ));
            csv.push(format!(
                "{},{threads},{:.1},{:.1},{:.2},{:.3}",
                r.system,
                r.write_kops() * 1e3,
                r.write_lat.p99_us,
                r.cpu_percent,
                r.efficiency
            ));
            grid.push((r.system.clone(), threads, r));
        }
    }
    ctx.write_csv(
        "fig12.csv",
        "system,threads,write_ops_s,p99_us,cpu_percent,efficiency",
        &csv,
    )?;
    // headline deltas (paper: KVACCEL up to +37% vs RocksDB, +17% vs ADOC)
    let find = |name: &str, th: usize| {
        grid.iter()
            .find(|(s, t, _)| s == name && *t == th)
            .map(|(_, _, r)| r)
    };
    let mut best_vs_rocks: f64 = 0.0;
    let mut best_vs_adoc: f64 = 0.0;
    for th in [1usize, 2, 4] {
        if let (Some(k), Some(r), Some(a)) =
            (find("KVACCEL", th), find("RocksDB", th), find("ADOC", th))
        {
            best_vs_rocks = best_vs_rocks
                .max(100.0 * (k.write_kops() - r.write_kops()) / r.write_kops());
            best_vs_adoc = best_vs_adoc
                .max(100.0 * (k.write_kops() - a.write_kops()) / a.write_kops());
        }
    }
    out.push_str(&format!(
        "  KVACCEL max gain: vs RocksDB {best_vs_rocks:+.0}% (paper +37%), vs ADOC {best_vs_adoc:+.0}% (paper +17%)\n",
    ));
    if let (Some(k1), Some(a4)) = (find("KVACCEL", 1), find("ADOC", 4)) {
        out.push_str(&format!(
            "  KVACCEL(1) {:.1} vs ADOC(4) {:.1} ops/s (paper: comparable)\n",
            k1.write_kops() * 1e3,
            a4.write_kops() * 1e3
        ));
    }
    ctx.log(&out);
    Ok(out)
}

/// Fig 13: read/write throughput for workloads A, B(9:1), C(8:2) across
/// RocksDB, ADOC, KVACCEL-L, KVACCEL-E (all 4 threads).
pub fn fig13(ctx: &ExpContext) -> Result<String> {
    let mut out = String::from("== Fig 13: rollback schemes across workloads (4 threads) ==\n");
    let systems = [
        SystemKind::RocksDb { slowdown: true },
        SystemKind::Adoc,
        SystemKind::Kvaccel { scheme: RollbackScheme::Lazy },
        SystemKind::Kvaccel { scheme: RollbackScheme::Eager },
    ];
    let workloads: [(&str, Option<(u64, u64)>); 3] =
        [("A", None), ("B", Some((9, 1))), ("C", Some((8, 2)))];
    let mut csv = Vec::new();
    for (wname, ratio) in workloads {
        for kind in systems {
            let r = match ratio {
                None => ctx.run_fillrandom(kind, 4),
                Some(rt) => ctx.run_rww(kind, 4, rt),
            };
            out.push_str(&format!(
                "  {wname} {:<10} write {:>8.1} ops/s  read {:>8.1} ops/s  hit {:>5.1}%  read-p99 {:>9}  rollbacks {:>4}\n",
                r.system,
                r.write_kops() * 1e3,
                r.read_kops() * 1e3,
                r.read_hit_rate() * 100.0,
                fmt::nanos(r.read_lat.p99_us * 1e3),
                r.rollbacks
            ));
            csv.push(format!(
                "{wname},{},{:.1},{:.1},{:.4},{:.1},{}",
                r.system,
                r.write_kops() * 1e3,
                r.read_kops() * 1e3,
                r.read_hit_rate(),
                r.read_lat.p99_us,
                r.rollbacks
            ));
        }
    }
    ctx.write_csv(
        "fig13.csv",
        "workload,system,write_ops_s,read_ops_s,read_hit_rate,read_p99_us,rollbacks",
        &csv,
    )?;
    out.push_str("  shape check: lazy wins writes on A; eager lifts reads on B/C\n");
    ctx.log(&out);
    Ok(out)
}

/// Fig 14: PCIe bandwidth overview, RocksDB(1) vs KVACCEL(1) (the paper
/// plots log-scale; we emit the series + utilization summary).
pub fn fig14(ctx: &ExpContext) -> Result<String> {
    let mut out = String::from("== Fig 14: PCIe bandwidth overview (1 thread) ==\n");
    for kind in [
        SystemKind::RocksDb { slowdown: false },
        SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
    ] {
        let r = ctx.run_fillrandom(kind, 1);
        let rows: Vec<String> = r
            .pcie_mbps
            .iter()
            .enumerate()
            .map(|(s, &m)| format!("{s},{m:.3}"))
            .collect();
        ctx.write_csv(
            &format!("fig14_{}.csv", r.system.to_lowercase()),
            "sec,pcie_mbps",
            &rows,
        )?;
        let idle = r.pcie_mbps.iter().filter(|&&m| m < 1.0).count();
        let mean = r.pcie_mbps.iter().sum::<f64>() / r.pcie_mbps.len().max(1) as f64;
        out.push_str(&format!(
            "  {:<10} mean {:>7.1} MB/s  idle seconds {:>4}/{}\n",
            r.system,
            mean,
            idle,
            r.pcie_mbps.len()
        ));
    }
    out.push_str("  shape check: KVACCEL keeps the link busy where RocksDB goes dark\n");
    ctx.log(&out);
    Ok(out)
}

/// Open-loop queueing delay (not a paper figure; Luo & Carey's write-
/// stall methodology): fixed-rate arrivals above the Main-LSM's
/// sustainable throughput. The LSM baseline's queueing delay grows
/// without bound while KVACCEL's redirection keeps it flat — the
/// pathology a closed-loop driver structurally cannot show.
pub fn qdelay(ctx: &ExpContext) -> Result<String> {
    let mut out = String::from("== qdelay: open-loop queueing delay at a fixed offered rate ==\n");
    let cfg = ctx.bench_config();
    // calibrate: measure the LSM's sustainable closed-loop rate on a
    // short probe, then offer 3x that (sustained rate varies with
    // scale/options, so a hard-coded rate could under-load the engine)
    let probe_cfg = crate::workload::BenchConfig {
        duration: 2 * crate::sim::NS_PER_SEC,
        ..cfg.clone()
    };
    let probe = {
        let (mut sys, mut env) =
            ctx.build_system(SystemKind::RocksDb { slowdown: true }, 4);
        crate::workload::fillrandom(&mut *sys, &mut env, &probe_cfg)
    };
    let sustainable = probe.writes.total as f64 / probe.duration_s;
    let rate = (sustainable * 3.0).max(1_000.0);
    out.push_str(&format!(
        "  probe: LSM sustains ~{sustainable:.0} ops/s closed-loop; offering {rate:.0} ops/s\n"
    ));
    for kind in [
        SystemKind::RocksDb { slowdown: true },
        SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
    ] {
        let spec = preset_spec(
            "A",
            &cfg,
            4,
            LoopMode::OpenFixed { ops_per_sec: rate },
            KeyDist::Uniform,
        )?;
        let r = ctx.run_workload(kind, 4, &spec);
        let rows: Vec<String> = r
            .queue_delay_series_us
            .iter()
            .enumerate()
            .map(|(s, &us)| format!("{s},{us:.1}"))
            .collect();
        ctx.write_csv(
            &format!("qdelay_{}.csv", r.system.to_lowercase()),
            "sec,mean_queue_delay_us",
            &rows,
        )?;
        let n = r.queue_delay_series_us.len();
        let half_mean = |range: std::ops::Range<usize>| {
            let slice = &r.queue_delay_series_us[range];
            slice.iter().sum::<f64>() / slice.len().max(1) as f64
        };
        let (first, second) = if n >= 2 {
            (half_mean(0..n / 2), half_mean(n / 2..n))
        } else {
            (0.0, 0.0)
        };
        out.push_str(&format!(
            "  {:<10} served {:>8}  qdelay p50 {:>10} p99 {:>10}  1st-half mean {:>9.0} us  2nd-half {:>9.0} us  redirects {}\n",
            r.system,
            r.writes.total,
            fmt::nanos(r.queue_delay.p50_us * 1e3),
            fmt::nanos(r.queue_delay.p99_us * 1e3),
            first,
            second,
            r.redirected_writes,
        ));
    }
    out.push_str("  shape check: LSM 2nd-half delay >> 1st-half (unbounded queue); KVACCEL stays bounded\n");
    ctx.log(&out);
    Ok(out)
}
