//! PR7 read-amplification experiment: the read-path acceleration stack
//! (engine-wide block cache + block compression) measured end to end.
//!
//! Sweep: cache capacity {0 = off, default} x codec {none, lz-like:50}
//! x the headline systems, each running YCSB-C (closed-loop read-only
//! point gets) against a preloaded store. The cache is warmed with one
//! untimed get sweep over the key space — the same warm-vs-cold
//! methodology db_bench uses — so the timed phase measures steady
//! state, not compulsory misses.
//!
//! Reported per config: read throughput, p50/p99 get latency,
//! blocks-per-get read amplification, cache hit rate, and the measured
//! bloom false-positive rate. Emits `results/read_amp.csv` and the
//! machine-readable `results/BENCH_PR7.json` built in CI; the headline
//! shape is p99(cache off) / p99(cache on) >= 2x on every system.

use anyhow::Result;

use crate::engine::{EngineBuilder, EngineStats, KvEngine};
use crate::env::SimEnv;
use crate::lsm::{Compression, LsmOptions};
use crate::ssd::SsdConfig;
use crate::workload::{self, BenchConfig, KeyDist, LoopMode};

use super::{headline_systems, ExpContext};

struct Row {
    system: String,
    cache_blocks: usize,
    codec: &'static str,
    read_kops: f64,
    get_p50_us: f64,
    get_p99_us: f64,
    blocks_per_get: f64,
    cache_hit_rate: f64,
    bloom_fpr: f64,
    bytes_flushed: u64,
}

const CLIENTS: usize = 4;

pub fn read_amp(ctx: &ExpContext) -> Result<String> {
    let mut out = String::from(
        "== Read-path stack: block cache x compression on YCSB-C (warmed) ==\n",
    );
    // a key space the preload can actually cover, so reads mostly find
    // their key and the cache has a working set to hold
    let key_space = ((1_000_000.0 * ctx.scale) as u32).clamp(20_000, 1_000_000);
    let cfg = BenchConfig {
        seed: ctx.seed,
        key_space,
        ..Default::default()
    }
    .scaled(ctx.scale);
    // ~1.5 preload writes per key: uniform draws cover most of the space
    let preload_bytes = key_space as u64 * (16 + cfg.value_size as u64) * 3 / 2;
    let cache_points = [0usize, LsmOptions::default().block_cache_blocks];
    let codecs: [(&'static str, Compression); 2] = [
        ("none", Compression::None),
        ("lz-like:50", Compression::LzLike { ratio_pct: 50 }),
    ];

    let mut rows: Vec<Row> = Vec::new();
    for kind in headline_systems() {
        for (codec_name, codec) in codecs {
            for cache_blocks in cache_points {
                let opts = LsmOptions::default()
                    .with_threads(2)
                    .with_cache_blocks(cache_blocks)
                    .with_compression(codec);
                let mut sys = EngineBuilder::new(kind)
                    .opts(opts)
                    .merge_engine(ctx.merge_engine())
                    .bloom_builder(ctx.bloom_builder())
                    .build();
                let mut env = SimEnv::new(ctx.seed, SsdConfig::default());
                let t0 =
                    workload::preload(&mut *sys, &mut env, &cfg, preload_bytes)?;
                // untimed warm sweep: one get per key populates the block
                // cache (and KVACCEL's dev-read cache) before measuring;
                // with --cache-blocks 0 the sweep inserts nothing and the
                // timed phase stays all-miss, which is the baseline
                let mut t = t0;
                for k in 0..key_space {
                    t = sys.get(&mut env, t, k).1;
                }
                let mut spec = workload::WorkloadSpec {
                    start_at: t,
                    ..workload::preset_spec(
                        "YCSB-C",
                        &cfg,
                        CLIENTS,
                        LoopMode::Closed { think: 0 },
                        KeyDist::Uniform,
                    )?
                };
                // bound per-config ops so smoke-scale runs finish fast
                spec.stop_after_ops =
                    Some(((2_000_000.0 * ctx.scale) as u64).clamp(40_000, 2_000_000));
                let r = workload::run_spec(&mut *sys, &mut env, &spec);
                let d = sys.db_stats();
                let c = sys.cache_stats();
                let row = Row {
                    system: kind.label(),
                    cache_blocks,
                    codec: codec_name,
                    read_kops: r.read_kops(),
                    get_p50_us: r.read_lat.p50_us,
                    get_p99_us: r.read_lat.p99_us,
                    blocks_per_get: d.blocks_per_get(),
                    cache_hit_rate: c.hit_rate(),
                    bloom_fpr: d.bloom_fpr(),
                    bytes_flushed: d.bytes_flushed,
                };
                out.push_str(&format!(
                    "  {:<10} cache {:>5} codec {:<10} {:>8.1} Kreads/s  \
                     p50/p99 {:>7.1}/{:>9.1} us  {:>5.3} blocks/get  \
                     hit {:>5.1}%  fpr {:.4}\n",
                    row.system,
                    row.cache_blocks,
                    row.codec,
                    row.read_kops,
                    row.get_p50_us,
                    row.get_p99_us,
                    row.blocks_per_get,
                    row.cache_hit_rate * 100.0,
                    row.bloom_fpr,
                ));
                rows.push(row);
            }
        }
    }

    // headline shape: p99 speedup from turning the default cache on
    for kind in headline_systems() {
        for (codec_name, _) in codecs {
            let find = |blocks: usize| {
                rows.iter().find(|r| {
                    r.system == kind.label()
                        && r.codec == codec_name
                        && r.cache_blocks == blocks
                })
            };
            if let (Some(off), Some(on)) = (find(0), find(cache_points[1])) {
                out.push_str(&format!(
                    "  p99 speedup {:<10} codec {:<10} {:.1}x \
                     ({:.1} us -> {:.1} us)\n",
                    kind.label(),
                    codec_name,
                    off.get_p99_us / on.get_p99_us.max(1e-9),
                    off.get_p99_us,
                    on.get_p99_us,
                ));
            }
        }
    }

    let csv: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{},{},{},{:.3},{:.2},{:.2},{:.4},{:.4},{:.6},{}",
                r.system,
                r.cache_blocks,
                r.codec,
                r.read_kops,
                r.get_p50_us,
                r.get_p99_us,
                r.blocks_per_get,
                r.cache_hit_rate,
                r.bloom_fpr,
                r.bytes_flushed,
            )
        })
        .collect();
    ctx.write_csv(
        "read_amp.csv",
        "system,cache_blocks,codec,read_kops,get_p50_us,get_p99_us,blocks_per_get,cache_hit_rate,bloom_fpr,bytes_flushed",
        &csv,
    )?;

    let json_rows: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                concat!(
                    "    {{\"system\": \"{}\", \"cache_blocks\": {}, ",
                    "\"codec\": \"{}\", \"read_kops\": {:.3}, ",
                    "\"get_p50_us\": {:.2}, \"get_p99_us\": {:.2}, ",
                    "\"blocks_per_get\": {:.4}, \"cache_hit_rate\": {:.4}, ",
                    "\"bloom_fpr\": {:.6}, \"bytes_flushed\": {}}}"
                ),
                r.system,
                r.cache_blocks,
                r.codec,
                r.read_kops,
                r.get_p50_us,
                r.get_p99_us,
                r.blocks_per_get,
                r.cache_hit_rate,
                r.bloom_fpr,
                r.bytes_flushed,
            )
        })
        .collect();
    let json = format!(
        concat!(
            "{{\n  \"schema\": \"kvaccel-readamp-v1\",\n",
            "  \"config\": {{\"workload\": \"C/ycsb-c read-only\", ",
            "\"loop_mode\": \"closed\", \"clients\": {}, ",
            "\"cache_points\": [0, {}], \"codecs\": [\"none\", \"lz-like:50\"], ",
            "\"key_space\": {}, \"scale\": {}, \"seed\": {}}},\n",
            "  \"rows\": [\n{}\n  ]\n}}\n"
        ),
        CLIENTS,
        cache_points[1],
        key_space,
        ctx.scale,
        ctx.seed,
        json_rows.join(",\n"),
    );
    std::fs::create_dir_all(&ctx.out_dir)?;
    std::fs::write(ctx.out_dir.join("BENCH_PR7.json"), json)?;

    out.push_str(
        "  shape check: the warmed default cache turns steady-state gets \
         into probe-cost hits (p99 >= 2x better than cache-off on every \
         system); compression shrinks flushed bytes and repacks blocks, \
         trading decompress CPU for device reads\n",
    );
    ctx.log(&out);
    Ok(out)
}
