//! PR5 shard-scale experiment: aggregate throughput, tail latency and
//! Eq. 1 efficiency vs shard count for LSM vs KVACCEL on one shared
//! dual-interface SSD.
//!
//! A fixed closed-loop client population (8 writers) drives workload A
//! against 1/2/4/8 range-partitioned shards. Sharding divides the ingest
//! each child LSM absorbs, so stall pressure drops with shard count; on
//! KVACCEL the shards additionally compete for the one device write
//! buffer, which is where the grant arbiter earns its keep — redirection
//! capacity follows whichever shard is stalling, and the aggregate must
//! scale without `stall_anomalies`.
//!
//! Emits `results/shard_scale.csv` and the machine-readable
//! `results/BENCH_PR5.json` built in CI.

use anyhow::Result;

use crate::baselines::SystemKind;
use crate::engine::{EngineBuilder, EngineStats};
use crate::env::SimEnv;
use crate::kvaccel::RollbackScheme;
use crate::lsm::LsmOptions;
use crate::shard::ShardPolicy;
use crate::ssd::SsdConfig;
use crate::workload::{self, BenchConfig, KeyDist, LoopMode};

use super::ExpContext;

struct Row {
    system: String,
    shards: usize,
    write_kops: f64,
    write_mbps: f64,
    p99_us: f64,
    efficiency: f64,
    stop_events: u64,
    stopped_s: f64,
    stall_anomalies: u64,
    redirected: u64,
    rebalances: u64,
}

const CLIENTS: usize = 8;
const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 8];

pub fn shard_scale(ctx: &ExpContext) -> Result<String> {
    let mut out = String::from(
        "== Shard scale: throughput/p99/efficiency vs shard count (shared device) ==\n",
    );
    let cfg = BenchConfig {
        seed: ctx.seed,
        key_space: 200_000,
        ..Default::default()
    }
    .scaled(ctx.scale);
    let mut rows: Vec<Row> = Vec::new();

    for kind in [
        SystemKind::RocksDb { slowdown: true },
        SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
    ] {
        for &n in &SHARD_COUNTS {
            // pressure-sized stores (as in the recovery experiment) so
            // stalls and redirection actually occur at CI scale
            let mut sys = EngineBuilder::new(kind)
                .opts(LsmOptions::small_for_test().with_threads(2))
                .merge_engine(ctx.merge_engine())
                .bloom_builder(ctx.bloom_builder())
                .sharded(n, ShardPolicy::Range)
                .shard_key_space(cfg.key_space)
                .build();
            let mut env = SimEnv::new(ctx.seed, SsdConfig::default());
            let mut spec = workload::preset_spec(
                "A",
                &cfg,
                CLIENTS,
                LoopMode::Closed { think: 0 },
                KeyDist::Uniform,
            )?;
            // bound the per-config op count so tiny-scale smoke runs
            // (and CI) finish fast; pressure-sized stores stall within
            // hundreds of ops, so the shapes survive the cap
            spec.stop_after_ops =
                Some(((800_000.0 * ctx.scale) as u64).clamp(8_000, 800_000));
            let r = workload::run_spec(&mut *sys, &mut env, &spec);
            let rebalances = sys
                .sharded()
                .map_or(0, |s| s.arbiter().stats.rebalances);
            let row = Row {
                system: kind.label(),
                shards: n,
                write_kops: r.write_kops(),
                write_mbps: r.write_mbps,
                p99_us: r.write_lat.p99_us,
                efficiency: r.efficiency,
                stop_events: r.stop_events,
                stopped_s: r.stopped_s,
                stall_anomalies: sys.db_stats().stall_anomalies,
                redirected: r.redirected_writes,
                rebalances,
            };
            out.push_str(&format!(
                "  {:<10} shards {:>2}  {:>8.1} Kops/s  p99 {:>9.1} us  \
                 eff {:>6.2}  {:>3} stops ({:>6.2}s)  {:>7} redirected  \
                 {:>2} rebalances  anomalies {}\n",
                row.system,
                row.shards,
                row.write_kops,
                row.p99_us,
                row.efficiency,
                row.stop_events,
                row.stopped_s,
                row.redirected,
                row.rebalances,
                row.stall_anomalies,
            ));
            rows.push(row);
        }
    }

    let csv: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{},{},{:.3},{:.3},{:.2},{:.4},{},{:.4},{},{},{}",
                r.system,
                r.shards,
                r.write_kops,
                r.write_mbps,
                r.p99_us,
                r.efficiency,
                r.stop_events,
                r.stopped_s,
                r.stall_anomalies,
                r.redirected,
                r.rebalances,
            )
        })
        .collect();
    ctx.write_csv(
        "shard_scale.csv",
        "system,shards,write_kops,write_mbps,p99_us,efficiency,stop_events,stopped_s,stall_anomalies,redirected,rebalances",
        &csv,
    )?;

    let json_rows: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                concat!(
                    "    {{\"system\": \"{}\", \"shards\": {}, ",
                    "\"write_kops\": {:.3}, \"write_mbps\": {:.3}, ",
                    "\"p99_us\": {:.2}, \"efficiency\": {:.4}, ",
                    "\"stop_events\": {}, \"stopped_s\": {:.4}, ",
                    "\"stall_anomalies\": {}, \"redirected\": {}, ",
                    "\"rebalances\": {}}}"
                ),
                r.system,
                r.shards,
                r.write_kops,
                r.write_mbps,
                r.p99_us,
                r.efficiency,
                r.stop_events,
                r.stopped_s,
                r.stall_anomalies,
                r.redirected,
                r.rebalances,
            )
        })
        .collect();
    let json = format!(
        concat!(
            "{{\n  \"schema\": \"kvaccel-shardscale-v1\",\n",
            "  \"config\": {{\"workload\": \"A/fillrandom\", \"loop_mode\": \"closed\", ",
            "\"clients\": {}, \"shard_policy\": \"range\", \"shard_counts\": [1, 2, 4, 8], ",
            "\"key_space\": {}, \"scale\": {}, \"seed\": {}}},\n",
            "  \"rows\": [\n{}\n  ]\n}}\n"
        ),
        CLIENTS,
        cfg.key_space,
        ctx.scale,
        ctx.seed,
        json_rows.join(",\n"),
    );
    std::fs::create_dir_all(&ctx.out_dir)?;
    std::fs::write(ctx.out_dir.join("BENCH_PR5.json"), json)?;

    out.push_str(
        "  shape check: stall time per shard drops as the ingest spreads; \
         KVACCEL scales 1 -> 4 shards on the shared buffer with zero \
         stall anomalies (arbiter follows the hot shard)\n",
    );
    ctx.log(&out);
    Ok(out)
}
