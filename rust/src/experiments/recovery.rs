//! PR4 recovery experiment: crash-recovery time vs device write-buffer
//! fill across the headline systems.
//!
//! Each run writes a scaled burst against a pressure-sized store (small
//! memtables, like the conformance rigs, so KVACCEL actually redirects),
//! power-losses the engine at a fraction of the burst, reopens it via
//! `EngineBuilder::open`, and measures: virtual recovery time, WAL
//! records replayed, device keys re-routed, and the fraction of written
//! keys whose *latest* value is visible after recovery (the sync=false
//! ack-vs-durable gap makes this < 1 for the page-cached WAL tail; the
//! capacitor-backed device buffer keeps KVACCEL's redirected writes).
//!
//! Emits `results/recovery.csv` and the machine-readable
//! `results/BENCH_PR4.json` built in CI.

use std::collections::HashMap;

use anyhow::Result;

use crate::baselines::SystemKind;
use crate::engine::{EngineBuilder, EngineStats, KvEngine};
use crate::env::SimEnv;
use crate::kvaccel::RollbackScheme;
use crate::lsm::entry::{Key, ValueDesc};
use crate::lsm::LsmOptions;
use crate::sim::NS_PER_SEC;
use crate::ssd::SsdConfig;
use crate::workload::KeyGen;

use super::ExpContext;

struct Row {
    system: String,
    crash_frac: f64,
    ops: u64,
    dev_fill_bytes: u64,
    wal_replayed: u64,
    dev_rerouted: u64,
    recovery_ms: f64,
    latest_visible_frac: f64,
}

pub fn recovery(ctx: &ExpContext) -> Result<String> {
    let mut out = String::from(
        "== Recovery: crash-recovery time vs device write-buffer fill ==\n",
    );
    let total_ops = ((200_000.0 * ctx.scale) as u64).max(2_000);
    let key_space: Key = 50_000;
    let crash_fracs = [0.25, 0.5, 0.75, 1.0];
    let mut rows: Vec<Row> = Vec::new();

    for kind in [
        SystemKind::RocksDb { slowdown: true },
        SystemKind::Adoc,
        SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
    ] {
        for &frac in &crash_fracs {
            let ops = ((total_ops as f64) * frac) as u64;
            let mut sys = EngineBuilder::new(kind)
                .opts(LsmOptions::small_for_test().with_threads(2))
                .merge_engine(ctx.merge_engine())
                .bloom_builder(ctx.bloom_builder())
                .build();
            let mut env = SimEnv::new(ctx.seed, SsdConfig::default());
            let mut gen = KeyGen::new(ctx.seed ^ 0x4EC0, key_space, 4096);
            let mut latest: HashMap<Key, ValueDesc> = HashMap::new();
            let mut t = 0;
            for op in 0..ops {
                let k = gen.write_key();
                let v = gen.value_for(k, op);
                t = sys.put(&mut env, t, k, v).done;
                latest.insert(k, v);
            }
            let dev_fill = env.device.kv_buffered_bytes(0);
            let image = sys.crash(&mut env, t);
            let (mut sys2, t_rec) = EngineBuilder::open(&mut env, t, image).expect("recovery failed");
            let h = sys2.health();
            // probe: is the latest acked value of each written key
            // visible after recovery? (< 1.0 shows the sync=false gap)
            let mut t2 = t_rec;
            let mut hits = 0u64;
            let mut probes: Vec<(Key, ValueDesc)> = latest
                .iter()
                .filter(|(k, _)| *k % 17 == 0)
                .map(|(&k, &v)| (k, v))
                .collect();
            probes.sort_unstable_by_key(|&(k, _)| k);
            for &(k, v) in &probes {
                let (got, nt) = sys2.get(&mut env, t2, k);
                t2 = nt;
                if got == Some(v) {
                    hits += 1;
                }
            }
            let visible = if probes.is_empty() {
                1.0
            } else {
                hits as f64 / probes.len() as f64
            };
            let recovery_ms = (t_rec.saturating_sub(t)) as f64
                / (NS_PER_SEC as f64 / 1e3);
            out.push_str(&format!(
                "  {:<10} crash@{:>4.0}%  dev fill {:>7.2} MB  replayed {:>6}  \
                 rerouted {:>6}  recovery {:>8.3} ms  latest visible {:>5.1}%\n",
                kind.label(),
                frac * 100.0,
                dev_fill as f64 / (1 << 20) as f64,
                h.recovered_wal_records,
                h.recovered_dev_keys,
                recovery_ms,
                visible * 100.0,
            ));
            rows.push(Row {
                system: kind.label(),
                crash_frac: frac,
                ops,
                dev_fill_bytes: dev_fill,
                wal_replayed: h.recovered_wal_records,
                dev_rerouted: h.recovered_dev_keys,
                recovery_ms,
                latest_visible_frac: visible,
            });
        }
    }

    let csv: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{},{},{},{},{},{},{:.4},{:.4}",
                r.system,
                r.crash_frac,
                r.ops,
                r.dev_fill_bytes,
                r.wal_replayed,
                r.dev_rerouted,
                r.recovery_ms,
                r.latest_visible_frac,
            )
        })
        .collect();
    ctx.write_csv(
        "recovery.csv",
        "system,crash_frac,ops,dev_fill_bytes,wal_replayed,dev_rerouted,recovery_ms,latest_visible_frac",
        &csv,
    )?;

    // machine-readable artifact for the CI perf trajectory
    let json_rows: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                concat!(
                    "    {{\"system\": \"{}\", \"crash_frac\": {}, \"ops\": {}, ",
                    "\"dev_fill_bytes\": {}, \"wal_replayed\": {}, ",
                    "\"dev_rerouted\": {}, \"recovery_ms\": {:.4}, ",
                    "\"latest_visible_frac\": {:.4}}}"
                ),
                r.system,
                r.crash_frac,
                r.ops,
                r.dev_fill_bytes,
                r.wal_replayed,
                r.dev_rerouted,
                r.recovery_ms,
                r.latest_visible_frac,
            )
        })
        .collect();
    let json = format!(
        concat!(
            "{{\n  \"schema\": \"kvaccel-recovery-v1\",\n",
            "  \"config\": {{\"total_ops\": {}, \"key_space\": {}, ",
            "\"scale\": {}, \"seed\": {}}},\n",
            "  \"rows\": [\n{}\n  ]\n}}\n"
        ),
        total_ops,
        key_space,
        ctx.scale,
        ctx.seed,
        json_rows.join(",\n"),
    );
    std::fs::create_dir_all(&ctx.out_dir)?;
    std::fs::write(ctx.out_dir.join("BENCH_PR4.json"), json)?;

    out.push_str(
        "  shape check: recovery time grows with the crash point; KVACCEL adds \
         the device rescan but loses no redirected write\n",
    );
    ctx.log(&out);
    Ok(out)
}
