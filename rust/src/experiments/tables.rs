//! Table reproductions (Table V: range-query throughput; Table VI:
//! module overheads).
//!
//! Table VI reports host CPU overheads, so this file measures real
//! elapsed time: the wall-clock ban (pallas-lint no-wall-clock,
//! clippy.toml disallowed-methods/types) is lifted here and only here.
#![allow(clippy::disallowed_methods, clippy::disallowed_types)]

use std::time::Instant;

use anyhow::Result;

use crate::baselines::SystemKind;
use crate::engine::KvEngine;
use crate::env::SimEnv;
use crate::kvaccel::{
    Detector, DetectorConfig, MetadataConfig, MetadataManager, RollbackScheme,
};
use crate::lsm::{LsmOptions, LsmDb};
use crate::runtime::{BloomBuilder, MergeEngine};
use crate::ssd::SsdConfig;
use crate::workload::{preload, seekrandom};

use super::ExpContext;

/// Table V: range-query throughput for workload D (seekrandom, Seek +
/// 1024 Next, after a 20 GB fillrandom preload).
/// Paper: RocksDB 302 Kops/s, ADOC 351, KVACCEL 100.
pub fn table5(ctx: &ExpContext) -> Result<String> {
    let mut out = String::from("== Table V: range query throughput (workload D) ==\n");
    let preload_bytes = ((20u64 << 30) as f64 * ctx.scale) as u64;
    let seeks = ((60_000) as f64 * ctx.scale).max(20.0) as usize;
    let mut csv = Vec::new();
    for kind in [
        SystemKind::RocksDb { slowdown: true },
        SystemKind::Adoc,
        // KVACCEL arrives at workload D with redirected pairs still in
        // the Dev-LSM (rollback deferred, as the paper's setup implies —
        // Dev-LSM point/range reads are uncached).
        SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
    ] {
        let (mut sys, mut env) = ctx.build_system(kind, 4);
        let cfg = ctx.bench_config();
        let t0 = preload(&mut *sys, &mut env, &cfg, preload_bytes)?;
        // leave residue in the Dev-LSM for KVACCEL: preload's finish()
        // drained it, so push a post-preload burst that redirects
        let t0 = if kind == (SystemKind::Kvaccel { scheme: RollbackScheme::Disabled }) {
            let burst = crate::workload::BenchConfig {
                duration: t0 + cfg.duration / 20,
                ..cfg.clone()
            };
            let mut t = t0;
            let mut gen = crate::workload::KeyGen::new(
                cfg.seed ^ 0xB00, cfg.key_space, cfg.value_size,
            );
            let mut op = 0;
            while t < burst.duration {
                let k = gen.random_key();
                let v = gen.value_for(k, op);
                t = sys.put(&mut env, t, k, v).done;
                op += 1;
            }
            t
        } else {
            t0
        };
        let r = seekrandom(&mut *sys, &mut env, &cfg, seeks, 1024, t0);
        let kops = r.reads.total as f64 / r.duration_s.max(1e-9) / 1e3;
        out.push_str(&format!(
            "  {:<10} {:>8.0} Kops/s   (paper: {})\n",
            kind.label(),
            kops,
            match kind {
                SystemKind::RocksDb { .. } => "302",
                SystemKind::Adoc => "351",
                _ => "100",
            }
        ));
        csv.push(format!("{},{kops:.1}", kind.label()));
    }
    ctx.write_csv("table5.csv", "system,range_kops", &csv)?;
    out.push_str("  shape check: KVACCEL markedly slower (no Dev-LSM read cache), others comparable\n");
    ctx.log(&out);
    Ok(out)
}

/// Table VI: wall-clock measured overheads of the KVACCEL modules on this
/// host (paper on their Xeon: detector 1.37 us, insert 0.45, check 0.20,
/// delete 0.28).
pub fn table6(ctx: &ExpContext) -> Result<String> {
    let mut out = String::from("== Table VI: module overheads (measured wall-clock) ==\n");
    let mut env = SimEnv::new(1, SsdConfig::default());
    let mut db = LsmDb::new(
        LsmOptions::small_for_test(),
        MergeEngine::rust(),
        BloomBuilder::rust(),
    );
    // put some state into the store so the detector reads real signals
    let mut t = 0;
    for k in 0..2000u32 {
        t = db
            .put(&mut env, t, k, crate::lsm::ValueDesc::new(k, 4096))
            .done;
    }
    let iters = 100_000u32;

    let mut det = Detector::new(DetectorConfig::default());
    let start = Instant::now();
    for i in 0..iters {
        det.sample(&mut env, t + i as u64, &db);
    }
    let detector_us = start.elapsed().as_secs_f64() * 1e6 / iters as f64;

    let mut meta = MetadataManager::new(MetadataConfig::default());
    let start = Instant::now();
    for i in 0..iters {
        meta.insert(&mut env, t, i);
    }
    let insert_us = start.elapsed().as_secs_f64() * 1e6 / iters as f64;
    let start = Instant::now();
    for i in 0..iters {
        std::hint::black_box(meta.check(&mut env, t, i));
    }
    let check_us = start.elapsed().as_secs_f64() * 1e6 / iters as f64;
    let start = Instant::now();
    for i in 0..iters {
        meta.delete(&mut env, t, i);
    }
    let delete_us = start.elapsed().as_secs_f64() * 1e6 / iters as f64;

    let rows = [
        ("Detector", detector_us, 1.37),
        ("Key Insert", insert_us, 0.45),
        ("Key Check", check_us, 0.20),
        ("Key Delete", delete_us, 0.28),
    ];
    let mut csv = Vec::new();
    for (name, got, paper) in rows {
        out.push_str(&format!(
            "  {name:<12} {got:>7.3} us   (paper: {paper} us)\n"
        ));
        csv.push(format!("{name},{got:.4},{paper}"));
    }
    ctx.write_csv("table6.csv", "operation,measured_us,paper_us", &csv)?;
    out.push_str("  shape check: all sub-2 us; check < delete < insert ordering\n");
    ctx.log(&out);
    Ok(out)
}
