//! PR8 replication experiment: CDC shipping lag under open-loop
//! overload, failover blackout after a primary crash, and Merkle
//! anti-entropy repair volume vs a full resync — for each headline
//! primary engine (LSM / ADOC / KVACCEL).
//!
//! Three simulated nodes replicate workload A over a deliberately
//! modest link (100 us, 128 MiB/s), so an offered rate the primary
//! absorbs faster than the link drains shows up as replica lag. The
//! run then crashes the primary mid-stream, promotes the most
//! caught-up replica, writes a divergence burst on the new primary,
//! and rejoins the crashed node through the Merkle range exchange.
//!
//! Emits `results/repl_lag.csv` and the machine-readable
//! `results/BENCH_PR8.json` built in CI.

use anyhow::Result;

use crate::engine::{EngineBuilder, KvEngine};
use crate::env::SimEnv;
use crate::lsm::entry::{Key, ValueDesc};
use crate::lsm::LsmOptions;
use crate::repl::{ReplConfig, ReplicatedDb};
use crate::sim::MILLIS;
use crate::ssd::SsdConfig;
use crate::workload::{self, BenchConfig, KeyDist, LoopMode};

use super::{headline_systems, ExpContext};

struct Row {
    system: String,
    write_kops: f64,
    p99_us: f64,
    max_lag: u64,
    mean_lag: f64,
    shipped_bytes: u64,
    promoted: usize,
    blackout_ms: f64,
    lost_records: u64,
    ae_bytes: u64,
    full_resync_bytes: u64,
    repaired: bool,
}

const CLIENTS: usize = 4;
const RATE: f64 = 30_000.0;
const REPLICAS: usize = 3;
const LINK_LATENCY: u64 = 100_000; // 100 us one way
const LINK_MBPS: f64 = 128.0;

pub fn repl_lag(ctx: &ExpContext) -> Result<String> {
    let mut out = String::from(
        "== Replication: CDC lag under overload, failover blackout, \
         anti-entropy vs full resync ==\n",
    );
    let cfg = BenchConfig {
        seed: ctx.seed,
        key_space: 200_000,
        ..Default::default()
    }
    .scaled(ctx.scale);
    let mut rows: Vec<Row> = Vec::new();

    for kind in headline_systems() {
        let rcfg = ReplConfig {
            replicas: REPLICAS,
            link_latency: LINK_LATENCY,
            link_mbps: LINK_MBPS,
            key_space: cfg.key_space,
            seed: ctx.seed,
            ..ReplConfig::default()
        };
        // pressure-sized stores (as in shard-scale) so stalls and
        // redirection occur at CI scale on the primary
        let mut repl = ReplicatedDb::new(rcfg, |_| {
            EngineBuilder::new(kind)
                .opts(LsmOptions::small_for_test().with_threads(2))
                .merge_engine(ctx.merge_engine())
                .bloom_builder(ctx.bloom_builder())
                .build()
        });
        let mut env = SimEnv::new(ctx.seed, SsdConfig::default());

        // phase 1: open-loop overload; replicas tail the CDC stream
        // over the slow link, so applied watermarks fall behind the log
        let mut spec = workload::preset_spec(
            "A",
            &cfg,
            CLIENTS,
            LoopMode::OpenFixed { ops_per_sec: RATE },
            KeyDist::Uniform,
        )?;
        spec.stop_after_ops =
            Some(((400_000.0 * ctx.scale) as u64).clamp(4_000, 400_000));
        let r = workload::run_spec(&mut repl, &mut env, &spec);
        let rep1 = r.replication.clone().expect("replicated run");
        let followers: Vec<_> = rep1
            .replicas
            .iter()
            .filter(|n| n.role == "replica")
            .collect();
        let max_lag = followers.iter().map(|n| n.max_lag).max().unwrap_or(0);
        let mean_lag = if followers.is_empty() {
            0.0
        } else {
            followers.iter().map(|n| n.mean_lag).sum::<f64>()
                / followers.len() as f64
        };

        // phase 2: crash the primary mid-stream and promote; batches on
        // the wire still land, the election window gates new writes
        let t_crash = env.now();
        let fo = repl.fail_primary(&mut env, t_crash);

        // phase 3: diverge the new primary past the dead node's state,
        // then rejoin the crashed node through the Merkle exchange
        let burst = (spec.stop_after_ops.unwrap() / 8).max(500);
        let mut t = env.now();
        for i in 0..burst {
            let key =
                (i.wrapping_mul(2_654_435_761) % cfg.key_space as u64) as Key;
            t = repl.put(&mut env, t, key, ValueDesc::new(i as u32, 512)).done;
        }
        let repair = repl.rejoin_crashed(&mut env, t).expect("rejoin failed");
        let t_end = repl.finish(&mut env, repair.done.max(t))?;
        let repaired = repl.node_digest(&mut env, t_end, fo.crashed)
            == repl.node_digest(&mut env, t_end, repl.primary_index());
        let rep = repl.results();

        let row = Row {
            system: kind.label(),
            write_kops: r.write_kops(),
            p99_us: r.write_lat.p99_us,
            max_lag,
            mean_lag,
            shipped_bytes: rep1.shipped_bytes,
            promoted: fo.promoted,
            blackout_ms: fo.blackout_ns as f64 / MILLIS as f64,
            lost_records: fo.lag_records,
            ae_bytes: rep.anti_entropy_bytes,
            full_resync_bytes: rep.full_resync_bytes,
            repaired,
        };
        out.push_str(&format!(
            "  {:<10} {:>8.1} Kops/s  p99 {:>9.1} us  lag max {:>6} / \
             mean {:>8.1}  blackout {:>7.2} ms (node {} promoted, {} lost)  \
             anti-entropy {:>10} B vs {:>10} B resync  repaired {}\n",
            row.system,
            row.write_kops,
            row.p99_us,
            row.max_lag,
            row.mean_lag,
            row.blackout_ms,
            row.promoted,
            row.lost_records,
            row.ae_bytes,
            row.full_resync_bytes,
            row.repaired,
        ));
        rows.push(row);
    }

    let csv: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{},{:.3},{:.2},{},{:.3},{},{},{:.4},{},{},{},{}",
                r.system,
                r.write_kops,
                r.p99_us,
                r.max_lag,
                r.mean_lag,
                r.shipped_bytes,
                r.promoted,
                r.blackout_ms,
                r.lost_records,
                r.ae_bytes,
                r.full_resync_bytes,
                r.repaired,
            )
        })
        .collect();
    ctx.write_csv(
        "repl_lag.csv",
        "system,write_kops,p99_us,max_lag,mean_lag,shipped_bytes,promoted,blackout_ms,lost_records,anti_entropy_bytes,full_resync_bytes,repaired",
        &csv,
    )?;

    let json_rows: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                concat!(
                    "    {{\"system\": \"{}\", \"write_kops\": {:.3}, ",
                    "\"p99_us\": {:.2}, \"max_lag\": {}, \"mean_lag\": {:.3}, ",
                    "\"shipped_bytes\": {}, \"promoted\": {}, ",
                    "\"blackout_ms\": {:.4}, \"lost_records\": {}, ",
                    "\"anti_entropy_bytes\": {}, \"full_resync_bytes\": {}, ",
                    "\"repaired\": {}}}"
                ),
                r.system,
                r.write_kops,
                r.p99_us,
                r.max_lag,
                r.mean_lag,
                r.shipped_bytes,
                r.promoted,
                r.blackout_ms,
                r.lost_records,
                r.ae_bytes,
                r.full_resync_bytes,
                r.repaired,
            )
        })
        .collect();
    let json = format!(
        concat!(
            "{{\n  \"schema\": \"kvaccel-repllag-v1\",\n",
            "  \"config\": {{\"workload\": \"A/fillrandom\", ",
            "\"loop_mode\": \"open\", \"rate_ops_s\": {}, \"clients\": {}, ",
            "\"replicas\": {}, \"link_latency_ns\": {}, \"link_mbps\": {}, ",
            "\"key_space\": {}, \"scale\": {}, \"seed\": {}}},\n",
            "  \"rows\": [\n{}\n  ]\n}}\n"
        ),
        RATE,
        CLIENTS,
        REPLICAS,
        LINK_LATENCY,
        LINK_MBPS,
        cfg.key_space,
        ctx.scale,
        ctx.seed,
        json_rows.join(",\n"),
    );
    std::fs::create_dir_all(&ctx.out_dir)?;
    std::fs::write(ctx.out_dir.join("BENCH_PR8.json"), json)?;

    out.push_str(
        "  shape check: replica lag grows with the primary's ingest rate \
         (the link is the bottleneck, not the engine); every repair ships \
         strictly fewer bytes than a full resync and converges the digests\n",
    );
    ctx.log(&out);
    Ok(out)
}
