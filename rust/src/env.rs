//! Shared simulation environment threaded through every operation: the
//! virtual clock, CPU accounting, deterministic RNG, and the one
//! dual-interface SSD.

use crate::sim::{Clock, CpuAccounting, SimRng};
use crate::ssd::{SsdConfig, SsdDevice};

pub struct SimEnv {
    pub clock: Clock,
    pub cpu: CpuAccounting,
    pub rng: SimRng,
    pub device: SsdDevice,
}

impl SimEnv {
    pub fn new(seed: u64, ssd: SsdConfig) -> Self {
        Self {
            clock: Clock::new(),
            cpu: CpuAccounting::new(),
            rng: SimRng::new(seed),
            device: SsdDevice::new(ssd),
        }
    }

    pub fn now(&self) -> crate::sim::Nanos {
        self.clock.now()
    }
}
