//! First-class snapshots and cursor-based iterators — the read-path
//! counterpart of the unified `KvEngine` write API.
//!
//! A [`Snapshot`] *pins* a point-in-time view by refcount: the memtable
//! and immutable runs are materialized once at snapshot creation, SSTs
//! and Dev-LSM runs are shared `Arc`s, and the KVACCEL metadata routing
//! table (the cross-interface recency authority) is captured as a pinned
//! key set. Because every source is either immutable-by-construction or
//! owned by the snapshot, background flushes, compactions and even a
//! KVACCEL rollback (which resets the device buffer and clears the
//! metadata table) cannot drop versions a live snapshot still sees —
//! the `Arc` refcount keeps them alive until the last iterator drops.
//!
//! An [`EngineIterator`] is the paper's Fig 10 aggregated range scan as
//! a *cursor*: one seekable/reversible merging iterator over the host
//! LSM plus (on KVACCEL) the `DevIterator` over the in-device write
//! buffer, switching interfaces at key-order crossovers. Every movement
//! op charges simulated latency — per-Next CPU, block-cache-aware SST
//! block reads on the host side, amortized NAND page reads on the
//! device side — and feeds the read-amplification counters
//! ([`ScanCounters`]) surfaced through `EngineStats`.

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};

use crate::env::SimEnv;
use crate::kvaccel::range_query::DevIterator;
use crate::lsm::entry::{Entry, Key, Seq, ValueDesc, MAX_USER_KEY};
use crate::lsm::iterator::LsmIterator;
use crate::lsm::sst::Sst;
use crate::lsm::LsmOptions;
use crate::sim::{CpuClass, Nanos};
use crate::util::LruCache;
use crate::vlog::VLOG_RECORD_HEADER;

// ---------------------------------------------------------------------
// Read-amplification accounting
// ---------------------------------------------------------------------

/// Engine-lifetime cursor counters (shared by every iterator the engine
/// hands out; `Arc` so iterators stay detached from the engine borrow).
#[derive(Debug, Default)]
pub struct ScanCounters {
    pub seeks: AtomicU64,
    pub nexts: AtomicU64,
    /// SST data blocks touched by Main-LSM cursors.
    pub main_blocks: AtomicU64,
    /// NAND pages read by Dev-LSM cursors (KVACCEL only).
    pub dev_pages: AtomicU64,
    /// Value-log blocks touched dereferencing separated values
    /// (key-value separation only).
    pub vlog_blocks: AtomicU64,
}

impl ScanCounters {
    pub fn snapshot(&self) -> ScanAmp {
        ScanAmp {
            seeks: self.seeks.load(Ordering::Relaxed),
            nexts: self.nexts.load(Ordering::Relaxed),
            main_blocks: self.main_blocks.load(Ordering::Relaxed),
            dev_pages: self.dev_pages.load(Ordering::Relaxed),
            vlog_blocks: self.vlog_blocks.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time copy of [`ScanCounters`] — Table V's per-interface
/// read amplification: blocks (host) and pages (device) touched per
/// Next().
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ScanAmp {
    pub seeks: u64,
    pub nexts: u64,
    pub main_blocks: u64,
    pub dev_pages: u64,
    pub vlog_blocks: u64,
}

impl ScanAmp {
    pub fn main_blocks_per_next(&self) -> f64 {
        if self.nexts == 0 {
            0.0
        } else {
            self.main_blocks as f64 / self.nexts as f64
        }
    }

    pub fn vlog_blocks_per_next(&self) -> f64 {
        if self.nexts == 0 {
            0.0
        } else {
            self.vlog_blocks as f64 / self.nexts as f64
        }
    }

    pub fn dev_pages_per_next(&self) -> f64 {
        if self.nexts == 0 {
            0.0
        } else {
            self.dev_pages as f64 / self.nexts as f64
        }
    }
}

// ---------------------------------------------------------------------
// Snapshots
// ---------------------------------------------------------------------

/// The Dev-LSM half of a KVACCEL snapshot: the device write buffer's
/// runs (run 0 is the materialized device memtable) plus the metadata
/// routing set pinned at snapshot time.
#[derive(Clone, Debug)]
pub struct DevPin {
    pub runs: Vec<Arc<Vec<Entry>>>,
    /// Keys whose latest version lived in the Dev-LSM at snapshot time.
    pub live: Arc<BTreeSet<Key>>,
    /// NAND page size (amortized read granularity for Dev-LSM Next()s).
    pub page_bytes: u64,
    /// Average encoded entry size (entries per page estimate).
    pub avg_entry: u64,
}

/// Pinned state backing a [`Snapshot`]; immutable once built.
#[derive(Debug)]
pub struct SnapshotInner {
    /// Highest Main-LSM sequence number visible to this snapshot.
    pub seq: Seq,
    /// Highest Dev-LSM sequence number visible (0 without a device pin).
    pub dev_seq: Seq,
    pub taken_at: Nanos,
    /// Materialized memtable + immutable runs, newest first.
    pub mem_runs: Vec<Arc<Vec<Entry>>>,
    /// L0 tables, newest first.
    pub l0: Vec<Arc<Sst>>,
    /// Levels 1..N (disjoint, key-sorted).
    pub levels: Vec<Vec<Arc<Sst>>>,
    pub dev: Option<DevPin>,
    /// Sharded-store snapshot: one pinned child snapshot per shard, all
    /// taken at the same virtual instant (the coherent sequence
    /// horizon). Empty for single-shard engines, whose state lives in
    /// the flat fields above.
    pub shards: Vec<Snapshot>,
}

/// A refcounted, sequence-number-stamped pinned view of an engine.
/// Cloning is cheap (`Arc`); the pin releases when the last clone and
/// every iterator reading through it drop.
#[derive(Clone, Debug)]
pub struct Snapshot {
    inner: Arc<SnapshotInner>,
}

impl Snapshot {
    #[allow(clippy::too_many_arguments)]
    pub fn pin(
        seq: Seq,
        dev_seq: Seq,
        taken_at: Nanos,
        mem_runs: Vec<Arc<Vec<Entry>>>,
        l0: Vec<Arc<Sst>>,
        levels: Vec<Vec<Arc<Sst>>>,
        dev: Option<DevPin>,
    ) -> Self {
        Self {
            inner: Arc::new(SnapshotInner {
                seq,
                dev_seq,
                taken_at,
                mem_runs,
                l0,
                levels,
                dev,
                shards: Vec::new(),
            }),
        }
    }

    /// Pin a sharded-store view from per-shard snapshots taken at one
    /// virtual instant. The composite `seq` is the highest child horizon
    /// (shards have independent sequence domains; coherence comes from
    /// the shared instant, not a shared counter).
    pub fn pin_sharded(taken_at: Nanos, shards: Vec<Snapshot>) -> Self {
        let seq = shards.iter().map(|s| s.seq()).max().unwrap_or(0);
        let dev_seq = shards.iter().map(|s| s.inner.dev_seq).max().unwrap_or(0);
        Self {
            inner: Arc::new(SnapshotInner {
                seq,
                dev_seq,
                taken_at,
                mem_runs: Vec::new(),
                l0: Vec::new(),
                levels: Vec::new(),
                dev: None,
                shards,
            }),
        }
    }

    pub fn seq(&self) -> Seq {
        self.inner.seq
    }

    pub fn taken_at(&self) -> Nanos {
        self.inner.taken_at
    }

    /// Does this snapshot pin device-buffer state (KVACCEL)?
    pub fn spans_device(&self) -> bool {
        self.inner.dev.is_some()
            || self.inner.shards.iter().any(|s| s.spans_device())
    }

    pub fn inner(&self) -> &SnapshotInner {
        &self.inner
    }

    pub(crate) fn downgrade(&self) -> Weak<SnapshotInner> {
        Arc::downgrade(&self.inner)
    }
}

// ---------------------------------------------------------------------
// Iterator options + trait
// ---------------------------------------------------------------------

/// Options for [`crate::engine::KvEngine::iter`]: key bounds, initial
/// direction, and an optional pre-pinned snapshot (without one, the
/// engine pins a fresh snapshot at iterator creation).
#[derive(Clone, Debug, Default)]
pub struct IterOptions {
    /// Inclusive lower key bound.
    pub lower_bound: Option<Key>,
    /// Exclusive upper key bound (RocksDB's `iterate_upper_bound`).
    pub upper_bound: Option<Key>,
    /// Mirror the cursor's movement ops: on a reverse cursor `seek`
    /// floor-positions, `next` descends, and `seek_to_first` lands on
    /// the range's last entry — so generic Seek+Next drivers walk the
    /// range descending without changing their loop.
    pub reverse: bool,
    pub snapshot: Option<Snapshot>,
}

impl IterOptions {
    pub fn new() -> Self {
        Self::default()
    }

    /// Iterate `[lower, upper)`.
    pub fn range(lower: Key, upper: Key) -> Self {
        Self::new().lower(lower).upper(upper)
    }

    pub fn lower(mut self, key: Key) -> Self {
        self.lower_bound = Some(key);
        self
    }

    pub fn upper(mut self, key: Key) -> Self {
        self.upper_bound = Some(key);
        self
    }

    pub fn backward(mut self) -> Self {
        self.reverse = true;
        self
    }

    /// Read through a pinned snapshot instead of the live store.
    pub fn at(mut self, snap: &Snapshot) -> Self {
        self.snapshot = Some(snap.clone());
        self
    }
}

/// A RocksDB-shaped cursor over one engine. Movement ops take an issue
/// time and return the virtual completion time (per-op latency is
/// charged against the simulated CPU/device); accessors are free.
///
/// Iterators are *detached*: they own their pinned sources, so the
/// engine can keep serving writes — including flushes, compactions and
/// rollbacks — while a cursor is open, without invalidating it.
pub trait DbIterator {
    /// Position at the first visible entry with key >= `key`.
    fn seek(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> Nanos;
    /// Position at the first in-bounds entry.
    fn seek_to_first(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos;
    /// Position at the last in-bounds entry.
    fn seek_to_last(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos;
    /// Position at the last visible entry with key <= `key`.
    fn seek_for_prev(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> Nanos;
    /// Advance to the next visible entry (ascending key order).
    fn next(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos;
    /// Retreat to the previous visible entry.
    fn prev(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos;

    fn valid(&self) -> bool;
    fn entry(&self) -> Option<Entry>;
    fn key(&self) -> Option<Key> {
        self.entry().map(|e| e.key)
    }
    fn value(&self) -> Option<ValueDesc> {
        self.entry().map(|e| e.val)
    }

    /// Read-amplification incurred by *this* cursor so far.
    fn amp(&self) -> ScanAmp;
}

/// Latency model constants an iterator needs from the engine's options
/// (copied so the cursor stays detached from the engine borrow).
#[derive(Clone, Copy, Debug)]
pub struct IterCost {
    pub next_cpu_ns: Nanos,
    pub get_cpu_ns: Nanos,
    pub block_bytes: u64,
    /// On-disk size of one data block under the engine's codec (equals
    /// `block_bytes` when compression is off).
    pub disk_block_bytes: u64,
    /// CPU charged per block materialized off the device (0 when
    /// compression is off).
    pub decompress_cpu_ns: Nanos,
}

impl IterCost {
    pub fn from_opts(opts: &LsmOptions) -> Self {
        Self {
            next_cpu_ns: opts.next_cpu_ns,
            get_cpu_ns: opts.get_cpu_ns,
            block_bytes: opts.block_bytes,
            disk_block_bytes: opts.disk_bytes(opts.block_bytes),
            decompress_cpu_ns: opts.decompress_ns(),
        }
    }
}

/// The engine-wide block cache: one instance per engine, shared by the
/// point-read path (`get()`), every cursor the engine hands out, and —
/// on KVACCEL — the device write-buffer read path, so scans warm point
/// reads and vice versa. Keys are `(sst_id, block_idx)`; the device
/// buffer uses the reserved `sst_id == u64::MAX` namespace (SST ids are
/// monotonically allocated from 1 and never reused).
pub type SharedBlockCache = Arc<Mutex<LruCache<(u64, usize), ()>>>;

/// Reserved cache-key namespace for device write-buffer entries.
pub const DEV_CACHE_NS: u64 = u64::MAX;

/// Reserved cache-key namespace for value-log blocks; the block index
/// packs `(segment << 32) | block_within_segment` (segment ids and
/// per-segment block counts both fit 32 bits by construction).
pub const VLOG_CACHE_NS: u64 = u64::MAX - 1;

/// Cache key of one value-log block.
pub fn vlog_cache_key(segment: u32, block: u64) -> (u64, usize) {
    (VLOG_CACHE_NS, ((segment as usize) << 32) | (block as usize & 0xFFFF_FFFF))
}

/// `blocks == 0` builds a disabled cache: every probe misses and
/// inserts are dropped (hot paths skip the probe entirely).
pub fn new_block_cache(blocks: usize) -> SharedBlockCache {
    Arc::new(Mutex::new(LruCache::new(blocks)))
}

// ---------------------------------------------------------------------
// The engine iterator (Fig 10 aggregated cursor)
// ---------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Dir {
    Fwd,
    Bwd,
}

/// The concrete [`DbIterator`] every engine hands out: a merge of the
/// Main-LSM cursor ([`LsmIterator`]) and, when the snapshot pins device
/// state, the Dev-LSM cursor ([`DevIterator`]) — the comparator
/// switches between the two interfaces as key order dictates, with the
/// pinned metadata set deciding cross-interface recency.
pub struct EngineIterator {
    main: LsmIterator,
    dev: Option<DevIterator>,
    live: Option<Arc<BTreeSet<Key>>>,
    snap: Snapshot,

    lower: Option<Key>,
    upper: Option<Key>,
    reverse: bool,
    dir: Dir,
    current: Option<Entry>,

    next_cpu_ns: Nanos,
    get_cpu_ns: Nanos,
    block_bytes: u64,
    disk_block_bytes: u64,
    decompress_cpu_ns: Nanos,
    /// Engine-wide block cache, shared with the engine's point-read
    /// path and every other cursor it hands out: scans warm point reads
    /// and vice versa.
    cache: SharedBlockCache,

    counters: Arc<ScanCounters>,
    local: ScanAmp,
    dev_pages_synced: u64,
}

impl EngineIterator {
    pub fn new(
        snap: Snapshot,
        opts: &IterOptions,
        cost: IterCost,
        counters: Arc<ScanCounters>,
        cache: SharedBlockCache,
    ) -> Self {
        let inner = snap.inner();
        let main = LsmIterator::from_runs(
            inner.mem_runs.clone(),
            inner.l0.clone(),
            inner.levels.clone(),
        )
        .with_visible_seq(inner.seq)
        .with_tombstones(true);
        let (dev, live) = match &inner.dev {
            Some(pin) => (
                Some(
                    DevIterator::new(pin.runs.clone(), pin.page_bytes, pin.avg_entry)
                        .with_visible_seq(inner.dev_seq),
                ),
                Some(pin.live.clone()),
            ),
            None => (None, None),
        };
        Self {
            main,
            dev,
            live,
            snap,
            lower: opts.lower_bound,
            upper: opts.upper_bound,
            reverse: opts.reverse,
            dir: Dir::Fwd,
            current: None,
            next_cpu_ns: cost.next_cpu_ns,
            get_cpu_ns: cost.get_cpu_ns,
            block_bytes: cost.block_bytes,
            disk_block_bytes: cost.disk_block_bytes,
            decompress_cpu_ns: cost.decompress_cpu_ns,
            cache,
            counters,
            local: ScanAmp::default(),
            dev_pages_synced: 0,
        }
    }

    pub fn snapshot(&self) -> &Snapshot {
        &self.snap
    }

    fn dev_live(&self, key: Key) -> bool {
        self.live.as_ref().is_some_and(|s| s.contains(&key))
    }

    /// Charge every Main-LSM block touched since the last drain: a
    /// cursor-cache hit costs CPU only, a miss reads through the device.
    fn charge_main_blocks(&mut self, env: &mut SimEnv, mut t: Nanos) -> Nanos {
        for (sst, block) in self.main.drain_blocks() {
            self.local.main_blocks += 1;
            self.counters.main_blocks.fetch_add(1, Ordering::Relaxed);
            let mut cache = self.cache.lock().expect("block cache poisoned");
            if cache.capacity() > 0 && cache.get(&(sst, block)).is_some() {
                env.cpu.charge(CpuClass::Foreground, t, self.get_cpu_ns / 2);
                t += self.get_cpu_ns / 2;
            } else {
                t = env.device.read_block(t, self.disk_block_bytes);
                if self.decompress_cpu_ns > 0 {
                    env.cpu.charge(CpuClass::Foreground, t, self.decompress_cpu_ns);
                    t += self.decompress_cpu_ns;
                }
                cache.insert((sst, block), ());
            }
        }
        t
    }

    /// Dereference a separated value at the emit boundary: charge the
    /// vlog blocks its record spans (cache-aware, like SST blocks but
    /// counted separately — `ScanAmp::vlog_blocks`) and return the
    /// entry with its location stripped, so cursor consumers never see
    /// pointers.
    fn deref_vlog(&mut self, env: &mut SimEnv, mut t: Nanos, e: Entry) -> (Entry, Nanos) {
        let crate::lsm::entry::ValueLoc::Vlog { segment, offset } = e.val.loc else {
            return (e, t);
        };
        let bb = self.block_bytes.max(1);
        let first = offset as u64 / bb;
        let last = (offset as u64 + VLOG_RECORD_HEADER + e.val.len as u64 - 1) / bb;
        for block in first..=last {
            self.local.vlog_blocks += 1;
            self.counters.vlog_blocks.fetch_add(1, Ordering::Relaxed);
            let key = vlog_cache_key(segment, block);
            let mut cache = self.cache.lock().expect("block cache poisoned");
            if cache.capacity() > 0 && cache.get(&key).is_some() {
                env.cpu.charge(CpuClass::Foreground, t, self.get_cpu_ns / 2);
                t += self.get_cpu_ns / 2;
            } else {
                // vlog blocks are stored uncompressed (blind appends)
                t = env.device.read_block(t, self.block_bytes);
                cache.insert(key, ());
            }
        }
        (e.inline_value(), t)
    }

    /// Fold the Dev-LSM cursor's page-read counter into the shared
    /// engine counters.
    fn sync_dev_pages(&mut self) {
        if let Some(d) = &self.dev {
            let n = d.pages_read();
            let delta = n.saturating_sub(self.dev_pages_synced);
            if delta > 0 {
                self.local.dev_pages += delta;
                self.counters.dev_pages.fetch_add(delta, Ordering::Relaxed);
                self.dev_pages_synced = n;
            }
        }
    }

    fn count_seek(&mut self) {
        self.local.seeks += 1;
        self.counters.seeks.fetch_add(1, Ordering::Relaxed);
    }

    fn count_next(&mut self) {
        self.local.nexts += 1;
        self.counters.nexts.fetch_add(1, Ordering::Relaxed);
    }

    /// The Fig 10 comparator, ascending: emit from whichever interface
    /// holds the smaller key; on equal keys, the pinned metadata set
    /// decides which copy is the newest; tombstones and stale device
    /// copies are consumed silently.
    fn settle_fwd(&mut self, env: &mut SimEnv, mut t: Nanos) -> Nanos {
        self.current = None;
        loop {
            let m = self.main.entry();
            let d = self.dev.as_ref().and_then(|x| x.entry());
            // every future winner's key is >= the smallest head: once
            // that crosses the upper bound, stop without consuming the
            // (possibly long, possibly stale) out-of-range tails
            if let (Some(up), Some(head)) = (
                self.upper,
                match (d, m) {
                    (Some(de), Some(me)) => Some(de.key.min(me.key)),
                    (Some(de), None) => Some(de.key),
                    (None, Some(me)) => Some(me.key),
                    (None, None) => None,
                },
            ) {
                if head >= up {
                    return t;
                }
            }
            let winner = match (d, m) {
                (None, None) => return t,
                (Some(de), me) if me.map_or(true, |me| de.key <= me.key) => {
                    let same = me.is_some_and(|me| me.key == de.key);
                    let live = self.dev_live(de.key);
                    t = self.dev.as_mut().unwrap().step_forward(env, t);
                    self.sync_dev_pages();
                    if same {
                        let me = me.unwrap();
                        self.main.step_forward();
                        t = self.charge_main_blocks(env, t);
                        if live {
                            de
                        } else {
                            me
                        }
                    } else if live {
                        de
                    } else {
                        // stale device copy: a newer Main-LSM write owns
                        // this key; whatever the main side holds (possibly
                        // nothing, if the newer write was a compacted-away
                        // tombstone) is the truth.
                        continue;
                    }
                }
                (_, Some(me)) => {
                    self.main.step_forward();
                    t = self.charge_main_blocks(env, t);
                    me
                }
                (Some(_), None) => unreachable!("covered by the guard arm"),
            };
            if let Some(up) = self.upper {
                if winner.key >= up {
                    return t;
                }
            }
            if winner.val.is_tombstone() {
                continue;
            }
            let (winner, nt) = self.deref_vlog(env, t, winner);
            t = nt;
            self.current = Some(winner);
            return t;
        }
    }

    /// The comparator, descending (largest key wins).
    fn settle_bwd(&mut self, env: &mut SimEnv, mut t: Nanos) -> Nanos {
        self.current = None;
        loop {
            let m = self.main.entry();
            let d = self.dev.as_ref().and_then(|x| x.entry());
            // mirror of settle_fwd: heads only descend, so stop as soon
            // as the largest head falls below the lower bound
            if let (Some(lo), Some(head)) = (
                self.lower,
                match (d, m) {
                    (Some(de), Some(me)) => Some(de.key.max(me.key)),
                    (Some(de), None) => Some(de.key),
                    (None, Some(me)) => Some(me.key),
                    (None, None) => None,
                },
            ) {
                if head < lo {
                    return t;
                }
            }
            let winner = match (d, m) {
                (None, None) => return t,
                (Some(de), me) if me.map_or(true, |me| de.key >= me.key) => {
                    let same = me.is_some_and(|me| me.key == de.key);
                    let live = self.dev_live(de.key);
                    t = self.dev.as_mut().unwrap().step_backward(env, t);
                    self.sync_dev_pages();
                    if same {
                        let me = me.unwrap();
                        self.main.step_backward();
                        t = self.charge_main_blocks(env, t);
                        if live {
                            de
                        } else {
                            me
                        }
                    } else if live {
                        de
                    } else {
                        continue;
                    }
                }
                (_, Some(me)) => {
                    self.main.step_backward();
                    t = self.charge_main_blocks(env, t);
                    me
                }
                (Some(_), None) => unreachable!("covered by the guard arm"),
            };
            if let Some(lo) = self.lower {
                if winner.key < lo {
                    return t;
                }
            }
            if winner.val.is_tombstone() {
                continue;
            }
            let (winner, nt) = self.deref_vlog(env, t, winner);
            t = nt;
            self.current = Some(winner);
            return t;
        }
    }
}

impl EngineIterator {
    /// Position at the first visible entry with key >= `key`.
    fn seek_ascending(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> Nanos {
        self.count_seek();
        let key = match self.lower {
            Some(lo) => key.max(lo),
            None => key,
        };
        env.cpu.charge(CpuClass::Foreground, at, self.get_cpu_ns);
        let mut t = at + self.get_cpu_ns;
        self.main.seek(key);
        t = self.charge_main_blocks(env, t);
        if let Some(d) = &mut self.dev {
            t = d.seek(env, t, key);
        }
        self.sync_dev_pages();
        self.dir = Dir::Fwd;
        t = self.settle_fwd(env, t);
        env.clock.advance_to(t);
        t
    }

    /// Position at the last visible entry with key <= `key`.
    fn seek_descending(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> Nanos {
        self.count_seek();
        let mut key = key;
        if let Some(up) = self.upper {
            if up == 0 {
                self.current = None;
                return at;
            }
            key = key.min(up - 1);
        }
        if let Some(lo) = self.lower {
            if key < lo {
                self.current = None;
                return at;
            }
        }
        env.cpu.charge(CpuClass::Foreground, at, self.get_cpu_ns);
        let mut t = at + self.get_cpu_ns;
        self.main.seek_for_prev(key);
        t = self.charge_main_blocks(env, t);
        if let Some(d) = &mut self.dev {
            t = d.seek_for_prev(env, t, key);
        }
        self.sync_dev_pages();
        self.dir = Dir::Bwd;
        t = self.settle_bwd(env, t);
        env.clock.advance_to(t);
        t
    }

    fn first_in_bounds(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        let lo = self.lower.unwrap_or(0);
        self.seek_ascending(env, at, lo)
    }

    fn last_in_bounds(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        let hi = match self.upper {
            Some(0) => {
                self.current = None;
                return at;
            }
            Some(up) => up - 1,
            None => MAX_USER_KEY,
        };
        self.seek_descending(env, at, hi)
    }

    /// Advance toward larger keys.
    fn step_ascending(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        let Some(cur) = self.current else { return at };
        self.count_next();
        env.cpu.charge(CpuClass::Foreground, at, self.next_cpu_ns);
        let mut t = at + self.next_cpu_ns;
        if self.dir == Dir::Bwd {
            // direction switch: re-position both interfaces past the
            // current key
            if cur.key >= MAX_USER_KEY {
                self.current = None;
                return t;
            }
            let from = cur.key + 1;
            self.main.seek(from);
            t = self.charge_main_blocks(env, t);
            if let Some(d) = &mut self.dev {
                t = d.seek(env, t, from);
            }
            self.sync_dev_pages();
            self.dir = Dir::Fwd;
        }
        t = self.settle_fwd(env, t);
        env.clock.advance_to(t);
        t
    }

    /// Advance toward smaller keys.
    fn step_descending(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        let Some(cur) = self.current else { return at };
        self.count_next();
        env.cpu.charge(CpuClass::Foreground, at, self.next_cpu_ns);
        let mut t = at + self.next_cpu_ns;
        if self.dir == Dir::Fwd {
            if cur.key == 0 {
                self.current = None;
                return t;
            }
            let to = cur.key - 1;
            self.main.seek_for_prev(to);
            t = self.charge_main_blocks(env, t);
            if let Some(d) = &mut self.dev {
                t = d.seek_for_prev(env, t, to);
            }
            self.sync_dev_pages();
            self.dir = Dir::Bwd;
        }
        t = self.settle_bwd(env, t);
        env.clock.advance_to(t);
        t
    }
}

// A reverse cursor (`IterOptions::reverse`) mirrors every movement op,
// so a generic Seek + N×Next driver walks the range descending.
impl DbIterator for EngineIterator {
    fn seek(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> Nanos {
        if self.reverse {
            self.seek_descending(env, at, key)
        } else {
            self.seek_ascending(env, at, key)
        }
    }

    fn seek_to_first(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        if self.reverse {
            self.last_in_bounds(env, at)
        } else {
            self.first_in_bounds(env, at)
        }
    }

    fn seek_to_last(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        if self.reverse {
            self.first_in_bounds(env, at)
        } else {
            self.last_in_bounds(env, at)
        }
    }

    fn seek_for_prev(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> Nanos {
        if self.reverse {
            self.seek_ascending(env, at, key)
        } else {
            self.seek_descending(env, at, key)
        }
    }

    fn next(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        if self.reverse {
            self.step_descending(env, at)
        } else {
            self.step_ascending(env, at)
        }
    }

    fn prev(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        if self.reverse {
            self.step_ascending(env, at)
        } else {
            self.step_descending(env, at)
        }
    }

    fn valid(&self) -> bool {
        self.current.is_some()
    }

    fn entry(&self) -> Option<Entry> {
        self.current
    }

    fn amp(&self) -> ScanAmp {
        self.local
    }
}
