//! The unified store interface: one `KvEngine` trait in front of every
//! evaluated system (Main-LSM alone, ADOC-tuned LSM, full KVACCEL), so
//! workloads, experiments and examples pick an engine by *construction*
//! (`EngineBuilder`) instead of by code path.
//!
//! This mirrors the paper's central claim — the dual-interface write
//! buffer swaps in *behind the same KV API* the host already uses — and
//! production practice (RocksDB's `DB` + `WriteBatch`, keystone-db's
//! `kstone-api` facade over `kstone-core`).
//!
//! Layering: `engine` sits above `lsm`/`kvaccel`/`baselines` (the trait
//! impls live next to the concrete types) and below `workload`/
//! `experiments`/`examples`, which only see `&mut dyn KvEngine`.

pub mod iter;

use anyhow::Result;

use crate::baselines::{AdocConfig, AdocEngine, SystemKind};
use crate::env::SimEnv;
use crate::kvaccel::{KvaccelConfig, KvaccelDb, RollbackScheme};
use crate::lsm::entry::{Entry, Key, Seq, ValueDesc};
use crate::lsm::{
    DbStats, LsmDb, LsmOptions, Manifest, PutResult, StallStats, WriteCondition,
};
use crate::runtime::{BloomBuilder, MergeEngine};
use crate::sim::Nanos;

pub use iter::{
    new_block_cache, vlog_cache_key, DbIterator, DevPin, EngineIterator, IterCost,
    IterOptions, ScanAmp, ScanCounters, SharedBlockCache, Snapshot, SnapshotInner,
    DEV_CACHE_NS, VLOG_CACHE_NS,
};

// ---------------------------------------------------------------------
// Write batches
// ---------------------------------------------------------------------

/// One operation inside a [`WriteBatch`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchOp {
    Put { key: Key, val: ValueDesc },
    Delete { key: Key },
}

impl BatchOp {
    pub fn key(&self) -> Key {
        match *self {
            BatchOp::Put { key, .. } | BatchOp::Delete { key } => key,
        }
    }

    /// The value this op writes (deletes write the tombstone sentinel).
    pub fn value(&self) -> ValueDesc {
        match *self {
            BatchOp::Put { val, .. } => val,
            BatchOp::Delete { .. } => ValueDesc::TOMBSTONE,
        }
    }

    pub fn is_delete(&self) -> bool {
        matches!(self, BatchOp::Delete { .. })
    }
}

/// An ordered group of writes applied as one unit: a single admission
/// gate (stall/slowdown) at the front, one group-committed WAL append,
/// and — on KVACCEL — a single Controller routing decision, so a whole
/// batch redirects to the Dev-LSM during an anticipated stall.
#[derive(Clone, Debug, Default)]
pub struct WriteBatch {
    ops: Vec<BatchOp>,
}

impl WriteBatch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(n: usize) -> Self {
        Self { ops: Vec::with_capacity(n) }
    }

    pub fn put(&mut self, key: Key, val: ValueDesc) -> &mut Self {
        self.ops.push(BatchOp::Put { key, val });
        self
    }

    pub fn delete(&mut self, key: Key) -> &mut Self {
        self.ops.push(BatchOp::Delete { key });
        self
    }

    pub fn ops(&self) -> &[BatchOp] {
        &self.ops
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    pub fn clear(&mut self) {
        self.ops.clear();
    }
}

/// One record in a primary's change-data-capture stream: the entry
/// exactly as the primary committed it (original sequence number) plus
/// the capture stream it came from. Unsharded engines expose a single
/// stream 0; a `ShardedDb` exposes one stream per shard, because each
/// child owns an independent seq domain and therefore needs its own
/// tailing watermark.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CdcRecord {
    pub entry: Entry,
    pub stream: usize,
}

impl CdcRecord {
    /// Bytes this record occupies on the replication wire: the WAL
    /// record encoding (12 B header + entry) plus a 4 B stream tag.
    pub fn wire_bytes(&self) -> u64 {
        16 + self.entry.encoded_len()
    }
}

/// Completion report for a batched write.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchResult {
    /// When the writer thread is free again.
    pub done: Nanos,
    /// Time blocked in a hard write stall at the admission gate.
    pub stalled_ns: Nanos,
    /// Slowdown sleep injected at the admission gate.
    pub delayed_ns: Nanos,
    /// Operations applied.
    pub ops: usize,
}

// ---------------------------------------------------------------------
// Durable lifecycle
// ---------------------------------------------------------------------

/// Everything that survives a power loss or clean shutdown, as captured
/// by [`KvEngine::close`] / [`KvEngine::crash`] and consumed by
/// [`EngineBuilder::open`]:
///
/// - the **manifest** — the durable version edit log whose SST handles
///   stand in for the on-flash files;
/// - the **durable WAL prefix** — records whose bytes reached flash
///   before the cut (empty after a clean close);
/// - the configuration needed to rebuild the engine.
///
/// Device-side durable state (Dev-LSM runs, the FTL map, the block FS)
/// survives *inside the device* (`SimEnv`), not in this image —
/// recovery re-reads it over the KV interface, exactly like the paper's
/// §V-C metadata rebuild.
pub struct DurableImage {
    pub kind: SystemKind,
    pub opts: LsmOptions,
    pub merge: MergeEngine,
    pub bloom: BloomBuilder,
    pub manifest: Manifest,
    /// Durable WAL records in append order.
    pub wal: Vec<Entry>,
    /// Value-log head image (None when key-value separation never
    /// engaged; sealed segments travel through the manifest).
    pub vlog: Option<crate::vlog::VlogImage>,
    pub kvaccel_cfg: Option<KvaccelConfig>,
    pub adoc_cfg: Option<AdocConfig>,
    /// Sharded-store image: the top-level shard manifest (ranges → child
    /// image slots) plus one full child image per shard. When set, the
    /// flat fields above are placeholders — each shard carries its own
    /// manifest, WAL and configuration.
    pub shard: Option<Box<crate::shard::ShardImage>>,
    /// True when produced by a clean close (sealed + fsync'd WAL and a
    /// final CleanShutdown manifest edit).
    pub clean: bool,
    pub taken_at: Nanos,
}

impl DurableImage {
    /// WAL records a reopen would replay (0 after a clean close),
    /// summed across shards for a sharded image.
    pub fn wal_records(&self) -> usize {
        match &self.shard {
            Some(s) => s.children.iter().map(|c| c.wal_records()).sum(),
            None => self.wal.len(),
        }
    }

    /// Manifest edits a reopen would read back, summed across shards.
    pub fn manifest_edits(&self) -> usize {
        match &self.shard {
            Some(s) => s.children.iter().map(|c| c.manifest_edits()).sum(),
            None => self.manifest.edit_count(),
        }
    }
}

// ---------------------------------------------------------------------
// Stats / health
// ---------------------------------------------------------------------

/// Point-in-time health snapshot — the same signals the paper's Detector
/// polls, uniform across engines.
#[derive(Clone, Debug)]
pub struct EngineHealth {
    pub write_condition: WriteCondition,
    pub l0_files: usize,
    pub imm_memtables: usize,
    pub memtable_bytes: u64,
    pub pending_compaction_bytes: u64,
    pub wal_live_bytes: u64,
    /// Keys currently resident in the Dev-LSM (0 for non-KVACCEL engines).
    pub dev_resident_keys: usize,
    /// Detector's current verdict (false for non-KVACCEL engines).
    pub stall_imminent: bool,
    /// Snapshots currently pinning versions against flush/compaction/
    /// rollback reclamation.
    pub live_snapshots: usize,
    /// Oldest sequence number a live snapshot still sees.
    pub min_pinned_seq: Option<Seq>,
    /// 1 when this engine life was opened from a durable image, 0 when
    /// built fresh (per-life, like all recovery stats).
    pub recoveries: u64,
    /// WAL records replayed into the memtable at the last open.
    pub recovered_wal_records: u64,
    /// Device-resident keys routed back to the Dev-LSM at the last open
    /// (0 for non-KVACCEL engines).
    pub recovered_dev_keys: u64,
}

/// Counters of the engine-wide block cache (one instance per engine,
/// shared by point reads, cursors and — on KVACCEL — device write-buffer
/// reads; a sharded store's children all share it too).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Blocks resident right now.
    pub cached_blocks: u64,
    /// Bytes resident right now (blocks × block size).
    pub cached_bytes: u64,
    /// Configured capacity in blocks (0 = cache disabled).
    pub capacity_blocks: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Read-only accessors shared by every engine; supertrait of
/// [`KvEngine`] so drivers can report without knowing the concrete type.
pub trait EngineStats {
    /// The Main-LSM behind this engine (every system has exactly one).
    fn main_db(&self) -> &LsmDb;

    /// Downcast hook for KVACCEL-specific reporting (redirects,
    /// rollbacks); `None` for the baselines.
    fn kvaccel(&self) -> Option<&KvaccelDb> {
        None
    }

    /// Downcast hook for sharded-store reporting (per-shard breakdown,
    /// arbiter grants); `None` for single-shard engines.
    fn sharded(&self) -> Option<&crate::shard::ShardedDb> {
        None
    }

    /// Downcast hook for replicated-store reporting (per-replica lag,
    /// anti-entropy bytes); `None` for unreplicated engines.
    fn replicated(&self) -> Option<&crate::repl::ReplicatedDb> {
        None
    }

    fn stall_stats(&self) -> &StallStats {
        &self.main_db().stall
    }

    fn db_stats(&self) -> &DbStats {
        &self.main_db().stats
    }

    /// Writes redirected to the device write buffer (summed across
    /// shards for a sharded store; 0 for the baselines).
    fn redirected_writes(&self) -> u64 {
        self.kvaccel().map_or(0, |k| k.controller.stats.writes_to_dev)
    }

    /// Completed rollbacks (summed across shards; 0 for the baselines).
    fn rollbacks(&self) -> u64 {
        self.kvaccel().map_or(0, |k| k.rollback.stats.rollbacks)
    }

    /// Cursor read-amplification totals (Seeks/Nexts issued, blocks and
    /// device pages touched) accumulated over the engine's lifetime.
    fn scan_amp(&self) -> ScanAmp {
        self.main_db().scan_counters.snapshot()
    }

    /// Engine-wide block-cache counters. The cache instance is shared by
    /// every shard/cursor of this engine, so any child's view is the
    /// engine-wide truth.
    fn cache_stats(&self) -> CacheStats {
        self.main_db().cache_stats()
    }

    fn health(&self) -> EngineHealth {
        let db = self.main_db();
        EngineHealth {
            write_condition: db.write_condition(),
            l0_files: db.l0_count(),
            imm_memtables: db.imm_count(),
            memtable_bytes: db.memtable_bytes(),
            pending_compaction_bytes: db.pending_compaction_bytes(),
            wal_live_bytes: db.wal_live_bytes(),
            dev_resident_keys: self.kvaccel().map_or(0, |k| k.metadata.len()),
            stall_imminent: self
                .kvaccel()
                .is_some_and(|k| k.detector.stall_imminent()),
            live_snapshots: db.live_snapshots(),
            min_pinned_seq: db.min_pinned_seq(),
            recoveries: db.recovery.recoveries,
            recovered_wal_records: db.recovery.wal_records_replayed,
            recovered_dev_keys: db.recovery.dev_keys_rerouted,
        }
    }
}

// ---------------------------------------------------------------------
// The engine trait
// ---------------------------------------------------------------------

/// Uniform KV store interface over the simulated SSD. All timing is
/// virtual: operations take an issue time `at` and return completion
/// times. Scans are snapshot-consistent — the result set is pinned at
/// issue time and unaffected by later writes.
pub trait KvEngine: EngineStats {
    /// Write one pair with full admission (stall/slowdown or redirect)
    /// semantics.
    fn put(&mut self, env: &mut SimEnv, at: Nanos, key: Key, val: ValueDesc) -> PutResult;

    /// Delete a key: a tombstone through the same write path (WAL →
    /// memtable → dropped at the bottommost compaction level).
    fn delete(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> PutResult;

    /// Point lookup; deleted keys read as absent.
    fn get(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> (Option<ValueDesc>, Nanos);

    /// Apply a [`WriteBatch`] as one unit (single admission gate, group
    /// WAL commit, single routing decision on KVACCEL).
    fn write_batch(&mut self, env: &mut SimEnv, at: Nanos, batch: &WriteBatch) -> BatchResult;

    /// Pin a refcounted point-in-time view: later writes, flushes,
    /// compactions — and on KVACCEL, rollbacks — are invisible to
    /// iterators opened at this snapshot, and cannot reclaim versions
    /// it still sees.
    fn snapshot(&mut self, env: &mut SimEnv, at: Nanos) -> Snapshot;

    /// Open a cursor (`seek`/`seek_for_prev`/`next`/`prev`) honoring
    /// `opts` bounds and direction. Without `opts.snapshot`, a fresh
    /// snapshot is pinned at `at`. The cursor is detached: the engine
    /// keeps serving writes while it is open.
    fn iter(&mut self, env: &mut SimEnv, at: Nanos, opts: IterOptions)
        -> Box<dyn DbIterator>;

    /// Snapshot range scan: seek to `start`, return up to `count` live
    /// entries in ascending key order, newest version per key.
    ///
    /// Compatibility wrapper over [`KvEngine::iter`] (Seek + Nexts on a
    /// fresh pinned snapshot); kept so pre-cursor callers and the
    /// unbounded-scan presets keep their exact semantics.
    fn scan(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        start: Key,
        count: usize,
    ) -> (Vec<Entry>, Nanos) {
        let mut it = self.iter(env, at, IterOptions::default());
        let mut t = it.seek(env, at, start);
        let mut out = Vec::with_capacity(count.min(4096));
        while out.len() < count {
            let Some(e) = it.entry() else { break };
            out.push(e);
            t = it.next(env, t);
        }
        env.clock.advance_to(t);
        (out, t)
    }

    /// Idle-time maintenance at `at`: apply background work that
    /// completed by now, refresh detectors/tuners, and close elapsed
    /// rollback windows — everything an operation's entry path would do,
    /// without issuing an operation. A sharding layer calls this on the
    /// shards an op does NOT touch, so idle shards' flushes/compactions
    /// interleave with the hot shard's traffic on virtual time instead
    /// of freezing until their next op arrives.
    fn tick(&mut self, _env: &mut SimEnv, _at: Nanos) {}

    /// Mutable KVACCEL downcast (the shard arbiter pushes occupancy
    /// grants through this); `None` for the baselines.
    fn kvaccel_mut(&mut self) -> Option<&mut KvaccelDb> {
        None
    }

    /// Number of independent CDC capture streams this engine exposes
    /// (one per shard on a `ShardedDb`, 1 otherwise). The shipper keeps
    /// one seq watermark per stream.
    fn cdc_streams(&self) -> usize {
        1
    }

    /// Change-data-capture tail: every committed record with
    /// `seq > wm[stream]` for its stream, in a deterministic order
    /// (seq order within a stream). Zero virtual time is charged — the
    /// shipper captures synchronously with each primary op and only the
    /// simulated replication link costs time. Engines that buffer
    /// writes outside the host WAL (KVACCEL's redirected writes) merge
    /// those sources here; the default (no capture) suits wrappers that
    /// delegate.
    fn cdc_tail(&self, _env: &SimEnv, _wm: &[Seq]) -> Vec<CdcRecord> {
        Vec::new()
    }

    /// Apply one replicated record, preserving its primary sequence
    /// number (`LsmDb::apply_entry` semantics): full admission gate,
    /// WAL append, memtable insert, but no new seq allocation — the
    /// replica shares the primary's seq domain, which is what makes
    /// failover's watermark comparison meaningful. The default routes
    /// through `put`/`delete` (allocating a fresh local seq) for
    /// wrappers that have no seq domain of their own.
    fn repl_apply(&mut self, env: &mut SimEnv, at: Nanos, rec: &CdcRecord) -> PutResult {
        let e = rec.entry;
        if e.val.is_tombstone() {
            self.delete(env, at, e.key)
        } else {
            self.put(env, at, e.key, e.val)
        }
    }

    /// Install an externally-owned engine-wide block cache. Engines that
    /// own an `LsmDb` forward to it (and a sharding layer fans out to
    /// every child); the default is a no-op so wrappers without a cache
    /// stay valid.
    fn set_block_cache(&mut self, _cache: SharedBlockCache) {}

    /// Force-rotate the memtable and drain all background work.
    fn flush(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos;

    /// End-of-run cleanup: final rollback (KVACCEL) + drain. After
    /// `finish`, the engine holds single-store semantics.
    fn finish(&mut self, env: &mut SimEnv, at: Nanos) -> Result<Nanos>;

    /// Clean shutdown: final rollback/flush, seal + fsync the WAL, write
    /// the CleanShutdown manifest edit, and hand back the durable image.
    /// Reopening a cleanly-closed image replays zero WAL records.
    fn close(self: Box<Self>, env: &mut SimEnv, at: Nanos) -> Result<DurableImage>;

    /// Power loss at `at`: background jobs that finished before `at`
    /// have applied (their manifest edits are durable); host memory and
    /// the page cache (unsynced WAL bytes — the sync=false ack-vs-
    /// durable gap) are lost; NAND contents, the FTL map and the Dev-LSM
    /// write buffer survive in the device. Returns what recovery gets.
    fn crash(self: Box<Self>, env: &mut SimEnv, at: Nanos) -> DurableImage;
}

// ---------------------------------------------------------------------
// Builder
// ---------------------------------------------------------------------

/// Constructs any evaluated system behind `Box<dyn KvEngine>`. Engine
/// choice is a constructor argument; everything downstream is generic.
///
/// ```ignore
/// let mut sys = EngineBuilder::kvaccel()
///     .opts(LsmOptions::default().with_threads(4))
///     .build();
/// ```
pub struct EngineBuilder {
    kind: SystemKind,
    opts: LsmOptions,
    merge: MergeEngine,
    bloom: BloomBuilder,
    kvaccel_cfg: KvaccelConfig,
    adoc_cfg: AdocConfig,
    shard: Option<crate::shard::ShardSpec>,
    block_cache: Option<SharedBlockCache>,
}

impl EngineBuilder {
    pub fn new(kind: SystemKind) -> Self {
        Self {
            kind,
            opts: LsmOptions::default(),
            merge: MergeEngine::rust(),
            bloom: BloomBuilder::rust(),
            kvaccel_cfg: KvaccelConfig::default(),
            adoc_cfg: AdocConfig::default(),
            shard: None,
            block_cache: None,
        }
    }

    /// Plain LSM engine (RocksDB row with slowdown enabled).
    pub fn lsm() -> Self {
        Self::new(SystemKind::RocksDb { slowdown: true })
    }

    /// RocksDB row with the slowdown feature on/off.
    pub fn rocksdb(slowdown: bool) -> Self {
        Self::new(SystemKind::RocksDb { slowdown })
    }

    /// ADOC baseline (feedback tuner, slowdown as last resort).
    pub fn adoc() -> Self {
        Self::new(SystemKind::Adoc)
    }

    /// KVACCEL in the write-optimized configuration (rollback disabled
    /// during the run).
    pub fn kvaccel() -> Self {
        Self::new(SystemKind::Kvaccel { scheme: RollbackScheme::Disabled })
    }

    /// KVACCEL with an explicit rollback scheme.
    pub fn kvaccel_scheme(scheme: RollbackScheme) -> Self {
        Self::new(SystemKind::Kvaccel { scheme })
    }

    pub fn kind(&self) -> SystemKind {
        self.kind
    }

    /// Replace the LSM options (slowdown flag is still forced by the
    /// kind at build: RocksDB rows honor their `slowdown` field, KVACCEL
    /// always disables it).
    pub fn opts(mut self, opts: LsmOptions) -> Self {
        self.opts = opts;
        self
    }

    pub fn threads(mut self, n: usize) -> Self {
        self.opts.compaction_threads = n;
        self
    }

    pub fn merge_engine(mut self, merge: MergeEngine) -> Self {
        self.merge = merge;
        self
    }

    pub fn bloom_builder(mut self, bloom: BloomBuilder) -> Self {
        self.bloom = bloom;
        self
    }

    pub fn kvaccel_config(mut self, cfg: KvaccelConfig) -> Self {
        self.kvaccel_cfg = cfg;
        self
    }

    pub fn adoc_config(mut self, cfg: AdocConfig) -> Self {
        self.adoc_cfg = cfg;
        self
    }

    /// Share an existing block cache with the engine being built (e.g.
    /// several standalone engines warming one cache); by default every
    /// engine builds its own instance sized by
    /// `LsmOptions::block_cache_blocks`.
    pub fn block_cache(mut self, cache: SharedBlockCache) -> Self {
        self.block_cache = Some(cache);
        self
    }

    /// Partition the keyspace over `n` child engines of this builder's
    /// kind behind one [`crate::shard::ShardedDb`]. All KVACCEL shards
    /// share the one simulated device, each in its own KV namespace,
    /// with the device arbiter partitioning the write-buffer capacity.
    pub fn sharded(mut self, n: usize, policy: crate::shard::ShardPolicy) -> Self {
        self.shard = Some(crate::shard::ShardSpec::new(n, policy));
        self
    }

    /// Key-space hint for the range router's boundary table (defaults to
    /// the full key domain; pass the workload's `key_space` so ranges
    /// split the populated prefix evenly).
    pub fn shard_key_space(mut self, key_space: Key) -> Self {
        if let Some(s) = &mut self.shard {
            s.key_space = key_space;
        }
        self
    }

    /// Reopen an engine from a durable image (crash recovery or clean
    /// restart): rebuild the Version from the manifest, replay the
    /// durable WAL records, and — on KVACCEL — rescan the device write
    /// buffer and reconcile the routing set against the recovered host
    /// state by sequence number. Returns the engine and the virtual time
    /// recovery completed, or an error when the device-side recovery
    /// scan fails (recovery paths must not panic).
    pub fn open(
        env: &mut SimEnv,
        at: Nanos,
        image: DurableImage,
    ) -> Result<(Box<dyn KvEngine>, Nanos)> {
        let DurableImage {
            kind,
            opts,
            merge,
            bloom,
            manifest,
            wal,
            vlog,
            kvaccel_cfg,
            adoc_cfg,
            shard,
            clean,
            ..
        } = image;
        if let Some(shard) = shard {
            let (db, t) = crate::shard::ShardedDb::open(env, at, *shard)?;
            return Ok((Box::new(db), t));
        }
        Ok(match kind {
            SystemKind::RocksDb { .. } => {
                let (db, t) = LsmDb::open(
                    env, at, opts, merge, bloom, manifest, wal, vlog, clean,
                );
                (Box::new(db) as Box<dyn KvEngine>, t)
            }
            SystemKind::Adoc => {
                let (eng, t) = AdocEngine::open(
                    env,
                    at,
                    opts,
                    adoc_cfg.unwrap_or_default(),
                    merge,
                    bloom,
                    manifest,
                    wal,
                    vlog,
                    clean,
                );
                (Box::new(eng) as Box<dyn KvEngine>, t)
            }
            SystemKind::Kvaccel { scheme } => {
                let cfg = kvaccel_cfg.unwrap_or_default().with_scheme(scheme);
                let (eng, t) = KvaccelDb::open(
                    env, at, opts, cfg, merge, bloom, manifest, wal, vlog, clean,
                )?;
                (Box::new(eng) as Box<dyn KvEngine>, t)
            }
        })
    }

    pub fn build(self) -> Box<dyn KvEngine> {
        let Self { kind, opts, merge, bloom, kvaccel_cfg, adoc_cfg, shard, block_cache } =
            self;
        let mut sys: Box<dyn KvEngine> = if let Some(spec) = shard {
            Box::new(crate::shard::ShardedDb::new(
                spec,
                kind,
                opts,
                merge,
                bloom,
                kvaccel_cfg,
                adoc_cfg,
            ))
        } else {
            match kind {
                SystemKind::RocksDb { slowdown } => {
                    Box::new(LsmDb::new(opts.with_slowdown(slowdown), merge, bloom))
                }
                SystemKind::Adoc => {
                    Box::new(AdocEngine::new(opts, adoc_cfg, merge, bloom))
                }
                SystemKind::Kvaccel { scheme } => Box::new(KvaccelDb::new(
                    opts,
                    kvaccel_cfg.with_scheme(scheme),
                    merge,
                    bloom,
                )),
            }
        };
        if let Some(cache) = block_cache {
            sys.set_block_cache(cache);
        }
        sys
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ssd::SsdConfig;

    #[test]
    fn batch_builder_orders_ops() {
        let mut b = WriteBatch::new();
        b.put(1, ValueDesc::new(1, 64)).delete(2).put(3, ValueDesc::new(3, 64));
        assert_eq!(b.len(), 3);
        assert!(!b.is_empty());
        assert_eq!(b.ops()[0].key(), 1);
        assert!(b.ops()[1].is_delete());
        assert!(b.ops()[1].value().is_tombstone());
        assert_eq!(b.ops()[2].value(), ValueDesc::new(3, 64));
    }

    #[test]
    fn builder_constructs_every_kind() {
        for kind in [
            SystemKind::RocksDb { slowdown: true },
            SystemKind::RocksDb { slowdown: false },
            SystemKind::Adoc,
            SystemKind::Kvaccel { scheme: RollbackScheme::Eager },
        ] {
            let mut env = SimEnv::new(1, SsdConfig::default());
            let mut sys = EngineBuilder::new(kind)
                .opts(LsmOptions::small_for_test())
                .build();
            let r = sys.put(&mut env, 0, 7, ValueDesc::new(7, 128));
            let (got, _) = sys.get(&mut env, r.done, 7);
            assert_eq!(got, Some(ValueDesc::new(7, 128)), "{}", kind.label());
        }
    }

    #[test]
    fn health_snapshot_via_trait() {
        let mut env = SimEnv::new(2, SsdConfig::default());
        let mut sys = EngineBuilder::lsm().opts(LsmOptions::small_for_test()).build();
        let mut t = 0;
        for k in 0..100u32 {
            t = sys.put(&mut env, t, k, ValueDesc::new(k, 1024)).done;
        }
        let h = sys.health();
        assert!(h.memtable_bytes > 0 || h.l0_files > 0 || h.imm_memtables > 0);
        assert_eq!(h.dev_resident_keys, 0);
        assert!(!h.stall_imminent);
        let _ = t;
    }
}
