//! Virtual clock: monotonically advancing nanosecond counter.

/// Virtual nanoseconds since experiment start.
pub type Nanos = u64;

pub const NS_PER_SEC: Nanos = 1_000_000_000;
pub const SECONDS: Nanos = NS_PER_SEC;
pub const MILLIS: Nanos = 1_000_000;
pub const MICROS: Nanos = 1_000;

/// The experiment-global virtual clock. Actors (workload threads,
/// background jobs, the device) all express time on this axis; the
/// workload driver advances it in global order.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Clock {
    now: Nanos,
}

impl Clock {
    pub fn new() -> Self {
        Self { now: 0 }
    }

    #[inline]
    pub fn now(&self) -> Nanos {
        self.now
    }

    /// Advance to an absolute time; ignores moves into the past (multiple
    /// actors may report completions out of order).
    #[inline]
    pub fn advance_to(&mut self, t: Nanos) {
        if t > self.now {
            self.now = t;
        }
    }

    #[inline]
    pub fn advance_by(&mut self, d: Nanos) {
        self.now += d;
    }

    /// Current 1-second bin index (used by all time-series collectors).
    #[inline]
    pub fn second(&self) -> usize {
        (self.now / NS_PER_SEC) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advance_monotonic() {
        let mut c = Clock::new();
        c.advance_to(100);
        c.advance_to(50); // no-op
        assert_eq!(c.now(), 100);
        c.advance_by(25);
        assert_eq!(c.now(), 125);
    }

    #[test]
    fn second_bins() {
        let mut c = Clock::new();
        assert_eq!(c.second(), 0);
        c.advance_to(NS_PER_SEC * 3 + 1);
        assert_eq!(c.second(), 3);
    }
}
