//! Discrete-event scheduler: a binary-heap event queue on the virtual
//! `Nanos` axis with deterministic tie-breaking.
//!
//! The workload layer (`workload::client`) runs N concurrent clients
//! against one `KvEngine` by popping events in global time order. Ties
//! are broken by actor id, then by insertion order, so a run is a pure
//! function of (spec, seed) — the determinism the conformance tests
//! assert. Engine side-effects still apply "when the clock catches up"
//! (see DESIGN.md §2); the queue only fixes the *issue order* of
//! operations across clients.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::Nanos;

/// Identifies one client/actor inside a workload run.
pub type ActorId = u32;

/// What a popped event means to the workload scheduler.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Closed-loop: the actor is ready to issue its next operation.
    Issue,
    /// Open-loop: a request arrives and joins the actor's FIFO.
    Arrival,
    /// Open-loop: the actor should consider serving its FIFO head.
    Dispatch,
    /// QoS controller heartbeat: rotate SLO windows, rebalance tenant
    /// device grants (`qos::QosController::on_tick`). The actor id is
    /// the reserved slot one past the last client.
    QosTick,
    /// Replication: a CDC batch leaves the primary's shipper for the
    /// replica identified by the actor id (`repl::ReplicatedDb` runs
    /// its own queue; the workload loop never sees these).
    ReplShip,
    /// Replication: a CDC batch finishes crossing the simulated link
    /// and is applied on the replica identified by the actor id.
    ReplDeliver,
}

/// A scheduled wake-up for one actor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    pub at: Nanos,
    pub actor: ActorId,
    pub kind: EventKind,
    /// Global insertion counter: the final tie-break, so two events at
    /// the same (at, actor) pop in push order.
    seq: u64,
}

// BinaryHeap is a max-heap; order events so the *earliest* pops first,
// ties broken by actor id then insertion order.
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.actor.cmp(&self.actor))
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// The event queue. Pop order is a total, deterministic function of the
/// push sequence.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, at: Nanos, actor: ActorId, kind: EventKind) {
        self.seq += 1;
        self.heap.push(Event { at, actor, kind, seq: self.seq });
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    /// Timestamp of the next event without popping it.
    pub fn peek_time(&self) -> Option<Nanos> {
        self.heap.peek().map(|e| e.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(300, 0, EventKind::Issue);
        q.push(100, 1, EventKind::Issue);
        q.push(200, 2, EventKind::Arrival);
        let order: Vec<Nanos> = std::iter::from_fn(|| q.pop()).map(|e| e.at).collect();
        assert_eq!(order, vec![100, 200, 300]);
    }

    #[test]
    fn ties_break_by_actor_then_insertion() {
        let mut q = EventQueue::new();
        q.push(50, 2, EventKind::Issue);
        q.push(50, 0, EventKind::Dispatch);
        q.push(50, 1, EventKind::Arrival);
        q.push(50, 1, EventKind::Issue); // same actor+time: push order
        let order: Vec<(ActorId, EventKind)> =
            std::iter::from_fn(|| q.pop()).map(|e| (e.actor, e.kind)).collect();
        assert_eq!(
            order,
            vec![
                (0, EventKind::Dispatch),
                (1, EventKind::Arrival),
                (1, EventKind::Issue),
                (2, EventKind::Issue),
            ]
        );
    }

    #[test]
    fn interleaved_push_pop_stays_deterministic() {
        let run = || {
            let mut q = EventQueue::new();
            let mut got = Vec::new();
            q.push(10, 0, EventKind::Issue);
            q.push(5, 1, EventKind::Issue);
            while let Some(e) = q.pop() {
                got.push((e.at, e.actor));
                if e.at < 30 {
                    q.push(e.at + 7, e.actor, EventKind::Issue);
                    q.push(e.at + 7, 1 - e.actor, EventKind::Arrival);
                }
            }
            got
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn peek_and_len() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
        q.push(9, 0, EventKind::Issue);
        q.push(4, 0, EventKind::Issue);
        assert_eq!(q.peek_time(), Some(4));
        assert_eq!(q.len(), 2);
    }
}
