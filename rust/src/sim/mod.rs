//! Virtual-time discrete-event substrate.
//!
//! The paper's experiments are 600-second wall-clock runs on a Cosmos+
//! OpenSSD testbed; here every I/O and CPU cost is charged in *virtual*
//! nanoseconds against device/CPU models, so a 600 s experiment runs in
//! seconds of wall time, deterministically (seeded). See DESIGN.md §2.

pub mod clock;
pub mod cpu;
pub mod jobs;
pub mod rng;
pub mod sched;

pub use clock::{Clock, Nanos, MICROS, MILLIS, NS_PER_SEC, SECONDS};
pub use cpu::{CpuAccounting, CpuClass};
pub use jobs::ThreadPool;
pub use rng::SimRng;
pub use sched::{ActorId, Event, EventKind, EventQueue};
