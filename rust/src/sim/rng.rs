//! Deterministic RNG for workload generation (offline image has no `rand`
//! crate; this is splitmix64 + xoshiro256**, both well-studied).

#[derive(Clone, Debug)]
pub struct SimRng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl SimRng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // xoshiro256**
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, bound) — Lemire's multiply-shift rejection.
    #[inline]
    pub fn gen_range_u64(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let threshold = bound.wrapping_neg() % bound;
            while lo < threshold {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn gen_range_u32(&mut self, bound: u32) -> u32 {
        self.gen_range_u64(bound as u64) as u32
    }

    /// Bernoulli with probability num/den.
    #[inline]
    pub fn gen_ratio(&mut self, num: u32, den: u32) -> bool {
        self.gen_range_u32(den) < num
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Fork an independent stream (for per-actor RNGs).
    pub fn fork(&mut self, stream: u64) -> SimRng {
        SimRng::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }
}

/// Deterministic value-byte stream used to materialize synthetic values
/// (see lsm::entry::ValueDesc) — must be reproducible from (seed, len).
pub fn value_bytes(seed: u32, len: u32) -> Vec<u8> {
    let mut state = (seed as u64) << 1 | 1;
    let mut out = Vec::with_capacity(len as usize);
    while out.len() < len as usize {
        let word = splitmix64(&mut state);
        for b in word.to_le_bytes() {
            if out.len() == len as usize {
                break;
            }
            out.push(b);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SimRng::new(42);
        let mut b = SimRng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = SimRng::new(1);
        let mut b = SimRng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn range_bounds() {
        let mut r = SimRng::new(7);
        for _ in 0..10_000 {
            assert!(r.gen_range_u64(17) < 17);
        }
        // rough uniformity over 16 buckets
        let mut hist = [0u32; 16];
        for _ in 0..16_000 {
            hist[r.gen_range_u64(16) as usize] += 1;
        }
        for h in hist {
            assert!((600..1400).contains(&h), "non-uniform: {hist:?}");
        }
    }

    #[test]
    fn ratio_sanity() {
        let mut r = SimRng::new(9);
        let hits = (0..10_000).filter(|_| r.gen_ratio(1, 10)).count();
        assert!((700..1300).contains(&hits), "ratio off: {hits}");
    }

    #[test]
    fn value_bytes_deterministic_and_sized() {
        assert_eq!(value_bytes(5, 100), value_bytes(5, 100));
        assert_eq!(value_bytes(5, 100).len(), 100);
        assert_ne!(value_bytes(5, 32), value_bytes(6, 32));
        assert!(value_bytes(0, 0).is_empty());
    }
}
