//! Background-thread availability model.
//!
//! RocksDB runs flush and compaction jobs on background thread pools; here
//! each pool is a vector of per-thread `free_at` horizons on the virtual
//! clock. A job enqueued at `ready` starts at `max(ready, earliest free
//! thread)` and occupies that thread for its duration. ADOC resizes the
//! pool dynamically (`set_threads`).

use super::clock::Nanos;

#[derive(Clone, Debug)]
pub struct ThreadPool {
    free_at: Vec<Nanos>,
    /// Cumulative busy ns (for utilization reporting).
    busy_total: Nanos,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        Self {
            free_at: vec![0; threads],
            busy_total: 0,
        }
    }

    pub fn threads(&self) -> usize {
        self.free_at.len()
    }

    /// Grow or shrink the pool. Shrinking keeps the busiest horizons so
    /// running jobs are never cancelled (matches RocksDB's behaviour of
    /// letting in-flight jobs finish).
    pub fn set_threads(&mut self, threads: usize) {
        assert!(threads > 0);
        if threads > self.free_at.len() {
            self.free_at.resize(threads, 0);
        } else if threads < self.free_at.len() {
            self.free_at.sort_unstable_by(|a, b| b.cmp(a));
            self.free_at.truncate(threads);
        }
    }

    /// Earliest time any thread is free.
    pub fn earliest_free(&self) -> Nanos {
        *self.free_at.iter().min().expect("pool non-empty")
    }

    /// Schedule a job that becomes ready at `ready` and runs `duration`.
    /// Returns (start, end).
    pub fn schedule(&mut self, ready: Nanos, duration: Nanos) -> (Nanos, Nanos) {
        let idx = self
            .free_at
            .iter()
            .enumerate()
            .min_by_key(|(_, &t)| t)
            .map(|(i, _)| i)
            .expect("pool non-empty");
        let start = self.free_at[idx].max(ready);
        let end = start + duration;
        self.free_at[idx] = end;
        self.busy_total += duration;
        (start, end)
    }

    /// Peek the thread and start time a job ready at `ready` would get,
    /// without committing. Pair with `occupy` once the caller has
    /// computed the job's actual end (device-dependent durations).
    pub fn reserve(&self, ready: Nanos) -> (usize, Nanos) {
        let idx = self
            .free_at
            .iter()
            .enumerate()
            .min_by_key(|(_, &t)| t)
            .map(|(i, _)| i)
            .expect("pool non-empty");
        (idx, self.free_at[idx].max(ready))
    }

    /// Commit a reservation: thread `idx` is busy until `end`.
    pub fn occupy(&mut self, idx: usize, start: Nanos, end: Nanos) {
        debug_assert!(end >= start);
        self.free_at[idx] = self.free_at[idx].max(end);
        self.busy_total += end - start;
    }

    /// Number of threads idle at time `t`.
    pub fn idle_at(&self, t: Nanos) -> usize {
        self.free_at.iter().filter(|&&f| f <= t).count()
    }

    pub fn busy_total(&self) -> Nanos {
        self.busy_total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_thread_serializes() {
        let mut p = ThreadPool::new(1);
        let (s1, e1) = p.schedule(0, 100);
        let (s2, e2) = p.schedule(10, 50);
        assert_eq!((s1, e1), (0, 100));
        assert_eq!((s2, e2), (100, 150)); // waits for thread
    }

    #[test]
    fn multi_thread_parallel() {
        let mut p = ThreadPool::new(2);
        let (_, e1) = p.schedule(0, 100);
        let (s2, _) = p.schedule(10, 50);
        assert_eq!(e1, 100);
        assert_eq!(s2, 10); // second thread picks it up immediately
    }

    #[test]
    fn ready_time_respected() {
        let mut p = ThreadPool::new(2);
        let (s, e) = p.schedule(500, 10);
        assert_eq!((s, e), (500, 510));
    }

    #[test]
    fn shrink_keeps_running_jobs() {
        let mut p = ThreadPool::new(4);
        p.schedule(0, 1000);
        p.schedule(0, 2000);
        p.set_threads(1);
        // the busiest horizon survives
        assert_eq!(p.earliest_free(), 2000);
    }

    #[test]
    fn idle_count() {
        let mut p = ThreadPool::new(3);
        p.schedule(0, 100);
        assert_eq!(p.idle_at(50), 2);
        assert_eq!(p.idle_at(100), 3);
    }
}
