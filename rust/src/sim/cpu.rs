//! CPU-time accounting: every host-side activity charges busy nanoseconds
//! to a class; CPU utilization (paper Eq. 1 denominator) integrates the
//! host classes over a modeled core budget (8 cores, Table II: "CPU usage
//! limited to 8 cores"). The device ARM core is accounted separately.

use super::clock::{Nanos, NS_PER_SEC};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CpuClass {
    /// Foreground writer/reader threads (WAL memcpy, memtable insert, ...).
    Foreground,
    /// Flush jobs (imm memtable -> L0 SST).
    Flush,
    /// Compaction merge work.
    Compaction,
    /// KVACCEL software modules (detector poll, metadata ops, rollback).
    Kvaccel,
    /// The device's single ARM Cortex-A9 (Dev-LSM work) — *not* host CPU.
    DeviceArm,
}

const HOST_CLASSES: [CpuClass; 4] = [
    CpuClass::Foreground,
    CpuClass::Flush,
    CpuClass::Compaction,
    CpuClass::Kvaccel,
];

#[derive(Clone, Debug, Default)]
pub struct CpuAccounting {
    foreground: Nanos,
    flush: Nanos,
    compaction: Nanos,
    kvaccel: Nanos,
    device_arm: Nanos,
    /// host busy ns binned per virtual second (for CPU time-series).
    host_bins: Vec<Nanos>,
}

impl CpuAccounting {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn charge(&mut self, class: CpuClass, at: Nanos, busy: Nanos) {
        let slot = match class {
            CpuClass::Foreground => &mut self.foreground,
            CpuClass::Flush => &mut self.flush,
            CpuClass::Compaction => &mut self.compaction,
            CpuClass::Kvaccel => &mut self.kvaccel,
            CpuClass::DeviceArm => &mut self.device_arm,
        };
        *slot += busy;
        if class != CpuClass::DeviceArm {
            let bin = (at / NS_PER_SEC) as usize;
            if self.host_bins.len() <= bin {
                self.host_bins.resize(bin + 1, 0);
            }
            self.host_bins[bin] += busy;
        }
    }

    pub fn busy(&self, class: CpuClass) -> Nanos {
        match class {
            CpuClass::Foreground => self.foreground,
            CpuClass::Flush => self.flush,
            CpuClass::Compaction => self.compaction,
            CpuClass::Kvaccel => self.kvaccel,
            CpuClass::DeviceArm => self.device_arm,
        }
    }

    pub fn host_busy_total(&self) -> Nanos {
        HOST_CLASSES.iter().map(|&c| self.busy(c)).sum()
    }

    /// Average host CPU utilization in percent of `cores` over `elapsed`.
    /// This is the denominator of the paper's efficiency metric (Eq. 1).
    pub fn host_cpu_percent(&self, elapsed: Nanos, cores: u32) -> f64 {
        if elapsed == 0 {
            return 0.0;
        }
        100.0 * self.host_busy_total() as f64 / (elapsed as f64 * cores as f64)
    }

    /// Per-second host CPU% series.
    pub fn host_percent_series(&self, cores: u32) -> Vec<f64> {
        self.host_bins
            .iter()
            .map(|&b| 100.0 * b as f64 / (NS_PER_SEC as f64 * cores as f64))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charges_accumulate_per_class() {
        let mut cpu = CpuAccounting::new();
        cpu.charge(CpuClass::Compaction, 0, 500);
        cpu.charge(CpuClass::Compaction, 10, 250);
        cpu.charge(CpuClass::DeviceArm, 10, 999);
        assert_eq!(cpu.busy(CpuClass::Compaction), 750);
        assert_eq!(cpu.host_busy_total(), 750);
        assert_eq!(cpu.busy(CpuClass::DeviceArm), 999);
    }

    #[test]
    fn percent_math() {
        let mut cpu = CpuAccounting::new();
        // 2 of 8 cores busy for 1s
        cpu.charge(CpuClass::Flush, 0, 2 * NS_PER_SEC);
        let pct = cpu.host_cpu_percent(NS_PER_SEC, 8);
        assert!((pct - 25.0).abs() < 1e-9);
    }

    #[test]
    fn series_bins() {
        let mut cpu = CpuAccounting::new();
        cpu.charge(CpuClass::Foreground, NS_PER_SEC * 2 + 5, NS_PER_SEC / 2);
        let series = cpu.host_percent_series(1);
        assert_eq!(series.len(), 3);
        assert!((series[2] - 50.0).abs() < 1e-9);
    }
}
