//! Compaction merge offload: the L1/L2 `compaction_merge` artifact driven
//! from the Rust compaction path, plus a bit-identical pure-Rust fallback.
//!
//! Contract (matches python/compile/model.py):
//! - Input: up to B*N `(key, tag)` u32 pairs; **lower tag == newer
//!   version**. The caller concatenates compaction input runs newest-first
//!   so the position index works directly as the tag.
//! - Output: pairs sorted ascending by `(key, tag)` with a keep mask on
//!   the first (newest) occurrence of each key; `PAD_KEY` pad lanes sort
//!   last and are stripped.

use anyhow::{anyhow, Result};
use std::sync::Arc;

use super::XlaRuntime;

/// Reserved padding key — never a user key (enforced by `lsm::Key` checks).
pub const PAD_KEY: u32 = u32::MAX;

/// One merged, deduped output element: `(key, tag)` where `tag` indexes the
/// caller's concatenated input (its permutation back to full entries).
pub type MergedPair = (u32, u32);

/// How a window of pairs is sorted+deduped.
#[derive(Clone)]
pub enum MergeEngine {
    /// AOT XLA artifact executed via PJRT (the paper-analog offload path).
    Xla(MergeAccelerator),
    /// Pure-Rust reference (also the bench baseline).
    Rust,
}

impl MergeEngine {
    pub fn rust() -> Self {
        MergeEngine::Rust
    }

    pub fn xla(rt: Arc<XlaRuntime>) -> Result<Self> {
        Ok(MergeEngine::Xla(MergeAccelerator::new(rt)?))
    }

    pub fn name(&self) -> &'static str {
        match self {
            MergeEngine::Xla(_) => "xla",
            MergeEngine::Rust => "rust",
        }
    }

    /// Sort + dedup one window of `(key, tag)` pairs (see module docs).
    /// Output is ascending by key, exactly one (the lowest-tag) pair per
    /// distinct key.
    pub fn merge_window(&self, pairs: &[(u32, u32)]) -> Result<Vec<MergedPair>> {
        match self {
            MergeEngine::Rust => Ok(merge_window_rust(pairs)),
            MergeEngine::Xla(acc) => acc.merge_window(pairs),
        }
    }
}

/// Reference implementation: identical semantics to the artifact.
pub fn merge_window_rust(pairs: &[(u32, u32)]) -> Vec<MergedPair> {
    let mut packed: Vec<u64> = pairs
        .iter()
        .map(|&(k, t)| ((k as u64) << 32) | t as u64)
        .collect();
    packed.sort_unstable();
    let mut out = Vec::with_capacity(packed.len());
    let mut prev_key = u64::MAX;
    for p in packed {
        let key = p >> 32;
        if key != prev_key {
            let k = key as u32;
            if k != PAD_KEY {
                out.push((k, (p & 0xFFFF_FFFF) as u32));
            }
            prev_key = key;
        }
    }
    out
}

/// PJRT-backed merge accelerator. Picks the smallest artifact window that
/// fits the input; larger inputs are split into windows and k-way merged
/// (the O(n log n) work stays on the accelerator; the final pass is a
/// linear scan).
#[derive(Clone)]
pub struct MergeAccelerator {
    rt: Arc<XlaRuntime>,
    /// (batch, lanes) shapes ascending by capacity.
    shapes: Vec<(usize, usize)>,
    /// Largest single-window lane count.
    max_lanes: usize,
}

impl MergeAccelerator {
    pub fn new(rt: Arc<XlaRuntime>) -> Result<Self> {
        let shapes = rt.merge_shapes();
        if shapes.is_empty() {
            return Err(anyhow!("runtime has no merge artifacts"));
        }
        let max_lanes = shapes.iter().map(|&(b, n)| b * n).max().unwrap();
        Ok(Self { rt, shapes, max_lanes })
    }

    /// Capacity of the largest single dispatch.
    pub fn max_window(&self) -> usize {
        self.max_lanes
    }

    pub fn merge_window(&self, pairs: &[(u32, u32)]) -> Result<Vec<MergedPair>> {
        if pairs.is_empty() {
            return Ok(Vec::new());
        }
        if pairs.len() <= self.max_lanes {
            let (keys, tags, keep, b, n) = self.execute_padded(pairs)?;
            let mut out = Vec::with_capacity(pairs.len());
            collect_kept(&keys, &tags, &keep, b, n, &mut out);
            // Windows within one dispatch are batch rows sorted
            // independently; merge them.
            if b > 1 {
                out = merge_sorted_dedup(out, n);
            }
            return Ok(out);
        }
        // Oversized input: accelerate per max-window chunk, then k-way
        // merge the sorted chunks (linear, newest-wins via tag).
        let mut runs: Vec<Vec<MergedPair>> = Vec::new();
        for chunk in pairs.chunks(self.max_lanes) {
            runs.push(self.merge_window(chunk)?);
        }
        Ok(kway_merge_dedup(runs))
    }

    /// Dispatch one padded window; returns raw artifact outputs.
    fn execute_padded(
        &self,
        pairs: &[(u32, u32)],
    ) -> Result<(Vec<u32>, Vec<u32>, Vec<u32>, usize, usize)> {
        let (b, n) = self.pick_shape(pairs.len());
        let total = b * n;
        let mut keys = vec![PAD_KEY; total];
        let mut tags = vec![u32::MAX; total];
        for (i, &(k, t)) in pairs.iter().enumerate() {
            keys[i] = k;
            tags[i] = t;
        }
        let exe = self
            .rt
            .merge_exe((b, n))
            .ok_or_else(|| anyhow!("missing merge artifact ({b},{n})"))?;
        let lk = xla::Literal::vec1(&keys)
            .reshape(&[b as i64, n as i64])
            .map_err(|e| anyhow!("reshape keys: {e:?}"))?;
        let lt = xla::Literal::vec1(&tags)
            .reshape(&[b as i64, n as i64])
            .map_err(|e| anyhow!("reshape tags: {e:?}"))?;
        let result = exe
            .execute::<xla::Literal>(&[lk, lt])
            .map_err(|e| anyhow!("execute merge: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let (k, t, m) = result
            .to_tuple3()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        Ok((
            k.to_vec::<u32>().map_err(|e| anyhow!("{e:?}"))?,
            t.to_vec::<u32>().map_err(|e| anyhow!("{e:?}"))?,
            m.to_vec::<u32>().map_err(|e| anyhow!("{e:?}"))?,
            b,
            n,
        ))
    }

    /// Smallest shape with capacity >= len (or the largest overall).
    fn pick_shape(&self, len: usize) -> (usize, usize) {
        for &(b, n) in &self.shapes {
            if b * n >= len {
                return (b, n);
            }
        }
        *self.shapes.last().unwrap()
    }
}

/// Gather kept (non-pad) pairs row by row from artifact output.
fn collect_kept(
    keys: &[u32],
    tags: &[u32],
    keep: &[u32],
    b: usize,
    n: usize,
    out: &mut Vec<MergedPair>,
) {
    for row in 0..b {
        let base = row * n;
        for i in 0..n {
            if keep[base + i] != 0 && keys[base + i] != PAD_KEY {
                out.push((keys[base + i], tags[base + i]));
            }
        }
    }
}

/// Merge `b` concatenated sorted deduped rows of width <= n into one.
fn merge_sorted_dedup(flat: Vec<MergedPair>, _n: usize) -> Vec<MergedPair> {
    // Rows are concatenated in `flat` but each row is sorted; split on
    // descending key boundaries and k-way merge.
    let mut runs: Vec<Vec<MergedPair>> = Vec::new();
    let mut cur: Vec<MergedPair> = Vec::new();
    for p in flat {
        if let Some(&last) = cur.last() {
            if p.0 < last.0 {
                runs.push(std::mem::take(&mut cur));
            }
        }
        cur.push(p);
    }
    if !cur.is_empty() {
        runs.push(cur);
    }
    kway_merge_dedup(runs)
}

/// Linear k-way merge of sorted, per-run-deduped `(key, tag)` runs;
/// across runs the lowest tag wins per key.
pub fn kway_merge_dedup(runs: Vec<Vec<MergedPair>>) -> Vec<MergedPair> {
    let total: usize = runs.iter().map(|r| r.len()).sum();
    let mut heads: Vec<usize> = vec![0; runs.len()];
    let mut out: Vec<MergedPair> = Vec::with_capacity(total);
    loop {
        let mut best: Option<(u32, u32, usize)> = None;
        for (ri, run) in runs.iter().enumerate() {
            if let Some(&(k, t)) = run.get(heads[ri]) {
                let better = match best {
                    None => true,
                    Some((bk, bt, _)) => (k, t) < (bk, bt),
                };
                if better {
                    best = Some((k, t, ri));
                }
            }
        }
        match best {
            None => break,
            Some((k, t, ri)) => {
                heads[ri] += 1;
                match out.last() {
                    Some(&(lk, _)) if lk == k => {} // older duplicate
                    _ => out.push((k, t)),
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rust_merge_sorts_and_dedups() {
        let pairs = vec![(5, 30), (9, 1), (5, 10), (1, 2), (5, 20)];
        let out = merge_window_rust(&pairs);
        assert_eq!(out, vec![(1, 2), (5, 10), (9, 1)]);
    }

    #[test]
    fn rust_merge_strips_pad() {
        let pairs = vec![(PAD_KEY, 0), (3, 1), (PAD_KEY, u32::MAX)];
        assert_eq!(merge_window_rust(&pairs), vec![(3, 1)]);
    }

    #[test]
    fn rust_merge_empty() {
        assert!(merge_window_rust(&[]).is_empty());
    }

    #[test]
    fn kway_newest_wins_across_runs() {
        let runs = vec![vec![(1, 5), (4, 0)], vec![(1, 2), (2, 9)]];
        assert_eq!(kway_merge_dedup(runs), vec![(1, 2), (2, 9), (4, 0)]);
    }

    #[test]
    fn kway_empty_runs() {
        assert!(kway_merge_dedup(vec![vec![], vec![]]).is_empty());
    }

    #[test]
    fn merge_sorted_dedup_splits_rows() {
        // two sorted rows concatenated: [1,3,7] ++ [2,3,9]
        let flat = vec![(1, 0), (3, 4), (7, 1), (2, 2), (3, 3), (9, 5)];
        let out = merge_sorted_dedup(flat, 3);
        assert_eq!(out, vec![(1, 0), (2, 2), (3, 3), (7, 1), (9, 5)]);
    }
}
