//! PJRT runtime: load AOT artifacts (HLO text lowered by
//! `python/compile/aot.py`) and execute them from the Rust hot path.
//!
//! Python runs only at build time; after `make artifacts` the binary is
//! self-contained. Interchange is HLO *text* — the image's xla_extension
//! 0.5.1 rejects jax>=0.5 serialized protos (64-bit instruction ids), and
//! the text parser reassigns ids (see /opt/xla-example/README.md).

pub mod bloom;
pub mod merge;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

pub use bloom::BloomBuilder;
pub use merge::{MergeAccelerator, MergeEngine, PAD_KEY};

/// Compiled artifact registry keyed by artifact kind + shape.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    /// merge executables keyed by (batch, lanes)
    merges: HashMap<(usize, usize), xla::PjRtLoadedExecutable>,
    /// bloom executables keyed by (keys, probes, bits)
    blooms: HashMap<(usize, usize, usize), xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl XlaRuntime {
    /// Load and compile every artifact in `dir` (see aot.py for the naming
    /// scheme). Compilation happens once, here; execution is lock-free.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let mut rt = Self {
            client,
            merges: HashMap::new(),
            blooms: HashMap::new(),
            dir: dir.clone(),
        };
        // lint:allow(no-real-io): host-side artifact loading at process start, not simulation state
        let entries = std::fs::read_dir(&dir)
            .with_context(|| format!("artifacts dir {dir:?} (run `make artifacts`)"))?;
        for entry in entries {
            let path = entry?.path();
            let name = match path.file_name().and_then(|n| n.to_str()) {
                Some(n) => n,
                None => continue,
            };
            if let Some(shape) = parse_merge_name(name) {
                let exe = rt.compile(&path)?;
                rt.merges.insert(shape, exe);
            } else if let Some(shape) = parse_bloom_name(name) {
                let exe = rt.compile(&path)?;
                rt.blooms.insert(shape, exe);
            }
        }
        if rt.merges.is_empty() {
            return Err(anyhow!(
                "no merge artifacts found in {dir:?}; run `make artifacts`"
            ));
        }
        Ok(rt)
    }

    fn compile(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {path:?}: {e:?}"))
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Merge-window shapes available, sorted ascending by capacity.
    pub fn merge_shapes(&self) -> Vec<(usize, usize)> {
        let mut v: Vec<_> = self.merges.keys().copied().collect();
        v.sort_by_key(|&(b, n)| (b * n, n));
        v
    }

    pub fn bloom_shapes(&self) -> Vec<(usize, usize, usize)> {
        let mut v: Vec<_> = self.blooms.keys().copied().collect();
        v.sort();
        v
    }

    pub(crate) fn merge_exe(
        &self,
        shape: (usize, usize),
    ) -> Option<&xla::PjRtLoadedExecutable> {
        self.merges.get(&shape)
    }

    pub(crate) fn bloom_exe(
        &self,
        shape: (usize, usize, usize),
    ) -> Option<&xla::PjRtLoadedExecutable> {
        self.blooms.get(&shape)
    }
}

/// `merge_b{B}_n{N}.hlo.txt` -> (B, N)
fn parse_merge_name(name: &str) -> Option<(usize, usize)> {
    let rest = name.strip_prefix("merge_b")?.strip_suffix(".hlo.txt")?;
    let (b, n) = rest.split_once("_n")?;
    Some((b.parse().ok()?, n.parse().ok()?))
}

/// `bloom_n{N}_p{P}_m{M}.hlo.txt` -> (N, P, M)
fn parse_bloom_name(name: &str) -> Option<(usize, usize, usize)> {
    let rest = name.strip_prefix("bloom_n")?.strip_suffix(".hlo.txt")?;
    let (n, rest) = rest.split_once("_p")?;
    let (p, m) = rest.split_once("_m")?;
    Some((n.parse().ok()?, p.parse().ok()?, m.parse().ok()?))
}

/// Shared handle used across the engine. `None` (no artifacts) degrades to
/// the pure-Rust fallbacks — used by unit tests that shouldn't pay PJRT
/// startup, and exercised on purpose by `MergeEngine::rust()`.
pub type SharedRuntime = Option<Arc<XlaRuntime>>;

/// Canonical artifacts location relative to the repo root, overridable via
/// `KVACCEL_ARTIFACTS`.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("KVACCEL_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_merge_names() {
        assert_eq!(parse_merge_name("merge_b4_n4096.hlo.txt"), Some((4, 4096)));
        assert_eq!(parse_merge_name("merge_b1_n1024.hlo.txt"), Some((1, 1024)));
        assert_eq!(parse_merge_name("bloom_n1_p2_m3.hlo.txt"), None);
        assert_eq!(parse_merge_name("merge_b4_n4096.hlo"), None);
    }

    #[test]
    fn parse_bloom_names() {
        assert_eq!(
            parse_bloom_name("bloom_n32768_p7_m327680.hlo.txt"),
            Some((32768, 7, 327680))
        );
        assert_eq!(parse_bloom_name("merge_b4_n4096.hlo.txt"), None);
    }
}
