//! SST bloom-filter construction via the `bloom_build` artifact, plus the
//! bit-identical pure-Rust fallback used for probing at read time (the
//! read path only tests bits; building the whole bitmap is the batch
//! workload that rides the offload).

use anyhow::{anyhow, Result};
use std::sync::Arc;

use super::XlaRuntime;

/// Hash constants — MUST match python/compile/kernels/bloom.py.
pub const H1_MULT: u32 = 0x9E37_79B1;
pub const H2_MULT: u32 = 0x85EB_CA77;

/// Probe positions for `key` (double hashing, Kirsch-Mitzenmacher).
#[inline]
pub fn probe_positions(key: u32, num_probes: usize, num_bits: u32) -> impl Iterator<Item = u32> {
    let h1 = key.wrapping_mul(H1_MULT) >> 17;
    let h2 = (key.wrapping_mul(H2_MULT) >> 15) | 1;
    (0..num_probes as u32).map(move |i| (h1.wrapping_add(i.wrapping_mul(h2))) % num_bits)
}

/// Build the packed bitmap words in pure Rust (reference + fallback).
pub fn build_bitmap_rust(keys: &[u32], num_probes: usize, num_bits: u32) -> Vec<u32> {
    assert_eq!(num_bits % 32, 0);
    let mut words = vec![0u32; (num_bits / 32) as usize];
    for &k in keys {
        for pos in probe_positions(k, num_probes, num_bits) {
            words[(pos / 32) as usize] |= 1 << (pos % 32);
        }
    }
    words
}

/// Test a key against packed bitmap words.
#[inline]
pub fn may_contain(words: &[u32], key: u32, num_probes: usize, num_bits: u32) -> bool {
    probe_positions(key, num_probes, num_bits)
        .all(|pos| words[(pos / 32) as usize] >> (pos % 32) & 1 == 1)
}

/// Bloom bitmap builder: XLA artifact if available + shape matches,
/// otherwise the Rust fallback. Both produce identical words.
#[derive(Clone, Default)]
pub struct BloomBuilder {
    rt: Option<Arc<XlaRuntime>>,
}

impl BloomBuilder {
    pub fn rust() -> Self {
        Self { rt: None }
    }

    pub fn xla(rt: Arc<XlaRuntime>) -> Self {
        Self { rt: Some(rt) }
    }

    pub fn is_accelerated(&self) -> bool {
        self.rt.is_some()
    }

    /// Build bitmap words for `keys` with the given geometry.
    pub fn build(&self, keys: &[u32], num_probes: usize, num_bits: u32) -> Result<Vec<u32>> {
        if let Some(rt) = &self.rt {
            // Find an artifact with matching probes/bits and capacity.
            let shape = rt
                .bloom_shapes()
                .into_iter()
                .find(|&(n, p, m)| {
                    n >= keys.len() && p == num_probes && m as u32 == num_bits
                });
            if let Some((n, p, m)) = shape {
                return self.build_xla(rt, keys, n, p, m);
            }
        }
        Ok(build_bitmap_rust(keys, num_probes, num_bits))
    }

    fn build_xla(
        &self,
        rt: &Arc<XlaRuntime>,
        keys: &[u32],
        n: usize,
        p: usize,
        m: usize,
    ) -> Result<Vec<u32>> {
        let exe = rt
            .bloom_exe((n, p, m))
            .ok_or_else(|| anyhow!("missing bloom artifact ({n},{p},{m})"))?;
        let mut padded = vec![0u32; n];
        padded[..keys.len()].copy_from_slice(keys);
        let lk = xla::Literal::vec1(&padded)
            .reshape(&[1, n as i64])
            .map_err(|e| anyhow!("reshape bloom keys: {e:?}"))?;
        let lv = xla::Literal::scalar(keys.len() as u32);
        let result = exe
            .execute::<xla::Literal>(&[lk, lv])
            .map_err(|e| anyhow!("execute bloom: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch bloom: {e:?}"))?;
        let words = result
            .to_tuple1()
            .map_err(|e| anyhow!("untuple bloom: {e:?}"))?;
        words.to_vec::<u32>().map_err(|e| anyhow!("{e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_false_negatives() {
        let keys: Vec<u32> = (0..500u32).map(|i| i.wrapping_mul(2654435761)).collect();
        let words = build_bitmap_rust(&keys, 7, 4096);
        for &k in &keys {
            assert!(may_contain(&words, k, 7, 4096));
        }
    }

    #[test]
    fn false_positive_rate_reasonable() {
        let keys: Vec<u32> = (0..1000u32).map(|i| i.wrapping_mul(2654435761)).collect();
        // 10 bits/key, 7 probes -> ~1% fpr
        let words = build_bitmap_rust(&keys, 7, 10240);
        let fp = (1_000_000u32..1_010_000)
            .filter(|&k| may_contain(&words, k, 7, 10240))
            .count();
        assert!(fp < 500, "fp rate too high: {fp}/10000");
    }

    #[test]
    fn empty_filter_rejects() {
        let words = build_bitmap_rust(&[], 7, 1024);
        assert!(!may_contain(&words, 42, 7, 1024));
    }

    #[test]
    fn probe_positions_in_range() {
        for k in [0u32, 1, u32::MAX, 0xDEADBEEF] {
            for pos in probe_positions(k, 10, 333 * 32) {
                assert!(pos < 333 * 32);
            }
        }
    }
}
