//! Source scanning for `pallas-lint`: a comment/string-stripping
//! tokenizer plus the structural facts the rules need — per-site
//! `lint:allow` directives, `#[cfg(test)]` regions, and function spans.
//!
//! The stripper replaces every character inside comments, string
//! literals, char literals, and raw strings with a space (newlines are
//! preserved), so rule matching never fires on prose, doc examples, or
//! assertion messages. This is deliberately a lexer, not a parser: the
//! rules match identifier tokens on the stripped text, which is exact
//! enough for deny-by-default invariants (`unwrap` never matches
//! `unwrap_or`) without dragging in a full Rust grammar.

/// One `// lint:allow(<rule>): <reason>` directive. The directive
/// suppresses findings of `rule` on its own line and on the line
/// directly below it (so it can trail the violating expression or sit
/// on its own line above it). A directive without a written reason is
/// ignored — justification is the point of the mechanism.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allow {
    pub rule: String,
    /// 1-based line the directive is written on.
    pub line: usize,
    pub reason: String,
}

/// A `fn` item span in the file, used for function-scoped rules
/// (recovery-path panics, sync-before-delete ordering).
#[derive(Debug, Clone)]
pub struct FnSpan {
    pub name: String,
    /// 1-based line of the `fn` keyword.
    pub start: usize,
    /// 1-based line of the closing brace of the body.
    pub end: usize,
}

/// The scanned form of one source file.
pub struct ScannedFile {
    /// Path relative to `rust/src`, forward slashes.
    pub rel_path: String,
    /// First path component with any `.rs` suffix dropped — the module
    /// the rules scope on (`lsm/db.rs` -> `lsm`, `main.rs` -> `main`).
    pub module: String,
    /// Stripped source, split into lines; `lines[0]` is line 1.
    pub lines: Vec<String>,
    pub allows: Vec<Allow>,
    /// `test_mask[i]` is true when line `i + 1` lies inside a
    /// `#[cfg(test)]` item's braces.
    pub test_mask: Vec<bool>,
    pub fns: Vec<FnSpan>,
}

impl ScannedFile {
    /// Innermost function span containing `line`, if any.
    pub fn enclosing_fn(&self, line: usize) -> Option<&FnSpan> {
        self.fns
            .iter()
            .filter(|f| f.start <= line && line <= f.end)
            .max_by_key(|f| f.start)
    }

    pub fn in_test(&self, line: usize) -> bool {
        self.test_mask.get(line.saturating_sub(1)).copied().unwrap_or(false)
    }

    /// Is a finding of `rule` on `line` covered by an allow directive?
    pub fn allowed(&self, rule: &str, line: usize) -> Option<&Allow> {
        self.allows
            .iter()
            .find(|a| a.rule == rule && (a.line == line || a.line + 1 == line))
    }
}

/// Scan one file: strip, then derive the structural facts.
pub fn scan_source(rel_path: &str, src: &str) -> ScannedFile {
    let (stripped, allows) = strip(src);
    let lines: Vec<String> = stripped.lines().map(str::to_string).collect();
    let test_mask = test_regions(&stripped, lines.len());
    let fns = fn_spans(&stripped);
    ScannedFile {
        rel_path: rel_path.to_string(),
        module: module_of(rel_path),
        lines,
        allows,
        test_mask,
        fns,
    }
}

/// `lsm/db.rs` -> `lsm`; `main.rs` -> `main`; `bin/pallas_lint.rs` ->
/// `bin`.
pub fn module_of(rel_path: &str) -> String {
    let first = rel_path.split('/').next().unwrap_or(rel_path);
    first.trim_end_matches(".rs").to_string()
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Replace comments and literals with spaces, collecting `lint:allow`
/// directives from line comments along the way.
fn strip(src: &str) -> (String, Vec<Allow>) {
    let b: Vec<char> = src.chars().collect();
    let mut out = String::with_capacity(src.len());
    let mut allows = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            out.push('\n');
            line += 1;
            i += 1;
            continue;
        }
        // line comment: blank to end of line, parse allow directives
        if c == '/' && b.get(i + 1) == Some(&'/') {
            let start = i;
            while i < b.len() && b[i] != '\n' {
                i += 1;
            }
            let text: String = b[start..i].iter().collect();
            if let Some(a) = parse_allow(&text, line) {
                allows.push(a);
            }
            for _ in start..i {
                out.push(' ');
            }
            continue;
        }
        // block comment (Rust block comments nest)
        if c == '/' && b.get(i + 1) == Some(&'*') {
            let mut depth = 1usize;
            out.push(' ');
            out.push(' ');
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == '/' && b.get(i + 1) == Some(&'*') {
                    depth += 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else if b[i] == '*' && b.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else {
                    if b[i] == '\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        out.push(' ');
                    }
                    i += 1;
                }
            }
            continue;
        }
        // raw string: r"...", r#"..."#, br"..." — no escapes inside
        let prev_ident = i > 0 && is_ident_char(b[i - 1]);
        if (c == 'r' || c == 'b') && !prev_ident {
            if let Some((hashes, prefix_len)) = raw_string_start(&b, i) {
                for _ in 0..prefix_len {
                    out.push(' ');
                }
                i += prefix_len;
                while i < b.len() {
                    if b[i] == '"' && closes_raw(&b, i, hashes) {
                        for _ in 0..=hashes {
                            out.push(' ');
                        }
                        i += 1 + hashes;
                        break;
                    }
                    if b[i] == '\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        out.push(' ');
                    }
                    i += 1;
                }
                continue;
            }
        }
        // ordinary string literal (backslash escapes, may span lines)
        if c == '"' {
            out.push(' ');
            i += 1;
            while i < b.len() {
                if b[i] == '\\' && i + 1 < b.len() {
                    out.push(' ');
                    if b[i + 1] == '\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        out.push(' ');
                    }
                    i += 2;
                    continue;
                }
                if b[i] == '"' {
                    out.push(' ');
                    i += 1;
                    break;
                }
                if b[i] == '\n' {
                    out.push('\n');
                    line += 1;
                } else {
                    out.push(' ');
                }
                i += 1;
            }
            continue;
        }
        // char literal vs lifetime: 'x' / '\n' are literals, 'a in
        // `&'a str` is a lifetime and passes through untouched
        if c == '\'' {
            let is_char = b.get(i + 1) == Some(&'\\')
                || (b.get(i + 2) == Some(&'\'') && b.get(i + 1) != Some(&'\''));
            if is_char {
                out.push(' ');
                i += 1;
                while i < b.len() {
                    if b[i] == '\\' && i + 1 < b.len() {
                        out.push(' ');
                        out.push(' ');
                        i += 2;
                        continue;
                    }
                    if b[i] == '\'' {
                        out.push(' ');
                        i += 1;
                        break;
                    }
                    if b[i] == '\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        out.push(' ');
                    }
                    i += 1;
                }
                continue;
            }
            out.push('\'');
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }
    (out, allows)
}

/// Does position `i` start a raw string (`r"`, `r#"`, `br##"` ...)?
/// Returns (hash count, prefix length including the opening quote).
fn raw_string_start(b: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if b.get(j) == Some(&'b') {
        j += 1;
    }
    if b.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while b.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if b.get(j) != Some(&'"') {
        return None;
    }
    Some((hashes, j + 1 - i))
}

fn closes_raw(b: &[char], i: usize, hashes: usize) -> bool {
    (1..=hashes).all(|k| b.get(i + k) == Some(&'#'))
}

/// Parse `lint:allow(<rule>): <reason>` out of a line comment.
fn parse_allow(comment: &str, line: usize) -> Option<Allow> {
    let pos = comment.find("lint:allow(")?;
    let rest = &comment[pos + "lint:allow(".len()..];
    let close = rest.find(')')?;
    let rule = rest[..close].trim();
    if rule.is_empty() {
        return None;
    }
    let after = rest[close + 1..].trim_start();
    let reason = after.strip_prefix(':').map(str::trim).unwrap_or("");
    if reason.is_empty() {
        // a justification is mandatory; an unexplained allow is inert
        return None;
    }
    Some(Allow { rule: rule.to_string(), line, reason: reason.to_string() })
}

/// Mark every line inside a `#[cfg(test)]` item's braces.
fn test_regions(stripped: &str, nlines: usize) -> Vec<bool> {
    let bytes = stripped.as_bytes();
    let mut mask = vec![false; nlines];
    let mut search = 0usize;
    while let Some(rel) = stripped[search..].find("cfg(test)") {
        let attr = search + rel;
        search = attr + "cfg(test)".len();
        // the guarded item's body is the next brace block
        let Some(open_rel) = stripped[search..].find('{') else { break };
        let open = search + open_rel;
        let mut depth = 0usize;
        let mut close = bytes.len();
        for (k, &ch) in bytes.iter().enumerate().skip(open) {
            if ch == b'{' {
                depth += 1;
            } else if ch == b'}' {
                depth -= 1;
                if depth == 0 {
                    close = k;
                    break;
                }
            }
        }
        let first = line_of(bytes, open);
        let last = line_of(bytes, close.min(bytes.len() - 1));
        let lo = (first - 1).min(nlines);
        let hi = last.min(nlines);
        if lo < hi {
            for m in &mut mask[lo..hi] {
                *m = true;
            }
        }
        search = close.min(bytes.len());
    }
    mask
}

/// 1-based line of byte offset `pos`.
fn line_of(bytes: &[u8], pos: usize) -> usize {
    1 + bytes[..pos.min(bytes.len())].iter().filter(|&&c| c == b'\n').count()
}

/// Find `fn` item spans by tracking brace depth. Trait-method
/// declarations (`fn f();`) are cancelled by the `;` before any body.
fn fn_spans(stripped: &str) -> Vec<FnSpan> {
    let bytes = stripped.as_bytes();
    let mut spans = Vec::new();
    // (name, start line, depth the body opened at)
    let mut stack: Vec<(String, usize, usize)> = Vec::new();
    let mut pending: Option<(String, usize)> = None;
    let mut expecting_name = false;
    let mut depth = 0usize;
    let mut i = 0usize;
    let mut line = 1usize;
    while i < bytes.len() {
        let c = bytes[i] as char;
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if is_ident_char(c) {
            let start = i;
            while i < bytes.len() && is_ident_char(bytes[i] as char) {
                i += 1;
            }
            let word = &stripped[start..i];
            if expecting_name {
                pending = Some((word.to_string(), line));
                expecting_name = false;
            } else if word == "fn" {
                expecting_name = true;
            }
            continue;
        }
        match c {
            '{' => {
                depth += 1;
                if let Some((name, start)) = pending.take() {
                    stack.push((name, start, depth));
                }
            }
            '}' => {
                if let Some(&(_, _, d)) = stack.last() {
                    if d == depth {
                        if let Some((name, start, _)) = stack.pop() {
                            spans.push(FnSpan { name, start, end: line });
                        }
                    }
                }
                depth = depth.saturating_sub(1);
            }
            ';' => {
                // `fn f();` — declaration without a body
                pending = None;
            }
            _ => {}
        }
        i += 1;
    }
    // unterminated spans (truncated input) close at the last line
    while let Some((name, start, _)) = stack.pop() {
        spans.push(FnSpan { name, start, end: line });
    }
    spans.sort_by_key(|s| s.start);
    spans
}

/// Iterate the identifier tokens of one stripped line with their byte
/// offsets.
pub fn idents(line: &str) -> Vec<(usize, &str)> {
    let bytes = line.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < bytes.len() {
        if is_ident_char(bytes[i] as char) {
            let start = i;
            while i < bytes.len() && is_ident_char(bytes[i] as char) {
                i += 1;
            }
            out.push((start, &line[start..i]));
        } else {
            i += 1;
        }
    }
    out
}

/// True when identifier token `word` occurs on `line` (exact token
/// match: `unwrap` does not match `unwrap_or`).
pub fn has_ident(line: &str, word: &str) -> bool {
    idents(line).iter().any(|(_, w)| *w == word)
}

/// True when `line` invokes macro `name!`.
pub fn has_macro(line: &str, name: &str) -> bool {
    for (off, w) in idents(line) {
        if w == name {
            let rest = line[off + w.len()..].trim_start();
            if rest.starts_with('!') {
                return true;
            }
        }
    }
    false
}

/// True when `line` mentions path `std::<seg>` (whitespace-tolerant).
pub fn has_std_path(line: &str, seg: &str) -> bool {
    let toks = idents(line);
    for (k, (off, w)) in toks.iter().enumerate() {
        if *w != "std" {
            continue;
        }
        if let Some((noff, nw)) = toks.get(k + 1) {
            let between = &line[off + w.len()..*noff];
            if between.trim() == "::" && *nw == seg {
                return true;
            }
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_blanked() {
        let src = "let a = \"Instant::now()\"; // Instant here too\nlet b = 1;\n";
        let (s, allows) = strip(src);
        assert!(!s.contains("Instant"));
        assert!(allows.is_empty());
        assert_eq!(s.lines().count(), src.lines().count());
    }

    #[test]
    fn nested_block_comments_and_raw_strings() {
        let src = "/* outer /* inner HashMap */ still */ let x = r#\"HashSet\"#;\n";
        let (s, _) = strip(src);
        assert!(!s.contains("HashMap"));
        assert!(!s.contains("HashSet"));
        assert!(s.contains("let x ="));
    }

    #[test]
    fn lifetimes_survive_char_literals_do_not() {
        let src = "fn f<'a>(s: &'a str) -> char { 'x' }\n";
        let (s, _) = strip(src);
        assert!(s.contains("'a"));
        assert!(!s.contains("'x'"));
    }

    #[test]
    fn allow_directive_requires_a_reason() {
        let with = "// lint:allow(no-wall-clock): calibration harness\n";
        let (_, a) = strip(with);
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].rule, "no-wall-clock");
        let without = "// lint:allow(no-wall-clock)\n";
        let (_, a) = strip(without);
        assert!(a.is_empty(), "an allow with no reason is inert");
    }

    #[test]
    fn test_region_masks_the_mod_body() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn tail() {}\n";
        let f = scan_source("lsm/x.rs", src);
        assert!(!f.in_test(1));
        assert!(f.in_test(4));
        assert!(!f.in_test(6));
    }

    #[test]
    fn fn_spans_nest_and_close() {
        let src = "fn outer() {\n    fn inner() {\n        let x = 1;\n    }\n}\n";
        let f = scan_source("lsm/x.rs", src);
        let inner = f.enclosing_fn(3).map(|s| s.name.clone());
        assert_eq!(inner.as_deref(), Some("inner"));
        let outer = f.enclosing_fn(5).map(|s| s.name.clone());
        assert_eq!(outer.as_deref(), Some("outer"));
    }

    #[test]
    fn ident_matching_is_exact() {
        assert!(has_ident("x.unwrap()", "unwrap"));
        assert!(!has_ident("x.unwrap_or(0)", "unwrap"));
        assert!(has_macro("panic!(\"boom\")", "panic"));
        assert!(!has_macro("self.panic_count += 1", "panic"));
        assert!(has_std_path("use std::fs::File;", "fs"));
        assert!(!has_std_path("use std::fmt;", "fs"));
    }
}
