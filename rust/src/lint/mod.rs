//! `pallas-lint`: repo-invariant static analysis for the simulator.
//!
//! Every headline result in this reproduction rests on invariants the
//! compiler cannot see: bit-identical traces per seed (PR2), QoS-off
//! and replication-off identity (PR6/PR8), and crash-safety orderings
//! like sync-before-delete (PR4). This module machine-checks them as
//! deny-by-default rules over `rust/src/**`:
//!
//! - **no-wall-clock** — no `Instant`/`SystemTime` outside the
//!   real-time harness allowlist; simulation time is virtual `Nanos`.
//! - **no-ambient-rng** — no `thread_rng`/`from_entropy`/`OsRng`; all
//!   randomness comes from seeded per-client streams.
//! - **no-unordered-iteration** — no `HashMap`/`HashSet` in the
//!   trace-affecting modules; deterministic collections only.
//! - **no-panic-in-recovery** — no `unwrap`/`expect`/`panic!` in
//!   manifest replay, WAL recovery, rollback, or Merkle-rejoin paths.
//! - **no-real-io** — `std::fs`/`std::net`/`std::thread` stay in the
//!   env/CLI layer.
//! - **sync-before-delete** — device-state deletion requires earlier
//!   sync/manifest evidence in the same function (the PR4 bug class).
//!
//! Suppression is per-site and must be justified:
//! `// lint:allow(<rule>): <reason>` on (or directly above) the line.
//! A checked-in baseline file (`rust/lint_baseline.txt`) can park known
//! findings during a migration; the tree currently lints clean against
//! an **empty** baseline, and CI keeps it that way via
//! `cargo run --bin pallas_lint`.
//!
//! See DESIGN.md §13 for the rule-by-rule rationale.

pub mod rules;
pub mod scan;

pub use rules::{check_file, FileReport, Finding, ALL_RULES};
pub use scan::{scan_source, ScannedFile};

/// Lint one source file: scan, run every rule, apply inline allows.
pub fn lint_file(rel_path: &str, src: &str) -> FileReport {
    check_file(&scan_source(rel_path, src))
}

/// Live (unsuppressed) findings for one source file.
pub fn lint_source(rel_path: &str, src: &str) -> Vec<Finding> {
    lint_file(rel_path, src).findings
}

/// The checked-in baseline: findings that are acknowledged but not yet
/// remediated. One entry per line, `<path>:<line>:<rule>` with `*`
/// accepted for the line number (survives unrelated line drift);
/// `#` comments and blank lines are ignored.
#[derive(Debug, Default)]
pub struct Baseline {
    entries: Vec<(String, Option<usize>, String)>,
}

impl Baseline {
    pub fn parse(text: &str) -> Self {
        let mut entries = Vec::new();
        for raw in text.lines() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.rsplitn(3, ':');
            let rule = parts.next().map(str::trim);
            let lineno = parts.next().map(str::trim);
            let path = parts.next().map(str::trim);
            if let (Some(path), Some(lineno), Some(rule)) = (path, lineno, rule) {
                let n = if lineno == "*" {
                    None
                } else {
                    match lineno.parse::<usize>() {
                        Ok(v) => Some(v),
                        Err(_) => continue,
                    }
                };
                entries.push((path.to_string(), n, rule.to_string()));
            }
        }
        Self { entries }
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn covers(&self, f: &Finding) -> bool {
        self.entries.iter().any(|(path, line, rule)| {
            *path == f.path
                && *rule == f.rule
                && line.map(|l| l == f.line).unwrap_or(true)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_parks_a_matching_finding() {
        let src = "fn f() { let t = Instant::now(); }\n";
        let findings = lint_source("sim/clock.rs", src);
        assert_eq!(findings.len(), 1);
        let exact = Baseline::parse("sim/clock.rs:1:no-wall-clock\n");
        assert!(exact.covers(&findings[0]));
        let wildcard = Baseline::parse("# park during migration\nsim/clock.rs:*:no-wall-clock\n");
        assert!(wildcard.covers(&findings[0]));
        let other = Baseline::parse("sim/clock.rs:1:no-real-io\n");
        assert!(!other.covers(&findings[0]));
        let wrong_line = Baseline::parse("sim/clock.rs:9:no-wall-clock\n");
        assert!(!wrong_line.covers(&findings[0]));
    }

    #[test]
    fn empty_baseline_parses_empty() {
        let b = Baseline::parse("# nothing parked\n\n");
        assert!(b.is_empty());
        assert_eq!(b.len(), 0);
    }
}
