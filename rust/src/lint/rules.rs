//! The deny-by-default rule set `pallas-lint` enforces, and the
//! incidents each rule guards. Scoping is module-aware: a rule either
//! applies everywhere minus an allowlist of harness files, or only to
//! the trace-affecting simulation modules whose behavior feeds the
//! bit-identity claims.

use super::scan::{has_ident, has_macro, has_std_path, ScannedFile};

/// One rule violation at a specific site.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Path relative to `rust/src`.
    pub path: String,
    /// 1-based line.
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

/// Result of checking one file: live findings plus the count of sites
/// an inline `lint:allow` suppressed (reported, never hidden).
pub struct FileReport {
    pub findings: Vec<Finding>,
    pub suppressed: usize,
}

/// Modules whose behavior feeds the deterministic trace: anything here
/// that iterates an unordered collection, reads a wall clock, or draws
/// ambient randomness can silently break the PR2/PR6/PR8 bit-identity
/// invariants.
const TRACE_MODULES: &[&str] = &[
    "sim", "workload", "lsm", "kvaccel", "shard", "qos", "repl", "ssd",
    "engine", "vlog",
];

/// Real-time harness files: the only place `Instant`/`SystemTime` is
/// legitimate (micro-bench timing, experiment wall-clock tables).
const WALL_CLOCK_ALLOW: &[&str] = &["bench_util.rs", "experiments/tables.rs"];

/// The env/CLI layer that is allowed to touch the real machine:
/// process entry points, experiment emitters, the lint tool itself.
const REAL_IO_ALLOW: &[&str] =
    &["main.rs", "bin/", "lint/", "experiments/", "util/cli.rs"];

/// Recovery-path files checked whole-file for panics (test mods exempt).
const RECOVERY_FILES: &[&str] = &[
    "lsm/manifest.rs",
    "lsm/wal.rs",
    "kvaccel/rollback.rs",
    "repl/merkle.rs",
];

/// Function-name prefixes that mark a recovery/replay path in the
/// trace modules: these run after a crash, where a panic turns a
/// recoverable store into an unrecoverable one.
const RECOVERY_FN_PREFIXES: &[&str] = &[
    "open",
    "recover",
    "replay",
    "rebuild",
    "rejoin",
    "anti_entropy",
    "crash_into_image",
    "power_loss",
];

/// Calls that destroy durable device state.
const DELETE_TOKENS: &[&str] = &["delete_file", "kv_reset"];

/// Evidence that the durable record preceding a delete was synced (or
/// replayed): the PR4 sync-before-delete ordering.
const SYNC_EVIDENCE: &[&str] =
    &["meta_sync_write", "wal_sync_on", "wal_sync", "fsync", "manifest"];

/// Modules where the sync-before-delete heuristic applies. `ssd` is
/// exempt: it *implements* the delete/sync mechanisms.
const SYNC_RULE_MODULES: &[&str] =
    &["lsm", "kvaccel", "shard", "repl", "engine", "vlog"];

pub const ALL_RULES: &[&str] = &[
    "no-wall-clock",
    "no-ambient-rng",
    "no-unordered-iteration",
    "no-panic-in-recovery",
    "no-real-io",
    "sync-before-delete",
];

fn path_in(path: &str, list: &[&str]) -> bool {
    list.iter().any(|p| {
        if p.ends_with('/') {
            path.starts_with(p)
        } else {
            path == *p
        }
    })
}

fn is_trace_module(module: &str) -> bool {
    TRACE_MODULES.contains(&module)
}

fn is_recovery_fn(name: &str) -> bool {
    RECOVERY_FN_PREFIXES.iter().any(|p| name.starts_with(p))
}

/// Run every rule over one scanned file, applying inline allows.
pub fn check_file(f: &ScannedFile) -> FileReport {
    let mut raw: Vec<Finding> = Vec::new();
    no_wall_clock(f, &mut raw);
    no_ambient_rng(f, &mut raw);
    no_unordered_iteration(f, &mut raw);
    no_panic_in_recovery(f, &mut raw);
    no_real_io(f, &mut raw);
    sync_before_delete(f, &mut raw);
    raw.sort_by_key(|x| (x.line, x.rule));
    let mut findings = Vec::new();
    let mut suppressed = 0usize;
    for x in raw {
        if f.allowed(x.rule, x.line).is_some() {
            suppressed += 1;
        } else {
            findings.push(x);
        }
    }
    FileReport { findings, suppressed }
}

/// no-wall-clock: simulation code runs on virtual `Nanos` only; a real
/// clock read anywhere else silently decouples results from the seed
/// (the PR2 bit-identity claim).
fn no_wall_clock(f: &ScannedFile, out: &mut Vec<Finding>) {
    if path_in(&f.rel_path, WALL_CLOCK_ALLOW) {
        return;
    }
    for (i, line) in f.lines.iter().enumerate() {
        for tok in ["Instant", "SystemTime"] {
            if has_ident(line, tok) {
                out.push(Finding {
                    path: f.rel_path.clone(),
                    line: i + 1,
                    rule: "no-wall-clock",
                    msg: format!(
                        "`{tok}` outside the real-time harness allowlist; \
                         simulation time is virtual `Nanos` only"
                    ),
                });
            }
        }
    }
}

/// no-ambient-rng: all randomness flows from seeded per-client streams;
/// an ambient generator makes runs irreproducible.
fn no_ambient_rng(f: &ScannedFile, out: &mut Vec<Finding>) {
    for (i, line) in f.lines.iter().enumerate() {
        for tok in ["thread_rng", "from_entropy", "OsRng"] {
            if has_ident(line, tok) {
                out.push(Finding {
                    path: f.rel_path.clone(),
                    line: i + 1,
                    rule: "no-ambient-rng",
                    msg: format!(
                        "`{tok}` draws ambient entropy; use the seeded \
                         per-client RNG streams"
                    ),
                });
            }
        }
    }
}

/// no-unordered-iteration: `HashMap`/`HashSet` in a trace module. Even
/// membership-only uses are banned — the cheapest way to keep iteration
/// order out of the trace is to not hold unordered collections where
/// the trace is produced.
fn no_unordered_iteration(f: &ScannedFile, out: &mut Vec<Finding>) {
    if !is_trace_module(&f.module) {
        return;
    }
    for (i, line) in f.lines.iter().enumerate() {
        for tok in ["HashMap", "HashSet"] {
            if has_ident(line, tok) {
                out.push(Finding {
                    path: f.rel_path.clone(),
                    line: i + 1,
                    rule: "no-unordered-iteration",
                    msg: format!(
                        "`{tok}` in trace module `{}`; use BTreeMap/BTreeSet \
                         (or a sorted snapshot) so iteration order is \
                         deterministic",
                        f.module
                    ),
                });
            }
        }
    }
}

/// no-panic-in-recovery: manifest replay, WAL recovery, rollback, and
/// Merkle-rejoin paths must return `Result` — a panic during recovery
/// turns a crashed-but-recoverable store into a dead one.
fn no_panic_in_recovery(f: &ScannedFile, out: &mut Vec<Finding>) {
    let whole_file = path_in(&f.rel_path, RECOVERY_FILES);
    if !whole_file && !is_trace_module(&f.module) {
        return;
    }
    for (i, line) in f.lines.iter().enumerate() {
        let lineno = i + 1;
        if f.in_test(lineno) {
            continue;
        }
        let in_scope = whole_file
            || f.enclosing_fn(lineno).is_some_and(|s| is_recovery_fn(&s.name));
        if !in_scope {
            continue;
        }
        for tok in ["unwrap", "expect"] {
            if has_ident(line, tok) {
                out.push(Finding {
                    path: f.rel_path.clone(),
                    line: lineno,
                    rule: "no-panic-in-recovery",
                    msg: format!(
                        "`{tok}` on a recovery path; propagate a `Result` \
                         instead of panicking mid-recovery"
                    ),
                });
            }
        }
        if has_macro(line, "panic") {
            out.push(Finding {
                path: f.rel_path.clone(),
                line: lineno,
                rule: "no-panic-in-recovery",
                msg: "`panic!` on a recovery path; return an error instead"
                    .to_string(),
            });
        }
    }
}

/// no-real-io: `std::fs`/`std::net`/`std::thread` stay in the env/CLI
/// layer; the simulator proper must not touch the real machine.
fn no_real_io(f: &ScannedFile, out: &mut Vec<Finding>) {
    if path_in(&f.rel_path, REAL_IO_ALLOW) {
        return;
    }
    for (i, line) in f.lines.iter().enumerate() {
        for seg in ["fs", "net", "thread"] {
            if has_std_path(line, seg) {
                out.push(Finding {
                    path: f.rel_path.clone(),
                    line: i + 1,
                    rule: "no-real-io",
                    msg: format!(
                        "`std::{seg}` outside the env/CLI layer; simulation \
                         code must not perform real I/O"
                    ),
                });
            }
        }
    }
}

/// sync-before-delete: a function that deletes durable device state
/// (`delete_file`, `kv_reset`) must show sync/manifest evidence earlier
/// in its body — the exact ordering bug PR4 fixed, where files died
/// before the manifest edit naming their replacement was durable.
fn sync_before_delete(f: &ScannedFile, out: &mut Vec<Finding>) {
    if !SYNC_RULE_MODULES.contains(&f.module.as_str()) {
        return;
    }
    for span in &f.fns {
        if f.in_test(span.start) {
            continue;
        }
        let mut evidence = false;
        let end = span.end.min(f.lines.len());
        for (idx, line) in f.lines.iter().enumerate().take(end).skip(span.start - 1) {
            let lineno = idx + 1;
            if SYNC_EVIDENCE.iter().any(|t| has_ident(line, t)) {
                evidence = true;
            }
            if evidence {
                continue;
            }
            for tok in DELETE_TOKENS {
                if has_ident(line, tok) {
                    out.push(Finding {
                        path: f.rel_path.clone(),
                        line: lineno,
                        rule: "sync-before-delete",
                        msg: format!(
                            "`{tok}` in `{}` with no prior sync/manifest \
                             evidence; durable state must be synced before \
                             its predecessor is deleted",
                            span.name
                        ),
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::lint::lint_source;

    fn rules_of(findings: &[super::Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    // --- no-wall-clock -----------------------------------------------

    #[test]
    fn wall_clock_fires_in_sim_code() {
        let src = "fn f() { let t = Instant::now(); }\n";
        let f = lint_source("sim/clock.rs", src);
        assert_eq!(rules_of(&f), vec!["no-wall-clock"]);
    }

    #[test]
    fn wall_clock_silent_on_the_harness_allowlist() {
        let src = "fn f() { let t = Instant::now(); }\n";
        assert!(lint_source("bench_util.rs", src).is_empty());
        assert!(lint_source("experiments/tables.rs", src).is_empty());
    }

    #[test]
    fn wall_clock_ignores_strings_and_comments() {
        let src = "// Instant::now() is banned\nfn f() { let s = \"SystemTime\"; }\n";
        assert!(lint_source("sim/clock.rs", src).is_empty());
    }

    // --- no-ambient-rng ----------------------------------------------

    #[test]
    fn ambient_rng_fires_everywhere() {
        let src = "fn f() { let mut r = thread_rng(); }\n";
        assert_eq!(rules_of(&lint_source("util/x.rs", src)), vec!["no-ambient-rng"]);
        let src2 = "fn f() { let r = OsRng; }\n";
        assert_eq!(rules_of(&lint_source("lsm/x.rs", src2)), vec!["no-ambient-rng"]);
    }

    #[test]
    fn seeded_rng_is_silent() {
        let src = "fn f(seed: u64) { let mut r = SplitMix64::new(seed); }\n";
        assert!(lint_source("workload/keygen.rs", src).is_empty());
    }

    // --- no-unordered-iteration --------------------------------------

    #[test]
    fn unordered_iteration_fires_in_trace_modules() {
        let src = "use std::collections::HashMap;\n";
        let f = lint_source("lsm/x.rs", src);
        assert_eq!(rules_of(&f), vec!["no-unordered-iteration"]);
    }

    #[test]
    fn unordered_iteration_silent_outside_trace_modules() {
        let src = "use std::collections::HashMap;\n";
        assert!(lint_source("util/lru.rs", src).is_empty());
        assert!(lint_source("runtime/x.rs", src).is_empty());
    }

    #[test]
    fn btree_collections_are_silent() {
        let src = "use std::collections::{BTreeMap, BTreeSet};\n";
        assert!(lint_source("lsm/x.rs", src).is_empty());
    }

    // --- no-panic-in-recovery ----------------------------------------

    #[test]
    fn panic_in_recovery_fires_in_an_open_fn() {
        let src = "fn open() { x.unwrap(); }\n";
        let f = lint_source("lsm/db.rs", src);
        assert_eq!(rules_of(&f), vec!["no-panic-in-recovery"]);
        let src2 = "fn rebuild_from() { y.expect(\"boom\"); }\n";
        let f2 = lint_source("kvaccel/metadata.rs", src2);
        assert_eq!(rules_of(&f2), vec!["no-panic-in-recovery"]);
    }

    #[test]
    fn panic_outside_recovery_fns_is_silent() {
        let src = "fn put() { x.unwrap(); }\n";
        assert!(lint_source("lsm/db.rs", src).is_empty());
    }

    #[test]
    fn unwrap_or_is_not_unwrap() {
        let src = "fn open() { let v = x.unwrap_or(0).max(y.unwrap_or_default()); }\n";
        assert!(lint_source("lsm/db.rs", src).is_empty());
    }

    #[test]
    fn recovery_files_are_checked_whole_file_minus_tests() {
        let src = "fn helper() { x.unwrap(); }\n";
        let f = lint_source("repl/merkle.rs", src);
        assert_eq!(rules_of(&f), vec!["no-panic-in-recovery"]);
        let in_tests =
            "#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\n";
        assert!(lint_source("repl/merkle.rs", in_tests).is_empty());
    }

    #[test]
    fn panic_macro_fires_on_recovery_paths() {
        let src = "fn replay() { panic!(\"torn log\"); }\n";
        let f = lint_source("lsm/wal.rs", src);
        assert_eq!(rules_of(&f), vec!["no-panic-in-recovery"]);
    }

    // --- no-real-io --------------------------------------------------

    #[test]
    fn real_io_fires_in_sim_code() {
        let src = "fn f() { let d = std::fs::read_dir(p); }\n";
        let f = lint_source("sim/x.rs", src);
        assert_eq!(rules_of(&f), vec!["no-real-io"]);
    }

    #[test]
    fn real_io_silent_in_the_env_cli_layer() {
        let src = "fn f() { let d = std::fs::read_dir(p); }\n";
        assert!(lint_source("main.rs", src).is_empty());
        assert!(lint_source("experiments/recovery.rs", src).is_empty());
        assert!(lint_source("bin/pallas_lint.rs", src).is_empty());
    }

    // --- sync-before-delete ------------------------------------------

    #[test]
    fn delete_without_sync_evidence_fires() {
        let src = "fn complete(&mut self) {\n    env.device.delete_file(id);\n}\n";
        let f = lint_source("lsm/compact.rs", src);
        assert_eq!(rules_of(&f), vec!["sync-before-delete"]);
    }

    #[test]
    fn delete_after_sync_evidence_is_silent() {
        let src = "fn complete(&mut self) {\n    env.device.meta_sync_write(at, bytes);\n    env.device.delete_file(id);\n}\n";
        assert!(lint_source("lsm/compact.rs", src).is_empty());
        let manifest_first = "fn open() {\n    let rec = manifest.rebuild(n);\n    env.device.delete_file(id);\n}\n";
        assert!(lint_source("lsm/compact.rs", manifest_first).is_empty());
    }

    #[test]
    fn sync_rule_skips_the_ssd_layer() {
        let src = "fn gc(&mut self) {\n    self.delete_file(id);\n}\n";
        assert!(lint_source("ssd/block_if.rs", src).is_empty());
    }

    // --- suppressions ------------------------------------------------

    #[test]
    fn inline_allow_suppresses_one_site() {
        let src = "fn f() {\n    // lint:allow(no-wall-clock): calibration-only probe\n    let t = Instant::now();\n}\n";
        assert!(lint_source("sim/clock.rs", src).is_empty());
        // a trailing same-line allow works too
        let trailing = "fn f() { let t = Instant::now(); } // lint:allow(no-wall-clock): calibration-only probe\n";
        assert!(lint_source("sim/clock.rs", trailing).is_empty());
    }

    #[test]
    fn allow_for_the_wrong_rule_does_not_suppress() {
        let src = "fn f() {\n    // lint:allow(no-real-io): wrong rule\n    let t = Instant::now();\n}\n";
        let f = lint_source("sim/clock.rs", src);
        assert_eq!(rules_of(&f), vec!["no-wall-clock"]);
    }

    #[test]
    fn allow_without_reason_does_not_suppress() {
        let src = "fn f() {\n    // lint:allow(no-wall-clock)\n    let t = Instant::now();\n}\n";
        let f = lint_source("sim/clock.rs", src);
        assert_eq!(rules_of(&f), vec!["no-wall-clock"]);
    }

    #[test]
    fn suppressed_sites_are_counted() {
        let src = "fn f() {\n    // lint:allow(no-wall-clock): calibration-only probe\n    let t = Instant::now();\n}\n";
        let rep = crate::lint::lint_file("sim/clock.rs", src);
        assert!(rep.findings.is_empty());
        assert_eq!(rep.suppressed, 1);
    }
}
