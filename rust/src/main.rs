//! KVACCEL CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   run <workload>      run a single workload (A|B|C|D|E / ycsb-e) on one system
//!   experiment <id|all> regenerate a paper figure/table (see DESIGN.md)
//!   bench               fixed open-loop comparison -> BENCH_PR2.json,
//!                       plus the scan-path bench -> BENCH_PR3.json
//!   inspect             print artifact + device model info
//!
//! Examples:
//!   kvaccel run A --system kvaccel --threads 4 --scale 0.1
//!   kvaccel run A --clients 8 --loop-mode open --rate 50000 --dist zipfian
//!   kvaccel run B --system rocksdb --clients 2 --loop-mode poisson --rate 20000
//!   kvaccel run ycsb-e --system kvaccel --scan-len 1:100 --dist zipfian
//!   kvaccel experiment fig12 --scale 0.25 --engine xla
//!   kvaccel bench --out BENCH_PR2.json --scan-out BENCH_PR3.json --scale 0.02
//!
//! Workload scheduler flags (run):
//!   --clients N          concurrent clients (default 1)
//!   --loop-mode M        closed | open | poisson (default closed)
//!   --rate R             aggregate offered ops/s for open/poisson
//!   --think-ms T         closed-loop think time per op (default 0)
//!   --dist D             uniform | zipfian | latest (default uniform)
//!   --theta F            zipfian skew in (0,1) (default 0.99)
//!   --scan-len L[:H]     YCSB-E Next count per scan: fixed L, or
//!                        uniform in [L, H] (default 1:100)
//!   --value-size S       per-op value size in bytes: fixed N,
//!                        uniform L:H, or lognormal:MU:SIGMA
//!                        (log-space parameters; preset default 4096)
//!   --vlog-threshold B   WiscKey-style key-value separation: values
//!                        >= B bytes go to the value log, the LSM keeps
//!                        a 12 B pointer (0/omitted = all inline)
//!   --vlog-segment-bytes B  value-log segment size (default 32 MiB)
//!   --crash-at P         inject a power loss after P issued ops (plain
//!                        integer) or at virtual time P (s|ms|ns
//!                        suffix), then reopen and report recovery
//!   --tenants N          round-robin the clients over N QoS tenants and
//!                        report a per-tenant breakdown
//!   --tenant-rate R      token-bucket admission rate per tenant, ops/s
//!                        (0/omitted = account only, no metering)
//!   --tenant-slo-p99 MS  p99 SLO per tenant in ms; an over-SLO tenant
//!                        has its stale open-loop backlog shed first
//!   --cache-blocks N     engine-wide block cache capacity in blocks
//!                        (0 disables; default from LsmOptions)
//!   --compression C      SST data-block codec: none, or lz-like[:RATIO]
//!                        with RATIO the compressed size in percent of
//!                        logical (1..=100, default 50)
//!   --replicas N         run N replicated nodes (a primary plus N-1
//!                        replicas) behind one store, shipping the
//!                        primary's CDC stream over a simulated link
//!   --read-policy P      primary | ryw | eventual (default primary)
//!   --repl-latency US    one-way link latency in microseconds
//!   --repl-bandwidth MB  per-link bandwidth in MB/s
//!
//! Read-heavy YCSB point presets: ycsb-b (95% read / 5% update),
//! ycsb-c (read-only), ycsb-d (read-latest; forces --dist latest).
//! Each preloads a working set before the timed phase.
//!
//! Contradictory flags are rejected up front (e.g. --rate with a closed
//! loop, --theta without --dist zipfian, --shard-policy without
//! --shards, --tenant-rate without --tenants, --dist with ycsb-d,
//! --read-policy without --replicas, --replicas 1,
//! --vlog-segment-bytes without --vlog-threshold).

use anyhow::{anyhow, Result};

use kvaccel::baselines::SystemKind;
use kvaccel::engine::{EngineBuilder, EngineStats, KvEngine};
use kvaccel::env::SimEnv;
use kvaccel::experiments::{run as run_experiment, EngineMode, ExpContext, ALL_EXPERIMENTS};
use kvaccel::kvaccel::RollbackScheme;
use kvaccel::lsm::{Compression, LsmOptions};
use kvaccel::repl::{ReadPolicy, ReplConfig, ReplicatedDb};
use kvaccel::runtime::{default_artifacts_dir, XlaRuntime};
use kvaccel::shard::ShardPolicy;
use kvaccel::sim::{Nanos, MILLIS, NS_PER_SEC};
use kvaccel::ssd::SsdConfig;
use kvaccel::util::{fmt, Args};
use kvaccel::workload::{self, BenchConfig, KeyDist, LoopMode, RunResult, ValueSizeDist};

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args),
        Some("experiment") | Some("exp") => cmd_experiment(&args),
        Some("bench") => cmd_bench(&args),
        Some("inspect") => cmd_inspect(),
        _ => {
            println!("kvaccel — host-SSD collaborative write accelerator (paper reproduction)");
            println!();
            println!("usage:");
            println!("  kvaccel run <A|B|C|D|E|ycsb-b|ycsb-c|ycsb-d|ycsb-e> [--system rocksdb|rocksdb-nosd|adoc|kvaccel|kvaccel-lazy|kvaccel-eager]");
            println!("              [--threads N] [--scale F] [--seed N] [--engine rust|xla]");
            println!("              [--clients N] [--loop-mode closed|open|poisson] [--rate OPS_S]");
            println!("              [--think-ms T] [--dist uniform|zipfian|latest] [--theta F]");
            println!("              [--scan-len L[:H]] [--crash-at OPS|TIME[s|ms|ns]]");
            println!("              [--shards N] [--shard-policy range|hash]");
            println!("              [--tenants N] [--tenant-rate OPS_S] [--tenant-slo-p99 MS]");
            println!("              [--cache-blocks N] [--compression none|lz-like[:RATIO]]");
            println!("              [--value-size N|L:H|lognormal:MU:SIGMA]");
            println!("              [--vlog-threshold BYTES] [--vlog-segment-bytes BYTES]");
            println!("              [--replicas N] [--read-policy primary|ryw|eventual]");
            println!("              [--repl-latency US] [--repl-bandwidth MBPS]");
            println!("  kvaccel experiment <id|all> [--scale F] [--seed N] [--engine rust|xla]");
            println!("      ids: {ALL_EXPERIMENTS:?}");
            println!("  kvaccel bench [--out BENCH_PR2.json] [--scan-out BENCH_PR3.json] [--scale F] [--rate OPS_S] [--clients N]");
            println!("                [--shards N] [--shard-policy range|hash]");
            println!("                [--tenants N] [--tenant-rate OPS_S] [--tenant-slo-p99 MS]");
            println!("                [--cache-blocks N] [--compression none|lz-like[:RATIO]]");
            println!("                [--value-size N|L:H|lognormal:MU:SIGMA]");
            println!("                [--vlog-threshold BYTES] [--vlog-segment-bytes BYTES]");
            println!("  kvaccel inspect");
            Ok(())
        }
    }
}

fn parse_system(name: &str) -> Result<SystemKind> {
    Ok(match name {
        "rocksdb" => SystemKind::RocksDb { slowdown: true },
        "rocksdb-nosd" => SystemKind::RocksDb { slowdown: false },
        "adoc" => SystemKind::Adoc,
        "kvaccel" => SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
        "kvaccel-lazy" => SystemKind::Kvaccel { scheme: RollbackScheme::Lazy },
        "kvaccel-eager" => SystemKind::Kvaccel { scheme: RollbackScheme::Eager },
        other => return Err(anyhow!("unknown system {other:?}")),
    })
}

fn parse_engine(args: &Args) -> EngineMode {
    match args.get_or("engine", "rust") {
        "xla" => EngineMode::Xla,
        _ => EngineMode::Rust,
    }
}

fn parse_loop_mode(args: &Args) -> Result<LoopMode> {
    let rate = args.get_f64("rate", 10_000.0);
    Ok(match args.get_or("loop-mode", "closed") {
        "closed" => LoopMode::Closed {
            think: (args.get_f64("think-ms", 0.0) * MILLIS as f64) as u64,
        },
        "open" | "open-fixed" | "fixed" => LoopMode::OpenFixed { ops_per_sec: rate },
        "poisson" | "open-poisson" => LoopMode::OpenPoisson { ops_per_sec: rate },
        other => return Err(anyhow!("unknown loop mode {other:?} (closed|open|poisson)")),
    })
}

/// `--scan-len L` (fixed) or `--scan-len L:H` (uniform in [L, H]);
/// defaults to YCSB-E's uniform 1..100.
fn parse_scan_len(args: &Args) -> Result<(usize, usize)> {
    let Some(s) = args.get("scan-len") else { return Ok((1, 100)) };
    let parse = |v: &str| -> Result<usize> {
        v.parse()
            .map_err(|_| anyhow!("--scan-len expects an integer or L:H, got {v:?}"))
    };
    match s.split_once(':') {
        Some((lo, hi)) => {
            let (lo, hi) = (parse(lo)?, parse(hi)?);
            if lo == 0 || hi < lo {
                return Err(anyhow!("--scan-len L:H needs 1 <= L <= H, got {s:?}"));
            }
            Ok((lo, hi))
        }
        None => {
            let n = parse(s)?;
            if n == 0 {
                return Err(anyhow!("--scan-len must be >= 1"));
            }
            Ok((n, n))
        }
    }
}

/// Crash-injection point for `run --crash-at`.
#[derive(Clone, Copy, Debug)]
enum CrashPoint {
    /// Power-loss after this many issued ops (all clients combined).
    Ops(u64),
    /// Power-loss at this virtual time (caps the workload horizon).
    At(Nanos),
}

/// `--crash-at N` (ops) or `--crash-at T[s|ms|ns]` (virtual time).
fn parse_crash_at(args: &Args) -> Result<Option<CrashPoint>> {
    let Some(s) = args.get("crash-at") else { return Ok(None) };
    let num = |v: &str| -> Result<f64> {
        v.parse().map_err(|_| {
            anyhow!("--crash-at expects <ops> or <time>[s|ms|ns], got {s:?}")
        })
    };
    Ok(Some(if let Some(v) = s.strip_suffix("ms") {
        CrashPoint::At((num(v)? * MILLIS as f64) as Nanos)
    } else if let Some(v) = s.strip_suffix("ns") {
        CrashPoint::At(num(v)? as Nanos)
    } else if let Some(v) = s.strip_suffix('s') {
        CrashPoint::At((num(v)? * NS_PER_SEC as f64) as Nanos)
    } else {
        CrashPoint::Ops(num(s)? as u64)
    }))
}

/// `--shards N [--shard-policy range|hash]`: partition the store over N
/// child engines (range is the default policy). `--shards 1` still goes
/// through the sharded layer (useful for conformance checks); omitting
/// the flag builds the plain unsharded engine.
fn parse_shards(args: &Args) -> Result<Option<(usize, ShardPolicy)>> {
    let Some(n) = args.get("shards") else { return Ok(None) };
    let n: usize = n
        .parse()
        .map_err(|_| anyhow!("--shards expects a positive integer, got {n:?}"))?;
    if n == 0 {
        return Err(anyhow!("--shards must be >= 1"));
    }
    let policy = match args.get_or("shard-policy", "range") {
        "range" => ShardPolicy::Range,
        "hash" => ShardPolicy::Hash,
        other => return Err(anyhow!("unknown shard policy {other:?} (range|hash)")),
    };
    Ok(Some((n, policy)))
}

/// `--replicas N [--read-policy primary|ryw|eventual] [--repl-latency US]
/// [--repl-bandwidth MBPS]`: run N replicated nodes (a primary plus N-1
/// replicas) behind one store, shipping the primary's CDC stream over a
/// simulated link. A 1-node "replica set" is the plain engine, so asking
/// for one is a mistake, not a no-op.
fn parse_replicas(args: &Args) -> Result<Option<ReplConfig>> {
    let Some(n) = args.get("replicas") else { return Ok(None) };
    let n: usize = n.parse().map_err(|_| {
        anyhow!("--replicas expects an integer >= 2, got {n:?}")
    })?;
    if n < 2 {
        return Err(anyhow!(
            "--replicas needs at least 2 nodes (a primary plus one \
             replica); omit the flag for an unreplicated store"
        ));
    }
    let read_policy = match args.get("read-policy") {
        Some(s) => ReadPolicy::parse(s).ok_or_else(|| {
            anyhow!("unknown read policy {s:?} (primary|ryw|eventual)")
        })?,
        None => ReadPolicy::Primary,
    };
    let mut cfg =
        ReplConfig { replicas: n, read_policy, ..ReplConfig::default() };
    if let Some(v) = args.get("repl-latency") {
        let us: f64 = v.parse().map_err(|_| {
            anyhow!("--repl-latency expects microseconds, got {v:?}")
        })?;
        if us < 0.0 {
            return Err(anyhow!("--repl-latency must be >= 0 us"));
        }
        cfg.link_latency = (us * 1_000.0) as Nanos;
    }
    if let Some(v) = args.get("repl-bandwidth") {
        let mbps: f64 = v.parse().map_err(|_| {
            anyhow!("--repl-bandwidth expects MB/s, got {v:?}")
        })?;
        if mbps <= 0.0 {
            return Err(anyhow!("--repl-bandwidth must be > 0 MB/s"));
        }
        cfg.link_mbps = mbps;
    }
    Ok(Some(cfg))
}

/// Reject contradictory `run` flags up front instead of silently
/// ignoring the loser (a closed-loop `--rate` used to do nothing).
fn validate_run_flags(args: &Args) -> Result<()> {
    let mode = args.get_or("loop-mode", "closed");
    let closed = mode == "closed";
    if closed && args.get("rate").is_some() {
        return Err(anyhow!(
            "--rate sets an open-loop arrival rate, but --loop-mode is closed \
             (closed loops reissue on completion; use --think-ms to slow them, \
             or add --loop-mode open|poisson)"
        ));
    }
    if !closed && args.get("think-ms").is_some() {
        return Err(anyhow!(
            "--think-ms is closed-loop think time, but --loop-mode is {mode:?} \
             (open/poisson arrival spacing comes from --rate)"
        ));
    }
    let dist = args.get_or("dist", "uniform");
    if args.get("theta").is_some() && !matches!(dist, "zipfian" | "zipf") {
        return Err(anyhow!(
            "--theta is the zipfian skew, but --dist is {dist:?} (add --dist zipfian)"
        ));
    }
    let workload = args.positional.get(1).map(|s| s.to_uppercase());
    if workload.as_deref() == Some("YCSB-D") && args.get("dist").is_some() {
        return Err(anyhow!(
            "--dist has no effect on ycsb-d (the preset IS read-latest; \
             it forces the Latest distribution)"
        ));
    }
    validate_bench_flags(args)
}

/// The dependency rules shared by `run` and `bench`: a qualifier flag
/// without the flag it qualifies is a mistake, not a no-op.
fn validate_bench_flags(args: &Args) -> Result<()> {
    if args.get("shard-policy").is_some() && args.get("shards").is_none() {
        return Err(anyhow!("--shard-policy has no effect without --shards N"));
    }
    for f in ["tenant-rate", "tenant-slo-p99"] {
        if args.get(f).is_some() && args.get("tenants").is_none() {
            return Err(anyhow!("--{f} has no effect without --tenants N"));
        }
    }
    for f in ["read-policy", "repl-latency", "repl-bandwidth"] {
        if args.get(f).is_some() && args.get("replicas").is_none() {
            return Err(anyhow!("--{f} has no effect without --replicas N"));
        }
    }
    if args.get("vlog-segment-bytes").is_some() && args.get("vlog-threshold").is_none()
    {
        return Err(anyhow!(
            "--vlog-segment-bytes has no effect without --vlog-threshold BYTES"
        ));
    }
    // malformed read-path, value-log, value-size, and replication flags
    // fail here, before any engine is built
    parse_cache_blocks(args)?;
    parse_compression(args)?;
    parse_value_size(args)?;
    parse_vlog(args)?;
    parse_replicas(args)?;
    Ok(())
}

/// `--tenants N [--tenant-rate OPS_S] [--tenant-slo-p99 MS]`: spread the
/// workload's clients round-robin over N tenants, each metered by a
/// token bucket at OPS_S ops/s (0/omitted = accounting only) with an
/// optional p99 SLO in milliseconds.
fn parse_tenants(args: &Args) -> Result<Option<(usize, f64, Option<Nanos>)>> {
    let Some(n) = args.get("tenants") else { return Ok(None) };
    let n: usize = n
        .parse()
        .map_err(|_| anyhow!("--tenants expects a positive integer, got {n:?}"))?;
    if n == 0 {
        return Err(anyhow!("--tenants must be >= 1"));
    }
    let rate = args.get_f64("tenant-rate", 0.0);
    if rate < 0.0 {
        return Err(anyhow!("--tenant-rate must be >= 0 ops/s"));
    }
    let slo = match args.get("tenant-slo-p99") {
        Some(v) => {
            let ms: f64 = v.parse().map_err(|_| {
                anyhow!("--tenant-slo-p99 expects milliseconds, got {v:?}")
            })?;
            if ms <= 0.0 {
                return Err(anyhow!("--tenant-slo-p99 must be > 0 ms"));
            }
            Some((ms * MILLIS as f64) as Nanos)
        }
        None => None,
    };
    Ok(Some((n, rate, slo)))
}

/// `--cache-blocks N`: engine-wide block cache capacity in blocks;
/// 0 disables caching (every block access pays device latency).
fn parse_cache_blocks(args: &Args) -> Result<Option<usize>> {
    let Some(s) = args.get("cache-blocks") else { return Ok(None) };
    let n: usize = s.parse().map_err(|_| {
        anyhow!("--cache-blocks expects a block count (0 disables), got {s:?}")
    })?;
    Ok(Some(n))
}

/// `--compression none | lz-like[:RATIO]`: SST data-block codec. RATIO
/// is the compressed size as a percent of logical bytes (1..=100,
/// default 50); `none` takes no ratio.
fn parse_compression(args: &Args) -> Result<Option<Compression>> {
    let Some(s) = args.get("compression") else { return Ok(None) };
    let (codec, ratio) = match s.split_once(':') {
        Some((c, r)) => (c, Some(r)),
        None => (s, None),
    };
    Ok(Some(match codec {
        "none" => {
            if ratio.is_some() {
                return Err(anyhow!(
                    "--compression none takes no ratio (got {s:?}); \
                     use lz-like:RATIO for a custom codec ratio"
                ));
            }
            Compression::None
        }
        "lz-like" | "lz" => {
            let pct: u64 = match ratio {
                Some(r) => r.parse().map_err(|_| {
                    anyhow!(
                        "--compression lz-like:RATIO expects an integer \
                         percent, got {r:?}"
                    )
                })?,
                None => 50,
            };
            if !(1..=100).contains(&pct) {
                return Err(anyhow!(
                    "--compression ratio is the compressed size in percent \
                     of logical, needs 1..=100, got {pct}"
                ));
            }
            Compression::LzLike { ratio_pct: pct }
        }
        other => {
            return Err(anyhow!(
                "unknown codec {other:?} (none|lz-like[:RATIO])"
            ))
        }
    }))
}

/// `--value-size N | L:H | lognormal:MU:SIGMA`: per-op value size in
/// bytes — fixed, uniform in [L, H], or log-normal with the given
/// log-space parameters (the long-tailed shape real value populations
/// show). Applies to run and bench; presets default to their own fixed
/// size (db_bench: 4096).
fn parse_value_size(args: &Args) -> Result<Option<ValueSizeDist>> {
    let Some(s) = args.get("value-size") else { return Ok(None) };
    ValueSizeDist::parse(s).map(Some).map_err(|e| anyhow!("--value-size: {e}"))
}

/// `--vlog-threshold BYTES [--vlog-segment-bytes BYTES]`: WiscKey-style
/// key-value separation. Values at or above the threshold append to the
/// value log and the LSM keeps a 12 B pointer; 0 (the default) keeps
/// every value inline in the SSTs.
fn parse_vlog(args: &Args) -> Result<Option<(u32, Option<u64>)>> {
    let seg = match args.get("vlog-segment-bytes") {
        Some(v) => {
            let n: u64 = v.parse().map_err(|_| {
                anyhow!("--vlog-segment-bytes expects a byte count, got {v:?}")
            })?;
            if n < 4096 {
                return Err(anyhow!(
                    "--vlog-segment-bytes must be >= 4096 (one block)"
                ));
            }
            Some(n)
        }
        None => None,
    };
    let Some(s) = args.get("vlog-threshold") else { return Ok(None) };
    let thr: u32 = s.parse().map_err(|_| {
        anyhow!("--vlog-threshold expects a byte count (0 disables), got {s:?}")
    })?;
    Ok(Some((thr, seg)))
}

/// Fold the read-path flags into the engine options.
fn apply_read_path_flags(mut opts: LsmOptions, args: &Args) -> Result<LsmOptions> {
    if let Some(n) = parse_cache_blocks(args)? {
        opts = opts.with_cache_blocks(n);
    }
    if let Some(c) = parse_compression(args)? {
        opts = opts.with_compression(c);
    }
    if let Some((thr, seg)) = parse_vlog(args)? {
        opts = opts.with_vlog_threshold(thr);
        if let Some(sb) = seg {
            opts = opts.with_vlog_segment_bytes(sb);
        }
    }
    Ok(opts)
}

fn parse_dist(args: &Args) -> Result<KeyDist> {
    Ok(match args.get_or("dist", "uniform") {
        "uniform" => KeyDist::Uniform,
        "zipfian" | "zipf" => {
            let theta = args.get_f64("theta", 0.99);
            if !(theta > 0.0 && theta < 1.0) {
                return Err(anyhow!(
                    "--theta must be in (0,1) exclusive (YCSB zipfian), got {theta}"
                ));
            }
            KeyDist::Zipfian { theta }
        }
        "latest" => KeyDist::Latest,
        other => return Err(anyhow!("unknown key dist {other:?} (uniform|zipfian|latest)")),
    })
}

fn cmd_run(args: &Args) -> Result<()> {
    let workload_id = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("run needs a workload: A|B|C|D"))?
        .to_uppercase();
    validate_run_flags(args)?;
    let kind = parse_system(args.get_or("system", "kvaccel"))?;
    let threads = args.get_usize("threads", 4);
    let scale = args.get_f64("scale", 0.1);
    let seed = args.get_u64("seed", 42);
    let clients = args.get_usize("clients", 1);
    let mode = parse_loop_mode(args)?;
    let dist = parse_dist(args)?;
    let crash = parse_crash_at(args)?;
    let shards = parse_shards(args)?;
    let tenants = parse_tenants(args)?;
    let replicas = parse_replicas(args)?;
    let vdist = parse_value_size(args)?;
    let ctx = ExpContext::new(scale, seed, parse_engine(args))?;
    let mut cfg: BenchConfig = ctx.bench_config();
    // preload and fixed-size presets (workload D) use the mean; the
    // scheduler specs below carry the full distribution
    if let Some(d) = vdist {
        cfg.value_size = d.mean().round().max(1.0) as u32;
    }

    let opts =
        apply_read_path_flags(LsmOptions::default().with_threads(threads), args)?;
    let key_space = cfg.key_space;
    // one node's engine stack; with --replicas the replication layer
    // calls this once per node (every node runs the same configuration)
    let mut make_engine = |_node: usize| {
        let mut builder = EngineBuilder::new(kind)
            .opts(opts.clone())
            .merge_engine(ctx.merge_engine())
            .bloom_builder(ctx.bloom_builder());
        if let Some((n, policy)) = shards {
            builder = builder.sharded(n, policy).shard_key_space(key_space);
        }
        builder.build()
    };
    let mut sys: Box<dyn KvEngine> = match replicas.clone() {
        Some(mut rcfg) => {
            rcfg.key_space = key_space;
            rcfg.seed = seed;
            Box::new(ReplicatedDb::new(rcfg, &mut make_engine))
        }
        None => make_engine(0),
    };
    let mut env = SimEnv::new(seed, SsdConfig::default());
    // crash injection: a time point caps the workload horizon, an op
    // point cuts the global issue budget; either way the run ends at the
    // crash and the engine is power-lost + reopened below
    if let Some(CrashPoint::At(t)) = crash {
        cfg.duration = cfg.duration.min(t);
    }
    let stop_ops = match crash {
        Some(CrashPoint::Ops(n)) => Some(n),
        _ => None,
    };

    let (r, clients_line) = match workload_id.as_str() {
        "A" | "B" | "C" => {
            let mut spec =
                workload::preset_spec(&workload_id, &cfg, clients, mode, dist)?;
            spec.stop_after_ops = stop_ops;
            if let Some(d) = vdist {
                spec = spec.with_value_dist(d);
            }
            if let Some((n, rate, slo)) = tenants {
                spec = spec.with_tenants(n, rate, slo);
            }
            // report the actors that actually ran (B/C add a read
            // client; open-loop rates are split per preset_spec)
            let line = format!(
                "clients       {} [{}] dist {dist:?}",
                spec.clients.len(),
                describe_clients(&spec)
            );
            (workload::run_spec(&mut *sys, &mut env, &spec), line)
        }
        "D" => {
            if tenants.is_some() {
                return Err(anyhow!(
                    "--tenants applies to A|B|C|E (D is a single sequential scanner)"
                ));
            }
            // seekrandom is a single sequential scanner; scheduler knobs
            // apply to A/B/C/E
            let preload_bytes = ((20u64 << 30) as f64 * scale) as u64;
            let t0 = workload::preload(&mut *sys, &mut env, &cfg, preload_bytes)?;
            let r = workload::seekrandom(
                &mut *sys, &mut env, &cfg, (60_000f64 * scale) as usize, 1024, t0,
            );
            let line = "clients       1 (sequential seekrandom; \
                --clients/--loop-mode/--rate/--dist apply to A|B|C|E)"
                .to_string();
            (r, line)
        }
        "YCSB-B" | "YCSB-C" | "YCSB-D" => {
            // read-heavy point presets: preload a working set first, or
            // every read misses and the run measures nothing but preload
            let preload_bytes = ((4u64 << 30) as f64 * scale) as u64;
            let t0 = workload::preload(&mut *sys, &mut env, &cfg, preload_bytes)?;
            let mut spec = workload::WorkloadSpec {
                start_at: t0,
                ..workload::preset_spec(&workload_id, &cfg, clients, mode, dist)?
            };
            spec.stop_after_ops = stop_ops;
            if let Some(d) = vdist {
                spec = spec.with_value_dist(d);
            }
            if let Some((n, rate, slo)) = tenants {
                spec = spec.with_tenants(n, rate, slo);
            }
            let line = format!(
                "clients       {} [{}] dist {:?}",
                spec.clients.len(),
                describe_clients(&spec),
                spec.clients[0].dist,
            );
            (workload::run_spec(&mut *sys, &mut env, &spec), line)
        }
        "E" | "YCSB-E" => {
            // YCSB-E: preload a working set, then the scan-heavy mix
            let (slo, shi) = parse_scan_len(args)?;
            let preload_bytes = ((4u64 << 30) as f64 * scale) as u64;
            let t0 = workload::preload(&mut *sys, &mut env, &cfg, preload_bytes)?;
            let mut spec = workload::WorkloadSpec {
                start_at: t0,
                ..workload::ycsb_e(&cfg, clients, mode, dist, slo, shi)
            };
            spec.stop_after_ops = stop_ops;
            if let Some(d) = vdist {
                spec = spec.with_value_dist(d);
            }
            if let Some((n, rate, slo)) = tenants {
                spec = spec.with_tenants(n, rate, slo);
            }
            let line = format!(
                "clients       {} [{}] dist {dist:?} scan-len {slo}..{shi}",
                spec.clients.len(),
                describe_clients(&spec)
            );
            (workload::run_spec(&mut *sys, &mut env, &spec), line)
        }
        other => return Err(anyhow!("unknown workload {other:?}")),
    };

    println!("system        {}", kind.label());
    if let Some((n, policy)) = shards {
        println!("shards        {n} ({} policy, shared device)", policy.label());
    }
    if let Some(rcfg) = &replicas {
        println!(
            "replicas      {} ({} reads, link {} + {:.0} MB/s)",
            rcfg.replicas,
            rcfg.read_policy.label(),
            fmt::nanos(rcfg.link_latency as f64),
            rcfg.link_mbps
        );
    }
    println!("workload      {} ({} virtual s, scale {scale})", r.workload, r.duration_s);
    println!("{clients_line}");
    print_result(&r);
    print_cache_line(&*sys);
    print_tenant_breakdown(&r);
    print_shard_breakdown(&*sys, &env);
    print_repl_breakdown(&r);

    if crash.is_some() {
        let t_crash = env.now();
        println!();
        println!("-- power loss at {} --", fmt::nanos(t_crash as f64));
        let image = sys.crash(&mut env, t_crash);
        println!(
            "durable image {} WAL records, {} manifest edits",
            image.wal_records(),
            image.manifest_edits()
        );
        let (sys2, t_rec) = EngineBuilder::open(&mut env, t_crash, image)?;
        let h = sys2.health();
        println!(
            "recovered in  {} (virtual): {} WAL records replayed, \
             {} dev keys re-routed",
            fmt::nanos(t_rec.saturating_sub(t_crash) as f64),
            h.recovered_wal_records,
            h.recovered_dev_keys
        );
    }
    Ok(())
}

/// One compact descriptor per actor in the spec, e.g.
/// `writer:open@9000/s, writer:open@9000/s, reader:open@2000/s`.
fn describe_clients(spec: &kvaccel::workload::WorkloadSpec) -> String {
    spec.clients
        .iter()
        .map(|c| {
            let role = if c.mix.scan > 0 && c.mix.scan >= c.mix.put {
                "scanner"
            } else if c.mix.get > 0 && c.mix.put == 0 {
                "reader"
            } else {
                "writer"
            };
            let paced = if c.pace.is_some() { "(paced)" } else { "" };
            match c.mode {
                LoopMode::Closed { think: 0 } => format!("{role}{paced}:closed"),
                LoopMode::Closed { think } => {
                    format!("{role}{paced}:closed+think{}ms", think / MILLIS)
                }
                LoopMode::OpenFixed { ops_per_sec } => {
                    format!("{role}:open@{ops_per_sec:.0}/s")
                }
                LoopMode::OpenPoisson { ops_per_sec } => {
                    format!("{role}:poisson@{ops_per_sec:.0}/s")
                }
            }
        })
        .collect::<Vec<_>>()
        .join(", ")
}

/// Block-cache and measured-bloom effectiveness lines (suppressed when
/// the read path never ran — e.g. pure fillrandom on a cold store).
fn print_cache_line(sys: &dyn KvEngine) {
    let c = sys.cache_stats();
    if c.hits + c.misses > 0 {
        println!(
            "block cache   {:.1}% hit ({} hits / {} misses, {} evictions, {} cached)",
            c.hit_rate() * 100.0,
            c.hits,
            c.misses,
            c.evictions,
            fmt::bytes(c.cached_bytes as f64),
        );
    }
    let d = sys.db_stats();
    if d.bloom_negative_probes > 0 {
        println!(
            "bloom fpr     {:.4} measured ({} false positives / {} negative probes)",
            d.bloom_fpr(),
            d.bloom_false_positives,
            d.bloom_negative_probes,
        );
    }
}

/// Per-tenant QoS breakdown (specs carrying a tenant table only).
fn print_tenant_breakdown(r: &RunResult) {
    if r.tenants.is_empty() {
        return;
    }
    println!("per-tenant breakdown:");
    // rows land in admission-table order; sort so the report is stable
    // under any upstream reordering (determinism: reports are diffed)
    let mut tenants: Vec<_> = r.tenants.iter().collect();
    tenants.sort_by(|a, b| a.name.cmp(&b.name));
    for t in tenants {
        let slo = if t.slo_p99_us > 0.0 {
            format!(
                "  slo {} ({} over-SLO ticks)",
                fmt::nanos(t.slo_p99_us * 1e3),
                t.over_slo_ticks
            )
        } else {
            String::new()
        };
        let grant = if t.device_grant > 0.0 {
            format!("  grant {:.0}%", t.device_grant * 100.0)
        } else {
            String::new()
        };
        println!(
            "  {:<8} {:>8} ops ({:>8.1}/s, {:>6.1} MB/s)  p50/p99 {} / {}  \
             {} throttled ({:.2}s)  {} shed{slo}{grant}",
            t.name,
            t.ops,
            t.ops_per_sec,
            t.mbps,
            fmt::nanos(t.lat.p50_us * 1e3),
            fmt::nanos(t.lat.p99_us * 1e3),
            t.throttled,
            t.throttle_delay_s,
            t.shed,
        );
    }
}

/// Per-shard stall/redirect breakdown (sharded stores only; a 1-shard
/// store is the plain engine, so the headline report already covers it).
fn print_shard_breakdown(sys: &dyn KvEngine, env: &SimEnv) {
    let Some(sh) = sys.sharded() else { return };
    if sh.shard_count() <= 1 {
        return;
    }
    println!("per-shard breakdown:");
    // same defensive ordering as the tenant rows: emit by shard index
    let mut reports = sh.shard_reports(env);
    reports.sort_by_key(|rep| rep.shard);
    for rep in reports {
        let grant = rep
            .grant
            .map(|g| format!(" grant {:.0}%", g * 100.0))
            .unwrap_or_default();
        println!(
            "  shard {:>2} {:<16} {:>8} puts  {:>7} redirected  {} rollbacks  \
             {} stops ({:.2}s)  {} slowdowns  {} dev keys ({:.1}% of KV region){grant}",
            rep.shard,
            rep.label,
            rep.puts,
            rep.redirected,
            rep.rollbacks,
            rep.stop_events,
            rep.stopped_s,
            rep.slowdown_events,
            rep.dev_resident_keys,
            rep.dev_occupancy * 100.0,
        );
    }
    let a = sh.arbiter().stats;
    if a.rebalances > 0 || a.recovered_transfers > 0 {
        println!(
            "  arbiter: {} grant rebalances, {} recovered transfers",
            a.rebalances, a.recovered_transfers
        );
    }
}

/// Replication breakdown (runs with `--replicas` only): per-node apply
/// progress and lag, CDC shipping volume, read routing, failover and
/// anti-entropy totals.
fn print_repl_breakdown(r: &RunResult) {
    let Some(rep) = &r.replication else { return };
    println!("replication breakdown ({} reads):", rep.read_policy);
    // emit by node id regardless of upstream row order
    let mut replicas: Vec<_> = rep.replicas.iter().collect();
    replicas.sort_by_key(|n| n.node);
    for n in replicas {
        println!(
            "  node {:>2} {:<8} {:>8} applied (seq {:>8})  lag max {:>6} / mean {:>8.1} records",
            n.node, n.role, n.applied_records, n.applied_seq, n.max_lag, n.mean_lag,
        );
    }
    println!(
        "  cdc: {} captured, {} shipped ({})",
        rep.captured_records,
        rep.shipped_records,
        fmt::bytes(rep.shipped_bytes as f64),
    );
    let reads = rep.primary_reads + rep.replica_reads;
    if reads > 0 {
        println!(
            "  reads: {} primary, {} replica ({} stale)",
            rep.primary_reads, rep.replica_reads, rep.stale_reads,
        );
    }
    if rep.failovers > 0 {
        println!(
            "  failover: {} promotions, {} blackout, {} committed records lost",
            rep.failovers,
            fmt::nanos(rep.blackout_ns as f64),
            rep.lost_records,
        );
    }
    if rep.anti_entropy_bytes > 0 {
        println!(
            "  anti-entropy: {} shipped (full resync would be {})",
            fmt::bytes(rep.anti_entropy_bytes as f64),
            fmt::bytes(rep.full_resync_bytes as f64),
        );
    }
}

fn print_result(r: &RunResult) {
    println!("writes        {} ({:.1} Kops/s)", r.writes.total, r.write_kops());
    println!("reads         {} ({:.1} Kops/s)", r.reads.total, r.read_kops());
    println!("write p50/p99 {} / {}", fmt::nanos(r.write_lat.p50_us * 1e3), fmt::nanos(r.write_lat.p99_us * 1e3));
    println!("read  p50/p99 {} / {}", fmt::nanos(r.read_lat.p50_us * 1e3), fmt::nanos(r.read_lat.p99_us * 1e3));
    if r.read_hits + r.read_misses > 0 {
        println!("read hit-rate {:.1}%", r.read_hit_rate() * 100.0);
    }
    if r.queue_delay.count > 0 {
        println!(
            "queue delay   p50 {} / p99 {} (open-loop wait before service)",
            fmt::nanos(r.queue_delay.p50_us * 1e3),
            fmt::nanos(r.queue_delay.p99_us * 1e3)
        );
    }
    if r.scans.total > 0 {
        println!(
            "scans         {} cursors ({:.1} Kops/s), p50/p99 {} / {}",
            r.scans.total,
            r.scan_kops(),
            fmt::nanos(r.scan_lat.p50_us * 1e3),
            fmt::nanos(r.scan_lat.p99_us * 1e3)
        );
        println!(
            "scan read-amp {:.3} blocks/next (main-lsm), {:.3} pages/next (dev-lsm)",
            r.scan_amp.main_blocks_per_next(),
            r.scan_amp.dev_pages_per_next()
        );
    }
    println!("throughput    {:.1} MB/s user writes", r.write_mbps);
    println!("cpu           {:.1}% of 8 cores", r.cpu_percent);
    println!("efficiency    {:.2} MB/s per CPU%", r.efficiency);
    println!("stalls        {} halts ({:.2}s), {} slowdown instances", r.stop_events, r.stopped_s, r.slowdown_events);
    println!("write amp     {:.2}", r.write_amplification);
    if r.redirected_writes > 0 || r.rollbacks > 0 {
        println!("kvaccel       {} redirected writes, {} rollbacks", r.redirected_writes, r.rollbacks);
    }
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("experiment needs an id or 'all'"))?;
    let scale = args.get_f64("scale", 0.1);
    let seed = args.get_u64("seed", 42);
    let ctx = ExpContext::new(scale, seed, parse_engine(args))?;
    println!(
        "running {id} at scale {scale} (paper = 1.0), engine {:?}; CSVs -> results/",
        ctx.engine
    );
    run_experiment(&ctx, id)?;
    Ok(())
}

/// Fixed open-loop comparison across the headline systems, emitted as
/// machine-readable JSON (the perf-trajectory artifact built in CI).
fn cmd_bench(args: &Args) -> Result<()> {
    validate_bench_flags(args)?;
    if args.get("replicas").is_some() {
        return Err(anyhow!(
            "--replicas applies to `run` (and `experiment repl-lag` covers \
             the replicated comparison); `bench` measures single-node engines"
        ));
    }
    let out = args.get_or("out", "BENCH_PR2.json").to_string();
    let scale = args.get_f64("scale", 0.02);
    let seed = args.get_u64("seed", 42);
    let clients = args.get_usize("clients", 4);
    let rate = args.get_f64("rate", 30_000.0);
    let threads = args.get_usize("threads", 4);
    let shards = parse_shards(args)?;
    let tenants = parse_tenants(args)?;
    let vdist = parse_value_size(args)?;
    let mut cfg = BenchConfig { seed, ..Default::default() }.scaled(scale);
    if let Some(d) = vdist {
        cfg.value_size = d.mean().round().max(1.0) as u32;
    }
    let mode = LoopMode::OpenFixed { ops_per_sec: rate };
    let bench_opts =
        apply_read_path_flags(LsmOptions::default().with_threads(threads), args)?;

    let mut rows = Vec::new();
    for kind in [
        SystemKind::RocksDb { slowdown: true },
        SystemKind::Adoc,
        SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
    ] {
        let mut builder = EngineBuilder::new(kind).opts(bench_opts.clone());
        if let Some((n, policy)) = shards {
            builder = builder.sharded(n, policy).shard_key_space(cfg.key_space);
        }
        let mut sys = builder.build();
        let mut env = SimEnv::new(seed, SsdConfig::default());
        let mut spec =
            workload::preset_spec("A", &cfg, clients, mode, KeyDist::Uniform)?;
        if let Some(d) = vdist {
            spec = spec.with_value_dist(d);
        }
        if let Some((n, t_rate, slo)) = tenants {
            spec = spec.with_tenants(n, t_rate, slo);
        }
        let r = workload::run_spec(&mut *sys, &mut env, &spec);
        println!("== {} ==", kind.label());
        print_result(&r);
        print_tenant_breakdown(&r);
        rows.push(format!(
            concat!(
                "    \"{}\": {{\"write_mbps\": {:.3}, \"write_ops\": {}, ",
                "\"p50_us\": {:.2}, \"p99_us\": {:.2}, \"p999_us\": {:.2}, ",
                "\"queue_delay_p99_us\": {:.2}, \"stall_stopped_s\": {:.3}, ",
                "\"slowdown_events\": {}, \"stop_events\": {}, ",
                "\"efficiency_mbps_per_cpu\": {:.4}, \"redirected_writes\": {}}}"
            ),
            kind.label(),
            r.write_mbps,
            r.writes.total,
            r.write_lat.p50_us,
            r.write_lat.p99_us,
            r.write_lat.p999_us,
            r.queue_delay.p99_us,
            r.stopped_s,
            r.slowdown_events,
            r.stop_events,
            r.efficiency,
            r.redirected_writes,
        ));
    }
    let json = format!(
        concat!(
            "{{\n  \"schema\": \"kvaccel-bench-v1\",\n",
            "  \"config\": {{\"workload\": \"A/fillrandom\", \"loop_mode\": \"open-fixed\", ",
            "\"rate_ops_s\": {:.1}, \"clients\": {}, \"threads\": {}, ",
            "\"scale\": {}, \"seed\": {}}},\n",
            "  \"systems\": {{\n{}\n  }}\n}}\n"
        ),
        rate,
        clients,
        threads,
        scale,
        seed,
        rows.join(",\n"),
    );
    std::fs::write(&out, &json)?;
    println!("\nwrote {out}");

    // scan-path bench (PR3): YCSB-E cursors after a preload, reporting
    // scan throughput/p99 and per-Next read amplification per interface
    let scan_out = args.get_or("scan-out", "BENCH_PR3.json").to_string();
    let mut srows = Vec::new();
    for kind in [
        SystemKind::RocksDb { slowdown: true },
        SystemKind::Adoc,
        SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
    ] {
        let mut sys = EngineBuilder::new(kind).opts(bench_opts.clone()).build();
        let mut env = SimEnv::new(seed, SsdConfig::default());
        let preload_bytes = ((4u64 << 30) as f64 * scale) as u64;
        let t0 = workload::preload(&mut *sys, &mut env, &cfg, preload_bytes)?;
        let mut spec = workload::WorkloadSpec {
            start_at: t0,
            ..workload::ycsb_e(
                &cfg,
                clients,
                LoopMode::Closed { think: 0 },
                KeyDist::Uniform,
                1,
                100,
            )
        };
        if let Some(d) = vdist {
            spec = spec.with_value_dist(d);
        }
        let r = workload::run_spec(&mut *sys, &mut env, &spec);
        println!("== {} (ycsb-e) ==", kind.label());
        print_result(&r);
        srows.push(format!(
            concat!(
                "    \"{}\": {{\"scan_ops\": {}, \"scan_kops\": {:.3}, ",
                "\"scan_p50_us\": {:.2}, \"scan_p99_us\": {:.2}, ",
                "\"nexts\": {}, \"seeks\": {}, ",
                "\"read_amp_main_blocks_per_next\": {:.4}, ",
                "\"read_amp_dev_pages_per_next\": {:.4}, ",
                "\"write_ops\": {}, \"stall_stopped_s\": {:.3}}}"
            ),
            kind.label(),
            r.scans.total,
            r.scan_kops(),
            r.scan_lat.p50_us,
            r.scan_lat.p99_us,
            r.scan_amp.nexts,
            r.scan_amp.seeks,
            r.scan_amp.main_blocks_per_next(),
            r.scan_amp.dev_pages_per_next(),
            r.writes.total,
            r.stopped_s,
        ));
    }
    let scan_json = format!(
        concat!(
            "{{\n  \"schema\": \"kvaccel-scanbench-v1\",\n",
            "  \"config\": {{\"workload\": \"E/ycsb-e\", \"scan_len\": \"uniform 1..100\", ",
            "\"loop_mode\": \"closed\", \"clients\": {}, \"threads\": {}, ",
            "\"scale\": {}, \"seed\": {}}},\n",
            "  \"systems\": {{\n{}\n  }}\n}}\n"
        ),
        clients,
        threads,
        scale,
        seed,
        srows.join(",\n"),
    );
    std::fs::write(&scan_out, &scan_json)?;
    println!("\nwrote {scan_out}");
    Ok(())
}

fn cmd_inspect() -> Result<()> {
    let dir = default_artifacts_dir();
    println!("artifacts dir: {}", dir.display());
    match XlaRuntime::load(&dir) {
        Ok(rt) => {
            println!("merge artifacts: {:?}", rt.merge_shapes());
            println!("bloom artifacts: {:?}", rt.bloom_shapes());
        }
        Err(e) => println!("runtime not loadable: {e:#}"),
    }
    let ssd = SsdConfig::default();
    println!(
        "ssd model: {} ch x {} way, page {}, peak program bw {}",
        ssd.nand.channels,
        ssd.nand.ways,
        fmt::bytes(ssd.nand.page_bytes as f64),
        fmt::bytes(ssd.nand.peak_program_bw())
    );
    println!(
        "pcie: {:.1} GB/s per direction, dma chunk {}",
        ssd.pcie.bytes_per_ns,
        fmt::bytes(ssd.dma_chunk_bytes as f64)
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn run_flags_reject_contradictions() {
        // --rate with the (default) closed loop
        assert!(validate_run_flags(&parse("run A --rate 1000")).is_err());
        assert!(
            validate_run_flags(&parse("run A --loop-mode closed --rate 1000")).is_err()
        );
        // --think-ms with an open loop
        assert!(
            validate_run_flags(&parse("run A --loop-mode open --think-ms 5")).is_err()
        );
        // --theta without a zipfian dist
        assert!(validate_run_flags(&parse("run A --theta 0.9")).is_err());
        assert!(
            validate_run_flags(&parse("run A --dist uniform --theta 0.9")).is_err()
        );
        // qualifier flags without the flag they qualify
        assert!(validate_run_flags(&parse("run A --shard-policy hash")).is_err());
        assert!(validate_run_flags(&parse("run A --tenant-rate 100")).is_err());
        assert!(validate_run_flags(&parse("run A --tenant-slo-p99 50")).is_err());
    }

    #[test]
    fn run_flags_accept_consistent_combinations() {
        assert!(validate_run_flags(&parse("run A")).is_ok());
        assert!(validate_run_flags(&parse("run A --loop-mode open --rate 1000")).is_ok());
        assert!(
            validate_run_flags(&parse("run A --loop-mode poisson --rate 500")).is_ok()
        );
        assert!(validate_run_flags(&parse("run A --think-ms 5")).is_ok());
        assert!(validate_run_flags(&parse("run A --dist zipfian --theta 0.9")).is_ok());
        assert!(validate_run_flags(&parse("run A --dist zipf --theta 0.9")).is_ok());
        assert!(
            validate_run_flags(&parse("run A --shards 4 --shard-policy hash")).is_ok()
        );
        assert!(validate_run_flags(&parse(
            "run A --tenants 2 --tenant-rate 100 --tenant-slo-p99 50"
        ))
        .is_ok());
    }

    #[test]
    fn bench_flags_validate_qualifier_dependencies() {
        assert!(validate_bench_flags(&parse("bench --shard-policy range")).is_err());
        assert!(validate_bench_flags(&parse("bench --tenant-rate 10")).is_err());
        assert!(validate_bench_flags(&parse("bench --tenant-slo-p99 20")).is_err());
        assert!(
            validate_bench_flags(&parse("bench --shards 2 --shard-policy range")).is_ok()
        );
        assert!(validate_bench_flags(&parse("bench --tenants 2")).is_ok());
        assert!(validate_bench_flags(&parse("bench")).is_ok());
    }

    #[test]
    fn cache_and_compression_flags_parse_and_validate() {
        // defaults: both absent
        assert!(parse_cache_blocks(&parse("run A")).unwrap().is_none());
        assert!(parse_compression(&parse("run A")).unwrap().is_none());
        // cache capacity, including 0 = disabled
        assert_eq!(
            parse_cache_blocks(&parse("run A --cache-blocks 4096")).unwrap(),
            Some(4096)
        );
        assert_eq!(
            parse_cache_blocks(&parse("run A --cache-blocks 0")).unwrap(),
            Some(0)
        );
        assert!(parse_cache_blocks(&parse("run A --cache-blocks big")).is_err());
        // codecs
        assert_eq!(
            parse_compression(&parse("run A --compression none")).unwrap(),
            Some(Compression::None)
        );
        assert_eq!(
            parse_compression(&parse("run A --compression lz-like")).unwrap(),
            Some(Compression::LzLike { ratio_pct: 50 })
        );
        assert_eq!(
            parse_compression(&parse("run A --compression lz-like:30")).unwrap(),
            Some(Compression::LzLike { ratio_pct: 30 })
        );
        // rejected shapes: none takes no ratio; ratio bounds; codec name
        assert!(parse_compression(&parse("run A --compression none:50")).is_err());
        assert!(parse_compression(&parse("run A --compression lz-like:0")).is_err());
        assert!(parse_compression(&parse("run A --compression lz-like:101")).is_err());
        assert!(parse_compression(&parse("run A --compression gzip")).is_err());
        // the shared validator catches them up front for run AND bench
        assert!(validate_run_flags(&parse("run A --compression gzip")).is_err());
        assert!(validate_bench_flags(&parse("bench --cache-blocks x")).is_err());
        assert!(validate_run_flags(
            &parse("run ycsb-c --cache-blocks 1024 --compression lz-like:50")
        )
        .is_ok());
        // ycsb-d forces the Latest distribution
        assert!(validate_run_flags(&parse("run ycsb-d --dist uniform")).is_err());
        assert!(validate_run_flags(&parse("run ycsb-d")).is_ok());
        assert!(validate_run_flags(&parse("run D --dist zipfian")).is_ok());
    }

    #[test]
    fn value_size_and_vlog_flags_parse_and_validate() {
        // defaults: both absent
        assert!(parse_value_size(&parse("run A")).unwrap().is_none());
        assert!(parse_vlog(&parse("run A")).unwrap().is_none());
        // the three value-size shapes
        assert_eq!(
            parse_value_size(&parse("run A --value-size 16384")).unwrap(),
            Some(ValueSizeDist::Fixed(16384))
        );
        assert_eq!(
            parse_value_size(&parse("run A --value-size 64:8192")).unwrap(),
            Some(ValueSizeDist::Uniform { lo: 64, hi: 8192 })
        );
        assert_eq!(
            parse_value_size(&parse("run A --value-size lognormal:8.0:1.5")).unwrap(),
            Some(ValueSizeDist::LogNormal { mu: 8.0, sigma: 1.5 })
        );
        assert!(parse_value_size(&parse("run A --value-size big")).is_err());
        assert!(parse_value_size(&parse("run A --value-size 10:5")).is_err());
        // vlog flags
        assert_eq!(
            parse_vlog(&parse("run A --vlog-threshold 1024")).unwrap(),
            Some((1024, None))
        );
        assert_eq!(
            parse_vlog(&parse(
                "run A --vlog-threshold 1024 --vlog-segment-bytes 1048576"
            ))
            .unwrap(),
            Some((1024, Some(1 << 20)))
        );
        assert!(parse_vlog(&parse("run A --vlog-threshold x")).is_err());
        assert!(parse_vlog(
            &parse("run A --vlog-threshold 1024 --vlog-segment-bytes 16")
        )
        .is_err());
        // qualifier without the flag it qualifies, and malformed values,
        // are caught by the shared validator for run AND bench
        assert!(validate_run_flags(&parse("run A --vlog-segment-bytes 65536")).is_err());
        assert!(validate_bench_flags(&parse("bench --vlog-segment-bytes 65536")).is_err());
        assert!(validate_run_flags(&parse("run A --value-size 0")).is_err());
        assert!(validate_bench_flags(&parse("bench --value-size lognormal:1")).is_err());
        assert!(validate_run_flags(&parse(
            "run A --value-size lognormal:9:1 --vlog-threshold 4096 \
             --vlog-segment-bytes 1048576"
        ))
        .is_ok());
    }

    #[test]
    fn tenants_flag_parses_and_validates() {
        assert!(parse_tenants(&parse("run A")).unwrap().is_none());
        let (n, rate, slo) = parse_tenants(&parse(
            "run A --tenants 4 --tenant-rate 250 --tenant-slo-p99 50"
        ))
        .unwrap()
        .unwrap();
        assert_eq!(n, 4);
        assert!((rate - 250.0).abs() < 1e-9);
        assert_eq!(slo, Some(50 * MILLIS));
        assert!(parse_tenants(&parse("run A --tenants 0")).is_err());
        assert!(parse_tenants(&parse("run A --tenants x")).is_err());
        assert!(parse_tenants(&parse("run A --tenants 2 --tenant-slo-p99 0")).is_err());
    }

    #[test]
    fn replication_flags_parse_and_validate() {
        // absent -> unreplicated
        assert!(parse_replicas(&parse("run A")).unwrap().is_none());
        // full parse with link overrides
        let cfg = parse_replicas(&parse(
            "run A --replicas 3 --read-policy eventual \
             --repl-latency 200 --repl-bandwidth 256"
        ))
        .unwrap()
        .unwrap();
        assert_eq!(cfg.replicas, 3);
        assert_eq!(cfg.read_policy, ReadPolicy::Eventual);
        assert_eq!(cfg.link_latency, 200_000);
        assert!((cfg.link_mbps - 256.0).abs() < 1e-9);
        // defaults when only the count is given
        let cfg = parse_replicas(&parse("run A --replicas 2")).unwrap().unwrap();
        assert_eq!(cfg.read_policy, ReadPolicy::Primary);
        // a 1-node "replicated" store is the unreplicated store
        assert!(parse_replicas(&parse("run A --replicas 1")).is_err());
        assert!(parse_replicas(&parse("run A --replicas 0")).is_err());
        assert!(parse_replicas(&parse("run A --replicas x")).is_err());
        // unknown policy and malformed link parameters
        assert!(
            parse_replicas(&parse("run A --replicas 3 --read-policy strong")).is_err()
        );
        assert!(
            parse_replicas(&parse("run A --replicas 3 --repl-latency -5")).is_err()
        );
        assert!(
            parse_replicas(&parse("run A --replicas 3 --repl-bandwidth 0")).is_err()
        );
        // qualifier flags without --replicas are mistakes, not no-ops
        assert!(validate_run_flags(&parse("run A --read-policy ryw")).is_err());
        assert!(validate_run_flags(&parse("run A --repl-latency 100")).is_err());
        assert!(validate_run_flags(&parse("run A --repl-bandwidth 512")).is_err());
        assert!(validate_bench_flags(&parse("bench --read-policy eventual")).is_err());
        // the shared validator catches malformed values up front
        assert!(validate_run_flags(&parse("run A --replicas 1")).is_err());
        assert!(validate_run_flags(
            &parse("run A --replicas 3 --read-policy ryw")
        )
        .is_ok());
    }
}
