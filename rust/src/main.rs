//! KVACCEL CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   run <workload>      run a single workload (A|B|C|D) on one system
//!   experiment <id|all> regenerate a paper figure/table (see DESIGN.md)
//!   inspect             print artifact + device model info
//!
//! Examples:
//!   kvaccel run A --system kvaccel --threads 4 --scale 0.1
//!   kvaccel experiment fig12 --scale 0.25 --engine xla
//!   kvaccel experiment all --scale 0.1 --engine rust

use anyhow::{anyhow, Result};

use kvaccel::baselines::SystemKind;
use kvaccel::engine::EngineBuilder;
use kvaccel::env::SimEnv;
use kvaccel::experiments::{run as run_experiment, EngineMode, ExpContext, ALL_EXPERIMENTS};
use kvaccel::kvaccel::RollbackScheme;
use kvaccel::lsm::LsmOptions;
use kvaccel::runtime::{default_artifacts_dir, XlaRuntime};
use kvaccel::ssd::SsdConfig;
use kvaccel::util::{fmt, Args};
use kvaccel::workload::{self, BenchConfig};

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args),
        Some("experiment") | Some("exp") => cmd_experiment(&args),
        Some("inspect") => cmd_inspect(),
        _ => {
            println!("kvaccel — host-SSD collaborative write accelerator (paper reproduction)");
            println!();
            println!("usage:");
            println!("  kvaccel run <A|B|C|D> [--system rocksdb|rocksdb-nosd|adoc|kvaccel|kvaccel-lazy|kvaccel-eager]");
            println!("              [--threads N] [--scale F] [--seed N] [--engine rust|xla]");
            println!("  kvaccel experiment <id|all> [--scale F] [--seed N] [--engine rust|xla]");
            println!("      ids: {ALL_EXPERIMENTS:?}");
            println!("  kvaccel inspect");
            Ok(())
        }
    }
}

fn parse_system(name: &str) -> Result<SystemKind> {
    Ok(match name {
        "rocksdb" => SystemKind::RocksDb { slowdown: true },
        "rocksdb-nosd" => SystemKind::RocksDb { slowdown: false },
        "adoc" => SystemKind::Adoc,
        "kvaccel" => SystemKind::Kvaccel { scheme: RollbackScheme::Disabled },
        "kvaccel-lazy" => SystemKind::Kvaccel { scheme: RollbackScheme::Lazy },
        "kvaccel-eager" => SystemKind::Kvaccel { scheme: RollbackScheme::Eager },
        other => return Err(anyhow!("unknown system {other:?}")),
    })
}

fn parse_engine(args: &Args) -> EngineMode {
    match args.get_or("engine", "rust") {
        "xla" => EngineMode::Xla,
        _ => EngineMode::Rust,
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let workload_id = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("run needs a workload: A|B|C|D"))?
        .to_uppercase();
    let kind = parse_system(args.get_or("system", "kvaccel"))?;
    let threads = args.get_usize("threads", 4);
    let scale = args.get_f64("scale", 0.1);
    let seed = args.get_u64("seed", 42);
    let ctx = ExpContext::new(scale, seed, parse_engine(args))?;

    let opts = LsmOptions::default().with_threads(threads);
    let mut sys = EngineBuilder::new(kind)
        .opts(opts)
        .merge_engine(ctx.merge_engine())
        .bloom_builder(ctx.bloom_builder())
        .build();
    let mut env = SimEnv::new(seed, SsdConfig::default());
    let cfg: BenchConfig = ctx.bench_config();

    let r = match workload_id.as_str() {
        "A" => workload::fillrandom(&mut *sys, &mut env, &cfg),
        "B" => workload::readwhilewriting(&mut *sys, &mut env, &cfg, 9, 1),
        "C" => workload::readwhilewriting(&mut *sys, &mut env, &cfg, 8, 2),
        "D" => {
            let preload_bytes = ((20u64 << 30) as f64 * scale) as u64;
            let t0 = workload::preload(&mut *sys, &mut env, &cfg, preload_bytes)?;
            workload::seekrandom(&mut *sys, &mut env, &cfg, (60_000f64 * scale) as usize, 1024, t0)
        }
        other => return Err(anyhow!("unknown workload {other:?}")),
    };

    println!("system        {}", kind.label());
    println!("workload      {} ({} virtual s, scale {scale})", r.workload, r.duration_s);
    println!("writes        {} ({:.1} Kops/s)", r.writes.total, r.write_kops());
    println!("reads         {} ({:.1} Kops/s)", r.reads.total, r.read_kops());
    println!("write p50/p99 {} / {}", fmt::nanos(r.write_lat.p50_us * 1e3), fmt::nanos(r.write_lat.p99_us * 1e3));
    println!("read  p50/p99 {} / {}", fmt::nanos(r.read_lat.p50_us * 1e3), fmt::nanos(r.read_lat.p99_us * 1e3));
    println!("throughput    {:.1} MB/s user writes", r.write_mbps);
    println!("cpu           {:.1}% of 8 cores", r.cpu_percent);
    println!("efficiency    {:.2} MB/s per CPU%", r.efficiency);
    println!("stalls        {} halts ({:.2}s), {} slowdown instances", r.stop_events, r.stopped_s, r.slowdown_events);
    println!("write amp     {:.2}", r.write_amplification);
    if r.redirected_writes > 0 || r.rollbacks > 0 {
        println!("kvaccel       {} redirected writes, {} rollbacks", r.redirected_writes, r.rollbacks);
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("experiment needs an id or 'all'"))?;
    let scale = args.get_f64("scale", 0.1);
    let seed = args.get_u64("seed", 42);
    let ctx = ExpContext::new(scale, seed, parse_engine(args))?;
    println!(
        "running {id} at scale {scale} (paper = 1.0), engine {:?}; CSVs -> results/",
        ctx.engine
    );
    run_experiment(&ctx, id)?;
    Ok(())
}

fn cmd_inspect() -> Result<()> {
    let dir = default_artifacts_dir();
    println!("artifacts dir: {}", dir.display());
    match XlaRuntime::load(&dir) {
        Ok(rt) => {
            println!("merge artifacts: {:?}", rt.merge_shapes());
            println!("bloom artifacts: {:?}", rt.bloom_shapes());
        }
        Err(e) => println!("runtime not loadable: {e:#}"),
    }
    let ssd = SsdConfig::default();
    println!(
        "ssd model: {} ch x {} way, page {}, peak program bw {}",
        ssd.nand.channels,
        ssd.nand.ways,
        fmt::bytes(ssd.nand.page_bytes as f64),
        fmt::bytes(ssd.nand.peak_program_bw())
    );
    println!(
        "pcie: {:.1} GB/s per direction, dma chunk {}",
        ssd.pcie.bytes_per_ns,
        fmt::bytes(ssd.dma_chunk_bytes as f64)
    );
    Ok(())
}
