//! Multi-tenant QoS: token-bucket admission control, SLO-aware overload
//! shedding, and per-tenant device-budget arbitration (DESIGN.md §10).
//!
//! Every workload client is tagged with a [`TenantId`]; a [`QosConfig`]
//! describes the tenants (token rate, burst, p99 SLO, weight) and the
//! scheduler threads a [`QosController`] through the event loop:
//!
//! - **Admission**: each op is charged to its tenant's deterministic
//!   [`TokenBucket`] in simulated bytes before it reaches the engine; an
//!   over-budget op is rescheduled to the bucket's exact ready time
//!   (closed-loop issues slide, open-loop dispatches wait at the FIFO
//!   head, so throttling surfaces as queueing delay).
//! - **SLO shedding**: a periodic tick measures each tenant's windowed
//!   p99; once a tenant exceeds its target, its *own* stale open-loop
//!   backlog is dropped first — bounded queues for the abuser instead of
//!   an engine stall for everyone.
//! - **Device budget** (KVACCEL): the PR5 revoke-before-grant arbiter is
//!   reused over tenants — each tenant holds a grant of the redirection
//!   budget (`max_kv_occupancy`), and the grant follows whichever tenant
//!   is actually stalling, weighted by the configured shares.
//!
//! With `enforce == false` the controller only *measures* (per-tenant
//! breakdowns in [`RunResult`](crate::workload::RunResult)); the op
//! stream is bit-identical to a run with no QoS at all — asserted by
//! `tests/qos_conformance.rs`.

pub mod bucket;

pub use bucket::TokenBucket;

use crate::engine::KvEngine;
use crate::env::SimEnv;
use crate::shard::{ArbiterConfig, DeviceArbiter, ShardSignal};
use crate::sim::{Nanos, MILLIS, NS_PER_SEC};
use crate::workload::stats::{Histogram, HistogramSummary};

/// Identifies one tenant inside a workload run (an index into
/// [`QosConfig::tenants`]).
pub type TenantId = u32;

/// One tenant's contract: how much it may push and what it was promised.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    pub name: String,
    /// Relative share of the device redirection budget (grants are
    /// seeded proportionally; the arbiter moves them afterwards).
    pub weight: f64,
    /// Token-bucket refill rate in simulated bytes/s; 0 = unlimited.
    pub rate_bytes_per_sec: u64,
    /// Token-bucket burst in bytes (ignored when unlimited).
    pub burst_bytes: u64,
    /// p99 total-latency target; when the measured windowed p99 exceeds
    /// it, the shedder drops this tenant's stale open-loop backlog.
    pub slo_p99: Option<Nanos>,
}

impl TenantSpec {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            weight: 1.0,
            rate_bytes_per_sec: 0,
            burst_bytes: 0,
            slo_p99: None,
        }
    }

    pub fn with_rate(mut self, bytes_per_sec: u64, burst_bytes: u64) -> Self {
        self.rate_bytes_per_sec = bytes_per_sec;
        self.burst_bytes = burst_bytes;
        self
    }

    pub fn with_slo_p99(mut self, target: Nanos) -> Self {
        self.slo_p99 = Some(target);
        self
    }

    pub fn with_weight(mut self, w: f64) -> Self {
        self.weight = w.max(1e-6);
        self
    }
}

/// Tenant table + controller knobs, carried on the
/// [`WorkloadSpec`](crate::workload::WorkloadSpec).
#[derive(Clone, Debug)]
pub struct QosConfig {
    pub tenants: Vec<TenantSpec>,
    /// false = measure per-tenant stats only; the op stream is untouched.
    pub enforce: bool,
    /// SLO/arbitration cadence (the detector's 0.1 s by default).
    pub tick_interval: Nanos,
    /// Minimum ops in a tick window before its p99 can trip the SLO.
    pub slo_min_window_ops: u64,
}

impl QosConfig {
    pub fn new(tenants: Vec<TenantSpec>) -> Self {
        Self {
            tenants,
            enforce: true,
            tick_interval: 100 * MILLIS,
            slo_min_window_ops: 16,
        }
    }

    /// Accounting-only mode: per-tenant breakdowns without perturbing
    /// the run (bit-identical to no QoS).
    pub fn monitor_only(mut self) -> Self {
        self.enforce = false;
        self
    }
}

/// Per-tenant slice of a [`RunResult`](crate::workload::RunResult).
#[derive(Clone, Debug)]
pub struct TenantResult {
    pub name: String,
    pub ops: u64,
    pub ops_per_sec: f64,
    pub mbps: f64,
    /// Total latency (queueing + service for open loop).
    pub lat: HistogramSummary,
    /// Open-loop FIFO wait (includes bucket hold time).
    pub queue_delay: HistogramSummary,
    /// Token-bucket refusals (an op can be refused more than once).
    pub throttled: u64,
    /// Total virtual time ops spent parked on the bucket.
    pub throttle_delay_s: f64,
    /// Backlogged ops dropped by the SLO shedder.
    pub shed: u64,
    /// Ticks whose windowed p99 exceeded the tenant's target.
    pub over_slo_ticks: u64,
    /// Configured target in us (0 = no SLO).
    pub slo_p99_us: f64,
    /// Final device redirection grant (0 unless arbitrated on KVACCEL).
    pub device_grant: f64,
    /// Redirected writes attributed to this tenant.
    pub redirected_writes: u64,
}

/// Scheduler-side QoS state: one bucket + measurement window per tenant,
/// and the tenant-granular device arbiter.
#[derive(Clone, Debug)]
pub struct QosController {
    cfg: QosConfig,
    buckets: Vec<TokenBucket>,
    arbiter: DeviceArbiter,
    lat: Vec<Histogram>,
    qdelay: Vec<Histogram>,
    win_lat: Vec<Histogram>,
    win_ops: Vec<u64>,
    ops: Vec<u64>,
    bytes: Vec<u64>,
    throttled: Vec<u64>,
    throttle_delay: Vec<Nanos>,
    shed: Vec<u64>,
    over_slo: Vec<bool>,
    over_slo_ticks: Vec<u64>,
    redirects: Vec<u64>,
    /// `writes_to_dev` snapshot taken just before the in-flight op.
    dev_base: u64,
    /// True once the device budget was actually pushed to a controller.
    device_arbitrated: bool,
}

impl QosController {
    pub fn new(cfg: &QosConfig) -> Self {
        let n = cfg.tenants.len().max(1);
        let buckets = cfg
            .tenants
            .iter()
            .map(|t| {
                if t.rate_bytes_per_sec == 0 {
                    TokenBucket::unlimited()
                } else {
                    TokenBucket::new(t.rate_bytes_per_sec, t.burst_bytes.max(1))
                }
            })
            .collect();
        // seed the grant table proportionally to the tenant weights;
        // recover() normalizes the sum to the budget and applies the
        // min-grant floor, exactly as a recovered shard table would
        let acfg = ArbiterConfig::default();
        let wsum: f64 = cfg.tenants.iter().map(|t| t.weight.max(1e-6)).sum();
        let grants: Vec<f64> = cfg
            .tenants
            .iter()
            .map(|t| acfg.total_occupancy * t.weight.max(1e-6) / wsum.max(1e-6))
            .collect();
        let arbiter = DeviceArbiter::recover(grants, None, acfg);
        Self {
            cfg: cfg.clone(),
            buckets,
            arbiter,
            lat: vec![Histogram::new(); n],
            qdelay: vec![Histogram::new(); n],
            win_lat: vec![Histogram::new(); n],
            win_ops: vec![0; n],
            ops: vec![0; n],
            bytes: vec![0; n],
            throttled: vec![0; n],
            throttle_delay: vec![0; n],
            shed: vec![0; n],
            over_slo: vec![false; n],
            over_slo_ticks: vec![0; n],
            redirects: vec![0; n],
            dev_base: 0,
            device_arbitrated: false,
        }
    }

    pub fn tenant_count(&self) -> usize {
        self.cfg.tenants.len()
    }

    pub fn tick_interval(&self) -> Nanos {
        self.cfg.tick_interval.max(1)
    }

    pub fn enforcing(&self) -> bool {
        self.cfg.enforce
    }

    pub fn arbiter(&self) -> &DeviceArbiter {
        &self.arbiter
    }

    /// Charge tenant `t`'s bucket for an op of `cost_bytes` at `now`.
    /// `None` = admitted; `Some(ready)` = reschedule the op at `ready`.
    pub fn try_charge(&mut self, t: usize, now: Nanos, cost_bytes: u64) -> Option<Nanos> {
        if !self.cfg.enforce {
            return None;
        }
        let ready = self.buckets[t].try_charge(now, cost_bytes)?;
        self.throttled[t] += 1;
        self.throttle_delay[t] += ready.saturating_sub(now);
        Some(ready)
    }

    /// When shedding applies to tenant `t` right now, the staleness
    /// threshold: backlog entries older than this are dropped.
    pub fn shed_threshold(&self, t: usize) -> Option<Nanos> {
        if self.cfg.enforce && self.over_slo[t] {
            self.cfg.tenants[t].slo_p99
        } else {
            None
        }
    }

    pub fn note_shed(&mut self, t: usize) {
        self.shed[t] += 1;
    }

    pub fn record_queue_wait(&mut self, t: usize, wait: Nanos) {
        self.qdelay[t].record(wait);
    }

    /// Called just before an admitted op reaches the engine: snapshot the
    /// redirect counter for attribution and (when enforcing) push tenant
    /// `t`'s effective redirection cap into the KVACCEL controller.
    pub fn before_op(&mut self, sys: &mut dyn KvEngine, env: &SimEnv, t: usize) {
        let Some(k) = sys.kvaccel_mut() else { return };
        self.dev_base = k.controller.stats.writes_to_dev;
        if self.cfg.enforce && self.tenant_count() >= 2 {
            let occ = env.device.kv_ns_occupancy(k.namespace());
            k.controller.cfg.max_kv_occupancy = self.device_cap(t, occ);
            self.device_arbitrated = true;
        }
    }

    /// Called right after the op completes: per-tenant measurement and
    /// redirect attribution.
    pub fn after_op(
        &mut self,
        sys: &mut dyn KvEngine,
        t: usize,
        cost_bytes: u64,
        lat: Nanos,
    ) {
        self.ops[t] += 1;
        self.win_ops[t] += 1;
        self.bytes[t] += cost_bytes;
        self.lat[t].record(lat);
        self.win_lat[t].record(lat);
        if let Some(k) = sys.kvaccel_mut() {
            self.redirects[t] +=
                k.controller.stats.writes_to_dev.saturating_sub(self.dev_base);
        }
    }

    /// Tenants share one KV-region namespace, so a tenant's cap is the
    /// occupancy everyone else already holds plus its own grant: its
    /// controller refuses redirection once *its* share reaches the grant,
    /// without revoking data other tenants already landed.
    fn device_cap(&self, t: usize, region_occupancy: f64) -> f64 {
        let total = self.arbiter.config().total_occupancy;
        let others = region_occupancy * (1.0 - self.occupancy_share(t));
        (others + self.arbiter.grants()[t]).clamp(0.0, total)
    }

    /// Tenant `t`'s share of redirected writes (proxy for its share of
    /// the KV region's resident data).
    fn occupancy_share(&self, t: usize) -> f64 {
        let total: u64 = self.redirects.iter().sum();
        if total == 0 {
            0.0
        } else {
            self.redirects[t] as f64 / total as f64
        }
    }

    /// Periodic controller pass: rotate the SLO windows and, on KVACCEL,
    /// rebalance the per-tenant device grants (revoke-before-grant, one
    /// transfer in flight, exactly the PR5 shard machinery).
    pub fn on_tick(&mut self, at: Nanos, sys: &mut dyn KvEngine, env: &SimEnv) {
        for t in 0..self.tenant_count() {
            let over = match self.cfg.tenants[t].slo_p99 {
                Some(slo) if self.win_lat[t].count() >= self.cfg.slo_min_window_ops => {
                    self.win_lat[t].p99() > slo
                }
                _ => false,
            };
            self.over_slo[t] = over;
            if over {
                self.over_slo_ticks[t] += 1;
            }
            self.win_lat[t] = Histogram::new();
        }
        if self.cfg.enforce && self.tenant_count() >= 2 {
            if let Some(k) = sys.kvaccel_mut() {
                let stall = k.detector.stall_imminent();
                let occ = env.device.kv_ns_occupancy(k.namespace());
                let signals: Vec<ShardSignal> = (0..self.tenant_count())
                    .map(|t| ShardSignal {
                        // a tenant only claims capacity while it is
                        // actually pushing ops into the stalling engine
                        stall_imminent: stall && self.win_ops[t] > 0,
                        occupancy: occ * self.occupancy_share(t),
                    })
                    .collect();
                self.arbiter.maybe_rebalance(at, &signals);
            }
        }
        for w in &mut self.win_ops {
            *w = 0;
        }
    }

    /// Fold the controller into the per-tenant result rows.
    pub fn into_results(self, duration_s: f64) -> Vec<TenantResult> {
        let dur = duration_s.max(1e-9);
        self.cfg
            .tenants
            .iter()
            .enumerate()
            .map(|(t, sp)| TenantResult {
                name: sp.name.clone(),
                ops: self.ops[t],
                ops_per_sec: self.ops[t] as f64 / dur,
                mbps: self.bytes[t] as f64 / dur / (1024.0 * 1024.0),
                lat: HistogramSummary::from(&self.lat[t]),
                queue_delay: HistogramSummary::from(&self.qdelay[t]),
                throttled: self.throttled[t],
                throttle_delay_s: self.throttle_delay[t] as f64 / NS_PER_SEC as f64,
                shed: self.shed[t],
                over_slo_ticks: self.over_slo_ticks[t],
                slo_p99_us: sp.slo_p99.map_or(0.0, |s| s as f64 / 1e3),
                device_grant: if self.device_arbitrated {
                    self.arbiter.grants()[t]
                } else {
                    0.0
                },
                redirected_writes: self.redirects[t],
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_tenants(enforce: bool) -> QosController {
        let mut cfg = QosConfig::new(vec![
            TenantSpec::new("abuser").with_rate(100_000, 50_000).with_slo_p99(50 * MILLIS),
            TenantSpec::new("victim"),
        ]);
        cfg.enforce = enforce;
        QosController::new(&cfg)
    }

    #[test]
    fn monitor_mode_never_throttles_or_sheds() {
        let mut q = two_tenants(false);
        for i in 0..1_000u64 {
            assert_eq!(q.try_charge(0, i, 1 << 20), None);
        }
        assert_eq!(q.shed_threshold(0), None);
        let r = q.into_results(1.0);
        assert_eq!(r[0].throttled, 0);
    }

    #[test]
    fn enforced_bucket_throttles_only_its_tenant() {
        let mut q = two_tenants(true);
        // drain the abuser's burst; the victim stays unlimited
        let mut refusals = 0;
        for i in 0..100u64 {
            if q.try_charge(0, i, 4_096).is_some() {
                refusals += 1;
            }
            assert_eq!(q.try_charge(1, i, 4_096), None, "victim throttled");
        }
        assert!(refusals > 0, "abuser never throttled");
        let r = q.into_results(1.0);
        assert_eq!(r[0].throttled, refusals);
        assert_eq!(r[1].throttled, 0);
    }

    #[test]
    fn slo_window_trips_and_arms_the_shedder() {
        let mut q = two_tenants(true);
        let slo = 50 * MILLIS;
        for _ in 0..32 {
            q.ops[0] += 1;
            q.win_lat[0].record(4 * slo); // way over target
        }
        assert_eq!(q.shed_threshold(0), None, "not armed before a tick");
        let mut sys = crate::engine::EngineBuilder::rocksdb(true)
            .opts(crate::lsm::LsmOptions::small_for_test())
            .build();
        let env = SimEnv::new(1, crate::ssd::SsdConfig::default());
        q.on_tick(0, &mut *sys, &env);
        assert_eq!(q.shed_threshold(0), Some(slo), "over-SLO tenant armed");
        assert_eq!(q.shed_threshold(1), None, "in-SLO tenant untouched");
        assert_eq!(q.over_slo_ticks[0], 1);
    }

    #[test]
    fn weighted_grants_sum_to_budget() {
        let cfg = QosConfig::new(vec![
            TenantSpec::new("a").with_weight(3.0),
            TenantSpec::new("b").with_weight(1.0),
        ]);
        let q = QosController::new(&cfg);
        let g = q.arbiter().grants();
        let sum: f64 = g.iter().sum();
        assert!((sum - 0.9).abs() < 1e-9, "sum {sum}");
        assert!(g[0] > g[1], "weight ignored: {g:?}");
    }
}
