//! Deterministic token bucket for per-tenant admission control.
//!
//! Tokens are stored in **byte-nanoseconds**: refilling for `dt` ns at
//! `rate` bytes/s adds `dt * rate` token units, and charging `b` bytes
//! costs `b * NS_PER_SEC` units. Both sides are exact integer
//! arithmetic, so the bucket's state is a pure function of the
//! (charge-time, cost) sequence — no float drift, bit-identical across
//! runs and platforms, which is what the scheduler's determinism
//! conformance demands. An insufficient charge does not consume
//! anything; it returns the exact virtual time at which the refill will
//! cover the cost, so the caller can reschedule instead of polling.

use crate::sim::{Nanos, NS_PER_SEC};

#[derive(Clone, Debug)]
pub struct TokenBucket {
    /// Sustained admission rate; 0 disables the bucket (unlimited).
    rate_bytes_per_sec: u64,
    /// Burst capacity in token units (byte-ns).
    capacity: u128,
    /// Current balance in token units.
    tokens: u128,
    last_refill: Nanos,
}

impl TokenBucket {
    /// A bucket admitting `rate_bytes_per_sec` sustained with up to
    /// `burst_bytes` of instantaneous burst. Starts full.
    pub fn new(rate_bytes_per_sec: u64, burst_bytes: u64) -> Self {
        let capacity = burst_bytes.max(1) as u128 * NS_PER_SEC as u128;
        Self { rate_bytes_per_sec, capacity, tokens: capacity, last_refill: 0 }
    }

    /// A bucket that admits everything (rate 0 = metering off).
    pub fn unlimited() -> Self {
        Self::new(0, 1)
    }

    pub fn is_unlimited(&self) -> bool {
        self.rate_bytes_per_sec == 0
    }

    /// Current balance, rounded down to whole bytes.
    pub fn tokens_bytes(&self) -> u64 {
        (self.tokens / NS_PER_SEC as u128) as u64
    }

    fn refill(&mut self, now: Nanos) {
        if now <= self.last_refill {
            return; // virtual time only moves forward
        }
        let dt = (now - self.last_refill) as u128;
        self.tokens =
            (self.tokens + dt * self.rate_bytes_per_sec as u128).min(self.capacity);
        self.last_refill = now;
    }

    /// Charge `cost_bytes` at virtual time `now`. Returns `None` when
    /// admitted (tokens deducted), or `Some(ready)` — the earliest time
    /// the refill covers the cost — without consuming anything. A cost
    /// larger than the burst capacity is clamped to it, so every op is
    /// eventually admittable (no starvation by construction).
    pub fn try_charge(&mut self, now: Nanos, cost_bytes: u64) -> Option<Nanos> {
        if self.is_unlimited() {
            return None;
        }
        self.refill(now);
        let cost =
            (cost_bytes.max(1) as u128 * NS_PER_SEC as u128).min(self.capacity);
        if self.tokens >= cost {
            self.tokens -= cost;
            return None;
        }
        let deficit = cost - self.tokens;
        let rate = self.rate_bytes_per_sec as u128;
        let wait = deficit.div_ceil(rate) as u64;
        Some(now.saturating_add(wait.max(1)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimRng;

    /// Property: over any charge sequence, the admitted volume never
    /// exceeds burst + elapsed * rate (token conservation, exactly, in
    /// token units).
    #[test]
    fn conservation_under_random_load() {
        for seed in [1u64, 7, 42, 0xDEAD] {
            let mut rng = SimRng::new(seed);
            let rate = 1_000 + rng.gen_range_u64(50_000);
            let burst = 4_096 + rng.gen_range_u64(1 << 20);
            let mut b = TokenBucket::new(rate, burst);
            let mut now: Nanos = 0;
            let mut admitted: u128 = 0;
            for _ in 0..10_000 {
                now += rng.gen_range_u64(200_000);
                let cost = 1 + rng.gen_range_u64(16_384);
                if b.try_charge(now, cost).is_none() {
                    admitted += (cost as u128 * NS_PER_SEC as u128).min(b.capacity);
                }
            }
            let budget =
                b.capacity + now as u128 * rate as u128;
            assert!(
                admitted <= budget,
                "seed {seed}: admitted {admitted} > budget {budget}"
            );
        }
    }

    /// Property: from a full bucket, instantaneous admission is bounded
    /// by the burst size.
    #[test]
    fn burst_bound() {
        let mut b = TokenBucket::new(10_000, 64 * 1024);
        let mut admitted = 0u64;
        loop {
            match b.try_charge(0, 4_096) {
                None => admitted += 4_096,
                Some(ready) => {
                    assert!(ready > 0, "ready time must advance");
                    break;
                }
            }
            assert!(admitted <= 64 * 1024, "burst exceeded: {admitted}");
        }
        assert_eq!(admitted, 64 * 1024, "full burst admittable at t=0");
    }

    /// Property: identical (time, cost) sequences leave two buckets in
    /// identical states and produce identical verdicts, whatever seed
    /// generated the sequence (refill determinism).
    #[test]
    fn refill_determinism_across_seeds() {
        for seed in [3u64, 11, 99, 12345] {
            let mut rng = SimRng::new(seed);
            let seq: Vec<(Nanos, u64)> = (0..5_000)
                .scan(0u64, |t, _| {
                    *t += rng.gen_range_u64(100_000);
                    Some((*t, 1 + rng.gen_range_u64(8_192)))
                })
                .collect();
            let mut a = TokenBucket::new(25_000, 256 * 1024);
            let mut b = TokenBucket::new(25_000, 256 * 1024);
            for &(now, cost) in &seq {
                assert_eq!(a.try_charge(now, cost), b.try_charge(now, cost));
                assert_eq!(a.tokens, b.tokens);
                assert_eq!(a.last_refill, b.last_refill);
            }
        }
    }

    /// The returned ready time is exact: charging again at `ready`
    /// (with no interleaving charges) always succeeds.
    #[test]
    fn ready_time_is_sufficient() {
        let mut b = TokenBucket::new(1_000, 2_048);
        // drain the burst
        while b.try_charge(0, 1_024).is_none() {}
        for cost in [1u64, 100, 1_024, 2_048, 1 << 20] {
            let Some(ready) = b.try_charge(0, cost) else {
                panic!("drained bucket admitted {cost} bytes");
            };
            assert!(
                b.try_charge(ready, cost).is_none(),
                "cost {cost} refused at its own ready time {ready}"
            );
        }
    }

    #[test]
    fn unlimited_never_throttles() {
        let mut b = TokenBucket::unlimited();
        for t in 0..1_000u64 {
            assert_eq!(b.try_charge(t, u64::MAX / 2), None);
        }
    }

    #[test]
    fn oversized_cost_clamps_to_burst() {
        // a single op larger than the burst charges the whole bucket but
        // is admitted once the bucket is full — no permanent starvation
        let mut b = TokenBucket::new(1_000, 512);
        assert_eq!(b.try_charge(0, 1 << 30), None, "full bucket admits");
        let ready = b.try_charge(0, 1 << 30).expect("empty bucket refuses");
        assert!(b.try_charge(ready, 1 << 30).is_none());
    }
}
