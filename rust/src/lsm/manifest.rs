//! Versioned manifest: the durable edit log behind crash recovery
//! (RocksDB's MANIFEST). Every structural change to the store — a flush
//! installing an L0 SST, a compaction swapping files, a KVACCEL rollback
//! window opening/closing, a clean shutdown — appends one fsync'd edit
//! record; reopening replays the log to rebuild the [`Version`] exactly.
//!
//! In this simulation the SST *handles* (`Arc<Sst>`) stand in for
//! re-opening the files by id: the edit log is the durable record, the
//! `Arc` is the NAND content it points at. Edit bytes are charged to the
//! device synchronously (manifest writes are fsync'd even under the
//! paper's sync=false db_bench config — exactly like RocksDB).

use std::collections::BTreeSet;
use std::sync::Arc;

use crate::env::SimEnv;
use crate::sim::Nanos;
use crate::vlog::VlogSegment;

use super::entry::Seq;
use super::sst::Sst;
use super::version::Version;

/// One durable edit record.
#[derive(Clone, Debug)]
pub enum ManifestEdit {
    /// Full base image written at reopen: compacts the log so the edit
    /// history stays bounded across restarts.
    Rebase {
        levels: Vec<Vec<Arc<Sst>>>,
        /// Highest sequence number covered by the flushed SSTs.
        flushed_upto: Seq,
        next_sst_id: u64,
        /// Live value-log segments (key-value separation; empty when the
        /// vlog is off).
        vlog: Vec<Arc<VlogSegment>>,
    },
    /// Flush install: a new L0 SST covering WAL records up to `max_seq`.
    AddL0 { sst: Arc<Sst>, max_seq: Seq },
    /// Compaction install: `removed` ids leave `level`/`level+1`,
    /// `installed` enters `level+1`.
    CompactionInstall {
        level: usize,
        removed: Vec<u64>,
        installed: Vec<Arc<Sst>>,
    },
    /// KVACCEL rollback window opened (device buffer being merged back).
    /// A crash that leaves this edit dangling (no matching
    /// [`ManifestEdit::RollbackEnd`]) tells recovery the redirection was
    /// in flight — reconciliation then decides per key which copy is
    /// durable (paper Fig 8's consistency protocol).
    RollbackBegin { at: Nanos },
    /// Rollback window closed: the device buffer was reset.
    RollbackEnd { returned: u64 },
    /// Clean shutdown: memtable flushed, WAL sealed + fsync'd and empty.
    CleanShutdown { last_seq: Seq },
    /// Value-log head sealed into an immutable segment. The vlog stream
    /// was fsync'd before this edit is appended, so every record the
    /// segment names is on flash when the manifest references it.
    VlogSeal { segment: Arc<VlogSegment> },
    /// Value-log segment retired by GC: its live values were re-appended
    /// to the head (and fsync'd) before this edit — recovery must no
    /// longer consider the segment part of the log.
    VlogDrop { segment: u32 },
}

impl ManifestEdit {
    /// Logical encoded size for device charging: a fixed record header
    /// plus one file descriptor per SST reference.
    fn encoded_len(&self) -> u64 {
        let refs = match self {
            ManifestEdit::Rebase { levels, vlog, .. } => {
                levels.iter().map(|l| l.len()).sum::<usize>() + vlog.len()
            }
            ManifestEdit::AddL0 { .. } => 1,
            ManifestEdit::CompactionInstall { removed, installed, .. } => {
                removed.len() + installed.len()
            }
            ManifestEdit::VlogSeal { .. } | ManifestEdit::VlogDrop { .. } => 1,
            _ => 0,
        };
        32 + 16 * refs as u64
    }
}

/// What [`Manifest::rebuild`] recovers from the edit log.
#[derive(Clone, Debug)]
pub struct RecoveredVersion {
    pub version: Version,
    pub next_sst_id: u64,
    /// Highest sequence number durably covered by flushed SSTs — WAL
    /// records at or below it are already in the tree and must NOT be
    /// replayed (an older WAL copy re-entering the memtable would shadow
    /// the newer SST version on the read path).
    pub flushed_upto: Seq,
    /// `Some(last_seq)` when the log ends in a clean shutdown.
    pub clean: Option<Seq>,
    /// A rollback window was open when the log ended (crash mid-rollback).
    pub dangling_rollback: bool,
    /// Live value-log segments (seals minus drops), id-ascending.
    pub vlog_segments: Vec<Arc<VlogSegment>>,
}

#[derive(Clone, Debug, Default)]
pub struct ManifestStats {
    pub edits: u64,
    pub bytes_written: u64,
    pub rebases: u64,
}

/// The durable edit log. Cloning is cheap (SST handles are `Arc`s); the
/// clone carried inside a `DurableImage` is the on-flash copy.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// (version number, edit), append order.
    edits: Vec<(u64, ManifestEdit)>,
    next_version: u64,
    /// Bytes of the CURRENT log on flash (reset by `rebase`;
    /// `stats.bytes_written` stays cumulative).
    live_bytes: u64,
    pub stats: ManifestStats,
}

impl Manifest {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn edit_count(&self) -> usize {
        self.edits.len()
    }

    pub fn is_empty(&self) -> bool {
        self.edits.is_empty()
    }

    /// Current log size on flash (recovery read charging; rewritten logs
    /// only pay for the live edits, not the rebased-away history).
    pub fn bytes(&self) -> u64 {
        self.live_bytes
    }

    /// Version number the next edit will carry.
    pub fn next_version(&self) -> u64 {
        self.next_version
    }

    /// Append one edit, charging a synchronous small device write
    /// (manifest records are fsync'd). Returns the sync completion time;
    /// the install itself is effective at `at` — the fsync tail only
    /// occupies device bandwidth.
    pub fn append(&mut self, env: &mut SimEnv, at: Nanos, edit: ManifestEdit) -> Nanos {
        let bytes = edit.encoded_len();
        let done = env.device.meta_sync_write(at, bytes);
        self.stats.edits += 1;
        self.stats.bytes_written += bytes;
        self.live_bytes += bytes;
        self.edits.push((self.next_version, edit));
        self.next_version += 1;
        done
    }

    /// Rewrite the log as a single [`ManifestEdit::Rebase`] snapshot of
    /// `version` (called at reopen so the log stays bounded).
    pub fn rebase(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        version: &Version,
        next_sst_id: u64,
        flushed_upto: Seq,
        vlog: Vec<Arc<VlogSegment>>,
    ) -> Nanos {
        self.edits.clear();
        self.live_bytes = 0;
        self.stats.rebases += 1;
        self.append(
            env,
            at,
            ManifestEdit::Rebase {
                levels: version.levels.clone(),
                flushed_upto,
                next_sst_id,
                vlog,
            },
        )
    }

    /// Replay the edit log into a fresh [`Version`] — the recovery path.
    pub fn rebuild(&self, num_levels: usize) -> RecoveredVersion {
        let mut version = Version::new(num_levels);
        let mut next_sst_id = 1u64;
        let mut flushed_upto: Seq = 0;
        let mut clean = None;
        let mut dangling_rollback = false;
        let mut vlog_segments: Vec<Arc<VlogSegment>> = Vec::new();
        for (_, edit) in &self.edits {
            match edit {
                ManifestEdit::Rebase { levels, flushed_upto: f, next_sst_id: n, vlog } => {
                    version = Version::new(num_levels.max(levels.len()));
                    for (l, files) in levels.iter().enumerate() {
                        version.set_level(l, files.clone());
                    }
                    flushed_upto = *f;
                    next_sst_id = *n;
                    clean = None;
                    dangling_rollback = false;
                    vlog_segments = vlog.clone();
                }
                ManifestEdit::AddL0 { sst, max_seq } => {
                    next_sst_id = next_sst_id.max(sst.id + 1);
                    flushed_upto = flushed_upto.max(*max_seq);
                    version.add_l0(sst.clone());
                    clean = None;
                }
                ManifestEdit::CompactionInstall { level, removed, installed } => {
                    let rm: BTreeSet<u64> = removed.iter().copied().collect();
                    for s in installed {
                        next_sst_id = next_sst_id.max(s.id + 1);
                    }
                    version.apply_compaction(*level, &rm, installed.clone());
                    clean = None;
                }
                ManifestEdit::RollbackBegin { .. } => {
                    dangling_rollback = true;
                    clean = None;
                }
                ManifestEdit::RollbackEnd { .. } => {
                    dangling_rollback = false;
                }
                ManifestEdit::CleanShutdown { last_seq } => {
                    clean = Some(*last_seq);
                }
                ManifestEdit::VlogSeal { segment } => {
                    vlog_segments.push(segment.clone());
                    clean = None;
                }
                ManifestEdit::VlogDrop { segment } => {
                    vlog_segments.retain(|s| s.id != *segment);
                    clean = None;
                }
            }
        }
        vlog_segments.sort_by_key(|s| s.id);
        vlog_segments.dedup_by_key(|s| s.id);
        RecoveredVersion {
            version,
            next_sst_id,
            flushed_upto,
            clean,
            dangling_rollback,
            vlog_segments,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsm::entry::{Entry, ValueDesc};
    use crate::runtime::bloom::BloomBuilder;
    use crate::ssd::SsdConfig;

    fn sst(id: u64, keys: std::ops::Range<u32>, seq_base: Seq) -> Arc<Sst> {
        let entries: Vec<Entry> = keys
            .map(|k| Entry::new(k, seq_base + k, ValueDesc::new(k, 512)))
            .collect();
        Arc::new(
            Sst::build(id, id, entries, &BloomBuilder::rust(), 7, 1024, 32 * 1024)
                .unwrap(),
        )
    }

    fn env() -> SimEnv {
        SimEnv::new(11, SsdConfig::default())
    }

    #[test]
    fn replay_reproduces_flush_and_compaction() {
        let mut env = env();
        let mut m = Manifest::new();
        m.append(&mut env, 0, ManifestEdit::AddL0 { sst: sst(1, 0..10, 100), max_seq: 109 });
        m.append(&mut env, 0, ManifestEdit::AddL0 { sst: sst(2, 5..15, 200), max_seq: 214 });
        m.append(
            &mut env,
            0,
            ManifestEdit::CompactionInstall {
                level: 0,
                removed: vec![1, 2],
                installed: vec![sst(3, 0..15, 300)],
            },
        );
        let rec = m.rebuild(3);
        assert_eq!(rec.version.l0_count(), 0);
        assert_eq!(rec.version.levels[1].len(), 1);
        assert_eq!(rec.version.levels[1][0].id, 3);
        assert_eq!(rec.flushed_upto, 214);
        assert!(rec.next_sst_id >= 4);
        assert!(rec.clean.is_none());
        assert!(!rec.dangling_rollback);
    }

    #[test]
    fn l0_replay_keeps_newest_first() {
        let mut env = env();
        let mut m = Manifest::new();
        m.append(&mut env, 0, ManifestEdit::AddL0 { sst: sst(1, 0..5, 10), max_seq: 14 });
        m.append(&mut env, 0, ManifestEdit::AddL0 { sst: sst(2, 0..5, 20), max_seq: 24 });
        let rec = m.rebuild(3);
        assert_eq!(rec.version.levels[0][0].id, 2, "newest flush first");
    }

    #[test]
    fn dangling_rollback_detected() {
        let mut env = env();
        let mut m = Manifest::new();
        m.append(&mut env, 0, ManifestEdit::RollbackBegin { at: 5 });
        assert!(m.rebuild(3).dangling_rollback);
        m.append(&mut env, 0, ManifestEdit::RollbackEnd { returned: 7 });
        assert!(!m.rebuild(3).dangling_rollback);
    }

    #[test]
    fn clean_marker_cleared_by_later_edits() {
        let mut env = env();
        let mut m = Manifest::new();
        m.append(&mut env, 0, ManifestEdit::CleanShutdown { last_seq: 42 });
        assert_eq!(m.rebuild(3).clean, Some(42));
        m.append(&mut env, 0, ManifestEdit::AddL0 { sst: sst(1, 0..5, 50), max_seq: 54 });
        assert!(m.rebuild(3).clean.is_none());
    }

    #[test]
    fn rebase_compacts_the_log() {
        let mut env = env();
        let mut m = Manifest::new();
        for i in 1..=5u64 {
            let base = i as Seq * 100;
            m.append(
                &mut env,
                0,
                ManifestEdit::AddL0 { sst: sst(i, 0..5, base), max_seq: base + 4 },
            );
        }
        let rec = m.rebuild(3);
        m.rebase(&mut env, 0, &rec.version, rec.next_sst_id, rec.flushed_upto, Vec::new());
        assert_eq!(m.edit_count(), 1);
        let rec2 = m.rebuild(3);
        assert_eq!(rec2.version.l0_count(), 5);
        assert_eq!(rec2.flushed_upto, rec.flushed_upto);
        assert_eq!(rec2.next_sst_id, rec.next_sst_id);
    }

    #[test]
    fn rebase_resets_the_live_log_size() {
        let mut env = env();
        let mut m = Manifest::new();
        for i in 1..=8u64 {
            m.append(
                &mut env,
                0,
                ManifestEdit::AddL0 { sst: sst(i, 0..5, i as Seq * 10), max_seq: i as Seq * 10 + 4 },
            );
        }
        let before = m.bytes();
        let rec = m.rebuild(3);
        m.rebase(&mut env, 0, &rec.version, rec.next_sst_id, rec.flushed_upto, Vec::new());
        assert!(m.bytes() < before, "rebased log must shed the history");
        assert!(m.stats.bytes_written > before, "cumulative stats keep growing");
    }

    #[test]
    fn vlog_seal_and_drop_replay() {
        let seg = |id: u32| {
            Arc::new(VlogSegment {
                id,
                file: None,
                records: Vec::new(),
                bytes: 1 << 20,
            })
        };
        let mut env = env();
        let mut m = Manifest::new();
        m.append(&mut env, 0, ManifestEdit::VlogSeal { segment: seg(0) });
        m.append(&mut env, 0, ManifestEdit::VlogSeal { segment: seg(1) });
        m.append(&mut env, 0, ManifestEdit::VlogDrop { segment: 0 });
        let rec = m.rebuild(3);
        let ids: Vec<u32> = rec.vlog_segments.iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![1], "drop retires the sealed segment");
        // rebase carries the survivors forward
        m.rebase(&mut env, 0, &rec.version, rec.next_sst_id, rec.flushed_upto, rec.vlog_segments);
        assert_eq!(m.edit_count(), 1);
        let rec2 = m.rebuild(3);
        let ids: Vec<u32> = rec2.vlog_segments.iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![1]);
    }

    #[test]
    fn appends_charge_the_device() {
        let mut env = env();
        let mut m = Manifest::new();
        let done = m.append(&mut env, 0, ManifestEdit::CleanShutdown { last_seq: 1 });
        assert!(done > 0, "manifest fsync must take device time");
        assert!(m.bytes() > 0);
    }
}
