//! Compaction merge execution: the *compute* of a compaction, performed
//! through the `MergeEngine` (the AOT XLA artifact on the hot path, or
//! the bit-identical Rust fallback).
//!
//! Recency encoding: inputs are concatenated newest-source-first, so a
//! pair's position index works as the artifact's tag (lower tag == newer
//! version). L0 inputs arrive newest-first from the version; victim-level
//! files precede target-level files.

use anyhow::Result;
use std::sync::Arc;

use crate::runtime::MergeEngine;

use super::entry::Entry;
use super::sst::Sst;
use super::version::CompactionPick;

/// Concatenate the pick's inputs in recency order (newest first).
pub fn concat_inputs(pick: &CompactionPick) -> Vec<Entry> {
    let mut out = Vec::with_capacity(pick.input_entries());
    for sst in pick.inputs.iter().chain(&pick.targets) {
        out.extend_from_slice(&sst.entries);
    }
    out
}

/// Run the merge: sort + newest-wins dedup via the engine, optionally
/// dropping tombstones (bottommost output), splitting the stream into
/// files of at most `target_file_bytes`.
pub fn run_merge(
    entries: &[Entry],
    engine: &MergeEngine,
    target_file_bytes: u64,
    drop_tombstones: bool,
) -> Result<Vec<Vec<Entry>>> {
    if entries.is_empty() {
        return Ok(Vec::new());
    }
    assert!(
        entries.len() < u32::MAX as usize,
        "merge window exceeds tag space"
    );
    let pairs: Vec<(u32, u32)> = entries
        .iter()
        .enumerate()
        .map(|(i, e)| (e.key, i as u32))
        .collect();
    let merged = engine.merge_window(&pairs)?;
    let mut files: Vec<Vec<Entry>> = Vec::new();
    let mut cur: Vec<Entry> = Vec::new();
    let mut cur_bytes = 0u64;
    for (_, tag) in merged {
        let e = entries[tag as usize];
        if drop_tombstones && e.val.is_tombstone() {
            continue;
        }
        cur_bytes += e.encoded_len();
        cur.push(e);
        if cur_bytes >= target_file_bytes {
            files.push(std::mem::take(&mut cur));
            cur_bytes = 0;
        }
    }
    if !cur.is_empty() {
        files.push(cur);
    }
    Ok(files)
}

/// Reference merge for differential testing: BTreeMap newest-wins.
pub fn merge_reference(entries: &[Entry], drop_tombstones: bool) -> Vec<Entry> {
    let mut map: std::collections::BTreeMap<u32, Entry> = Default::default();
    // iterate oldest-first so newer (earlier in slice) overwrite
    for e in entries.iter().rev() {
        map.insert(e.key, *e);
    }
    map.into_values()
        .filter(|e| !(drop_tombstones && e.val.is_tombstone()))
        .collect()
}

/// Bytes/entries that the merge's three phases move (timing model input).
#[derive(Clone, Copy, Debug)]
pub struct MergeShape {
    pub read_bytes: u64,
    pub entries: usize,
    pub write_bytes: u64,
}

pub fn shape_of(pick: &CompactionPick, outputs: &[Vec<Entry>]) -> MergeShape {
    MergeShape {
        read_bytes: pick.input_bytes(),
        entries: pick.input_entries(),
        write_bytes: outputs
            .iter()
            .flatten()
            .map(|e| e.encoded_len())
            .sum(),
    }
}

/// Helper for tests: wrap entry vectors in a pick-like shape.
pub fn pick_of(inputs: Vec<Arc<Sst>>, targets: Vec<Arc<Sst>>, level: usize) -> CompactionPick {
    CompactionPick { level, inputs, targets }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsm::entry::ValueDesc;

    fn e(k: u32, s: u32) -> Entry {
        Entry::new(k, s, ValueDesc::new(s, 256))
    }

    fn tomb(k: u32, s: u32) -> Entry {
        Entry::new(k, s, ValueDesc::TOMBSTONE)
    }

    #[test]
    fn merge_matches_reference() {
        // newest-first concatenation: seq encodes recency for the check
        let entries = vec![e(5, 9), e(1, 8), e(5, 3), e(2, 2), e(9, 1)];
        let out = run_merge(&entries, &MergeEngine::rust(), u64::MAX, false)
            .unwrap()
            .concat();
        assert_eq!(out, merge_reference(&entries, false));
        // key 5 kept the newest (position-first) version
        assert_eq!(out.iter().find(|x| x.key == 5).unwrap().seq, 9);
    }

    #[test]
    fn tombstones_dropped_only_at_bottom() {
        let entries = vec![tomb(1, 9), e(1, 3), e(2, 1)];
        let kept = run_merge(&entries, &MergeEngine::rust(), u64::MAX, false)
            .unwrap()
            .concat();
        assert!(kept.iter().any(|x| x.key == 1 && x.val.is_tombstone()));
        let dropped = run_merge(&entries, &MergeEngine::rust(), u64::MAX, true)
            .unwrap()
            .concat();
        assert!(!dropped.iter().any(|x| x.key == 1));
        assert!(dropped.iter().any(|x| x.key == 2));
    }

    #[test]
    fn file_splitting_respects_target() {
        let entries: Vec<Entry> = (0..100).map(|k| e(k, k + 1)).collect();
        let files =
            run_merge(&entries, &MergeEngine::rust(), 10 * (16 + 256), false).unwrap();
        assert!(files.len() >= 9, "files: {}", files.len());
        let total: usize = files.iter().map(|f| f.len()).sum();
        assert_eq!(total, 100);
        // outputs globally sorted
        let keys: Vec<u32> = files.iter().flatten().map(|x| x.key).collect();
        assert!(keys.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn empty_input_no_files() {
        assert!(run_merge(&[], &MergeEngine::rust(), 1024, false)
            .unwrap()
            .is_empty());
    }

    #[test]
    fn large_window_exercises_chunking() {
        let entries: Vec<Entry> =
            (0..10_000u32).rev().map(|k| e(k % 2048, k + 1)).collect();
        let out = run_merge(&entries, &MergeEngine::rust(), u64::MAX, false)
            .unwrap()
            .concat();
        assert_eq!(out, merge_reference(&entries, false));
        assert_eq!(out.len(), 2048);
    }
}
