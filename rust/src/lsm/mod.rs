//! RocksDB-like LSM engine substrate (the host-side Main-LSM).
//!
//! Built from scratch for this reproduction: memtable/WAL/SST/leveled
//! compaction with RocksDB's stall + slowdown semantics, over the block
//! interface of the simulated dual-interface SSD. The compaction merge
//! and SST bloom builds execute through `runtime::` (AOT XLA artifacts).

pub mod compaction;
pub mod db;
pub mod entry;
pub mod iterator;
pub mod manifest;
pub mod memtable;
pub mod options;
pub mod sst;
pub mod stall;
pub mod version;
pub mod wal;

pub use db::{DbStats, LsmDb, PutResult, RecoveryStats};
pub use entry::{Entry, Key, Seq, ValueDesc, ValueLoc, MAX_USER_KEY};
pub use manifest::{Manifest, ManifestEdit, RecoveredVersion};
pub use options::{Compression, LsmOptions};
pub use stall::{StallReason, StallStats, WriteCondition};
