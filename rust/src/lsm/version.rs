//! Leveled manifest: which SSTs live at which level, overlap queries,
//! compaction scoring/picking, and the pending-compaction-bytes estimate
//! that drives one of the three stall conditions.

use std::collections::BTreeSet;
use std::sync::Arc;

use super::entry::Key;
use super::options::LsmOptions;
use super::sst::Sst;

/// Max oldest-L0 files folded into one L0->L1 job (RocksDB picks subsets
/// rather than the whole level; keeps jobs small and stalls oscillatory).
pub const MAX_L0_FILES_PER_COMPACTION: usize = 8;

#[derive(Clone, Debug)]
pub struct Version {
    /// levels[0] is newest-first (overlapping files); levels[1..] are
    /// sorted by smallest key, pairwise disjoint.
    pub levels: Vec<Vec<Arc<Sst>>>,
    /// Cached per-level byte totals, maintained incrementally — the
    /// stall conditions read these on EVERY put, so recomputing from the
    /// file lists was the #1 foreground hotspot (see EXPERIMENTS.md §Perf).
    bytes: Vec<u64>,
}

/// A picked compaction: inputs from `level`, overlapping files from
/// `level + 1`.
#[derive(Clone, Debug)]
pub struct CompactionPick {
    pub level: usize,
    pub inputs: Vec<Arc<Sst>>,
    pub targets: Vec<Arc<Sst>>,
}

impl CompactionPick {
    pub fn input_bytes(&self) -> u64 {
        self.inputs.iter().chain(&self.targets).map(|s| s.bytes).sum()
    }

    pub fn input_entries(&self) -> usize {
        self.inputs
            .iter()
            .chain(&self.targets)
            .map(|s| s.len())
            .sum()
    }

    pub fn all_ids(&self) -> impl Iterator<Item = u64> + '_ {
        self.inputs.iter().chain(&self.targets).map(|s| s.id)
    }
}

impl Version {
    pub fn new(num_levels: usize) -> Self {
        Self {
            levels: vec![Vec::new(); num_levels],
            bytes: vec![0; num_levels],
        }
    }

    pub fn l0_count(&self) -> usize {
        self.levels[0].len()
    }

    pub fn level_bytes(&self, level: usize) -> u64 {
        debug_assert_eq!(
            self.bytes[level],
            self.levels[level].iter().map(|s| s.bytes).sum::<u64>(),
            "cached level bytes diverged at L{level}"
        );
        self.bytes[level]
    }

    pub fn total_bytes(&self) -> u64 {
        (0..self.levels.len()).map(|l| self.level_bytes(l)).sum()
    }

    pub fn file_count(&self) -> usize {
        self.levels.iter().map(|l| l.len()).sum()
    }

    /// Add a flushed SST to L0 (newest first).
    pub fn add_l0(&mut self, sst: Arc<Sst>) {
        self.bytes[0] += sst.bytes;
        self.levels[0].insert(0, sst);
    }

    /// Install compaction outputs: remove `removed` ids from `level` and
    /// `level+1`, insert `added` into `level+1` keeping key order.
    pub fn apply_compaction(
        &mut self,
        level: usize,
        removed: &BTreeSet<u64>,
        added: Vec<Arc<Sst>>,
    ) {
        let removed_bytes = |files: &[Arc<Sst>]| -> u64 {
            files
                .iter()
                .filter(|s| removed.contains(&s.id))
                .map(|s| s.bytes)
                .sum()
        };
        self.bytes[level] -= removed_bytes(&self.levels[level]);
        self.levels[level].retain(|s| !removed.contains(&s.id));
        let out = level + 1;
        self.bytes[out] -= removed_bytes(&self.levels[out]);
        self.levels[out].retain(|s| !removed.contains(&s.id));
        self.bytes[out] += added.iter().map(|s| s.bytes).sum::<u64>();
        self.levels[out].extend(added);
        self.levels[out].sort_by_key(|s| s.smallest);
        debug_assert!(self.level_disjoint(out), "L{out} overlap after compaction");
    }

    /// Check the disjointness invariant of a level >= 1.
    pub fn level_disjoint(&self, level: usize) -> bool {
        self.levels[level]
            .windows(2)
            .all(|w| w[0].largest < w[1].smallest)
    }

    /// Files in `level` overlapping [min, max].
    pub fn overlapping(&self, level: usize, min: Key, max: Key) -> Vec<Arc<Sst>> {
        self.levels[level]
            .iter()
            .filter(|s| s.overlaps(min, max))
            .cloned()
            .collect()
    }

    /// RocksDB-style estimate: bytes that still need to flow down before
    /// every level is under target.
    pub fn pending_compaction_bytes(&self, opts: &LsmOptions) -> u64 {
        let mut pending = 0u64;
        // L0 beyond the compaction trigger counts in full.
        let l0_bytes = self.level_bytes(0);
        let trigger_bytes =
            opts.l0_compaction_trigger as u64 * opts.write_buffer_size;
        pending += l0_bytes.saturating_sub(trigger_bytes);
        for level in 1..self.levels.len() - 1 {
            pending += self
                .level_bytes(level)
                .saturating_sub(opts.level_target_bytes(level));
        }
        pending
    }

    /// Compaction score per level (score >= 1.0 means "needs compaction").
    pub fn compaction_score(&self, level: usize, opts: &LsmOptions) -> f64 {
        if level == 0 {
            self.l0_count() as f64 / opts.l0_compaction_trigger as f64
        } else {
            self.level_bytes(level) as f64
                / opts.level_target_bytes(level) as f64
        }
    }

    /// Replace a whole level (tests/tools); keeps the byte cache coherent.
    pub fn set_level(&mut self, level: usize, files: Vec<Arc<Sst>>) {
        self.bytes[level] = files.iter().map(|s| s.bytes).sum();
        self.levels[level] = files;
    }

    /// Device file ids referenced by any live SST — recovery's orphan
    /// cleanup deletes block-FS files outside this set (outputs of jobs
    /// that were mid-write at the crash).
    pub fn live_file_ids(&self) -> BTreeSet<crate::ssd::block_if::FileId> {
        self.levels.iter().flatten().map(|s| s.file).collect()
    }

    /// Pick the highest-score level needing compaction, excluding files
    /// already being compacted. L0->L1 is serialized (only one at a time —
    /// the paper's write-stall event #2): if any L0 file is busy, L0 is
    /// skipped.
    pub fn pick_compaction(
        &self,
        opts: &LsmOptions,
        busy: &BTreeSet<u64>,
    ) -> Option<CompactionPick> {
        // Levels in descending score order; take the first feasible pick
        // so a busy L0 does not starve lower-level compactions (RocksDB
        // runs them concurrently on the remaining threads).
        let mut scored: Vec<(f64, usize)> = (0..self.levels.len() - 1)
            .map(|l| (self.compaction_score(l, opts), l))
            .filter(|&(s, _)| s >= 1.0)
            .collect();
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        for (_, level) in scored {
            if let Some(pick) = self.pick_at_level(level, busy) {
                return Some(pick);
            }
        }
        None
    }

    fn pick_at_level(
        &self,
        level: usize,
        busy: &BTreeSet<u64>,
    ) -> Option<CompactionPick> {
        let inputs: Vec<Arc<Sst>> = if level == 0 {
            // L0->L1 is serialized (stall type #2) and incremental: take
            // the OLDEST few files (safe: they are older than every
            // remaining L0 file) so jobs stay small and the L0 count
            // oscillates around the slowdown trigger like RocksDB's.
            if self.levels[0].iter().any(|s| busy.contains(&s.id)) {
                return None;
            }
            let k = self.levels[0].len().min(MAX_L0_FILES_PER_COMPACTION);
            let start = self.levels[0].len() - k;
            self.levels[0][start..].to_vec()
        } else {
            // oldest-ish heuristic: first non-busy file
            let f = self.levels[level]
                .iter()
                .find(|s| !busy.contains(&s.id))?
                .clone();
            vec![f]
        };
        if inputs.is_empty() {
            return None;
        }
        let min = inputs.iter().map(|s| s.smallest).min().unwrap();
        let max = inputs.iter().map(|s| s.largest).max().unwrap();
        let targets = self.overlapping(level + 1, min, max);
        if targets.iter().any(|s| busy.contains(&s.id)) {
            return None;
        }
        Some(CompactionPick { level, inputs, targets })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsm::entry::{Entry, ValueDesc};
    use crate::runtime::bloom::BloomBuilder;

    fn sst(id: u64, keys: std::ops::Range<u32>) -> Arc<Sst> {
        let entries: Vec<Entry> = keys
            .map(|k| Entry::new(k, id as u32 * 1000 + k, ValueDesc::new(k, 512)))
            .collect();
        Arc::new(
            Sst::build(id, id, entries, &BloomBuilder::rust(), 7, 1024, 32 * 1024)
                .unwrap(),
        )
    }

    #[test]
    fn l0_newest_first() {
        let mut v = Version::new(3);
        v.add_l0(sst(1, 0..10));
        v.add_l0(sst(2, 5..15));
        assert_eq!(v.levels[0][0].id, 2);
        assert_eq!(v.l0_count(), 2);
    }

    #[test]
    fn scores_trigger_picks() {
        let opts = LsmOptions::small_for_test();
        let mut v = Version::new(3);
        for i in 0..4 {
            v.add_l0(sst(i, (i as u32 * 10)..(i as u32 * 10 + 10)));
        }
        assert!(v.compaction_score(0, &opts) >= 1.0);
        let pick = v.pick_compaction(&opts, &BTreeSet::new()).unwrap();
        assert_eq!(pick.level, 0);
        assert_eq!(pick.inputs.len(), 4);
    }

    #[test]
    fn l0_pick_blocked_while_busy() {
        let opts = LsmOptions::small_for_test();
        let mut v = Version::new(3);
        for i in 0..4 {
            v.add_l0(sst(i, 0..10));
        }
        let mut busy = BTreeSet::new();
        busy.insert(2u64);
        assert!(v.pick_compaction(&opts, &busy).is_none());
    }

    #[test]
    fn apply_compaction_maintains_disjoint() {
        let mut v = Version::new(3);
        v.add_l0(sst(1, 0..10));
        v.set_level(1, vec![sst(2, 0..5), sst(3, 20..30)]);
        let removed: BTreeSet<u64> = [1u64, 2].into_iter().collect();
        v.apply_compaction(0, &removed, vec![sst(4, 0..10)]);
        assert_eq!(v.l0_count(), 0);
        assert_eq!(v.levels[1].len(), 2);
        assert!(v.level_disjoint(1));
    }

    #[test]
    fn overlapping_query() {
        let mut v = Version::new(3);
        v.set_level(1, vec![sst(1, 0..5), sst(2, 10..15), sst(3, 20..25)]);
        let hits = v.overlapping(1, 4, 11);
        let ids: Vec<u64> = hits.iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![1, 2]);
    }

    #[test]
    fn pending_bytes_grows_with_l0() {
        let opts = LsmOptions::small_for_test();
        let mut v = Version::new(3);
        let before = v.pending_compaction_bytes(&opts);
        for i in 0..10 {
            v.add_l0(sst(i, 0..100));
        }
        assert!(v.pending_compaction_bytes(&opts) > before);
    }
}
