//! Sorted String Table: sorted, key-unique entries with a bloom filter
//! and block-granular read accounting.
//!
//! Entries live in memory (`Arc<Vec<Entry>>`, value payloads are
//! descriptors — see entry.rs); the file's *logical* bytes (including the
//! 4 KB payloads) are what the device models charge. The bloom filter is
//! built through `runtime::BloomBuilder`, i.e. by the AOT bloom artifact
//! when one is loaded.

use std::sync::Arc;

use anyhow::Result;

use crate::runtime::bloom::{may_contain, BloomBuilder};
use crate::ssd::block_if::FileId;

use super::entry::{Entry, Key};
use super::options::Compression;

#[derive(Clone, Debug)]
pub struct BloomFilter {
    pub words: Vec<u32>,
    pub probes: usize,
    pub bits: u32,
}

impl BloomFilter {
    pub fn may_contain(&self, key: Key) -> bool {
        if self.bits == 0 {
            return true;
        }
        may_contain(&self.words, key, self.probes, self.bits)
    }
}

#[derive(Clone, Debug)]
pub struct Sst {
    pub id: u64,
    pub file: FileId,
    /// Sorted ascending by key; exactly one entry per key.
    pub entries: Arc<Vec<Entry>>,
    pub smallest: Key,
    pub largest: Key,
    /// Logical file size (entries' encoded bytes + ~2% metadata).
    pub bytes: u64,
    pub filter: BloomFilter,
    /// Data-block size used for read accounting.
    pub block_bytes: u64,
    /// Max seq contained (recency ordering for overlapping L0 files).
    pub max_seq: u32,
}

impl Sst {
    /// Assemble an uncompressed SST from sorted unique entries. The
    /// caller provides the already-created device file id (I/O is
    /// charged there).
    pub fn build(
        id: u64,
        file: FileId,
        entries: Vec<Entry>,
        builder: &BloomBuilder,
        probes: usize,
        bits: u32,
        block_bytes: u64,
    ) -> Result<Self> {
        Self::build_with_codec(
            id,
            file,
            entries,
            builder,
            probes,
            bits,
            block_bytes,
            Compression::None,
        )
    }

    /// Assemble an SST whose data blocks occupy `codec.disk_bytes` on
    /// the simulated device. `bytes` (and therefore `block_of`'s
    /// geometry — entries per on-disk block) shrink with the ratio;
    /// `Compression::None` is bit-identical to `build`.
    #[allow(clippy::too_many_arguments)]
    pub fn build_with_codec(
        id: u64,
        file: FileId,
        entries: Vec<Entry>,
        builder: &BloomBuilder,
        probes: usize,
        bits: u32,
        block_bytes: u64,
        codec: Compression,
    ) -> Result<Self> {
        assert!(!entries.is_empty(), "SSTs are never empty");
        debug_assert!(
            entries.windows(2).all(|w| w[0].key < w[1].key),
            "entries must be sorted and unique"
        );
        let keys: Vec<Key> = entries.iter().map(|e| e.key).collect();
        let words = builder.build(&keys, probes, bits)?;
        let data_bytes: u64 =
            codec.disk_bytes(entries.iter().map(|e| e.encoded_len()).sum());
        let bytes = data_bytes + data_bytes / 50 + 4096; // index+filter+footer
        let max_seq = entries.iter().map(|e| e.seq).max().unwrap();
        Ok(Self {
            id,
            file,
            smallest: entries.first().unwrap().key,
            largest: entries.last().unwrap().key,
            entries: Arc::new(entries),
            bytes,
            filter: BloomFilter { words, probes, bits },
            block_bytes,
            max_seq,
        })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn overlaps(&self, min: Key, max: Key) -> bool {
        self.smallest <= max && min <= self.largest
    }

    /// Binary-search lookup. Returns the entry and the data-block index
    /// it lives in (for cache/IO accounting).
    pub fn get(&self, key: Key) -> Option<(Entry, usize)> {
        match self.entries.binary_search_by(|e| e.key.cmp(&key)) {
            Ok(idx) => Some((self.entries[idx], self.block_of(idx))),
            Err(_) => None,
        }
    }

    /// Index of the first entry >= key (iterator seek).
    pub fn lower_bound(&self, key: Key) -> usize {
        self.entries.partition_point(|e| e.key < key)
    }

    /// Data-block index of entry `idx` (fixed entries/block derived from
    /// the average encoded length).
    pub fn block_of(&self, idx: usize) -> usize {
        let avg = (self.bytes / self.entries.len().max(1) as u64).max(1);
        let per_block = (self.block_bytes / avg).max(1) as usize;
        idx / per_block
    }

    pub fn block_count(&self) -> usize {
        self.block_of(self.entries.len().saturating_sub(1)) + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsm::entry::ValueDesc;

    fn build(keys: &[Key]) -> Sst {
        let entries: Vec<Entry> = keys
            .iter()
            .map(|&k| Entry::new(k, k + 1, ValueDesc::new(k, 4096)))
            .collect();
        Sst::build(1, 0, entries, &BloomBuilder::rust(), 7, 1024, 32 * 1024).unwrap()
    }

    #[test]
    fn build_sets_bounds() {
        let s = build(&[3, 7, 11]);
        assert_eq!((s.smallest, s.largest), (3, 11));
        assert_eq!(s.len(), 3);
        assert!(s.bytes > 3 * 4096);
    }

    #[test]
    fn get_hits_and_misses() {
        let s = build(&[1, 5, 9]);
        assert_eq!(s.get(5).unwrap().0.val, ValueDesc::new(5, 4096));
        assert!(s.get(4).is_none());
    }

    #[test]
    fn bloom_has_no_false_negatives() {
        let keys: Vec<Key> = (0..200).map(|i| i * 17).collect();
        let s = build(&keys);
        for &k in &keys {
            assert!(s.filter.may_contain(k));
        }
    }

    #[test]
    fn overlap_logic() {
        let s = build(&[10, 20]);
        assert!(s.overlaps(5, 10));
        assert!(s.overlaps(15, 16));
        assert!(!s.overlaps(21, 30));
        assert!(!s.overlaps(0, 9));
    }

    #[test]
    fn lower_bound_seek() {
        let s = build(&[10, 20, 30]);
        assert_eq!(s.lower_bound(5), 0);
        assert_eq!(s.lower_bound(20), 1);
        assert_eq!(s.lower_bound(25), 2);
        assert_eq!(s.lower_bound(31), 3);
    }

    #[test]
    fn blocks_partition_entries() {
        let s = build(&(0..100).collect::<Vec<_>>());
        assert!(s.block_count() >= 10); // ~8 entries of 4KB per 32KB block
        assert_eq!(s.block_of(0), 0);
        assert!(s.block_of(99) >= s.block_of(50));
    }

    #[test]
    fn compressed_sst_shrinks_and_repacks_blocks() {
        let entries: Vec<Entry> = (0..100)
            .map(|k| Entry::new(k, k + 1, ValueDesc::new(k, 4096)))
            .collect();
        let plain = Sst::build_with_codec(
            1,
            0,
            entries.clone(),
            &BloomBuilder::rust(),
            7,
            1024,
            32 * 1024,
            Compression::None,
        )
        .unwrap();
        let packed = Sst::build_with_codec(
            1,
            0,
            entries.clone(),
            &BloomBuilder::rust(),
            7,
            1024,
            32 * 1024,
            Compression::LzLike { ratio_pct: 50 },
        )
        .unwrap();
        assert!(packed.bytes < plain.bytes);
        // fewer on-disk blocks cover the same entries
        assert!(packed.block_count() < plain.block_count());
        // ratio 100 is bit-identical to the uncompressed build
        let ident = Sst::build_with_codec(
            1,
            0,
            entries,
            &BloomBuilder::rust(),
            7,
            1024,
            32 * 1024,
            Compression::LzLike { ratio_pct: 100 },
        )
        .unwrap();
        assert_eq!(ident.bytes, plain.bytes);
        assert_eq!(ident.block_count(), plain.block_count());
    }

    #[test]
    #[should_panic]
    fn empty_sst_panics() {
        Sst::build(1, 0, vec![], &BloomBuilder::rust(), 7, 64, 1024).unwrap();
    }
}
