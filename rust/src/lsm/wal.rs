//! Write-ahead log bookkeeping. Bytes are charged to the device through
//! `SsdDevice::wal_append` (page-cache semantics, sync=false as in the
//! paper's db_bench runs); segments retain typed entries plus their
//! cumulative stream offsets, so crash recovery can cut the log at the
//! device's durable watermark and replay exactly the records that
//! reached flash before the power loss.

use super::entry::{Entry, Seq};

#[derive(Clone, Debug, Default)]
pub struct WalSegment {
    pub entries: Vec<Entry>,
    /// Cumulative stream offset (bytes since WAL creation) at the END of
    /// each record; parallel to `entries`. Monotone across segments.
    ends: Vec<u64>,
    pub bytes: u64,
    pub max_seq: Seq,
}

#[derive(Clone, Debug, Default)]
pub struct Wal {
    /// Sealed segments not yet released by a flush.
    segments: Vec<WalSegment>,
    current: WalSegment,
    pub total_appended: u64,
}

impl Wal {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one record; returns its encoded size (charged by caller).
    pub fn append(&mut self, e: Entry) -> u64 {
        // WAL record: 12 B header + key + seq + value payload.
        let sz = 12 + e.encoded_len();
        self.total_appended += sz;
        self.current.entries.push(e);
        self.current.ends.push(self.total_appended);
        self.current.bytes += sz;
        self.current.max_seq = self.current.max_seq.max(e.seq);
        sz
    }

    /// Seal the current segment at a memtable rotation.
    pub fn seal(&mut self) {
        if !self.current.entries.is_empty() {
            self.segments.push(std::mem::take(&mut self.current));
        }
    }

    /// Release sealed segments made durable by a flush up to `seq`.
    pub fn release_upto(&mut self, seq: Seq) -> u64 {
        let mut freed = 0;
        self.segments.retain(|s| {
            if s.max_seq <= seq {
                freed += s.bytes;
                false
            } else {
                true
            }
        });
        freed
    }

    /// Entries that would be replayed after a crash (sealed + current).
    pub fn replay(&self) -> Vec<Entry> {
        let mut out: Vec<Entry> = Vec::new();
        for s in &self.segments {
            out.extend_from_slice(&s.entries);
        }
        out.extend_from_slice(&self.current.entries);
        out
    }

    /// Records whose bytes had reached the device by stream offset
    /// `watermark` — the crash durability cut: with sync=false, the tail
    /// still sitting in the host page cache is lost at power loss
    /// (`SsdDevice::wal_durable_watermark` reports the cut).
    pub fn durable_entries(&self, watermark: u64) -> Vec<Entry> {
        let mut out: Vec<Entry> = Vec::new();
        for s in self.segments.iter().chain(std::iter::once(&self.current)) {
            for (e, &end) in s.entries.iter().zip(&s.ends) {
                if end <= watermark {
                    out.push(*e);
                }
            }
        }
        out
    }

    /// CDC tailing cursor: every live record with `seq > wm`, in append
    /// order. Segments wholly at or below the watermark are skipped, so
    /// a caught-up shipper pays nothing per poll. Records released by a
    /// flush before being tailed are gone — the shipper must capture
    /// synchronously with each op (it does; see `repl::ReplicatedDb`).
    pub fn entries_after(&self, wm: Seq) -> Vec<Entry> {
        let mut out: Vec<Entry> = Vec::new();
        for s in self.segments.iter().chain(std::iter::once(&self.current)) {
            if s.max_seq <= wm && !s.entries.is_empty() {
                continue;
            }
            out.extend(s.entries.iter().filter(|e| e.seq > wm).copied());
        }
        out
    }

    pub fn live_bytes(&self) -> u64 {
        self.segments.iter().map(|s| s.bytes).sum::<u64>() + self.current.bytes
    }

    pub fn segment_count(&self) -> usize {
        self.segments.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsm::entry::ValueDesc;

    fn e(k: u32, s: Seq) -> Entry {
        Entry::new(k, s, ValueDesc::new(0, 64))
    }

    #[test]
    fn append_sizes() {
        let mut w = Wal::new();
        let sz = w.append(e(1, 1));
        assert_eq!(sz, 12 + 16 + 64);
        assert_eq!(w.total_appended, sz);
    }

    #[test]
    fn seal_and_release() {
        let mut w = Wal::new();
        w.append(e(1, 1));
        w.append(e(2, 2));
        w.seal();
        w.append(e(3, 3));
        assert_eq!(w.segment_count(), 1);
        let freed = w.release_upto(2);
        assert!(freed > 0);
        assert_eq!(w.segment_count(), 0);
        // unsealed entries survive
        assert_eq!(w.replay().len(), 1);
    }

    #[test]
    fn release_respects_seq() {
        let mut w = Wal::new();
        w.append(e(1, 5));
        w.seal();
        w.append(e(2, 9));
        w.seal();
        w.release_upto(5);
        assert_eq!(w.segment_count(), 1);
    }

    #[test]
    fn replay_order_preserved() {
        let mut w = Wal::new();
        for s in 1..=5 {
            w.append(e(s, s));
            if s % 2 == 0 {
                w.seal();
            }
        }
        let seqs: Vec<Seq> = w.replay().iter().map(|x| x.seq).collect();
        assert_eq!(seqs, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn durable_cut_respects_watermark() {
        let mut w = Wal::new();
        let sz = w.append(e(1, 1));
        w.append(e(2, 2));
        w.seal();
        w.append(e(3, 3));
        // only the first record's bytes reached the device
        let durable = w.durable_entries(sz);
        assert_eq!(durable.len(), 1);
        assert_eq!(durable[0].seq, 1);
        // everything durable once the full stream is written back
        assert_eq!(w.durable_entries(w.total_appended).len(), 3);
        // mid-record watermarks exclude the torn record
        assert_eq!(w.durable_entries(sz + 1).len(), 1);
    }

    #[test]
    fn entries_after_tails_from_watermark() {
        let mut w = Wal::new();
        for s in 1..=6 {
            w.append(e(s, s));
            if s % 2 == 0 {
                w.seal();
            }
        }
        let seqs: Vec<Seq> = w.entries_after(3).iter().map(|x| x.seq).collect();
        assert_eq!(seqs, vec![4, 5, 6]);
        assert!(w.entries_after(6).is_empty());
        assert_eq!(w.entries_after(0).len(), 6);
        // released segments no longer appear in the tail
        w.release_upto(2);
        assert_eq!(w.entries_after(0).len(), 4);
    }

    #[test]
    fn durable_cut_survives_release() {
        let mut w = Wal::new();
        w.append(e(1, 1));
        w.seal();
        w.append(e(2, 2));
        let total = w.total_appended;
        w.release_upto(1); // flushed: segment gone, offsets still global
        assert_eq!(w.durable_entries(total).len(), 1);
        assert_eq!(w.durable_entries(total)[0].seq, 2);
    }
}
