//! RocksDB's write-stall / slowdown condition state machine and its
//! bookkeeping (stall intervals feed Figs 4/5; slowdown instance counts
//! reproduce §III's 258/433 numbers).
//!
//! Three trigger families (SILK/ADOC taxonomy quoted by the paper §II-A):
//!  1. flush-based (memtable exhaustion),
//!  2. L0->L1 serialization (L0 file count),
//!  3. pending compaction bytes.

use crate::sim::Nanos;

use super::options::LsmOptions;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StallReason {
    MemtableLimit,
    L0Files,
    PendingBytes,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WriteCondition {
    Normal,
    /// Slowdown region: writes proceed but are throttled when the
    /// slowdown feature is enabled.
    Delayed(StallReason),
    /// Hard stop: writes block until background work clears the trigger.
    Stopped(StallReason),
}

impl WriteCondition {
    pub fn is_stopped(&self) -> bool {
        matches!(self, WriteCondition::Stopped(_))
    }

    pub fn is_delayed(&self) -> bool {
        matches!(self, WriteCondition::Delayed(_))
    }
}

/// Evaluate the condition from the raw signals (the same three the
/// paper's Detector polls: L0 count, memtable state, pending bytes).
pub fn evaluate(
    l0_files: usize,
    imm_count: usize,
    memtable_full: bool,
    pending_bytes: u64,
    opts: &LsmOptions,
) -> WriteCondition {
    // stops (checked first)
    if imm_count + 1 >= opts.max_write_buffer_number && memtable_full {
        return WriteCondition::Stopped(StallReason::MemtableLimit);
    }
    if l0_files >= opts.l0_stop_trigger {
        return WriteCondition::Stopped(StallReason::L0Files);
    }
    if pending_bytes >= opts.hard_pending_compaction_bytes {
        return WriteCondition::Stopped(StallReason::PendingBytes);
    }
    // slowdowns. Memtable pressure only arms a slowdown when there are
    // at least 3 write buffers (RocksDB: `max_write_buffer_number > 3`
    // guards the memtable delay trigger); with the default 2, a pending
    // flush is normal operation and only a full pair stops writes.
    if opts.max_write_buffer_number >= 3
        && imm_count + 2 >= opts.max_write_buffer_number
    {
        return WriteCondition::Delayed(StallReason::MemtableLimit);
    }
    if l0_files >= opts.l0_slowdown_trigger {
        return WriteCondition::Delayed(StallReason::L0Files);
    }
    if pending_bytes >= opts.soft_pending_compaction_bytes {
        return WriteCondition::Delayed(StallReason::PendingBytes);
    }
    WriteCondition::Normal
}

/// Interval + event accounting.
#[derive(Clone, Debug, Default)]
pub struct StallStats {
    /// Closed [start, end) intervals during which writes were stopped.
    pub stall_intervals: Vec<(Nanos, Nanos)>,
    /// Transitions into the delayed state ("slowdown instances", §III-A).
    pub slowdown_events: u64,
    /// Transitions into the stopped state.
    pub stop_events: u64,
    pub stopped_ns_total: Nanos,
    pub delayed_ns_total: Nanos,
    in_delay: bool,
}

impl StallStats {
    pub fn record_stop(&mut self, start: Nanos, end: Nanos) {
        if end > start {
            self.stop_events += 1;
            self.stopped_ns_total += end - start;
            self.stall_intervals.push((start, end));
        }
    }

    /// Record a throttled write; counts an "instance" on the transition
    /// into the delayed state, like RocksDB's stall counters.
    pub fn record_delay(&mut self, sleep: Nanos) {
        if !self.in_delay {
            self.in_delay = true;
            self.slowdown_events += 1;
        }
        self.delayed_ns_total += sleep;
    }

    pub fn clear_delay(&mut self) {
        self.in_delay = false;
    }

    /// Was virtual second `sec` inside any stop interval? (Fig 4's green
    /// boxes / Fig 5's CDF filter.)
    pub fn second_in_stall(&self, sec: usize) -> bool {
        let start = sec as Nanos * crate::sim::NS_PER_SEC;
        let end = start + crate::sim::NS_PER_SEC;
        self.stall_intervals
            .iter()
            .any(|&(s, e)| s < end && start < e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> LsmOptions {
        LsmOptions::default()
    }

    #[test]
    fn normal_when_quiet() {
        assert_eq!(evaluate(0, 0, false, 0, &opts()), WriteCondition::Normal);
    }

    #[test]
    fn memtable_stop_requires_full_active() {
        let o = opts(); // max_write_buffer_number = 2
        assert_eq!(
            evaluate(0, 1, true, 0, &o),
            WriteCondition::Stopped(StallReason::MemtableLimit)
        );
        // with only 2 buffers, a pending flush alone is NOT a slowdown
        assert_eq!(evaluate(0, 1, false, 0, &o), WriteCondition::Normal);
        // with >= 3 buffers the delay trigger arms
        let mut o3 = opts();
        o3.max_write_buffer_number = 4;
        assert_eq!(
            evaluate(0, 2, false, 0, &o3),
            WriteCondition::Delayed(StallReason::MemtableLimit)
        );
    }

    #[test]
    fn l0_thresholds() {
        let o = opts();
        assert!(evaluate(20, 0, false, 0, &o).is_delayed());
        assert!(evaluate(36, 0, false, 0, &o).is_stopped());
        assert_eq!(evaluate(19, 0, false, 0, &o), WriteCondition::Normal);
    }

    #[test]
    fn pending_bytes_thresholds() {
        let o = opts();
        assert!(evaluate(0, 0, false, o.soft_pending_compaction_bytes, &o).is_delayed());
        assert!(evaluate(0, 0, false, o.hard_pending_compaction_bytes, &o).is_stopped());
    }

    #[test]
    fn stop_takes_priority_over_delay() {
        let o = opts();
        let c = evaluate(36, 1, false, o.soft_pending_compaction_bytes, &o);
        assert!(c.is_stopped());
    }

    #[test]
    fn stats_transitions() {
        let mut s = StallStats::default();
        s.record_delay(100);
        s.record_delay(100);
        s.clear_delay();
        s.record_delay(100);
        assert_eq!(s.slowdown_events, 2);
        assert_eq!(s.delayed_ns_total, 300);
        s.record_stop(10, 20);
        s.record_stop(30, 30); // empty: ignored
        assert_eq!(s.stop_events, 1);
        assert_eq!(s.stopped_ns_total, 10);
    }

    #[test]
    fn second_in_stall_overlap() {
        let mut s = StallStats::default();
        let sec = crate::sim::NS_PER_SEC;
        s.record_stop(sec + 100, 3 * sec);
        assert!(!s.second_in_stall(0));
        assert!(s.second_in_stall(1));
        assert!(s.second_in_stall(2));
        assert!(!s.second_in_stall(3));
    }
}
