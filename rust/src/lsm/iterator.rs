//! Merging iterators over the Main-LSM (memtable + immutables + L0 files
//! + one cursor per deeper level). Newest-wins dedup by source priority;
//! tombstones are skipped for user-visible scans.
//!
//! Block touches are accumulated in `blocks_touched` so the DB can charge
//! cache lookups / device reads per Next() — Table V's read-amplification
//! difference between Main-LSM and Dev-LSM iterators comes from exactly
//! this accounting.

use std::sync::Arc;

use super::entry::{Entry, Key};
use super::sst::Sst;

/// One sorted input source. Priority = position in the source list
/// (lower index == newer data wins ties).
enum Source {
    /// Materialized sorted run (memtable/immutable snapshot).
    Run(Vec<Entry>),
    /// A single SST.
    Table(Arc<Sst>),
    /// A level >= 1: disjoint tables sorted by key.
    Level(Vec<Arc<Sst>>),
}

struct Cursor {
    src: Source,
    /// entry index within the current table / run
    idx: usize,
    /// table index (Level sources)
    tbl: usize,
}

impl Cursor {
    fn seek(&mut self, key: Key) {
        match &self.src {
            Source::Run(v) => {
                self.idx = v.partition_point(|e| e.key < key);
            }
            Source::Table(t) => {
                self.idx = t.lower_bound(key);
            }
            Source::Level(tables) => {
                self.tbl = tables.partition_point(|t| t.largest < key);
                self.idx = match tables.get(self.tbl) {
                    Some(t) => t.lower_bound(key),
                    None => 0,
                };
            }
        }
    }

    fn peek(&self) -> Option<Entry> {
        match &self.src {
            Source::Run(v) => v.get(self.idx).copied(),
            Source::Table(t) => t.entries.get(self.idx).copied(),
            Source::Level(tables) => {
                let t = tables.get(self.tbl)?;
                t.entries.get(self.idx).copied()
            }
        }
    }

    /// Advance; push any (sst_id, block) touched into `blocks`.
    fn advance(&mut self, blocks: &mut Vec<(u64, usize)>) {
        match &self.src {
            Source::Run(_) => self.idx += 1,
            Source::Table(t) => {
                blocks.push((t.id, t.block_of(self.idx)));
                self.idx += 1;
            }
            Source::Level(tables) => {
                if let Some(t) = tables.get(self.tbl) {
                    blocks.push((t.id, t.block_of(self.idx)));
                    self.idx += 1;
                    if self.idx >= t.entries.len() {
                        self.tbl += 1;
                        self.idx = 0;
                    }
                }
            }
        }
    }
}

pub struct LsmIterator {
    sources: Vec<Cursor>,
    /// (sst_id, block_idx) touched since last drain — caller charges I/O.
    pub blocks_touched: Vec<(u64, usize)>,
    /// include tombstones in output (internal scans want them)
    pub keep_tombstones: bool,
}

impl LsmIterator {
    /// Build from snapshot pieces, newest first:
    /// memtable run, imm runs (newest first), L0 tables (newest first),
    /// then levels 1..N.
    pub fn new(
        mem: Vec<Entry>,
        imms: Vec<Vec<Entry>>,
        l0: Vec<Arc<Sst>>,
        levels: Vec<Vec<Arc<Sst>>>,
    ) -> Self {
        let mut sources = Vec::new();
        sources.push(Cursor { src: Source::Run(mem), idx: 0, tbl: 0 });
        for run in imms {
            sources.push(Cursor { src: Source::Run(run), idx: 0, tbl: 0 });
        }
        for t in l0 {
            sources.push(Cursor { src: Source::Table(t), idx: 0, tbl: 0 });
        }
        for lvl in levels {
            sources.push(Cursor { src: Source::Level(lvl), idx: 0, tbl: 0 });
        }
        Self {
            sources,
            blocks_touched: Vec::new(),
            keep_tombstones: false,
        }
    }

    pub fn seek(&mut self, key: Key) {
        for s in &mut self.sources {
            s.seek(key);
        }
    }

    /// Next user-visible entry in ascending key order (newest version per
    /// key; tombstoned keys skipped unless `keep_tombstones`).
    pub fn next(&mut self) -> Option<Entry> {
        loop {
            // find the smallest key among sources; lowest source index
            // wins ties (it is the newest).
            let mut best: Option<(Key, usize)> = None;
            for (i, s) in self.sources.iter().enumerate() {
                if let Some(e) = s.peek() {
                    match best {
                        None => best = Some((e.key, i)),
                        Some((bk, _)) if e.key < bk => best = Some((e.key, i)),
                        _ => {}
                    }
                }
            }
            let (key, winner) = best?;
            let entry = self.sources[winner].peek().unwrap();
            // advance every source sitting on this key (skips older dups)
            for s in &mut self.sources {
                while let Some(e) = s.peek() {
                    if e.key == key {
                        s.advance(&mut self.blocks_touched);
                    } else {
                        break;
                    }
                }
            }
            if entry.val.is_tombstone() && !self.keep_tombstones {
                continue;
            }
            return Some(entry);
        }
    }

    pub fn drain_blocks(&mut self) -> Vec<(u64, usize)> {
        std::mem::take(&mut self.blocks_touched)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsm::entry::ValueDesc;
    use crate::runtime::bloom::BloomBuilder;

    fn e(k: Key, s: u32) -> Entry {
        Entry::new(k, s, ValueDesc::new(s, 64))
    }

    fn sst(id: u64, entries: Vec<Entry>) -> Arc<Sst> {
        Arc::new(
            Sst::build(id, id, entries, &BloomBuilder::rust(), 7, 256, 32 * 1024)
                .unwrap(),
        )
    }

    #[test]
    fn merges_across_sources_newest_wins() {
        let mem = vec![e(2, 100)];
        let l0 = vec![sst(1, vec![e(1, 50), e(2, 50)])];
        let levels = vec![vec![sst(2, vec![e(1, 10), e(3, 10)])]];
        let mut it = LsmIterator::new(mem, vec![], l0, levels);
        it.seek(0);
        let got: Vec<(Key, u32)> =
            std::iter::from_fn(|| it.next()).map(|x| (x.key, x.seq)).collect();
        assert_eq!(got, vec![(1, 50), (2, 100), (3, 10)]);
    }

    #[test]
    fn tombstones_hide_older_versions() {
        let mem = vec![Entry::new(1, 9, ValueDesc::TOMBSTONE)];
        let l0 = vec![sst(1, vec![e(1, 5), e(2, 5)])];
        let mut it = LsmIterator::new(mem, vec![], l0, vec![]);
        it.seek(0);
        let keys: Vec<Key> = std::iter::from_fn(|| it.next()).map(|x| x.key).collect();
        assert_eq!(keys, vec![2]);
    }

    #[test]
    fn seek_starts_midway() {
        let l0 = vec![sst(1, (0..20).map(|k| e(k, 1)).collect())];
        let mut it = LsmIterator::new(vec![], vec![], l0, vec![]);
        it.seek(15);
        assert_eq!(it.next().unwrap().key, 15);
    }

    #[test]
    fn level_cursor_crosses_files() {
        let levels = vec![vec![
            sst(1, vec![e(1, 1), e(2, 1)]),
            sst(2, vec![e(10, 1), e(11, 1)]),
        ]];
        let mut it = LsmIterator::new(vec![], vec![], vec![], levels);
        it.seek(0);
        let keys: Vec<Key> = std::iter::from_fn(|| it.next()).map(|x| x.key).collect();
        assert_eq!(keys, vec![1, 2, 10, 11]);
    }

    #[test]
    fn blocks_are_tracked_for_sst_reads() {
        let l0 = vec![sst(1, (0..50).map(|k| e(k, 1)).collect())];
        let mut it = LsmIterator::new(vec![], vec![], l0, vec![]);
        it.seek(0);
        for _ in 0..50 {
            it.next();
        }
        let blocks = it.drain_blocks();
        assert_eq!(blocks.len(), 50);
        assert!(blocks.iter().all(|&(id, _)| id == 1));
    }

    #[test]
    fn imm_priority_between_mem_and_l0() {
        let mem = vec![];
        let imms = vec![vec![e(1, 80)]];
        let l0 = vec![sst(1, vec![e(1, 50)])];
        let mut it = LsmIterator::new(mem, imms, l0, vec![]);
        it.seek(0);
        assert_eq!(it.next().unwrap().seq, 80);
    }
}
