//! The Main-LSM merging cursor: seekable, reversible, k-way merge over
//! the memtable/immutable runs, L0 files and one cursor per deeper
//! level, with *sequence-number visibility filtering* — entries newer
//! than `visible_seq` are skipped, which is how snapshot reads see a
//! frozen history instead of eagerly-deduped "latest" state.
//!
//! Tombstones are filtered here only when `keep_tombstones` is false;
//! the engine-level dual-interface cursor keeps them (a device-buffer
//! copy may supersede or be superseded by a host tombstone — that
//! decision needs the tombstone to surface).
//!
//! Block touches accumulate in `blocks_touched` so the owner can charge
//! cache lookups / device reads per movement — Table V's
//! read-amplification difference between Main-LSM and Dev-LSM cursors
//! comes from exactly this accounting.

use std::sync::Arc;

use super::entry::{Entry, Key, Seq, MAX_USER_KEY};
use super::sst::Sst;

/// One sorted input source.
enum Source {
    /// Materialized sorted run (memtable/immutable snapshot).
    Run(Arc<Vec<Entry>>),
    /// A single SST.
    Table(Arc<Sst>),
    /// A level >= 1: disjoint tables sorted by key.
    Level(Vec<Arc<Sst>>),
}

/// A positional cursor over one source. Invariant: when `valid`,
/// `(tbl, idx)` addresses an entry whose seq passed the visibility
/// filter applied by the last movement.
struct Cursor {
    src: Source,
    tbl: usize,
    idx: usize,
    valid: bool,
}

impl Cursor {
    fn new(src: Source) -> Self {
        Self { src, tbl: 0, idx: 0, valid: false }
    }

    fn tables(&self) -> usize {
        match &self.src {
            Source::Run(_) | Source::Table(_) => 1,
            Source::Level(v) => v.len(),
        }
    }

    fn seg(&self, tbl: usize) -> &[Entry] {
        match &self.src {
            Source::Run(v) => v.as_slice(),
            Source::Table(t) => t.entries.as_slice(),
            Source::Level(v) => v[tbl].entries.as_slice(),
        }
    }

    /// Record a block touch for the entry at `(tbl, idx)` (SST sources
    /// only; in-memory runs are free).
    fn charge(&self, tbl: usize, idx: usize, blocks: &mut Vec<(u64, usize)>) {
        match &self.src {
            Source::Run(_) => {}
            Source::Table(t) => blocks.push((t.id, t.block_of(idx))),
            Source::Level(v) => {
                let t = &v[tbl];
                blocks.push((t.id, t.block_of(idx)));
            }
        }
    }

    fn peek(&self) -> Option<Entry> {
        if !self.valid {
            return None;
        }
        self.seg(self.tbl).get(self.idx).copied()
    }

    /// Raw forward step across table boundaries.
    fn raw_next(&mut self) -> bool {
        self.idx += 1;
        while self.tbl < self.tables() && self.idx >= self.seg(self.tbl).len() {
            self.tbl += 1;
            self.idx = 0;
        }
        self.valid = self.tbl < self.tables();
        self.valid
    }

    /// Raw backward step across table boundaries.
    fn raw_prev(&mut self) -> bool {
        loop {
            if self.idx > 0 {
                self.idx -= 1;
                self.valid = true;
                return true;
            }
            if self.tbl == 0 {
                self.valid = false;
                return false;
            }
            self.tbl -= 1;
            self.idx = self.seg(self.tbl).len();
            // loop decrements into the new table (skips it when empty)
        }
    }

    /// Skip entries invisible to the snapshot (seq > `vis`), forward.
    fn norm_fwd(&mut self, vis: Seq, blocks: &mut Vec<(u64, usize)>) {
        while let Some(e) = self.peek() {
            if e.seq <= vis {
                return;
            }
            self.charge(self.tbl, self.idx, blocks);
            if !self.raw_next() {
                return;
            }
        }
    }

    fn norm_bwd(&mut self, vis: Seq, blocks: &mut Vec<(u64, usize)>) {
        while let Some(e) = self.peek() {
            if e.seq <= vis {
                return;
            }
            self.charge(self.tbl, self.idx, blocks);
            if !self.raw_prev() {
                return;
            }
        }
    }

    /// Position at the first visible entry with key >= `key`.
    fn seek_fwd(&mut self, key: Key, vis: Seq, blocks: &mut Vec<(u64, usize)>) {
        match &self.src {
            Source::Run(v) => {
                self.tbl = 0;
                self.idx = v.partition_point(|e| e.key < key);
                self.valid = self.idx < v.len();
            }
            Source::Table(t) => {
                self.tbl = 0;
                self.idx = t.lower_bound(key);
                self.valid = self.idx < t.entries.len();
            }
            Source::Level(tables) => {
                self.tbl = tables.partition_point(|t| t.largest < key);
                if self.tbl < tables.len() {
                    // this table's largest >= key, so lower_bound is in
                    // range
                    self.idx = tables[self.tbl].lower_bound(key);
                    self.valid = true;
                } else {
                    self.idx = 0;
                    self.valid = false;
                }
            }
        }
        if self.valid {
            self.norm_fwd(vis, blocks);
        }
    }

    /// Position at the last visible entry with key <= `key`.
    fn seek_bwd(&mut self, key: Key, vis: Seq, blocks: &mut Vec<(u64, usize)>) {
        match &self.src {
            Source::Run(v) => {
                self.tbl = 0;
                let pp = v.partition_point(|e| e.key <= key);
                self.valid = pp > 0;
                self.idx = pp.saturating_sub(1);
            }
            Source::Table(t) => {
                self.tbl = 0;
                let pp = t.entries.partition_point(|e| e.key <= key);
                self.valid = pp > 0;
                self.idx = pp.saturating_sub(1);
            }
            Source::Level(tables) => {
                // last table whose smallest key is <= `key`
                let tb = tables.partition_point(|t| t.smallest <= key);
                if tb == 0 {
                    self.tbl = 0;
                    self.idx = 0;
                    self.valid = false;
                } else {
                    self.tbl = tb - 1;
                    let ents = &tables[self.tbl].entries;
                    let pp = ents.partition_point(|e| e.key <= key);
                    // smallest <= key implies pp >= 1
                    self.idx = pp.saturating_sub(1);
                    self.valid = pp > 0;
                }
            }
        }
        if self.valid {
            self.norm_bwd(vis, blocks);
        }
    }

    /// Consume every entry with key <= `key` (forward direction), then
    /// re-apply the visibility filter.
    fn skip_past_fwd(&mut self, key: Key, vis: Seq, blocks: &mut Vec<(u64, usize)>) {
        while let Some(e) = self.peek() {
            if e.key > key {
                break;
            }
            self.charge(self.tbl, self.idx, blocks);
            if !self.raw_next() {
                return;
            }
        }
        self.norm_fwd(vis, blocks);
    }

    /// Consume every entry with key >= `key` (backward direction).
    fn skip_past_bwd(&mut self, key: Key, vis: Seq, blocks: &mut Vec<(u64, usize)>) {
        while let Some(e) = self.peek() {
            if e.key < key {
                break;
            }
            self.charge(self.tbl, self.idx, blocks);
            if !self.raw_prev() {
                return;
            }
        }
        self.norm_bwd(vis, blocks);
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Dir {
    Forward,
    Backward,
}

pub struct LsmIterator {
    sources: Vec<Cursor>,
    /// (sst_id, block_idx) touched since last drain — caller charges I/O.
    pub blocks_touched: Vec<(u64, usize)>,
    /// include tombstones in output (the engine-level merge wants them)
    pub keep_tombstones: bool,
    visible_seq: Seq,
    dir: Dir,
    current: Option<Entry>,
}

impl LsmIterator {
    /// Build from snapshot pieces, newest first: memtable run, imm runs
    /// (newest first), L0 tables (newest first), then levels 1..N.
    pub fn new(
        mem: Vec<Entry>,
        imms: Vec<Vec<Entry>>,
        l0: Vec<Arc<Sst>>,
        levels: Vec<Vec<Arc<Sst>>>,
    ) -> Self {
        let mut runs = Vec::with_capacity(1 + imms.len());
        runs.push(Arc::new(mem));
        runs.extend(imms.into_iter().map(Arc::new));
        Self::from_runs(runs, l0, levels)
    }

    /// Build from refcount-shared runs (the snapshot-pinned path).
    pub fn from_runs(
        runs: Vec<Arc<Vec<Entry>>>,
        l0: Vec<Arc<Sst>>,
        levels: Vec<Vec<Arc<Sst>>>,
    ) -> Self {
        let mut sources = Vec::with_capacity(runs.len() + l0.len() + levels.len());
        for r in runs {
            sources.push(Cursor::new(Source::Run(r)));
        }
        for t in l0 {
            sources.push(Cursor::new(Source::Table(t)));
        }
        for lvl in levels {
            sources.push(Cursor::new(Source::Level(lvl)));
        }
        Self {
            sources,
            blocks_touched: Vec::new(),
            keep_tombstones: false,
            visible_seq: Seq::MAX,
            dir: Dir::Forward,
            current: None,
        }
    }

    /// Hide entries with seq beyond this bound (snapshot visibility).
    pub fn with_visible_seq(mut self, seq: Seq) -> Self {
        self.visible_seq = seq;
        self
    }

    pub fn with_tombstones(mut self, keep: bool) -> Self {
        self.keep_tombstones = keep;
        self
    }

    pub fn valid(&self) -> bool {
        self.current.is_some()
    }

    /// Current entry without advancing.
    pub fn entry(&self) -> Option<Entry> {
        self.current
    }

    /// Position at the first visible entry with key >= `key`.
    pub fn seek(&mut self, key: Key) {
        self.dir = Dir::Forward;
        let vis = self.visible_seq;
        for c in &mut self.sources {
            c.seek_fwd(key, vis, &mut self.blocks_touched);
        }
        self.settle_fwd();
    }

    pub fn seek_to_first(&mut self) {
        self.seek(0);
    }

    /// Position at the last visible entry with key <= `key`.
    pub fn seek_for_prev(&mut self, key: Key) {
        self.dir = Dir::Backward;
        let vis = self.visible_seq;
        for c in &mut self.sources {
            c.seek_bwd(key, vis, &mut self.blocks_touched);
        }
        self.settle_bwd();
    }

    pub fn seek_to_last(&mut self) {
        self.seek_for_prev(MAX_USER_KEY);
    }

    /// Winner among source heads: smallest key; equal keys resolve to
    /// the highest (newest) visible sequence number.
    fn pick_fwd(&self) -> Option<Entry> {
        let mut best: Option<Entry> = None;
        for c in &self.sources {
            if let Some(e) = c.peek() {
                best = Some(match best {
                    None => e,
                    Some(b) if e.key < b.key || (e.key == b.key && e.seq > b.seq) => e,
                    Some(b) => b,
                });
            }
        }
        best
    }

    fn pick_bwd(&self) -> Option<Entry> {
        let mut best: Option<Entry> = None;
        for c in &self.sources {
            if let Some(e) = c.peek() {
                best = Some(match best {
                    None => e,
                    Some(b) if e.key > b.key || (e.key == b.key && e.seq > b.seq) => e,
                    Some(b) => b,
                });
            }
        }
        best
    }

    fn settle_fwd(&mut self) {
        loop {
            let Some(e) = self.pick_fwd() else {
                self.current = None;
                return;
            };
            let vis = self.visible_seq;
            for c in &mut self.sources {
                c.skip_past_fwd(e.key, vis, &mut self.blocks_touched);
            }
            if e.val.is_tombstone() && !self.keep_tombstones {
                continue;
            }
            self.current = Some(e);
            return;
        }
    }

    fn settle_bwd(&mut self) {
        loop {
            let Some(e) = self.pick_bwd() else {
                self.current = None;
                return;
            };
            let vis = self.visible_seq;
            for c in &mut self.sources {
                c.skip_past_bwd(e.key, vis, &mut self.blocks_touched);
            }
            if e.val.is_tombstone() && !self.keep_tombstones {
                continue;
            }
            self.current = Some(e);
            return;
        }
    }

    /// Move to the next visible entry (ascending). Direction switches
    /// re-seek every cursor past the current key.
    pub fn step_forward(&mut self) {
        let Some(cur) = self.current else { return };
        if self.dir == Dir::Backward {
            let from = cur.key.saturating_add(1);
            let vis = self.visible_seq;
            for c in &mut self.sources {
                c.seek_fwd(from, vis, &mut self.blocks_touched);
            }
            self.dir = Dir::Forward;
        }
        self.settle_fwd();
    }

    /// Move to the previous visible entry (descending).
    pub fn step_backward(&mut self) {
        let Some(cur) = self.current else { return };
        if self.dir == Dir::Forward {
            if cur.key == 0 {
                self.current = None;
                self.dir = Dir::Backward;
                return;
            }
            let vis = self.visible_seq;
            for c in &mut self.sources {
                c.seek_bwd(cur.key - 1, vis, &mut self.blocks_touched);
            }
            self.dir = Dir::Backward;
        }
        self.settle_bwd();
    }

    /// Streaming accessor: return the current entry and advance
    /// (ascending) — the shape the scan wrapper and tests consume.
    pub fn next(&mut self) -> Option<Entry> {
        let e = self.current?;
        self.step_forward();
        Some(e)
    }

    pub fn drain_blocks(&mut self) -> Vec<(u64, usize)> {
        std::mem::take(&mut self.blocks_touched)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsm::entry::ValueDesc;
    use crate::runtime::bloom::BloomBuilder;

    fn e(k: Key, s: u32) -> Entry {
        Entry::new(k, s, ValueDesc::new(s, 64))
    }

    fn sst(id: u64, entries: Vec<Entry>) -> Arc<Sst> {
        Arc::new(
            Sst::build(id, id, entries, &BloomBuilder::rust(), 7, 256, 32 * 1024)
                .unwrap(),
        )
    }

    #[test]
    fn merges_across_sources_newest_wins() {
        let mem = vec![e(2, 100)];
        let l0 = vec![sst(1, vec![e(1, 50), e(2, 50)])];
        let levels = vec![vec![sst(2, vec![e(1, 10), e(3, 10)])]];
        let mut it = LsmIterator::new(mem, vec![], l0, levels);
        it.seek(0);
        let got: Vec<(Key, u32)> =
            std::iter::from_fn(|| it.next()).map(|x| (x.key, x.seq)).collect();
        assert_eq!(got, vec![(1, 50), (2, 100), (3, 10)]);
    }

    #[test]
    fn tombstones_hide_older_versions() {
        let mem = vec![Entry::new(1, 9, ValueDesc::TOMBSTONE)];
        let l0 = vec![sst(1, vec![e(1, 5), e(2, 5)])];
        let mut it = LsmIterator::new(mem, vec![], l0, vec![]);
        it.seek(0);
        let keys: Vec<Key> = std::iter::from_fn(|| it.next()).map(|x| x.key).collect();
        assert_eq!(keys, vec![2]);
    }

    #[test]
    fn seek_starts_midway() {
        let l0 = vec![sst(1, (0..20).map(|k| e(k, 1)).collect())];
        let mut it = LsmIterator::new(vec![], vec![], l0, vec![]);
        it.seek(15);
        assert_eq!(it.next().unwrap().key, 15);
    }

    #[test]
    fn level_cursor_crosses_files() {
        let levels = vec![vec![
            sst(1, vec![e(1, 1), e(2, 1)]),
            sst(2, vec![e(10, 1), e(11, 1)]),
        ]];
        let mut it = LsmIterator::new(vec![], vec![], vec![], levels);
        it.seek(0);
        let keys: Vec<Key> = std::iter::from_fn(|| it.next()).map(|x| x.key).collect();
        assert_eq!(keys, vec![1, 2, 10, 11]);
    }

    #[test]
    fn blocks_are_tracked_for_sst_reads() {
        let l0 = vec![sst(1, (0..50).map(|k| e(k, 1)).collect())];
        let mut it = LsmIterator::new(vec![], vec![], l0, vec![]);
        it.seek(0);
        for _ in 0..50 {
            it.next();
        }
        let blocks = it.drain_blocks();
        assert_eq!(blocks.len(), 50);
        assert!(blocks.iter().all(|&(id, _)| id == 1));
    }

    #[test]
    fn imm_priority_between_mem_and_l0() {
        let mem = vec![];
        let imms = vec![vec![e(1, 80)]];
        let l0 = vec![sst(1, vec![e(1, 50)])];
        let mut it = LsmIterator::new(mem, imms, l0, vec![]);
        it.seek(0);
        assert_eq!(it.next().unwrap().seq, 80);
    }

    #[test]
    fn reverse_iteration_descends() {
        let mem = vec![e(2, 100)];
        let l0 = vec![sst(1, vec![e(1, 50), e(2, 50), e(5, 50)])];
        let levels = vec![vec![sst(2, vec![e(3, 10), e(9, 10)])]];
        let mut it = LsmIterator::new(mem, vec![], l0, levels);
        it.seek_to_last();
        let mut got = Vec::new();
        while let Some(x) = it.entry() {
            got.push((x.key, x.seq));
            it.step_backward();
        }
        assert_eq!(got, vec![(9, 10), (5, 50), (3, 10), (2, 100), (1, 50)]);
    }

    #[test]
    fn seek_for_prev_lands_on_floor_key() {
        let l0 = vec![sst(1, vec![e(10, 1), e(20, 1), e(30, 1)])];
        let mut it = LsmIterator::new(vec![], vec![], l0, vec![]);
        it.seek_for_prev(25);
        assert_eq!(it.entry().unwrap().key, 20);
        it.seek_for_prev(30);
        assert_eq!(it.entry().unwrap().key, 30);
        it.seek_for_prev(9);
        assert!(!it.valid());
    }

    #[test]
    fn direction_switch_mid_iteration() {
        let l0 = vec![sst(1, (0..10).map(|k| e(k, 1)).collect())];
        let mut it = LsmIterator::new(vec![], vec![], l0, vec![]);
        it.seek(4);
        assert_eq!(it.entry().unwrap().key, 4);
        it.step_forward();
        assert_eq!(it.entry().unwrap().key, 5);
        it.step_backward();
        assert_eq!(it.entry().unwrap().key, 4);
        it.step_backward();
        assert_eq!(it.entry().unwrap().key, 3);
        it.step_forward();
        assert_eq!(it.entry().unwrap().key, 4);
    }

    #[test]
    fn visible_seq_filters_newer_writes() {
        // two versions of key 1 across sources; a snapshot at seq 40
        // must see the older one, and must not see key 3 at all
        let mem = vec![e(1, 90), e(3, 95)];
        let l0 = vec![sst(1, vec![e(1, 30), e(2, 30)])];
        let mut it = LsmIterator::new(mem, vec![], l0, vec![]).with_visible_seq(40);
        it.seek(0);
        let got: Vec<(Key, u32)> =
            std::iter::from_fn(|| it.next()).map(|x| (x.key, x.seq)).collect();
        assert_eq!(got, vec![(1, 30), (2, 30)]);
    }

    #[test]
    fn visible_seq_filters_in_reverse() {
        let mem = vec![e(1, 90), e(3, 95)];
        let l0 = vec![sst(1, vec![e(1, 30), e(2, 30)])];
        let mut it = LsmIterator::new(mem, vec![], l0, vec![]).with_visible_seq(40);
        it.seek_to_last();
        let mut got = Vec::new();
        while let Some(x) = it.entry() {
            got.push((x.key, x.seq));
            it.step_backward();
        }
        assert_eq!(got, vec![(2, 30), (1, 30)]);
    }

    #[test]
    fn kept_tombstones_surface_in_output() {
        let mem = vec![Entry::new(1, 9, ValueDesc::TOMBSTONE)];
        let l0 = vec![sst(1, vec![e(1, 5), e(2, 5)])];
        let mut it =
            LsmIterator::new(mem, vec![], l0, vec![]).with_tombstones(true);
        it.seek(0);
        let first = it.next().unwrap();
        assert_eq!(first.key, 1);
        assert!(first.val.is_tombstone());
        assert_eq!(it.next().unwrap().key, 2);
    }

    #[test]
    fn reverse_tombstones_hide_keys() {
        let mem = vec![Entry::new(2, 9, ValueDesc::TOMBSTONE)];
        let l0 = vec![sst(1, vec![e(1, 5), e(2, 5), e(3, 5)])];
        let mut it = LsmIterator::new(mem, vec![], l0, vec![]);
        it.seek_to_last();
        let mut keys = Vec::new();
        while let Some(x) = it.entry() {
            keys.push(x.key);
            it.step_backward();
        }
        assert_eq!(keys, vec![3, 1]);
    }
}
