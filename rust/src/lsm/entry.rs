//! Core key/value/entry types shared by the host LSM, the device Dev-LSM
//! and the runtime merge contract.
//!
//! Keys are 4-byte (u32) per the paper's db_bench configuration (Table
//! IV: 4 B keys, 4 KB values). `u32::MAX` is reserved as the merge
//! artifact's padding sentinel (runtime::PAD_KEY) and is never a user key.
//!
//! Values are *descriptors* `(seed, len)`: the byte payload is a
//! deterministic stream regenerable from the descriptor
//! (`sim::rng::value_bytes`), so a 4 KB value costs 4 KB in every
//! bandwidth/size model but O(8 B) of host RAM. This is what makes 600
//! virtual seconds of 630 MB/s traffic simulable in-memory; see DESIGN.md.

use crate::sim::rng::value_bytes;

pub type Key = u32;
/// Monotone sequence number assigned by the writing store (u32: the
/// paper's runs are <2^32 operations).
pub type Seq = u32;

/// Largest permitted user key (u32::MAX is the merge pad sentinel).
pub const MAX_USER_KEY: Key = u32::MAX - 1;

/// Length tag marking a tombstone.
const TOMBSTONE_LEN: u32 = u32::MAX;

/// On-flash footprint of a `ValueLoc::Vlog` pointer: 4 B segment +
/// 4 B offset + 4 B length (WiscKey's `<segment, offset, len>` triple).
pub const VLOG_POINTER_BYTES: u64 = 12;

/// Where the value's bytes live. `Inline` is the classic LSM layout
/// (payload travels with the entry through WAL/memtable/SSTs); `Vlog`
/// means the entry carries only a pointer — the payload was appended to
/// the value log and the LSM's footprint shrinks to pointer size.
///
/// The `(seed, len)` descriptor stays in `ValueDesc` either way (values
/// are deterministic streams, so "dereferencing" a pointer is purely a
/// cost-model event: a vlog block read), which keeps snapshots and
/// pinned iterators correct by construction while GC relocates data.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum ValueLoc {
    #[default]
    Inline,
    Vlog { segment: u32, offset: u32 },
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ValueDesc {
    pub seed: u32,
    pub len: u32,
    pub loc: ValueLoc,
}

impl ValueDesc {
    pub const TOMBSTONE: ValueDesc =
        ValueDesc { seed: 0, len: TOMBSTONE_LEN, loc: ValueLoc::Inline };

    pub fn new(seed: u32, len: u32) -> Self {
        assert_ne!(len, TOMBSTONE_LEN, "len reserved for tombstones");
        Self { seed, len, loc: ValueLoc::Inline }
    }

    pub fn is_tombstone(&self) -> bool {
        self.len == TOMBSTONE_LEN
    }

    /// Logical value size in bytes (0 for tombstones).
    pub fn value_len(&self) -> u64 {
        if self.is_tombstone() {
            0
        } else {
            self.len as u64
        }
    }

    /// Bytes this value occupies *in the LSM* (WAL / memtable / SST):
    /// the payload when inline, a fixed-size pointer when separated.
    pub fn stored_len(&self) -> u64 {
        if self.is_tombstone() {
            0
        } else if self.in_vlog() {
            VLOG_POINTER_BYTES
        } else {
            self.len as u64
        }
    }

    pub fn in_vlog(&self) -> bool {
        matches!(self.loc, ValueLoc::Vlog { .. })
    }

    /// The same value with its location stripped — what user-facing
    /// reads return (callers never see vlog pointers).
    pub fn inline(&self) -> ValueDesc {
        ValueDesc { seed: self.seed, len: self.len, loc: ValueLoc::Inline }
    }

    /// The same value relocated into the value log.
    pub fn at_vlog(&self, segment: u32, offset: u32) -> ValueDesc {
        debug_assert!(!self.is_tombstone(), "tombstones are never separated");
        ValueDesc { seed: self.seed, len: self.len, loc: ValueLoc::Vlog { segment, offset } }
    }

    /// Materialize the deterministic payload (tests / verification).
    pub fn materialize(&self) -> Vec<u8> {
        assert!(!self.is_tombstone(), "tombstones carry no payload");
        value_bytes(self.seed, self.len)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Entry {
    pub key: Key,
    pub seq: Seq,
    pub val: ValueDesc,
}

impl Entry {
    pub fn new(key: Key, seq: Seq, val: ValueDesc) -> Self {
        debug_assert!(key <= MAX_USER_KEY, "key {key:#x} collides with pad sentinel");
        Self { key, seq, val }
    }

    /// Logical on-flash footprint: 4 B key + 8 B internal metadata
    /// (seq + type, RocksDB-style) + 4 B length + payload (or a 12 B
    /// vlog pointer when the value is separated).
    pub fn encoded_len(&self) -> u64 {
        16 + self.val.stored_len()
    }

    /// The same entry with its value location stripped (read-boundary
    /// normalization: user-visible results never expose vlog pointers).
    pub fn inline_value(&self) -> Entry {
        Entry { key: self.key, seq: self.seq, val: self.val.inline() }
    }

    /// Ordering used everywhere: by key ascending, then seq *descending*
    /// (newest first) — matches RocksDB's internal key comparator.
    pub fn internal_cmp(&self, other: &Entry) -> std::cmp::Ordering {
        self.key
            .cmp(&other.key)
            .then(other.seq.cmp(&self.seq))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tombstone_flagging() {
        assert!(ValueDesc::TOMBSTONE.is_tombstone());
        assert!(!ValueDesc::new(1, 100).is_tombstone());
        assert_eq!(ValueDesc::TOMBSTONE.value_len(), 0);
    }

    #[test]
    #[should_panic]
    fn reserved_len_panics() {
        ValueDesc::new(0, u32::MAX);
    }

    #[test]
    fn materialize_roundtrip() {
        let v = ValueDesc::new(42, 4096);
        let b = v.materialize();
        assert_eq!(b.len(), 4096);
        assert_eq!(b, v.materialize());
    }

    #[test]
    fn encoded_len_includes_payload() {
        let e = Entry::new(1, 1, ValueDesc::new(0, 4096));
        assert_eq!(e.encoded_len(), 16 + 4096);
        let t = Entry::new(1, 2, ValueDesc::TOMBSTONE);
        assert_eq!(t.encoded_len(), 16);
    }

    #[test]
    fn vlog_pointer_shrinks_footprint() {
        let v = ValueDesc::new(9, 4096).at_vlog(3, 8192);
        assert!(v.in_vlog());
        assert_eq!(v.stored_len(), VLOG_POINTER_BYTES);
        assert_eq!(v.value_len(), 4096, "logical size unchanged");
        let e = Entry::new(1, 1, v);
        assert_eq!(e.encoded_len(), 16 + VLOG_POINTER_BYTES);
        // stripping the location restores equality with the original
        assert_eq!(v.inline(), ValueDesc::new(9, 4096));
        assert_eq!(e.inline_value().val, ValueDesc::new(9, 4096));
    }

    #[test]
    fn internal_cmp_newest_first() {
        let a = Entry::new(5, 10, ValueDesc::new(0, 1));
        let b = Entry::new(5, 20, ValueDesc::new(0, 1));
        let c = Entry::new(6, 1, ValueDesc::new(0, 1));
        assert_eq!(b.internal_cmp(&a), std::cmp::Ordering::Less); // newer first
        assert_eq!(a.internal_cmp(&c), std::cmp::Ordering::Less);
    }
}
