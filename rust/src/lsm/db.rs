//! The Main-LSM engine: RocksDB-shaped put/get/scan over the block
//! interface, with flush + leveled compaction running on modeled
//! background threads and RocksDB's stall/slowdown state machine.
//!
//! All timing is virtual: operations take an explicit issue time `at` and
//! return completion times; background jobs are computed eagerly (inputs
//! pinned at schedule, real merge executed through the MergeEngine) and
//! their *effects* apply when the clock catches up to their end.

use std::collections::{BTreeSet, VecDeque};
use std::sync::{Arc, Weak};

use anyhow::Result;

use crate::engine::{new_block_cache, ScanCounters, SharedBlockCache, Snapshot, SnapshotInner};

use crate::engine::{vlog_cache_key, VLOG_CACHE_NS};
use crate::env::SimEnv;
use crate::runtime::{BloomBuilder, MergeEngine};
use crate::sim::{CpuClass, Nanos, ThreadPool};
use crate::vlog::{
    Vlog, VlogImage, VlogSegment, VlogStats, VLOG_RECORD_HEADER, VLOG_STREAM_OFFSET,
};

use super::compaction::{concat_inputs, run_merge, shape_of};
use super::entry::{Entry, Key, Seq, ValueDesc, ValueLoc};
use super::iterator::LsmIterator;
use super::manifest::{Manifest, ManifestEdit};
use super::memtable::Memtable;
use super::options::LsmOptions;
use super::stall::{evaluate, StallStats, WriteCondition};
use super::version::Version;
use super::wal::Wal;

#[derive(Clone, Copy, Debug, Default)]
pub struct PutResult {
    pub done: Nanos,
    /// time spent blocked in a hard write stall
    pub stalled_ns: Nanos,
    /// slowdown sleep injected into this put
    pub delayed_ns: Nanos,
}

#[derive(Clone, Debug, Default)]
pub struct DbStats {
    pub puts: u64,
    pub deletes: u64,
    /// `write_batch` calls (each may carry many puts/deletes).
    pub batches: u64,
    pub gets: u64,
    pub get_hits: u64,
    pub flush_count: u64,
    pub compaction_count: u64,
    pub bytes_flushed: u64,
    pub bytes_compacted_read: u64,
    pub bytes_compacted_written: u64,
    pub user_bytes_written: u64,
    /// Data-block accesses on the point-read path (cache hit or miss) —
    /// the numerator of blocks-per-get.
    pub block_reads: u64,
    /// Bloom-filter consultations where the key turned out to be absent
    /// from the SST (filter-negative skips + false positives) — the
    /// denominator of the measured false-positive rate.
    pub bloom_negative_probes: u64,
    /// Absent-key consultations the filter answered "maybe" (a wasted
    /// block read each).
    pub bloom_false_positives: u64,
    /// force-released stalls with no background job to wait for (should
    /// stay 0; counted instead of deadlocking)
    pub stall_anomalies: u64,
}

impl DbStats {
    /// Total write amplification (flushed + compacted) / user bytes.
    pub fn write_amplification(&self) -> f64 {
        if self.user_bytes_written == 0 {
            return 0.0;
        }
        (self.bytes_flushed + self.bytes_compacted_written) as f64
            / self.user_bytes_written as f64
    }

    /// Measured bloom false-positive rate: of the filter consultations
    /// for keys absent from the SST, the fraction answered "maybe".
    pub fn bloom_fpr(&self) -> f64 {
        if self.bloom_negative_probes == 0 {
            return 0.0;
        }
        self.bloom_false_positives as f64 / self.bloom_negative_probes as f64
    }

    /// Data blocks touched per point lookup.
    pub fn blocks_per_get(&self) -> f64 {
        if self.gets == 0 {
            return 0.0;
        }
        self.block_reads as f64 / self.gets as f64
    }
}

/// What the last `EngineBuilder::open` recovered — surfaced through
/// `EngineHealth` so drivers can report recovery work uniformly. All
/// counters are per-life: a durable image carries no stats history, so
/// a freshly reopened engine reports exactly its own recovery.
#[derive(Clone, Debug, Default)]
pub struct RecoveryStats {
    /// 1 when this life was opened from a durable image, 0 when built
    /// fresh (images do not carry prior lives' counts).
    pub recoveries: u64,
    /// Durable WAL records replayed into the memtable at the last open.
    pub wal_records_replayed: u64,
    /// Durable WAL records already covered by flushed SSTs (skipped so
    /// an older WAL copy can't shadow the newer SST version).
    pub wal_records_discarded: u64,
    /// Block-FS files deleted because no recovered SST references them
    /// (outputs of jobs that were mid-write at the crash).
    pub orphan_files_removed: u64,
    /// Entries returned by the recovery scan of the device write buffer.
    pub dev_entries_scanned: u64,
    /// Device-resident keys routed back to the Dev-LSM (their device
    /// copy is the newest durable version).
    pub dev_keys_rerouted: u64,
    /// Device-resident keys superseded by a newer durable Main-LSM
    /// version (stale copies; excluded from routing).
    pub dev_keys_stale: u64,
    /// Manifest ended inside a rollback window (crash mid-rollback).
    pub interrupted_rollbacks: u64,
    /// The image came from a clean close (zero WAL records by contract).
    pub clean_reopen: bool,
    /// Virtual time the last recovery took, open() call to ready.
    pub last_recovery_ns: Nanos,
}

enum JobKind {
    Flush {
        sst: Arc<super::sst::Sst>,
        max_seq: Seq,
    },
    Compaction {
        level: usize,
        removed: BTreeSet<u64>,
        removed_files: Vec<crate::ssd::block_if::FileId>,
        outputs: Vec<Arc<super::sst::Sst>>,
        read_bytes: u64,
        write_bytes: u64,
        /// `(segment, len)` of separated values whose pointer entries
        /// the merge dropped — their vlog bytes go dead at install.
        dead_vlog: Vec<(u32, u32)>,
    },
}

struct PendingJob {
    end: Nanos,
    kind: JobKind,
}

pub struct LsmDb {
    pub opts: LsmOptions,
    engine: MergeEngine,
    bloom: BloomBuilder,

    mem: Memtable,
    imms: VecDeque<Memtable>, // oldest at front
    version: Version,
    wal: Wal,
    /// Durable edit log mirroring every Version change (crash recovery).
    manifest: Manifest,
    seq: Seq,
    next_sst_id: u64,

    flush_free_at: Nanos,
    pool: ThreadPool,
    pending: Vec<PendingJob>,
    busy: BTreeSet<u64>,
    inflight_flushes: usize,
    inflight_compactions: usize,

    /// Live snapshot registry (weak: a snapshot unpins by dropping).
    snapshots: Vec<Weak<SnapshotInner>>,
    /// Cursor read-amplification counters, shared with every iterator
    /// this engine hands out.
    pub scan_counters: Arc<ScanCounters>,
    /// The engine-wide block cache: one instance shared by the `get()`
    /// point-read path, every cursor this store hands out and (on
    /// KVACCEL) the device write-buffer read path — scans warm point
    /// reads and vice versa. A sharded store installs one cache across
    /// all its children via `set_block_cache`.
    pub block_cache: SharedBlockCache,

    pub stall: StallStats,
    pub stats: DbStats,
    pub recovery: RecoveryStats,

    /// WiscKey-style value log (key-value separation). Created lazily on
    /// the first separated append, so a store whose `vlog_threshold` is
    /// configured but never crossed — and every store with the feature
    /// off — is bit-identical to one built before the vlog existed.
    vlog: Option<Box<Vlog>>,
    /// GC-retired segments awaiting physical deletion, tagged with the
    /// seq at retirement: the file is only deleted once no live snapshot
    /// pins an older view (the drop's manifest edit is already durable).
    vlog_pending_drops: Vec<(Seq, Arc<VlogSegment>)>,
}

impl LsmDb {
    pub fn new(opts: LsmOptions, engine: MergeEngine, bloom: BloomBuilder) -> Self {
        Self {
            pool: ThreadPool::new(opts.compaction_threads),
            version: Version::new(opts.num_levels),
            engine,
            bloom,
            mem: Memtable::new(),
            imms: VecDeque::new(),
            wal: Wal::new(),
            manifest: Manifest::new(),
            seq: 0,
            next_sst_id: 1,
            flush_free_at: 0,
            pending: Vec::new(),
            busy: BTreeSet::new(),
            inflight_flushes: 0,
            inflight_compactions: 0,
            snapshots: Vec::new(),
            scan_counters: Arc::new(ScanCounters::default()),
            block_cache: new_block_cache(opts.block_cache_blocks),
            stall: StallStats::default(),
            stats: DbStats::default(),
            recovery: RecoveryStats::default(),
            vlog: None,
            vlog_pending_drops: Vec::new(),
            opts,
        }
    }

    // -----------------------------------------------------------------
    // Introspection (Detector inputs + tests)
    // -----------------------------------------------------------------

    pub fn l0_count(&self) -> usize {
        self.version.l0_count()
    }

    pub fn imm_count(&self) -> usize {
        self.imms.len()
    }

    pub fn memtable_bytes(&self) -> u64 {
        self.mem.approximate_bytes()
    }

    pub fn pending_compaction_bytes(&self) -> u64 {
        self.version.pending_compaction_bytes(&self.opts)
    }

    pub fn version(&self) -> &Version {
        &self.version
    }

    pub fn last_seq(&self) -> Seq {
        self.seq
    }

    /// Allocate the next sequence number. KVACCEL draws Dev-LSM write
    /// seqs from this same domain, so cross-interface recency is totally
    /// ordered — the authority crash recovery reconciles by.
    pub fn alloc_seq(&mut self) -> Seq {
        self.seq += 1;
        self.seq
    }

    /// Resume the sequence domain above externally-durable writes (the
    /// recovery scan of the device buffer may hold higher seqs than the
    /// recovered host state).
    pub fn bump_seq_to(&mut self, seq: Seq) {
        self.seq = self.seq.max(seq);
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Append a durable manifest edit (KVACCEL writes its rollback
    /// window markers through this). Returns the fsync completion time.
    pub fn manifest_append(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        edit: ManifestEdit,
    ) -> Nanos {
        self.manifest.append(env, at, edit)
    }

    /// Newest visible sequence number for `key` across every source, in
    /// read-path recency order. No latency is charged — recovery
    /// reconciliation walks this in bulk and charges CPU once.
    pub fn latest_seq(&self, key: Key) -> Option<Seq> {
        self.latest_desc(key).map(|(seq, _)| seq)
    }

    /// Newest visible `(seq, value)` for `key` — the vlog GC's liveness
    /// oracle (a separated value is live iff the latest version still
    /// points at its exact log location). No latency is charged.
    pub fn latest_desc(&self, key: Key) -> Option<(Seq, ValueDesc)> {
        if let Some(hit) = self.mem.get(key) {
            return Some(hit);
        }
        for imm in self.imms.iter().rev() {
            if let Some(hit) = imm.get(key) {
                return Some(hit);
            }
        }
        for sst in &self.version.levels[0] {
            if !sst.overlaps(key, key) {
                continue;
            }
            if let Some((e, _)) = sst.get(key) {
                return Some((e.seq, e.val));
            }
        }
        for level in 1..self.version.levels.len() {
            let files = &self.version.levels[level];
            let idx = files.partition_point(|s| s.largest < key);
            let Some(sst) = files.get(idx) else { continue };
            if let Some((e, _)) = sst.get(key) {
                return Some((e.seq, e.val));
            }
        }
        None
    }

    // -----------------------------------------------------------------
    // Value log (key-value separation)
    // -----------------------------------------------------------------

    /// Counters of this store's value log (zero when separation is off
    /// or never triggered).
    pub fn vlog_stats(&self) -> VlogStats {
        self.vlog.as_ref().map(|v| v.stats).unwrap_or_default()
    }

    /// Current value-log footprint on the device (head + sealed
    /// segments; retired-but-undeleted segments excluded).
    pub fn vlog_total_bytes(&self) -> u64 {
        self.vlog.as_ref().map(|v| v.total_bytes()).unwrap_or(0)
    }

    /// Known-dead bytes still occupying the value log — the numerator
    /// of vlog space amplification.
    pub fn vlog_dead_bytes(&self) -> u64 {
        self.vlog.as_ref().map(|v| v.dead_bytes()).unwrap_or(0)
    }

    /// Fsync the value-log stream if one exists (wrapping engines call
    /// this before capturing a clean image). No-op time-wise when off.
    pub fn vlog_sync(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        match &self.vlog {
            Some(v) => env.device.wal_sync_on(v.stream(), at),
            None => at,
        }
    }

    /// Durable byte watermark of the value-log stream (None when the
    /// log never engaged) — the crash cut wrapping engines capture
    /// before the power loss wipes page-cache accounting.
    pub fn vlog_durable_watermark(&self, env: &SimEnv) -> Option<u64> {
        self.vlog
            .as_ref()
            .map(|v| env.device.wal_durable_watermark_on(v.stream()))
    }

    /// Route `val` through the value log when separation applies:
    /// appends the payload to the log (lazily creating it) and returns
    /// the pointer descriptor the LSM stores instead. Installs the seal
    /// edit when the append fills the head.
    fn separate_value(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        key: Key,
        seq: Seq,
        val: ValueDesc,
    ) -> ValueDesc {
        if self.opts.vlog_threshold == 0
            || val.is_tombstone()
            || val.in_vlog()
            || val.len < self.opts.vlog_threshold
        {
            return val;
        }
        let vlog = self.vlog.get_or_insert_with(|| {
            Box::new(Vlog::new(self.opts.wal_stream, self.opts.vlog_segment_bytes))
        });
        let out = vlog.append(env, at, key, seq, val);
        if let Some(segment) = out.sealed {
            self.manifest.append(env, at, ManifestEdit::VlogSeal { segment });
        }
        out.desc
    }

    /// An insert shadowed `old` in the active memtable: if it pointed
    /// into the value log, those log bytes are now dead.
    fn note_shadowed(&mut self, old: ValueDesc) {
        if let ValueLoc::Vlog { segment, .. } = old.loc {
            if let Some(vlog) = self.vlog.as_mut() {
                vlog.mark_dead(segment, old.len);
            }
        }
    }

    /// One background GC step for the value log (driven from
    /// `KvEngine::tick` and piggybacked on the write path so every
    /// engine kind reclaims space): pick the deadest sealed segment past the
    /// configured dead ratio, rewrite its live values to the log head
    /// at fresh seqs, make both logs durable, then install the segment
    /// drop edit. Physical deletion defers until no live snapshot pins
    /// the pre-GC view (`flush_pending_vlog_drops`).
    pub fn vlog_gc_tick(&mut self, env: &mut SimEnv, at: Nanos) {
        self.flush_pending_vlog_drops(env);
        let Some(victim) = self
            .vlog
            .as_ref()
            .and_then(|v| v.gc_victim(self.opts.vlog_gc_dead_ratio))
        else {
            return;
        };
        let Some(seg) =
            self.vlog.as_ref().and_then(|v| v.sealed_segment(victim).cloned())
        else {
            return;
        };
        let mut t = at;
        // read the whole victim back (sequential segment read)
        if let Some(file) = seg.file {
            t = env.device.read_file(t, file, seg.bytes);
        }
        if let Some(vlog) = self.vlog.as_mut() {
            vlog.stats.gc_runs += 1;
            vlog.stats.gc_read_bytes += seg.bytes;
        }
        // liveness sift: one latest-version probe per record
        let sift_cpu = seg.records.len() as u64 * self.opts.merge_cpu_ns_per_entry;
        env.cpu.charge(CpuClass::Compaction, t, sift_cpu);
        t += sift_cpu;
        for rec in &seg.records {
            let live = matches!(
                self.latest_desc(rec.key),
                Some((_, d))
                    if d.loc == (ValueLoc::Vlog { segment: victim, offset: rec.offset })
            );
            if !live {
                continue;
            }
            // rewrite = a fresh internal write of the same logical value:
            // new seq, value re-appended at the log head, pointer through
            // WAL + memtable so recovery and replicas see it normally
            if self.mem.approximate_bytes() >= self.opts.write_buffer_size
                && self.imms.len() + 1 < self.opts.max_write_buffer_number
            {
                self.rotate_memtable(env, t);
            }
            self.seq += 1;
            let val = self.separate_value(
                env,
                t,
                rec.key,
                self.seq,
                ValueDesc::new(rec.seed, rec.len),
            );
            if let Some(vlog) = self.vlog.as_mut() {
                vlog.stats.gc_rewritten_bytes += rec.record_bytes();
            }
            let entry = Entry::new(rec.key, self.seq, val);
            let wal_bytes = self.wal.append(entry);
            env.device.wal_append_on(self.opts.wal_stream, t, wal_bytes);
            if let Some((_, old)) = self.mem.insert(entry) {
                self.note_shadowed(old);
            }
            env.cpu.charge(CpuClass::Compaction, t, self.opts.flush_cpu_ns_per_entry);
            t += self.opts.flush_cpu_ns_per_entry;
        }
        // durability order: new value copies first, then the pointer WAL,
        // then the drop edit — only after all three may old copies go
        let vstream = self.vlog.as_ref().expect("victim implies vlog").stream();
        t = env.device.wal_sync_on(vstream, t);
        t = env.device.wal_sync_on(self.opts.wal_stream, t);
        let retired = self.vlog.as_mut().expect("victim implies vlog").retire(victim);
        let t = self
            .manifest
            .append(env, t, ManifestEdit::VlogDrop { segment: victim });
        if let Some(seg) = retired {
            self.vlog_pending_drops.push((self.seq, seg));
        }
        // release the victim's cached blocks (ids are never reused)
        {
            let mut cache = self.block_cache.lock().expect("block cache poisoned");
            if cache.capacity() > 0 && !cache.is_empty() {
                cache.retain(|k| {
                    k.0 != VLOG_CACHE_NS || (k.1 >> 32) as u32 != victim
                });
            }
        }
        self.flush_pending_vlog_drops(env);
        env.clock.advance_to(t);
    }

    /// Physically delete GC-retired segment files once no live snapshot
    /// can still observe the pre-GC view (the drop's manifest edit is
    /// already durable — this only reclaims space).
    fn flush_pending_vlog_drops(&mut self, env: &mut SimEnv) {
        if self.vlog_pending_drops.is_empty() {
            return;
        }
        let min_pinned = self.min_pinned_seq();
        self.vlog_pending_drops.retain(|(gc_seq, seg)| {
            if matches!(min_pinned, Some(p) if p < *gc_seq) {
                return true; // a snapshot still pins the pre-GC view
            }
            if let Some(file) = seg.file {
                // deferred physical reclaim: the covering VlogDrop edit was
                // appended and synced in vlog_gc_tick before the segment
                // entered this queue, so only snapshot pins gate it here
                // lint:allow(sync-before-delete): drop edit synced in vlog_gc_tick
                let _ = env.device.delete_file(file);
            }
            false
        });
    }

    pub fn has_pending_jobs(&self) -> bool {
        !self.pending.is_empty()
    }

    /// Current write condition from live signals (what the paper's
    /// Detector samples every 0.1 s).
    pub fn write_condition(&self) -> WriteCondition {
        evaluate(
            self.version.l0_count(),
            self.imms.len(),
            self.mem.approximate_bytes() >= self.opts.write_buffer_size,
            self.version.pending_compaction_bytes(&self.opts),
            &self.opts,
        )
    }

    /// ADOC-style dynamic reconfiguration hooks.
    pub fn set_compaction_threads(&mut self, n: usize) {
        self.pool.set_threads(n);
    }

    pub fn set_write_buffer_size(&mut self, bytes: u64) {
        self.opts.write_buffer_size = bytes;
    }

    pub fn compaction_threads(&self) -> usize {
        self.pool.threads()
    }

    // -----------------------------------------------------------------
    // Background machinery
    // -----------------------------------------------------------------

    /// Apply every finished background job with end <= `at`.
    pub fn catch_up(&mut self, env: &mut SimEnv, at: Nanos) {
        loop {
            let idx = self
                .pending
                .iter()
                .enumerate()
                .filter(|(_, j)| j.end <= at)
                .min_by_key(|(_, j)| j.end)
                .map(|(i, _)| i);
            let Some(idx) = idx else { break };
            let job = self.pending.swap_remove(idx);
            let end = job.end;
            self.complete(env, job);
            self.maybe_schedule(env, end);
        }
    }

    fn complete(&mut self, env: &mut SimEnv, job: PendingJob) {
        let end = job.end;
        match job.kind {
            JobKind::Flush { sst, max_seq } => {
                self.stats.flush_count += 1;
                self.stats.bytes_flushed += sst.bytes;
                // the install is durable once its manifest edit is; the
                // fsync tail only occupies device bandwidth
                self.manifest.append(
                    env,
                    end,
                    ManifestEdit::AddL0 { sst: sst.clone(), max_seq },
                );
                self.version.add_l0(sst);
                self.imms.pop_front();
                self.inflight_flushes -= 1;
                self.wal.release_upto(max_seq);
            }
            JobKind::Compaction {
                level,
                removed,
                removed_files,
                outputs,
                read_bytes,
                write_bytes,
                dead_vlog,
            } => {
                self.stats.compaction_count += 1;
                self.stats.bytes_compacted_read += read_bytes;
                self.stats.bytes_compacted_written += write_bytes;
                for id in &removed {
                    self.busy.remove(id);
                }
                let mut removed_ids: Vec<u64> = removed.iter().copied().collect();
                removed_ids.sort_unstable();
                self.manifest.append(
                    env,
                    end,
                    ManifestEdit::CompactionInstall {
                        level,
                        removed: removed_ids,
                        installed: outputs.clone(),
                    },
                );
                self.version.apply_compaction(level, &removed, outputs);
                if let Some(vlog) = self.vlog.as_mut() {
                    for (segment, len) in dead_vlog {
                        vlog.mark_dead(segment, len);
                    }
                }
                for f in removed_files {
                    // files may already be gone in pathological shutdowns
                    let _ = env.device.delete_file(f);
                }
                // invalidate the dead inputs' cached blocks: their SST
                // ids are never reused, so this only releases capacity
                {
                    let mut cache =
                        self.block_cache.lock().expect("block cache poisoned");
                    if cache.capacity() > 0 && !cache.is_empty() {
                        cache.retain(|k| !removed.contains(&k.0));
                    }
                }
                self.inflight_compactions -= 1;
            }
        }
    }

    /// Schedule any newly-possible background work as of time `now`.
    pub fn maybe_schedule(&mut self, env: &mut SimEnv, now: Nanos) {
        // flushes: one job per unscheduled immutable memtable
        while self.inflight_flushes < self.imms.len() {
            let imm_idx = self.inflight_flushes;
            let entries = self.imms[imm_idx].to_entries();
            let max_seq = self.imms[imm_idx].max_seq;
            if entries.is_empty() {
                // empty imm: drop it synchronously
                self.imms.remove(imm_idx);
                continue;
            }
            self.schedule_flush(env, now, entries, max_seq)
                .expect("flush scheduling failed");
        }
        // compactions: fill the pool
        while self.inflight_compactions < self.pool.threads() {
            let Some(pick) = self.version.pick_compaction(&self.opts, &self.busy)
            else {
                break;
            };
            self.schedule_compaction(env, now, pick)
                .expect("compaction scheduling failed");
        }
    }

    fn schedule_flush(
        &mut self,
        env: &mut SimEnv,
        now: Nanos,
        entries: Vec<Entry>,
        max_seq: Seq,
    ) -> Result<()> {
        let mut start = self.flush_free_at.max(now);
        if let Some(vlog) = &self.vlog {
            // SST pointers must never reference page-cached vlog bytes:
            // sync the value log before the flush makes pointers durable
            start = env.device.wal_sync_on(vlog.stream(), start);
        }
        let n = entries.len() as u64;
        let bytes: u64 = entries.iter().map(|e| e.encoded_len()).sum();
        // entry encode cost plus (when a codec is on) per-block
        // compression of the output
        let cpu = n * self.opts.flush_cpu_ns_per_entry
            + bytes.div_ceil(self.opts.block_bytes) * self.opts.compress_ns();
        env.cpu.charge(CpuClass::Flush, start, cpu);
        let (file, io_done) = env.device.write_file_priority_for(
            self.opts.wal_stream,
            start + cpu,
            self.opts.disk_bytes(bytes),
        )?;
        let id = self.next_sst_id;
        self.next_sst_id += 1;
        let bits = self.opts.bloom_bits_for(entries.len());
        let sst = Arc::new(super::sst::Sst::build_with_codec(
            id,
            file,
            entries,
            &self.bloom,
            self.opts.bloom_probes,
            bits,
            self.opts.block_bytes,
            self.opts.compression,
        )?);
        let end = io_done;
        self.flush_free_at = end;
        self.inflight_flushes += 1;
        self.pending.push(PendingJob { end, kind: JobKind::Flush { sst, max_seq } });
        Ok(())
    }

    fn schedule_compaction(
        &mut self,
        env: &mut SimEnv,
        now: Nanos,
        pick: super::version::CompactionPick,
    ) -> Result<()> {
        let (thread, start) = self.pool.reserve(now);
        for id in pick.all_ids() {
            self.busy.insert(id);
        }
        // phase 1: read inputs (NAND + PCIe d2h)
        let mut read_done = start;
        let mut read_bytes = 0u64;
        for sst in pick.inputs.iter().chain(&pick.targets) {
            read_done = read_done.max(env.device.read_file(start, sst.file, sst.bytes));
            read_bytes += sst.bytes;
        }
        // phase 2: merge on the compaction thread (no device traffic —
        // this is the PCIe gap of Fig 4). L0->L1 is key-range-split
        // across the pool (RocksDB's max_subcompactions): total CPU work
        // is unchanged but wall time shrinks with thread count — this is
        // how compaction threads buy throughput in the paper's Fig 12.
        let entries = concat_inputs(&pick);
        // materializing compressed inputs pays decompression per block
        let input_blocks: u64 = pick
            .inputs
            .iter()
            .chain(&pick.targets)
            .map(|s| s.block_count() as u64)
            .sum();
        let merge_cpu = entries.len() as u64 * self.opts.merge_cpu_ns_per_entry
            + input_blocks * self.opts.decompress_ns();
        env.cpu.charge(CpuClass::Compaction, read_done, merge_cpu);
        let subcompactions = if pick.level == 0 {
            self.pool.threads() as u64
        } else {
            1
        };
        let merge_done = read_done + merge_cpu / subcompactions;
        let drop_tombstones = pick.level + 2 >= self.opts.num_levels;
        let output_sets = run_merge(
            &entries,
            &self.engine,
            self.opts.target_file_size,
            drop_tombstones,
        )?;
        // separated values whose pointer entries the merge dropped (old
        // versions, shadowed writes, expired tombstone targets): their
        // vlog bytes go dead when this compaction installs. Pointers the
        // merge *kept* just move between SSTs — values never rewrite.
        let mut dead_vlog: Vec<(u32, u32)> = Vec::new();
        if self.vlog.is_some() {
            let kept: BTreeSet<(Key, Seq)> = output_sets
                .iter()
                .flatten()
                .map(|e| (e.key, e.seq))
                .collect();
            for e in &entries {
                if let ValueLoc::Vlog { segment, .. } = e.val.loc {
                    if !kept.contains(&(e.key, e.seq)) {
                        dead_vlog.push((segment, e.val.len));
                    }
                }
            }
        }
        // phase 3: write outputs
        let shape = shape_of(&pick, &output_sets);
        let mut outputs = Vec::with_capacity(output_sets.len());
        let mut write_done = merge_done;
        let mut disk_write_bytes = 0u64;
        for set in output_sets {
            let bytes: u64 = set.iter().map(|e| e.encoded_len()).sum();
            // per-block compression of this output on the compaction
            // thread, then the (smaller) compressed file hits the device
            let compress_cpu =
                bytes.div_ceil(self.opts.block_bytes) * self.opts.compress_ns();
            if compress_cpu > 0 {
                env.cpu.charge(CpuClass::Compaction, merge_done, compress_cpu);
            }
            let disk_bytes = self.opts.disk_bytes(bytes);
            disk_write_bytes += disk_bytes;
            let (file, done) = env.device.write_file_for(
                self.opts.wal_stream,
                merge_done + compress_cpu,
                disk_bytes,
            )?;
            write_done = write_done.max(done);
            let id = self.next_sst_id;
            self.next_sst_id += 1;
            let bits = self.opts.bloom_bits_for(set.len());
            outputs.push(Arc::new(super::sst::Sst::build_with_codec(
                id,
                file,
                set,
                &self.bloom,
                self.opts.bloom_probes,
                bits,
                self.opts.block_bytes,
                self.opts.compression,
            )?));
        }
        let end = write_done.max(start + 1);
        self.pool.occupy(thread, start, end);
        self.inflight_compactions += 1;
        let removed: BTreeSet<u64> = pick.all_ids().collect();
        let removed_files = pick
            .inputs
            .iter()
            .chain(&pick.targets)
            .map(|s| s.file)
            .collect();
        self.pending.push(PendingJob {
            end,
            kind: JobKind::Compaction {
                level: pick.level,
                removed,
                removed_files,
                outputs,
                read_bytes,
                // identical to shape.write_bytes when compression is off
                write_bytes: disk_write_bytes,
                dead_vlog,
            },
        });
        debug_assert!(
            !self.opts.compression.is_none()
                || disk_write_bytes == shape.write_bytes
        );
        Ok(())
    }

    fn rotate_memtable(&mut self, env: &mut SimEnv, now: Nanos) {
        self.wal.seal();
        let full = std::mem::replace(&mut self.mem, Memtable::new());
        self.imms.push_back(full);
        self.maybe_schedule(env, now);
    }

    // -----------------------------------------------------------------
    // Write path
    // -----------------------------------------------------------------

    /// Admission gate shared by `put` and `write_batch`: apply finished
    /// background work, rotate the memtable when possible, then block
    /// (hard stop) or sleep (slowdown) per the stall state machine.
    /// Returns the admitted issue time plus stalled/delayed accounting.
    fn admit_write(&mut self, env: &mut SimEnv, at: Nanos) -> (Nanos, Nanos, Nanos) {
        let mut at = at;
        let mut stalled_ns = 0;
        let mut delayed_ns = 0;
        self.catch_up(env, at);
        loop {
            let memtable_full =
                self.mem.approximate_bytes() >= self.opts.write_buffer_size;
            if memtable_full && self.imms.len() + 1 < self.opts.max_write_buffer_number
            {
                self.rotate_memtable(env, at);
                continue;
            }
            let cond = evaluate(
                self.version.l0_count(),
                self.imms.len(),
                memtable_full,
                self.version.pending_compaction_bytes(&self.opts),
                &self.opts,
            );
            match cond {
                WriteCondition::Stopped(_) => {
                    self.maybe_schedule(env, at);
                    let next = self.pending.iter().map(|j| j.end).min();
                    match next {
                        Some(end) if end > at => {
                            let start = at;
                            stalled_ns += end - at;
                            at = end;
                            self.catch_up(env, at);
                            self.stall.record_stop(start, at);
                        }
                        _ => {
                            // no job to wait for: anomalous; release
                            self.stats.stall_anomalies += 1;
                            break;
                        }
                    }
                }
                WriteCondition::Delayed(_) if self.opts.enable_slowdown => {
                    // one slowdown sleep per write (RocksDB's delayed
                    // write pacing, §III-A)
                    self.stall.record_delay(self.opts.slowdown_sleep_ns);
                    delayed_ns = self.opts.slowdown_sleep_ns;
                    at += delayed_ns;
                    self.catch_up(env, at);
                    break;
                }
                _ => {
                    self.stall.clear_delay();
                    break;
                }
            }
        }
        (at, stalled_ns, delayed_ns)
    }

    /// Write with full stall/slowdown semantics. `at` is the issue time;
    /// the result's `done` is when the writer thread is free again.
    pub fn put(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        key: Key,
        val: ValueDesc,
    ) -> PutResult {
        let (mut at, stalled_ns, delayed_ns) = self.admit_write(env, at);
        // the write itself
        self.seq += 1;
        let val = self.separate_value(env, at, key, self.seq, val);
        let entry = Entry::new(key, self.seq, val);
        self.stats.puts += 1;
        // user bytes are the *logical* write (key + metadata + payload),
        // independent of whether the payload was separated
        self.stats.user_bytes_written += 16 + entry.val.value_len();
        let wal_bytes = self.wal.append(entry);
        env.device.wal_append_on(self.opts.wal_stream, at, wal_bytes);
        if let Some((_, old)) = self.mem.insert(entry) {
            self.note_shadowed(old);
        }
        env.cpu.charge(CpuClass::Foreground, at, self.opts.put_cpu_ns);
        at += self.opts.put_cpu_ns;
        env.clock.advance_to(at);
        // piggybacked GC check: engines without an external tick driver
        // still reclaim dead vlog space under a steady write load (a
        // strict no-op while the value log is empty or healthy)
        self.vlog_gc_tick(env, at);
        PutResult { done: at, stalled_ns, delayed_ns }
    }

    /// Delete a key: a tombstone through the standard write path (WAL
    /// record → memtable tombstone → dropped at the bottommost
    /// compaction level by `run_merge`).
    pub fn delete(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> PutResult {
        self.stats.deletes += 1;
        self.put(env, at, key, ValueDesc::TOMBSTONE)
    }

    /// Replication apply: the full write path (admission gate, WAL,
    /// memtable) but with the entry's *original* primary sequence number
    /// preserved, so replicas share the primary's seq domain and the
    /// applied-seq watermark is comparable across nodes. The local seq
    /// counter only moves forward (a replica never re-issues a primary
    /// seq for its own writes after promotion).
    pub fn apply_entry(&mut self, env: &mut SimEnv, at: Nanos, e: Entry) -> PutResult {
        let (mut at, stalled_ns, delayed_ns) = self.admit_write(env, at);
        self.seq = self.seq.max(e.seq);
        self.stats.puts += 1;
        if e.val.is_tombstone() {
            self.stats.deletes += 1;
        }
        self.stats.user_bytes_written += 16 + e.val.value_len();
        // CDC ships values, never pointers: strip any stray location and
        // re-separate against *this* store's own value log
        let val = self.separate_value(env, at, e.key, e.seq, e.val.inline());
        let e = Entry { val, ..e };
        let wal_bytes = self.wal.append(e);
        env.device.wal_append_on(self.opts.wal_stream, at, wal_bytes);
        if let Some((_, old)) = self.mem.insert(e) {
            self.note_shadowed(old);
        }
        env.cpu.charge(CpuClass::Foreground, at, self.opts.put_cpu_ns);
        at += self.opts.put_cpu_ns;
        env.clock.advance_to(at);
        self.vlog_gc_tick(env, at);
        PutResult { done: at, stalled_ns, delayed_ns }
    }

    /// CDC tailing cursor over the host WAL: live records with
    /// `seq > wm`, in append order (see `Wal::entries_after`).
    pub fn wal_entries_after(&self, wm: Seq) -> Vec<Entry> {
        self.wal.entries_after(wm)
    }

    /// Apply a batch as one unit: a single admission gate up front, per-
    /// entry memtable inserts (with mid-batch rotation when a slot is
    /// free), and one group-committed WAL submission — ops after the
    /// first pay the amortized `put_cpu_ns / batch_cpu_divisor`.
    pub fn write_batch(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        batch: &crate::engine::WriteBatch,
    ) -> crate::engine::BatchResult {
        if batch.is_empty() {
            self.catch_up(env, at);
            return crate::engine::BatchResult { done: at, ..Default::default() };
        }
        let (mut at, stalled_ns, delayed_ns) = self.admit_write(env, at);
        self.stats.batches += 1;
        let mut wal_bytes = 0u64;
        for op in batch.ops() {
            // rotate mid-batch when the memtable fills and a slot is
            // free; a stopped condition never re-blocks inside a batch
            // (the gate already ran), matching put_internal's policy.
            if self.mem.approximate_bytes() >= self.opts.write_buffer_size
                && self.imms.len() + 1 < self.opts.max_write_buffer_number
            {
                self.rotate_memtable(env, at);
            }
            self.seq += 1;
            // batched separated values land contiguously in the log (the
            // whole batch appends before the single group-commit below)
            let val = self.separate_value(env, at, op.key(), self.seq, op.value());
            let entry = Entry::new(op.key(), self.seq, val);
            // `puts` counts every write op (tombstones included), exactly
            // like the single-op path; `deletes` is supplementary.
            self.stats.puts += 1;
            if op.is_delete() {
                self.stats.deletes += 1;
            }
            self.stats.user_bytes_written += 16 + entry.val.value_len();
            wal_bytes += self.wal.append(entry);
            if let Some((_, old)) = self.mem.insert(entry) {
                self.note_shadowed(old);
            }
        }
        // one group-commit WAL submission for the whole batch
        env.device.wal_append_on(self.opts.wal_stream, at, wal_bytes);
        let cpu = self.opts.batch_cpu_ns(batch.len() as u64);
        env.cpu.charge(CpuClass::Foreground, at, cpu);
        at += cpu;
        env.clock.advance_to(at);
        self.vlog_gc_tick(env, at);
        crate::engine::BatchResult { done: at, stalled_ns, delayed_ns, ops: batch.len() }
    }

    /// Internal write used by the rollback path: bypasses stall blocking
    /// (the Rollback Manager only runs when no stall is present) but still
    /// pays WAL + memtable + rotation costs.
    pub fn put_internal(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        key: Key,
        val: ValueDesc,
    ) -> Nanos {
        let mut at = at;
        self.catch_up(env, at);
        if self.mem.approximate_bytes() >= self.opts.write_buffer_size
            && self.imms.len() + 1 < self.opts.max_write_buffer_number
        {
            self.rotate_memtable(env, at);
        }
        self.seq += 1;
        let val = self.separate_value(env, at, key, self.seq, val);
        let entry = Entry::new(key, self.seq, val);
        self.stats.user_bytes_written += 16 + entry.val.value_len();
        let wal_bytes = self.wal.append(entry);
        env.device.wal_append_on(self.opts.wal_stream, at, wal_bytes);
        if let Some((_, old)) = self.mem.insert(entry) {
            self.note_shadowed(old);
        }
        at += self.opts.flush_cpu_ns_per_entry; // bulk-load cost, not client path
        env.cpu.charge(CpuClass::Kvaccel, at, self.opts.flush_cpu_ns_per_entry);
        at
    }

    // -----------------------------------------------------------------
    // Read path
    // -----------------------------------------------------------------

    /// Charge one data-block access: block-cache hit costs CPU only; a
    /// miss reads the (possibly compressed) block through the device and
    /// pays the decompression CPU. Returns the time the data is ready.
    fn block_access(&mut self, env: &mut SimEnv, at: Nanos, sst: u64, block: usize) -> Nanos {
        self.stats.block_reads += 1;
        let mut cache = self.block_cache.lock().expect("block cache poisoned");
        if cache.capacity() > 0 && cache.get(&(sst, block)).is_some() {
            env.cpu.charge(CpuClass::Foreground, at, self.opts.get_cpu_ns / 2);
            return at + self.opts.get_cpu_ns / 2;
        }
        let mut done =
            env.device.read_block(at, self.opts.disk_bytes(self.opts.block_bytes));
        let decompress = self.opts.decompress_ns();
        if decompress > 0 {
            env.cpu.charge(CpuClass::Foreground, done, decompress);
            done += decompress;
        }
        cache.insert((sst, block), ());
        done
    }

    /// Public block-access charger for external merging iterators (the
    /// KVACCEL dual-iterator range query charges Main-LSM block touches
    /// through this).
    pub fn charge_block_access(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        sst: u64,
        block: usize,
    ) -> Nanos {
        self.block_access(env, at, sst, block)
    }

    /// Dereference a separated value on the point-read path: charge the
    /// value-log block touches through the shared block cache (hits cost
    /// CPU only; misses read uncompressed vlog blocks from the device)
    /// and return the normalized inline value. Inline values pass
    /// through untouched.
    fn vlog_deref(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        v: ValueDesc,
    ) -> (ValueDesc, Nanos) {
        let ValueLoc::Vlog { segment, offset } = v.loc else {
            return (v, at);
        };
        let mut at = at;
        let bb = self.opts.block_bytes;
        let first = offset as u64 / bb;
        let last = (offset as u64 + VLOG_RECORD_HEADER + v.len as u64 - 1) / bb;
        if let Some(vlog) = self.vlog.as_mut() {
            vlog.stats.derefs += 1;
        }
        for block in first..=last {
            let cache_key = vlog_cache_key(segment, block);
            let mut cache = self.block_cache.lock().expect("block cache poisoned");
            if cache.capacity() > 0 && cache.get(&cache_key).is_some() {
                env.cpu.charge(CpuClass::Foreground, at, self.opts.get_cpu_ns / 2);
                at += self.opts.get_cpu_ns / 2;
                continue;
            }
            at = env.device.read_block(at, bb);
            cache.insert(cache_key, ());
            drop(cache);
            if let Some(vlog) = self.vlog.as_mut() {
                vlog.stats.deref_blocks_read += 1;
            }
        }
        (v.inline(), at)
    }

    /// Terminal step of a point lookup that found `v`: tombstones read
    /// as absent; separated values are dereferenced through the vlog.
    fn finish_get(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        v: ValueDesc,
    ) -> (Option<ValueDesc>, Nanos) {
        if v.is_tombstone() {
            env.clock.advance_to(at);
            return (None, at);
        }
        let (v, at) = self.vlog_deref(env, at, v);
        env.clock.advance_to(at);
        (Some(v), at)
    }

    /// Point lookup. Tombstones read as absent.
    pub fn get(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        key: Key,
    ) -> (Option<ValueDesc>, Nanos) {
        self.catch_up(env, at);
        self.stats.gets += 1;
        env.cpu.charge(CpuClass::Foreground, at, self.opts.get_cpu_ns);
        let mut at = at + self.opts.get_cpu_ns;
        if let Some((_, v)) = self.mem.get(key) {
            self.stats.get_hits += 1;
            return self.finish_get(env, at, v);
        }
        for i in (0..self.imms.len()).rev() {
            if let Some((_, v)) = self.imms[i].get(key) {
                self.stats.get_hits += 1;
                return self.finish_get(env, at, v);
            }
        }
        // L0: newest first, overlapping ranges
        for sst in &self.version.levels[0].clone() {
            if !sst.overlaps(key, key) {
                continue;
            }
            if !sst.filter.may_contain(key) {
                // filter said no and the key is indeed absent
                self.stats.bloom_negative_probes += 1;
                continue;
            }
            match sst.get(key) {
                Some((e, block)) => {
                    at = self.block_access(env, at, sst.id, block);
                    self.stats.get_hits += 1;
                    return self.finish_get(env, at, e.val);
                }
                None => {
                    // bloom false positive: wasted block read
                    self.stats.bloom_negative_probes += 1;
                    self.stats.bloom_false_positives += 1;
                    at = self.block_access(env, at, sst.id, 0);
                }
            }
        }
        for level in 1..self.version.levels.len() {
            let files = &self.version.levels[level];
            let idx = files.partition_point(|s| s.largest < key);
            let Some(sst) = files.get(idx).cloned() else { continue };
            if !sst.overlaps(key, key) {
                continue;
            }
            if !sst.filter.may_contain(key) {
                self.stats.bloom_negative_probes += 1;
                continue;
            }
            match sst.get(key) {
                Some((e, block)) => {
                    at = self.block_access(env, at, sst.id, block);
                    self.stats.get_hits += 1;
                    return self.finish_get(env, at, e.val);
                }
                None => {
                    self.stats.bloom_negative_probes += 1;
                    self.stats.bloom_false_positives += 1;
                    at = self.block_access(env, at, sst.id, 0);
                }
            }
        }
        env.clock.advance_to(at);
        (None, at)
    }

    /// Snapshot iterator over the whole store (raw merging cursor; the
    /// engine-level [`crate::engine::DbIterator`] adds latency charging,
    /// bounds and the Dev-LSM source).
    pub fn iter(&self) -> LsmIterator {
        let mem = self.mem.to_entries();
        let imms: Vec<Vec<Entry>> = self.imms.iter().rev().map(|m| m.to_entries()).collect();
        let l0 = self.version.levels[0].clone();
        let levels: Vec<_> = self.version.levels[1..].to_vec();
        LsmIterator::new(mem, imms, l0, levels)
    }

    /// Pin the current read view: materialize the memtable/immutable
    /// runs, share the SST lists by refcount. Flushes and compactions
    /// replace `Arc`s in the live version, so the pinned clones keep
    /// every version this view can see alive.
    pub fn pin_parts(
        &mut self,
    ) -> (
        Seq,
        Vec<Arc<Vec<Entry>>>,
        Vec<Arc<super::sst::Sst>>,
        Vec<Vec<Arc<super::sst::Sst>>>,
    ) {
        let mut runs: Vec<Arc<Vec<Entry>>> = Vec::with_capacity(1 + self.imms.len());
        runs.push(self.mem.pin());
        for m in self.imms.iter_mut().rev() {
            runs.push(m.pin());
        }
        let l0 = self.version.levels[0].clone();
        let levels = self.version.levels[1..].to_vec();
        (self.seq, runs, l0, levels)
    }

    /// Take a refcounted snapshot of this store at `at`.
    pub fn snapshot(&mut self, env: &mut SimEnv, at: Nanos) -> Snapshot {
        self.catch_up(env, at);
        let (seq, runs, l0, levels) = self.pin_parts();
        let snap = Snapshot::pin(seq, 0, at, runs, l0, levels, None);
        self.register_snapshot(&snap);
        snap
    }

    /// Track a live snapshot (for `EngineHealth` reporting and so the
    /// store can answer "what is the oldest pinned seq").
    pub fn register_snapshot(&mut self, snap: &Snapshot) {
        self.snapshots.retain(|w| w.strong_count() > 0);
        self.snapshots.push(snap.downgrade());
    }

    pub fn live_snapshots(&self) -> usize {
        self.snapshots.iter().filter(|w| w.strong_count() > 0).count()
    }

    /// Oldest sequence number a live snapshot still pins.
    pub fn min_pinned_seq(&self) -> Option<Seq> {
        self.snapshots.iter().filter_map(|w| w.upgrade()).map(|s| s.seq).min()
    }

    /// Build the engine cursor over `snap` — one construction site for
    /// every engine (KVACCEL delegates here with its dual-interface
    /// snapshot).
    pub fn make_iter(
        &self,
        snap: Snapshot,
        opts: &crate::engine::IterOptions,
    ) -> Box<dyn crate::engine::DbIterator> {
        Box::new(crate::engine::EngineIterator::new(
            snap,
            opts,
            crate::engine::IterCost::from_opts(&self.opts),
            self.scan_counters.clone(),
            self.block_cache.clone(),
        ))
    }

    /// Range scan: a thin compatibility wrapper over the cursor API
    /// (Seek + up to `count` Nexts through a fresh pinned snapshot).
    pub fn scan(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        start: Key,
        count: usize,
    ) -> (Vec<Entry>, Nanos) {
        crate::engine::KvEngine::scan(self, env, at, start, count)
    }

    // -----------------------------------------------------------------
    // Maintenance / test helpers
    // -----------------------------------------------------------------

    /// Force-rotate and wait for all background work to finish.
    pub fn flush_and_wait(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        let mut at = at;
        if !self.mem.is_empty() {
            self.wal.seal();
            let full = std::mem::replace(&mut self.mem, Memtable::new());
            self.imms.push_back(full);
        }
        self.maybe_schedule(env, at);
        while let Some(end) = self.pending.iter().map(|j| j.end).min() {
            at = at.max(end);
            self.catch_up(env, at);
            self.maybe_schedule(env, at);
        }
        env.clock.advance_to(at);
        at
    }

    /// Entries that crash recovery would replay from the WAL.
    pub fn wal_replay(&self) -> Vec<Entry> {
        self.wal.replay()
    }

    pub fn wal_live_bytes(&self) -> u64 {
        self.wal.live_bytes()
    }

    pub fn cache_hit_rate(&self) -> f64 {
        self.block_cache.lock().expect("block cache poisoned").hit_rate()
    }

    /// Snapshot of the engine-wide block cache counters. On a sharded
    /// store every child shares one instance, so any child reports the
    /// engine-wide truth.
    pub fn cache_stats(&self) -> crate::engine::CacheStats {
        let cache = self.block_cache.lock().expect("block cache poisoned");
        crate::engine::CacheStats {
            hits: cache.hits(),
            misses: cache.misses(),
            evictions: cache.evictions(),
            cached_blocks: cache.len() as u64,
            cached_bytes: cache.len() as u64 * self.opts.block_bytes,
            capacity_blocks: cache.capacity() as u64,
        }
    }

    /// Swap in an externally-owned block cache (the engine builder and
    /// the sharding layer install one engine-wide instance here).
    pub fn set_block_cache(&mut self, cache: SharedBlockCache) {
        self.block_cache = cache;
    }

    // -----------------------------------------------------------------
    // Durable lifecycle: close / crash / open
    // -----------------------------------------------------------------

    /// Split into the parts a `DurableImage` carries. `watermark`
    /// selects the WAL cut: `Some(w)` keeps only records whose bytes
    /// reached flash by stream offset `w` (crash); `None` keeps every
    /// retained record (clean close — empty by then). `vlog_watermark`
    /// is the same cut for the value-log stream.
    #[allow(clippy::type_complexity)]
    pub fn into_image_parts(
        self,
        watermark: Option<u64>,
        vlog_watermark: Option<u64>,
    ) -> (
        LsmOptions,
        MergeEngine,
        BloomBuilder,
        Manifest,
        Vec<Entry>,
        Option<VlogImage>,
    ) {
        let LsmDb { opts, engine, bloom, manifest, wal, vlog, .. } = self;
        let mut records = match watermark {
            Some(w) => wal.durable_entries(w),
            None => wal.replay(),
        };
        let vlog_img = vlog.map(|v| match vlog_watermark {
            Some(w) => v.crash_image(w),
            None => v.clean_image(),
        });
        if let Some(img) = &vlog_img {
            // old-copy semantics for a crash mid-append: a durable WAL
            // record whose pointer references a head value that never
            // reached flash is dropped — the value is gone, so recovery
            // surfaces the previous version instead of a torn new one.
            // Sealed-segment pointers are always durable (seal = fsync).
            let durable: BTreeSet<u32> =
                img.head_records.iter().map(|r| r.offset).collect();
            records.retain(|e| match e.val.loc {
                ValueLoc::Vlog { segment, offset } if segment == img.head_id => {
                    durable.contains(&offset)
                }
                _ => true,
            });
        }
        (opts, engine, bloom, manifest, records, vlog_img)
    }

    /// Clean shutdown: drain all work, seal + fsync the WAL, write the
    /// CleanShutdown manifest edit. The returned image reopens with zero
    /// WAL records to replay.
    pub fn close_into_image(
        mut self,
        env: &mut SimEnv,
        at: Nanos,
    ) -> Result<crate::engine::DurableImage> {
        let t = self.flush_and_wait(env, at);
        self.flush_pending_vlog_drops(env);
        let mut t = env.device.wal_sync_on(self.opts.wal_stream, t);
        if let Some(vlog) = &self.vlog {
            t = t.max(env.device.wal_sync_on(vlog.stream(), t));
        }
        let last_seq = self.seq;
        let t = self
            .manifest
            .append(env, t, ManifestEdit::CleanShutdown { last_seq });
        env.clock.advance_to(t);
        let slowdown = self.opts.enable_slowdown;
        let (opts, merge, bloom, manifest, wal, vlog) =
            self.into_image_parts(None, None);
        Ok(crate::engine::DurableImage {
            kind: crate::baselines::SystemKind::RocksDb { slowdown },
            opts,
            merge,
            bloom,
            manifest,
            wal,
            vlog,
            kvaccel_cfg: None,
            adoc_cfg: None,
            shard: None,
            clean: true,
            taken_at: t,
        })
    }

    /// Power loss at `at`: background jobs finished before `at` have
    /// applied (their manifest edits are durable); everything else —
    /// memtables, page-cached WAL bytes, in-flight job outputs — is
    /// lost. The device keeps NAND contents and the FTL map.
    pub fn crash_into_image(
        mut self,
        env: &mut SimEnv,
        at: Nanos,
    ) -> crate::engine::DurableImage {
        self.catch_up(env, at);
        // capture the durability cuts BEFORE the power loss wipes the
        // page-cache accounting (those bytes are lost, not durable)
        let watermark = env.device.wal_durable_watermark_on(self.opts.wal_stream);
        let vlog_watermark = self
            .vlog
            .as_ref()
            .map(|v| env.device.wal_durable_watermark_on(v.stream()));
        env.device.crash(at);
        let slowdown = self.opts.enable_slowdown;
        let (opts, merge, bloom, manifest, wal, vlog) =
            self.into_image_parts(Some(watermark), vlog_watermark);
        crate::engine::DurableImage {
            kind: crate::baselines::SystemKind::RocksDb { slowdown },
            opts,
            merge,
            bloom,
            manifest,
            wal,
            vlog,
            kvaccel_cfg: None,
            adoc_cfg: None,
            shard: None,
            clean: false,
            taken_at: at,
        }
    }

    /// Reopen from a durable image: rebuild the Version from the
    /// manifest edit log, delete orphan files, replay the durable WAL
    /// records into the memtable with their original sequence numbers,
    /// and resume the sequence domain. Returns the store and the virtual
    /// time recovery completed.
    #[allow(clippy::too_many_arguments)]
    pub fn open(
        env: &mut SimEnv,
        at: Nanos,
        opts: LsmOptions,
        merge: MergeEngine,
        bloom: BloomBuilder,
        manifest: Manifest,
        wal_records: Vec<Entry>,
        vlog: Option<VlogImage>,
        clean: bool,
    ) -> (Self, Nanos) {
        let mut db = LsmDb::new(opts, merge, bloom);
        // a reopen starts a fresh WAL log: restart the device's stream
        // accounting so the durable watermark matches the new offsets
        env.device.wal_reset_stream_on(db.opts.wal_stream);
        // read the manifest log back from flash
        let mut t = env.device.read_block(at, manifest.bytes().max(64));
        let rec = manifest.rebuild(db.opts.num_levels);
        db.version = rec.version;
        db.next_sst_id = rec.next_sst_id;
        // resume the sequence domain above everything durable: flushed
        // SSTs, plus the clean-shutdown marker (seqs may have been
        // allocated to writes that compacted away entirely)
        db.seq = rec.flushed_upto.max(rec.clean.unwrap_or(0));
        db.manifest = manifest;
        db.recovery.recoveries += 1;
        db.recovery.clean_reopen = clean;
        db.recovery.interrupted_rollbacks = rec.dangling_rollback as u64;
        // orphan cleanup: block-FS files in THIS store's directory that
        // no recovered SST references were mid-write at the crash (a
        // sharded sibling's files live in other directories and are
        // never touched)
        let live = db.version.live_file_ids();
        for id in env.device.fs.file_ids_for(db.opts.wal_stream) {
            if !live.contains(&id) {
                let _ = env.device.delete_file(id);
                db.recovery.orphan_files_removed += 1;
            }
        }
        // value-log recovery: sealed segments come back through the
        // manifest, the head from the image's durable prefix. Orphans in
        // the vlog directory (GC-retired victims whose deferred delete
        // never ran, superseded head extents) are removed once the live
        // set is known.
        let vlog_stream = VLOG_STREAM_OFFSET + db.opts.wal_stream;
        if vlog.is_some() || !rec.vlog_segments.is_empty() {
            env.device.wal_reset_stream_on(vlog_stream);
            let img = vlog.unwrap_or_else(|| VlogImage {
                // no head survived the crash: start a fresh one above
                // every recovered segment id
                head_id: rec
                    .vlog_segments
                    .iter()
                    .map(|s| s.id + 1)
                    .max()
                    .unwrap_or(0),
                ..VlogImage::default()
            });
            let log = Vlog::reopen(
                env,
                t,
                db.opts.wal_stream,
                db.opts.vlog_segment_bytes,
                &img,
                rec.vlog_segments.clone(),
            );
            let keep = log.live_file_ids();
            db.vlog = Some(Box::new(log));
            for id in env.device.fs.file_ids_for(vlog_stream) {
                if !keep.contains(&id) {
                    let _ = env.device.delete_file(id);
                    db.recovery.orphan_files_removed += 1;
                }
            }
        }
        // WAL replay: stream the durable records back, skip anything a
        // flushed SST already covers, re-insert the rest at their
        // original seqs (rotating the memtable when it fills)
        let wal_bytes: u64 =
            wal_records.iter().map(|e| 12 + e.encoded_len()).sum();
        if wal_bytes > 0 {
            t = env.device.read_block(t, wal_bytes);
        }
        let mut replayed = 0u64;
        for e in wal_records {
            if e.seq <= rec.flushed_upto {
                db.recovery.wal_records_discarded += 1;
                continue;
            }
            db.seq = db.seq.max(e.seq);
            let bytes = db.wal.append(e);
            env.device.wal_append_on(db.opts.wal_stream, t, bytes);
            if let Some((_, old)) = db.mem.insert(e) {
                db.note_shadowed(old);
            }
            replayed += 1;
            if db.mem.approximate_bytes() >= db.opts.write_buffer_size
                && db.imms.len() + 1 < db.opts.max_write_buffer_number
            {
                db.rotate_memtable(env, t);
            }
        }
        let replay_cpu = replayed * db.opts.flush_cpu_ns_per_entry;
        env.cpu.charge(CpuClass::Flush, t, replay_cpu);
        t += replay_cpu;
        // replayed records are made durable again before serving traffic
        t = env.device.wal_sync_on(db.opts.wal_stream, t);
        db.recovery.wal_records_replayed = replayed;
        // a reopened log starts a fresh epoch: rebase so the edit log
        // stays bounded across restarts
        let vlog_segs: Vec<Arc<VlogSegment>> = db
            .vlog
            .as_ref()
            .map(|v| v.sealed_segments().cloned().collect())
            .unwrap_or_default();
        t = db
            .manifest
            .rebase(env, t, &db.version, db.next_sst_id, rec.flushed_upto, vlog_segs);
        db.recovery.last_recovery_ns = t.saturating_sub(at);
        db.maybe_schedule(env, t);
        env.clock.advance_to(t);
        (db, t)
    }
}

// ---------------------------------------------------------------------
// Unified engine interface
// ---------------------------------------------------------------------

impl crate::engine::EngineStats for LsmDb {
    fn main_db(&self) -> &LsmDb {
        self
    }
}

impl crate::engine::KvEngine for LsmDb {
    fn put(&mut self, env: &mut SimEnv, at: Nanos, key: Key, val: ValueDesc) -> PutResult {
        LsmDb::put(self, env, at, key, val)
    }

    fn delete(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> PutResult {
        LsmDb::delete(self, env, at, key)
    }

    fn get(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> (Option<ValueDesc>, Nanos) {
        LsmDb::get(self, env, at, key)
    }

    fn write_batch(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        batch: &crate::engine::WriteBatch,
    ) -> crate::engine::BatchResult {
        LsmDb::write_batch(self, env, at, batch)
    }

    fn snapshot(&mut self, env: &mut SimEnv, at: Nanos) -> Snapshot {
        LsmDb::snapshot(self, env, at)
    }

    fn iter(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        opts: crate::engine::IterOptions,
    ) -> Box<dyn crate::engine::DbIterator> {
        let snap = match &opts.snapshot {
            Some(s) => s.clone(),
            None => LsmDb::snapshot(self, env, at),
        };
        self.make_iter(snap, &opts)
    }

    fn tick(&mut self, env: &mut SimEnv, at: Nanos) {
        self.catch_up(env, at);
        self.vlog_gc_tick(env, at);
        self.maybe_schedule(env, at);
    }

    fn cdc_tail(&self, _env: &SimEnv, wm: &[Seq]) -> Vec<crate::engine::CdcRecord> {
        // replication ships the value itself, never a vlog pointer — a
        // replica's log layout is its own business
        self.wal
            .entries_after(wm.first().copied().unwrap_or(0))
            .into_iter()
            .map(|entry| crate::engine::CdcRecord { entry: entry.inline_value(), stream: 0 })
            .collect()
    }

    fn repl_apply(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        rec: &crate::engine::CdcRecord,
    ) -> PutResult {
        self.apply_entry(env, at, rec.entry)
    }

    fn set_block_cache(&mut self, cache: SharedBlockCache) {
        LsmDb::set_block_cache(self, cache);
    }

    fn flush(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        self.flush_and_wait(env, at)
    }

    fn finish(&mut self, env: &mut SimEnv, at: Nanos) -> Result<Nanos> {
        Ok(self.flush_and_wait(env, at))
    }

    fn close(
        self: Box<Self>,
        env: &mut SimEnv,
        at: Nanos,
    ) -> Result<crate::engine::DurableImage> {
        (*self).close_into_image(env, at)
    }

    fn crash(self: Box<Self>, env: &mut SimEnv, at: Nanos) -> crate::engine::DurableImage {
        (*self).crash_into_image(env, at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ssd::SsdConfig;

    fn rig() -> (LsmDb, SimEnv) {
        let opts = LsmOptions::small_for_test();
        (
            LsmDb::new(opts, MergeEngine::rust(), BloomBuilder::rust()),
            SimEnv::new(7, SsdConfig::default()),
        )
    }

    fn v(seed: u32) -> ValueDesc {
        ValueDesc::new(seed, 4096)
    }

    #[test]
    fn put_get_roundtrip() {
        let (mut db, mut env) = rig();
        let r = db.put(&mut env, 0, 42, v(1));
        let (got, _) = db.get(&mut env, r.done, 42);
        assert_eq!(got, Some(v(1)));
    }

    #[test]
    fn overwrite_returns_latest() {
        let (mut db, mut env) = rig();
        let mut t = 0;
        t = db.put(&mut env, t, 1, v(1)).done;
        t = db.put(&mut env, t, 1, v(2)).done;
        let (got, _) = db.get(&mut env, t, 1);
        assert_eq!(got, Some(v(2)));
    }

    #[test]
    fn delete_via_tombstone() {
        let (mut db, mut env) = rig();
        let mut t = 0;
        t = db.put(&mut env, t, 1, v(1)).done;
        t = db.put(&mut env, t, 1, ValueDesc::TOMBSTONE).done;
        let (got, _) = db.get(&mut env, t, 1);
        assert_eq!(got, None);
    }

    #[test]
    fn flush_then_get_from_sst() {
        let (mut db, mut env) = rig();
        let mut t = 0;
        for k in 0..50 {
            t = db.put(&mut env, t, k, v(k)).done;
        }
        t = db.flush_and_wait(&mut env, t);
        assert!(db.version().file_count() >= 1);
        for k in 0..50 {
            let (got, nt) = db.get(&mut env, t, k);
            t = nt;
            assert_eq!(got, Some(v(k)), "key {k}");
        }
    }

    #[test]
    fn sustained_writes_trigger_flush_and_compaction() {
        let (mut db, mut env) = rig();
        let mut t = 0;
        for k in 0..3000u32 {
            t = db.put(&mut env, t, k % 701, v(k)).done;
        }
        t = db.flush_and_wait(&mut env, t);
        assert!(db.stats.flush_count > 0, "no flushes happened");
        assert!(db.stats.compaction_count > 0, "no compactions happened");
        // every key readable with its latest value
        for k in 0..701u32 {
            let expect = (0..3000u32)
                .filter(|x| x % 701 == k)
                .max()
                .map(v);
            let (got, nt) = db.get(&mut env, t, k);
            t = nt;
            assert_eq!(got, expect, "key {k}");
        }
    }

    #[test]
    fn stalls_emerge_without_slowdown() {
        let (mut db, mut env) = rig();
        db.opts.enable_slowdown = false;
        let mut t = 0;
        let mut stalled = 0u64;
        for k in 0..4000u32 {
            let r = db.put(&mut env, t, k, v(k));
            t = r.done;
            stalled += r.stalled_ns;
        }
        assert!(
            stalled > 0 || db.stall.stop_events > 0,
            "small config under pressure should stall"
        );
        assert_eq!(db.stats.stall_anomalies, 0);
    }

    #[test]
    fn slowdown_throttles_instead_of_stopping() {
        let (mut a, mut env_a) = rig();
        a.opts.enable_slowdown = true;
        let (mut b, mut env_b) = rig();
        b.opts.enable_slowdown = false;
        let (mut ta, mut tb) = (0, 0);
        for k in 0..4000u32 {
            ta = a.put(&mut env_a, ta, k, v(k)).done;
            tb = b.put(&mut env_b, tb, k, v(k)).done;
        }
        assert!(a.stall.slowdown_events > 0, "slowdown never engaged");
        assert!(
            a.stall.stopped_ns_total <= b.stall.stopped_ns_total,
            "slowdown should reduce hard-stop time: {} vs {}",
            a.stall.stopped_ns_total,
            b.stall.stopped_ns_total
        );
    }

    #[test]
    fn scan_merges_all_sources() {
        let (mut db, mut env) = rig();
        let mut t = 0;
        for k in (0..100).rev() {
            t = db.put(&mut env, t, k, v(k)).done;
        }
        t = db.flush_and_wait(&mut env, t);
        for k in 100..120 {
            t = db.put(&mut env, t, k, v(k)).done;
        }
        let (got, _) = db.scan(&mut env, t, 90, 20);
        let keys: Vec<Key> = got.iter().map(|e| e.key).collect();
        assert_eq!(keys, (90..110).collect::<Vec<_>>());
    }

    #[test]
    fn wal_replay_covers_unflushed() {
        let (mut db, mut env) = rig();
        let mut t = 0;
        for k in 0..10 {
            t = db.put(&mut env, t, k, v(k)).done;
        }
        let replay = db.wal_replay();
        assert_eq!(replay.len(), 10);
        let _ = t;
    }

    #[test]
    fn write_amplification_reported() {
        let (mut db, mut env) = rig();
        let mut t = 0;
        for k in 0..3000u32 {
            t = db.put(&mut env, t, k, v(k)).done;
        }
        db.flush_and_wait(&mut env, t);
        let wa = db.stats.write_amplification();
        assert!(wa > 1.0, "WA {wa} should exceed 1 after compactions");
    }

    #[test]
    fn delete_survives_flush_and_compaction() {
        let (mut db, mut env) = rig();
        let mut t = 0;
        t = db.put(&mut env, t, 42, v(1)).done;
        t = db.delete(&mut env, t, 42).done;
        // enough disjoint-key traffic to force flushes + compactions so
        // the tombstone travels down the tree
        for k in 0..3000u32 {
            t = db.put(&mut env, t, 1000 + (k % 701), v(k)).done;
        }
        t = db.flush_and_wait(&mut env, t);
        assert!(db.stats.compaction_count > 0, "no compactions happened");
        assert_eq!(db.stats.deletes, 1);
        let (got, nt) = db.get(&mut env, t, 42);
        t = nt;
        assert_eq!(got, None, "deleted key resurfaced");
        let _ = t;
    }

    #[test]
    fn write_batch_matches_individual_puts() {
        use crate::engine::WriteBatch;
        let (mut a, mut env_a) = rig();
        let (mut b, mut env_b) = rig();
        let mut wb = WriteBatch::new();
        let mut tb = 0;
        for k in 0..200u32 {
            wb.put(k, v(k));
            tb = b.put(&mut env_b, tb, k, v(k)).done;
        }
        wb.delete(50).delete(199);
        tb = b.delete(&mut env_b, tb, 50).done;
        tb = b.delete(&mut env_b, tb, 199).done;
        let r = a.write_batch(&mut env_a, 0, &wb);
        assert_eq!(r.ops, 202);
        assert_eq!(a.stats.puts, b.stats.puts);
        assert_eq!(a.stats.deletes, b.stats.deletes);
        let mut ta = r.done;
        for k in 0..200u32 {
            let want = if k == 50 || k == 199 { None } else { Some(v(k)) };
            let (ga, na) = a.get(&mut env_a, ta, k);
            ta = na;
            let (gb, nb) = b.get(&mut env_b, tb, k);
            tb = nb;
            assert_eq!(ga, want, "batch key {k}");
            assert_eq!(gb, want, "sequential key {k}");
        }
    }

    #[test]
    fn write_batch_amortizes_client_cost() {
        use crate::engine::WriteBatch;
        let (mut db, mut env) = rig();
        let n = 8u32;
        let mut wb = WriteBatch::new();
        for k in 0..n {
            wb.put(k, v(k));
        }
        let r = db.write_batch(&mut env, 0, &wb);
        assert_eq!(r.stalled_ns, 0);
        assert!(
            r.done < n as u64 * db.opts.put_cpu_ns,
            "batch of {n} should beat {n} sequential puts: {}",
            r.done
        );
    }

    #[test]
    fn empty_batch_is_a_noop() {
        use crate::engine::WriteBatch;
        let (mut db, mut env) = rig();
        let r = db.write_batch(&mut env, 17, &WriteBatch::new());
        assert_eq!(r.done, 17);
        assert_eq!(r.ops, 0);
        assert_eq!(db.stats.puts, 0);
        assert_eq!(db.stats.batches, 0);
    }

    #[test]
    fn large_batch_rotates_memtable_midway() {
        use crate::engine::WriteBatch;
        let (mut db, mut env) = rig();
        // small_for_test buffer is 64 KB; ~keys*4KB blows well past it
        let mut wb = WriteBatch::new();
        for k in 0..64u32 {
            wb.put(k, v(k));
        }
        let r = db.write_batch(&mut env, 0, &wb);
        let mut t = db.flush_and_wait(&mut env, r.done);
        for k in 0..64u32 {
            let (got, nt) = db.get(&mut env, t, k);
            t = nt;
            assert_eq!(got, Some(v(k)), "key {k}");
        }
    }

    #[test]
    fn manifest_mirrors_installs() {
        let (mut db, mut env) = rig();
        let mut t = 0;
        for k in 0..3000u32 {
            t = db.put(&mut env, t, k, v(k)).done;
        }
        db.flush_and_wait(&mut env, t);
        assert!(db.stats.flush_count > 0 && db.stats.compaction_count > 0);
        assert_eq!(
            db.manifest().edit_count() as u64,
            db.stats.flush_count + db.stats.compaction_count,
            "every install must write exactly one manifest edit"
        );
        // replaying the edit log reproduces the live version exactly
        let rec = db.manifest().rebuild(db.opts.num_levels);
        for (l, files) in db.version().levels.iter().enumerate() {
            let got: Vec<u64> = rec.version.levels[l].iter().map(|s| s.id).collect();
            let want: Vec<u64> = files.iter().map(|s| s.id).collect();
            assert_eq!(got, want, "level {l} diverged");
        }
    }

    #[test]
    fn lifecycle_close_open_roundtrip() {
        let (mut db, mut env) = rig();
        let mut t = 0;
        for k in 0..300u32 {
            t = db.put(&mut env, t, k, v(k)).done;
        }
        let img = db.close_into_image(&mut env, t).unwrap();
        assert!(img.clean);
        assert!(img.wal.is_empty(), "clean close must drain the WAL");
        let (mut db2, mut t2) = LsmDb::open(
            &mut env, t, img.opts, img.merge, img.bloom, img.manifest, img.wal,
            img.vlog, img.clean,
        );
        assert_eq!(db2.recovery.wal_records_replayed, 0);
        assert_eq!(db2.recovery.recoveries, 1);
        for k in (0..300u32).step_by(37) {
            let (got, nt) = db2.get(&mut env, t2, k);
            t2 = nt;
            assert_eq!(got, Some(v(k)), "key {k} after clean reopen");
        }
    }

    #[test]
    fn crash_open_recovers_everything_flushed() {
        let (mut db, mut env) = rig();
        let mut t = 0;
        for k in 0..200u32 {
            t = db.put(&mut env, t, k, v(k)).done;
        }
        t = db.flush_and_wait(&mut env, t);
        // unsynced tail, possibly lost (page cache)
        for k in 200..260u32 {
            t = db.put(&mut env, t, k, v(k)).done;
        }
        let img = db.crash_into_image(&mut env, t);
        assert!(!img.clean);
        let (mut db2, mut t2) = LsmDb::open(
            &mut env, t, img.opts, img.merge, img.bloom, img.manifest, img.wal,
            img.vlog, img.clean,
        );
        assert_eq!(db2.recovery.recoveries, 1);
        for k in 0..200u32 {
            let (got, nt) = db2.get(&mut env, t2, k);
            t2 = nt;
            assert_eq!(got, Some(v(k)), "flushed key {k} lost");
        }
    }

    #[test]
    fn latest_seq_tracks_read_priority() {
        let (mut db, mut env) = rig();
        let mut t = 0;
        t = db.put(&mut env, t, 9, v(1)).done;
        let s1 = db.latest_seq(9).unwrap();
        t = db.flush_and_wait(&mut env, t);
        assert_eq!(db.latest_seq(9), Some(s1), "flush preserves the seq");
        t = db.put(&mut env, t, 9, v(2)).done;
        assert!(db.latest_seq(9).unwrap() > s1, "memtable shadows the SST");
        assert_eq!(db.latest_seq(123_456), None);
        let _ = t;
    }

    #[test]
    fn levels_stay_disjoint() {
        let (mut db, mut env) = rig();
        let mut t = 0;
        for k in 0..5000u32 {
            t = db.put(&mut env, t, (k * 37) % 2048, v(k)).done;
        }
        db.flush_and_wait(&mut env, t);
        for l in 1..db.version().levels.len() {
            assert!(db.version().level_disjoint(l), "level {l} overlaps");
        }
    }
}
