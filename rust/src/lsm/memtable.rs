//! In-memory write buffer (RocksDB's MemTable). A BTreeMap stands in for
//! the skiplist: same ordering semantics, deterministic iteration.

use std::collections::BTreeMap;
use std::sync::Arc;

use super::entry::{Entry, Key, Seq, ValueDesc};

#[derive(Clone, Debug, Default)]
pub struct Memtable {
    map: BTreeMap<Key, (Seq, ValueDesc)>,
    bytes: u64,
    /// Sequence range held (for WAL release bookkeeping).
    pub min_seq: Seq,
    pub max_seq: Seq,
    /// Cached materialized run handed to snapshots; invalidated on
    /// insert (copy-on-write pinning — immutable memtables pin in O(1)).
    pinned: Option<Arc<Vec<Entry>>>,
}

impl Memtable {
    pub fn new() -> Self {
        Self {
            map: BTreeMap::new(),
            bytes: 0,
            min_seq: Seq::MAX,
            max_seq: 0,
            pinned: None,
        }
    }

    /// Insert, returning the value this write shadowed in the active
    /// buffer (the vlog marks the shadowed copy's bytes dead).
    pub fn insert(&mut self, e: Entry) -> Option<(Seq, ValueDesc)> {
        self.bytes += e.encoded_len();
        self.min_seq = self.min_seq.min(e.seq);
        self.max_seq = self.max_seq.max(e.seq);
        self.pinned = None;
        self.map.insert(e.key, (e.seq, e.val))
    }

    pub fn get(&self, key: Key) -> Option<(Seq, ValueDesc)> {
        self.map.get(&key).copied()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Approximate arena footprint (logical encoded bytes; RocksDB counts
    /// arena allocation the same way for the stall triggers).
    pub fn approximate_bytes(&self) -> u64 {
        self.bytes
    }

    /// Drain into a sorted, key-unique entry vector (flush input).
    pub fn to_entries(&self) -> Vec<Entry> {
        self.map
            .iter()
            .map(|(&k, &(seq, val))| Entry { key: k, seq, val })
            .collect()
    }

    /// Refcounted materialized run for snapshot pinning; cached until
    /// the next insert, so read-only phases pin in O(1).
    pub fn pin(&mut self) -> Arc<Vec<Entry>> {
        if let Some(p) = &self.pinned {
            return p.clone();
        }
        let p = Arc::new(self.to_entries());
        self.pinned = Some(p.clone());
        p
    }

    /// Range scan over [start, end) — newest value per key by
    /// construction (the map holds the latest write).
    pub fn range(&self, start: Key, end: Key) -> impl Iterator<Item = Entry> + '_ {
        self.map
            .range(start..end)
            .map(|(&k, &(seq, val))| Entry { key: k, seq, val })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn e(k: Key, s: Seq) -> Entry {
        Entry::new(k, s, ValueDesc::new(s, 100))
    }

    #[test]
    fn insert_get_overwrite() {
        let mut m = Memtable::new();
        m.insert(e(1, 1));
        m.insert(e(1, 5));
        assert_eq!(m.get(1), Some((5, ValueDesc::new(5, 100))));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn bytes_accumulate_even_on_overwrite() {
        // RocksDB arena grows on every insert (no in-place update).
        let mut m = Memtable::new();
        m.insert(e(1, 1));
        let b1 = m.approximate_bytes();
        m.insert(e(1, 2));
        assert_eq!(m.approximate_bytes(), b1 * 2);
    }

    #[test]
    fn to_entries_sorted_unique() {
        let mut m = Memtable::new();
        for k in [5u32, 2, 9, 2] {
            m.insert(e(k, k));
        }
        let v = m.to_entries();
        let keys: Vec<Key> = v.iter().map(|x| x.key).collect();
        assert_eq!(keys, vec![2, 5, 9]);
    }

    #[test]
    fn seq_range_tracked() {
        let mut m = Memtable::new();
        m.insert(e(1, 10));
        m.insert(e(2, 3));
        assert_eq!((m.min_seq, m.max_seq), (3, 10));
    }

    #[test]
    fn range_scan_bounds() {
        let mut m = Memtable::new();
        for k in 0..10u32 {
            m.insert(e(k, k + 1));
        }
        let got: Vec<Key> = m.range(3, 7).map(|e| e.key).collect();
        assert_eq!(got, vec![3, 4, 5, 6]);
    }
}
