//! LSM engine tuning knobs. Defaults mirror the paper's setup (RocksDB
//! v8.3.2 with 128 MB memtables, Table III) and RocksDB's documented
//! stall/slowdown triggers; the CPU-cost constants are calibrated so the
//! simulated foreground burst rate and stall cadence match the paper's
//! measured shapes (see DESIGN.md §2 and EXPERIMENTS.md).

use crate::sim::{Nanos, MICROS};

/// Block-compression codec model. The simulator does not compress real
/// payloads; the codec is a cost model: data blocks occupy
/// `ratio_pct`% of their logical bytes on the simulated device (fewer
/// pages per read and per compaction write), and every block
/// materialization off the device pays a decompression CPU charge
/// (flush/compaction outputs pay the compression charge).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Compression {
    /// No codec: byte-identical accounting to a store built before
    /// compression existed.
    None,
    /// An LZ4-like fast codec; `ratio_pct` is compressed/logical size in
    /// percent (1..=100).
    LzLike { ratio_pct: u64 },
}

impl Compression {
    /// Compressed size of `logical` bytes on the simulated device.
    pub fn disk_bytes(&self, logical: u64) -> u64 {
        match *self {
            Compression::None => logical,
            Compression::LzLike { ratio_pct } => {
                (logical * ratio_pct.clamp(1, 100)) / 100
            }
        }
    }

    pub fn is_none(&self) -> bool {
        matches!(self, Compression::None)
    }
}

#[derive(Clone, Debug)]
pub struct LsmOptions {
    // ----- structure -----
    /// Active memtable capacity (paper Table III: 128 MB).
    pub write_buffer_size: u64,
    /// Max memtables (active + immutable) before writes must stop.
    pub max_write_buffer_number: usize,
    /// L0 file count that triggers L0->L1 compaction.
    pub l0_compaction_trigger: usize,
    /// L0 file count that triggers write slowdown (RocksDB default 20).
    pub l0_slowdown_trigger: usize,
    /// L0 file count that stops writes (RocksDB default 36).
    pub l0_stop_trigger: usize,
    /// Target size of L1 (max_bytes_for_level_base).
    pub max_bytes_for_level_base: u64,
    /// Per-level size multiplier.
    pub level_multiplier: u64,
    pub num_levels: usize,
    /// Output SST target size.
    pub target_file_size: u64,
    /// Pending-compaction-bytes soft limit (slowdown trigger).
    pub soft_pending_compaction_bytes: u64,
    /// Pending-compaction-bytes hard limit (stop trigger).
    pub hard_pending_compaction_bytes: u64,

    // ----- background work -----
    /// Compaction thread count (the paper's swept parameter, Table III).
    pub compaction_threads: usize,

    // ----- slowdown policy -----
    /// RocksDB's slowdown mechanism on/off (Fig 2/3's variable).
    pub enable_slowdown: bool,
    /// Sleep injected per write while in the delayed state (the paper
    /// cites ~1 ms thread sleeps [31]; calibrated to the ~2 Kops/s
    /// slowed-down service floor in Fig 2).
    pub slowdown_sleep_ns: Nanos,

    // ----- SST / read path -----
    /// SST data-block size.
    pub block_bytes: u64,
    /// Block cache capacity in blocks (0 disables the cache).
    pub block_cache_blocks: usize,
    pub bloom_bits_per_key: u32,
    pub bloom_probes: usize,
    /// Data-block compression cost model (None = bit-identical
    /// accounting to an uncompressed store).
    pub compression: Compression,
    /// CPU to decompress one data block when it is materialized from the
    /// device (cache misses, compaction input reads). Unused when
    /// `compression` is `None`.
    pub decompress_block_cpu_ns: Nanos,
    /// CPU to compress one data block on the write side (flush and
    /// compaction outputs). Unused when `compression` is `None`.
    pub compress_block_cpu_ns: Nanos,

    // ----- calibrated CPU cost model -----
    /// Foreground cost of one put (client + WAL memcpy + memtable insert).
    pub put_cpu_ns: Nanos,
    /// Foreground cost of one get step (seek + block decode, pre-I/O).
    pub get_cpu_ns: Nanos,
    /// Compaction merge CPU per entry (decode + compare + encode + CRC).
    pub merge_cpu_ns_per_entry: Nanos,
    /// Flush CPU per entry.
    pub flush_cpu_ns_per_entry: Nanos,
    /// Iterator next CPU per entry (cached path).
    pub next_cpu_ns: Nanos,
    /// Group-commit amortization for `write_batch`: ops after the first
    /// cost `put_cpu_ns / batch_cpu_divisor` each (one WAL submission and
    /// one client round-trip are shared by the whole batch).
    pub batch_cpu_divisor: u64,

    // ----- sharding -----
    /// Which device WAL log this store appends to. A sharded store gives
    /// every shard its own stream (per-shard WAL directory), so each
    /// shard has an independent crash durability cut; 0 is the default
    /// log unsharded engines use.
    pub wal_stream: u32,

    // ----- key-value separation (WiscKey-style value log) -----
    /// Values of at least this many bytes are separated into the value
    /// log; the LSM keeps a 12 B pointer. 0 disables separation entirely
    /// (bit-identical accounting to a store built before the vlog
    /// existed).
    pub vlog_threshold: u32,
    /// Value-log segment size: the append head seals and a new segment
    /// starts once this many bytes have been written to it.
    pub vlog_segment_bytes: u64,
    /// GC trigger: a sealed segment whose dead-byte fraction reaches this
    /// ratio is rewritten (live values re-appended to the head) and
    /// dropped.
    pub vlog_gc_dead_ratio: f64,
}

impl Default for LsmOptions {
    fn default() -> Self {
        Self {
            write_buffer_size: 128 << 20,
            max_write_buffer_number: 2,
            l0_compaction_trigger: 4,
            l0_slowdown_trigger: 20,
            l0_stop_trigger: 36,
            max_bytes_for_level_base: 256 << 20,
            level_multiplier: 10,
            num_levels: 7,
            target_file_size: 64 << 20,
            soft_pending_compaction_bytes: 64 << 30,
            hard_pending_compaction_bytes: 256 << 30,
            compaction_threads: 1,
            enable_slowdown: true,
            slowdown_sleep_ns: 500 * MICROS,
            block_bytes: 32 * 1024,
            block_cache_blocks: 16 * 1024, // 512 MB of 32 KB blocks
            bloom_bits_per_key: 10,
            bloom_probes: 7,
            compression: Compression::None,
            // LZ4-class costs for a 32 KB block (~1 GB/s compress,
            // ~3 GB/s decompress)
            decompress_block_cpu_ns: 10 * MICROS,
            compress_block_cpu_ns: 30 * MICROS,
            put_cpu_ns: 33 * MICROS,
            get_cpu_ns: 2 * MICROS,
            merge_cpu_ns_per_entry: 10 * MICROS,
            flush_cpu_ns_per_entry: MICROS,
            next_cpu_ns: 2 * MICROS,
            batch_cpu_divisor: 4,
            wal_stream: 0,
            vlog_threshold: 0,
            vlog_segment_bytes: 32 << 20,
            vlog_gc_dead_ratio: 0.4,
        }
    }
}

impl LsmOptions {
    /// Target byte size for level `l` (l >= 1).
    pub fn level_target_bytes(&self, level: usize) -> u64 {
        if level == 0 {
            // L0 is file-count driven; report trigger * memtable size.
            return self.l0_compaction_trigger as u64 * self.write_buffer_size;
        }
        let mut target = self.max_bytes_for_level_base;
        for _ in 1..level {
            target = target.saturating_mul(self.level_multiplier);
        }
        target
    }

    /// Bloom geometry for an SST with `keys` entries: bits rounded up to
    /// a multiple of 32.
    pub fn bloom_bits_for(&self, keys: usize) -> u32 {
        let bits = (keys as u32).saturating_mul(self.bloom_bits_per_key).max(64);
        bits.div_ceil(32) * 32
    }

    /// Client CPU for an `ops`-entry group commit: the first op pays the
    /// full `put_cpu_ns`, the rest the amortized share (one WAL
    /// submission + one client round-trip for the whole batch).
    pub fn batch_cpu_ns(&self, ops: u64) -> Nanos {
        if ops == 0 {
            return 0;
        }
        self.put_cpu_ns + (ops - 1) * self.put_cpu_ns / self.batch_cpu_divisor.max(1)
    }

    /// Paper Table III variant: n compaction threads.
    pub fn with_threads(mut self, n: usize) -> Self {
        self.compaction_threads = n;
        self
    }

    pub fn with_slowdown(mut self, enabled: bool) -> Self {
        self.enable_slowdown = enabled;
        self
    }

    /// Bind this store to an explicit device WAL log (sharding).
    pub fn with_wal_stream(mut self, stream: u32) -> Self {
        self.wal_stream = stream;
        self
    }

    /// Block cache capacity in blocks (0 disables the cache).
    pub fn with_cache_blocks(mut self, blocks: usize) -> Self {
        self.block_cache_blocks = blocks;
        self
    }

    pub fn with_compression(mut self, codec: Compression) -> Self {
        self.compression = codec;
        self
    }

    /// Separate values >= `threshold` bytes into the value log (0 off).
    pub fn with_vlog_threshold(mut self, threshold: u32) -> Self {
        self.vlog_threshold = threshold;
        self
    }

    pub fn with_vlog_segment_bytes(mut self, bytes: u64) -> Self {
        self.vlog_segment_bytes = bytes.max(4 << 10);
        self
    }

    /// On-disk size of `logical` bytes under the configured codec.
    pub fn disk_bytes(&self, logical: u64) -> u64 {
        self.compression.disk_bytes(logical)
    }

    /// CPU charged when one block is materialized from the device.
    pub fn decompress_ns(&self) -> Nanos {
        if self.compression.is_none() {
            0
        } else {
            self.decompress_block_cpu_ns
        }
    }

    /// CPU charged per block written by a flush/compaction output.
    pub fn compress_ns(&self) -> Nanos {
        if self.compression.is_none() {
            0
        } else {
            self.compress_block_cpu_ns
        }
    }

    /// Scaled-down configuration for fast tests: small memtables/files so
    /// flushes and compactions trigger after a few hundred entries.
    pub fn small_for_test() -> Self {
        Self {
            write_buffer_size: 64 << 10,
            max_bytes_for_level_base: 256 << 10,
            target_file_size: 64 << 10,
            soft_pending_compaction_bytes: 4 << 20,
            hard_pending_compaction_bytes: 16 << 20,
            block_cache_blocks: 128,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_targets_scale_by_multiplier() {
        let o = LsmOptions::default();
        assert_eq!(o.level_target_bytes(1), 256 << 20);
        assert_eq!(o.level_target_bytes(2), (256 << 20) * 10);
        assert_eq!(o.level_target_bytes(3), (256 << 20) * 100);
    }

    #[test]
    fn bloom_bits_multiple_of_32() {
        let o = LsmOptions::default();
        for keys in [1usize, 10, 1000, 32768] {
            assert_eq!(o.bloom_bits_for(keys) % 32, 0);
            assert!(o.bloom_bits_for(keys) >= keys as u32 * 10 || keys == 1);
        }
    }

    #[test]
    fn builders() {
        let o = LsmOptions::default().with_threads(4).with_slowdown(false);
        assert_eq!(o.compaction_threads, 4);
        assert!(!o.enable_slowdown);
        let o = o
            .with_cache_blocks(0)
            .with_compression(Compression::LzLike { ratio_pct: 50 });
        assert_eq!(o.block_cache_blocks, 0);
        assert_eq!(o.disk_bytes(1000), 500);
        assert!(o.decompress_ns() > 0 && o.compress_ns() > 0);
    }

    #[test]
    fn compression_none_is_identity() {
        let o = LsmOptions::default();
        assert_eq!(o.disk_bytes(12345), 12345);
        assert_eq!(o.decompress_ns(), 0);
        assert_eq!(o.compress_ns(), 0);
    }

    #[test]
    fn compression_ratio_bounds() {
        let c = Compression::LzLike { ratio_pct: 0 };
        assert_eq!(c.disk_bytes(1000), 10); // clamped to 1%
        let c = Compression::LzLike { ratio_pct: 200 };
        assert_eq!(c.disk_bytes(1000), 1000); // clamped to 100%
    }
}
