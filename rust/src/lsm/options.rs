//! LSM engine tuning knobs. Defaults mirror the paper's setup (RocksDB
//! v8.3.2 with 128 MB memtables, Table III) and RocksDB's documented
//! stall/slowdown triggers; the CPU-cost constants are calibrated so the
//! simulated foreground burst rate and stall cadence match the paper's
//! measured shapes (see DESIGN.md §2 and EXPERIMENTS.md).

use crate::sim::{Nanos, MICROS};

#[derive(Clone, Debug)]
pub struct LsmOptions {
    // ----- structure -----
    /// Active memtable capacity (paper Table III: 128 MB).
    pub write_buffer_size: u64,
    /// Max memtables (active + immutable) before writes must stop.
    pub max_write_buffer_number: usize,
    /// L0 file count that triggers L0->L1 compaction.
    pub l0_compaction_trigger: usize,
    /// L0 file count that triggers write slowdown (RocksDB default 20).
    pub l0_slowdown_trigger: usize,
    /// L0 file count that stops writes (RocksDB default 36).
    pub l0_stop_trigger: usize,
    /// Target size of L1 (max_bytes_for_level_base).
    pub max_bytes_for_level_base: u64,
    /// Per-level size multiplier.
    pub level_multiplier: u64,
    pub num_levels: usize,
    /// Output SST target size.
    pub target_file_size: u64,
    /// Pending-compaction-bytes soft limit (slowdown trigger).
    pub soft_pending_compaction_bytes: u64,
    /// Pending-compaction-bytes hard limit (stop trigger).
    pub hard_pending_compaction_bytes: u64,

    // ----- background work -----
    /// Compaction thread count (the paper's swept parameter, Table III).
    pub compaction_threads: usize,

    // ----- slowdown policy -----
    /// RocksDB's slowdown mechanism on/off (Fig 2/3's variable).
    pub enable_slowdown: bool,
    /// Sleep injected per write while in the delayed state (the paper
    /// cites ~1 ms thread sleeps [31]; calibrated to the ~2 Kops/s
    /// slowed-down service floor in Fig 2).
    pub slowdown_sleep_ns: Nanos,

    // ----- SST / read path -----
    /// SST data-block size.
    pub block_bytes: u64,
    /// Block cache capacity in blocks.
    pub block_cache_blocks: usize,
    pub bloom_bits_per_key: u32,
    pub bloom_probes: usize,

    // ----- calibrated CPU cost model -----
    /// Foreground cost of one put (client + WAL memcpy + memtable insert).
    pub put_cpu_ns: Nanos,
    /// Foreground cost of one get step (seek + block decode, pre-I/O).
    pub get_cpu_ns: Nanos,
    /// Compaction merge CPU per entry (decode + compare + encode + CRC).
    pub merge_cpu_ns_per_entry: Nanos,
    /// Flush CPU per entry.
    pub flush_cpu_ns_per_entry: Nanos,
    /// Iterator next CPU per entry (cached path).
    pub next_cpu_ns: Nanos,
    /// Group-commit amortization for `write_batch`: ops after the first
    /// cost `put_cpu_ns / batch_cpu_divisor` each (one WAL submission and
    /// one client round-trip are shared by the whole batch).
    pub batch_cpu_divisor: u64,

    // ----- sharding -----
    /// Which device WAL log this store appends to. A sharded store gives
    /// every shard its own stream (per-shard WAL directory), so each
    /// shard has an independent crash durability cut; 0 is the default
    /// log unsharded engines use.
    pub wal_stream: u32,
}

impl Default for LsmOptions {
    fn default() -> Self {
        Self {
            write_buffer_size: 128 << 20,
            max_write_buffer_number: 2,
            l0_compaction_trigger: 4,
            l0_slowdown_trigger: 20,
            l0_stop_trigger: 36,
            max_bytes_for_level_base: 256 << 20,
            level_multiplier: 10,
            num_levels: 7,
            target_file_size: 64 << 20,
            soft_pending_compaction_bytes: 64 << 30,
            hard_pending_compaction_bytes: 256 << 30,
            compaction_threads: 1,
            enable_slowdown: true,
            slowdown_sleep_ns: 500 * MICROS,
            block_bytes: 32 * 1024,
            block_cache_blocks: 16 * 1024, // 512 MB of 32 KB blocks
            bloom_bits_per_key: 10,
            bloom_probes: 7,
            put_cpu_ns: 33 * MICROS,
            get_cpu_ns: 2 * MICROS,
            merge_cpu_ns_per_entry: 10 * MICROS,
            flush_cpu_ns_per_entry: MICROS,
            next_cpu_ns: 2 * MICROS,
            batch_cpu_divisor: 4,
            wal_stream: 0,
        }
    }
}

impl LsmOptions {
    /// Target byte size for level `l` (l >= 1).
    pub fn level_target_bytes(&self, level: usize) -> u64 {
        if level == 0 {
            // L0 is file-count driven; report trigger * memtable size.
            return self.l0_compaction_trigger as u64 * self.write_buffer_size;
        }
        let mut target = self.max_bytes_for_level_base;
        for _ in 1..level {
            target = target.saturating_mul(self.level_multiplier);
        }
        target
    }

    /// Bloom geometry for an SST with `keys` entries: bits rounded up to
    /// a multiple of 32.
    pub fn bloom_bits_for(&self, keys: usize) -> u32 {
        let bits = (keys as u32).saturating_mul(self.bloom_bits_per_key).max(64);
        bits.div_ceil(32) * 32
    }

    /// Client CPU for an `ops`-entry group commit: the first op pays the
    /// full `put_cpu_ns`, the rest the amortized share (one WAL
    /// submission + one client round-trip for the whole batch).
    pub fn batch_cpu_ns(&self, ops: u64) -> Nanos {
        if ops == 0 {
            return 0;
        }
        self.put_cpu_ns + (ops - 1) * self.put_cpu_ns / self.batch_cpu_divisor.max(1)
    }

    /// Paper Table III variant: n compaction threads.
    pub fn with_threads(mut self, n: usize) -> Self {
        self.compaction_threads = n;
        self
    }

    pub fn with_slowdown(mut self, enabled: bool) -> Self {
        self.enable_slowdown = enabled;
        self
    }

    /// Bind this store to an explicit device WAL log (sharding).
    pub fn with_wal_stream(mut self, stream: u32) -> Self {
        self.wal_stream = stream;
        self
    }

    /// Scaled-down configuration for fast tests: small memtables/files so
    /// flushes and compactions trigger after a few hundred entries.
    pub fn small_for_test() -> Self {
        Self {
            write_buffer_size: 64 << 10,
            max_bytes_for_level_base: 256 << 10,
            target_file_size: 64 << 10,
            soft_pending_compaction_bytes: 4 << 20,
            hard_pending_compaction_bytes: 16 << 20,
            block_cache_blocks: 128,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_targets_scale_by_multiplier() {
        let o = LsmOptions::default();
        assert_eq!(o.level_target_bytes(1), 256 << 20);
        assert_eq!(o.level_target_bytes(2), (256 << 20) * 10);
        assert_eq!(o.level_target_bytes(3), (256 << 20) * 100);
    }

    #[test]
    fn bloom_bits_multiple_of_32() {
        let o = LsmOptions::default();
        for keys in [1usize, 10, 1000, 32768] {
            assert_eq!(o.bloom_bits_for(keys) % 32, 0);
            assert!(o.bloom_bits_for(keys) >= keys as u32 * 10 || keys == 1);
        }
    }

    #[test]
    fn builders() {
        let o = LsmOptions::default().with_threads(4).with_slowdown(false);
        assert_eq!(o.compaction_threads, 4);
        assert!(!o.enable_slowdown);
    }
}
