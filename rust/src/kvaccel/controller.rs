//! Controller module (paper §V-C): turns Detector reports into per-
//! operation interface decisions.
//!
//! Write path: stall imminent -> Dev-LSM (KV interface); otherwise
//! Main-LSM (block interface). Read path: Metadata Manager membership
//! decides. The Controller also refuses to redirect when the KV region
//! is nearly full (backpressure — the buffer is finite NAND space).

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WritePath {
    Main,
    Dev,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadPath {
    Main,
    Dev,
}

#[derive(Clone, Debug)]
pub struct ControllerConfig {
    /// Refuse redirection beyond this KV-region occupancy.
    pub max_kv_occupancy: f64,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        Self { max_kv_occupancy: 0.9 }
    }
}

#[derive(Clone, Debug, Default)]
pub struct ControllerStats {
    pub writes_to_main: u64,
    pub writes_to_dev: u64,
    pub reads_from_main: u64,
    pub reads_from_dev: u64,
    pub redirect_refusals: u64,
}

#[derive(Debug, Default)]
pub struct Controller {
    pub cfg: ControllerConfig,
    pub stats: ControllerStats,
}

impl Controller {
    pub fn new(cfg: ControllerConfig) -> Self {
        Self { cfg, stats: ControllerStats::default() }
    }

    /// Decide the write path from the Detector's report.
    pub fn write_path(&mut self, stall_imminent: bool, kv_occupancy: f64) -> WritePath {
        if stall_imminent {
            if kv_occupancy < self.cfg.max_kv_occupancy {
                self.stats.writes_to_dev += 1;
                return WritePath::Dev;
            }
            self.stats.redirect_refusals += 1;
        }
        self.stats.writes_to_main += 1;
        WritePath::Main
    }

    /// Decide the read path from metadata membership.
    pub fn read_path(&mut self, key_in_dev: bool) -> ReadPath {
        if key_in_dev {
            self.stats.reads_from_dev += 1;
            ReadPath::Dev
        } else {
            self.stats.reads_from_main += 1;
            ReadPath::Main
        }
    }

    /// Redirection ratio so far (reporting).
    pub fn redirect_fraction(&self) -> f64 {
        let total = self.stats.writes_to_main + self.stats.writes_to_dev;
        if total == 0 {
            0.0
        } else {
            self.stats.writes_to_dev as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_by_stall_signal() {
        let mut c = Controller::default();
        assert_eq!(c.write_path(false, 0.0), WritePath::Main);
        assert_eq!(c.write_path(true, 0.0), WritePath::Dev);
        assert_eq!(c.stats.writes_to_main, 1);
        assert_eq!(c.stats.writes_to_dev, 1);
    }

    #[test]
    fn backpressure_refuses_redirect() {
        let mut c = Controller::default();
        assert_eq!(c.write_path(true, 0.95), WritePath::Main);
        assert_eq!(c.stats.redirect_refusals, 1);
    }

    #[test]
    fn read_path_follows_metadata() {
        let mut c = Controller::default();
        assert_eq!(c.read_path(true), ReadPath::Dev);
        assert_eq!(c.read_path(false), ReadPath::Main);
    }

    #[test]
    fn redirect_fraction_math() {
        let mut c = Controller::default();
        c.write_path(true, 0.0);
        c.write_path(false, 0.0);
        c.write_path(false, 0.0);
        c.write_path(true, 0.0);
        assert!((c.redirect_fraction() - 0.5).abs() < 1e-9);
    }
}
