//! KVACCEL software modules (paper §V): Detector, Controller, Metadata
//! Manager, Rollback Manager, the dual-interface range query, and the
//! assembled `KvaccelDb`.

pub mod controller;
pub mod db;
pub mod detector;
pub mod metadata;
pub mod range_query;
pub mod rollback;

pub use controller::{Controller, ControllerConfig, ReadPath, WritePath};
pub use db::{KvaccelConfig, KvaccelDb};
pub use detector::{Detector, DetectorConfig, DetectorSample};
pub use metadata::{MetadataConfig, MetadataManager};
pub use range_query::DevIterator;
pub use rollback::{RollbackConfig, RollbackManager, RollbackScheme};
