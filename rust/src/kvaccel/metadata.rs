//! Metadata Manager (paper §V-C): an in-memory membership table tracking
//! which keys currently live in the Dev-LSM, consulted on every
//! read/write for interface routing ("membership testing"). The paper
//! uses a hash table; this reproduction keeps the set ordered
//! (`BTreeSet`) so any iteration over the routing set is deterministic
//! — the Table VI per-op costs are charged explicitly either way.
//!
//! On loss (crash), the table is rebuilt by a full range scan of the
//! key-value interface — `rebuild_from` implements that recovery path.
//!
//! Per-op costs are charged from the paper's measured overheads
//! (Table VI: insert 0.45 us, check 0.20 us, delete 0.28 us).

use std::collections::BTreeSet;
use std::sync::Arc;

use crate::env::SimEnv;
use crate::lsm::entry::{Entry, Key};
use crate::sim::{CpuClass, Nanos};

#[derive(Clone, Debug)]
pub struct MetadataConfig {
    pub insert_cost_ns: Nanos,
    pub check_cost_ns: Nanos,
    pub delete_cost_ns: Nanos,
}

impl Default for MetadataConfig {
    fn default() -> Self {
        Self { insert_cost_ns: 450, check_cost_ns: 200, delete_cost_ns: 280 }
    }
}

#[derive(Clone, Debug, Default)]
pub struct MetadataStats {
    pub inserts: u64,
    pub checks: u64,
    pub deletes: u64,
    pub rebuilds: u64,
}

#[derive(Debug)]
pub struct MetadataManager {
    cfg: MetadataConfig,
    in_dev: BTreeSet<Key>,
    /// Cached refcounted copy of `in_dev` handed to snapshots;
    /// invalidated by any mutation (copy-on-write pinning).
    pinned: Option<Arc<BTreeSet<Key>>>,
    pub stats: MetadataStats,
}

impl MetadataManager {
    pub fn new(cfg: MetadataConfig) -> Self {
        Self {
            cfg,
            in_dev: BTreeSet::new(),
            pinned: None,
            stats: MetadataStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.in_dev.len()
    }

    pub fn is_empty(&self) -> bool {
        self.in_dev.is_empty()
    }

    /// Record that `key`'s latest version now lives in the Dev-LSM.
    pub fn insert(&mut self, env: &mut SimEnv, at: Nanos, key: Key) {
        self.stats.inserts += 1;
        env.cpu.charge(CpuClass::Kvaccel, at, self.cfg.insert_cost_ns);
        self.pinned = None;
        self.in_dev.insert(key);
    }

    /// Membership test: does the latest version of `key` live in Dev-LSM?
    pub fn check(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> bool {
        self.stats.checks += 1;
        env.cpu.charge(CpuClass::Kvaccel, at, self.cfg.check_cost_ns);
        self.in_dev.contains(&key)
    }

    /// The write-path step (3-1): a fresh Main-LSM write supersedes the
    /// Dev-LSM copy. Returns true if a record was removed.
    pub fn delete(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> bool {
        self.stats.deletes += 1;
        env.cpu.charge(CpuClass::Kvaccel, at, self.cfg.delete_cost_ns);
        self.pinned = None;
        self.in_dev.remove(&key)
    }

    /// Drop everything (rollback completed; Dev-LSM was reset). Live
    /// snapshots keep their own pinned copy of the routing set, so a
    /// scan spanning the rollback window stays consistent.
    pub fn clear(&mut self) {
        self.pinned = None;
        self.in_dev.clear();
    }

    /// Crash recovery: rebuild from a full KV-interface range scan.
    pub fn rebuild_from(&mut self, entries: &[Entry]) {
        self.stats.rebuilds += 1;
        self.pinned = None;
        self.in_dev.clear();
        self.in_dev.extend(entries.iter().map(|e| e.key));
    }

    /// Recovery rebuild with host-device reconciliation already applied
    /// by the caller (only keys whose device copy is the newest durable
    /// version): installs the routing set in one pass and charges the
    /// Table VI insert cost in bulk. Returns when the rebuild is done.
    pub fn rebuild_routing(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        keys: impl IntoIterator<Item = Key>,
    ) -> Nanos {
        self.stats.rebuilds += 1;
        self.pinned = None;
        self.in_dev.clear();
        let mut n = 0u64;
        for k in keys {
            self.in_dev.insert(k);
            n += 1;
        }
        self.stats.inserts += n;
        let cost = n * self.cfg.insert_cost_ns;
        env.cpu.charge(CpuClass::Kvaccel, at, cost);
        at + cost
    }

    /// Refcounted copy of the routing set for snapshot pinning. Cached
    /// until the next mutation, so read-only phases (e.g. seekrandom)
    /// pin in O(1).
    pub fn pin(&mut self) -> Arc<BTreeSet<Key>> {
        if let Some(p) = &self.pinned {
            return p.clone();
        }
        let p = Arc::new(self.in_dev.clone());
        self.pinned = Some(p.clone());
        p
    }

    /// Zero-cost read used by rollback filtering (no Table VI charge: the
    /// rollback batch walks the table directly).
    pub fn contains(&self, key: Key) -> bool {
        self.in_dev.contains(&key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsm::ValueDesc;
    use crate::ssd::SsdConfig;

    fn rig() -> (MetadataManager, SimEnv) {
        (
            MetadataManager::new(MetadataConfig::default()),
            SimEnv::new(3, SsdConfig::default()),
        )
    }

    #[test]
    fn insert_check_delete_cycle() {
        let (mut m, mut env) = rig();
        assert!(!m.check(&mut env, 0, 5));
        m.insert(&mut env, 0, 5);
        assert!(m.check(&mut env, 0, 5));
        assert!(m.delete(&mut env, 0, 5));
        assert!(!m.check(&mut env, 0, 5));
        assert!(!m.delete(&mut env, 0, 5));
        assert_eq!(m.stats.inserts, 1);
        assert_eq!(m.stats.checks, 3);
        assert_eq!(m.stats.deletes, 2);
    }

    #[test]
    fn costs_charged() {
        let (mut m, mut env) = rig();
        m.insert(&mut env, 0, 1);
        m.check(&mut env, 0, 1);
        m.delete(&mut env, 0, 1);
        assert_eq!(env.cpu.busy(CpuClass::Kvaccel), 450 + 200 + 280);
    }

    #[test]
    fn rebuild_matches_scan() {
        let (mut m, mut env) = rig();
        m.insert(&mut env, 0, 1);
        let entries: Vec<Entry> = [7u32, 9, 11]
            .iter()
            .map(|&k| Entry::new(k, 1, ValueDesc::new(k, 10)))
            .collect();
        m.rebuild_from(&entries);
        assert_eq!(m.len(), 3);
        assert!(!m.contains(1));
        assert!(m.contains(9));
    }

    #[test]
    fn rebuild_routing_charges_bulk_inserts() {
        let (mut m, mut env) = rig();
        let before = env.cpu.busy(CpuClass::Kvaccel);
        let done = m.rebuild_routing(&mut env, 100, [1u32, 2, 3]);
        assert_eq!(done, 100 + 3 * 450);
        assert_eq!(env.cpu.busy(CpuClass::Kvaccel) - before, 3 * 450);
        assert_eq!(m.len(), 3);
        assert!(m.contains(2));
        assert_eq!(m.stats.rebuilds, 1);
    }

    #[test]
    fn clear_empties() {
        let (mut m, mut env) = rig();
        m.insert(&mut env, 0, 1);
        m.clear();
        assert!(m.is_empty());
    }
}
