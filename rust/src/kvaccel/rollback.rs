//! Rollback Manager (paper §V-E): aggregates the two LSMs back into one
//! by draining the Dev-LSM through the in-device iterator-based bulky
//! range scan, DMA-ing 512 KB chunks to host memory, merging into the
//! Main-LSM, and finally resetting the Dev-LSM.
//!
//! Scheduling schemes (paper): **eager** triggers as soon as the Detector
//! reports calm and the Dev-LSM is non-empty (read-oriented workloads);
//! **lazy** waits for a sustained quiet period or KV-region pressure
//! (write-intensive workloads).

use anyhow::Result;

use crate::env::SimEnv;
use crate::lsm::LsmDb;
use crate::sim::{CpuClass, Nanos};
use crate::ssd::kv_if::NamespaceId;

use super::detector::Detector;
use super::metadata::MetadataManager;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RollbackScheme {
    Eager,
    Lazy,
    /// Never roll back during the run (the paper's write-optimized
    /// workload-A configuration; a final rollback runs at `finish`).
    Disabled,
}

#[derive(Clone, Debug)]
pub struct RollbackConfig {
    pub scheme: RollbackScheme,
    /// Lazy: consecutive calm detector ticks before rolling back.
    pub lazy_quiet_ticks: u64,
    /// Lazy: KV-region occupancy fraction that forces a rollback.
    pub lazy_occupancy_limit: f64,
    /// Host CPU per merged-back entry.
    pub merge_cpu_ns_per_entry: Nanos,
}

impl Default for RollbackConfig {
    fn default() -> Self {
        Self {
            scheme: RollbackScheme::Eager,
            lazy_quiet_ticks: 50, // 5 s of calm at the 0.1 s tick
            lazy_occupancy_limit: 0.5,
            merge_cpu_ns_per_entry: 1_000,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct RollbackStats {
    pub rollbacks: u64,
    pub entries_returned: u64,
    pub entries_stale_skipped: u64,
    pub total_rollback_ns: Nanos,
    pub last_completion: Nanos,
}

/// An open rollback window: the merge-back (Fig 9 steps 3-7) has run,
/// the device reset + metadata clear (step 8) are deferred to
/// [`RollbackManager::finalize`] at the completion horizon. A crash
/// inside the window leaves both copies in place — the device runs
/// intact, the merged copies in the (possibly unsynced) Main-LSM WAL —
/// and recovery reconciles per key by sequence number.
#[derive(Clone, Copy, Debug)]
struct PendingRollback {
    started: Nanos,
    end: Nanos,
    returned: u64,
}

#[derive(Debug)]
pub struct RollbackManager {
    pub cfg: RollbackConfig,
    /// completion horizon of an in-flight rollback (no re-trigger before).
    in_flight_until: Nanos,
    pending: Option<PendingRollback>,
    pub stats: RollbackStats,
}

impl RollbackManager {
    pub fn new(cfg: RollbackConfig) -> Self {
        Self {
            cfg,
            in_flight_until: 0,
            pending: None,
            stats: RollbackStats::default(),
        }
    }

    /// Is a rollback window open at `at`? While it is, the Controller
    /// routes every write through the Main-LSM (redirecting into a
    /// buffer that is being drained would race the deferred reset).
    pub fn in_flight(&self, at: Nanos) -> bool {
        self.pending.is_some() && at < self.in_flight_until
    }

    /// Completion horizon of the open window, if any.
    pub fn pending_end(&self) -> Option<Nanos> {
        self.pending.map(|p| p.end)
    }

    /// Should a rollback start now? Consulted on detector ticks.
    pub fn should_rollback(
        &self,
        at: Nanos,
        detector: &Detector,
        dev_empty: bool,
        kv_occupancy: f64,
    ) -> bool {
        if dev_empty || at < self.in_flight_until || detector.stall_imminent() {
            return false;
        }
        match self.cfg.scheme {
            RollbackScheme::Eager => true,
            RollbackScheme::Lazy => {
                detector.calm_ticks >= self.cfg.lazy_quiet_ticks
                    || kv_occupancy >= self.cfg.lazy_occupancy_limit
            }
            RollbackScheme::Disabled => false,
        }
    }

    /// Phase 1 of a rollback (paper Fig 9 steps 3-7):
    ///  3-4: device iterator scans the whole Dev-LSM;
    ///  5-6: bulk-serialized pairs DMA to host in 512 KB chunks;
    ///  7:   host merges them into the Main-LSM (stale pairs — already
    ///       superseded by newer Main-LSM writes per the Metadata Manager
    ///       — are dropped).
    ///
    /// The device reset and metadata clear (step 8) are DEFERRED to
    /// [`RollbackManager::finalize`] at the returned completion horizon,
    /// so a crash inside the window never tears the redirection: the
    /// device copy stays durable until the merged-back copy is.
    ///
    /// Runs as a detached background activity in virtual time: device and
    /// CPU costs are charged, Main-LSM state changes apply immediately,
    /// and the foreground is not blocked (`at` is not advanced for the
    /// caller). Returns the completion horizon.
    pub fn begin(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        ns: NamespaceId,
        main: &mut LsmDb,
        metadata: &mut MetadataManager,
    ) -> Result<Nanos> {
        let (entries, dma_done) = env.device.kv_bulk_scan(ns, at)?;
        let mut t = dma_done;
        let mut returned = 0u64;
        for e in &entries {
            // step 7 filter: only keys the metadata manager still routes
            // to the Dev-LSM are live; the rest were overwritten in main.
            if !metadata.contains(e.key) {
                self.stats.entries_stale_skipped += 1;
                continue;
            }
            returned += 1;
            env.cpu.charge(CpuClass::Kvaccel, t, self.cfg.merge_cpu_ns_per_entry);
            t = main.put_internal(env, t, e.key, e.val);
        }
        self.stats.entries_returned += returned;
        let end = t.max(at + 1);
        self.pending = Some(PendingRollback { started: at, end, returned });
        self.in_flight_until = end;
        Ok(end)
    }

    /// Phase 2 (Fig 9 step 8), at/after the window's completion horizon:
    /// fsync the merged-back copies, then reset the Dev-LSM and clear
    /// the routing table. The sync-before-reset ordering is the
    /// consistency linchpin: the device copy is only dropped once the
    /// host copy is durable, so no crash point can lose an acked
    /// redirected write. Returns `Some((done, entries_returned))` if a
    /// window was open.
    pub fn finalize(
        &mut self,
        env: &mut SimEnv,
        ns: NamespaceId,
        wal_stream: u32,
        metadata: &mut MetadataManager,
    ) -> Result<Option<(Nanos, u64)>> {
        let Some(p) = self.pending.take() else {
            return Ok(None);
        };
        let synced = env.device.wal_sync_on(wal_stream, p.end);
        let reset_done = env.device.kv_reset(ns, synced)?;
        metadata.clear();
        let done = reset_done.max(p.end);
        // a rollback counts once it has fully completed (reset issued)
        self.stats.rollbacks += 1;
        self.stats.total_rollback_ns += done.saturating_sub(p.started);
        self.stats.last_completion = done;
        self.in_flight_until = done;
        Ok(Some((done, p.returned)))
    }

    /// One-shot rollback: begin + immediate finalize (the end-of-run
    /// drain in `finish`, and direct test use). Returns the completion
    /// horizon.
    pub fn perform(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        ns: NamespaceId,
        main: &mut LsmDb,
        metadata: &mut MetadataManager,
    ) -> Result<Nanos> {
        self.begin(env, at, ns, main, metadata)?;
        let stream = main.opts.wal_stream;
        let (done, _) = self
            .finalize(env, ns, stream, metadata)?
            .ok_or_else(|| anyhow::anyhow!("rollback window vanished between begin and finalize"))?;
        Ok(done)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvaccel::detector::DetectorConfig;
    use crate::lsm::{Entry, LsmOptions, ValueDesc};
    use crate::runtime::{BloomBuilder, MergeEngine};
    use crate::ssd::SsdConfig;

    fn rig() -> (LsmDb, SimEnv, Detector, MetadataManager, RollbackManager) {
        (
            LsmDb::new(
                LsmOptions::small_for_test(),
                MergeEngine::rust(),
                BloomBuilder::rust(),
            ),
            SimEnv::new(5, SsdConfig::default()),
            Detector::new(DetectorConfig::default()),
            MetadataManager::new(Default::default()),
            RollbackManager::new(RollbackConfig::default()),
        )
    }

    fn dev_put(env: &mut SimEnv, meta: &mut MetadataManager, k: u32, seq: u32) {
        let e = Entry::new(k, seq, ValueDesc::new(k + seq, 512));
        env.device.kv_put(0, 0, e).unwrap();
        meta.insert(env, 0, k);
    }

    #[test]
    fn rollback_moves_entries_to_main() {
        let (mut main, mut env, mut det, mut meta, mut rb) = rig();
        for k in 0..20u32 {
            dev_put(&mut env, &mut meta, k, k + 1);
        }
        det.sample(&mut env, 0, &main);
        assert!(rb.should_rollback(0, &det, env.device.kv_is_empty(0), 0.0));
        let end = rb.perform(&mut env, 0, 0, &mut main, &mut meta).unwrap();
        assert!(end > 0);
        assert!(env.device.kv_is_empty(0));
        assert!(meta.is_empty());
        for k in 0..20u32 {
            let (v, _) = main.get(&mut env, end, k);
            assert_eq!(v, Some(ValueDesc::new(k + k + 1, 512)), "key {k}");
        }
        assert_eq!(rb.stats.entries_returned, 20);
    }

    #[test]
    fn stale_entries_skipped() {
        let (mut main, mut env, _det, mut meta, mut rb) = rig();
        dev_put(&mut env, &mut meta, 1, 1);
        dev_put(&mut env, &mut meta, 2, 1);
        // key 1 later overwritten in main: metadata record removed
        main.put(&mut env, 0, 1, ValueDesc::new(999, 512));
        meta.delete(&mut env, 0, 1);
        let end = rb.perform(&mut env, 0, 0, &mut main, &mut meta).unwrap();
        let (v1, _) = main.get(&mut env, end, 1);
        assert_eq!(v1, Some(ValueDesc::new(999, 512)), "stale dev copy must not win");
        let (v2, _) = main.get(&mut env, end, 2);
        assert_eq!(v2, Some(ValueDesc::new(3, 512)));
        assert_eq!(rb.stats.entries_stale_skipped, 1);
    }

    #[test]
    fn schemes_gate_triggering() {
        let (main, mut env, mut det, _meta, _rb) = rig();
        det.sample(&mut env, 0, &main);
        let eager = RollbackManager::new(RollbackConfig {
            scheme: RollbackScheme::Eager,
            ..Default::default()
        });
        let lazy = RollbackManager::new(RollbackConfig {
            scheme: RollbackScheme::Lazy,
            lazy_quiet_ticks: 100,
            ..Default::default()
        });
        let off = RollbackManager::new(RollbackConfig {
            scheme: RollbackScheme::Disabled,
            ..Default::default()
        });
        assert!(eager.should_rollback(0, &det, false, 0.0));
        assert!(!lazy.should_rollback(0, &det, false, 0.0), "lazy needs quiet");
        assert!(lazy.should_rollback(0, &det, false, 0.9), "occupancy forces lazy");
        assert!(!off.should_rollback(0, &det, false, 0.9));
        // nothing to do when dev empty
        assert!(!eager.should_rollback(0, &det, true, 0.0));
    }

    #[test]
    fn window_defers_reset_until_finalize() {
        let (mut main, mut env, _det, mut meta, mut rb) = rig();
        for k in 0..10u32 {
            dev_put(&mut env, &mut meta, k, k + 1);
        }
        let end = rb.begin(&mut env, 0, 0, &mut main, &mut meta).unwrap();
        // inside the window: device buffer + routing table still intact
        assert!(rb.in_flight(end - 1));
        assert!(!env.device.kv_is_empty(0), "reset must be deferred");
        assert!(!meta.is_empty(), "routing cleared only at finalize");
        let (done, returned) = rb.finalize(&mut env, 0, 0, &mut meta).unwrap().unwrap();
        assert!(done >= end);
        assert_eq!(returned, 10);
        assert!(env.device.kv_is_empty(0));
        assert!(meta.is_empty());
        assert!(rb.finalize(&mut env, 0, 0, &mut meta).unwrap().is_none());
    }

    #[test]
    fn no_retrigger_while_in_flight() {
        let (mut main, mut env, mut det, mut meta, mut rb) = rig();
        dev_put(&mut env, &mut meta, 1, 1);
        det.sample(&mut env, 0, &main);
        let end = rb.perform(&mut env, 0, 0, &mut main, &mut meta).unwrap();
        dev_put(&mut env, &mut meta, 2, 2);
        assert!(!rb.should_rollback(end - 1, &det, false, 0.0));
        assert!(rb.should_rollback(end, &det, false, 0.0));
    }
}
