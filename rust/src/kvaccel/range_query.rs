//! Range query support (paper §V-F, Fig 10): the Dev-LSM side of the
//! dual-interface cursor. [`DevIterator`] is a host-side
//! seekable/reversible merge over the device write buffer's runs (SEEK +
//! NEXT/PREV through the KV interface); it plugs into
//! [`crate::engine::EngineIterator`] as one source of the aggregated
//! merge, where a comparator switches between interfaces as key order
//! dictates.
//!
//! The Dev-LSM has no read cache — a SEEK pays one NAND page read per
//! on-flash run (the device walks its run indexes), and every
//! `entries_per_page` NEXTs cross a page boundary and pay another.
//! That amortization restarts on every re-seek (a fresh SEEK lands on a
//! fresh page), which is exactly the Table V performance gap between
//! Main-LSM and Dev-LSM range reads.

use std::sync::Arc;

use crate::env::SimEnv;
use crate::lsm::entry::{Entry, Key, Seq};
use crate::sim::Nanos;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Dir {
    Fwd,
    Bwd,
}

#[derive(Clone, Copy, Debug)]
struct RunPos {
    idx: usize,
    valid: bool,
}

/// Host-side cursor over a pinned set of Dev-LSM runs (run 0 is the
/// materialized device memtable — DRAM, so it never pays NAND reads).
/// Entries newer than `visible_seq` are skipped (snapshot visibility on
/// the device-side sequence domain).
pub struct DevIterator {
    runs: Vec<Arc<Vec<Entry>>>,
    pos: Vec<RunPos>,
    visible_seq: Seq,
    /// entries per NAND page (amortized read granularity)
    entries_per_page: usize,
    nexts_since_read: usize,
    pages_read: u64,
    dir: Dir,
    current: Option<Entry>,
}

impl DevIterator {
    pub fn new(runs: Vec<Arc<Vec<Entry>>>, page_bytes: u64, avg_entry: u64) -> Self {
        let n = runs.len();
        Self {
            runs,
            pos: vec![RunPos { idx: 0, valid: false }; n],
            visible_seq: Seq::MAX,
            entries_per_page: (page_bytes / avg_entry.max(1)).max(1) as usize,
            nexts_since_read: 0,
            pages_read: 0,
            dir: Dir::Fwd,
            current: None,
        }
    }

    /// Hide device entries newer than `seq` (snapshot visibility).
    pub fn with_visible_seq(mut self, seq: Seq) -> Self {
        self.visible_seq = seq;
        self
    }

    /// NAND pages this cursor has read so far.
    pub fn pages_read(&self) -> u64 {
        self.pages_read
    }

    pub fn valid(&self) -> bool {
        self.current.is_some()
    }

    /// Current entry without advancing (comparator input).
    pub fn entry(&self) -> Option<Entry> {
        self.current
    }

    /// Current head key without advancing.
    pub fn peek_key(&self) -> Option<Key> {
        self.current.map(|e| e.key)
    }

    fn page_read(&mut self, env: &mut SimEnv, t: Nanos) -> Nanos {
        self.pages_read += 1;
        env.device.kv_iter_page_read(t)
    }

    // ----- per-run cursor helpers -------------------------------------

    fn run_norm_fwd(&mut self, i: usize) {
        loop {
            let run = &self.runs[i];
            let p = &mut self.pos[i];
            if !p.valid {
                return;
            }
            match run.get(p.idx) {
                Some(e) if e.seq > self.visible_seq => {
                    p.idx += 1;
                    if p.idx >= run.len() {
                        p.valid = false;
                        return;
                    }
                }
                Some(_) => return,
                None => {
                    p.valid = false;
                    return;
                }
            }
        }
    }

    fn run_norm_bwd(&mut self, i: usize) {
        loop {
            let run = &self.runs[i];
            let p = &mut self.pos[i];
            if !p.valid {
                return;
            }
            match run.get(p.idx) {
                Some(e) if e.seq > self.visible_seq => {
                    if p.idx == 0 {
                        p.valid = false;
                        return;
                    }
                    p.idx -= 1;
                }
                Some(_) => return,
                None => {
                    p.valid = false;
                    return;
                }
            }
        }
    }

    fn seek_run_fwd(&mut self, i: usize, key: Key) {
        let run = &self.runs[i];
        let idx = run.partition_point(|e| e.key < key);
        self.pos[i] = RunPos { idx, valid: idx < run.len() };
        self.run_norm_fwd(i);
    }

    fn seek_run_bwd(&mut self, i: usize, key: Key) {
        let run = &self.runs[i];
        let pp = run.partition_point(|e| e.key <= key);
        self.pos[i] = RunPos { idx: pp.saturating_sub(1), valid: pp > 0 };
        self.run_norm_bwd(i);
    }

    fn skip_past_run_fwd(&mut self, i: usize, key: Key) {
        loop {
            let run = &self.runs[i];
            let p = &mut self.pos[i];
            if !p.valid {
                return;
            }
            match run.get(p.idx) {
                Some(e) if e.key <= key => {
                    p.idx += 1;
                    if p.idx >= run.len() {
                        p.valid = false;
                        return;
                    }
                }
                Some(_) => break,
                None => {
                    p.valid = false;
                    return;
                }
            }
        }
        self.run_norm_fwd(i);
    }

    fn skip_past_run_bwd(&mut self, i: usize, key: Key) {
        loop {
            let run = &self.runs[i];
            let p = &mut self.pos[i];
            if !p.valid {
                return;
            }
            match run.get(p.idx) {
                Some(e) if e.key >= key => {
                    if p.idx == 0 {
                        p.valid = false;
                        return;
                    }
                    p.idx -= 1;
                }
                Some(_) => break,
                None => {
                    p.valid = false;
                    return;
                }
            }
        }
        self.run_norm_bwd(i);
    }

    // ----- merge across runs ------------------------------------------

    fn pick(&self, backward: bool) -> Option<Entry> {
        let mut best: Option<Entry> = None;
        for (i, run) in self.runs.iter().enumerate() {
            let p = self.pos[i];
            if !p.valid {
                continue;
            }
            if let Some(&e) = run.get(p.idx) {
                best = Some(match best {
                    None => e,
                    Some(b)
                        if (!backward && e.key < b.key)
                            || (backward && e.key > b.key)
                            || (e.key == b.key && e.seq > b.seq) =>
                    {
                        e
                    }
                    Some(b) => b,
                });
            }
        }
        best
    }

    fn settle_fwd(&mut self) {
        match self.pick(false) {
            Some(e) => {
                for i in 0..self.runs.len() {
                    self.skip_past_run_fwd(i, e.key);
                }
                self.current = Some(e);
            }
            None => self.current = None,
        }
    }

    fn settle_bwd(&mut self) {
        match self.pick(true) {
            Some(e) => {
                for i in 0..self.runs.len() {
                    self.skip_past_run_bwd(i, e.key);
                }
                self.current = Some(e);
            }
            None => self.current = None,
        }
    }

    // ----- movement ---------------------------------------------------

    /// SEEK: position every run at the first visible key >= `key`. Each
    /// on-flash run pays one NAND page read; the per-page NEXT
    /// amortization restarts (a fresh SEEK reads a fresh page).
    pub fn seek(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> Nanos {
        let mut t = at;
        self.dir = Dir::Fwd;
        self.nexts_since_read = 0;
        for i in 0..self.runs.len() {
            self.seek_run_fwd(i, key);
            if i > 0 && !self.runs[i].is_empty() {
                // run 0 is the device memtable (DRAM) — no NAND read
                t = self.page_read(env, t);
            }
        }
        self.settle_fwd();
        t
    }

    /// SEEK-FOR-PREV: position at the last visible key <= `key`.
    pub fn seek_for_prev(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> Nanos {
        let mut t = at;
        self.dir = Dir::Bwd;
        self.nexts_since_read = 0;
        for i in 0..self.runs.len() {
            self.seek_run_bwd(i, key);
            if i > 0 && !self.runs[i].is_empty() {
                t = self.page_read(env, t);
            }
        }
        self.settle_bwd();
        t
    }

    /// NEXT: consume the current entry and move to the next visible key
    /// (newest version per key), charging an amortized NAND page read.
    pub fn step_forward(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        let Some(cur) = self.current else { return at };
        if self.dir == Dir::Bwd {
            // direction switch: a fresh device SEEK past the current key
            return self.seek(env, at, cur.key.saturating_add(1));
        }
        let mut t = at;
        self.nexts_since_read += 1;
        if self.nexts_since_read >= self.entries_per_page {
            self.nexts_since_read = 0;
            t = self.page_read(env, t);
        }
        self.settle_fwd();
        t
    }

    /// PREV: consume the current entry and move to the previous visible
    /// key.
    pub fn step_backward(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        let Some(cur) = self.current else { return at };
        if self.dir == Dir::Fwd {
            if cur.key == 0 {
                self.current = None;
                return at;
            }
            return self.seek_for_prev(env, at, cur.key - 1);
        }
        let mut t = at;
        self.nexts_since_read += 1;
        if self.nexts_since_read >= self.entries_per_page {
            self.nexts_since_read = 0;
            t = self.page_read(env, t);
        }
        self.settle_bwd();
        t
    }

    /// Streaming accessor: return the current entry and advance.
    pub fn next(&mut self, env: &mut SimEnv, at: Nanos) -> (Option<Entry>, Nanos) {
        let Some(e) = self.current else { return (None, at) };
        let t = self.step_forward(env, at);
        (Some(e), t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{
        new_block_cache, DbIterator, DevPin, EngineIterator, IterCost, IterOptions,
        ScanCounters, Snapshot,
    };
    use crate::lsm::entry::ValueDesc;
    use crate::lsm::LsmOptions;
    use crate::ssd::SsdConfig;
    use std::collections::BTreeSet;

    fn env() -> SimEnv {
        SimEnv::new(11, SsdConfig::default())
    }

    fn e(k: Key, s: u32) -> Entry {
        Entry::new(k, s, ValueDesc::new(s, 64))
    }

    fn dev_iter(env: &mut SimEnv, keys: &[(Key, u32)]) -> DevIterator {
        let mut t = 0;
        for &(k, s) in keys {
            t = env.device.kv_put(0, t, e(k, s)).unwrap();
        }
        let snap = env.device.kv_snapshot(0).unwrap();
        DevIterator::new(snap.runs, 16 * 1024, 4112)
    }

    /// Aggregated cursor over a materialized main run + the given dev
    /// runs, with `live` as the pinned metadata routing set.
    fn dual(
        main: Vec<Entry>,
        dev_runs: Vec<Arc<Vec<Entry>>>,
        live: &[Key],
    ) -> EngineIterator {
        let pin = DevPin {
            runs: dev_runs,
            live: Arc::new(live.iter().copied().collect::<BTreeSet<Key>>()),
            page_bytes: 16 * 1024,
            avg_entry: 4112,
        };
        let snap = Snapshot::pin(
            Seq::MAX,
            Seq::MAX,
            0,
            vec![Arc::new(main)],
            vec![],
            vec![],
            Some(pin),
        );
        let opts = LsmOptions::default();
        EngineIterator::new(
            snap,
            &IterOptions::default(),
            IterCost::from_opts(&opts),
            Arc::new(ScanCounters::default()),
            new_block_cache(opts.block_cache_blocks),
        )
    }

    #[test]
    fn dev_iterator_orders_and_dedups() {
        let mut env = env();
        let mut it = dev_iter(&mut env, &[(5, 1), (1, 1), (9, 1), (5, 7)]);
        it.seek(&mut env, 0, 0);
        let mut got = Vec::new();
        let mut t = 0;
        while let (Some(x), nt) = it.next(&mut env, t) {
            got.push((x.key, x.seq));
            t = nt;
        }
        assert_eq!(got, vec![(1, 1), (5, 7), (9, 1)]);
    }

    #[test]
    fn dev_seek_positions_midway() {
        let mut env = env();
        let mut it = dev_iter(&mut env, &[(1, 1), (5, 1), (9, 1)]);
        it.seek(&mut env, 0, 4);
        assert_eq!(it.peek_key(), Some(5));
    }

    #[test]
    fn dev_reverse_iteration() {
        let mut env = env();
        let mut it = dev_iter(&mut env, &[(1, 1), (5, 1), (9, 1), (5, 7)]);
        it.seek_for_prev(&mut env, 0, 100);
        let mut got = Vec::new();
        let mut t = 0;
        while let Some(x) = it.entry() {
            got.push((x.key, x.seq));
            t = it.step_backward(&mut env, t);
        }
        assert_eq!(got, vec![(9, 1), (5, 7), (1, 1)]);
    }

    #[test]
    fn dev_direction_switch() {
        let mut env = env();
        let mut it = dev_iter(&mut env, &[(1, 1), (5, 1), (9, 1)]);
        it.seek(&mut env, 0, 5);
        assert_eq!(it.peek_key(), Some(5));
        it.step_backward(&mut env, 0);
        assert_eq!(it.peek_key(), Some(1));
        it.step_forward(&mut env, 0);
        assert_eq!(it.peek_key(), Some(5));
    }

    #[test]
    fn reseek_resets_page_amortization() {
        // regression: `nexts_since_read` must reset on SEEK, otherwise
        // the first run of NEXTs after a re-seek is undercharged
        let mut env = env();
        let pairs: Vec<(Key, u32)> = (0..8).map(|k| (k, 1)).collect();
        let mut it = dev_iter(&mut env, &pairs);
        it.seek(&mut env, 0, 0);
        // walk just below the per-page amortization threshold
        let steps = it.entries_per_page - 1;
        let mut t = 0;
        for _ in 0..steps.min(7) {
            t = it.step_forward(&mut env, t);
        }
        let counted = it.nexts_since_read;
        assert!(counted > 0, "walk should accrue toward the next page");
        it.seek(&mut env, t, 0);
        assert_eq!(
            it.nexts_since_read, 0,
            "SEEK must restart the page-read amortization window"
        );
    }

    #[test]
    fn aggregated_cursor_interleaves_sources() {
        let mut env = env();
        // dev holds 2, 6; main holds 1, 4, 9
        let dev_runs = vec![Arc::new(vec![e(2, 10), e(6, 10)])];
        let mut it = dual(vec![e(1, 1), e(4, 1), e(9, 1)], dev_runs, &[2, 6]);
        let mut t = it.seek(&mut env, 0, 0);
        let mut keys = Vec::new();
        while let Some(x) = it.entry() {
            keys.push(x.key);
            t = it.next(&mut env, t);
        }
        assert_eq!(keys, vec![1, 2, 4, 6, 9]);
    }

    #[test]
    fn dev_wins_on_duplicate_key() {
        let mut env = env();
        let dev_runs = vec![Arc::new(vec![e(4, 99)])];
        let mut it = dual(vec![e(4, 1), e(5, 1)], dev_runs, &[4]);
        let t = it.seek(&mut env, 0, 0);
        assert_eq!(it.entry().unwrap().seq, 99, "dev (redirected, newest) must win");
        it.next(&mut env, t);
        assert_eq!(it.entry().unwrap().key, 5, "main's stale copy skipped");
    }

    #[test]
    fn stale_dev_copy_loses_to_newer_main_write() {
        // dev holds key 4, but metadata says main owns it now
        let mut env = env();
        let dev_runs = vec![Arc::new(vec![e(4, 1)])];
        let mut it = dual(vec![e(4, 50), e(5, 1)], dev_runs, &[]);
        let t = it.seek(&mut env, 0, 0);
        assert_eq!(it.entry().unwrap().seq, 50, "main's newer copy must win");
        it.next(&mut env, t);
        assert_eq!(it.entry().unwrap().key, 5);
    }

    #[test]
    fn dev_tombstone_hides_older_main_copy() {
        let mut env = env();
        let dev_runs = vec![Arc::new(vec![Entry::new(4, 9, ValueDesc::TOMBSTONE)])];
        let mut it = dual(vec![e(4, 2), e(5, 1)], dev_runs, &[4]);
        it.seek(&mut env, 0, 0);
        assert_eq!(it.entry().unwrap().key, 5, "deleted key must not appear");
    }

    #[test]
    fn aggregated_reverse_interleaves() {
        let mut env = env();
        let dev_runs = vec![Arc::new(vec![e(2, 10), e(6, 10)])];
        let mut it = dual(vec![e(1, 1), e(4, 1), e(9, 1)], dev_runs, &[2, 6]);
        let mut t = it.seek_for_prev(&mut env, 0, 100);
        let mut keys = Vec::new();
        while let Some(x) = it.entry() {
            keys.push(x.key);
            t = it.prev(&mut env, t);
        }
        assert_eq!(keys, vec![9, 6, 4, 2, 1]);
    }

    #[test]
    fn bounds_clip_the_aggregated_cursor() {
        let mut env = env();
        let dev_runs = vec![Arc::new(vec![e(2, 10), e(6, 10)])];
        let pin = DevPin {
            runs: dev_runs,
            live: Arc::new([2u32, 6].into_iter().collect::<BTreeSet<Key>>()),
            page_bytes: 16 * 1024,
            avg_entry: 4112,
        };
        let snap = Snapshot::pin(
            Seq::MAX,
            Seq::MAX,
            0,
            vec![Arc::new(vec![e(1, 1), e(4, 1), e(9, 1)])],
            vec![],
            vec![],
            Some(pin),
        );
        let opts = LsmOptions::default();
        let mut it = EngineIterator::new(
            snap,
            &IterOptions::range(2, 9),
            IterCost::from_opts(&opts),
            Arc::new(ScanCounters::default()),
            new_block_cache(opts.block_cache_blocks),
        );
        let mut t = it.seek(&mut env, 0, 0); // clamped up to the lower bound
        let mut keys = Vec::new();
        while let Some(x) = it.entry() {
            keys.push(x.key);
            t = it.next(&mut env, t);
        }
        assert_eq!(keys, vec![2, 4, 6], "upper bound 9 is exclusive");
    }

    #[test]
    fn dev_nexts_charge_device_reads() {
        let mut env = env();
        let mut t0 = 0;
        for k in 0..40u32 {
            t0 = env.device.kv_put(0, t0, e(k, 1)).unwrap();
        }
        // force data into NAND runs so reads are charged
        env.device
            .kv
            .ns_mut(0)
            .unwrap()
            .flush(0, &mut env.device.nand, &mut env.device.ftl)
            .ok();
        let snap = env.device.kv_snapshot(0).unwrap();
        let mut it = DevIterator::new(snap.runs, 16 * 1024, 4112);
        let t1 = it.seek(&mut env, t0, 0);
        let mut t = t1;
        for _ in 0..40 {
            let (x, nt) = it.next(&mut env, t);
            assert!(x.is_some());
            t = nt;
        }
        assert!(t > t1, "page-crossing nexts must cost device time");
        assert!(it.pages_read() > 0);
    }
}
