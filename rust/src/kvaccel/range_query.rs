//! Range query support (paper §V-F, Fig 10): one iterator per interface,
//! aggregated by a comparator that switches between them as key order
//! dictates. The Dev-LSM iterator has no read cache — every few Next()s
//! cross a NAND page, which is exactly the Table V performance gap.

use std::sync::Arc;

use crate::env::SimEnv;
use crate::lsm::entry::{Entry, Key};
use crate::sim::Nanos;
use crate::ssd::devlsm::DevSnapshot;
use crate::ssd::kv_if::NamespaceId;

/// Host-side cursor over a Dev-LSM snapshot (SEEK + NEXT through the KV
/// interface). Charges a device page read per run on seek and an
/// amortized page read while scanning.
pub struct DevIterator {
    ns: NamespaceId,
    runs: Vec<Arc<Vec<Entry>>>,
    idx: Vec<usize>,
    /// entries per NAND page (amortized read granularity)
    entries_per_page: usize,
    nexts_since_read: usize,
}

impl DevIterator {
    pub fn new(ns: NamespaceId, snap: DevSnapshot, page_bytes: u64, avg_entry: u64) -> Self {
        let n = snap.runs.len();
        Self {
            ns,
            runs: snap.runs,
            idx: vec![0; n],
            entries_per_page: (page_bytes / avg_entry.max(1)).max(1) as usize,
            nexts_since_read: 0,
        }
    }

    /// SEEK: position every run at the first key >= `key`. Each NAND run
    /// pays one page read (the device walks its run index).
    pub fn seek(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> Nanos {
        let mut t = at;
        for (i, run) in self.runs.iter().enumerate() {
            self.idx[i] = run.partition_point(|e| e.key < key);
            if i > 0 && !run.is_empty() {
                // run 0 is the device memtable (DRAM) — no NAND read
                t = env.device.kv_iter_page_read(t);
            }
        }
        let _ = self.ns;
        t
    }

    fn peek(&self) -> Option<(usize, Entry)> {
        let mut best: Option<(usize, Entry)> = None;
        for (i, run) in self.runs.iter().enumerate() {
            if let Some(&e) = run.get(self.idx[i]) {
                match best {
                    None => best = Some((i, e)),
                    // strictly-less keeps the newest (lowest run idx) on ties
                    Some((_, b)) if e.key < b.key => best = Some((i, e)),
                    _ => {}
                }
            }
        }
        best
    }

    /// Current head without advancing (comparator input).
    pub fn peek_key(&self) -> Option<Key> {
        self.peek().map(|(_, e)| e.key)
    }

    /// NEXT: return the next entry (newest version per key), charging an
    /// amortized NAND page read.
    pub fn next(&mut self, env: &mut SimEnv, at: Nanos) -> (Option<Entry>, Nanos) {
        let Some((_, entry)) = self.peek() else { return (None, at) };
        // advance all runs past this key (dedup older versions)
        for (i, run) in self.runs.iter().enumerate() {
            while run
                .get(self.idx[i])
                .map(|e| e.key == entry.key)
                .unwrap_or(false)
            {
                self.idx[i] += 1;
            }
        }
        let mut t = at;
        self.nexts_since_read += 1;
        if self.nexts_since_read >= self.entries_per_page {
            self.nexts_since_read = 0;
            t = env.device.kv_iter_page_read(t);
        }
        (Some(entry), t)
    }
}

/// The aggregated dual-interface range scan (Fig 10): Seek both, then
/// repeatedly emit from whichever iterator holds the smaller key,
/// switching iterators at crossover points. The Metadata Manager is the
/// recency authority across interfaces: a Dev-LSM entry is live only if
/// the metadata table still routes its key to the device — otherwise a
/// newer Main-LSM write superseded it and the device copy is stale
/// (awaiting the next rollback's reset).
pub struct AggregatedScan<'a> {
    pub main: crate::lsm::iterator::LsmIterator,
    pub dev: &'a mut DevIterator,
    meta: &'a super::metadata::MetadataManager,
    main_head: Option<Entry>,
}

impl<'a> AggregatedScan<'a> {
    pub fn new(
        mut main: crate::lsm::iterator::LsmIterator,
        dev: &'a mut DevIterator,
        meta: &'a super::metadata::MetadataManager,
        env: &mut SimEnv,
        at: Nanos,
        start: Key,
    ) -> (Self, Nanos) {
        main.seek(start);
        let t = dev.seek(env, at, start);
        let main_head = main.next();
        (Self { main, dev, meta, main_head }, t)
    }

    /// Produce the next merged entry; returns (entry, blocks_touched_in_main, time).
    pub fn next(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
    ) -> (Option<Entry>, Vec<(u64, usize)>, Nanos) {
        let mut t = at;
        loop {
            let dev_key = self.dev.peek_key();
            let main_key = self.main_head.map(|e| e.key);
            match (dev_key, main_key) {
                (None, None) => return (None, self.main.drain_blocks(), t),
                // dev head is at or before main head
                (Some(d), m) if m.map_or(true, |mk| d <= mk) => {
                    let dev_live = self.meta.contains(d);
                    let (e, nt) = self.dev.next(env, t);
                    t = nt;
                    let e = e.expect("peeked dev entry must exist");
                    if !dev_live {
                        // stale device copy: a newer Main-LSM write owns
                        // this key; let the main side emit it.
                        continue;
                    }
                    // dev copy is the newest: drop the superseded main copy
                    if Some(d) == m {
                        self.main_head = self.main.next();
                    }
                    if e.val.is_tombstone() {
                        // live deletion buffered in the device
                        continue;
                    }
                    return (Some(e), self.main.drain_blocks(), t);
                }
                _ => {
                    let e = self.main_head.take();
                    self.main_head = self.main.next();
                    return (e, self.main.drain_blocks(), t);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsm::entry::ValueDesc;
    use crate::lsm::iterator::LsmIterator;
    use crate::ssd::SsdConfig;

    fn env() -> SimEnv {
        SimEnv::new(11, SsdConfig::default())
    }

    /// metadata table routing every listed key to the device
    fn meta_with(keys: &[Key]) -> crate::kvaccel::MetadataManager {
        let mut m = crate::kvaccel::MetadataManager::new(Default::default());
        let entries: Vec<Entry> = keys
            .iter()
            .map(|&k| Entry::new(k, 1, ValueDesc::new(k, 8)))
            .collect();
        m.rebuild_from(&entries);
        m
    }

    fn e(k: Key, s: u32) -> Entry {
        Entry::new(k, s, ValueDesc::new(s, 64))
    }

    fn dev_iter(env: &mut SimEnv, keys: &[(Key, u32)]) -> DevIterator {
        let mut t = 0;
        for &(k, s) in keys {
            t = env.device.kv_put(0, t, e(k, s)).unwrap();
        }
        let snap = env.device.kv_snapshot(0).unwrap();
        DevIterator::new(0, snap, 16 * 1024, 4112)
    }

    #[test]
    fn dev_iterator_orders_and_dedups() {
        let mut env = env();
        let mut it = dev_iter(&mut env, &[(5, 1), (1, 1), (9, 1), (5, 7)]);
        it.seek(&mut env, 0, 0);
        let mut got = Vec::new();
        let mut t = 0;
        while let (Some(x), nt) = it.next(&mut env, t) {
            got.push((x.key, x.seq));
            t = nt;
        }
        assert_eq!(got, vec![(1, 1), (5, 7), (9, 1)]);
    }

    #[test]
    fn dev_seek_positions_midway() {
        let mut env = env();
        let mut it = dev_iter(&mut env, &[(1, 1), (5, 1), (9, 1)]);
        it.seek(&mut env, 0, 4);
        assert_eq!(it.peek_key(), Some(5));
    }

    #[test]
    fn aggregated_scan_interleaves_sources() {
        let mut env = env();
        // dev holds 2, 6; main holds 1, 4, 9
        let mut dev = dev_iter(&mut env, &[(2, 10), (6, 10)]);
        let meta = meta_with(&[2, 6]);
        let main = LsmIterator::new(vec![e(1, 1), e(4, 1), e(9, 1)], vec![], vec![], vec![]);
        let (mut scan, t0) = AggregatedScan::new(main, &mut dev, &meta, &mut env, 0, 0);
        let mut keys = Vec::new();
        let mut t = t0;
        loop {
            let (x, _blocks, nt) = scan.next(&mut env, t);
            t = nt;
            match x {
                Some(x) => keys.push(x.key),
                None => break,
            }
        }
        assert_eq!(keys, vec![1, 2, 4, 6, 9]);
    }

    #[test]
    fn dev_wins_on_duplicate_key() {
        let mut env = env();
        let mut dev = dev_iter(&mut env, &[(4, 99)]);
        let meta = meta_with(&[4]);
        let main = LsmIterator::new(vec![e(4, 1), e(5, 1)], vec![], vec![], vec![]);
        let (mut scan, t0) = AggregatedScan::new(main, &mut dev, &meta, &mut env, 0, 0);
        let (x, _, t) = scan.next(&mut env, t0);
        assert_eq!(x.unwrap().seq, 99, "dev (redirected, newest) must win");
        let (y, _, _) = scan.next(&mut env, t);
        assert_eq!(y.unwrap().key, 5, "main's stale copy skipped");
    }

    #[test]
    fn stale_dev_copy_loses_to_newer_main_write() {
        // dev holds key 4, but metadata says main owns it now
        let mut env = env();
        let mut dev = dev_iter(&mut env, &[(4, 1)]);
        let meta = meta_with(&[]);
        let main = LsmIterator::new(vec![e(4, 50), e(5, 1)], vec![], vec![], vec![]);
        let (mut scan, t0) = AggregatedScan::new(main, &mut dev, &meta, &mut env, 0, 0);
        let (x, _, t) = scan.next(&mut env, t0);
        assert_eq!(x.unwrap().seq, 50, "main's newer copy must win");
        let (y, _, _) = scan.next(&mut env, t);
        assert_eq!(y.unwrap().key, 5);
    }

    #[test]
    fn dev_tombstone_hides_older_main_copy() {
        let mut env = env();
        let mut t0 = 0;
        t0 = env
            .device
            .kv_put(0, t0, Entry::new(4, 9, ValueDesc::TOMBSTONE))
            .unwrap();
        let _ = t0;
        let snap = env.device.kv_snapshot(0).unwrap();
        let mut dev = DevIterator::new(0, snap, 16 * 1024, 4112);
        let meta = meta_with(&[4]);
        let main = LsmIterator::new(vec![e(4, 2), e(5, 1)], vec![], vec![], vec![]);
        let (mut scan, t) = AggregatedScan::new(main, &mut dev, &meta, &mut env, 0, 0);
        let (x, _, _) = scan.next(&mut env, t);
        assert_eq!(x.unwrap().key, 5, "deleted key must not appear");
    }

    #[test]
    fn dev_nexts_charge_device_reads() {
        let mut env = env();
        let pairs: Vec<(Key, u32)> = (0..40).map(|k| (k, 1)).collect();
        let mut it = dev_iter(&mut env, &pairs);
        // force data into NAND runs so reads are charged
        env.device.kv.ns_mut(0).unwrap().flush(0, &mut env.device.nand, &mut env.device.ftl).ok();
        let t0 = it.seek(&mut env, 0, 0);
        let mut t = t0;
        for _ in 0..40 {
            let (x, nt) = it.next(&mut env, t);
            assert!(x.is_some());
            t = nt;
        }
        assert!(t > t0, "page-crossing nexts must cost device time");
    }
}
