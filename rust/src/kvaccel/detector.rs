//! Detector module (paper §V-C): a detached thread that samples the
//! Main-LSM's stall signals — L0 SST count, memtable size, pending
//! compaction bytes — every 0.1 s and reports to the Controller /
//! Rollback Manager.
//!
//! In virtual time the "thread" is a tick: operations entering the store
//! refresh the sample when the 0.1 s boundary has passed. Each poll
//! charges the measured overhead (Table VI: 1.37 us).

use crate::env::SimEnv;
use crate::lsm::{LsmDb, WriteCondition};
use crate::sim::{CpuClass, Nanos, MILLIS};

#[derive(Clone, Debug)]
pub struct DetectorConfig {
    /// Sampling period (paper: 0.1 s).
    pub interval: Nanos,
    /// CPU cost of one poll (paper Table VI: 1.37 us average).
    pub poll_cost_ns: Nanos,
}

impl Default for DetectorConfig {
    fn default() -> Self {
        Self { interval: 100 * MILLIS, poll_cost_ns: 1_370 }
    }
}

/// One sampled snapshot of the Main-LSM's stall signals.
#[derive(Clone, Copy, Debug, Default)]
pub struct DetectorSample {
    pub at: Nanos,
    pub l0_files: usize,
    pub imm_count: usize,
    pub memtable_bytes: u64,
    pub pending_compaction_bytes: u64,
    pub stall_imminent: bool,
}

#[derive(Debug)]
pub struct Detector {
    cfg: DetectorConfig,
    last: DetectorSample,
    sampled_once: bool,
    /// consecutive calm (not stall-imminent) samples — the Rollback
    /// Manager's lazy-scheme quiet signal.
    pub calm_ticks: u64,
    pub polls: u64,
}

impl Detector {
    pub fn new(cfg: DetectorConfig) -> Self {
        Self {
            cfg,
            last: DetectorSample::default(),
            sampled_once: false,
            calm_ticks: 0,
            polls: 0,
        }
    }

    pub fn config(&self) -> &DetectorConfig {
        &self.cfg
    }

    /// Refresh the sample if the polling interval elapsed. Returns true
    /// when a new sample was taken (tick boundary — rollback checks hook
    /// here, like the paper's detached detector/rollback thread).
    pub fn maybe_sample(&mut self, env: &mut SimEnv, at: Nanos, db: &LsmDb) -> bool {
        if self.sampled_once && at < self.last.at + self.cfg.interval {
            return false;
        }
        self.sample(env, at, db);
        true
    }

    /// Unconditional poll.
    pub fn sample(&mut self, env: &mut SimEnv, at: Nanos, db: &LsmDb) {
        self.polls += 1;
        env.cpu.charge(CpuClass::Kvaccel, at, self.cfg.poll_cost_ns);
        let cond = db.write_condition();
        let stall_imminent = !matches!(cond, WriteCondition::Normal);
        self.last = DetectorSample {
            at,
            l0_files: db.l0_count(),
            imm_count: db.imm_count(),
            memtable_bytes: db.memtable_bytes(),
            pending_compaction_bytes: db.pending_compaction_bytes(),
            stall_imminent,
        };
        self.sampled_once = true;
        if stall_imminent {
            self.calm_ticks = 0;
        } else {
            self.calm_ticks += 1;
        }
    }

    /// Latest sample (possibly up to one interval stale — that staleness
    /// is part of the paper's design).
    pub fn sample_ref(&self) -> &DetectorSample {
        &self.last
    }

    /// The Controller's redirect signal.
    pub fn stall_imminent(&self) -> bool {
        self.last.stall_imminent
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsm::{LsmOptions, ValueDesc};
    use crate::runtime::{BloomBuilder, MergeEngine};
    use crate::ssd::SsdConfig;

    fn rig() -> (LsmDb, SimEnv, Detector) {
        (
            LsmDb::new(
                LsmOptions::small_for_test(),
                MergeEngine::rust(),
                BloomBuilder::rust(),
            ),
            SimEnv::new(1, SsdConfig::default()),
            Detector::new(DetectorConfig::default()),
        )
    }

    #[test]
    fn samples_respect_interval() {
        let (db, mut env, mut det) = rig();
        assert!(det.maybe_sample(&mut env, 0, &db));
        assert!(!det.maybe_sample(&mut env, 50 * MILLIS, &db));
        assert!(det.maybe_sample(&mut env, 100 * MILLIS, &db));
        assert_eq!(det.polls, 2);
    }

    #[test]
    fn detects_pressure() {
        let (mut db, mut env, mut det) = rig();
        det.sample(&mut env, 0, &db);
        assert!(!det.stall_imminent());
        // pile up writes with tiny memtables -> L0 pressure
        db.opts.enable_slowdown = false;
        let mut t = 0;
        let mut seen_imminent = false;
        for k in 0..4000u32 {
            t = db.put(&mut env, t, k, ValueDesc::new(k, 4096)).done;
            if det.maybe_sample(&mut env, t, &db) && det.stall_imminent() {
                seen_imminent = true;
                break;
            }
        }
        assert!(seen_imminent, "detector never saw pressure");
    }

    #[test]
    fn calm_ticks_accumulate_and_reset() {
        let (db, mut env, mut det) = rig();
        det.sample(&mut env, 0, &db);
        det.sample(&mut env, 100 * MILLIS, &db);
        assert_eq!(det.calm_ticks, 2);
    }

    #[test]
    fn poll_charges_cpu() {
        let (db, mut env, mut det) = rig();
        let before = env.cpu.busy(CpuClass::Kvaccel);
        det.sample(&mut env, 0, &db);
        assert_eq!(env.cpu.busy(CpuClass::Kvaccel) - before, 1_370);
    }
}
