//! KvaccelDb: the full KVACCEL system — Main-LSM (block interface) +
//! Dev-LSM (KV interface) behind one KV API, glued by the Detector,
//! Controller, Metadata Manager and Rollback Manager (paper Fig 7b).
//!
//! KVACCEL never uses RocksDB's slowdown (paper §VI-B): instead of
//! throttling, writes are redirected to the device write buffer when the
//! Detector anticipates a stall; the Main-LSM path is configured with
//! `enable_slowdown = false`, and hard stops on the main path are avoided
//! by the same redirection.

use anyhow::Result;

use crate::baselines::SystemKind;
use crate::engine::{DbIterator, DevPin, DurableImage, IterOptions, Snapshot};
use crate::env::SimEnv;
use crate::lsm::entry::{Entry, Key, Seq, ValueDesc};
use crate::lsm::{LsmDb, LsmOptions, Manifest, ManifestEdit, PutResult};
use crate::runtime::{BloomBuilder, MergeEngine};
use crate::sim::{CpuClass, Nanos};
use crate::ssd::kv_if::NamespaceId;

use super::controller::{Controller, ControllerConfig, ReadPath, WritePath};
use super::detector::{Detector, DetectorConfig};
use super::metadata::{MetadataConfig, MetadataManager};
use super::rollback::{RollbackConfig, RollbackManager, RollbackScheme};

#[derive(Clone, Debug)]
pub struct KvaccelConfig {
    pub detector: DetectorConfig,
    pub controller: ControllerConfig,
    pub metadata: MetadataConfig,
    pub rollback: RollbackConfig,
    pub namespace: NamespaceId,
}

impl Default for KvaccelConfig {
    fn default() -> Self {
        Self {
            detector: DetectorConfig::default(),
            controller: ControllerConfig::default(),
            metadata: MetadataConfig::default(),
            rollback: RollbackConfig::default(),
            namespace: 0,
        }
    }
}

impl KvaccelConfig {
    pub fn with_scheme(mut self, scheme: RollbackScheme) -> Self {
        self.rollback.scheme = scheme;
        self
    }
}

#[derive(Clone, Debug, Default)]
pub struct KvaccelStats {
    pub dev_seq: Seq,
}

pub struct KvaccelDb {
    pub main: LsmDb,
    pub detector: Detector,
    pub controller: Controller,
    pub metadata: MetadataManager,
    pub rollback: RollbackManager,
    ns: NamespaceId,
    /// Sequence number of the newest redirected write. Dev-LSM seqs are
    /// drawn from the Main-LSM's domain (`LsmDb::alloc_seq`), so
    /// cross-interface recency is totally ordered — the authority crash
    /// recovery reconciles by. Interface routing on the hot path is
    /// still owned by the Metadata Manager.
    dev_seq: Seq,
    /// Set by the shard arbiter: compare THIS namespace's share of the
    /// KV region against the controller cap (the shard's grant), instead
    /// of the whole region's fill. Per-shard grants sum to the region
    /// budget, so each shard honoring its own grant bounds the region —
    /// while a standalone store keeps the region-wide signal.
    pub scoped_occupancy: bool,
    /// Original configuration, retained for the durable image.
    cfg: KvaccelConfig,
}

impl KvaccelDb {
    pub fn new(
        mut opts: LsmOptions,
        cfg: KvaccelConfig,
        engine: MergeEngine,
        bloom: BloomBuilder,
    ) -> Self {
        // KVACCEL does not employ slowdowns (paper §VI-B).
        opts.enable_slowdown = false;
        Self::from_parts(LsmDb::new(opts, engine, bloom), cfg)
    }

    /// Assemble the managers around an existing Main-LSM (fresh build or
    /// the recovery path).
    fn from_parts(main: LsmDb, cfg: KvaccelConfig) -> Self {
        Self {
            main,
            detector: Detector::new(cfg.detector.clone()),
            controller: Controller::new(cfg.controller.clone()),
            metadata: MetadataManager::new(cfg.metadata.clone()),
            rollback: RollbackManager::new(cfg.rollback.clone()),
            ns: cfg.namespace,
            dev_seq: 0,
            scoped_occupancy: false,
            cfg,
        }
    }

    /// The occupancy the Controller weighs against its cap: the whole KV
    /// region's fill for a standalone store, this namespace's share when
    /// a shard arbiter granted this shard a slice of the region. The
    /// scoped signal keeps a physical device-full backstop: per-ns
    /// shares are logical bytes while the FTL allocates whole pages, so
    /// when the region itself is nearly out of pages, refuse outright
    /// rather than let rounding overfill it.
    fn backpressure_occ(&self, env: &SimEnv) -> f64 {
        if self.scoped_occupancy {
            if env.device.kv_occupancy() >= 0.98 {
                return 1.0;
            }
            env.device.kv_ns_occupancy(self.ns)
        } else {
            env.device.kv_occupancy()
        }
    }

    pub fn namespace(&self) -> NamespaceId {
        self.ns
    }

    /// Close the open rollback window, if any: fsync the merged copies,
    /// reset the device buffer, clear the routing table, and write the
    /// RollbackEnd manifest edit. Returns the completion time.
    fn finalize_window(&mut self, env: &mut SimEnv) -> Result<Option<Nanos>> {
        let stream = self.main.opts.wal_stream;
        let Some((done, returned)) =
            self.rollback.finalize(env, self.ns, stream, &mut self.metadata)?
        else {
            return Ok(None);
        };
        // the device buffer was reset: drop its cached keys so later
        // reads pay real (Main-LSM) latency instead of phantom hits
        {
            let mut cache =
                self.main.block_cache.lock().expect("block cache poisoned");
            if cache.capacity() > 0 && !cache.is_empty() {
                cache.retain(|k| k.0 != crate::engine::DEV_CACHE_NS);
            }
        }
        self.main
            .manifest_append(env, done, ManifestEdit::RollbackEnd { returned });
        Ok(Some(done))
    }

    /// Detector tick + rollback trigger — the detached 0.1 s thread of
    /// the paper, driven by operation arrivals in virtual time.
    fn tick(&mut self, env: &mut SimEnv, at: Nanos) {
        // Apply any finished background work first: while traffic is
        // redirected the Main-LSM sees no operations, and without this the
        // Detector would sample a frozen (stalled-forever) snapshot.
        self.main.catch_up(env, at);
        self.main.vlog_gc_tick(env, at);
        // Close a rollback window whose horizon has passed (Fig 9 step
        // 8: device reset + routing clear, deferred from `begin`).
        if self.rollback.pending_end().is_some_and(|end| end <= at) {
            self.finalize_window(env).expect("rollback finalize failed");
        }
        if !self.detector.maybe_sample(env, at, &self.main) {
            return;
        }
        let dev_empty = env.device.kv_is_empty(self.ns);
        // same scoping as the routing backpressure: a sharded sibling's
        // fill must not force-trigger THIS shard's lazy rollback
        let occ = self.backpressure_occ(env);
        if self
            .rollback
            .should_rollback(at, &self.detector, dev_empty, occ)
        {
            self.main
                .manifest_append(env, at, ManifestEdit::RollbackBegin { at });
            self.rollback
                .begin(env, at, self.ns, &mut self.main, &mut self.metadata)
                .expect("rollback failed");
        }
    }

    /// Idle-time maintenance: the same detector/rollback tick operations
    /// run, exposed so a sharding layer can keep this shard's detector
    /// and background work current while traffic concentrates elsewhere
    /// (an idle shard's stall signals must stay fresh for the device
    /// arbiter to reclaim its grant).
    pub fn maintain(&mut self, env: &mut SimEnv, at: Nanos) {
        self.tick(env, at);
    }

    /// One routing decision: during an open rollback window every write
    /// takes the Main path (redirecting into a buffer that is being
    /// drained would race the deferred reset); otherwise the Controller
    /// decides from the stall signal and KV-region occupancy.
    fn route_write(&mut self, at: Nanos, stall: bool, occ: f64) -> WritePath {
        if self.rollback.in_flight(at) {
            self.controller.stats.writes_to_main += 1;
            return WritePath::Main;
        }
        self.controller.write_path(stall, occ)
    }

    /// Write path (paper §V-C): detector check, then either redirect to
    /// the Dev-LSM or write through the Main-LSM.
    pub fn put(&mut self, env: &mut SimEnv, at: Nanos, key: Key, val: ValueDesc) -> PutResult {
        self.tick(env, at);
        // Consult the *live* stop condition too: the detector sample can
        // be up to 0.1 s stale and a hard stop must never block KVACCEL.
        let stall = self.detector.stall_imminent()
            || self.main.write_condition().is_stopped();
        let occ = self.backpressure_occ(env);
        match self.route_write(at, stall, occ) {
            WritePath::Dev => {
                self.dev_seq = self.main.alloc_seq();
                let entry = Entry::new(key, self.dev_seq, val);
                self.metadata.insert(env, at, key);
                let ack = env
                    .device
                    .kv_put(self.ns, at, entry)
                    .expect("kv interface put failed");
                // client-side submit cost is the same db_bench path
                env.cpu.charge(CpuClass::Foreground, at, self.main.opts.put_cpu_ns);
                let done = ack.max(at + self.main.opts.put_cpu_ns);
                env.clock.advance_to(done);
                PutResult { done, stalled_ns: 0, delayed_ns: 0 }
            }
            WritePath::Main => {
                // write-path step 3-1: supersede any Dev-LSM copy
                if self.metadata.check(env, at, key) {
                    self.metadata.delete(env, at, key);
                }
                self.main.put(env, at, key, val)
            }
        }
    }

    /// Delete: a tombstone through the same dual-path write pipeline —
    /// redirected tombstones land in the Dev-LSM and supersede on
    /// rollback; main-path tombstones compact away at the bottom level.
    /// Counted in `DbStats::deletes` regardless of the route so the
    /// `EngineStats` counter stays uniform across engines.
    pub fn delete(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> PutResult {
        self.main.stats.deletes += 1;
        self.put(env, at, key, ValueDesc::TOMBSTONE)
    }

    /// Batched write path: one Detector tick and one Controller routing
    /// decision for the whole batch, so an anticipated stall redirects
    /// the batch as a unit to the Dev-LSM (and a calm store group-commits
    /// it through the Main-LSM WAL).
    pub fn write_batch(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        batch: &crate::engine::WriteBatch,
    ) -> crate::engine::BatchResult {
        if batch.is_empty() {
            return crate::engine::BatchResult { done: at, ..Default::default() };
        }
        self.tick(env, at);
        let stall = self.detector.stall_imminent()
            || self.main.write_condition().is_stopped();
        let occ = self.backpressure_occ(env);
        match self.route_write(at, stall, occ) {
            WritePath::Dev => {
                // The routing decision covers the whole batch, but the KV
                // region is finite NAND space: re-check the same occupancy
                // cap write_path enforces per put, and spill the tail to
                // the Main-LSM if the buffer fills mid-batch.
                let cap = self.controller.cfg.max_kv_occupancy;
                let mut ack_done = at;
                let mut dev_ops: usize = 0;
                for op in batch.ops() {
                    if self.backpressure_occ(env) >= cap {
                        break;
                    }
                    self.dev_seq = self.main.alloc_seq();
                    let entry = Entry::new(op.key(), self.dev_seq, op.value());
                    self.metadata.insert(env, at, op.key());
                    if op.is_delete() {
                        self.main.stats.deletes += 1;
                    }
                    let ack = env
                        .device
                        .kv_put(self.ns, at, entry)
                        .expect("kv interface put failed");
                    ack_done = ack_done.max(ack);
                    dev_ops += 1;
                }
                // controller stats count ops (the decision already added
                // one), keeping redirect_fraction comparable with the
                // single-op path
                self.controller.stats.writes_to_dev +=
                    (dev_ops as u64).saturating_sub(1);
                // client submit cost amortized like the Main-LSM batch
                let cpu = self.main.opts.batch_cpu_ns(dev_ops as u64);
                env.cpu.charge(CpuClass::Foreground, at, cpu);
                let done = ack_done.max(at + cpu);
                env.clock.advance_to(done);
                if dev_ops == batch.len() {
                    // fully redirected: count the batch here so the
                    // DbStats::batches counter stays uniform across
                    // engines (the spill path counts via main.write_batch)
                    self.main.stats.batches += 1;
                    return crate::engine::BatchResult {
                        done,
                        stalled_ns: 0,
                        delayed_ns: 0,
                        ops: batch.len(),
                    };
                }
                // backpressure spill: the rest goes through the Main-LSM
                self.controller.stats.redirect_refusals += 1;
                let mut rest =
                    crate::engine::WriteBatch::with_capacity(batch.len() - dev_ops);
                for op in &batch.ops()[dev_ops..] {
                    if self.metadata.check(env, done, op.key()) {
                        self.metadata.delete(env, done, op.key());
                    }
                    match *op {
                        crate::engine::BatchOp::Put { key, val } => {
                            rest.put(key, val);
                        }
                        crate::engine::BatchOp::Delete { key } => {
                            rest.delete(key);
                        }
                    }
                }
                self.controller.stats.writes_to_main += rest.len() as u64;
                let r = self.main.write_batch(env, done, &rest);
                crate::engine::BatchResult {
                    done: r.done,
                    stalled_ns: r.stalled_ns,
                    delayed_ns: r.delayed_ns,
                    ops: batch.len(),
                }
            }
            WritePath::Main => {
                // controller stats count ops (the decision added one)
                self.controller.stats.writes_to_main += batch.len() as u64 - 1;
                for op in batch.ops() {
                    if self.metadata.check(env, at, op.key()) {
                        self.metadata.delete(env, at, op.key());
                    }
                }
                self.main.write_batch(env, at, batch)
            }
        }
    }

    /// Read path (paper §V-C): metadata membership picks the interface.
    /// Device-buffer reads go through the engine-wide block cache under
    /// the reserved `DEV_CACHE_NS` key namespace: a hit serves the live
    /// buffered value with zero-cost `kv_peek` (no simulated round
    /// trip), a miss pays the full KV-interface GET and caches the key.
    /// Correctness never depends on the cache — the metadata routing
    /// gates this path, and `kv_peek` reads live device state.
    pub fn get(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> (Option<ValueDesc>, Nanos) {
        self.tick(env, at);
        let in_dev = self.metadata.check(env, at, key);
        match self.controller.read_path(in_dev) {
            ReadPath::Dev => {
                let ckey = (crate::engine::DEV_CACHE_NS, key as usize);
                let hit = {
                    let mut cache = self
                        .main
                        .block_cache
                        .lock()
                        .expect("block cache poisoned");
                    cache.capacity() > 0 && cache.get(&ckey).is_some()
                };
                if hit {
                    let probe = self.main.opts.get_cpu_ns / 2;
                    env.cpu.charge(CpuClass::Foreground, at, probe);
                    let done = at + probe;
                    env.clock.advance_to(done);
                    let v = env
                        .device
                        .kv_peek(self.ns, key)
                        .filter(|d| !d.is_tombstone());
                    return (v, done);
                }
                let (v, done) = env
                    .device
                    .kv_get(self.ns, at, key)
                    .expect("kv interface get failed");
                env.cpu.charge(CpuClass::Foreground, at, self.main.opts.get_cpu_ns);
                env.clock.advance_to(done);
                self.main
                    .block_cache
                    .lock()
                    .expect("block cache poisoned")
                    .insert(ckey, ());
                let v = v.filter(|d| !d.is_tombstone());
                (v, done)
            }
            ReadPath::Main => self.main.get(env, at, key),
        }
    }

    /// Pin a snapshot spanning both interfaces: the Main-LSM parts plus
    /// the Dev-LSM runs and the metadata routing set (the Fig 10
    /// cross-interface recency authority). A rollback occurring after
    /// this point resets the live device buffer and clears the live
    /// metadata table, but the pinned `Arc`s keep this view intact.
    pub fn snapshot(&mut self, env: &mut SimEnv, at: Nanos) -> Snapshot {
        self.tick(env, at);
        self.main.catch_up(env, at);
        let (seq, runs, l0, levels) = self.main.pin_parts();
        let dev_snap = env.device.kv_snapshot(self.ns).expect("kv snapshot");
        let pin = DevPin {
            runs: dev_snap.runs,
            live: self.metadata.pin(),
            page_bytes: env.device.nand.config().page_bytes,
            avg_entry: 16 + 4096,
        };
        let snap = Snapshot::pin(seq, self.dev_seq, at, runs, l0, levels, Some(pin));
        self.main.register_snapshot(&snap);
        snap
    }

    /// Open the aggregated dual-interface cursor (paper §V-F, Fig 10).
    pub fn iter(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        opts: IterOptions,
    ) -> Box<dyn DbIterator> {
        let snap = match &opts.snapshot {
            Some(s) => s.clone(),
            None => self.snapshot(env, at),
        };
        self.main.make_iter(snap, &opts)
    }

    /// Aggregated dual-iterator range scan — a thin wrapper over the
    /// cursor API.
    pub fn scan(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        start: Key,
        count: usize,
    ) -> (Vec<Entry>, Nanos) {
        crate::engine::KvEngine::scan(self, env, at, start, count)
    }

    /// End-of-run cleanup: close any open rollback window, final
    /// rollback (lazy/disabled schemes hold data in the Dev-LSM), drain
    /// background work.
    pub fn finish(&mut self, env: &mut SimEnv, at: Nanos) -> Result<Nanos> {
        let mut t = at;
        if let Some(end) = self.rollback.pending_end() {
            t = t.max(end);
            if let Some(done) = self.finalize_window(env)? {
                t = t.max(done);
            }
        }
        if !env.device.kv_is_empty(self.ns) {
            self.main
                .manifest_append(env, t, ManifestEdit::RollbackBegin { at: t });
            let before = self.rollback.stats.entries_returned;
            t = self
                .rollback
                .perform(env, t, self.ns, &mut self.main, &mut self.metadata)?;
            let returned = self.rollback.stats.entries_returned - before;
            self.main
                .manifest_append(env, t, ManifestEdit::RollbackEnd { returned });
        }
        Ok(self.main.flush_and_wait(env, t))
    }

    /// Crash-recovery drill for the Metadata Manager (paper §V-C): wipe
    /// the table and rebuild it from a full KV-interface range scan.
    pub fn recover_metadata(&mut self, env: &mut SimEnv, at: Nanos) -> Result<Nanos> {
        let (entries, done) = env.device.kv_bulk_scan(self.ns, at)?;
        self.metadata.rebuild_from(&entries);
        Ok(done)
    }

    // -----------------------------------------------------------------
    // Durable lifecycle: close / crash / open
    // -----------------------------------------------------------------

    /// Clean shutdown: final rollback + drain (single-store semantics),
    /// seal + fsync the WAL, CleanShutdown manifest edit.
    pub fn close_into_image(
        mut self,
        env: &mut SimEnv,
        at: Nanos,
    ) -> Result<DurableImage> {
        let t = self.finish(env, at)?;
        let t = env.device.wal_sync_on(self.main.opts.wal_stream, t);
        let t = self.main.vlog_sync(env, t);
        let last_seq = self.main.last_seq();
        let t = self
            .main
            .manifest_append(env, t, ManifestEdit::CleanShutdown { last_seq });
        env.clock.advance_to(t);
        let KvaccelDb { main, cfg, .. } = self;
        let scheme = cfg.rollback.scheme;
        let (opts, merge, bloom, manifest, wal, vlog) =
            main.into_image_parts(None, None);
        Ok(DurableImage {
            kind: SystemKind::Kvaccel { scheme },
            opts,
            merge,
            bloom,
            manifest,
            wal,
            vlog,
            kvaccel_cfg: Some(cfg),
            adoc_cfg: None,
            shard: None,
            clean: true,
            taken_at: t,
        })
    }

    /// Power loss at `at`. A rollback window open at the cut — even one
    /// whose horizon has passed but was never finalized by a tick —
    /// stays open in the manifest (dangling RollbackBegin): the device
    /// buffer keeps its runs (the lazy deferred reset genuinely never
    /// ran), the merged-back copies sit in the (partially durable) WAL,
    /// and recovery reconciles per key by sequence number, leaving the
    /// routing set pointing at whichever copy is durable. Finalizing
    /// here instead would fabricate an fsync + reset at the instant of
    /// power loss.
    pub fn crash_into_image(mut self, env: &mut SimEnv, at: Nanos) -> DurableImage {
        self.main.catch_up(env, at);
        // capture the durability cut BEFORE the power loss wipes the
        // page-cache accounting (those bytes are lost, not durable)
        let watermark =
            env.device.wal_durable_watermark_on(self.main.opts.wal_stream);
        let vlog_watermark = self.main.vlog_durable_watermark(env);
        env.device.crash(at);
        let KvaccelDb { main, cfg, .. } = self;
        let scheme = cfg.rollback.scheme;
        let (opts, merge, bloom, manifest, wal, vlog) =
            main.into_image_parts(Some(watermark), vlog_watermark);
        DurableImage {
            kind: SystemKind::Kvaccel { scheme },
            opts,
            merge,
            bloom,
            manifest,
            wal,
            vlog,
            kvaccel_cfg: Some(cfg),
            adoc_cfg: None,
            shard: None,
            clean: false,
            taken_at: at,
        }
    }

    /// Reopen from a durable image: recover the Main-LSM (manifest +
    /// WAL replay), then rebuild the volatile routing set with a full
    /// KV-interface range scan (paper §V-C) **reconciled against the
    /// recovered host state**: a device copy superseded by a newer
    /// durable Main-LSM version is stale and stays unrouted (the
    /// rollback drain will skip it); otherwise the device copy — always
    /// durable, the buffer is capacitor-backed NAND — owns the key.
    #[allow(clippy::too_many_arguments)]
    pub fn open(
        env: &mut SimEnv,
        at: Nanos,
        mut opts: LsmOptions,
        cfg: KvaccelConfig,
        merge: MergeEngine,
        bloom: BloomBuilder,
        manifest: Manifest,
        wal: Vec<Entry>,
        vlog: Option<crate::vlog::VlogImage>,
        clean: bool,
    ) -> Result<(Self, Nanos)> {
        opts.enable_slowdown = false;
        let (main, t0) =
            LsmDb::open(env, at, opts, merge, bloom, manifest, wal, vlog, clean);
        let mut db = Self::from_parts(main, cfg);
        // full recovery scan of the device write buffer (charges the
        // NAND reads + chunked DMA of the paper's Fig 9 path)
        let (entries, scan_done) = env.device.kv_bulk_scan(db.ns, t0)?;
        let mut routed: Vec<Key> = Vec::with_capacity(entries.len());
        let mut stale = 0u64;
        let mut max_dev_seq: Seq = 0;
        for e in &entries {
            max_dev_seq = max_dev_seq.max(e.seq);
            if db.main.latest_seq(e.key).is_some_and(|s| s > e.seq) {
                stale += 1;
            } else {
                routed.push(e.key);
            }
        }
        let rerouted = routed.len() as u64;
        let t = db.metadata.rebuild_routing(env, scan_done, routed);
        db.main.bump_seq_to(max_dev_seq);
        db.dev_seq = max_dev_seq;
        db.main.recovery.dev_entries_scanned = entries.len() as u64;
        db.main.recovery.dev_keys_rerouted = rerouted;
        db.main.recovery.dev_keys_stale = stale;
        env.clock.advance_to(t);
        Ok((db, t))
    }
}

// ---------------------------------------------------------------------
// Unified engine interface
// ---------------------------------------------------------------------

impl crate::engine::EngineStats for KvaccelDb {
    fn main_db(&self) -> &LsmDb {
        &self.main
    }

    fn kvaccel(&self) -> Option<&KvaccelDb> {
        Some(self)
    }
}

impl crate::engine::KvEngine for KvaccelDb {
    fn put(&mut self, env: &mut SimEnv, at: Nanos, key: Key, val: ValueDesc) -> PutResult {
        KvaccelDb::put(self, env, at, key, val)
    }

    fn delete(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> PutResult {
        KvaccelDb::delete(self, env, at, key)
    }

    fn get(&mut self, env: &mut SimEnv, at: Nanos, key: Key) -> (Option<ValueDesc>, Nanos) {
        KvaccelDb::get(self, env, at, key)
    }

    fn write_batch(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        batch: &crate::engine::WriteBatch,
    ) -> crate::engine::BatchResult {
        KvaccelDb::write_batch(self, env, at, batch)
    }

    fn snapshot(&mut self, env: &mut SimEnv, at: Nanos) -> Snapshot {
        KvaccelDb::snapshot(self, env, at)
    }

    fn iter(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        opts: IterOptions,
    ) -> Box<dyn DbIterator> {
        KvaccelDb::iter(self, env, at, opts)
    }

    fn tick(&mut self, env: &mut SimEnv, at: Nanos) {
        KvaccelDb::maintain(self, env, at);
    }

    /// KVACCEL's CDC stream merges both write interfaces: host-WAL
    /// records and redirected writes buffered in the device KV namespace
    /// (which bypass the host WAL). Both draw seqs from the one Main-LSM
    /// domain, so a merge by seq restores the total commit order. A
    /// rollback's merged-back copies re-enter the WAL under fresh seqs —
    /// the shipper re-captures them as duplicates, which replicas apply
    /// idempotently (same value, newer seq).
    fn cdc_tail(&self, env: &SimEnv, wm: &[Seq]) -> Vec<crate::engine::CdcRecord> {
        let wm0 = wm.first().copied().unwrap_or(0);
        let mut entries = self.main.wal_entries_after(wm0);
        entries.extend(env.device.kv_tail_since(self.ns, wm0));
        entries.sort_by_key(|e| e.seq);
        entries
            .into_iter()
            // ship values, never vlog pointers — the replica separates
            // against its own log
            .map(|entry| crate::engine::CdcRecord {
                entry: entry.inline_value(),
                stream: 0,
            })
            .collect()
    }

    /// Replica apply goes straight into the Main-LSM with the primary's
    /// seq (no Controller routing — a replica never redirects applies).
    /// Any device copy this node still routes (possible on a rejoined
    /// ex-primary) is superseded first, exactly like the main-path write
    /// step 3-1, so the rollback drain skips the stale copy.
    fn repl_apply(
        &mut self,
        env: &mut SimEnv,
        at: Nanos,
        rec: &crate::engine::CdcRecord,
    ) -> PutResult {
        self.tick(env, at);
        if self.metadata.check(env, at, rec.entry.key) {
            self.metadata.delete(env, at, rec.entry.key);
        }
        self.main.apply_entry(env, at, rec.entry)
    }

    fn set_block_cache(&mut self, cache: crate::engine::SharedBlockCache) {
        self.main.set_block_cache(cache);
    }

    fn kvaccel_mut(&mut self) -> Option<&mut KvaccelDb> {
        Some(self)
    }

    fn flush(&mut self, env: &mut SimEnv, at: Nanos) -> Nanos {
        self.main.flush_and_wait(env, at)
    }

    fn finish(&mut self, env: &mut SimEnv, at: Nanos) -> Result<Nanos> {
        KvaccelDb::finish(self, env, at)
    }

    fn close(self: Box<Self>, env: &mut SimEnv, at: Nanos) -> Result<DurableImage> {
        (*self).close_into_image(env, at)
    }

    fn crash(self: Box<Self>, env: &mut SimEnv, at: Nanos) -> DurableImage {
        (*self).crash_into_image(env, at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ssd::SsdConfig;

    fn rig(scheme: RollbackScheme) -> (KvaccelDb, SimEnv) {
        (
            KvaccelDb::new(
                LsmOptions::small_for_test(),
                KvaccelConfig::default().with_scheme(scheme),
                MergeEngine::rust(),
                BloomBuilder::rust(),
            ),
            SimEnv::new(9, SsdConfig::default()),
        )
    }

    fn v(seed: u32) -> ValueDesc {
        ValueDesc::new(seed, 4096)
    }

    #[test]
    fn basic_roundtrip() {
        let (mut db, mut env) = rig(RollbackScheme::Eager);
        let r = db.put(&mut env, 0, 1, v(1));
        let (got, _) = db.get(&mut env, r.done, 1);
        assert_eq!(got, Some(v(1)));
    }

    #[test]
    fn redirected_writes_readable_from_dev() {
        let (mut db, mut env) = rig(RollbackScheme::Disabled);
        // force the detector to believe a stall is imminent
        let mut t = 0;
        for k in 0..4000u32 {
            t = db.put(&mut env, t, k, v(k)).done;
        }
        assert!(
            db.controller.stats.writes_to_dev > 0,
            "pressure should have redirected some writes"
        );
        // every key still readable with the correct value
        for k in (0..4000u32).step_by(97) {
            let (got, nt) = db.get(&mut env, t, k);
            t = nt;
            assert_eq!(got, Some(v(k)), "key {k}");
        }
    }

    #[test]
    fn kvaccel_never_hard_stalls() {
        let (mut db, mut env) = rig(RollbackScheme::Disabled);
        let mut t = 0;
        let mut stalled = 0;
        for k in 0..4000u32 {
            let r = db.put(&mut env, t, k, v(k));
            t = r.done;
            stalled += r.stalled_ns;
        }
        assert_eq!(stalled, 0, "redirection must absorb stalls");
        assert_eq!(db.main.stall.slowdown_events, 0, "no slowdowns by design");
    }

    #[test]
    fn rollback_restores_single_store_semantics() {
        let (mut db, mut env) = rig(RollbackScheme::Eager);
        let mut t = 0;
        for k in 0..3000u32 {
            t = db.put(&mut env, t, k, v(k)).done;
        }
        t = db.finish(&mut env, t).unwrap();
        assert!(env.device.kv_is_empty(0), "finish must drain the Dev-LSM");
        assert!(db.metadata.is_empty());
        for k in (0..3000u32).step_by(113) {
            let (got, nt) = db.get(&mut env, t, k);
            t = nt;
            assert_eq!(got, Some(v(k)), "key {k} after rollback");
        }
    }

    #[test]
    fn overwrite_ordering_across_interfaces() {
        let (mut db, mut env) = rig(RollbackScheme::Disabled);
        let mut t = 0;
        // drive into redirection
        for k in 0..4000u32 {
            t = db.put(&mut env, t, k % 512, v(k)).done;
        }
        t = db.finish(&mut env, t).unwrap();
        // latest write of each key must win regardless of which interface
        // absorbed it
        for key in 0..512u32 {
            let latest = (0..4000u32).filter(|x| x % 512 == key).max().unwrap();
            let (got, nt) = db.get(&mut env, t, key);
            t = nt;
            assert_eq!(got, Some(v(latest)), "key {key}");
        }
    }

    #[test]
    fn scan_spans_both_interfaces() {
        let (mut db, mut env) = rig(RollbackScheme::Disabled);
        let mut t = 0;
        for k in 0..4000u32 {
            t = db.put(&mut env, t, k, v(k)).done;
        }
        let (got, _) = db.scan(&mut env, t, 100, 50);
        let keys: Vec<Key> = got.iter().map(|e| e.key).collect();
        assert_eq!(keys, (100..150).collect::<Vec<_>>());
    }

    #[test]
    fn metadata_recovery_rebuilds_routing() {
        let (mut db, mut env) = rig(RollbackScheme::Disabled);
        let mut t = 0;
        for k in 0..4000u32 {
            t = db.put(&mut env, t, k, v(k)).done;
        }
        let before = db.metadata.len();
        assert!(before > 0, "expected redirected keys");
        db.metadata.clear(); // simulated crash
        t = db.recover_metadata(&mut env, t).unwrap();
        assert_eq!(db.metadata.len(), before, "recovery must restore routing");
        let _ = t;
    }

    #[test]
    fn batched_writes_redirect_as_a_unit() {
        use crate::engine::WriteBatch;
        let (mut db, mut env) = rig(RollbackScheme::Disabled);
        // drive the store into stall-imminent territory
        let mut t = 0;
        for k in 0..4000u32 {
            t = db.put(&mut env, t, k, v(k)).done;
        }
        let mut wb = WriteBatch::new();
        for k in 10_000..10_064u32 {
            wb.put(k, v(k));
        }
        wb.delete(10_000);
        let r = db.write_batch(&mut env, t, &wb);
        assert_eq!(r.ops, 65);
        // this batch fits the dev buffer, so redirection absorbs the
        // stall; a batch that overflows the KV region spills its tail
        // through the Main-LSM and may legitimately block there
        assert_eq!(r.stalled_ns, 0, "in-buffer batch should not hard-stall");
        t = db.finish(&mut env, r.done).unwrap();
        for k in 10_001..10_064u32 {
            let (got, nt) = db.get(&mut env, t, k);
            t = nt;
            assert_eq!(got, Some(v(k)), "key {k}");
        }
        let (got, _) = db.get(&mut env, t, 10_000);
        assert_eq!(got, None, "batched delete must win over batched put");
    }

    #[test]
    fn delete_routes_like_put() {
        let (mut db, mut env) = rig(RollbackScheme::Disabled);
        let mut t = 0;
        for k in 0..4000u32 {
            t = db.put(&mut env, t, k, v(k)).done;
        }
        // deletes issued under pressure redirect to the Dev-LSM like puts
        for k in (0..4000u32).step_by(500) {
            t = db.delete(&mut env, t, k).done;
        }
        t = db.finish(&mut env, t).unwrap();
        for k in (0..4000u32).step_by(500) {
            let (got, nt) = db.get(&mut env, t, k);
            t = nt;
            assert_eq!(got, None, "deleted key {k} resurfaced");
        }
        let (got, _) = db.get(&mut env, t, 3);
        assert_eq!(got, Some(v(3)));
    }

    #[test]
    fn tombstone_through_dev_interface() {
        let (mut db, mut env) = rig(RollbackScheme::Disabled);
        let mut t = 0;
        for k in 0..4000u32 {
            t = db.put(&mut env, t, k, v(k)).done;
        }
        // find a redirected key and tombstone it (likely still redirecting)
        t = db.put(&mut env, t, 42, ValueDesc::TOMBSTONE).done;
        t = db.finish(&mut env, t).unwrap();
        let (got, _) = db.get(&mut env, t, 42);
        assert_eq!(got, None, "tombstone must survive rollback");
    }
}
