//! Small utilities: CLI parsing (offline image has no clap), LRU cache,
//! human formatting.

pub mod cli;
pub mod fmt;
pub mod lru;

pub use cli::Args;
pub use lru::LruCache;
