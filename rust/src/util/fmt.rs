//! Human-readable number formatting for experiment output tables.

/// 1234567 -> "1.23 M", 630_000_000 -> "630.00 M"
pub fn si(v: f64) -> String {
    let (scaled, suffix) = if v.abs() >= 1e9 {
        (v / 1e9, " G")
    } else if v.abs() >= 1e6 {
        (v / 1e6, " M")
    } else if v.abs() >= 1e3 {
        (v / 1e3, " K")
    } else {
        (v, " ")
    };
    format!("{scaled:.2}{suffix}")
}

/// Bytes -> "630.0 MB/s"-style strings.
pub fn bytes(v: f64) -> String {
    let (scaled, suffix) = if v.abs() >= 1024.0 * 1024.0 * 1024.0 {
        (v / (1024.0 * 1024.0 * 1024.0), "GB")
    } else if v.abs() >= 1024.0 * 1024.0 {
        (v / (1024.0 * 1024.0), "MB")
    } else if v.abs() >= 1024.0 {
        (v / 1024.0, "KB")
    } else {
        (v, "B")
    };
    format!("{scaled:.1} {suffix}")
}

/// Nanoseconds -> "1.37 us" / "2.5 ms" / "3.1 s".
pub fn nanos(v: f64) -> String {
    if v >= 1e9 {
        format!("{:.2} s", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2} ms", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.2} us", v / 1e3)
    } else {
        format!("{v:.0} ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn si_scales() {
        assert_eq!(si(1_234_567.0), "1.23 M");
        assert_eq!(si(999.0).trim_end(), "999.00");
        assert_eq!(si(2_500.0), "2.50 K");
    }

    #[test]
    fn bytes_scales() {
        assert_eq!(bytes(630.0 * 1024.0 * 1024.0), "630.0 MB");
        assert_eq!(bytes(512.0), "512.0 B");
    }

    #[test]
    fn nanos_scales() {
        assert_eq!(nanos(1370.0), "1.37 us");
        assert_eq!(nanos(250.0), "250 ns");
        assert_eq!(nanos(2.5e9), "2.50 s");
    }
}
