//! Minimal CLI argument parser (the offline image has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::HashMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get_u64(name, default as u64) as usize
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a float, got {v:?}")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn mixed_forms() {
        let a = parse("run fig11 --seed 7 --scale=0.5 --verbose");
        assert_eq!(a.positional, vec!["run", "fig11"]);
        assert_eq!(a.get_u64("seed", 0), 7);
        assert!((a.get_f64("scale", 1.0) - 0.5).abs() < 1e-12);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--fast");
        assert!(a.flag("fast"));
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_u64("n", 9), 9);
    }
}
