//! A compact LRU cache (HashMap + intrusive doubly-linked list over a
//! slab). Used for the block cache on the Main-LSM read path.

use std::collections::HashMap;
use std::hash::Hash;

const NIL: usize = usize::MAX;

struct Node<K, V> {
    key: K,
    value: V,
    prev: usize,
    next: usize,
}

pub struct LruCache<K: Eq + Hash + Clone, V> {
    map: HashMap<K, usize>,
    slab: Vec<Node<K, V>>,
    free: Vec<usize>,
    head: usize, // most-recently used
    tail: usize, // least-recently used
    capacity: usize,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl<K: Eq + Hash + Clone, V> LruCache<K, V> {
    /// A `capacity` of 0 means "cache disabled": inserts are dropped and
    /// every lookup misses. Callers on hot paths should skip the probe
    /// entirely when `capacity() == 0`.
    pub fn new(capacity: usize) -> Self {
        Self {
            map: HashMap::with_capacity(capacity + 1),
            slab: Vec::with_capacity(capacity.min(1 << 20)),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            capacity,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    fn detach(&mut self, idx: usize) {
        let (prev, next) = (self.slab[idx].prev, self.slab[idx].next);
        if prev != NIL {
            self.slab[prev].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.slab[next].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    fn attach_front(&mut self, idx: usize) {
        self.slab[idx].prev = NIL;
        self.slab[idx].next = self.head;
        if self.head != NIL {
            self.slab[self.head].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    /// Look up and mark as most-recently-used.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        match self.map.get(key).copied() {
            Some(idx) => {
                self.hits += 1;
                self.detach(idx);
                self.attach_front(idx);
                Some(&self.slab[idx].value)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Check membership without counting a hit or touching recency.
    pub fn contains(&self, key: &K) -> bool {
        self.map.contains_key(key)
    }

    /// Insert (or refresh) a key. Evicts LRU entries over capacity.
    pub fn insert(&mut self, key: K, value: V) {
        if self.capacity == 0 {
            return;
        }
        if let Some(&idx) = self.map.get(&key) {
            self.slab[idx].value = value;
            self.detach(idx);
            self.attach_front(idx);
            return;
        }
        let idx = match self.free.pop() {
            Some(i) => {
                self.slab[i] = Node { key: key.clone(), value, prev: NIL, next: NIL };
                i
            }
            None => {
                self.slab.push(Node { key: key.clone(), value, prev: NIL, next: NIL });
                self.slab.len() - 1
            }
        };
        self.map.insert(key, idx);
        self.attach_front(idx);
        while self.map.len() > self.capacity {
            let tail = self.tail;
            debug_assert_ne!(tail, NIL);
            self.detach(tail);
            let k = self.slab[tail].key.clone();
            self.map.remove(&k);
            self.free.push(tail);
            self.evictions += 1;
        }
    }

    /// Drop a key without touching the hit/miss/eviction counters
    /// (invalidation, not capacity pressure).
    pub fn remove(&mut self, key: &K) -> bool {
        match self.map.remove(key) {
            Some(idx) => {
                self.detach(idx);
                self.free.push(idx);
                true
            }
            None => false,
        }
    }

    /// Keep only the entries whose key satisfies the predicate
    /// (invalidation sweep, e.g. dropping a dead SST's blocks).
    pub fn retain<F: FnMut(&K) -> bool>(&mut self, mut pred: F) {
        let doomed: Vec<K> =
            self.map.keys().filter(|k| !pred(k)).cloned().collect();
        for k in doomed {
            self.remove(&k);
        }
    }

    pub fn clear(&mut self) {
        self.map.clear();
        self.slab.clear();
        self.free.clear();
        self.head = NIL;
        self.tail = NIL;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_lru_order() {
        let mut c = LruCache::new(2);
        c.insert(1, "a");
        c.insert(2, "b");
        c.get(&1); // 2 is now LRU
        c.insert(3, "c");
        assert!(c.get(&2).is_none());
        assert!(c.get(&1).is_some());
        assert!(c.get(&3).is_some());
    }

    #[test]
    fn update_refreshes() {
        let mut c = LruCache::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        c.insert(1, 11); // refresh 1; 2 becomes LRU
        c.insert(3, 30);
        assert_eq!(c.get(&1), Some(&11));
        assert!(c.get(&2).is_none());
    }

    #[test]
    fn hit_rate_counts() {
        let mut c = LruCache::new(4);
        c.insert(1, ());
        c.get(&1);
        c.get(&9);
        assert!((c.hit_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn reuses_slots_after_eviction() {
        let mut c = LruCache::new(2);
        for i in 0..100 {
            c.insert(i, i);
        }
        assert_eq!(c.len(), 2);
        assert!(c.slab.len() <= 3);
        assert_eq!(c.evictions(), 98);
    }

    #[test]
    fn zero_capacity_disables() {
        let mut c = LruCache::new(0);
        c.insert(1, ());
        assert!(c.is_empty());
        assert!(c.get(&1).is_none());
        assert_eq!(c.evictions(), 0);
    }

    #[test]
    fn remove_and_retain_skip_counters() {
        let mut c = LruCache::new(8);
        for i in 0..6 {
            c.insert((i % 2, i), i);
        }
        assert!(c.remove(&(0, 0)));
        assert!(!c.remove(&(0, 0)));
        c.retain(|k| k.0 != 1);
        assert_eq!(c.len(), 2);
        assert!(c.contains(&(0, 2)) && c.contains(&(0, 4)));
        assert_eq!(c.evictions(), 0);
        // freed slots are reused, not leaked
        for i in 10..14 {
            c.insert((0, i), i);
        }
        assert_eq!(c.len(), 6);
        assert!(c.slab.len() <= 8);
    }
}
