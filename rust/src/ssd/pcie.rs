//! PCIe link model + bandwidth tracker (the simulator's stand-in for
//! Intel PCM — Figs 4, 5, 14 are read straight off this tracker).
//!
//! Gen2 x8: 4 GB/s raw per direction; we model an effective payload rate
//! and full-duplex independent horizons. Every transfer is binned into
//! 1-second buckets (split accurately across bucket boundaries) so the
//! per-second MB/s series is exact.

use crate::sim::{Nanos, NS_PER_SEC};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    HostToDevice,
    DeviceToHost,
}

#[derive(Clone, Debug)]
pub struct PcieConfig {
    /// Effective payload bytes per nanosecond per direction.
    /// Gen2 x8 = 4 GB/s raw, ~3.2 GB/s effective -> 3.2 B/ns.
    pub bytes_per_ns: f64,
    /// Per-command fixed overhead (doorbell, completion).
    pub cmd_overhead: Nanos,
}

impl Default for PcieConfig {
    fn default() -> Self {
        Self {
            bytes_per_ns: 3.2,
            cmd_overhead: 2_000, // 2 us NVMe round-trip floor
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct PcieStats {
    /// bytes per 1-second bin, host->device
    pub h2d_bins: Vec<u64>,
    /// bytes per 1-second bin, device->host
    pub d2h_bins: Vec<u64>,
    pub h2d_total: u64,
    pub d2h_total: u64,
}

impl PcieStats {
    fn record(&mut self, dir: Direction, start: Nanos, end: Nanos, bytes: u64) {
        let bins = match dir {
            Direction::HostToDevice => &mut self.h2d_bins,
            Direction::DeviceToHost => &mut self.d2h_bins,
        };
        match dir {
            Direction::HostToDevice => self.h2d_total += bytes,
            Direction::DeviceToHost => self.d2h_total += bytes,
        }
        let span = (end - start).max(1);
        let first = (start / NS_PER_SEC) as usize;
        let last = (end.saturating_sub(1) / NS_PER_SEC) as usize;
        if bins.len() <= last {
            bins.resize(last + 1, 0);
        }
        if first == last {
            bins[first] += bytes;
            return;
        }
        // Split proportionally across the seconds the transfer spans.
        let mut remaining = bytes;
        for sec in first..=last {
            let bin_start = (sec as u64) * NS_PER_SEC;
            let bin_end = bin_start + NS_PER_SEC;
            let overlap = end.min(bin_end).saturating_sub(start.max(bin_start));
            let share = ((bytes as u128 * overlap as u128) / span as u128) as u64;
            let share = share.min(remaining);
            bins[sec] += share;
            remaining -= share;
        }
        if remaining > 0 {
            bins[last] += remaining;
        }
    }

    /// Combined (both directions) MB/s per second.
    pub fn combined_mbps(&self) -> Vec<f64> {
        let n = self.h2d_bins.len().max(self.d2h_bins.len());
        (0..n)
            .map(|i| {
                let h = self.h2d_bins.get(i).copied().unwrap_or(0);
                let d = self.d2h_bins.get(i).copied().unwrap_or(0);
                (h + d) as f64 / (1024.0 * 1024.0)
            })
            .collect()
    }
}

/// Full-duplex link with independent busy horizons per direction.
#[derive(Clone, Debug)]
pub struct PcieLink {
    cfg: PcieConfig,
    h2d_free: Nanos,
    d2h_free: Nanos,
    pub stats: PcieStats,
}

impl PcieLink {
    pub fn new(cfg: PcieConfig) -> Self {
        Self {
            cfg,
            h2d_free: 0,
            d2h_free: 0,
            stats: PcieStats::default(),
        }
    }

    pub fn config(&self) -> &PcieConfig {
        &self.cfg
    }

    /// Bulk transfer `bytes` starting no earlier than `t`; returns
    /// completion. Bulk streams (SST files, WAL writeback, rollback DMA
    /// chunks) serialize FIFO per direction — they are bandwidth-bound.
    pub fn transfer(&mut self, t: Nanos, bytes: u64, dir: Direction) -> Nanos {
        let free = match dir {
            Direction::HostToDevice => &mut self.h2d_free,
            Direction::DeviceToHost => &mut self.d2h_free,
        };
        let start = t.max(*free) + self.cfg.cmd_overhead;
        let dur = (bytes as f64 / self.cfg.bytes_per_ns).ceil() as Nanos;
        let end = start + dur;
        *free = end;
        self.stats.record(dir, start, end, bytes);
        end
    }

    /// Latency-sensitive small transfer (NVMe-KV commands, single-page
    /// iterator reads). PCIe is packet-interleaved: a 4 KB command does
    /// NOT wait behind an in-flight multi-MB DMA; while bulk traffic is
    /// active it sees roughly half the lane rate (fair share), otherwise
    /// the full rate. Does not push the bulk horizon.
    pub fn transfer_small(&mut self, t: Nanos, bytes: u64, dir: Direction) -> Nanos {
        let bulk_busy = match dir {
            Direction::HostToDevice => self.h2d_free > t,
            Direction::DeviceToHost => self.d2h_free > t,
        };
        let rate = if bulk_busy {
            self.cfg.bytes_per_ns / 2.0
        } else {
            self.cfg.bytes_per_ns
        };
        let start = t + self.cfg.cmd_overhead;
        let dur = (bytes as f64 / rate).ceil() as Nanos;
        let end = start + dur;
        self.stats.record(dir, start, end, bytes);
        end
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_math() {
        let mut link = PcieLink::new(PcieConfig { bytes_per_ns: 4.0, cmd_overhead: 0 });
        let end = link.transfer(0, 4_000, Direction::HostToDevice);
        assert_eq!(end, 1_000);
    }

    #[test]
    fn directions_independent() {
        let mut link = PcieLink::new(PcieConfig { bytes_per_ns: 1.0, cmd_overhead: 0 });
        let a = link.transfer(0, 1_000_000, Direction::HostToDevice);
        let b = link.transfer(0, 1_000, Direction::DeviceToHost);
        assert!(b < a, "full duplex: d2h should not queue behind h2d");
    }

    #[test]
    fn same_direction_serializes() {
        let mut link = PcieLink::new(PcieConfig { bytes_per_ns: 1.0, cmd_overhead: 0 });
        link.transfer(0, 1_000, Direction::HostToDevice);
        let second = link.transfer(0, 1_000, Direction::HostToDevice);
        assert_eq!(second, 2_000);
    }

    #[test]
    fn bins_split_across_seconds() {
        let mut link = PcieLink::new(PcieConfig { bytes_per_ns: 1.0, cmd_overhead: 0 });
        // 2-second transfer spanning bins 0 and 1 equally
        link.transfer(0, 2 * NS_PER_SEC, Direction::HostToDevice);
        let bins = &link.stats.h2d_bins;
        assert_eq!(bins.len(), 2);
        let total: u64 = bins.iter().sum();
        assert_eq!(total, 2 * NS_PER_SEC);
        assert!((bins[0] as i64 - bins[1] as i64).abs() < (NS_PER_SEC / 100) as i64);
    }

    #[test]
    fn totals_accumulate() {
        let mut link = PcieLink::new(PcieConfig::default());
        link.transfer(0, 100, Direction::HostToDevice);
        link.transfer(0, 200, Direction::DeviceToHost);
        assert_eq!(link.stats.h2d_total, 100);
        assert_eq!(link.stats.d2h_total, 200);
    }

    #[test]
    fn combined_series() {
        let mut link = PcieLink::new(PcieConfig { bytes_per_ns: 1000.0, cmd_overhead: 0 });
        link.transfer(0, 1024 * 1024, Direction::HostToDevice);
        link.transfer(0, 1024 * 1024, Direction::DeviceToHost);
        let s = link.stats.combined_mbps();
        assert!((s[0] - 2.0).abs() < 1e-9);
    }
}
