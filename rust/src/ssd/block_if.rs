//! Block interface: a minimal extent filesystem over the FTL's block
//! region — the stand-in for ext4 hosting the Main-LSM's SST and WAL
//! files. Tracks file extents and sizes; file *content* lives in the
//! owning LSM structures (typed entries), while every byte is charged to
//! the NAND/PCIe models here.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use super::ftl::{Extent, Ftl, Region};

pub type FileId = u64;

#[derive(Clone, Debug)]
pub struct FileMeta {
    pub extent: Extent,
    pub bytes: u64,
    /// Directory tag: which store's files these are. Matches the owning
    /// LSM's WAL stream id (0 for an unsharded store); a sharded store's
    /// per-shard recovery scans only its own directory, so one shard's
    /// orphan cleanup can never delete a sibling's live SSTs.
    pub owner: u32,
}

#[derive(Clone, Debug, Default)]
pub struct BlockFs {
    files: BTreeMap<FileId, FileMeta>,
    next_id: FileId,
    pub bytes_written: u64,
    pub bytes_deleted: u64,
}

impl BlockFs {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate a file of `bytes` in the block region (directory 0).
    pub fn create_file(&mut self, ftl: &mut Ftl, bytes: u64) -> Result<FileId> {
        self.create_file_for(ftl, 0, bytes)
    }

    /// Allocate a file in `owner`'s directory.
    pub fn create_file_for(
        &mut self,
        ftl: &mut Ftl,
        owner: u32,
        bytes: u64,
    ) -> Result<FileId> {
        let extent = ftl.alloc_bytes(Region::Block, bytes)?;
        let id = self.next_id;
        self.next_id += 1;
        self.files.insert(id, FileMeta { extent, bytes, owner });
        self.bytes_written += bytes;
        Ok(id)
    }

    pub fn delete_file(&mut self, ftl: &mut Ftl, id: FileId) -> Result<()> {
        let meta = self
            .files
            .remove(&id)
            .ok_or_else(|| anyhow!("delete of unknown file {id}"))?;
        ftl.trim(Region::Block, meta.extent);
        self.bytes_deleted += meta.bytes;
        Ok(())
    }

    pub fn file(&self, id: FileId) -> Option<&FileMeta> {
        self.files.get(&id)
    }

    pub fn file_count(&self) -> usize {
        self.files.len()
    }

    /// All live file ids, sorted (deterministic iteration for recovery's
    /// orphan cleanup).
    pub fn file_ids(&self) -> Vec<FileId> {
        let mut ids: Vec<FileId> = self.files.keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    /// Live file ids in `owner`'s directory, sorted — the scope of one
    /// store's recovery scan.
    pub fn file_ids_for(&self, owner: u32) -> Vec<FileId> {
        let mut ids: Vec<FileId> = self
            .files
            .iter()
            .filter(|(_, m)| m.owner == owner)
            .map(|(&id, _)| id)
            .collect();
        ids.sort_unstable();
        ids
    }

    pub fn live_bytes(&self) -> u64 {
        self.files.values().map(|f| f.bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rig() -> (BlockFs, Ftl) {
        (BlockFs::new(), Ftl::new(10_000, 8_000, 16 * 1024))
    }

    #[test]
    fn create_and_delete() {
        let (mut fs, mut ftl) = rig();
        let id = fs.create_file(&mut ftl, 1 << 20).unwrap();
        assert_eq!(fs.file(id).unwrap().bytes, 1 << 20);
        assert_eq!(fs.file_count(), 1);
        fs.delete_file(&mut ftl, id).unwrap();
        assert_eq!(fs.file_count(), 0);
        assert_eq!(ftl.allocated_pages(Region::Block), 0);
    }

    #[test]
    fn delete_unknown_errors() {
        let (mut fs, mut ftl) = rig();
        assert!(fs.delete_file(&mut ftl, 99).is_err());
    }

    #[test]
    fn ids_unique() {
        let (mut fs, mut ftl) = rig();
        let a = fs.create_file(&mut ftl, 100).unwrap();
        let b = fs.create_file(&mut ftl, 100).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn accounting_totals() {
        let (mut fs, mut ftl) = rig();
        let a = fs.create_file(&mut ftl, 500).unwrap();
        fs.create_file(&mut ftl, 300).unwrap();
        fs.delete_file(&mut ftl, a).unwrap();
        assert_eq!(fs.bytes_written, 800);
        assert_eq!(fs.bytes_deleted, 500);
        assert_eq!(fs.live_bytes(), 300);
    }
}
