//! Dev-LSM: the in-device LSM-based write buffer behind the key-value
//! interface (paper §V-B/§V-E). Runs entirely on the device's single ARM
//! Cortex-A9 core; its NAND traffic shares the array with the block
//! interface.
//!
//! Structure: a device memtable (DRAM, capacitor-backed like commercial
//! KV-SSDs) plus L0-style sorted runs programmed to the KV region of the
//! FTL. No in-device compaction by default (the paper disables Dev-LSM
//! compaction for its write-intensive evaluation; `DevLsmConfig::compact`
//! enables a simple run-count-triggered merge).

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;

use crate::lsm::entry::{Entry, Key, Seq, ValueDesc};
use crate::sim::{Nanos, MICROS};

use super::ftl::{Extent, Ftl, Region};
use super::nand::{NandArray, NandOp};

#[derive(Clone, Debug)]
pub struct DevLsmConfig {
    /// Device DRAM budget for the memtable.
    pub memtable_bytes: u64,
    /// ARM cost of one memtable insert.
    pub arm_put_ns: Nanos,
    /// ARM cost of a point-lookup step (memtable or one run probe).
    pub arm_lookup_ns: Nanos,
    /// ARM cost per entry while serializing (flush/scan).
    pub arm_serialize_ns: Nanos,
    /// Merge device runs when their count exceeds this (0 = never, the
    /// paper's workload-A configuration).
    pub compact_run_trigger: usize,
}

impl Default for DevLsmConfig {
    fn default() -> Self {
        Self {
            memtable_bytes: 32 * 1024 * 1024,
            arm_put_ns: 3 * MICROS,
            arm_lookup_ns: 2 * MICROS,
            arm_serialize_ns: MICROS / 2,
            compact_run_trigger: 0,
        }
    }
}

/// One sorted run in the KV region.
#[derive(Clone, Debug)]
pub struct DevRun {
    pub entries: Arc<Vec<Entry>>,
    pub extent: Extent,
    pub bytes: u64,
}

#[derive(Clone, Debug, Default)]
pub struct DevLsmStats {
    pub puts: u64,
    pub gets: u64,
    pub flushes: u64,
    pub resets: u64,
    pub bulk_scans: u64,
    pub compactions: u64,
}

/// The in-device LSM. NAND/FTL are passed in by the owning `SsdDevice`
/// (they are shared with the block interface — that sharing *is* the
/// paper's architecture).
#[derive(Clone, Debug)]
pub struct DevLsm {
    cfg: DevLsmConfig,
    mem: BTreeMap<Key, (Seq, ValueDesc)>,
    mem_bytes: u64,
    runs: Vec<DevRun>, // newest first
    /// Single ARM core busy horizon.
    arm_free: Nanos,
    /// Cached materialized memtable run handed to snapshots;
    /// invalidated on every memtable mutation (copy-on-write pinning).
    pinned_mem: Option<Arc<Vec<Entry>>>,
    pub stats: DevLsmStats,
}

impl DevLsm {
    pub fn new(cfg: DevLsmConfig) -> Self {
        Self {
            cfg,
            mem: BTreeMap::new(),
            mem_bytes: 0,
            runs: Vec::new(),
            arm_free: 0,
            pinned_mem: None,
            stats: DevLsmStats::default(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.mem.is_empty() && self.runs.is_empty()
    }

    pub fn entry_count(&self) -> usize {
        self.mem.len() + self.runs.iter().map(|r| r.entries.len()).sum::<usize>()
    }

    pub fn buffered_bytes(&self) -> u64 {
        self.mem_bytes + self.runs.iter().map(|r| r.bytes).sum::<u64>()
    }

    pub fn run_count(&self) -> usize {
        self.runs.len()
    }

    /// Charge `work` on the ARM core starting no earlier than `t`.
    fn arm(&mut self, t: Nanos, work: Nanos) -> Nanos {
        let start = t.max(self.arm_free);
        self.arm_free = start + work;
        self.arm_free
    }

    /// Device-side PUT (data already DMA'd in). Returns ack time and the
    /// ARM busy-time charged (for device-CPU accounting).
    pub fn put(
        &mut self,
        t: Nanos,
        entry: Entry,
        nand: &mut NandArray,
        ftl: &mut Ftl,
    ) -> Result<(Nanos, Nanos)> {
        self.stats.puts += 1;
        let mut charged = self.cfg.arm_put_ns;
        let ack = self.arm(t, self.cfg.arm_put_ns);
        let sz = entry.encoded_len();
        self.mem_bytes += sz;
        self.pinned_mem = None;
        self.mem.insert(entry.key, (entry.seq, entry.val));
        if self.mem_bytes >= self.cfg.memtable_bytes {
            charged += self.flush(ack, nand, ftl)?;
        }
        Ok((ack, charged))
    }

    /// Materialize the memtable as a sorted entry run (flush input).
    fn mem_entries(&self) -> Vec<Entry> {
        self.mem
            .iter()
            .map(|(&k, &(seq, val))| Entry { key: k, seq, val })
            .collect()
    }

    /// Install `entries` as the newest run and clear the memtable —
    /// the structural half shared by the timed flush and the zero-cost
    /// capacitor dump. Returns the run's byte size.
    fn install_mem_run(&mut self, entries: Vec<Entry>, ftl: &mut Ftl) -> Result<u64> {
        let bytes: u64 = entries.iter().map(|e| e.encoded_len()).sum();
        let extent = ftl.alloc_bytes(Region::KeyValue, bytes)?;
        self.runs.insert(
            0,
            DevRun { entries: Arc::new(entries), extent, bytes },
        );
        self.mem.clear();
        self.mem_bytes = 0;
        self.pinned_mem = None;
        Ok(bytes)
    }

    /// Flush the device memtable to a sorted NAND run. The ARM serializes
    /// entries; NAND programs complete asynchronously (capacitor-backed).
    /// Returns ARM busy-time charged.
    pub fn flush(
        &mut self,
        t: Nanos,
        nand: &mut NandArray,
        ftl: &mut Ftl,
    ) -> Result<Nanos> {
        if self.mem.is_empty() {
            return Ok(0);
        }
        self.stats.flushes += 1;
        let entries = self.mem_entries();
        let work = self.cfg.arm_serialize_ns * entries.len() as u64;
        let ready = self.arm(t, work);
        let bytes = self.install_mem_run(entries, ftl)?;
        nand.submit(ready, bytes, NandOp::Program);
        if self.cfg.compact_run_trigger > 0 && self.runs.len() > self.cfg.compact_run_trigger
        {
            return Ok(work + self.compact_runs(ready, nand, ftl)?);
        }
        Ok(work)
    }

    /// Simple full-merge device compaction (optional; see config).
    fn compact_runs(
        &mut self,
        t: Nanos,
        nand: &mut NandArray,
        ftl: &mut Ftl,
    ) -> Result<Nanos> {
        self.stats.compactions += 1;
        let read_bytes: u64 = self.runs.iter().map(|r| r.bytes).sum();
        let ready = nand.submit(t, read_bytes, NandOp::Read);
        let merged = self.merged_entries();
        let work = self.cfg.arm_serialize_ns * merged.len() as u64;
        let done = self.arm(ready, work);
        let bytes: u64 = merged.iter().map(|e| e.encoded_len()).sum();
        for run in self.runs.drain(..) {
            ftl.trim(Region::KeyValue, run.extent);
        }
        let extent = ftl.alloc_bytes(Region::KeyValue, bytes)?;
        nand.submit(done, bytes, NandOp::Program);
        self.runs.push(DevRun { entries: Arc::new(merged), extent, bytes });
        Ok(work)
    }

    /// Point lookup. Returns (result, ack_time, arm_ns, nand_reads).
    pub fn get(
        &mut self,
        t: Nanos,
        key: Key,
        nand: &mut NandArray,
    ) -> (Option<ValueDesc>, Nanos, Nanos) {
        self.stats.gets += 1;
        let mut charged = self.cfg.arm_lookup_ns;
        let mut now = self.arm(t, self.cfg.arm_lookup_ns);
        if let Some(&(_, val)) = self.mem.get(&key) {
            return (Some(val), now, charged);
        }
        // probe runs newest-first; each probe costs a NAND page read —
        // the paper's "slower point read query on the Dev-LSM".
        let page = nand.config().page_bytes;
        let mut result = None;
        for run in &self.runs {
            charged += self.cfg.arm_lookup_ns;
            let probe_done = nand.submit(now, page, NandOp::Read);
            now = probe_done.max(now) + self.cfg.arm_lookup_ns;
            if let Ok(idx) = run.entries.binary_search_by(|e| e.key.cmp(&key)) {
                result = Some(run.entries[idx].val);
                break;
            }
        }
        self.arm_free = self.arm_free.max(now);
        (result, now, charged)
    }

    /// Zero-cost point lookup: the same memtable-then-newest-run walk as
    /// `get`, but charging no ARM/NAND time and touching no counters.
    /// Serves host block-cache hits, where the simulated I/O is skipped
    /// but the (live) value is still needed.
    pub fn peek(&self, key: Key) -> Option<ValueDesc> {
        if let Some(&(_, val)) = self.mem.get(&key) {
            return Some(val);
        }
        for run in &self.runs {
            if let Ok(idx) = run.entries.binary_search_by(|e| e.key.cmp(&key)) {
                return Some(run.entries[idx].val);
            }
        }
        None
    }

    /// All live entries, newest version per key, ascending by key. This is
    /// the iterator-based range scan's payload (paper Fig 9 steps 3-5).
    pub fn merged_entries(&self) -> Vec<Entry> {
        let mut out: BTreeMap<Key, (Seq, ValueDesc)> = BTreeMap::new();
        // oldest runs first so newer overwrite
        for run in self.runs.iter().rev() {
            for e in run.entries.iter() {
                match out.get(&e.key) {
                    Some(&(seq, _)) if seq >= e.seq => {}
                    _ => {
                        out.insert(e.key, (e.seq, e.val));
                    }
                }
            }
        }
        for (&k, &(seq, val)) in &self.mem {
            match out.get(&k) {
                Some(&(s, _)) if s >= seq => {}
                _ => {
                    out.insert(k, (seq, val));
                }
            }
        }
        out.into_iter()
            .map(|(k, (seq, val))| Entry { key: k, seq, val })
            .collect()
    }

    /// Iterator-based bulky range scan for rollback: reads every run page
    /// from NAND, merges on the ARM, and returns the entries plus the time
    /// the serialized stream is ready in device memory for DMA-out.
    /// Returns (entries, ready_time, arm_ns_charged, payload_bytes).
    pub fn bulk_scan(
        &mut self,
        t: Nanos,
        nand: &mut NandArray,
    ) -> (Vec<Entry>, Nanos, Nanos, u64) {
        self.stats.bulk_scans += 1;
        let read_bytes: u64 = self.runs.iter().map(|r| r.bytes).sum();
        let nand_done = if read_bytes > 0 {
            nand.submit(t, read_bytes, NandOp::Read)
        } else {
            t
        };
        let entries = self.merged_entries();
        let work = self.cfg.arm_serialize_ns * entries.len() as u64;
        let ready = self.arm(nand_done, work);
        let payload: u64 = entries.iter().map(|e| e.encoded_len()).sum();
        (entries, ready, work, payload)
    }

    /// Power-loss capacitor dump: the device memtable (capacitor-backed
    /// DRAM, commercial KV-SSD PLP semantics) persists as a NAND run with
    /// no timing cost — the capacitor powers the dump after host power is
    /// gone. If the KV region can't fit the run the memtable is retained
    /// in place (the DRAM copy itself is battery-persistent in this
    /// model), so redirected writes are never lost to a crash.
    pub fn power_loss_flush(&mut self, ftl: &mut Ftl) {
        if self.mem.is_empty() {
            return;
        }
        let entries = self.mem_entries();
        if self.install_mem_run(entries, ftl).is_ok() {
            self.stats.flushes += 1;
        }
    }

    /// CDC tailing cursor: every buffered entry with `seq > wm`, sorted
    /// by seq. Zero-cost like `peek` — the shipper's capture runs at
    /// host speed against capacitor-backed state; only the simulated
    /// replication link charges time.
    pub fn tail_since(&self, wm: Seq) -> Vec<Entry> {
        let mut out: Vec<Entry> = self
            .runs
            .iter()
            .flat_map(|r| r.entries.iter())
            .filter(|e| e.seq > wm)
            .copied()
            .collect();
        out.extend(
            self.mem
                .iter()
                .filter(|&(_, &(seq, _))| seq > wm)
                .map(|(&k, &(seq, val))| Entry { key: k, seq, val }),
        );
        out.sort_by_key(|e| e.seq);
        out
    }

    /// Largest sequence number resident anywhere in the buffer (recovery
    /// resumes the shared sequence domain above it).
    pub fn max_seq(&self) -> Seq {
        let mem_max = self.mem.values().map(|&(s, _)| s).max().unwrap_or(0);
        let run_max = self
            .runs
            .iter()
            .flat_map(|r| r.entries.iter().map(|e| e.seq))
            .max()
            .unwrap_or(0);
        mem_max.max(run_max)
    }

    /// Reset after rollback (paper Fig 9 step 8): trim every run, clear
    /// the memtable.
    pub fn reset(&mut self, t: Nanos, ftl: &mut Ftl) -> Nanos {
        self.stats.resets += 1;
        for run in self.runs.drain(..) {
            ftl.trim(Region::KeyValue, run.extent);
        }
        self.mem.clear();
        self.mem_bytes = 0;
        self.pinned_mem = None;
        self.arm(t, 10 * MICROS)
    }

    /// Snapshot for a range iterator (memtable materialized + run refs).
    /// The memtable run is cached copy-on-write, so read-only stretches
    /// (seekrandom, scan-heavy mixes) snapshot in O(runs).
    pub fn iter_snapshot(&mut self) -> DevSnapshot {
        if self.pinned_mem.is_none() {
            let mem_run: Vec<Entry> = self
                .mem
                .iter()
                .map(|(&k, &(seq, val))| Entry { key: k, seq, val })
                .collect();
            self.pinned_mem = Some(Arc::new(mem_run));
        }
        let mut runs: Vec<Arc<Vec<Entry>>> =
            vec![self.pinned_mem.as_ref().expect("just pinned").clone()];
        runs.extend(self.runs.iter().map(|r| r.entries.clone()));
        DevSnapshot { runs }
    }

    pub fn config(&self) -> &DevLsmConfig {
        &self.cfg
    }
}

/// Immutable snapshot of Dev-LSM state for range iteration (newest source
/// first).
#[derive(Clone, Debug)]
pub struct DevSnapshot {
    pub runs: Vec<Arc<Vec<Entry>>>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ssd::nand::NandConfig;

    fn rig() -> (DevLsm, NandArray, Ftl) {
        let nand_cfg = NandConfig::default();
        let total = 1 << 20;
        (
            DevLsm::new(DevLsmConfig::default()),
            NandArray::new(nand_cfg),
            Ftl::new(total, total / 2, 16 * 1024),
        )
    }

    fn e(key: Key, seq: Seq) -> Entry {
        Entry::new(key, seq, ValueDesc::new(key ^ seq, 4096))
    }

    #[test]
    fn put_then_get_from_memtable() {
        let (mut d, mut nand, mut ftl) = rig();
        d.put(0, e(5, 1), &mut nand, &mut ftl).unwrap();
        let (v, _, _) = d.get(1000, 5, &mut nand);
        assert_eq!(v, Some(ValueDesc::new(5 ^ 1, 4096)));
    }

    #[test]
    fn get_missing_is_none() {
        let (mut d, mut nand, _) = rig();
        let (v, _, _) = d.get(0, 42, &mut nand);
        assert!(v.is_none());
    }

    #[test]
    fn flush_creates_run_and_get_still_works() {
        let (mut d, mut nand, mut ftl) = rig();
        for k in 0..10 {
            d.put(0, e(k, k + 1), &mut nand, &mut ftl).unwrap();
        }
        d.flush(0, &mut nand, &mut ftl).unwrap();
        assert_eq!(d.run_count(), 1);
        let (v, t, _) = d.get(0, 3, &mut nand);
        assert_eq!(v, Some(ValueDesc::new(3 ^ 4, 4096)));
        // run probe paid a NAND read
        assert!(t >= nand.config().t_read);
    }

    #[test]
    fn memtable_overflow_autoflushes() {
        let nand_cfg = NandConfig::default();
        let mut d = DevLsm::new(DevLsmConfig {
            memtable_bytes: 10 * 4112,
            ..Default::default()
        });
        let mut nand = NandArray::new(nand_cfg);
        let mut ftl = Ftl::new(1 << 20, 0, 16 * 1024);
        for k in 0..25 {
            d.put(0, e(k, k + 1), &mut nand, &mut ftl).unwrap();
        }
        assert!(d.run_count() >= 2, "runs: {}", d.run_count());
    }

    #[test]
    fn merged_entries_newest_wins() {
        let (mut d, mut nand, mut ftl) = rig();
        d.put(0, e(1, 1), &mut nand, &mut ftl).unwrap();
        d.flush(0, &mut nand, &mut ftl).unwrap();
        d.put(0, e(1, 9), &mut nand, &mut ftl).unwrap();
        let m = d.merged_entries();
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].seq, 9);
    }

    #[test]
    fn bulk_scan_returns_everything_sorted() {
        let (mut d, mut nand, mut ftl) = rig();
        for k in [5u32, 1, 9, 3] {
            d.put(0, e(k, k), &mut nand, &mut ftl).unwrap();
        }
        d.flush(0, &mut nand, &mut ftl).unwrap();
        d.put(0, e(2, 10), &mut nand, &mut ftl).unwrap();
        let (entries, ready, _, payload) = d.bulk_scan(0, &mut nand);
        let keys: Vec<Key> = entries.iter().map(|e| e.key).collect();
        assert_eq!(keys, vec![1, 2, 3, 5, 9]);
        assert!(ready > 0);
        assert!(payload > 5 * 4096);
    }

    #[test]
    fn reset_empties_and_frees_pages(){
        let (mut d, mut nand, mut ftl) = rig();
        for k in 0..10 {
            d.put(0, e(k, k + 1), &mut nand, &mut ftl).unwrap();
        }
        d.flush(0, &mut nand, &mut ftl).unwrap();
        let allocated = ftl.allocated_pages(Region::KeyValue);
        assert!(allocated > 0);
        d.reset(0, &mut ftl);
        assert!(d.is_empty());
        assert_eq!(ftl.allocated_pages(Region::KeyValue), 0);
    }

    #[test]
    fn device_compaction_merges_runs() {
        let nand_cfg = NandConfig::default();
        let mut d = DevLsm::new(DevLsmConfig {
            memtable_bytes: 5 * 4112,
            compact_run_trigger: 2,
            ..Default::default()
        });
        let mut nand = NandArray::new(nand_cfg);
        let mut ftl = Ftl::new(1 << 20, 0, 16 * 1024);
        for k in 0..40 {
            d.put(0, e(k % 7, k + 1), &mut nand, &mut ftl).unwrap();
        }
        d.flush(0, &mut nand, &mut ftl).unwrap();
        assert!(d.run_count() <= 2, "compaction should bound runs");
        assert!(d.stats.compactions > 0);
    }

    #[test]
    fn power_loss_dumps_memtable_to_a_run() {
        let (mut d, mut nand, mut ftl) = rig();
        for k in 0..6 {
            d.put(0, e(k, k + 1), &mut nand, &mut ftl).unwrap();
        }
        assert_eq!(d.run_count(), 0);
        assert_eq!(d.max_seq(), 6);
        d.power_loss_flush(&mut ftl);
        assert_eq!(d.run_count(), 1);
        assert!(d.mem.is_empty());
        assert_eq!(d.max_seq(), 6, "sequence domain preserved across the dump");
        let m = d.merged_entries();
        assert_eq!(m.len(), 6, "no entry lost at power loss");
    }

    #[test]
    fn arm_core_serializes_ops() {
        let (mut d, mut nand, mut ftl) = rig();
        let (a1, _) = d.put(0, e(1, 1), &mut nand, &mut ftl).unwrap();
        let (a2, _) = d.put(0, e(2, 2), &mut nand, &mut ftl).unwrap();
        assert!(a2 >= a1 + d.config().arm_put_ns);
    }
}
