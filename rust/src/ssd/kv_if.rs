//! Key-value interface: NVMe-KV-style command surface (PUT / GET / SEEK /
//! NEXT / bulk SCAN / RESET) with namespace support.
//!
//! Multi-tenancy (paper §V-D): each KV namespace owns an isolated Dev-LSM;
//! all namespaces share the device's single ARM core, the NAND array and
//! the KV region of the FTL — the same isolation model as [37].

use anyhow::{anyhow, Result};

use super::devlsm::{DevLsm, DevLsmConfig, DevSnapshot};
use super::ftl::Ftl;
use super::nand::NandArray;
use crate::lsm::entry::{Entry, Key, ValueDesc};
use crate::sim::Nanos;

pub type NamespaceId = u32;

#[derive(Debug)]
pub struct KvInterface {
    namespaces: Vec<DevLsm>,
}

impl KvInterface {
    pub fn new(cfg: DevLsmConfig) -> Self {
        Self { namespaces: vec![DevLsm::new(cfg)] }
    }

    /// Create an additional namespace; returns its id.
    pub fn create_namespace(&mut self, cfg: DevLsmConfig) -> NamespaceId {
        self.namespaces.push(DevLsm::new(cfg));
        (self.namespaces.len() - 1) as NamespaceId
    }

    pub fn namespace_count(&self) -> usize {
        self.namespaces.len()
    }

    pub fn ns(&self, ns: NamespaceId) -> Result<&DevLsm> {
        self.namespaces
            .get(ns as usize)
            .ok_or_else(|| anyhow!("unknown KV namespace {ns}"))
    }

    pub fn ns_mut(&mut self, ns: NamespaceId) -> Result<&mut DevLsm> {
        self.namespaces
            .get_mut(ns as usize)
            .ok_or_else(|| anyhow!("unknown KV namespace {ns}"))
    }

    pub fn put(
        &mut self,
        ns: NamespaceId,
        t: Nanos,
        entry: Entry,
        nand: &mut NandArray,
        ftl: &mut Ftl,
    ) -> Result<(Nanos, Nanos)> {
        self.ns_mut(ns)?.put(t, entry, nand, ftl)
    }

    pub fn get(
        &mut self,
        ns: NamespaceId,
        t: Nanos,
        key: Key,
        nand: &mut NandArray,
    ) -> Result<(Option<ValueDesc>, Nanos, Nanos)> {
        Ok(self.ns_mut(ns)?.get(t, key, nand))
    }

    pub fn bulk_scan(
        &mut self,
        ns: NamespaceId,
        t: Nanos,
        nand: &mut NandArray,
    ) -> Result<(Vec<Entry>, Nanos, Nanos, u64)> {
        Ok(self.ns_mut(ns)?.bulk_scan(t, nand))
    }

    pub fn reset(&mut self, ns: NamespaceId, t: Nanos, ftl: &mut Ftl) -> Result<Nanos> {
        Ok(self.ns_mut(ns)?.reset(t, ftl))
    }

    pub fn snapshot(&mut self, ns: NamespaceId) -> Result<DevSnapshot> {
        Ok(self.ns_mut(ns)?.iter_snapshot())
    }

    /// Power loss: every namespace's capacitor-backed memtable dumps to
    /// a NAND run (runs themselves are already on flash and survive).
    pub fn power_loss(&mut self, ftl: &mut Ftl) {
        for ns in &mut self.namespaces {
            ns.power_loss_flush(ftl);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ssd::nand::NandConfig;

    fn rig() -> (KvInterface, NandArray, Ftl) {
        (
            KvInterface::new(DevLsmConfig::default()),
            NandArray::new(NandConfig::default()),
            Ftl::new(1 << 20, 0, 16 * 1024),
        )
    }

    fn e(key: Key, seq: u32) -> Entry {
        Entry::new(key, seq, ValueDesc::new(key, 128))
    }

    #[test]
    fn default_namespace_works() {
        let (mut kv, mut nand, mut ftl) = rig();
        kv.put(0, 0, e(1, 1), &mut nand, &mut ftl).unwrap();
        let (v, _, _) = kv.get(0, 0, 1, &mut nand).unwrap();
        assert_eq!(v, Some(ValueDesc::new(1, 128)));
    }

    #[test]
    fn namespaces_isolated() {
        let (mut kv, mut nand, mut ftl) = rig();
        let ns2 = kv.create_namespace(DevLsmConfig::default());
        kv.put(0, 0, e(1, 1), &mut nand, &mut ftl).unwrap();
        let (v, _, _) = kv.get(ns2, 0, 1, &mut nand).unwrap();
        assert!(v.is_none(), "tenant isolation violated");
        kv.put(ns2, 0, e(1, 7), &mut nand, &mut ftl).unwrap();
        let (v0, _, _) = kv.get(0, 0, 1, &mut nand).unwrap();
        assert_eq!(v0.map(|d| d.seed), Some(1));
    }

    #[test]
    fn unknown_namespace_errors() {
        let (mut kv, mut nand, _) = rig();
        assert!(kv.get(9, 0, 1, &mut nand).is_err());
    }

    #[test]
    fn reset_scopes_to_namespace() {
        let (mut kv, mut nand, mut ftl) = rig();
        let ns2 = kv.create_namespace(DevLsmConfig::default());
        kv.put(0, 0, e(1, 1), &mut nand, &mut ftl).unwrap();
        kv.put(ns2, 0, e(2, 1), &mut nand, &mut ftl).unwrap();
        kv.reset(0, 0, &mut ftl).unwrap();
        assert!(kv.ns(0).unwrap().is_empty());
        assert!(!kv.ns(ns2).unwrap().is_empty());
    }
}
